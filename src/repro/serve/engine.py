"""Serving engine: batched prefill + decode with sampling, plus the
cascade-serving combinator (the paper's filter-before-the-expensive-block
insight applied to an inference fleet).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.cascade import Stage, compacting_cascade


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    temperature: float = 0.0      # 0 => greedy
    top_k: int = 0                # 0 => off


def sample(logits, key, cfg: SamplerConfig):
    """logits: (b, vocab) -> (b,) int32."""
    if cfg.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits.astype(jnp.float32) / cfg.temperature
    vocab = logits.shape[-1]
    # top_k >= vocab keeps the whole distribution (and top_k == 0 means
    # off); only a proper subset needs the kth-value filter — the raw
    # ``[:, -top_k]`` index wraps around for top_k > vocab
    k = min(int(cfg.top_k), vocab)
    if 0 < k < vocab:
        kth = jnp.sort(logits, axis=-1)[:, vocab - k][:, None]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    return jax.random.categorical(key, logits).astype(jnp.int32)


def generate(model, params, prompt, n_tokens: int, *, enc_out=None,
             sampler: SamplerConfig = SamplerConfig(), seed: int = 0):
    """Prefill the prompt, then scan n_tokens greedy/sampled decode steps.

    prompt: (b, s) int32.  Returns (b, n_tokens) int32.
    """
    b, s = prompt.shape
    last_logits, cache = model.prefill(params, prompt, enc_out)
    cache = model.pad_cache(cache, n_tokens)
    key = jax.random.PRNGKey(seed)

    def body(carry, t):
        cache, logits, key = carry
        key, sub = jax.random.split(key)
        tok = sample(logits, sub, sampler)
        new_logits, cache = model.decode_step(
            params, tok[:, None], cache, s + t)
        return (cache, new_logits[:, 0], key), tok

    (_, _, _), toks = jax.lax.scan(
        body, (cache, last_logits, key), jnp.arange(n_tokens, dtype=jnp.int32))
    return jnp.moveaxis(toks, 0, 1)


# ---------------------------------------------------------------------------
# Cascade serving (paper §III at cluster scale)
# ---------------------------------------------------------------------------


def cascade_serve(scorer_fn, big_model_fn, requests, *, threshold: float,
                  capacity_fraction: float = 0.25,
                  capacity: int | None = None):
    """Run a cheap scorer over all requests; only survivors (bounded by a
    static capacity) reach the big model — 'Viola-Jones in front of the NN'
    for an inference cluster.

    scorer_fn:   (batch_items) -> scores (b,)   — cheap (small model / heuristic)
    big_model_fn:(batch_items) -> outputs, any pytree with leading batch axis
    ``capacity`` is the absolute big-model batch (clamped to [1, b]);
    when None it derives from ``capacity_fraction``.

    Returns ``(outputs, served, stats)``: outputs is the big model's pytree
    scattered back to the request index space (zeros for non-served rows),
    ``served`` the (b,) bool mask of requests that reached the big model.
    Capacity is enforced *inside* the compacting cascade (a zero-cost
    admit stage bounded to ``capacity``), so ``stats['n_dropped_capacity']``
    is the cascade's own overflow count, and the dropped survivors are
    surfaced deterministically: the cascade compacts with a stable argsort
    on the live mask (original-index tie-break), so the kept set is always
    the ``capacity`` lowest-indexed survivors and
    ``stats['dropped_capacity_idx']`` lists the overflowed survivor indices
    ascending, padded with -1 — a caller (the streaming runtime) can
    re-queue exactly those requests.
    """
    b = requests.shape[0]
    cap = int(b * capacity_fraction) if capacity is None else int(capacity)
    cap = max(1, min(cap, b))

    def admit(items):
        return jnp.zeros((items.shape[0],), jnp.float32)

    res = compacting_cascade(
        [Stage(scorer_fn, threshold, "scorer"),
         Stage(admit, -jnp.inf, "capacity")],
        requests, capacities=[b, cap])
    scorer_mask = res.scores[0] >= threshold
    served = res.mask                       # survivors that fit the capacity

    # rebuild the cascade's compaction permutation (same stable argsort on
    # the post-scorer mask) to gather the big-model sub-batch
    order = jnp.argsort(jnp.where(scorer_mask, 0, 1), stable=True)
    picked = order[:cap]
    sub_batch = jnp.take(requests, picked, axis=0)
    sub_out = big_model_fn(sub_batch)

    def scatter(leaf):
        out = jnp.zeros((b,) + leaf.shape[1:], leaf.dtype).at[picked].set(leaf)
        keep = served.reshape((b,) + (1,) * (out.ndim - 1))
        return jnp.where(keep, out, jnp.zeros_like(out))

    outputs = jax.tree_util.tree_map(scatter, sub_out)
    idx = jnp.arange(b, dtype=jnp.int32)
    dropped = scorer_mask & ~served
    dropped_idx = jnp.sort(jnp.where(dropped, idx, jnp.int32(b)))
    stats = {
        "n_candidates": res.n_survivors[0],
        "n_served": res.n_survivors[1],
        "n_dropped_capacity": res.dropped[1],
        "dropped_capacity_idx": jnp.where(dropped_idx == b, -1, dropped_idx),
    }
    return outputs, served, stats
