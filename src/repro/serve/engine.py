"""Serving engine: batched prefill + decode with sampling, plus the
cascade-serving combinator (the paper's filter-before-the-expensive-block
insight applied to an inference fleet).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.cascade import Stage, compacting_cascade


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    temperature: float = 0.0      # 0 => greedy
    top_k: int = 0                # 0 => off


def sample(logits, key, cfg: SamplerConfig):
    """logits: (b, vocab) -> (b,) int32."""
    if cfg.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits.astype(jnp.float32) / cfg.temperature
    if cfg.top_k:
        kth = jnp.sort(logits, axis=-1)[:, -cfg.top_k][:, None]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    return jax.random.categorical(key, logits).astype(jnp.int32)


def generate(model, params, prompt, n_tokens: int, *, enc_out=None,
             sampler: SamplerConfig = SamplerConfig(), seed: int = 0):
    """Prefill the prompt, then scan n_tokens greedy/sampled decode steps.

    prompt: (b, s) int32.  Returns (b, n_tokens) int32.
    """
    b, s = prompt.shape
    last_logits, cache = model.prefill(params, prompt, enc_out)
    cache = model.pad_cache(cache, n_tokens)
    key = jax.random.PRNGKey(seed)

    def body(carry, t):
        cache, logits, key = carry
        key, sub = jax.random.split(key)
        tok = sample(logits, sub, sampler)
        new_logits, cache = model.decode_step(
            params, tok[:, None], cache, s + t)
        return (cache, new_logits[:, 0], key), tok

    (_, _, _), toks = jax.lax.scan(
        body, (cache, last_logits, key), jnp.arange(n_tokens, dtype=jnp.int32))
    return jnp.moveaxis(toks, 0, 1)


# ---------------------------------------------------------------------------
# Cascade serving (paper §III at cluster scale)
# ---------------------------------------------------------------------------


def cascade_serve(scorer_fn, big_model_fn, requests, *, threshold: float,
                  capacity_fraction: float = 0.25):
    """Run a cheap scorer over all requests; only survivors (bounded by a
    static capacity) reach the big model — 'Viola-Jones in front of the NN'
    for an inference cluster.

    scorer_fn:   (batch_items) -> scores (b,)   — cheap (small model / heuristic)
    big_model_fn:(batch_items) -> outputs (b, ...) — expensive
    Returns (outputs (b, ...) with zeros for filtered, mask, stats).
    """
    b = requests.shape[0]
    cap = max(1, int(b * capacity_fraction))
    res = compacting_cascade(
        [Stage(scorer_fn, threshold, "scorer")], requests, capacities=[b])
    mask = res.mask

    # compact survivors to a static capacity batch for the big model
    order = jnp.argsort(jnp.where(mask, 0, 1), stable=True)
    picked = order[:cap]
    sub_batch = jnp.take(requests, picked, axis=0)
    sub_out = big_model_fn(sub_batch)
    out_shape = (b,) + sub_out.shape[1:]
    outputs = jnp.zeros(out_shape, sub_out.dtype).at[picked].set(sub_out)
    picked_mask = jnp.zeros((b,), bool).at[picked].set(True)
    served = picked_mask & mask
    stats = {
        "n_candidates": jnp.sum(mask).astype(jnp.int32),
        "n_served": jnp.sum(served).astype(jnp.int32),
        "n_dropped_capacity": (jnp.sum(mask) - jnp.sum(served)).astype(jnp.int32),
    }
    return jnp.where(served[(...,) + (None,) * (outputs.ndim - 1)], outputs, 0), served, stats
