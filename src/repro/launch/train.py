"""Production training entry point.

    PYTHONPATH=src python -m repro.launch.train --arch yi-9b --steps 200 \
        [--smoke] [--mesh 2,2,2] [--plan auto] [--grad-compress] \
        [--ckpt-dir /path] [--global-batch 16] [--seq 64]

On real hardware the mesh comes from the TPU topology; on CPU pass
``--mesh`` with fake devices via XLA_FLAGS, or omit for single-device.
Resumes automatically from the newest checkpoint in --ckpt-dir.
"""

from __future__ import annotations

import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b")
    ap.add_argument("--smoke", action="store_true", default=True,
                    help="reduced config (full configs need a real pod)")
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--mesh", default="", help="e.g. '2,2,2' => (pod,data,model)")
    ap.add_argument("--plan", default="auto")
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.mesh:
        dims = [int(x) for x in args.mesh.split(",")]
        n = 1
        for d in dims:
            n *= d
        os.environ.setdefault(
            "XLA_FLAGS", f"--xla_force_host_platform_device_count={n}")

    import jax
    import jax.numpy as jnp

    from repro.configs.registry import get_config
    from repro.configs.shapes import ShapeSpec
    from repro.data.pipeline import DataConfig, batch_for_step, encdec_batch_for_step
    from repro.models.transformer import Model
    from repro.parallel.axes import use_sharding
    from repro.parallel.plans import plan_rules, recommend_plan
    from repro.train.loop import LoopConfig, train
    from repro.train.optimizer import AdamWConfig

    cfg = get_config(args.arch, smoke=args.smoke)
    model = Model(cfg)
    print(f"[train] {cfg.name}{' (reduced)' if args.smoke else ''}: "
          f"{model.n_params():,} params")

    data = DataConfig(vocab=cfg.vocab, seq=args.seq,
                      global_batch=args.global_batch, seed=args.seed)

    def make_batch(step):
        if cfg.is_encdec:
            b = encdec_batch_for_step(data, cfg.d_model, cfg.enc_seq, step)
        else:
            b = batch_for_step(data, step)
        return {k: jnp.asarray(v) for k, v in b.items()}

    loop_cfg = LoopConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                          ckpt_dir=args.ckpt_dir, accum=args.accum)
    opt_cfg = AdamWConfig(lr_peak=args.lr, warmup_steps=max(args.steps // 10, 1),
                          decay_steps=args.steps)

    def run():
        _, _, out = train(model, make_batch, loop_cfg, opt_cfg, seed=args.seed)
        hist = out["history"]
        if hist:
            print(f"[train] loss {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f}; "
                  f"median step {1e3*sorted(h['dt'] for h in hist)[len(hist)//2]:.0f} ms; "
                  f"stragglers flagged: {len(out['stragglers'])}")

    if args.mesh:
        dims = [int(x) for x in args.mesh.split(",")]
        names = ("pod", "data", "model")[-len(dims):]
        mesh = jax.make_mesh(tuple(dims), names)
        shape = ShapeSpec("cli", args.seq, args.global_batch, "train")
        plan = args.plan if args.plan != "auto" else recommend_plan(cfg, shape)
        print(f"[train] mesh {dict(zip(names, dims))} plan={plan}"
              f"{' +int8-pod-AR' if args.grad_compress else ''}")
        with use_sharding(mesh, plan_rules(plan)):
            run()
    else:
        run()


if __name__ == "__main__":
    main()
