"""Serving entry point: batched prefill + decode with optional cascade filter.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --requests 16 \
        --prompt-len 32 --gen 16 [--cascade] [--mesh 2,2]
"""

from __future__ import annotations

import argparse
import os
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--cascade", action="store_true",
                    help="cheap-scorer filter in front (paper's §III insight)")
    ap.add_argument("--mesh", default="", help="e.g. '2,2' => (data,model)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.mesh:
        dims = [int(x) for x in args.mesh.split(",")]
        n = 1
        for d in dims:
            n *= d
        os.environ.setdefault(
            "XLA_FLAGS", f"--xla_force_host_platform_device_count={n}")

    import jax
    import jax.numpy as jnp

    from repro.configs.registry import get_config
    from repro.models.transformer import Model
    from repro.parallel.axes import use_sharding
    from repro.serve.engine import SamplerConfig, cascade_serve, generate

    cfg = get_config(args.arch, smoke=args.smoke)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    prompts = jax.random.randint(jax.random.PRNGKey(args.seed + 1),
                                 (args.requests, args.prompt_len), 0, cfg.vocab)
    sampler = SamplerConfig(temperature=args.temperature)
    enc_out = None
    if cfg.is_encdec:
        enc_in = jax.random.normal(jax.random.PRNGKey(2),
                                   (args.requests, cfg.enc_seq, cfg.d_model),
                                   cfg.param_dtype)
        enc_out = model.encode(params, enc_in)

    def serve():
        t0 = time.time()
        if args.cascade:
            def scorer(batch):
                logits, _ = model.logits(params, batch[:, -8:], None)
                lg = logits[:, -1].astype(jnp.float32)
                p = jax.nn.softmax(lg, axis=-1)
                return -jnp.sum(p * jnp.log(p + 1e-9), axis=-1)

            out, served, stats = cascade_serve(
                scorer,
                lambda b: generate(model, params, b, args.gen, sampler=sampler),
                prompts, threshold=0.0, capacity_fraction=0.5)
            print(f"[serve] cascade: {int(stats['n_served'])}/{args.requests} "
                  f"served by the big model")
            toks = out
        else:
            toks = generate(model, params, prompts, args.gen, enc_out=enc_out,
                            sampler=sampler, seed=args.seed)
        toks.block_until_ready()
        dt = time.time() - t0
        n_tok = args.requests * args.gen
        print(f"[serve] {n_tok} tokens in {dt:.2f}s "
              f"({n_tok/dt:.1f} tok/s incl. prefill+compile)")
        print(f"[serve] sample row: {list(map(int, toks[0][:8]))}")

    if args.mesh:
        dims = [int(x) for x in args.mesh.split(",")]
        names = ("pod", "data", "model")[-len(dims):]
        mesh = jax.make_mesh(tuple(dims), names)
        with use_sharding(mesh):
            serve()
    else:
        serve()


if __name__ == "__main__":
    main()
