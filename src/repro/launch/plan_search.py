"""Analytic plan search: the comp-comm placement solver at the CLI.

Ranks candidate sharding plans for an (arch x shape) cell with the
three-term roofline estimator (core.placement.estimate_plan) — no
compilation.  This is `solve_cut` at pod scale (DESIGN.md §2): the same
enumerate-configurations/argmin structure the paper applies to camera
pipelines, applied to mesh placements.  The dry-run then validates the
winner against compiled HLO.

    PYTHONPATH=src python -m repro.launch.plan_search --arch yi-9b \
        --shape train_4k [--chips 256] [--pods 1]
"""

from __future__ import annotations

import argparse

from repro.configs.registry import CONFIGS
from repro.configs.shapes import SHAPES
from repro.core.placement import ShardingPlan, estimate_plan, rank_sharding
from repro.models.transformer import Model


def candidates(chips: int, pods: int):
    """Enumerate (dp, fsdp, tp) factorizations of the per-pod chip count."""
    per_pod = chips // pods
    out = []
    t = 1
    while t <= per_pod:
        rest = per_pod // t
        f = 1
        while f <= rest:
            d = rest // f
            if d * f * t == per_pod:
                out.append(ShardingPlan(f"d{d}f{f}t{t}", data=d, fsdp=f,
                                        tensor=t, pod=pods))
                if pods > 1:
                    out.append(ShardingPlan(f"d{d}f{f}t{t}+gc", data=d, fsdp=f,
                                            tensor=t, pod=pods,
                                            grad_compress=True))
            f *= 2
        t *= 2
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--chips", type=int, default=256)
    ap.add_argument("--pods", type=int, default=1)
    ap.add_argument("--top", type=int, default=12)
    args = ap.parse_args()

    cfg = CONFIGS[args.arch]
    shape = SHAPES[args.shape]
    model = Model(cfg)
    n = model.n_params()
    n_active = model.n_active_params()
    tokens = shape.batch * (shape.seq if shape.mode != "decode" else 1)

    def estimator(plan):
        return estimate_plan(
            plan, name=f"{args.arch}|{args.shape}", params=n,
            active_params=n_active, layer_flops=2 * n_active * tokens,
            train=(shape.mode == "train"), tokens=tokens,
            d_model=cfg.d_model, seq=shape.seq, batch=shape.batch,
            n_experts=(cfg.moe.n_experts if cfg.moe else 1),
            top_k=(cfg.moe.top_k if cfg.moe else 1),
            n_layers=cfg.n_layers)

    ranked = rank_sharding(candidates(args.chips, args.pods), estimator)
    print(f"{args.arch} x {args.shape} on {args.chips} chips "
          f"({args.pods} pod{'s' if args.pods > 1 else ''}); "
          f"params={n:.3e} active={n_active:.3e} tokens={tokens:,}")
    hdr = (f"{'plan':<22s} {'compute_s':>10s} {'memory_s':>10s} "
           f"{'collect_s':>10s} {'dominant':>10s} {'feasible':>9s}")
    print(hdr)
    print("-" * len(hdr))
    for s in ranked[: args.top]:
        r = s.roofline
        print(f"{s.plan.describe():<22s} {r.compute_s:>10.3f} {r.memory_s:>10.3f} "
              f"{r.collective_s:>10.3f} {r.dominant:>10s} "
              f"{'yes' if s.feasible else 'NO: ' + s.why_infeasible[:24]:>9s}")


if __name__ == "__main__":
    main()
