import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

# --- everything below may import jax -------------------------------------
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs.registry import CONFIGS, get_config, input_specs, list_archs
from repro.configs.shapes import SHAPES, applicable
from repro.core.costmodel import Roofline, TPU_V5E
from repro.launch.hlo_stats import analyze_hlo
from repro.launch.mesh import make_production_mesh, mesh_chip_count
from repro.parallel.plans import plan_rules, recommend_plan
from repro.models.layers import abstract_params
from repro.models.transformer import Model
from repro.parallel.axes import use_sharding
from repro.train.optimizer import AdamWConfig, OptState
from repro.train.step import (init_ef_states, make_prefill_step,
                             make_serve_step, make_train_step,
                             make_train_step_compressed)

"""Multi-pod dry-run (assignment deliverable e).

For every (arch x shape x mesh) cell: build abstract inputs
(ShapeDtypeStruct, zero allocation), `jit(...).lower(...).compile()` under
the production mesh, and record memory_analysis / cost_analysis /
collective-byte stats.  A cell failing to compile (sharding mismatch, OOM
at compile, unsupported collective) is a bug in this framework — the
dry-run is the proof the distribution config is coherent.

Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json; the
roofline harness (benchmarks/roofline.py) consumes them.
"""

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "experiments", "dryrun")


def rules_for_cell(cfg, shape):
    """Cell-specific sharding-rule overrides (the placement solver's pick)."""
    rules = {}
    if shape.mode == "decode" and shape.batch == 1:
        # long-context decode: batch unshardable; shard the cache/state over
        # 'data' (context parallelism) instead.
        rules["cache_seq"] = "data"
        rules["batch"] = None
    return rules


def opt_state_abstract(params_abs):
    zeros_like_f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32,
                                                    sharding=p.sharding)
    return OptState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        master=jax.tree_util.tree_map(zeros_like_f32, params_abs),
        mu=jax.tree_util.tree_map(zeros_like_f32, params_abs),
        nu=jax.tree_util.tree_map(zeros_like_f32, params_abs),
    )


def lower_cell(arch: str, shape_name: str, multi_pod: bool, verbose=True,
               accum: int = 4, plan: str = "auto", plan_overrides=None,
               grad_compress: bool = False):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    runs, why = applicable(cfg, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    cell = f"{arch}__{shape_name}__{mesh_name}"
    if not runs:
        return {"cell": cell, "status": "skipped", "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh_chip_count(mesh)
    model = Model(cfg)
    t0 = time.time()

    if plan == "auto":
        plan = recommend_plan(cfg, shape)
    rules = plan_rules(plan)
    rules.update(rules_for_cell(cfg, shape))
    if plan_overrides:
        rules.update(plan_overrides)
    with use_sharding(mesh, rules) as ctx:
        params_abs = abstract_params(model.specs(), ctx)
        inputs = input_specs(cfg, shape, ctx)

        if shape.mode == "train":
            opt_abs = opt_state_abstract(params_abs)
            batch = {"tokens": inputs["tokens"]}
            if cfg.is_encdec:
                batch["enc_input"] = inputs["enc_input"]
            if grad_compress and multi_pod:
                # int8+EF gradient exchange across pods (core/reduction)
                step = make_train_step_compressed(model, AdamWConfig())
                ef_abs = jax.tree_util.tree_map(
                    lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32,
                                                   sharding=p.sharding),
                    params_abs)
                fn = jax.jit(step, donate_argnums=(0, 1, 2))
                lowered = fn.lower(params_abs, opt_abs, ef_abs, batch)
            else:
                step = make_train_step(model, AdamWConfig(), accum=accum)
                fn = jax.jit(step, donate_argnums=(0, 1))
                lowered = fn.lower(params_abs, opt_abs, batch)
        elif shape.mode == "prefill":
            step = make_prefill_step(model)
            batch = {"tokens": inputs["tokens"]}
            if cfg.is_encdec:
                batch["enc_input"] = inputs["enc_input"]
            fn = jax.jit(step)
            lowered = fn.lower(params_abs, batch)
        else:  # decode
            step = make_serve_step(model)
            fn = jax.jit(step, donate_argnums=(2,))
            lowered = fn.lower(params_abs, inputs["token"], inputs["cache"],
                               inputs["position"])

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = analyze_hlo(compiled.as_text())

    mem_dict = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        mem_dict[k] = getattr(mem, k, None)

    # cost_analysis is per-device and does NOT multiply while-loop bodies
    # (measured; see EXPERIMENTS.md §Dry-run methodology) — kept for
    # reference; the roofline uses the loop-aware analyzer, scaled to
    # global by chip count.
    xla_flops_perdev = float(cost.get("flops", 0.0)) if cost else 0.0
    xla_bytes_perdev = float(cost.get("bytes accessed", 0.0)) if cost else 0.0

    tokens = shape.batch * (shape.seq if shape.mode in ("train", "prefill") else 1)
    mult = 6.0 if shape.mode == "train" else 2.0
    model_flops = mult * model.n_active_params() * tokens

    rec = {
        "cell": cell,
        "status": "ok",
        "arch": arch,
        "shape": shape_name,
        "mode": shape.mode,
        "mesh": mesh_name,
        "plan": plan,
        "accum": accum,
        "grad_compress": bool(grad_compress and multi_pod),
        "chips": chips,
        "n_params": model.n_params(),
        "n_active_params": model.n_active_params(),
        "tokens": tokens,
        "model_flops": model_flops,
        "hlo_flops": hlo.flops * chips,                 # global, loop-aware
        "hlo_bytes": hlo.bytes_rw * chips,              # global r/w proxy
        "collective_bytes": hlo.collective_bytes * chips,
        "collective_detail": {k: v * chips for k, v in hlo.coll_bytes_by_op.items()},
        "collective_counts": dict(hlo.coll_count_by_op),
        "xla_cost_analysis": {"flops_per_device": xla_flops_perdev,
                              "bytes_per_device": xla_bytes_perdev},
        "memory_analysis": mem_dict,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
    }
    if verbose:
        print(f"[{cell}] OK lower={t_lower:.1f}s compile={t_compile:.1f}s")
        print(f"  memory_analysis(per-device): {mem_dict}")
        print(hlo.describe())
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all", help="shape name or 'all'")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--plan", default="auto")
    ap.add_argument("--accum", type=int, default=4)
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--out", default=RESULTS_DIR)
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    archs = list_archs() if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    failures = []
    for arch in archs:
        for shape_name in shapes:
            for multi_pod in meshes:
                mesh_name = "2x16x16" if multi_pod else "16x16"
                cell = f"{arch}__{shape_name}__{mesh_name}"
                path = os.path.join(args.out, cell + ".json")
                try:
                    rec = lower_cell(arch, shape_name, multi_pod,
                                     accum=args.accum, plan=args.plan,
                                     grad_compress=args.grad_compress)
                except Exception as e:  # noqa: BLE001 — record and continue
                    traceback.print_exc()
                    rec = {"cell": cell, "status": "failed", "error": str(e)[-2000:]}
                    failures.append(cell)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
    if failures:
        print(f"\nFAILED cells ({len(failures)}):")
        for c in failures:
            print(" ", c)
        raise SystemExit(1)
    print("\nAll requested cells passed the dry-run.")


if __name__ == "__main__":
    main()
