"""Production mesh construction (assignment §Multi-pod dry-run).

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — smoke tests must keep seeing
one device; only dryrun.py sets the 512-device XLA flag before first init.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU multi-device tests (host-device-count set by the
    test runner, not here)."""
    return jax.make_mesh(shape, axes)


def mesh_chip_count(mesh) -> int:
    n = 1
    for s in mesh.devices.shape:
        n *= s
    return n
