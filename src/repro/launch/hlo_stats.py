"""Loop-aware HLO analysis: FLOPs, byte traffic, collective bytes.

Why not just ``compiled.cost_analysis()``?  Two measured facts (see
EXPERIMENTS.md §Dry-run methodology):

1. it reports **per-device** numbers for SPMD modules, and
2. it counts ``while`` loop bodies **once**, so a scan-over-layers model is
   undercounted by ~n_layers x.

Since the framework deliberately scans layers (compile-time sanity at 512
devices), we parse the post-optimization HLO ourselves:

* split the module into computations and build a per-computation symbol
  table (operand shapes are not inlined in post-opt HLO);
* walk the call graph (while/call/fusion/conditional edges), multiplying
  while bodies by their trip count (``known_trip_count`` backend config,
  falling back to the loop-condition constant);
* FLOPs: dots = 2 * |out| * k from resolved operand shapes + contracting
  dims; elementwise arithmetic ops = |out| (keeps elementwise-heavy models
  like RWKV honest);
* memory traffic: op-aware read+write proxy (dynamic-slice/gather count
  their slice, not the sliced buffer; DUS counts the update region);
* collective result-shape bytes by op kind.

All numbers are per-device (shapes in partitioned HLO are shard shapes);
the dry-run multiplies by chip count to report global quantities.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# NB: tuple shapes may contain /*index=N*/ comments (hence [^()] not [^=])
_LINE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*((?:\([^()]*\))|(?:[\w\-]+\[[\d,]*\](?:{[^}]*})?))\s*"
    r"([\w\-]+)\(([^\n]*)$")

_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")

_CONST_RE = re.compile(r"[su]\d+\[\]\s+constant\((\d+)\)")
_TRIP_RE = re.compile(r'"known_trip_count":\s*{"n":\s*"(\d+)"')
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_WHILE_RE = re.compile(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations={([^}]*)}")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims={([\d,]*)}")

_TRIVIAL = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "reshape", "copy-done",
    "all-gather-done", "all-reduce-done", "collective-permute-done",
}

# Memory-traffic threshold: tensors below this stay in VMEM/registers on the
# TPU target (loop-carried scan state, scalars, small reductions) and are not
# charged as HBM traffic.  Without it, per-step values of a 4096-iteration
# sequence scan dominate the byte count and the memory roofline term is
# nonsense (measured: rwkv train "memory_s" = 1e5 s).  1 MiB is conservative:
# v5e VMEM is two orders larger.
_HBM_MIN_BYTES = 1 << 20

# elementwise ops counted as 1 flop / output element
_ARITH = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "exponential-minus-one", "log", "log-plus-one", "tanh",
    "rsqrt", "sqrt", "negate", "abs", "floor", "ceil", "sign", "cosine",
    "sine", "logistic", "atan2", "remainder", "select", "compare", "and",
    "or", "xor", "not", "clamp", "convert", "reduce", "erf",
}


def _shape_elems_bytes(s: str):
    elems, total = 0, 0
    for m in _SHAPE_RE.finditer(s):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        total += n * _DTYPE_BYTES[dt]
    return elems, total


def _lhs_shape(s: str):
    m = _SHAPE_RE.search(s)
    if not m:
        return ()
    return tuple(int(d) for d in m.group(2).split(",") if d)


@dataclasses.dataclass
class Instr:
    name: str
    shape_str: str
    op: str
    rest: str


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool = False
    instrs: list = dataclasses.field(default_factory=list)
    consts: list = dataclasses.field(default_factory=list)


def _split_computations(hlo_text: str):
    comps = []
    cur = None
    depth = 0
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HDR_RE.match(line)
            if m and line.endswith("{"):
                cur = Computation(name=m.group(2), is_entry=bool(m.group(1)))
                depth = 1
            continue
        depth += line.count("{") - line.count("}")
        if depth <= 0:
            comps.append(cur)
            cur = None
            continue
        lm = _LINE_RE.match(line)
        if lm:
            cur.instrs.append(Instr(lm.group(1), lm.group(2), lm.group(3),
                                    lm.group(4)))
        for cm in _CONST_RE.finditer(line):
            cur.consts.append(int(cm.group(1)))
    if cur is not None:
        comps.append(cur)
    return comps


@dataclasses.dataclass
class CompStats:
    flops: float = 0.0
    bytes_rw: float = 0.0
    coll_bytes: dict = dataclasses.field(default_factory=lambda: defaultdict(float))
    coll_count: dict = dataclasses.field(default_factory=lambda: defaultdict(float))
    whiles: list = dataclasses.field(default_factory=list)   # (body, trip)
    callees: list = dataclasses.field(default_factory=list)


def _analyze_computation(comp: Computation, cond_consts: dict) -> CompStats:
    st = CompStats()
    table = {}   # instr name -> (elems, bytes) of its output
    for ins in comp.instrs:
        table[ins.name] = _shape_elems_bytes(ins.shape_str)

    def operand_sizes(rest: str, limit_paren=True):
        # operands live before the first "), " after the open paren
        args = rest.split(")", 1)[0] if limit_paren else rest
        out = []
        for m in _OPERAND_RE.finditer(args):
            if m.group(1) in table:
                out.append(table[m.group(1)])
        return out

    for ins in comp.instrs:
        out_elems, out_bytes = table[ins.name]
        op = ins.op
        base = op.replace("-start", "")

        # call-graph edges
        wm = _WHILE_RE.search(ins.rest)
        if op == "while" and wm:
            trip_m = _TRIP_RE.search(ins.rest)
            if trip_m:
                trip = int(trip_m.group(1))
            else:
                trip = cond_consts.get(wm.group(1), 1)
            st.whiles.append((wm.group(2), trip))
        else:
            for cm in _CALLS_RE.finditer(ins.rest):
                st.callees.append(cm.group(1))
            bm = _BRANCH_RE.search(ins.rest)
            if bm:
                st.callees.extend(x.strip().lstrip("%") for x in bm.group(1).split(","))

        if op in _TRIVIAL or op == "while":
            continue

        if base in _COLLECTIVES:
            st.coll_bytes[base] += out_bytes
            st.coll_count[base] += 1
            if out_bytes >= _HBM_MIN_BYTES:
                st.bytes_rw += 2 * out_bytes
            continue

        if op == "dot":
            ops_sz = operand_sizes(ins.rest)
            lhs = None
            args = ins.rest.split(")", 1)[0]
            names = _OPERAND_RE.findall(args)
            k = 1
            cm = _LHS_CONTRACT_RE.search(ins.rest)
            if cm and names:
                # resolve lhs dims from the defining instruction's shape str
                lhs_name = names[0]
                lhs_shape = ()
                for other in comp.instrs:
                    if other.name == lhs_name:
                        lhs_shape = _lhs_shape(other.shape_str)
                        break
                for ci in [int(x) for x in cm.group(1).split(",") if x]:
                    if ci < len(lhs_shape):
                        k *= lhs_shape[ci]
            st.flops += 2.0 * out_elems * k
            st.bytes_rw += sum(b for b in [out_bytes] + [b for _, b in ops_sz]
                               if b >= _HBM_MIN_BYTES)
            continue

        if op in ("dynamic-slice", "gather"):
            if out_bytes >= _HBM_MIN_BYTES:
                st.bytes_rw += 2 * out_bytes
            continue
        if op == "dynamic-update-slice":
            ops_sz = operand_sizes(ins.rest)
            upd = ops_sz[1][1] if len(ops_sz) > 1 else out_bytes
            if upd >= _HBM_MIN_BYTES:
                st.bytes_rw += 2 * upd
            continue
        if op == "scatter":
            ops_sz = operand_sizes(ins.rest)
            upd = ops_sz[2][1] if len(ops_sz) > 2 else out_bytes
            if upd >= _HBM_MIN_BYTES:
                st.bytes_rw += 2 * upd
            continue

        if op in _ARITH:
            st.flops += out_elems
        st.bytes_rw += sum(b for b in [out_bytes]
                           + [b for _, b in operand_sizes(ins.rest)]
                           if b >= _HBM_MIN_BYTES)
    return st


@dataclasses.dataclass
class HLOAnalysis:
    """Per-device, loop-multiplied totals for one compiled module."""

    flops: float
    bytes_rw: float
    coll_bytes_by_op: dict
    coll_count_by_op: dict
    n_computations: int

    @property
    def collective_bytes(self) -> float:
        return float(sum(self.coll_bytes_by_op.values()))

    # aliases kept for earlier call sites
    @property
    def bytes_by_op(self):
        return self.coll_bytes_by_op

    @property
    def count_by_op(self):
        return self.coll_count_by_op

    @property
    def total_bytes(self):
        return self.collective_bytes

    def describe(self) -> str:
        lines = [f"  flops (loop-mult, per-device): {self.flops:.4e}",
                 f"  bytes r/w proxy (per-device):  {self.bytes_rw:.4e}"]
        for op in sorted(self.coll_bytes_by_op):
            lines.append(
                f"  {op:>20s}: {self.coll_count_by_op[op]:10.0f} ops, "
                f"{self.coll_bytes_by_op[op]/2**30:12.5f} GiB")
        lines.append(f"  {'collective TOTAL':>20s}: {'':16s} "
                     f"{self.collective_bytes/2**30:12.5f} GiB")
        return "\n".join(lines)


def analyze_hlo(hlo_text: str) -> HLOAnalysis:
    comps = {c.name: c for c in _split_computations(hlo_text)}
    cond_consts = {c.name: (max(c.consts) if c.consts else 1) for c in comps.values()}
    stats = {name: _analyze_computation(c, cond_consts) for name, c in comps.items()}
    entry = next((c.name for c in comps.values() if c.is_entry), None)
    if entry is None:
        return HLOAnalysis(0.0, 0.0, {}, {}, len(comps))

    flops = 0.0
    bytes_rw = 0.0
    coll_b = defaultdict(float)
    coll_c = defaultdict(float)

    def accumulate(name: str, mult: float, stack):
        if name not in stats or name in stack:
            return
        nonlocal flops, bytes_rw
        st = stats[name]
        flops += st.flops * mult
        bytes_rw += st.bytes_rw * mult
        for op, b in st.coll_bytes.items():
            coll_b[op] += b * mult
            coll_c[op] += st.coll_count[op] * mult
        stack = stack | {name}
        for body, trip in st.whiles:
            accumulate(body, mult * trip, stack)
        for callee in st.callees:
            accumulate(callee, mult, stack)

    accumulate(entry, 1.0, frozenset())
    return HLOAnalysis(flops, bytes_rw, dict(coll_b), dict(coll_c), len(comps))


def collective_bytes(hlo_text: str) -> HLOAnalysis:
    return analyze_hlo(hlo_text)
