"""Findings, reports, and the baseline workflow (DESIGN.md §11).

A *finding* is one violation of a statically checkable contract, keyed by a
stable fingerprint (family|code|subject|where).  The checked-in
``analysis/baseline.json`` holds the fingerprints of findings the repo has
explicitly accepted; tier-1 fails on anything NOT in the baseline, so a new
violation can land only by editing the baseline in the same diff — which is
exactly the review surface we want.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from typing import Iterable


FAMILIES = ("dispatch", "precision", "kernel", "cut", "obs")

DEFAULT_BASELINE_PATH = os.path.join(os.path.dirname(__file__),
                                     "baseline.json")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One contract violation, locatable and fingerprint-stable."""

    family: str    # one of FAMILIES
    code: str      # short id, e.g. "D004" — stable across sessions
    subject: str   # analyzed unit: executor target, kernel, or cut name
    where: str     # stable location inside the subject (eqn path, field, ...)
    message: str   # human-readable description; NOT part of the fingerprint
    severity: str = "error"

    @property
    def fingerprint(self) -> str:
        key = "|".join((self.family, self.code, self.subject, self.where))
        return hashlib.sha256(key.encode()).hexdigest()[:16]

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["fingerprint"] = self.fingerprint
        return d

    def __str__(self) -> str:
        return (f"[{self.code}/{self.severity}] {self.subject} @ {self.where}"
                f": {self.message}")


@dataclasses.dataclass
class PassResult:
    """Outcome of one pass family over every subject it analyzed."""

    family: str
    subjects: list          # names of analyzed units (even if clean)
    findings: list          # list[Finding]


@dataclasses.dataclass
class AnalysisReport:
    results: list           # list[PassResult]

    @property
    def findings(self):
        return [f for r in self.results for f in r.findings]

    @property
    def subjects(self):
        return {r.family: list(r.subjects) for r in self.results}

    def new_findings(self, baseline: "Baseline | None"):
        """Findings whose fingerprint is not baselined (all, if strict)."""
        if baseline is None:
            return list(self.findings)
        return [f for f in self.findings
                if f.fingerprint not in baseline.fingerprints]

    def to_dict(self, baseline: "Baseline | None" = None) -> dict:
        new = self.new_findings(baseline)
        return {
            "schema": "repro.analysis/v1",
            "families": {
                r.family: {
                    "subjects": list(r.subjects),
                    "findings": [f.to_dict() for f in r.findings],
                }
                for r in self.results
            },
            "totals": {
                "subjects": sum(len(r.subjects) for r in self.results),
                "findings": len(self.findings),
                "baselined": len(self.findings) - len(new),
                "non_baselined": len(new),
            },
        }

    def summary_lines(self, baseline: "Baseline | None" = None):
        lines = []
        for r in self.results:
            lines.append(f"{r.family}: {len(r.subjects)} subjects, "
                         f"{len(r.findings)} findings")
        new = self.new_findings(baseline)
        lines.append(f"total findings: {len(self.findings)} "
                     f"({len(new)} not baselined)")
        return lines


class Baseline:
    """Accepted-finding fingerprints, persisted as JSON."""

    def __init__(self, entries: Iterable[dict] = ()):
        self.entries = list(entries)
        self.fingerprints = {e["fingerprint"] for e in self.entries}

    @classmethod
    def load(cls, path: str = DEFAULT_BASELINE_PATH) -> "Baseline":
        if not os.path.exists(path):
            return cls()
        with open(path) as fh:
            data = json.load(fh)
        return cls(data.get("accepted", []))

    @classmethod
    def from_report(cls, report: AnalysisReport) -> "Baseline":
        return cls([
            {"fingerprint": f.fingerprint, "family": f.family,
             "code": f.code, "subject": f.subject, "where": f.where,
             "message": f.message}
            for f in report.findings
        ])

    def save(self, path: str = DEFAULT_BASELINE_PATH) -> None:
        entries = sorted(self.entries, key=lambda e: (
            e["family"], e["code"], e["subject"], e["where"]))
        with open(path, "w") as fh:
            json.dump({"schema": "repro.analysis.baseline/v1",
                       "accepted": entries}, fh, indent=2, sort_keys=True)
            fh.write("\n")
