"""Builds the analyzed universe (DESIGN.md §11).

Small deterministic instances of every registered executor — the fused
§III funnel, the §IV rig, and both offload families' node/cloud halves at
every legal cut — plus the kernel ANALYSIS hooks.  Construction trains the
toy detector/NN once per process (cached); analysis itself never runs the
pipelines, it only traces them.
"""

from __future__ import annotations

import dataclasses
import functools
import importlib
import pkgutil


@dataclasses.dataclass
class ExecutorTarget:
    """One traceable unit for the jaxpr passes."""

    name: str
    fn: object               # callable to jax.make_jaxpr
    args: tuple              # concrete example arrays / avals
    lut_pairs: tuple = ()    # ((lut, meta), ...) for the P003 spec check


@dataclasses.dataclass
class CutFamily:
    """One offload executor family for the cut-soundness pass."""

    name: str
    executor_cls: type
    make: object             # (cut, bits) -> offload executor
    node_args: object        # (offload_ex) -> node-half example args
    template_blocks: tuple   # analytic pipeline block names
    # expected session-layer sideband spec for pass C006; None means the
    # canonical payloads.SESSION_SIDEBAND (seq/crc/attempt, uint32/int32)
    session_spec: object = None


@functools.lru_cache(maxsize=None)
def _fa_base():
    import jax.numpy as jnp

    from repro.camera.face_nn import train_face_nn
    from repro.camera.pipelines import FaceAuthExecutor
    from repro.camera.synthetic import face_dataset, security_video
    from repro.camera.viola_jones import make_feature_pool, train_cascade

    frames, _ = security_video(n_frames=10, motion_frames=5, seed=1)
    X, y, _ = face_dataset(n_per_class=80, seed=3)
    casc = train_cascade(X, y, make_feature_pool(n=60), n_stages=2,
                         per_stage=6, seed=0)
    nn = train_face_nn(X, y, steps=60)
    ex = FaceAuthExecutor(casc, nn, frames.shape[1], frames.shape[2],
                          scale_factor=1.6, step=8.0, adaptive=False)
    ex.calibrate(frames)
    return ex, jnp.asarray(frames)


@functools.lru_cache(maxsize=None)
def _vr_base():
    import numpy as np
    import jax.numpy as jnp

    from repro.camera.bssa import GridSpec
    from repro.camera.pipelines import VRRigExecutor
    from repro.camera.synthetic import stereo_pair

    pairs = [stereo_pair(h=48, w=64, max_disp=6, seed=s) for s in (2, 3)]
    lefts = jnp.asarray(np.stack([p[0] for p in pairs]))
    rights = jnp.asarray(np.stack([p[1] for p in pairs]))
    ex = VRRigExecutor(GridSpec(sigma_spatial=8), max_disp=6, n_iters=2,
                       rig_parallel=False)
    return ex, lefts, rights


def _zeros_like_avals(avals):
    import jax
    import jax.numpy as jnp

    return jax.tree_util.tree_map(
        lambda a: jnp.zeros(a.shape, a.dtype), avals)


def build_targets():
    """Every traceable executor unit for the dispatch/precision passes."""
    import functools as ft

    import jax

    from repro.camera.offload.executors import (FaceAuthOffloadExecutor,
                                                VROffloadExecutor)
    from repro.kernels.quant_matmul.ops import nn_forward_quantized
    from repro.kernels.wire_codec.ops import wire_roundtrip

    targets = []
    fa, frames = _fa_base()
    targets.append(ExecutorTarget(
        "face_auth.funnel", fa._funnel, (frames,) + tuple(fa._consts),
        lut_pairs=((fa.lut, fa.lut_meta),)))

    vr, lefts, rights = _vr_base()
    targets.append(ExecutorTarget(
        "vr_rig.depth", jax.vmap(vr.pair_depth), (lefts, rights)))
    import jax.numpy as jnp
    depths0 = jnp.zeros(lefts.shape, jnp.float32)
    targets.append(ExecutorTarget(
        "vr_rig.panorama", vr.pano_fn, (lefts, rights, depths0)))

    for cut in FaceAuthOffloadExecutor.CUTS:
        for bits in (None, 8):
            off = FaceAuthOffloadExecutor(fa, cut, bits=bits,
                                          use_pallas=False)
            tag = f"fa_offload[{cut},{bits or 'raw'}]"
            node_args = (frames,) + tuple(off._consts)
            targets.append(ExecutorTarget(
                f"{tag}.node", off._node_fn, node_args,
                lut_pairs=((fa.lut, fa.lut_meta),)))
            avals, _ = jax.eval_shape(off._node_fn, *node_args)
            cloud = ft.partial(off._cloud_fn,
                               frames_shape=tuple(frames.shape))
            targets.append(ExecutorTarget(
                f"{tag}.cloud", cloud,
                (_zeros_like_avals(avals),) + tuple(off._consts),
                lut_pairs=((fa.lut, fa.lut_meta),)))

    for cut in VROffloadExecutor.CUTS:
        for bits in (None, 8):
            off = VROffloadExecutor(vr, cut, bits=bits, use_pallas=False)
            tag = f"vr_offload[{cut},{bits or 'raw'}]"
            targets.append(ExecutorTarget(
                f"{tag}.node", off._node_fn, (lefts, rights)))
            avals, _ = jax.eval_shape(off._node_fn, lefts, rights)
            pano_shapes = None
            if cut == "stitch":
                lp, rp = jax.eval_shape(
                    lambda l, r: off._pano(l, r, off._depth(l, r)),
                    lefts, rights)
                pano_shapes = (tuple(lp.shape), tuple(rp.shape))
            cloud = off._cloud_fn_for((tuple(lefts.shape), pano_shapes))
            targets.append(ExecutorTarget(
                f"{tag}.cloud", cloud, (_zeros_like_avals(avals),)))

    # serving-runtime jit units (DESIGN.md §13): the re-entrant micro-batch
    # step, the fused node+cloud placement-group step, and the bugfixed
    # cascade_serve admission path the scheduler dispatches every tick
    from repro.serve.engine import cascade_serve

    S, chunk = 3, 4
    sframes = jnp.stack([frames[:chunk]] * S)
    svalid = jnp.ones((S,), bool)
    bstep = fa.batch_step(S, chunk)
    targets.append(ExecutorTarget(
        f"serve.batch_step[{S}x{chunk}]", bstep._core,
        (sframes, svalid) + tuple(bstep._consts),
        lut_pairs=((fa.lut, fa.lut_meta),)))

    off8 = FaceAuthOffloadExecutor(fa, "vj", bits=8, use_pallas=False)
    gshape = (chunk,) + tuple(frames.shape[1:])

    def group_one(fr, *c):
        arrays, wire_b = off8._node_fn(fr, *c)
        out = dict(off8._cloud_fn(arrays, *c, frames_shape=gshape))
        out["wire_b"] = wire_b
        return out

    targets.append(ExecutorTarget(
        "serve.group_step[vj,8]",
        jax.vmap(group_one, in_axes=(0,) + (None,) * len(off8._consts)),
        (sframes,) + tuple(off8._consts),
        lut_pairs=((fa.lut, fa.lut_meta),)))

    # chaos-plane jit units (DESIGN.md §14): the degraded placement-group
    # step a ladder rung below the granted cut (bits=4 is a precision
    # surface no §10 offload target covers), and the restore path's first
    # traced compute — chunk motion scoring over queue stacks rebuilt
    # from a server checkpoint
    off4 = FaceAuthOffloadExecutor(fa, "vj", bits=4, use_pallas=False)

    def group_one_degraded(fr, *c):
        arrays, wire_b = off4._node_fn(fr, *c)
        out = dict(off4._cloud_fn(arrays, *c, frames_shape=gshape))
        out["wire_b"] = wire_b
        return out

    targets.append(ExecutorTarget(
        "serve.group_step_degraded[vj,4]",
        jax.vmap(group_one_degraded,
                 in_axes=(0,) + (None,) * len(off4._consts)),
        (sframes,) + tuple(off4._consts),
        lut_pairs=((fa.lut, fa.lut_meta),)))

    from repro.camera.serve.runtime import chunk_motion_scores

    targets.append(ExecutorTarget(
        "serve.restore_rescore",
        ft.partial(chunk_motion_scores, motion_factor=fa.motion_factor),
        (sframes,)))

    def admit_path(reqs):
        scorer = lambda x: jnp.mean(jnp.abs(x), axis=(1, 2, 3))  # noqa: E731
        return cascade_serve(scorer, lambda x: {"y": x * 2.0}, reqs,
                             threshold=0.5, capacity=2)

    targets.append(ExecutorTarget(
        "serve.cascade_admit", admit_path,
        (jnp.zeros((6, chunk, 8, 8), jnp.float32),)))

    # dedicated precision subgraphs: the quantized NN tail + the codec
    qnn, lut, meta = fa.qnn, fa.lut, fa.lut_meta
    X8 = jnp.zeros((8, qnn.w1_q.shape[0]), jnp.float32)
    targets.append(ExecutorTarget(
        "quant.nn_forward",
        lambda x: nn_forward_quantized(qnn, x, lut, meta, use_pallas=False),
        (X8,), lut_pairs=((lut, meta),)))
    for bits in (4, 8):
        x = jnp.zeros((3, 300), jnp.float32)
        targets.append(ExecutorTarget(
            f"codec.roundtrip[b{bits}]",
            ft.partial(wire_roundtrip, bits=bits, use_pallas=False), (x,)))
    return targets


def build_cut_families():
    from repro.camera.offload.executors import (FaceAuthOffloadExecutor,
                                                VROffloadExecutor)
    from repro.camera.pipelines import (FAWorkloadStats, VRWorkloadStats,
                                        fa_pipeline, vr_pipeline)

    fa, frames = _fa_base()
    vr, lefts, rights = _vr_base()
    fa_blocks = tuple(b.name for b in fa_pipeline(FAWorkloadStats()).blocks)
    vr_blocks = tuple(b.name for b in vr_pipeline(VRWorkloadStats()).blocks)
    return [
        CutFamily(
            name="face_auth", executor_cls=FaceAuthOffloadExecutor,
            make=lambda cut, bits: FaceAuthOffloadExecutor(
                fa, cut, bits=bits, use_pallas=False),
            node_args=lambda off: (frames,) + tuple(off._consts),
            template_blocks=fa_blocks),
        CutFamily(
            name="vr_video", executor_cls=VROffloadExecutor,
            make=lambda cut, bits: VROffloadExecutor(
                vr, cut, bits=bits, use_pallas=False),
            node_args=lambda off: (lefts, rights),
            template_blocks=vr_blocks),
    ]


def build_kernel_specs():
    """Import every kernels/* package and collect its ANALYSIS hook."""
    import repro.kernels as kernels_pkg

    specs, missing = [], []
    for info in sorted(pkgutil.iter_modules(kernels_pkg.__path__),
                       key=lambda m: m.name):
        if not info.ispkg:
            continue
        mod = importlib.import_module(f"repro.kernels.{info.name}")
        hook = getattr(mod, "ANALYSIS", None)
        if hook is None:
            missing.append(info.name)
        else:
            specs.append(hook)
    return specs, missing


def build_context():
    from repro.analysis.passes import PassContext
    from repro.configs.shapes import KERNEL_SHAPES

    specs, missing = build_kernel_specs()
    return PassContext(
        targets=build_targets(),
        cut_families=build_cut_families(),
        kernel_specs=specs,
        kernel_missing=missing,
        kernel_shapes=KERNEL_SHAPES,
    )
