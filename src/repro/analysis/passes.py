"""The four pass families (DESIGN.md §11).

Every pass consumes a :class:`PassContext` (the analyzed universe built by
``registry.build_universe``) and returns a :class:`report.PassResult`.
Nothing here executes pipeline code: executors are inspected through
``jax.make_jaxpr`` / ``jax.eval_shape`` traces, kernels through their
registered static plans.

  dispatch   — host-sync / dispatch-discipline hazards in executor jaxprs
  precision  — int8/int4 domain discipline in quant + codec subgraphs
  kernel     — Pallas BlockSpec divisibility, VMEM budget, ref signatures
  cut        — offload payload schema coverage + byte-accounting soundness
  obs        — telemetry-plane contracts (DESIGN.md §15): aux declarations,
               uncharged sidebands, counter dtype discipline
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.analysis import jaxpr_utils as ju
from repro.analysis.report import Finding, PassResult
from repro.analysis.spec import VMEM_BUDGET_BYTES, signature_mismatches


@dataclasses.dataclass
class PassContext:
    targets: list           # list[registry.ExecutorTarget]
    cut_families: list      # list[registry.CutFamily]
    kernel_specs: list      # list[spec.KernelAnalysisSpec]
    kernel_missing: list    # kernel package names without an ANALYSIS hook
    kernel_shapes: dict     # configs.shapes.KERNEL_SHAPES
    vmem_budget: int = VMEM_BUDGET_BYTES


def _trace(target):
    import jax

    return jax.make_jaxpr(target.fn)(*target.args)


_NARROW_INTS = ("int8", "int4", "uint8", "uint4")
_CALLBACK_PRIMS = ("debug_callback", "io_callback", "pure_callback")


def _is_narrow_int(dtype) -> bool:
    return dtype is not None and str(dtype) in _NARROW_INTS


def _is_float(dtype) -> bool:
    return dtype is not None and np.issubdtype(np.dtype(str(dtype)),
                                               np.floating)


def _is_int(dtype) -> bool:
    return dtype is not None and np.issubdtype(np.dtype(str(dtype)),
                                               np.integer)


def _unspecified_sharding(s) -> bool:
    return s is None or "Unspecified" in type(s).__name__


# ---------------------------------------------------------------------------
# 1. dispatch lint
# ---------------------------------------------------------------------------

class DispatchPass:
    family = "dispatch"

    def run(self, ctx: PassContext) -> PassResult:
        findings, subjects = [], []
        for tgt in ctx.targets:
            subjects.append(tgt.name)
            closed = _trace(tgt)
            findings.extend(self._lint(tgt.name, closed))
        return PassResult(self.family, subjects, findings)

    def _lint(self, name, closed):
        out = []

        def fnd(code, where, msg, severity="error"):
            out.append(Finding("dispatch", code, name, where, msg, severity))

        def visit(site):
            eqn, prim = site.eqn, site.eqn.primitive.name
            if prim in ("xla_pmap", "pmap"):
                fnd("D001", site.path,
                    "nested pmap inside a traced executor: per-call device "
                    "transfer + separate dispatch per map")
            if prim == "sharding_constraint":
                fnd("D002", site.path,
                    "sharding constraint baked into an executor jaxpr: "
                    "re-jitting under a different mesh will miscompile",
                    severity="warning")
            if prim == "pjit":
                shardings = list(eqn.params.get("in_shardings", ())) + \
                    list(eqn.params.get("out_shardings", ()))
                if any(not _unspecified_sharding(s) for s in shardings):
                    fnd("D002", site.path,
                        "inner jit with explicit shardings leaks placement "
                        "into the executor graph", severity="warning")
            if prim in _CALLBACK_PRIMS:
                fnd("D003", site.path,
                    f"{prim} forces a host sync inside the dispatch "
                    "(breaks the single-dispatch contract)")
            if ju.has_wide_output(eqn) and not ju.has_wide_input(eqn):
                fnd("D004", site.path,
                    "implicit 64-bit promotion point (x64 leak): "
                    "doubles wire/VMEM cost and diverges across platforms")
            if prim == "gather" and not site.in_pallas \
                    and not ju.gather_mode_is_fill(eqn):
                idx_guards = site.in_guards[1] if len(site.in_guards) > 1 \
                    else ju.NONE
                if idx_guards != ju.BOTH:
                    fnd("D005", site.path,
                        "non-fill gather with unguarded indices: "
                        "out-of-bounds reads are backend-defined "
                        "(clamp both sides or use mode='fill')")
            if prim in ("scatter", "scatter-add", "scatter_add") \
                    and not site.in_pallas \
                    and not ju.gather_mode_is_fill(eqn):
                idx_guards = site.in_guards[1] if len(site.in_guards) > 1 \
                    else ju.NONE
                if idx_guards != ju.BOTH:
                    fnd("D005", site.path,
                        "non-fill scatter with unguarded indices")
            if prim == "convert_element_type":
                in_dt = ju.eqn_in_dtypes(eqn)[0] if eqn.invars else None
                out_dt = ju.eqn_out_dtypes(eqn)[0]
                if _is_float(in_dt) and _is_int(out_dt) \
                        and not _is_narrow_int(out_dt) \
                        and site.in_guards \
                        and site.in_guards[0] != ju.BOTH:
                    fnd("D006", site.path,
                        f"unclamped float->{out_dt} cast: NaN/inf casts are "
                        "backend-defined; clamp in float before the cast")

        ju.walk(closed, visit)
        for i, var in enumerate(closed.jaxpr.outvars):
            shape = getattr(var.aval, "shape", ())
            if any(not isinstance(d, (int, np.integer)) for d in shape):
                out.append(Finding(
                    "dispatch", "D007", name, f"out[{i}]",
                    f"dynamic output dim {shape}: breaks the capacity-"
                    "padding contract (DESIGN.md §9)"))
        return out


# ---------------------------------------------------------------------------
# 2. precision-domain lint
# ---------------------------------------------------------------------------

class PrecisionPass:
    family = "precision"

    def run(self, ctx: PassContext) -> PassResult:
        findings, subjects = [], []
        for tgt in ctx.targets:
            subjects.append(tgt.name)
            closed = _trace(tgt)
            findings.extend(self._lint(tgt.name, closed))
            findings.extend(self._lut_spec(tgt))
        return PassResult(self.family, subjects, findings)

    def _lint(self, name, closed):
        out = []
        sites = []
        ju.walk(closed, sites.append)

        # consumer index: var -> list of sites using it (same-level links)
        consumers = {}
        for site in sites:
            for v in site.eqn.invars:
                if not isinstance(v, ju.Literal):
                    consumers.setdefault(id(v), []).append(site)

        for site in sites:
            eqn, prim = site.eqn, site.eqn.primitive.name
            if prim == "convert_element_type":
                in_dt = ju.eqn_in_dtypes(eqn)[0] if eqn.invars else None
                out_dt = ju.eqn_out_dtypes(eqn)[0]
                if _is_narrow_int(in_dt) and _is_float(out_dt):
                    cons = consumers.get(id(eqn.outvars[0]), [])
                    scaled = any(
                        c.eqn.primitive.name in ("mul", "div", "dot_general")
                        for c in cons)
                    if cons and not scaled:
                        out.append(Finding(
                            "precision", "P001", name, site.path,
                            f"{in_dt} value dequantized to {out_dt} without "
                            "a scale multiply: float ops on the quantized "
                            "domain outside a sanctioned dequant point"))
                if _is_float(in_dt) and _is_narrow_int(out_dt) \
                        and site.in_guards \
                        and site.in_guards[0] != ju.BOTH:
                    out.append(Finding(
                        "precision", "P002", name, site.path,
                        f"float->{out_dt} quantization cast without a "
                        "clip: values outside the narrow range wrap"))
            if prim == "dot_general":
                in_dts = ju.eqn_in_dtypes(eqn)
                if len(in_dts) >= 2 and _is_narrow_int(in_dts[0]) \
                        and _is_narrow_int(in_dts[1]):
                    pref = eqn.params.get("preferred_element_type")
                    if pref is None or "int32" not in str(np.dtype(pref)):
                        out.append(Finding(
                            "precision", "P004", name, site.path,
                            "int8 matmul without preferred_element_type="
                            "int32: accumulates in the narrow domain"))
        return out

    def _lut_spec(self, tgt):
        from repro.camera.face_nn import make_sigmoid_lut

        out = []
        for i, (lut, meta) in enumerate(tgt.lut_pairs):
            lo, hi, entries = meta
            rebuilt, _ = make_sigmoid_lut(entries=int(entries), lo=float(lo),
                                          hi=float(hi))
            lut_np = np.asarray(lut)
            if lut_np.shape != rebuilt.shape \
                    or not np.array_equal(lut_np, np.asarray(rebuilt)):
                out.append(Finding(
                    "precision", "P003", tgt.name, f"lut[{i}]",
                    f"sigmoid LUT does not match its threaded meta "
                    f"(lo={lo}, hi={hi}, entries={entries}): kernel-side "
                    "indexing will drift from face_nn.sigmoid_lut"))
        return out


# ---------------------------------------------------------------------------
# 3. Pallas kernel legality
# ---------------------------------------------------------------------------

class KernelPass:
    family = "kernel"

    def run(self, ctx: PassContext) -> PassResult:
        findings, subjects = [], []
        for name in ctx.kernel_missing:
            findings.append(Finding(
                "kernel", "K005", name, "package",
                "kernel package has no ANALYSIS registration hook"))
        for spec in ctx.kernel_specs:
            subjects.append(spec.name)
            for j, pair in enumerate(spec.pairs):
                for msg in signature_mismatches(pair):
                    findings.append(Finding(
                        "kernel", "K003", spec.name, f"pair[{j}]",
                        f"kernel/ref signature drift: {msg}"))
            cases = ctx.kernel_shapes.get(spec.name)
            if not cases:
                findings.append(Finding(
                    "kernel", "K004", spec.name, "shapes",
                    "no shape cases registered in configs.shapes."
                    "KERNEL_SHAPES"))
                continue
            for case in cases:
                plan = spec.plan(case)
                for chk in plan.checks:
                    if not chk.ok:
                        findings.append(Finding(
                            "kernel", "K001", spec.name,
                            f"{plan.case}:{chk.label}",
                            f"BlockSpec divisibility violated: {chk.label} "
                            f"with size={chk.size}, block={chk.block}"))
                if plan.vmem_bytes > ctx.vmem_budget:
                    findings.append(Finding(
                        "kernel", "K002", spec.name, f"{plan.case}:vmem",
                        f"per-block VMEM footprint {plan.vmem_bytes} B "
                        f"exceeds budget {ctx.vmem_budget} B"))
        return PassResult(self.family, subjects, findings)


# ---------------------------------------------------------------------------
# 4. cut-soundness lint
# ---------------------------------------------------------------------------

class CutPass:
    family = "cut"

    def run(self, ctx: PassContext) -> PassResult:
        import jax

        from repro.camera.offload.payloads import (SESSION_SIDEBAND,
                                                   static_array_bytes)
        from repro.kernels.wire_codec.ops import BLOCK, wire_bytes

        findings, subjects = [], []
        for fam in ctx.cut_families:
            cuts = tuple(fam.executor_cls.CUTS)
            schema_tbl = fam.executor_cls.PAYLOAD_SCHEMA
            extra_cuts = [c for c in cuts if c not in fam.template_blocks]
            for c in extra_cuts:
                findings.append(Finding(
                    "cut", "C004", fam.name, c,
                    f"cut {c!r} has no matching block in the analytic "
                    f"pipeline template {fam.template_blocks}: "
                    "placement solver and runtime disagree on legal cuts"))
            for c in [c for c in schema_tbl if c not in cuts]:
                findings.append(Finding(
                    "cut", "C004", fam.name, c,
                    f"schema declares unknown cut {c!r}"))

            raw_avals = {}
            for cut in cuts:
                subjects.append(f"{fam.name}[{cut}]")
                schema = schema_tbl.get(cut)
                if schema is None:
                    findings.append(Finding(
                        "cut", "C002", fam.name, cut,
                        "cut has no PayloadSchema declaration"))
                    continue
                ex_raw = fam.make(cut, None)
                arrays_raw, _ = jax.eval_shape(ex_raw._node_fn,
                                               *fam.node_args(ex_raw))
                raw_avals[cut] = arrays_raw

                # C006: session-layer sideband discipline.  The resilience
                # runtime (offload/resilience.OffloadSession) staples
                # seq/crc/attempt onto every transmission at 4 B each; a
                # cut that does not declare them ships uncharged framing,
                # and a spec outside int32/uint32 breaks the 4 B charge.
                spec = fam.session_spec if fam.session_spec is not None \
                    else SESSION_SIDEBAND
                spec_names = tuple(n for n, _ in spec)
                declared_sb = tuple(schema.session)
                for f in [n for n in spec_names if n not in declared_sb]:
                    findings.append(Finding(
                        "cut", "C006", f"{fam.name}[{cut}]", f,
                        f"session sideband field {f!r} not declared in "
                        "PayloadSchema.session: OffloadSession charges it "
                        "on every transmission attempt but the wire "
                        "contract does not admit it"))
                for f in [n for n in declared_sb if n not in spec_names]:
                    findings.append(Finding(
                        "cut", "C006", f"{fam.name}[{cut}]", f,
                        f"PayloadSchema.session declares unknown sideband "
                        f"field {f!r} (spec has {spec_names})"))
                for f, dt in spec:
                    if dt not in ("int32", "uint32"):
                        findings.append(Finding(
                            "cut", "C006", f"{fam.name}[{cut}]", f,
                            f"session sideband field {f!r} has dtype {dt} "
                            "but is charged at 4 B/attempt — int32/uint32 "
                            "only"))
                for f in sorted(set(spec_names) & set(arrays_raw)):
                    findings.append(Finding(
                        "cut", "C006", f"{fam.name}[{cut}]", f,
                        f"session sideband name {f!r} collides with a "
                        "node-half payload array: receiver framing would "
                        "shadow payload data"))
                for bits in (None, 8):
                    subj = f"{fam.name}[{cut},{bits or 'raw'}]"
                    if bits is None:
                        avals = arrays_raw
                    else:
                        ex = fam.make(cut, bits)
                        avals, _ = jax.eval_shape(ex._node_fn,
                                                  *fam.node_args(ex))
                    declared = schema.declared(bits)
                    for f in sorted(set(avals) - declared):
                        findings.append(Finding(
                            "cut", "C001", subj, f,
                            f"node half ships undeclared array {f!r} "
                            f"{tuple(avals[f].shape)}: uncharged bytes on "
                            "the wire"))
                    for f in sorted(declared - set(avals)):
                        findings.append(Finding(
                            "cut", "C002", subj, f,
                            f"declared payload field {f!r} missing from "
                            "node-half output"))
                    for f in schema.codec:
                        if f not in arrays_raw or f not in avals:
                            continue
                        n = int(np.prod(arrays_raw[f].shape))
                        if bits is None:
                            cap = static_array_bytes(arrays_raw[f])
                            ana = wire_bytes(n, None)
                            if str(arrays_raw[f].dtype) != "float32":
                                findings.append(Finding(
                                    "cut", "C005", subj, f,
                                    f"raw codec field {f!r} is "
                                    f"{arrays_raw[f].dtype}, expected "
                                    "float32"))
                        else:
                            packed = avals[f]
                            scales = avals.get(f + "_scales")
                            nb = -(-n // BLOCK)
                            if tuple(packed.shape) != (nb, BLOCK * bits // 8) \
                                    or scales is None \
                                    or tuple(scales.shape) != (nb, 1):
                                findings.append(Finding(
                                    "cut", "C003", subj, f,
                                    f"packed field {f!r} shape "
                                    f"{tuple(packed.shape)} does not match "
                                    f"codec layout for {n} logical values "
                                    f"(expect ({nb}, {BLOCK * bits // 8}) + "
                                    f"({nb}, 1) scales)"))
                                continue
                            cap = static_array_bytes(packed) \
                                + static_array_bytes(scales)
                            ana = wire_bytes(nb * BLOCK, bits)
                        if abs(cap - ana) > 1e-6:
                            findings.append(Finding(
                                "cut", "C003", subj, f,
                                f"byte accounting drift on {f!r}: payload "
                                f"capacity {cap} B vs analytic full-"
                                f"occupancy wire_bytes {ana} B"))
                    for f in schema.i32:
                        if f in avals and str(avals[f].dtype) != "int32":
                            findings.append(Finding(
                                "cut", "C005", subj, f,
                                f"sideband field {f!r} is {avals[f].dtype} "
                                "but charged at 4 B/entry (int32)"))
                    for f in schema.bools:
                        if f in avals and str(avals[f].dtype) != "bool":
                            findings.append(Finding(
                                "cut", "C005", subj, f,
                                f"sideband field {f!r} is {avals[f].dtype} "
                                "but charged bit-packed (bool)"))
        return PassResult(self.family, subjects, findings)


# ---------------------------------------------------------------------------
# 5. telemetry-plane lint (DESIGN.md §15)
# ---------------------------------------------------------------------------

class ObsPass:
    """O001–O003: the telemetry plane's static contracts.

    O001  every registered executor target resolves to a TELEMETRY_AUX
          declaration (an empty tuple is a legal "emits nothing"), so
          the aux-output surface is auditable, not accidental.
    O002  no ``tel_``-prefixed array ever enters a WirePayload — neither
          emitted by a node half nor admitted by a PayloadSchema.
          Telemetry that rides the wire is uncharged bytes; offload
          counters belong at the session layer.
    O003  every declared counter dtype is int32/uint32 (the panel's
          accumulation contract; wider or float counters would perturb
          dispatch caching and the 4 B accounting assumption).
    """

    family = "obs"

    def run(self, ctx: PassContext) -> PassResult:
        import jax

        from repro.obs.counters import (ALLOWED_DTYPES, TEL_PREFIX,
                                        telemetry_decl)

        findings, subjects = [], []
        for tgt in ctx.targets:
            subjects.append(tgt.name)
            decl = telemetry_decl(tgt.name)
            if decl is None:
                findings.append(Finding(
                    "obs", "O001", tgt.name, "decl",
                    "registered executor target has no TELEMETRY_AUX "
                    "declaration: the telemetry plane cannot audit its aux "
                    "outputs (declare an empty tuple for targets that "
                    "intentionally emit no counters)"))
                continue
            for cname, dt in decl:
                if dt not in ALLOWED_DTYPES:
                    findings.append(Finding(
                        "obs", "O003", tgt.name, cname,
                        f"declared telemetry counter {cname!r} has dtype "
                        f"{dt!r}; counters are {ALLOWED_DTYPES} only"))
        for fam in ctx.cut_families:
            for cut in fam.executor_cls.CUTS:
                subj = f"{fam.name}[{cut}]"
                subjects.append(subj)
                schema = fam.executor_cls.PAYLOAD_SCHEMA.get(cut)
                if schema is not None:
                    admitted = set(schema.declared(None)) \
                        | set(schema.declared(8)) | set(schema.session)
                    for f in sorted(x for x in admitted
                                    if x.startswith(TEL_PREFIX)):
                        findings.append(Finding(
                            "obs", "O002", subj, f,
                            f"PayloadSchema admits telemetry field {f!r}: "
                            "telemetry must never ride the wire contract"))
                ex = fam.make(cut, None)
                arrays, _ = jax.eval_shape(ex._node_fn, *fam.node_args(ex))
                for f in sorted(x for x in arrays
                                if x.startswith(TEL_PREFIX)):
                    findings.append(Finding(
                        "obs", "O002", subj, f,
                        f"node half emits telemetry array {f!r} into the "
                        "WirePayload: uncharged sideband bytes on the air "
                        "(hoist the counter to the session layer)"))
        return PassResult(self.family, subjects, findings)


PASSES = {
    "dispatch": DispatchPass,
    "precision": PrecisionPass,
    "kernel": KernelPass,
    "cut": CutPass,
    "obs": ObsPass,
}


def run_passes(ctx: PassContext, only=None):
    from repro.analysis.report import AnalysisReport

    results = []
    for fam, cls in PASSES.items():
        if only and fam not in only:
            continue
        results.append(cls().run(ctx))
    return AnalysisReport(results)
