"""Static contract analyzer (DESIGN.md §11): jaxpr- and spec-level lint
for dispatch discipline, precision domains, Pallas block legality, and
offload-cut soundness.  Run with ``python -m repro.analysis``."""

from repro.analysis.cli import run_analysis
from repro.analysis.report import (AnalysisReport, Baseline, Finding,
                                   PassResult)

__all__ = ["AnalysisReport", "Baseline", "Finding", "PassResult",
           "run_analysis"]
