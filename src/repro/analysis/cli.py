"""``python -m repro.analysis`` — run the static contract analyzer.

Exit code 0 iff every finding is baselined (or, with ``--strict``, iff
there are no findings at all).  ``--update-baseline`` rewrites
``analysis/baseline.json`` to accept the current findings — a deliberate,
reviewed action (DESIGN.md §11), never done implicitly.
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.analysis.report import (DEFAULT_BASELINE_PATH, AnalysisReport,
                                   Baseline)


def run_analysis(only=None) -> AnalysisReport:
    """Build the analyzed universe and run the requested pass families."""
    import jax

    # precision/dispatch results are only platform-stable with x64 off
    jax.config.update("jax_enable_x64", False)

    from repro.analysis.passes import run_passes
    from repro.analysis.registry import build_context

    return run_passes(build_context(), only=only)


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="jaxpr/spec-level static contract analyzer")
    p.add_argument("--json", metavar="PATH",
                   help="write the full JSON report to PATH ('-' = stdout)")
    p.add_argument("--strict", action="store_true",
                   help="ignore the baseline: any finding fails (pre-merge)")
    p.add_argument("--baseline", default=DEFAULT_BASELINE_PATH,
                   help="baseline file (default: the checked-in one)")
    p.add_argument("--update-baseline", action="store_true",
                   help="accept current findings into the baseline file")
    p.add_argument("--only", metavar="FAMILIES",
                   help="comma-separated pass families "
                        "(dispatch,precision,kernel,cut)")
    args = p.parse_args(argv)

    only = tuple(args.only.split(",")) if args.only else None
    report = run_analysis(only=only)
    baseline = None if args.strict else Baseline.load(args.baseline)

    if args.update_baseline:
        Baseline.from_report(report).save(args.baseline)
        print(f"baseline updated: {args.baseline} "
              f"({len(report.findings)} accepted findings)")
        return 0

    doc = report.to_dict(baseline)
    if args.json == "-":
        json.dump(doc, sys.stdout, indent=2)
        print()
    elif args.json:
        with open(args.json, "w") as fh:
            json.dump(doc, fh, indent=2)

    for line in report.summary_lines(baseline):
        print(line)
    new = report.new_findings(baseline)
    for f in new:
        print(f"  NEW {f}")
    if new:
        mode = "strict" if args.strict else "non-baselined"
        print(f"FAIL: {len(new)} {mode} finding(s)")
        return 1
    print("OK")
    return 0
