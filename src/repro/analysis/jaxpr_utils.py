"""Jaxpr walking + a small guard-propagation dataflow (DESIGN.md §11).

Everything here is *static*: we trace executors with ``jax.make_jaxpr`` and
inspect equations — nothing executes.

The guard lattice tracks, per intermediate value, whether it has been
deliberately bounded from below ("lo"), above ("hi"), or both.  ``max`` with
anything contributes "lo", ``min`` contributes "hi", ``clamp``/``iota``/
literals/consts are bounded on both sides, and elementwise/shape ops
propagate the *intersection* of their operands' guards.  A non-``fill``
gather whose index operand is not two-sided-guarded is a host-of-UB hazard
(XLA clamps, TPU wraps, interpret modes differ) and gets flagged; so does a
float→int ``convert_element_type`` of an unguarded float (NaN/±inf casts are
backend-defined *before* any later clip can save them).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from jax.core import ClosedJaxpr, Jaxpr, Literal


BOTH = frozenset(("lo", "hi"))
NONE = frozenset()

# wide dtypes that indicate an implicit x64 promotion leak
WIDE_DTYPES = ("float64", "int64", "uint64", "complex128")

# primitives that yield values bounded on both sides by construction
_ORIGIN_BOTH = {"iota", "clamp", "rem", "argmin", "argmax",
                "population_count", "clz"}

# single-data-operand pass-throughs: out guards = operand guards
_PASSTHROUGH = {"reshape", "broadcast_in_dim", "transpose", "squeeze",
                "slice", "rev", "copy", "stop_gradient", "floor", "ceil",
                "round", "convert_element_type", "reduce_min", "reduce_max",
                "reduce_or", "reduce_and", "expand_dims", "real", "imag"}

# n-ary elementwise combiners: out guards = intersection over data operands
_INTERSECT = {"add", "sub", "mul", "div", "pow", "integer_pow",
              "concatenate", "pad", "nextafter", "shift_right_logical",
              "shift_right_arithmetic", "shift_left"}


def _is_wide(dtype) -> bool:
    return str(dtype) in WIDE_DTYPES


@dataclasses.dataclass
class EqnSite:
    """One visited equation with its guard context."""

    path: str          # stable-ish location: nesting of "<idx>:<prim>"
    eqn: object        # jax.core.JaxprEqn
    in_guards: list    # guard set per invar, aligned with eqn.invars
    depth: int
    in_pallas: bool


def _sub_closed(obj) -> ClosedJaxpr | None:
    if isinstance(obj, ClosedJaxpr):
        return obj
    if isinstance(obj, Jaxpr):
        return ClosedJaxpr(obj, ())
    return None


def subjaxprs(eqn):
    """Yield (tag, ClosedJaxpr) for every jaxpr nested in eqn.params."""
    for key, val in eqn.params.items():
        sub = _sub_closed(val)
        if sub is not None:
            yield key, sub
        elif isinstance(val, (tuple, list)):
            for i, item in enumerate(val):
                sub = _sub_closed(item)
                if sub is not None:
                    yield f"{key}[{i}]", sub


def walk(closed: ClosedJaxpr,
         visit: Callable[[EqnSite], None],
         in_guards=None) -> list:
    """Visit every eqn (recursively) with guard dataflow; return out guards.

    ``visit`` sees every equation at every nesting depth exactly once.
    Guard propagation recurses through pjit/scan/cond/while/custom-call
    bodies by mapping caller operand guards onto callee invars; unknown
    primitives default to unguarded outputs (sound for the checks built on
    top, which only ever *trust* a guard, never its absence).
    """
    return _walk(closed, visit, in_guards, path="", depth=0,
                 in_pallas=False)


def _walk(closed, visit, in_guards, *, path, depth, in_pallas):
    jaxpr = closed.jaxpr
    env = {}

    def write(var, guards):
        env[var] = frozenset(guards)

    def read(atom):
        if isinstance(atom, Literal):
            return BOTH
        return env.get(atom, NONE)

    if in_guards is None:
        in_guards = [NONE] * len(jaxpr.invars)
    for var, g in zip(jaxpr.invars, in_guards):
        write(var, g)
    for var in jaxpr.constvars:
        write(var, BOTH)       # consts are known, finite tables

    for idx, eqn in enumerate(jaxpr.eqns):
        prim = eqn.primitive.name
        here = f"{path}/{idx}:{prim}" if path else f"{idx}:{prim}"
        ins = [read(v) for v in eqn.invars]
        visit(EqnSite(path=here, eqn=eqn, in_guards=ins, depth=depth,
                      in_pallas=in_pallas or prim == "pallas_call"))

        outs = _transfer(prim, eqn, ins, visit, here, depth, in_pallas)
        for var, g in zip(eqn.outvars, outs):
            write(var, g)

    return [read(v) for v in jaxpr.outvars]


def _intersect(guard_sets):
    out = BOTH
    for g in guard_sets:
        out = out & g
    return out


def _transfer(prim, eqn, ins, visit, here, depth, in_pallas):
    """Guard transfer function; recurses into nested jaxprs."""
    n_out = len(eqn.outvars)

    if prim in ("pjit", "closed_call", "core_call", "remat", "checkpoint",
                "custom_jvp_call", "custom_vjp_call", "custom_jvp_call_jaxpr",
                "custom_vjp_call_jaxpr"):
        for _, sub in subjaxprs(eqn):
            n_const = len(sub.jaxpr.constvars)
            mapped = ins[-len(sub.jaxpr.invars):] \
                if len(ins) >= len(sub.jaxpr.invars) else None
            outs = _walk(sub, visit, mapped, path=here, depth=depth + 1,
                         in_pallas=in_pallas)
            del n_const
            if len(outs) == n_out:
                return outs
            break
        return [NONE] * n_out

    if prim == "scan":
        sub = eqn.params.get("jaxpr")
        sub = _sub_closed(sub)
        if sub is not None and len(sub.jaxpr.invars) == len(ins):
            outs = _walk(sub, visit, ins, path=here, depth=depth + 1,
                         in_pallas=in_pallas)
            n_carry = eqn.params.get("num_carry", 0)
            if len(outs) >= n_out - n_carry:
                return outs[:n_out] if len(outs) >= n_out \
                    else outs + [NONE] * (n_out - len(outs))
        elif sub is not None:
            _walk(sub, visit, None, path=here, depth=depth + 1,
                  in_pallas=in_pallas)
        return [NONE] * n_out

    if prim == "while":
        for tag, sub in subjaxprs(eqn):
            _walk(sub, visit, None, path=f"{here}.{tag}", depth=depth + 1,
                  in_pallas=in_pallas)
        return [NONE] * n_out

    if prim == "cond":
        branch_outs = []
        for tag, sub in subjaxprs(eqn):
            mapped = ins[1:] if len(sub.jaxpr.invars) == len(ins) - 1 \
                else None
            branch_outs.append(
                _walk(sub, visit, mapped, path=f"{here}.{tag}",
                      depth=depth + 1, in_pallas=in_pallas))
        if branch_outs and all(len(o) == n_out for o in branch_outs):
            return [_intersect([o[i] for o in branch_outs])
                    for i in range(n_out)]
        return [NONE] * n_out

    if prim in ("pallas_call", "xla_pmap", "xla_call"):
        for tag, sub in subjaxprs(eqn):
            _walk(sub, visit, None, path=f"{here}.{tag}", depth=depth + 1,
                  in_pallas=True if prim == "pallas_call" else in_pallas)
        return [NONE] * n_out

    # --- leaf transfer rules ---
    if prim in _ORIGIN_BOTH:
        return [BOTH] * n_out
    if prim == "max":
        return [_intersect(ins) | {"lo"}] * n_out
    if prim == "min":
        return [_intersect(ins) | {"hi"}] * n_out
    if prim == "abs":
        return [_intersect(ins) | {"lo"}] * n_out
    if prim == "neg":
        g = ins[0] if ins else NONE
        flipped = set()
        if "lo" in g:
            flipped.add("hi")
        if "hi" in g:
            flipped.add("lo")
        return [frozenset(flipped)] * n_out
    if prim in _PASSTHROUGH:
        return [ins[0] if ins else NONE] * n_out
    if prim in _INTERSECT:
        return [_intersect(ins)] * n_out
    if prim == "select_n":
        return [_intersect(ins[1:])] * n_out
    if prim in ("gather", "dynamic_slice"):
        return [ins[0] if ins else NONE] * n_out
    if prim == "sort":
        # outputs are permutations of the respective operands
        return [ins[i] if i < len(ins) else NONE for i in range(n_out)]
    return [NONE] * n_out


# ---------------------------------------------------------------------------
# helpers the passes share
# ---------------------------------------------------------------------------

def gather_mode_is_fill(eqn) -> bool:
    mode = eqn.params.get("mode")
    return mode is not None and "FILL_OR_DROP" in str(mode)


def eqn_out_dtypes(eqn):
    return [getattr(v.aval, "dtype", None) for v in eqn.outvars]


def eqn_in_dtypes(eqn):
    out = []
    for v in eqn.invars:
        aval = v.aval if not isinstance(v, Literal) else None
        if aval is None:
            out.append(np.asarray(v.val).dtype if hasattr(v, "val") else None)
        else:
            out.append(getattr(aval, "dtype", None))
    return out


def has_wide_output(eqn) -> bool:
    return any(d is not None and _is_wide(d) for d in eqn_out_dtypes(eqn))


def has_wide_input(eqn) -> bool:
    return any(d is not None and _is_wide(d) for d in eqn_in_dtypes(eqn))


def is_wide_dtype(dtype) -> bool:
    return _is_wide(dtype)
