"""Kernel registration hooks for the static legality pass (DESIGN.md §11).

Each ``kernels/<pkg>/__init__.py`` exports an ``ANALYSIS`` spec: which
callables form the kernel/ref pair, which keyword args are tuning knobs the
ref legitimately lacks, and a ``plan`` that — given one shape case from
``configs.shapes.KERNEL_SHAPES`` — statically reproduces the block-size
choices the entry point would make and returns the VMEM-resident tiles plus
the divisibility constraints the kernel asserts at trace time.  The pass
then re-checks those constraints and the per-block VMEM footprint without
tracing or running anything.
"""

from __future__ import annotations

import dataclasses
import inspect
import math
from typing import Callable

import numpy as np


VMEM_BUDGET_BYTES = 16 * 1024 * 1024   # per-core VMEM (pallas guide)


@dataclasses.dataclass(frozen=True)
class Tile:
    """One VMEM-resident block (input/output block or scratch)."""

    label: str
    shape: tuple
    dtype: str = "float32"

    @property
    def nbytes(self) -> int:
        return int(math.prod(self.shape)) * np.dtype(self.dtype).itemsize


@dataclasses.dataclass(frozen=True)
class DivCheck:
    """`size % block == 0` constraint the kernel asserts at trace time."""

    label: str
    size: int
    block: int

    @property
    def ok(self) -> bool:
        return self.block > 0 and self.size % self.block == 0


@dataclasses.dataclass
class KernelPlan:
    """Static tiling plan for one (kernel, shape-case) pair."""

    case: str
    grid: tuple
    tiles: list          # list[Tile]
    checks: list         # list[DivCheck]

    @property
    def vmem_bytes(self) -> int:
        return sum(t.nbytes for t in self.tiles)


@dataclasses.dataclass(frozen=True)
class FnPair:
    """A pallas entry point and the pure-jnp ref it must mirror."""

    kernel_fn: Callable
    ref_fn: Callable
    tuning_kwargs: frozenset = frozenset()


@dataclasses.dataclass
class KernelAnalysisSpec:
    name: str
    pairs: list                      # list[FnPair]
    plan: Callable                   # (case: dict) -> KernelPlan


def adapt_block(size: int, block: int) -> int:
    """The entry-point convention: shrink the block to the largest divisor
    of ``size`` that is <= the requested block (see ops.py wrappers)."""
    b = min(block, size)
    while b > 1 and size % b:
        b -= 1
    return max(b, 1)


def round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def signature_mismatches(pair: FnPair):
    """Static kernel-vs-ref signature check.

    Positional parameters must match by name and order; the kernel's extra
    keyword-only parameters must all be declared tuning knobs.  Returns a
    list of human-readable mismatch strings (empty == compatible).
    """
    out = []
    ksig = inspect.signature(pair.kernel_fn)
    rsig = inspect.signature(pair.ref_fn)

    def split(sig):
        pos, kw = [], []
        for p in sig.parameters.values():
            if p.kind in (p.POSITIONAL_ONLY, p.POSITIONAL_OR_KEYWORD):
                pos.append(p.name)
            elif p.kind == p.KEYWORD_ONLY:
                kw.append(p.name)
        return pos, kw

    kpos, kkw = split(ksig)
    rpos, rkw = split(rsig)
    if kpos != rpos:
        out.append(f"positional args differ: kernel{tuple(kpos)} "
                   f"vs ref{tuple(rpos)}")
    extra = set(kkw) - set(rkw) - set(pair.tuning_kwargs)
    if extra:
        out.append(f"kernel-only kwargs not declared as tuning knobs: "
                   f"{sorted(extra)}")
    missing = set(rkw) - set(kkw)
    if missing:
        out.append(f"ref kwargs missing from kernel: {sorted(missing)}")
    return out
