"""Named sharding plans = logical-axis rule sets (the solver's vocabulary).

A plan is to a TPU job what a cut-point configuration is to a camera
pipeline (DESIGN.md §2): it decides which bytes cross which interconnect.
`recommend_plan` is the placement solver's arch-level decision, driven by
the same napkin math as core.placement.estimate_plan:

* ``fsdp``  — pure data parallelism over all mesh axes with 2D-sharded
  parameters (ZeRO-3).  Per-step traffic ~= one parameter all-gather
  (hoisted out of the layer scan by XLA) + one gradient reduce-scatter.
  Optimal when params_bytes << activation-AR traffic of TP, i.e. for the
  small/medium dense archs (9-34B at 4k batch-tokens per chip).
* ``tp``    — Megatron-style tensor parallelism on the 'model' axis with
  batch DP on 'data'.  Needed when one chip cannot hold its FSDP shard's
  working set or when per-device batch would vanish; the naive variant
  all-reduces full activations twice per layer.
* ``tp_sp`` — TP + sequence-parallel residual stream: activations between
  blocks are sharded over 'model' along the sequence axis, so each TP
  all-reduce becomes reduce-scatter(+all-gather) at half the traffic and
  norms compute on 1/16th of the tokens.
* ``ep``    — tp_sp plus experts sharded over 'model' (MoE all-to-alls stay
  intra-pod).  MoE archs pick ep/tp per `MoEConfig.parallelism`.

Decode plans are orthogonal: batch over 'data', heads over 'model',
long-context cells shard the cache over 'data' (rules_for_cell in
launch/dryrun.py).
"""

from __future__ import annotations

PLANS = {
    # Megatron TP (naive): activations replicated over 'model' between ops.
    "tp": {},

    # TP + sequence-parallel residual stream.
    "tp_sp": {
        "seq": "model",
    },

    # 1D-FSDP on the 'model' axis + DP on 'data' (MaxText-style hybrid).
    # Weights shard their embed dim 16-way over 'model' and are all-gathered
    # at use (scan-hoisted); batch is 16-way DP on 'data'; no tensor is
    # sharded on two mesh axes.  We *measured* (EXPERIMENTS.md §Perf iter 3)
    # that 256-way batch x 256-way embed sharding trips XLA's involuntary-
    # full-rematerialization fallback (46 TB activation gathers, Shardy bug
    # b/433785288), so ZeRO stays 1D.  Parameters deliberately stay sharded
    # intra-pod: the pod axis carries only the gradient all-reduce — the
    # comp-comm cut again.
    "fsdp": {
        "manual_fsdp": True,
        "batch": ("pod", "data"),
        "seq": "model",
        "embed": "model",
        "vocab": "model",
        "heads": None,
        "kv_heads": None,
        "mlp": None,
        "heads_act": None,
        "mlp_act": None,
        "experts_act": None,
        "vocab_act": None,
    },
}


PLANS["fsdp_noseq"] = dict(PLANS["fsdp"], seq=None)


def recommend_plan(cfg, shape) -> str:
    """Arch-level plan choice (the placement solver's static decision).

    MoE models keep the 'model' axis for EP/TP expert placement; dense
    models below ~40B params are FSDP-dominant at these batch sizes.

    Recurrent mixers (mamba/rwkv) must NOT shard the sequence globally:
    their time scans are sequential, so a seq-sharded residual stream makes
    XLA re-gather the full sequence every layer (measured on jamba train:
    4.9 TiB/device of all-gathers — §Perf iteration 6).  The MoE block
    still seq-shards internally at its own shard_map boundary
    (models/moe.py), which stays local and cheap.
    """
    if shape.mode != "train":
        return "tp"          # serving: TP heads + DP batch; cache rules per cell
    recurrent = cfg.mixer in ("rwkv", "mamba")
    if cfg.moe is not None:
        return "tp" if recurrent else "tp_sp"
    if recurrent:
        return "fsdp_noseq"  # batch-DP + param sharding, full seq per device
    # dense: params bf16 all-gather once per step vs 2 activation ARs/layer
    # favors FSDP until params_bytes ~ tokens*d_model*n_layers*4 (napkin)
    return "fsdp"


def plan_rules(name: str) -> dict:
    return dict(PLANS[name])
