"""Logical-axis sharding vocabulary (MaxText-style rules).

Model code annotates tensors with *logical* axis names ("batch", "embed",
"heads", "experts", ...).  A *rules* table maps logical names to mesh axis
names; the placement solver (repro.core.placement) picks the rules, the
launcher activates them, and model code stays oblivious — that separation
is what lets the comp-comm solver re-place the same model without touching
model code (DESIGN.md §2).

Rules values may be a mesh axis name, a tuple of axis names (a logical axis
sharded over several mesh axes), or None (replicated).  Mesh axes absent
from the active mesh are dropped at resolve time, so one rules table serves
both the single-pod (data, model) and multi-pod (pod, data, model) meshes.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Mapping, Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# Default rules: FSDP over 'data' (params' embed axis), TP over 'model'
# (heads / mlp / vocab / experts), batch over ('pod', 'data').
DEFAULT_RULES: dict = {
    # activation axes
    "batch": ("pod", "data"),
    "seq": None,
    "kv_seq": None,          # attention K/V sequence dim (kept gatherable)
    "cache_seq": None,          # KV-cache sequence; 'data' for long-context cells
    "embed_act": None,          # activation d_model: kept replicated (TP collects)
    "heads_act": "model",
    "mlp_act": "model",
    "experts_act": "model",
    "vocab_act": "model",
    # parameter axes
    "embed": "data",            # FSDP shard dim of weight matrices
    "embed_nofsdp": None,
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "experts": "model",
    "vocab": "model",
    "kv_lora": None,
    "conv": None,
    "state": None,
    "dt_rank": None,
    "stack": None,              # scanned-layer leading axis: never sharded
}


@dataclasses.dataclass(frozen=True)
class ShardingContext:
    mesh: Mesh
    rules: Mapping[str, object]

    def resolve(self, logical_axes: Sequence[Optional[str]]) -> P:
        """Map logical axes -> PartitionSpec, dropping absent mesh axes and
        axes whose size does not divide the tensor dimension (checked by the
        caller via resolve_for_shape when shapes are known)."""
        mesh_axes = set(self.mesh.axis_names)
        spec = []
        used = set()
        for ax in logical_axes:
            entry = self.rules.get(ax) if ax is not None else None
            if entry is None:
                spec.append(None)
                continue
            if isinstance(entry, str):
                entry = (entry,)
            picked = tuple(a for a in entry if a in mesh_axes and a not in used)
            used.update(picked)
            if not picked:
                spec.append(None)
            elif len(picked) == 1:
                spec.append(picked[0])
            else:
                spec.append(picked)
        return P(*spec)

    def resolve_for_shape(self, logical_axes, shape) -> P:
        """Like resolve(), but drops mesh axes that don't divide the dim."""
        mesh_shape = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        base = self.resolve(logical_axes)
        out = []
        for dim, entry in zip(shape, tuple(base) + (None,) * (len(shape) - len(base))):
            if entry is None:
                out.append(None)
                continue
            axes = (entry,) if isinstance(entry, str) else tuple(entry)
            ways = 1
            kept = []
            for a in axes:
                ways *= mesh_shape[a]
                kept.append(a)
            if dim % ways != 0:
                # drop trailing axes until it divides; replicate if none fit
                while kept and dim % _prod(mesh_shape[a] for a in kept) != 0:
                    kept.pop()
            if not kept:
                out.append(None)
            elif len(kept) == 1:
                out.append(kept[0])
            else:
                out.append(tuple(kept))
        return P(*out)

    def named_sharding(self, logical_axes, shape=None) -> NamedSharding:
        spec = (
            self.resolve_for_shape(logical_axes, shape)
            if shape is not None
            else self.resolve(logical_axes)
        )
        return NamedSharding(self.mesh, spec)


def _prod(it):
    r = 1
    for x in it:
        r *= x
    return r


_tls = threading.local()


def current_context() -> Optional[ShardingContext]:
    return getattr(_tls, "ctx", None)


@contextlib.contextmanager
def use_sharding(mesh: Mesh, rules: Mapping[str, object] | None = None):
    """Activate a sharding context; model-code `constrain()` calls bind to it."""
    prev = current_context()
    _tls.ctx = ShardingContext(mesh=mesh, rules=dict(DEFAULT_RULES, **(rules or {})))
    try:
        with mesh:
            yield _tls.ctx
    finally:
        _tls.ctx = prev


def _manual_axes() -> frozenset:
    """Mesh axes currently bound Manual (inside a shard_map over them).
    Constraints must not mention them: the tensor is already axis-local."""
    try:
        am = jax.sharding.get_abstract_mesh()
        if am is None or am.empty:
            return frozenset()
        return frozenset(
            name for name, ty in zip(am.axis_names, am.axis_types)
            if str(ty).endswith("Manual"))
    except Exception:  # noqa: BLE001 — abstract mesh API absent/changed
        return frozenset()


def compat_shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
                     check_vma=True):
    """``jax.shard_map`` across jax versions.

    Newer jax exposes ``jax.shard_map(..., axis_names=, check_vma=)``;
    0.4.x only has ``jax.experimental.shard_map.shard_map`` with the
    complementary ``auto=`` set and ``check_rep=``.  Partial-manual regions
    (``axis_names`` a strict subset of the mesh axes) need a concrete mesh
    on 0.4.x to compute the complement.
    """
    if hasattr(jax, "shard_map"):
        kwargs = {"check_vma": check_vma}
        if axis_names is not None:
            kwargs["axis_names"] = axis_names
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kwargs)
    from jax.experimental.shard_map import shard_map as _shard_map
    kwargs = {"check_rep": check_vma}
    if axis_names is not None and mesh is not None:
        auto = frozenset(mesh.axis_names) - frozenset(axis_names)
        if auto:
            kwargs["auto"] = auto
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kwargs)


def shard_map_mesh(ctx):
    """Mesh argument for a nested-safe shard_map: None (bind the ambient
    context mesh) when tracing inside another shard_map region, else the
    concrete mesh."""
    try:
        am = jax.sharding.get_abstract_mesh()
        if am is not None and not am.empty:
            return None
    except Exception:  # noqa: BLE001
        pass
    return ctx.mesh


def constrain(x: jax.Array, logical_axes: Sequence[Optional[str]]) -> jax.Array:
    """Annotate an activation with logical axes (no-op outside a context)."""
    ctx = current_context()
    if ctx is None:
        return x
    spec = ctx.resolve_for_shape(logical_axes, x.shape)
    manual = _manual_axes()
    if manual:
        def drop(entry):
            if entry is None:
                return None
            axes = (entry,) if isinstance(entry, str) else tuple(entry)
            kept = tuple(a for a in axes if a not in manual)
            return kept[0] if len(kept) == 1 else (kept or None)
        spec = P(*[drop(e) for e in spec])
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))
