"""In-camera processing pipelines (paper Fig. 1), generalized.

The paper decomposes a camera application into a linear pipeline of
functional blocks ``B_1 .. B_n``.  Each block has a computation cost and
each block *boundary* has a communication cost (the cost of shipping that
intermediate off the node).  Blocks are either *core* (required for
correctness: the NN authenticator, the BSSA depth solver) or *optional*
(data reducers that only exist to make everything downstream cheaper:
motion detection, Viola-Jones).

This module is the shared vocabulary for both halves of the framework:

* the **camera substrate** (``repro.camera``) instantiates the paper's two
  pipelines block-for-block, and
* the **LM substrate** (``repro.models``) exports every transformer as a
  block pipeline (embed / attn / ffn / unembed ...) so the same placement
  solver (``repro.core.placement``) can reason about TPU-pod execution.

Costs are stored as *work descriptors* (flops, bytes in/out, working-set
bytes), never as seconds or joules — converting work into cost is the job
of a ``HardwareProfile`` (``repro.core.costmodel``), which is what lets a
single pipeline be evaluated on an MSP430, a 65 nm ASIC, or a TPU v5e pod.
"""

from __future__ import annotations

import dataclasses
import enum
import math
from typing import Callable, Iterable, Sequence


class BlockKind(enum.Enum):
    """Paper §II-A: core blocks are essential; optional blocks only filter."""

    CORE = "core"
    OPTIONAL = "optional"
    # Source blocks produce data (the image sensor); they cannot be offloaded
    # and have no upstream edge.
    SOURCE = "source"


@dataclasses.dataclass(frozen=True)
class Block:
    """One functional block ``B_i`` of an in-camera pipeline.

    Attributes
    ----------
    name:            human-readable id (``"motion"``, ``"vj"``, ``"nn"``,
                     ``"attn[12]"`` ...).
    flops:           arithmetic work to process one unit of input (one frame
                     for camera pipelines, one step-batch for LM pipelines).
    bytes_in:        size of the block's input for one unit.
    bytes_out:       size of the block's output for one unit.  ``bytes_out``
                     of ``B_i`` is the communication payload if the pipeline
                     is cut after ``B_i``.
    kind:            core / optional / source.
    selectivity:     expected fraction of input *units* that survive the
                     block (paper: motion passes 12/62 frames = 0.19; VJ
                     passes 40 windows of ~7.9k = 0.005).  Downstream blocks
                     only pay for surviving units; this is exactly how the
                     paper's optional blocks buy their keep.
    working_set:     bytes the block needs resident while running (paper:
                     the 1 kB two-row integral buffer vs the 57 kB frame
                     buffer).  Used for VMEM/SRAM feasibility checks.
    sram_kib:        on-chip memory of the paper's ASIC implementation, kept
                     for the faithful reproduction tables (0 if n/a).
    meta:            free-form tag dict (layer index, shard axes, ...).
    """

    name: str
    flops: float
    bytes_in: float
    bytes_out: float
    kind: BlockKind = BlockKind.CORE
    selectivity: float = 1.0
    working_set: float = 0.0
    requires: tuple = ()              # optional blocks this block needs on-node
                                      # (paper: the NN ASIC consumes VJ's 20x20
                                      # windows over CSI2 — running it in-camera
                                      # without FD is not a wirable config)
    meta: tuple = ()

    def scaled(self, unit_fraction: float) -> "Block":
        """Return a copy with work scaled by the fraction of units reaching it."""
        return dataclasses.replace(
            self,
            flops=self.flops * unit_fraction,
            bytes_in=self.bytes_in * unit_fraction,
            bytes_out=self.bytes_out * unit_fraction,
        )

    @property
    def arithmetic_intensity(self) -> float:
        denom = self.bytes_in + self.bytes_out
        return self.flops / denom if denom else math.inf


@dataclasses.dataclass(frozen=True)
class Pipeline:
    """A linear pipeline ``B_1 -> B_2 -> ... -> B_n`` (paper Fig. 1).

    ``blocks[0]`` is normally a SOURCE block (the sensor).  The pipeline is
    *configurable*: optional blocks may be dropped, and the pipeline may be
    *cut* after any block, offloading the remainder.  Enumerating those
    configurations is ``repro.core.placement``'s job; this class only holds
    structure and provides the effective (selectivity-scaled) view.
    """

    name: str
    blocks: tuple

    def __post_init__(self):
        object.__setattr__(self, "blocks", tuple(self.blocks))
        names = [b.name for b in self.blocks]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate block names in pipeline {self.name}: {names}")

    # -- structure ----------------------------------------------------------
    def __iter__(self):
        return iter(self.blocks)

    def __len__(self):
        return len(self.blocks)

    def block(self, name: str) -> Block:
        for b in self.blocks:
            if b.name == name:
                return b
        raise KeyError(name)

    def index(self, name: str) -> int:
        for i, b in enumerate(self.blocks):
            if b.name == name:
                return i
        raise KeyError(name)

    @property
    def optional_names(self) -> tuple:
        return tuple(b.name for b in self.blocks if b.kind is BlockKind.OPTIONAL)

    # -- configuration ------------------------------------------------------
    def configure(self, include_optional: Iterable[str] = ()) -> "Pipeline":
        """Drop optional blocks not listed in ``include_optional``.

        Core and source blocks are always kept.  This mirrors the paper's
        configuration space in Fig. 8 (e.g. "motion+FD, offload NN" is
        ``configure({"motion", "vj"})`` cut after ``vj``).
        """
        keep = set(include_optional)
        unknown = keep - set(self.optional_names)
        if unknown:
            raise KeyError(f"not optional blocks of {self.name}: {sorted(unknown)}")
        blocks = tuple(
            b for b in self.blocks
            if b.kind is not BlockKind.OPTIONAL or b.name in keep
        )
        return Pipeline(self.name, blocks)

    def effective_blocks(self) -> tuple:
        """Blocks with work scaled by cumulative upstream selectivity.

        Paper §III-D: "The computation power is the sum of power at that
        block and the processing blocks preceding it" — but a filter that
        passes 19% of frames means every later block only runs on 19% of
        units.  We propagate the product of upstream selectivities.
        """
        out = []
        frac = 1.0
        for b in self.blocks:
            out.append(b.scaled(frac))
            frac *= b.selectivity
        return tuple(out)

    def cut_payload_bytes(self, cut_after: int) -> float:
        """Bytes/unit crossing the offload link when cut after index ``cut_after``.

        ``bytes_out`` is per *surviving* unit, so the payload includes the
        block's own selectivity (a filter that passes 20% of frames only
        transmits those 20%).  ``cut_after = len-1`` means fully on-node —
        the final block's (tiny) output still ships (the paper's NN still
        transmits its 1-bit answer).
        """
        eff = self.effective_blocks()
        i = cut_after if cut_after >= 0 else 0
        return eff[i].bytes_out * self.blocks[i].selectivity

    def total_flops(self, upto: int | None = None) -> float:
        eff = self.effective_blocks()[: None if upto is None else upto + 1]
        return sum(b.flops for b in eff)

    def describe(self) -> str:
        lines = [f"Pipeline {self.name}:"]
        for b in self.effective_blocks():
            lines.append(
                f"  {b.name:>14s} [{b.kind.value:8s}] flops={b.flops:.3e} "
                f"in={b.bytes_in:.3e}B out={b.bytes_out:.3e}B sel={b.selectivity:.3g}"
            )
        return "\n".join(lines)


def linear_pipeline(name: str, specs: Sequence[dict]) -> Pipeline:
    """Convenience constructor from a list of dicts."""
    blocks = []
    for s in specs:
        s = dict(s)
        kind = s.pop("kind", "core")
        blocks.append(Block(kind=BlockKind(kind), **s))
    return Pipeline(name, tuple(blocks))
