"""Computation–communication cost model (paper §II-A, §III-D, §IV-C).

The paper evaluates every pipeline configuration under one of two regimes:

* **Energy regime** (face authentication, §III): the node is
  power-constrained; the cost of a configuration is the *sum* of the
  average power of every on-node block plus the power to transmit the
  cut-point payload.  "We assume the energy cost of computing in the cloud
  as free ... but the cost to get data to the cloud is not."

* **Throughput regime** (VR video, §IV): the pipeline is pipelined across
  frames; the cost of a configuration is the *bottleneck* — the minimum
  over blocks of per-block throughput, and the offload link's throughput on
  the cut-point payload.  Real-time iff both clear 30 FPS.

Both regimes consume the same inputs: a ``Pipeline`` of work descriptors
(``repro.core.pipeline``) and per-block ``HardwareProfile``s.  The same
machinery scores TPU sharding plans through the three-term roofline model
(``Roofline``), which is the regime the assignment grades: compute, memory
and collective seconds per step on a v5e mesh.

Hardware constants for the TPU target (assignment-specified):
197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Mapping, Sequence

from repro.core.pipeline import Block, BlockKind, Pipeline

# ---------------------------------------------------------------------------
# Hardware profiles
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class HardwareProfile:
    """A device (or link) that can host a block (or a cut-point payload).

    Energy-regime fields
    --------------------
    p_active_w:     power while actively processing (W).
    p_leak_w:       standby power while idle but powered (W).  The paper's
                    sub-threshold analysis (Fig. 6) makes leakage a
                    first-class term; it is what makes the in-camera NN a
                    *bad* deal at low duty cycle (§III-D) and a *good* deal
                    once window traffic amortizes it (the 8 MP result).
    joules_per_byte: transmit energy for link profiles (J/B).

    Throughput-regime fields
    ------------------------
    flops_per_s:    sustained arithmetic rate.
    mem_bw:         bytes/s to the block's working memory.
    link_bw:        bytes/s for link profiles.
    """

    name: str
    # throughput regime
    flops_per_s: float = 0.0
    mem_bw: float = 0.0
    link_bw: float = 0.0
    # energy regime
    p_active_w: float = 0.0
    p_leak_w: float = 0.0
    joules_per_byte: float = 0.0

    def time_for(self, block: Block) -> float:
        """Seconds to process one unit of ``block`` (throughput regime).

        max(compute, memory) — the block-level roofline.  Profiles with only
        one rate defined use that rate alone.
        """
        terms = []
        if self.flops_per_s:
            terms.append(block.flops / self.flops_per_s)
        if self.mem_bw:
            terms.append((block.bytes_in + block.bytes_out) / self.mem_bw)
        if not terms:
            raise ValueError(f"profile {self.name} has no throughput rates")
        return max(terms)

    def power_for(self, block: Block, duty: float) -> float:
        """Average watts to run ``block`` at duty cycle ``duty`` (energy regime)."""
        duty = min(max(duty, 0.0), 1.0)
        return self.p_leak_w + duty * max(self.p_active_w - self.p_leak_w, 0.0)


# -- TPU v5e target (assignment constants) ----------------------------------

TPU_V5E = HardwareProfile(
    name="tpu_v5e",
    flops_per_s=197e12,     # bf16 peak per chip
    mem_bw=819e9,           # HBM
    link_bw=50e9,           # per ICI link
)

# Pod-to-pod (data-center network / DCI) — the "RF offload link" of a
# multi-pod job.  ~25 GB/s effective per chip-pair is generous for DCN;
# what matters to the placement solver is that it is the slow axis.
POD_LINK = HardwareProfile(name="pod_link", link_bw=12.5e9)


# -- Paper §III profiles (Table I + calibration, see benchmarks/fa_system) --
# Absolute powers for sensor/motion and the RF joules-per-byte are not
# printed in the paper text (they live in unreadable figures); they are
# calibrated in ``repro.camera.calibration`` so that the paper's *stated*
# claims hold exactly:  +28% total power when adding the NN in-camera,
# cost-crossover at 2.68x comm energy, lowest-power config = motion+VJ.
# Table I values (337 uW VJ, 393 uW NN, 181 uW MSP430, 27.9 MHz) are used
# verbatim.

MSP430 = HardwareProfile(
    name="openmsp430",
    flops_per_s=27.9e6 / 8.0,   # 16-bit MAC in ~8 cycles w/ HW multiplier
    p_active_w=181e-6,
    p_leak_w=2e-6,
)

VJ_ASIC = HardwareProfile(
    name="vj_asic",
    flops_per_s=27.9e6 * 2,     # streaming: ~2 ops/cycle (accumulate + compare)
    p_active_w=337e-6,
    p_leak_w=67e-6,             # always-powered frame-buffer SRAM share
)

NN_ASIC = HardwareProfile(
    name="nn_asic",
    flops_per_s=27.9e6 * 16,    # 8 PEs x MAC = 16 ops/cycle
    p_active_w=393e-6,
    p_leak_w=53e-6,             # calibrated: weight SRAM leakage (see §III-D fit)
)

IMAGE_SENSOR = HardwareProfile(
    name="image_sensor", p_active_w=25e-6, p_leak_w=25e-6,  # always-on capture
)

MOTION_ASIC = HardwareProfile(
    name="motion_asic", p_active_w=15e-6, p_leak_w=15e-6,   # always-on frame diff
)

# RF offload link; joules_per_byte is overwritten by calibration.
RF_LINK = HardwareProfile(name="rf_link", joules_per_byte=83e-9)


# -- Paper §IV profiles (Zynq eval platform, Fig. 12-14) ---------------------
# Rates chosen to reproduce the paper's relative results: FPGA ~10x GPU-or-
# CPU on BSSA, CPU/GPU below 30 FPS on depth refinement, only FPGA config
# real-time.  See benchmarks/vr_system.py.

# Sustained rates on the BSSA workload, anchored to the paper's relative
# claims: the Zynq eval FPGA beats the tuned-Halide CPU baseline by 10x
# (§IV-C "up to 10x"); a compute unit = 18 DSPs = an 8-MAC f32 cascade at
# 125 MHz (2 flops/MAC).  The Fig. 14 "FPGA" row is the production target
# (Table II: Virtex UltraScale+, 682 units) — the Zynq is the 2-camera
# eval vehicle.
_FPGA_UNIT_FLOPS = 8 * 2 * 125e6              # one compute unit
ARM_A9 = HardwareProfile(name="arm_cortex_a9", flops_per_s=2.4e9, mem_bw=4e9)
QUADRO_GPU = HardwareProfile(name="quadro_k2200", flops_per_s=8e9, mem_bw=80e9)
ZYNQ_FPGA = HardwareProfile(
    name="zynq7020_fpga", flops_per_s=12 * _FPGA_UNIT_FLOPS, mem_bw=8e9,
)
VIRTEX_FPGA = HardwareProfile(
    name="virtex_us_fpga", flops_per_s=682 * _FPGA_UNIT_FLOPS, mem_bw=64e9,
)
ETH_25G = HardwareProfile(name="eth_25g", link_bw=25e9 / 8)
ETH_400G = HardwareProfile(name="eth_400g", link_bw=400e9 / 8)


# ---------------------------------------------------------------------------
# Energy regime (paper §III)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class EnergyReport:
    """Cost of one pipeline configuration in the energy regime."""

    config_name: str
    compute_w: float                 # sum of on-node block powers
    comm_w: float                    # transmit power for cut payload
    per_block_w: tuple               # ((name, watts), ...) cumulative detail
    cut_after: str

    @property
    def total_w(self) -> float:
        return self.compute_w + self.comm_w


def energy_cost(
    pipeline: Pipeline,
    profiles: Mapping[str, HardwareProfile],
    link: HardwareProfile,
    cut_after: str,
    unit_rate_hz: float = 1.0,
    duties: Mapping[str, float] | None = None,
    config_name: str | None = None,
) -> EnergyReport:
    """Total average power of a configuration (paper Fig. 8 / Fig. 9).

    ``pipeline`` must already be ``configure()``d (optional blocks chosen).
    ``cut_after`` names the last on-node block; its (selectivity-scaled)
    output is the offload payload.  ``unit_rate_hz`` is the source rate
    (1 FPS for WISPCam).  ``duties`` optionally overrides per-block duty
    cycles; by default duty = time_for(block) * effective unit rate.
    """
    duties = dict(duties or {})
    cut_idx = pipeline.index(cut_after)
    eff = pipeline.effective_blocks()

    per_block = []
    compute_w = 0.0
    for i, blk in enumerate(eff[: cut_idx + 1]):
        prof = profiles[blk.name]
        if blk.name in duties:
            duty = duties[blk.name]
        elif prof.flops_per_s or prof.mem_bw:
            duty = prof.time_for(blk) * unit_rate_hz
        else:
            duty = 1.0  # always-on blocks (sensor, motion comparator)
        w = prof.power_for(blk, duty)
        compute_w += w
        per_block.append((blk.name, w))

    payload = pipeline.cut_payload_bytes(cut_idx) * unit_rate_hz
    comm_w = payload * link.joules_per_byte
    return EnergyReport(
        config_name=config_name or f"{pipeline.name}|cut={cut_after}",
        compute_w=compute_w,
        comm_w=comm_w,
        per_block_w=tuple(per_block),
        cut_after=cut_after,
    )


# ---------------------------------------------------------------------------
# Throughput regime (paper §IV)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ThroughputReport:
    """Cost of one configuration in the throughput regime (paper Fig. 14)."""

    config_name: str
    compute_fps: float               # bottleneck over on-node blocks
    comm_fps: float                  # link rate / cut payload
    per_block_fps: tuple
    cut_after: str

    @property
    def fps(self) -> float:
        return min(self.compute_fps, self.comm_fps)

    def realtime(self, target_fps: float = 30.0) -> bool:
        """Paper: real-time iff *both* compute and comm clear the target."""
        return self.compute_fps >= target_fps and self.comm_fps >= target_fps


def throughput_cost(
    pipeline: Pipeline,
    profiles: Mapping[str, HardwareProfile],
    link: HardwareProfile,
    cut_after: str,
    config_name: str | None = None,
) -> ThroughputReport:
    """Bottleneck throughput of a configuration (paper §IV-C methodology).

    "Because this processing flow can be pipelined across frames ... the
    total cost of the system [is] dominated by the lowest-throughput block."
    """
    cut_idx = pipeline.index(cut_after)
    eff = pipeline.effective_blocks()
    per_block = []
    compute_fps = math.inf
    for blk in eff[: cut_idx + 1]:
        prof = profiles[blk.name]
        if not (prof.flops_per_s or prof.mem_bw):
            continue  # source blocks: rate set by the sensor, not a bound here
        t = prof.time_for(blk)
        fps = (1.0 / t) if t > 0 else math.inf
        per_block.append((blk.name, fps))
        compute_fps = min(compute_fps, fps)
    payload = pipeline.cut_payload_bytes(cut_idx)
    comm_fps = link.link_bw / payload if payload else math.inf
    return ThroughputReport(
        config_name=config_name or f"{pipeline.name}|cut={cut_after}",
        compute_fps=compute_fps,
        comm_fps=comm_fps,
        per_block_fps=tuple(per_block),
        cut_after=cut_after,
    )


# ---------------------------------------------------------------------------
# TPU roofline (assignment §Roofline) — the throughput regime at pod scale
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Roofline:
    """Three-term roofline for one compiled (arch x shape x mesh) cell.

    compute_s    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory_s     = HLO_bytes / (chips * HBM_bw)
    collective_s = collective_bytes / (chips * link_bw)

    ``flops``/``bytes`` are *global* (whole-program) quantities as reported
    by ``compiled.cost_analysis()``; ``collective_bytes`` is summed from the
    HLO text (see ``repro.launch.hlo_stats``).
    """

    name: str
    flops: float
    hbm_bytes: float
    collective_bytes: float
    n_chips: int
    model_flops: float = 0.0            # 6*N*D (or 6*N_active*D for MoE)
    ideal_bytes: float = 0.0            # structural minimum HBM traffic
    chip: HardwareProfile = TPU_V5E
    link: HardwareProfile = TPU_V5E

    @property
    def compute_s(self) -> float:
        return self.flops / (self.n_chips * self.chip.flops_per_s)

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / (self.n_chips * self.chip.mem_bw)

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / (self.n_chips * self.link.link_bw)

    @property
    def step_s(self) -> float:
        """Optimistic overlapped step time: the dominant term."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flop_fraction(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — catches remat / redundant compute."""
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def ideal_s(self) -> float:
        """Unavoidable step time: max of the pure-model-FLOP time and the
        structural-minimum HBM time (params + caches + boundary
        activations).  Decode steps are memory-bound by construction —
        judging them against a FLOP-only ideal reports 0% for every
        possible implementation; the bytes term fixes the denominator."""
        t_flops = (self.model_flops / (self.n_chips * self.chip.flops_per_s)
                   if self.model_flops else 0.0)
        t_bytes = (self.ideal_bytes / (self.n_chips * self.chip.mem_bw)
                   if self.ideal_bytes else 0.0)
        return max(t_flops, t_bytes)

    @property
    def roofline_fraction(self) -> float:
        """ideal_s / step_s — the score reported in EXPERIMENTS.md §Perf."""
        ideal = self.ideal_s
        return ideal / self.step_s if (ideal and self.step_s) else 0.0

    def row(self) -> dict:
        return {
            "name": self.name,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "model_flops": self.model_flops,
            "hlo_flops": self.flops,
            "useful_flop_fraction": self.useful_flop_fraction,
            "roofline_fraction": self.roofline_fraction,
        }


def format_roofline_table(rows: Sequence[Roofline]) -> str:
    hdr = (
        f"{'cell':<38s} {'compute_s':>11s} {'memory_s':>11s} {'collect_s':>11s} "
        f"{'dominant':>10s} {'useful%':>8s} {'roofline%':>9s}"
    )
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r.name:<38s} {r.compute_s:>11.4e} {r.memory_s:>11.4e} "
            f"{r.collective_s:>11.4e} {r.dominant:>10s} "
            f"{100*r.useful_flop_fraction:>7.1f}% {100*r.roofline_fraction:>8.1f}%"
        )
    return "\n".join(lines)
