"""The paper's primary contribution: computation-communication tradeoff
analysis and placement for block pipelines (camera nodes then, TPU pods now).

- pipeline:  Block / Pipeline work descriptors (paper Fig. 1)
- costmodel: energy + throughput regimes, hardware profiles, TPU roofline
- placement: cut-point solver + sharding-plan solver
- cascade:   progressive filtering, TPU-native (masked + compacting)
- reduction: early data reduction for the slow link (int8 EF, top-k, pod AR)
"""

from repro.core.pipeline import Block, BlockKind, Pipeline, linear_pipeline
from repro.core.costmodel import (
    HardwareProfile,
    Roofline,
    EnergyReport,
    ThroughputReport,
    energy_cost,
    throughput_cost,
    format_roofline_table,
    TPU_V5E,
    POD_LINK,
)
from repro.core.placement import (
    CutSolution,
    ShardingPlan,
    PlanScore,
    solve_cut,
    solve_sharding,
    rank_sharding,
    estimate_plan,
)
from repro.core.cascade import (
    Stage,
    CascadeResult,
    masked_cascade,
    compacting_cascade,
    cascade_flops,
    capacities_from_counts,
    compaction_work,
)
from repro.core.reduction import (
    EFState,
    quantize_int8,
    dequantize_int8,
    quantize_bits,
    ef_compress_int8,
    ef_compress_topk,
    compressed_pod_allreduce,
    uncompressed_pod_allreduce,
    compress_boundary,
)
