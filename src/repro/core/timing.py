"""Warm-then-average wall-clock measurement, dataclass-aware blocking.

ONE implementation for every consumer — the hot-path benchmarks
(``benchmarks/timing.py`` re-exports these) and the offload cut
controller (``repro.camera.offload.controller``), whose measured Block
descriptors feed ``solve_cut``.  A fix to blocking semantics or timer
choice here reaches both at once.
"""

from __future__ import annotations

import dataclasses
import time


def block(out):
    """Block until every device array in ``out`` is ready.

    Handles pytrees and plain result dataclasses (``WirePayload``,
    ``FAExecResult``) alike — an unexpanded dataclass would be a no-op
    pytree leaf and the timer would stop before the device work finished.
    """
    import jax

    if dataclasses.is_dataclass(out) and not isinstance(out, type):
        out = vars(out)
    jax.block_until_ready(out)


def timed(fn, *args, reps: int = 3):
    """(seconds_per_rep, last_output): one warm call (compile + caches),
    then ``reps`` timed calls, blocking on device completion."""
    out = fn(*args)
    block(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    block(out)
    return (time.perf_counter() - t0) / reps, out
