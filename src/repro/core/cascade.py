"""Progressive filtering cascades, TPU-native (paper §III, Fig. 2 & 4b).

The face-authentication pipeline is a cascade: motion detection passes a
fraction of frames to Viola-Jones, which passes a fraction of windows to
the NN.  The VJ classifier is *itself* a cascade of stages.  The paper's
observation is that this structure "spend[s] more computation on windows
where there is likely to be a face, rather than executing a uniform
computation at every window."

On a GPU/ASIC this is data-dependent control flow.  On TPU, data-dependent
shapes are hostile to XLA, so we adapt the idea (DESIGN.md §2) with two
TPU-idiomatic mechanisms:

1. **Masked cascade** (:func:`masked_cascade`): every stage computes on the
   full batch but multiplies by a live-mask; `jax.lax.cond`-free, fully
   static.  This saves *no* FLOPs but gives exact cascade semantics —
   it is the oracle, and what you use when stages are cheap.

2. **Compacting cascade** (:func:`compacting_cascade`): after each stage,
   survivors are *compacted* to the front (stable argsort on the mask) and
   the next stage runs on a statically-bounded prefix — a *capacity* in
   the MoE sense.  Work drops geometrically with stage selectivity while
   shapes stay static: this is the paper's "86% fewer classifier
   invocations" knob expressed for a systolic machine.  Overflowing
   survivors beyond capacity are dropped and counted (like MoE token
   dropping); capacities are chosen from measured stage selectivities the
   same way the paper chose window step/scale from workload statistics.

Both mechanisms are shape-polymorphic and jit/pjit-compatible; the
compacting variant is what `examples/cascade_serving.py` uses to put a
cheap scorer in front of a large LM — "Viola-Jones in front of the NN" for
an inference cluster.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Callable, Sequence

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Stage:
    """One cascade stage.

    fn:        (carry_items) -> scores, shape (batch,) float.  Items with
               score >= threshold survive.  fn must be jit-traceable.
    threshold: survival threshold.
    name:      for reporting.
    """

    fn: Callable
    threshold: float
    name: str = "stage"


@dataclasses.dataclass
class CascadeResult:
    mask: jax.Array            # (batch,) bool — survived every stage
    scores: jax.Array          # (n_stages, batch) raw scores (masked stages = -inf)
    n_survivors: jax.Array     # (n_stages,) int32 survivor counts per stage
    dropped: jax.Array         # (n_stages,) int32 capacity-overflow drops


def masked_cascade(stages: Sequence[Stage], items: jax.Array) -> CascadeResult:
    """Exact cascade semantics via masking; computes every stage on all items."""
    batch = items.shape[0]
    mask = jnp.ones((batch,), dtype=bool)
    all_scores = []
    counts = []
    for st in stages:
        scores = st.fn(items)
        scores = jnp.where(mask, scores, -jnp.inf)
        mask = mask & (scores >= st.threshold)
        all_scores.append(scores)
        counts.append(jnp.sum(mask).astype(jnp.int32))
    return CascadeResult(
        mask=mask,
        scores=jnp.stack(all_scores),
        n_survivors=jnp.stack(counts),
        dropped=jnp.zeros((len(stages),), jnp.int32),
    )


def _compact(items: jax.Array, mask: jax.Array, capacity: int):
    """Stable-move survivors to the front; return (compacted, perm, kept_mask).

    Static shapes: output batch == capacity.  Survivors beyond capacity are
    dropped (counted by the caller).  Non-survivors fill the tail of the
    capacity window and are masked off.
    """
    batch = items.shape[0]
    # key: survivors first (0), then dead (1); stable by original index.
    order = jnp.argsort(jnp.where(mask, 0, 1), stable=True)
    perm = order[:capacity]
    compacted = jnp.take(items, perm, axis=0)
    kept_mask = jnp.take(mask, perm, axis=0)
    return compacted, perm, kept_mask


def compacting_cascade(
    stages: Sequence[Stage],
    items: jax.Array,
    capacities: Sequence[int],
) -> CascadeResult:
    """Cascade with survivor compaction to statically-bounded batches.

    ``capacities[i]`` bounds the number of items stage ``i`` processes.
    ``capacities[0]`` must equal ``items.shape[0]``.  Returns masks/scores
    in the *original* index space.
    """
    if len(capacities) != len(stages):
        raise ValueError("need one capacity per stage")
    batch = items.shape[0]
    if capacities[0] != batch:
        raise ValueError("capacities[0] must equal the input batch")

    # original-index bookkeeping
    idx = jnp.arange(batch)
    cur_items, cur_idx = items, idx
    cur_mask = jnp.ones((batch,), bool)

    full_mask = jnp.ones((batch,), bool)
    all_scores = []
    counts = []
    drops = []

    for i, st in enumerate(stages):
        cap = capacities[i]
        if cur_items.shape[0] != cap:
            # count drops before shrinking
            n_live = jnp.sum(cur_mask)
            dropped_here = jnp.maximum(n_live - cap, 0).astype(jnp.int32)
            cur_items, perm, cur_mask = _compact(cur_items, cur_mask, cap)
            cur_idx = jnp.take(cur_idx, perm, axis=0)
        else:
            dropped_here = jnp.int32(0)

        scores = st.fn(cur_items)
        scores = jnp.where(cur_mask, scores, -jnp.inf)
        cur_mask = cur_mask & (scores >= st.threshold)

        # scatter scores / mask back to original index space; items dropped by
        # capacity are no longer carried, hence read back as dead.
        full_scores = jnp.full((batch,), -jnp.inf, scores.dtype).at[cur_idx].set(scores)
        full_mask = jnp.zeros((batch,), bool).at[cur_idx].set(cur_mask)

        all_scores.append(full_scores)
        counts.append(jnp.sum(cur_mask).astype(jnp.int32))
        drops.append(dropped_here)

    return CascadeResult(
        mask=full_mask,
        scores=jnp.stack(all_scores),
        n_survivors=jnp.stack(counts),
        dropped=jnp.stack(drops),
    )


def capacities_from_counts(batch: int, survivor_counts: Sequence[int],
                           margin: float = 1.5, quantum: int = 128) -> list:
    """Derive compacting capacities from *measured* per-stage survivor counts.

    ``survivor_counts[i]`` is the (max over calibration items) number of
    survivors after stage ``i``; stage ``i + 1``'s capacity bounds exactly
    that population.  ``margin`` multiplies the measurement and ``quantum``
    rounds up (lane-width friendly), so natural workload variation does not
    overflow into drops — the same measure-then-set-the-knob procedure the
    paper uses for window scale/step.  Stage 0 always gets the full batch.
    """
    caps = [int(batch)]
    for c in list(survivor_counts)[:-1]:
        cap = (int(math.ceil(float(c) * margin)) // quantum + 1) * quantum
        caps.append(int(min(batch, max(quantum, cap))))
    return caps


def compaction_work(stage_costs: Sequence[float], batch: int,
                    capacities: Sequence[int] | None = None) -> tuple:
    """(masked_total, compacted_total) unit-work for one cascade pass.

    The masked oracle evaluates every stage on the full batch; compaction
    clips stage ``i`` to ``capacities[i]``.  The ratio is the *actual* FLOP
    saving static-shape compaction realizes (vs the data-dependent ideal
    that ``cascade_flops`` counts).
    """
    masked = float(batch) * float(sum(stage_costs))
    if capacities is None:
        return masked, masked
    compacted = float(sum(float(c) * float(f)
                          for c, f in zip(capacities, stage_costs)))
    return masked, compacted


def cascade_flops(
    stage_flops: Sequence[float],
    selectivities: Sequence[float],
    capacities: Sequence[float] | None = None,
) -> float:
    """Expected per-item FLOPs of a cascade (analysis-side companion).

    With no capacities this is the paper's energy argument: stage i costs
    ``stage_flops[i] * prod(selectivities[:i])``.  With capacities, work is
    additionally clipped — the static-shape price of the TPU adaptation.
    """
    total = 0.0
    frac = 1.0
    for i, f in enumerate(stage_flops):
        eff = frac
        if capacities is not None:
            eff = min(eff, capacities[i])
        total += f * eff
        frac *= selectivities[i]
    return total
