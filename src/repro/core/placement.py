"""Offload / placement solver (the paper's configuration search, §III-D, §IV-C).

The paper hand-enumerates pipeline configurations — which optional blocks
to include and where to cut the pipeline for offload — and evaluates each
with the computation-communication cost model.  This module solves that
search exactly and generally:

* :func:`solve_cut` — exhaustive optimum over (optional-block subset x cut
  point) for a linear pipeline, in either cost regime.  The configuration
  spaces in the paper are tiny (<= 2^3 x 5), so exhaustive search *is* the
  exact algorithm; for deep LM pipelines we exploit that, with a fixed
  block subset, the energy objective is prefix-decomposable and a single
  O(n) sweep finds the best cut.

* :func:`solve_sharding` — the TPU-scale analogue: scores candidate
  sharding plans for an (arch x shape x mesh) cell with the three-term
  roofline model and returns the argmin.  Candidates are produced
  analytically (``estimate_plan``) so the solver can rank plans without
  compiling; the dry-run then validates the chosen plan with real
  ``cost_analysis`` numbers.

The unifying view (DESIGN.md §2): a sharding plan decides which bytes cross
which interconnect tier, exactly as the cut point decides which bytes cross
the RF link.
"""

from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Callable, Mapping, Sequence

from repro.core.costmodel import (
    EnergyReport,
    HardwareProfile,
    Roofline,
    ThroughputReport,
    energy_cost,
    throughput_cost,
)
from repro.core.pipeline import BlockKind, Pipeline


# ---------------------------------------------------------------------------
# Linear-pipeline cut solver (camera regime)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CutSolution:
    pipeline: Pipeline                  # configured pipeline (optionals chosen)
    cut_after: str
    report: object                      # EnergyReport | ThroughputReport
    objective: float                    # watts (energy) or -fps (throughput)
    all_reports: tuple                  # every configuration evaluated


def _cut_candidates(pipeline: Pipeline):
    # A cut is legal after any block except we never cut "before the source".
    return [b.name for b in pipeline.blocks]


def solve_cut(
    pipeline: Pipeline,
    profiles: Mapping[str, HardwareProfile],
    link: HardwareProfile,
    regime: str = "energy",
    unit_rate_hz: float = 1.0,
    duties: Mapping[str, float] | None = None,
    target_fps: float = 30.0,
) -> CutSolution:
    """Exact optimum over optional-block subsets x cut points.

    regime="energy": minimize total watts (paper §III).
    regime="throughput": maximize end-to-end FPS; ties broken toward fewer
    on-node blocks (paper §IV: offload as early as bandwidth allows).
    """
    if regime not in ("energy", "throughput"):
        raise ValueError(regime)

    reports = []
    best = None
    opts = pipeline.optional_names
    for r in range(len(opts) + 1):
        for subset in itertools.combinations(opts, r):
            cfg = pipeline.configure(subset)
            for cut in _cut_candidates(cfg):
                # structural dependencies: every on-node block's `requires`
                # must be satisfied by the included optional set
                cut_i = cfg.index(cut)
                if any(set(b.requires) - set(subset)
                       for b in cfg.blocks[: cut_i + 1]):
                    continue
                name = f"{'+'.join(subset) or 'none'}|cut={cut}"
                if regime == "energy":
                    rep = energy_cost(
                        cfg, profiles, link, cut,
                        unit_rate_hz=unit_rate_hz, duties=duties,
                        config_name=name,
                    )
                    obj = rep.total_w
                else:
                    rep = throughput_cost(cfg, profiles, link, cut, config_name=name)
                    obj = -rep.fps
                reports.append(rep)
                # tie-break toward fewer on-node blocks ("offload as early
                # as bandwidth allows"): the *configured* pipeline's cut
                # index is the on-node block count — the unconfigured
                # index would mis-order configs once optionals are dropped
                key = (obj, cut_i)
                if best is None or key < best[0]:
                    best = (key, cfg, cut, rep)

    _, cfg, cut, rep = best
    return CutSolution(
        pipeline=cfg,
        cut_after=cut,
        report=rep,
        objective=rep.total_w if regime == "energy" else -rep.fps,
        all_reports=tuple(reports),
    )


# ---------------------------------------------------------------------------
# TPU sharding-plan solver (pod regime)
# ---------------------------------------------------------------------------
#
# A *plan* assigns logical tensor axes to mesh axes (repro.parallel.sharding
# defines the vocabulary).  estimate_plan() computes the roofline terms of a
# transformer step under a plan analytically: per-layer matmul FLOPs, HBM
# traffic for weights/activations (with FSDP all-gathers), and the collective
# bytes implied by each parallelism choice.  The formulas are standard
# (Megatron/MaxText-style napkin math) — they only need to be *relatively*
# accurate to rank plans; the dry-run re-measures the winner exactly.


@dataclasses.dataclass(frozen=True)
class ShardingPlan:
    """A candidate parallelism assignment for one (arch x shape x mesh) cell."""

    name: str
    data: int = 1          # pure data-parallel ways (batch sharding)
    fsdp: int = 1          # ZeRO-style param/optimizer sharding ways (over data axis)
    tensor: int = 1        # TP ways (heads / mlp / vocab)
    expert: int = 1        # EP ways (MoE experts)
    sequence: int = 1      # context/sequence parallel ways
    pod: int = 1           # outer DP over pods
    grad_compress: bool = False   # int8 pod-axis gradient all-reduce (core/reduction)

    @property
    def n_chips(self) -> int:
        # EP reuses the tensor axis (experts shard over 'model'), so it does
        # not multiply the chip count.
        return self.data * self.fsdp * self.tensor * self.sequence * self.pod

    def describe(self) -> str:
        parts = [f"{k}={v}" for k, v in (
            ("dp", self.data), ("fsdp", self.fsdp), ("tp", self.tensor),
            ("ep", self.expert), ("sp", self.sequence), ("pod", self.pod))
            if v != 1]
        if self.grad_compress:
            parts.append("int8-podAR")
        return f"{self.name}({', '.join(parts) or 'replicated'})"


@dataclasses.dataclass(frozen=True)
class PlanScore:
    plan: ShardingPlan
    roofline: Roofline
    feasible: bool
    why_infeasible: str = ""


def estimate_plan(
    plan: ShardingPlan,
    *,
    name: str,
    params: float,                 # total parameter count
    active_params: float,          # per-token active params (MoE-aware)
    layer_flops: float,            # total fwd FLOPs for the step's tokens
    train: bool,
    tokens: int,                   # tokens in the step (batch*seq)
    d_model: int,
    seq: int,
    batch: int,
    n_experts: int = 1,
    top_k: int = 1,
    n_layers: int = 1,
    dtype_bytes: int = 2,
    hbm_gib: float = 16.0,
) -> PlanScore:
    """Analytic three-term roofline for a plan.  See module docstring.

    Standard napkin math:
      fwd flops ~= 2 * active_params * tokens ; train ~= 3x fwd (+remat ~4x).
      HBM bytes ~= params_bytes_resident + activation traffic.
      collectives:
        TP:   2 all-reduces of activations per layer (attn-out + mlp-out),
              ring cost ~ 2*(t-1)/t * bytes each.
        FSDP: all-gather params once per step (+reduce-scatter grads in train).
        DP/pod: all-reduce grads (2x params bytes, /compress factor).
        EP:   2 all-to-alls of top_k-expanded tokens per MoE layer.
        SP:   all-gather of KV (or ring permute) per attn layer.
    """
    chips = plan.n_chips
    why = ""

    mult = 3.0 if train else 1.0
    hlo_flops = layer_flops * mult
    if train:
        hlo_flops *= 4.0 / 3.0  # full remat recompute of fwd

    param_bytes = params * dtype_bytes
    # Parameter residency per chip: sharded by tp * fsdp * ep(expert slice).
    ep_ways = max(plan.expert, 1)
    shard_ways = plan.tensor * plan.fsdp * ep_ways if n_experts > 1 else plan.tensor * plan.fsdp
    resident = param_bytes / shard_ways
    # Optimizer state (f32 master + 2 moments) in training, ZeRO-sharded.
    opt_bytes = params * 12 / (plan.fsdp * plan.tensor * (ep_ways if n_experts > 1 else 1)) if train else 0.0
    act_bytes = tokens * d_model * dtype_bytes * n_layers / (plan.data * plan.fsdp * plan.pod * plan.sequence)
    if train:
        act_bytes *= 2  # saved boundary activations (full remat inside layers)
    per_chip_hbm = resident + opt_bytes + act_bytes
    feasible = per_chip_hbm < hbm_gib * 2**30
    if not feasible:
        why = f"per-chip HBM {per_chip_hbm/2**30:.1f} GiB > {hbm_gib} GiB"

    # HBM traffic: read params (x2 for train: grads write), activations stream.
    hbm_traffic = (param_bytes / (plan.tensor * (ep_ways if n_experts > 1 else 1))) * (4 if train else 1)
    hbm_traffic += act_bytes * (8 if train else 2)
    # cost_analysis reports global bytes; approximate global = per-chip * chips
    hbm_global = hbm_traffic * max(plan.data * plan.fsdp * plan.pod * plan.sequence, 1)

    # Collectives (global bytes on the wire).
    coll = 0.0
    tok_local = tokens / (plan.data * plan.fsdp * plan.pod * plan.sequence)
    act_layer = tok_local * d_model * dtype_bytes
    t = plan.tensor
    if t > 1:
        coll += n_layers * 2 * 2 * (t - 1) / t * act_layer * chips / t * (3 if train else 1)
    f = plan.fsdp
    if f > 1:
        coll += param_bytes / plan.tensor * (f - 1) / f * (3 if train else 1) * f  # AG fwd(+bwd) + RS grads
    dp = plan.data * plan.pod
    if train and dp > 1:
        grad_bytes = 2 * (params * 4) * (dp - 1) / dp / plan.fsdp
        if plan.grad_compress:
            grad_bytes /= 4.0   # int8 + scales over the pod axis
        coll += grad_bytes
    if n_experts > 1 and plan.expert > 1:
        # two all-to-alls (dispatch+combine) per MoE layer of top_k-expanded tokens
        coll += n_layers * 2 * top_k * act_layer * (plan.expert - 1) / plan.expert * chips / plan.expert * (3 if train else 1)
    if plan.sequence > 1:
        coll += n_layers * 2 * act_layer * (plan.sequence - 1) * (3 if train else 1)

    rl = Roofline(
        name=f"{name}|{plan.describe()}",
        flops=hlo_flops,
        hbm_bytes=hbm_global,
        collective_bytes=coll,
        n_chips=chips,
        model_flops=(6.0 if train else 2.0) * active_params * tokens,
    )
    return PlanScore(plan=plan, roofline=rl, feasible=feasible, why_infeasible=why)


def solve_sharding(
    candidates: Sequence[ShardingPlan],
    estimator: Callable[[ShardingPlan], PlanScore],
) -> PlanScore:
    """Pick the feasible plan with the lowest dominant roofline term.

    This is `solve_cut` at pod scale: enumerate configurations, score with
    the comp-comm model, take the argmin.  Returns the best PlanScore; all
    scores are attached for reporting.
    """
    scores = [estimator(p) for p in candidates]
    feas = [s for s in scores if s.feasible]
    pool = feas or scores
    return min(pool, key=lambda s: s.roofline.step_s)


def rank_sharding(
    candidates: Sequence[ShardingPlan],
    estimator: Callable[[ShardingPlan], PlanScore],
) -> list:
    scores = [estimator(p) for p in candidates]
    return sorted(scores, key=lambda s: (not s.feasible, s.roofline.step_s))
