"""Early data reduction before the slow link (paper's central finding).

"We find that an early data reduction step, either before complex
processing or offloading, is the most critical optimization for in-camera
systems."  (§Abstract, §V)

At pod scale the slow link is the pod-to-pod interconnect, and the bytes
crossing it are gradients (training) or boundary activations (pipelining /
serving).  This module provides the reduction operators the placement
solver can insert at a cut:

* int8 block-scaled quantization with **error feedback** — the moral
  equivalent of the paper's 8-bit datapath study (§III-A: 8-bit costs 0.4%
  accuracy, 41% power saving; 4-bit is past the knee).  We keep the same
  shape of experiment: tests sweep 16/8/4-bit and verify the knee.
* top-k sparsification with error feedback.
* :func:`compressed_pod_allreduce` — hierarchical all-reduce: full-precision
  reduce inside the pod (fast ICI), quantized exchange across pods (slow
  DCI), exactly "filter before you transmit".

All operators are pure-JAX, shard_map-compatible, and carry their state
(error-feedback residual) explicitly so they compose with jit/scan.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Quantization primitives
# ---------------------------------------------------------------------------


def quantize_int8(x: jax.Array, block: int = 256, key: jax.Array | None = None):
    """Block-scaled symmetric int8 quantization.

    Returns (q, scales) with q int8 of x.shape and scales of shape
    (ceil(n/block),) broadcast over flat blocks.  If ``key`` is given,
    stochastic rounding is used (unbiased — required for error feedback to
    converge).
    """
    flat = x.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.where(scale == 0, 1.0, scale)
    y = blocks / scale
    if key is not None:
        y = y + jax.random.uniform(key, y.shape, y.dtype, -0.5, 0.5)
    q = jnp.clip(jnp.round(y), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q: jax.Array, scale: jax.Array, shape, dtype=jnp.float32):
    blocks = q.astype(jnp.float32) * scale
    flat = blocks.reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape).astype(dtype)


def quantize_bits(x: jax.Array, bits: int, block: int = 256):
    """General b-bit symmetric quantizer (for the 16/8/4-bit knee sweeps)."""
    qmax = 2 ** (bits - 1) - 1
    flat = x.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / qmax
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(blocks / scale), -qmax, qmax)
    deq = (q * scale).reshape(-1)[:n].reshape(x.shape)
    return deq.astype(x.dtype)


# ---------------------------------------------------------------------------
# Error-feedback compression state
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class EFState:
    """Per-tensor error-feedback residual (pytree leaf dict in practice)."""

    residual: jax.Array

    @staticmethod
    def init(x: jax.Array) -> "EFState":
        return EFState(residual=jnp.zeros_like(x, dtype=jnp.float32))


def ef_compress_int8(x: jax.Array, state: EFState, block: int = 256):
    """Quantize x+residual to int8; new residual = input - dequant."""
    target = x.astype(jnp.float32) + state.residual
    q, scale = quantize_int8(target, block=block)
    deq = dequantize_int8(q, scale, x.shape)
    new_state = EFState(residual=target - deq)
    return (q, scale), deq, new_state


def ef_compress_topk(x: jax.Array, state: EFState, k_fraction: float = 0.01):
    """Top-|k| sparsification with error feedback.

    Returns (values, indices), dense decompressed tensor, new state.
    """
    target = (x.astype(jnp.float32) + state.residual).reshape(-1)
    n = target.shape[0]
    k = max(1, int(n * k_fraction))
    _, idx = jax.lax.top_k(jnp.abs(target), k)
    vals = target[idx]
    dense = jnp.zeros_like(target).at[idx].set(vals)
    new_state = EFState(residual=(target - dense).reshape(x.shape))
    return (vals, idx), dense.reshape(x.shape), new_state


# ---------------------------------------------------------------------------
# Hierarchical compressed all-reduce over the pod axis
# ---------------------------------------------------------------------------


def compressed_pod_allreduce(
    grad: jax.Array,
    state: EFState,
    *,
    pod_axis: str,
    inner_axes: tuple = (),
    block: int = 256,
) -> Tuple[jax.Array, EFState]:
    """All-reduce ``grad`` over (inner_axes + pod_axis) with int8 on the pod hop.

    Inside a shard_map:
      1. full-precision psum over ``inner_axes`` (fast ICI) — bytes stay on
         the fast link, exactly as the paper keeps cheap blocks on-node;
      2. int8(+scales) all_gather over ``pod_axis`` (slow link) — 4x fewer
         bytes than an fp32 ring all-reduce, 2x fewer than bf16;
      3. local dequant + sum, error feedback absorbs the quantization error.

    Wire bytes over the slow link: N/4 + scales vs 2N for a ring all-reduce
    — an ~8x reduction at pod_count=2 (EXPERIMENTS.md §Perf quantifies this
    on the compiled HLO).
    """
    if inner_axes:
        grad = jax.lax.psum(grad, inner_axes)
    (q, scale), _, new_state = ef_compress_int8(grad, state, block=block)
    q_all = jax.lax.all_gather(q, pod_axis)          # (pods, *q.shape) int8
    s_all = jax.lax.all_gather(scale, pod_axis)      # (pods, blocks, 1) f32
    deq = q_all.astype(jnp.float32) * s_all          # (pods, blocks, block)
    total_blocks = jnp.sum(deq, axis=0)
    flat = total_blocks.reshape(-1)
    n = grad.size
    out = flat[:n].reshape(grad.shape).astype(grad.dtype)
    return out, new_state


def uncompressed_pod_allreduce(grad, *, pod_axis, inner_axes=()):
    """Baseline: plain psum over every data axis (for A/B roofline tests)."""
    return jax.lax.psum(grad, inner_axes + (pod_axis,))


# ---------------------------------------------------------------------------
# Activation-boundary reduction (cut-point payload compression)
# ---------------------------------------------------------------------------


def compress_boundary(x: jax.Array, bits: int = 8, block: int = 256) -> jax.Array:
    """Fake-quantize an activation crossing a placement cut (straight-through).

    Used at pipeline-stage and pod boundaries when the placement solver
    marks the edge as comm-bound; gradient flows straight through.
    """
    deq = quantize_bits(jax.lax.stop_gradient(x), bits=bits, block=block)
    return x + jax.lax.stop_gradient(deq - x)
