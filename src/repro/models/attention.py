"""Attention: GQA/MQA/MHA, sliding-window, and DeepSeek MLA, with KV caches.

Three cache layouts, because the KV cache *is* a comp-comm artifact
(DESIGN.md §4): standard GQA caches (batch, S, n_kv, d_head) x2; sliding-
window attention caches only the window (a ring buffer — the paper's
"two-row integral buffer" idea applied to sequence state); MLA caches the
512-dim latent + rope key instead of 128 heads x 128 dims — a 40x cache
reduction that is exactly an "early data reduction before the slow link"
(HBM and, for sharded caches, ICI).

All softmax math in f32; matmuls accumulate in f32 (MXU semantics).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, dense, rms_norm, spec
from repro.parallel.axes import constrain

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------


def attn_specs(cfg) -> dict:
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.d_head
    dt = cfg.param_dtype
    if cfg.attn_type == "mla":
        m = cfg.mla
        out = {
            "wq": spec((d, H, m.qk_nope + m.qk_rope), ("embed", "heads", None), dtype=dt),
            "wkv_down": spec((d, m.kv_lora + m.qk_rope), ("embed", "kv_lora"), dtype=dt),
            "kv_norm": spec((m.kv_lora,), ("kv_lora",), "ones", dtype=dt),
            "wk_up": spec((m.kv_lora, H, m.qk_nope), ("kv_lora", "heads", None), dtype=dt),
            "wv_up": spec((m.kv_lora, H, m.v_dim), ("kv_lora", "heads", None), dtype=dt),
            "wo": spec((H, m.v_dim, d), ("heads", None, "embed"), dtype=dt),
        }
        return out
    out = {
        "wq": spec((d, H, hd), ("embed", "heads", None), dtype=dt),
        "wk": spec((d, KV, hd), ("embed", "kv_heads", None), dtype=dt),
        "wv": spec((d, KV, hd), ("embed", "kv_heads", None), dtype=dt),
        "wo": spec((H, hd, d), ("heads", None, "embed"), dtype=dt),
    }
    if cfg.attn_bias:
        out["bq"] = spec((H, hd), ("heads", None), "zeros", dtype=dt)
        out["bk"] = spec((KV, hd), ("kv_heads", None), "zeros", dtype=dt)
        out["bv"] = spec((KV, hd), ("kv_heads", None), "zeros", dtype=dt)
    if cfg.qk_norm:
        out["q_norm"] = spec((hd,), (None,), "ones", dtype=dt)
        out["k_norm"] = spec((hd,), (None,), "ones", dtype=dt)
    return out


# ---------------------------------------------------------------------------
# Masks
# ---------------------------------------------------------------------------


def causal_mask(q_pos: jax.Array, k_pos: jax.Array, window: Optional[int] = None):
    """(q, k) bool mask: True = attend.  window limits lookback (SWA)."""
    m = k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        m &= k_pos[None, :] > (q_pos[:, None] - window)
    return m


def _mha(q, k, v, mask, scale):
    """q: (b,s,kv,g,d) k,v: (b,t,kv,d) mask: (s,t) or (b,s,t) -> (b,s,kv,g,dv).

    Dense formulation — decode path only (s=1, tiny logits).  Train/prefill
    use :func:`_mha_streaming` (chunked online softmax), which never
    materializes the (s, t) logit matrix; full logits at 512 devices cost
    GiBs of per-device temp (measured — EXPERIMENTS.md §Perf iteration 1).
    """
    logits = jnp.einsum("bskgd,btkd->bkgst", q, k, preferred_element_type=jnp.float32)
    logits = logits * scale
    if mask.ndim == 2:
        mask = mask[None]
    logits = jnp.where(mask[:, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(v.dtype)


def _pick_chunk(t: int, target: int = 1024) -> int:
    """Largest divisor of t that is <= target (static shapes need exactness)."""
    c = min(t, target)
    while t % c:
        c -= 1
    return c


def _mha_streaming(q, k, v, q_pos, k_pos, scale, window=None, chunk=1024):
    """Online-softmax attention over key chunks (flash-attention semantics).

    q: (b, s, H, d) — full query heads (GQA already expanded; expanding the
    sharded head axis keeps TP clean: no (kv, group) reshape across the
    sharded dimension).  k, v: (b, t, H, d).  q_pos: (s,), k_pos: (t,).
    Returns (b, s, H, d).  Never materializes (s, t); peak temp per chunk is
    (b, H, s, chunk) f32.  Also the reference semantics for
    kernels/flash_attention.
    """
    b, s, H, d = q.shape
    t = k.shape[1]
    dv = v.shape[-1]                 # may differ from d (MLA folded keys)
    c = _pick_chunk(t, chunk)
    n_chunks = t // c
    q32 = q.astype(jnp.float32) * scale

    kc = k.reshape(b, n_chunks, c, H, d)
    vc = v.reshape(b, n_chunks, c, H, dv)
    pc = k_pos.reshape(n_chunks, c)

    def body(carry, xs):
        m, l, acc = carry                       # (b,H,s), (b,H,s), (b,H,s,d)
        k_i, v_i, p_i = xs                      # (b,c,H,d), (b,c,H,d), (c,)
        logits = jnp.einsum("bshd,bchd->bhsc", q32, k_i.astype(jnp.float32))
        valid = p_i[None, :] <= q_pos[:, None]  # (s, c)
        if window is not None:
            valid &= p_i[None, :] > (q_pos[:, None] - window)
        logits = jnp.where(valid[None, None], logits, NEG_INF)
        m_i = jnp.max(logits, axis=-1)          # (b,H,s)
        m_new = jnp.maximum(m, m_i)
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(logits - m_new[..., None])  # (b,H,s,c)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhsc,bchd->bhsd", p, v_i.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    init = (
        jnp.full((b, H, s), NEG_INF, jnp.float32),
        jnp.zeros((b, H, s), jnp.float32),
        jnp.zeros((b, H, s, dv), jnp.float32),
    )
    (m, l, acc), _ = jax.lax.scan(
        body, init,
        (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0), pc),
    )
    out = acc / jnp.maximum(l, 1e-37)[..., None]
    return jnp.moveaxis(out, 1, 2).astype(v.dtype)   # (b,s,H,d)


def _expand_kv(k, n_heads: int):
    """(b, t, kv, d) -> (b, t, H, d) by repeating each kv head g times.
    The repeat happens on the sharded head axis, dividing cleanly under TP."""
    kv = k.shape[2]
    g = n_heads // kv
    if g == 1:
        return k
    return jnp.repeat(k, g, axis=2)


# ---------------------------------------------------------------------------
# Standard attention (GQA / MQA / MHA, optional sliding window)
# ---------------------------------------------------------------------------


def _project_qkv(params, cfg, x):
    q = dense(params["wq"], x, "bsd,dhe->bshe", waxes=("embed", "heads", None))
    k = dense(params["wk"], x, "bsd,dke->bske", waxes=("embed", "kv_heads", None))
    v = dense(params["wv"], x, "bsd,dke->bske", waxes=("embed", "kv_heads", None))
    if cfg.attn_bias:
        q = q + params["bq"].astype(q.dtype)
        k = k + params["bk"].astype(k.dtype)
        v = v + params["bv"].astype(v.dtype)
    if cfg.qk_norm:
        q = rms_norm(params["q_norm"], q, cfg.norm_eps)
        k = rms_norm(params["k_norm"], k, cfg.norm_eps)
    return q, k, v


def attention_train(params, cfg, x, positions, return_kv=False):
    """Full-sequence causal attention (streaming softmax).  x: (b, s, d)."""
    b, s, _ = x.shape
    H = cfg.n_heads
    q, k, v = _project_qkv(params, cfg, x)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, ("batch", "seq", "heads_act", None))
    kv_entry = _ring_cache_entry(cfg, k, v) if return_kv else None
    k = constrain(_expand_kv(k, H), ("batch", "kv_seq", "heads_act", None))
    v = constrain(_expand_kv(v, H), ("batch", "kv_seq", "heads_act", None))
    window = cfg.window if cfg.attn_type == "swa" else None
    pos = jnp.arange(s, dtype=jnp.int32)
    out = _mha_streaming(q, k, v, pos, pos, 1.0 / math.sqrt(cfg.d_head),
                         window=window)
    y = dense(params["wo"], out, "bshe,hed->bsd", waxes=("heads", None, "embed"))
    if return_kv:
        return y, kv_entry
    return y


def _ring_cache_entry(cfg, k, v):
    """Arrange prefill K/V into the decode cache layout.

    Full attention: identity.  SWA: the last ``window`` positions placed at
    ring slots ``pos % window`` (the decode layout).
    """
    if cfg.attn_type != "swa":
        return {"k": k, "v": v}
    S = k.shape[1]
    W = cfg.window
    if S <= W:
        pad = [(0, 0), (0, W - S), (0, 0), (0, 0)]
        return {"k": jnp.pad(k, pad), "v": jnp.pad(v, pad)}
    # slot i <- largest position p < S with p % W == i
    slots = jnp.arange(W)
    pos = (S - 1) - ((S - 1 - slots) % W)
    return {"k": k[:, pos], "v": v[:, pos]}


def init_cache(cfg, batch: int, max_seq: int, dtype=None):
    """Allocate a decode cache.  SWA caches only the window (ring buffer)."""
    dtype = dtype or cfg.param_dtype
    if cfg.attn_type == "mla":
        m = cfg.mla
        return {
            "ckv": jnp.zeros((batch, max_seq, m.kv_lora), dtype),
            "krope": jnp.zeros((batch, max_seq, m.qk_rope), dtype),
        }
    seq = min(max_seq, cfg.window) if cfg.attn_type == "swa" else max_seq
    return {
        "k": jnp.zeros((batch, seq, cfg.n_kv, cfg.d_head), dtype),
        "v": jnp.zeros((batch, seq, cfg.n_kv, cfg.d_head), dtype),
    }


def cache_specs(cfg, batch: int, max_seq: int):
    """Logical axes of the cache (for dry-run sharding).  cache_seq may map
    to 'data' for long-context cells."""
    if cfg.attn_type == "mla":
        return {"ckv": ("batch", "cache_seq", "kv_lora"),
                "krope": ("batch", "cache_seq", None)}
    return {"k": ("batch", "cache_seq", "kv_heads", None),
            "v": ("batch", "cache_seq", "kv_heads", None)}


def attention_decode(params, cfg, x, cache, position):
    """One-token decode against a populated cache.

    x: (b, 1, d); position: scalar int32 — index of the new token.
    Returns (out, new_cache).  SWA writes into a ring slot (position % window).
    """
    b = x.shape[0]
    H, KV, hd = cfg.n_heads, cfg.n_kv, cfg.d_head
    q, k_new, v_new = _project_qkv(params, cfg, x)
    pos_arr = jnp.full((b, 1), position, jnp.int32)
    q = apply_rope(q, pos_arr, cfg.rope_theta)
    k_new = apply_rope(k_new, pos_arr, cfg.rope_theta)

    if cfg.attn_type == "swa":
        slot = position % cfg.window
    else:
        slot = position
    k = jax.lax.dynamic_update_slice(cache["k"], k_new, (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new, (0, slot, 0, 0))
    new_cache = {"k": k, "v": v}

    S = k.shape[1]
    if cfg.attn_type == "swa":
        # ring buffer: slot i holds absolute position p satisfying p % window == i
        # and p in (position-window, position]
        idx = jnp.arange(S)
        base = position - (position % cfg.window)
        k_pos = jnp.where(idx <= (position % cfg.window), base + idx, base - cfg.window + idx)
        valid = (k_pos >= 0) & (k_pos > position - cfg.window) & (k_pos <= position)
    else:
        k_pos = jnp.arange(S)
        valid = k_pos <= position

    # expanded-KV formulation: q stays (b,1,H,d) with H sharded over 'model';
    # expanding k/v reads only this shard's kv heads (no cross-shard reshape)
    kf = _expand_kv(k, H)
    vf = _expand_kv(v, H)
    logits = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32),
                        kf.astype(jnp.float32)) / math.sqrt(hd)
    logits = jnp.where(valid[None, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", probs, vf.astype(jnp.float32)).astype(x.dtype)
    return dense(params["wo"], out, "bshe,hed->bsd"), new_cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2): latent KV compression
# ---------------------------------------------------------------------------


def mla_train(params, cfg, x, positions, return_kv=False):
    m = cfg.mla
    b, s, _ = x.shape
    H = cfg.n_heads
    q = dense(params["wq"], x, "bsd,dhe->bshe", waxes=("embed", "heads", None))
    q_nope, q_rope = jnp.split(q, [m.qk_nope], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv = dense(params["wkv_down"], x, "bsd,de->bse", waxes=("embed", "kv_lora"))
    ckv, k_rope = jnp.split(kv, [m.kv_lora], axis=-1)
    ckv = rms_norm(params["kv_norm"], ckv, cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]

    k_nope = dense(params["wk_up"], ckv, "bse,ehn->bshn", waxes=("kv_lora", "heads", None))
    v = dense(params["wv_up"], ckv, "bse,ehn->bshn", waxes=("kv_lora", "heads", None))
    k_nope = constrain(k_nope, ("batch", "kv_seq", "heads_act", None))
    v = constrain(v, ("batch", "kv_seq", "heads_act", None))

    # fold the shared rope key into the per-head key: streaming attention
    # over concat([nope, rope]) dims == the two-term MLA logit sum
    q_cat = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_cat = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  k_nope.shape[:3] + (m.qk_rope,))], axis=-1)
    scale = 1.0 / math.sqrt(m.qk_nope + m.qk_rope)
    pos = jnp.arange(s, dtype=jnp.int32)
    out = _mha_streaming(q_cat, k_cat, v, pos, pos, scale)
    y = dense(params["wo"], out, "bshe,hed->bsd", waxes=("heads", None, "embed"))
    if return_kv:
        return y, {"ckv": ckv, "krope": k_rope}
    return y


def mla_decode(params, cfg, x, cache, position):
    """Absorbed-matrix MLA decode: scores & values in the 512-dim latent space.

    The cache holds (ckv, k_rope) only — the paper's early-reduction insight
    applied to the KV cache: compress *before* it hits memory/interconnect.
    """
    m = cfg.mla
    b = x.shape[0]
    H = cfg.n_heads
    q = dense(params["wq"], x, "bsd,dhe->bshe")
    q_nope, q_rope = jnp.split(q, [m.qk_nope], axis=-1)
    pos_arr = jnp.full((b, 1), position, jnp.int32)
    q_rope = apply_rope(q_rope, pos_arr, cfg.rope_theta)

    kv = dense(params["wkv_down"], x, "bsd,de->bse")
    ckv_new, k_rope_new = jnp.split(kv, [m.kv_lora], axis=-1)
    ckv_new = rms_norm(params["kv_norm"], ckv_new, cfg.norm_eps)
    k_rope_new = apply_rope(k_rope_new[:, :, None, :], pos_arr, cfg.rope_theta)[:, :, 0, :]

    ckv = jax.lax.dynamic_update_slice(cache["ckv"], ckv_new, (0, position, 0))
    krope = jax.lax.dynamic_update_slice(cache["krope"], k_rope_new, (0, position, 0))
    new_cache = {"ckv": ckv, "krope": krope}

    # absorb W_uk into the query: q_lat (b,1,h,kv_lora)
    q_lat = jnp.einsum("bshn,ehn->bshe", q_nope, params["wk_up"],
                       preferred_element_type=jnp.float32).astype(x.dtype)
    scale = 1.0 / math.sqrt(m.qk_nope + m.qk_rope)
    logits = jnp.einsum("bshe,bte->bhst", q_lat, ckv,
                        preferred_element_type=jnp.float32)
    logits += jnp.einsum("bshr,btr->bhst", q_rope, krope,
                         preferred_element_type=jnp.float32)
    S = ckv.shape[1]
    valid = jnp.arange(S) <= position
    logits = jnp.where(valid[None, None, None], logits * scale, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    # values in latent space, then up-project once per token
    o_lat = jnp.einsum("bhst,bte->bshe", probs, ckv.astype(jnp.float32),
                       preferred_element_type=jnp.float32).astype(x.dtype)
    out = jnp.einsum("bshe,ehn->bshn", o_lat, params["wv_up"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    return dense(params["wo"], out, "bshe,hed->bsd"), new_cache


# ---------------------------------------------------------------------------
# Cross attention (whisper decoder)
# ---------------------------------------------------------------------------


def cross_attn_specs(cfg) -> dict:
    d, H, hd = cfg.d_model, cfg.n_heads, cfg.d_head
    dt = cfg.param_dtype
    return {
        "wq": spec((d, H, hd), ("embed", "heads", None), dtype=dt),
        "wk": spec((d, H, hd), ("embed", "heads", None), dtype=dt),
        "wv": spec((d, H, hd), ("embed", "heads", None), dtype=dt),
        "wo": spec((H, hd, d), ("heads", None, "embed"), dtype=dt),
    }


def cross_attention(params, cfg, x, enc_out):
    """x: (b, s, d) queries; enc_out: (b, t, d) keys/values (no mask)."""
    b, s, _ = x.shape
    q = dense(params["wq"], x, "bsd,dhe->bshe")
    k = dense(params["wk"], enc_out, "btd,dhe->bthe")
    v = dense(params["wv"], enc_out, "btd,dhe->bthe")
    logits = jnp.einsum("bshe,bthe->bhst", q, k, preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits / math.sqrt(cfg.d_head), axis=-1)
    out = jnp.einsum("bhst,bthe->bshe", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32).astype(v.dtype)
    return dense(params["wo"], out, "bshe,hed->bsd")


def bidir_attention(params, cfg, x):
    """Encoder self-attention (no mask) — whisper encoder."""
    b, s, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv, cfg.d_head
    q, k, v = _project_qkv(params, cfg, x)
    g = H // KV
    q = q.reshape(b, s, KV, g, hd)
    mask = jnp.ones((s, s), bool)
    out = _mha(q, k, v, mask, 1.0 / math.sqrt(hd))
    return dense(params["wo"], out.reshape(b, s, H, hd), "bshe,hed->bsd")
