"""Parameter specs and elementary layers (pure JAX, pytree params).

No flax/haiku in this environment, so the framework carries its own tiny
module system:

* a **spec tree** mirrors the parameter pytree; each leaf is a
  :class:`ParamSpec` (shape, logical axes, initializer, dtype).  From one
  spec tree we derive (a) initialized params, (b) NamedShardings for the
  active mesh/rules, (c) ShapeDtypeStructs for the dry-run — so the three
  never drift apart.
* apply-functions are free functions taking the param subtree first.

Matmuls run in the param dtype (bf16 on the TPU target) with f32
accumulation via ``preferred_element_type`` — MXU semantics.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.parallel.axes import ShardingContext, constrain, current_context


# ---------------------------------------------------------------------------
# Param specs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple
    axes: tuple                       # logical axis names, len == len(shape)
    init: str = "normal"              # normal | zeros | ones | scaled
    scale: float = 1.0                # stddev multiplier for normal/scaled
    dtype: jnp.dtype = jnp.bfloat16

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"axes {self.axes} vs shape {self.shape}")


def spec(shape, axes, init="normal", scale=1.0, dtype=jnp.bfloat16):
    return ParamSpec(tuple(shape), tuple(axes), init, scale, jnp.dtype(dtype))


def _init_leaf(s: ParamSpec, key) -> jax.Array:
    if s.init == "zeros":
        return jnp.zeros(s.shape, s.dtype)
    if s.init == "ones":
        return jnp.ones(s.shape, s.dtype)
    if s.init == "normal":
        fan_in = s.shape[0] if s.shape else 1
        std = s.scale / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, s.shape, jnp.float32) * std).astype(s.dtype)
    if s.init == "scaled":  # raw stddev = scale
        return (jax.random.normal(key, s.shape, jnp.float32) * s.scale).astype(s.dtype)
    raise ValueError(s.init)


def is_spec(x):
    return isinstance(x, ParamSpec)


def init_params(specs, key):
    """Materialize a spec tree deterministically (fold_in by flattened path)."""
    leaves, treedef = jax.tree_util.tree_flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    out = [_init_leaf(s, k) for s, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, out)


def abstract_params(specs, ctx: Optional[ShardingContext] = None):
    """ShapeDtypeStructs (with shardings if ctx given) — dry-run stand-ins."""
    def leaf(s: ParamSpec):
        if ctx is None:
            return jax.ShapeDtypeStruct(s.shape, s.dtype)
        return jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=ctx.named_sharding(s.axes, s.shape)
        )
    return jax.tree_util.tree_map(leaf, specs, is_leaf=is_spec)


def param_shardings(specs, ctx: ShardingContext):
    return jax.tree_util.tree_map(
        lambda s: ctx.named_sharding(s.axes, s.shape), specs, is_leaf=is_spec
    )


def param_count(specs) -> int:
    leaves = jax.tree_util.tree_leaves(specs, is_leaf=is_spec)
    return sum(int(math.prod(s.shape)) for s in leaves)


def stack_specs(specs, n: int, axis_name: str = "stack"):
    """Prepend a scanned-layer dimension to every leaf (for lax.scan stacks)."""
    return jax.tree_util.tree_map(
        lambda s: ParamSpec((n,) + s.shape, (axis_name,) + s.axes, s.init, s.scale, s.dtype),
        specs,
        is_leaf=is_spec,
    )


# ---------------------------------------------------------------------------
# Elementary ops
# ---------------------------------------------------------------------------


def dense(w: jax.Array, x: jax.Array, eq: str, waxes: Optional[tuple] = None) -> jax.Array:
    """einsum with f32 accumulation, result cast back to x.dtype.

    Under a sharding context whose rules set ``manual_fsdp`` (the fsdp
    plan), and given the weight's logical axes ``waxes``, the einsum runs
    inside a *partial-manual* shard_map over the 'model' axis: the weight
    shard is explicitly all-gathered (backward: psum_scatter — ZeRO
    semantics by construction).  We adopted this after measuring that the
    auto-partitioner falls into involuntary-full-rematerialization on the
    dW dot of FSDP-sharded weights (46 TB activation gathers; see
    EXPERIMENTS.md §Perf iteration 3) — manual collectives make the plan's
    cost structural rather than propagation-dependent.

    Activations are assumed (batch, seq, ...) with seq sharded over 'model'
    per the fsdp plan; everything on other mesh axes stays automatic.
    """
    from repro.parallel.axes import current_context  # local: avoid cycle

    ctx = current_context()
    if (
        ctx is None
        or waxes is None
        or not ctx.rules.get("manual_fsdp")
        or "model" not in ctx.mesh.axis_names
    ):
        y = jnp.einsum(eq, x, w, preferred_element_type=jnp.float32)
        return y.astype(x.dtype)

    from jax.sharding import PartitionSpec as P

    msize = ctx.mesh.shape["model"]
    wspec = ctx.resolve_for_shape(waxes, w.shape)
    gather_dims = [i for i, e in enumerate(tuple(wspec)) if e == "model"]
    seq_ok = x.ndim >= 2 and x.shape[1] % msize == 0
    if not gather_dims or not seq_ok:
        y = jnp.einsum(eq, x, w, preferred_element_type=jnp.float32)
        return y.astype(x.dtype)
    gdim = gather_dims[0]

    out_sub = eq.split("->")[1]
    out_ndim = x.ndim if "..." in eq else len(out_sub)

    # custom_vjp around the weight gather: backward reduce-scatters the
    # weight cotangent in f32 — XLA CPU's AllReducePromotion pass CHECK-fails
    # cloning 16-bit reduce-scatters (measured; EXPERIMENTS.md §Perf iter 3),
    # and f32 gradient reduction is what we want numerically anyway.
    @jax.custom_vjp
    def gather_w(w_shard):
        return jax.lax.all_gather(w_shard, "model", axis=gdim, tiled=True)

    def gather_w_fwd(w_shard):
        return gather_w(w_shard), None

    def gather_w_bwd(_, ct):
        rs = jax.lax.psum_scatter(ct.astype(jnp.float32), "model",
                                  scatter_dimension=gdim, tiled=True)
        return (rs.astype(w.dtype),)

    gather_w.defvjp(gather_w_fwd, gather_w_bwd)

    def body(x_in, w_shard):
        w_full = gather_w(w_shard)
        y = jnp.einsum(eq, x_in, w_full, preferred_element_type=jnp.float32)
        return y.astype(x_in.dtype)

    x_spec = P(*([None, "model"] + [None] * (x.ndim - 2)))
    w_spec = P(*[("model" if i == gdim else None) for i in range(w.ndim)])
    y_spec = P(*([None, "model"] + [None] * (out_ndim - 2)))
    # ambient mesh when nested inside the pod-manual compressed-gradient
    # region (axis_types must match); concrete mesh otherwise
    from repro.parallel.axes import compat_shard_map, shard_map_mesh
    fn = compat_shard_map(
        body, mesh=shard_map_mesh(ctx), in_specs=(x_spec, w_spec),
        out_specs=y_spec, axis_names=frozenset({"model"}), check_vma=False,
    )
    return fn(x, w)


def rms_norm(scale: jax.Array, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def layer_norm(scale, bias, x, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def swiglu(w_gate, w_up, w_down, x):
    """LLaMA-style gated MLP.  x: (..., d_model)."""
    g = dense(w_gate, x, "...d,df->...f", waxes=("embed", "mlp"))
    u = dense(w_up, x, "...d,df->...f", waxes=("embed", "mlp"))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    h = constrain(h, ("batch", "seq", "mlp_act"))
    return dense(w_down, h, "...f,fd->...d", waxes=("mlp", "embed"))


def gelu_mlp(w_fc, b_fc, w_proj, b_proj, x):
    """GPT-style 2-matrix MLP (granite / whisper)."""
    h = dense(w_fc, x, "...d,df->...f", waxes=("embed", "mlp")) + b_fc.astype(x.dtype)
    h = jax.nn.gelu(h.astype(jnp.float32), approximate=True).astype(x.dtype)
    h = constrain(h, ("batch", "seq", "mlp_act"))
    return dense(w_proj, h, "...f,fd->...d", waxes=("mlp", "embed")) + b_proj.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embedding (llama-style, half-dim pairing)
# ---------------------------------------------------------------------------


def rope_freqs(d_head: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """x: (..., seq, heads, d_head); positions: broadcastable to (..., seq)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                        # (d/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, d/2)
    cos = jnp.cos(angles)[..., None, :]                 # (..., seq, 1, d/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, d_model: int) -> jax.Array:
    """Whisper-style fixed sinusoidal embeddings."""
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d_model // 2, dtype=jnp.float32)[None, :]
    inv = jnp.exp(-math.log(10000.0) * dim / (d_model // 2 - 1))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def embed_spec(vocab: int, d_model: int, dtype=jnp.bfloat16):
    return {
        "embedding": spec((vocab, d_model), ("vocab", "embed"), "scaled", 0.02, dtype),
    }


def embed(params, tokens: jax.Array) -> jax.Array:
    x = jnp.take(params["embedding"], tokens, axis=0)
    return constrain(x, ("batch", "seq", "embed_act"))


def unembed(params, x: jax.Array) -> jax.Array:
    logits = dense(params["embedding"], x, "...d,vd->...v", waxes=("vocab", "embed"))
    return constrain(logits, ("batch", "seq", "vocab_act"))
