"""Attention-free sequence mixers: RWKV6 (Finch) and Mamba (for Jamba).

These are the assignment's sub-quadratic families — and, in the paper's
vocabulary, the extreme early-data-reduction designs: all history is
compressed into O(1) recurrent state, so the long-context "offload payload"
(KV cache) disappears entirely (DESIGN.md §4).

Train path: `jax.lax.scan` over time (carries in f32).  Decode path: a
single-step state update.  The chunked TPU kernel for RWKV6 lives in
`repro.kernels.rwkv_scan`; this module is also its reference semantics.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.models.layers import dense, rms_norm, spec
from repro.parallel.axes import constrain


# ---------------------------------------------------------------------------
# RWKV6 time-mix + channel-mix
# ---------------------------------------------------------------------------

RWKV_HEAD_DIM = 64
RWKV_LORA_MIX = 32
RWKV_LORA_DECAY = 64


def rwkv_time_mix_specs(cfg) -> dict:
    d, dt = cfg.d_model, cfg.param_dtype
    H = d // RWKV_HEAD_DIM
    return {
        "mu_base": spec((5, d), (None, "embed_nofsdp"), "zeros", dtype=dt),
        "maa_w1": spec((d, 5 * RWKV_LORA_MIX), ("embed", None), dtype=dt),
        "maa_w2": spec((5, RWKV_LORA_MIX, d), (None, None, "embed"), dtype=dt),
        "decay_base": spec((d,), ("embed_nofsdp",), "zeros", dtype=jnp.float32),
        "decay_w1": spec((d, RWKV_LORA_DECAY), ("embed", None), dtype=dt),
        "decay_w2": spec((RWKV_LORA_DECAY, d), (None, "embed"), dtype=dt),
        "bonus": spec((H, RWKV_HEAD_DIM), ("heads", None), "zeros", dtype=jnp.float32),
        "wr": spec((d, d), ("embed", "heads"), dtype=dt),
        "wk": spec((d, d), ("embed", "heads"), dtype=dt),
        "wv": spec((d, d), ("embed", "heads"), dtype=dt),
        "wg": spec((d, d), ("embed", "heads"), dtype=dt),
        "wo": spec((d, d), ("heads", "embed"), dtype=dt),
        "ln_scale": spec((d,), ("embed_nofsdp",), "ones", dtype=dt),
    }


def _rwkv_mix_inputs(params, x, x_prev):
    """Data-dependent token-shift interpolation (RWKV6's defining feature)."""
    xx = x_prev - x
    base = x + xx * params["mu_base"][0].astype(x.dtype)
    lora = jnp.tanh(dense(params["maa_w1"], base, "...d,de->...e"))
    lora = lora.reshape(*lora.shape[:-1], 5, RWKV_LORA_MIX)
    deltas = jnp.einsum("...fe,fed->...fd", lora.astype(jnp.float32),
                        params["maa_w2"].astype(jnp.float32)).astype(x.dtype)
    mixed = []
    for i in range(5):
        mu = params["mu_base"][i].astype(x.dtype) + deltas[..., i, :]
        mixed.append(x + xx * mu)
    return mixed  # [xw, xk, xv, xr, xg]


def _rwkv_decay(params, xw):
    lora = jnp.tanh(dense(params["decay_w1"], xw, "...d,de->...e"))
    dd = dense(params["decay_w2"], lora, "...e,ed->...d").astype(jnp.float32)
    return jnp.exp(-jnp.exp(params["decay_base"] + dd))      # in (0,1)


def rwkv_state_init(cfg, batch: int):
    d = cfg.d_model
    H = d // RWKV_HEAD_DIM
    return {
        "x_prev": jnp.zeros((batch, d), cfg.param_dtype),
        "wkv": jnp.zeros((batch, H, RWKV_HEAD_DIM, RWKV_HEAD_DIM), jnp.float32),
        "x_prev_cm": jnp.zeros((batch, d), cfg.param_dtype),
    }


def rwkv_state_axes():
    return {"x_prev": ("batch", None), "wkv": ("batch", "heads_act", None, None),
            "x_prev_cm": ("batch", None)}


def _wkv_step(state, r, k, v, w, u):
    """One recurrence step.  r,k,v,w: (b,H,K); state: (b,H,K,V) f32."""
    kv = k[..., :, None] * v[..., None, :]                  # (b,H,K,V)
    out = jnp.einsum("bhk,bhkv->bhv", r, state + u[..., :, None] * kv)
    new_state = w[..., :, None] * state + kv
    return new_state, out


def rwkv_time_mix(params, cfg, x, state=None):
    """x: (b, s, d).  Returns (out, new_state).  Scan over time."""
    b, s, d = x.shape
    H = d // RWKV_HEAD_DIM
    if state is None:
        state = rwkv_state_init(cfg, b)
    x_prev_seq = jnp.concatenate([state["x_prev"][:, None], x[:, :-1]], axis=1)
    xw, xk, xv, xr, xg = _rwkv_mix_inputs(params, x, x_prev_seq)

    r = dense(params["wr"], xr, "bsd,de->bse", waxes=("embed", "heads")).reshape(b, s, H, RWKV_HEAD_DIM)
    k = dense(params["wk"], xk, "bsd,de->bse", waxes=("embed", "heads")).reshape(b, s, H, RWKV_HEAD_DIM)
    v = dense(params["wv"], xv, "bsd,de->bse", waxes=("embed", "heads")).reshape(b, s, H, RWKV_HEAD_DIM)
    g = dense(params["wg"], xg, "bsd,de->bse", waxes=("embed", "heads"))
    w = _rwkv_decay(params, xw).reshape(b, s, H, RWKV_HEAD_DIM)
    u = params["bonus"]

    r32, k32, v32 = (t.astype(jnp.float32) for t in (r, k, v))

    def step(carry, inp):
        rt, kt, vt, wt = inp
        new, out = _wkv_step(carry, rt, kt, vt, wt, u)
        return new, out

    seq_first = lambda t: jnp.moveaxis(t, 1, 0)
    new_wkv, outs = jax.lax.scan(
        step, state["wkv"], (seq_first(r32), seq_first(k32), seq_first(v32), seq_first(w))
    )
    out = jnp.moveaxis(outs, 0, 1).reshape(b, s, d)         # (b,s,H*V)

    # per-head group norm, gate, project
    out = out.reshape(b, s, H, RWKV_HEAD_DIM)
    mu = jnp.mean(out, axis=-1, keepdims=True)
    var = jnp.var(out, axis=-1, keepdims=True)
    out = ((out - mu) * jax.lax.rsqrt(var + 64e-5)).reshape(b, s, d)
    out = out * params["ln_scale"].astype(jnp.float32)
    out = out.astype(x.dtype) * jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype)
    y = dense(params["wo"], out, "bsd,de->bse", waxes=("heads", "embed"))

    new_state = dict(state, x_prev=x[:, -1], wkv=new_wkv)
    return y, new_state


def rwkv_channel_mix_specs(cfg) -> dict:
    d, f, dt = cfg.d_model, cfg.d_ff, cfg.param_dtype
    return {
        "mu_k": spec((d,), ("embed_nofsdp",), "zeros", dtype=dt),
        "mu_r": spec((d,), ("embed_nofsdp",), "zeros", dtype=dt),
        "wk": spec((d, f), ("embed", "mlp"), dtype=dt),
        "wv": spec((f, d), ("mlp", "embed"), dtype=dt),
        "wr": spec((d, d), ("embed", "heads"), dtype=dt),
    }


def rwkv_channel_mix(params, cfg, x, x_prev_last=None):
    """RWKV6 channel-mix (squared-relu FFN with token shift)."""
    b, s, d = x.shape
    if x_prev_last is None:
        x_prev_last = jnp.zeros((b, d), x.dtype)
    x_prev = jnp.concatenate([x_prev_last[:, None], x[:, :-1]], axis=1)
    xx = x_prev - x
    xk = x + xx * params["mu_k"].astype(x.dtype)
    xr = x + xx * params["mu_r"].astype(x.dtype)
    k = dense(params["wk"], xk, "bsd,df->bsf", waxes=("embed", "mlp"))
    k = jnp.square(jax.nn.relu(k.astype(jnp.float32))).astype(x.dtype)
    k = constrain(k, ("batch", "seq", "mlp_act"))
    kv = dense(params["wv"], k, "bsf,fd->bsd", waxes=("mlp", "embed"))
    r = jax.nn.sigmoid(dense(params["wr"], xr, "bsd,de->bse", waxes=("embed", "heads")).astype(jnp.float32))
    return r.astype(x.dtype) * kv, x[:, -1]


# ---------------------------------------------------------------------------
# Mamba (selective SSM) — Jamba's mixer
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 256


def mamba_specs(cfg, m: MambaConfig) -> dict:
    d, dt = cfg.d_model, cfg.param_dtype
    di = m.expand * d
    return {
        "in_proj": spec((d, 2 * di), ("embed", "mlp"), dtype=dt),
        "conv_w": spec((m.d_conv, di), ("conv", "mlp"), scale=1.0, dtype=dt),
        "conv_b": spec((di,), ("mlp",), "zeros", dtype=dt),
        "x_proj": spec((di, m.dt_rank + 2 * m.d_state), ("mlp", None), dtype=dt),
        "dt_proj": spec((m.dt_rank, di), ("dt_rank", "mlp"), dtype=dt),
        "dt_bias": spec((di,), ("mlp",), "zeros", dtype=jnp.float32),
        "A_log": spec((di, m.d_state), ("mlp", "state"), "zeros", dtype=jnp.float32),
        "D": spec((di,), ("mlp",), "ones", dtype=jnp.float32),
        "out_proj": spec((di, d), ("mlp", "embed"), dtype=dt),
        # Jamba adds RMS norms on dt/B/C
        "dt_norm": spec((m.dt_rank,), ("dt_rank",), "ones", dtype=dt),
        "b_norm": spec((m.d_state,), ("state",), "ones", dtype=dt),
        "c_norm": spec((m.d_state,), ("state",), "ones", dtype=dt),
    }


def mamba_state_init(cfg, m: MambaConfig, batch: int):
    di = m.expand * cfg.d_model
    return {
        "conv": jnp.zeros((batch, m.d_conv - 1, di), cfg.param_dtype),
        "ssm": jnp.zeros((batch, di, m.d_state), jnp.float32),
    }


def mamba_state_axes():
    return {"conv": ("batch", None, "mlp_act"), "ssm": ("batch", "mlp_act", "state")}


def _mamba_scan(delta, A, Bx, C, h0=None):
    """h_t = exp(delta_t A) h_{t-1} + Bx_t ; y_t = C_t . h_t
    delta: (b,s,di)  A: (di,n)  Bx: (b,s,di,n)  C: (b,s,n) -> y (b,s,di)."""
    dA = jnp.exp(delta[..., None] * A)                      # (b,s,di,n)

    def step(h, inp):
        dA_t, Bx_t, C_t = inp
        h = dA_t * h + Bx_t
        y = jnp.einsum("bdn,bn->bd", h, C_t)
        return h, y

    seq_first = lambda t: jnp.moveaxis(t, 1, 0)
    if h0 is None:
        h0 = jnp.zeros(dA.shape[:1] + dA.shape[2:], jnp.float32)
    hT, ys = jax.lax.scan(step, h0, (seq_first(dA), seq_first(Bx), seq_first(C)))
    return jnp.moveaxis(ys, 0, 1), hT


def mamba_mixer(params, cfg, m: MambaConfig, x, state=None):
    """x: (b, s, d) -> (out, new_state)."""
    b, s, d = x.shape
    di = m.expand * d
    xz = dense(params["in_proj"], x, "bsd,de->bse", waxes=("embed", "mlp"))
    xi, z = jnp.split(xz, 2, axis=-1)
    xi = constrain(xi, ("batch", "seq", "mlp_act"))

    # depthwise causal conv over seq, carrying conv state for decode parity
    if state is not None:
        pad = state["conv"]
    else:
        pad = jnp.zeros((b, m.d_conv - 1, di), xi.dtype)
    xpad = jnp.concatenate([pad, xi], axis=1)
    conv_w = params["conv_w"].astype(jnp.float32)           # (w, di)
    xc = sum(
        xpad[:, i : i + s].astype(jnp.float32) * conv_w[i]
        for i in range(m.d_conv)
    )
    xc = jax.nn.silu(xc + params["conv_b"].astype(jnp.float32)).astype(x.dtype)

    proj = dense(params["x_proj"], xc, "bse,ef->bsf")
    dt, B, C = jnp.split(proj, [m.dt_rank, m.dt_rank + m.d_state], axis=-1)
    dt = rms_norm(params["dt_norm"], dt, cfg.norm_eps)
    B = rms_norm(params["b_norm"], B, cfg.norm_eps).astype(jnp.float32)
    C = rms_norm(params["c_norm"], C, cfg.norm_eps).astype(jnp.float32)
    delta = jax.nn.softplus(
        dense(params["dt_proj"], dt, "bsr,re->bse").astype(jnp.float32)
        + params["dt_bias"]
    )                                                        # (b,s,di)
    A = -jnp.exp(params["A_log"])                            # (di,n)
    Bx = delta[..., None] * B[:, :, None, :] * xc.astype(jnp.float32)[..., None]
    h0 = state["ssm"] if state is not None else None
    ys, hT = _mamba_scan(delta, A, Bx, C, h0)
    y = ys + params["D"] * xc.astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = dense(params["out_proj"], y, "bse,ed->bsd", waxes=("mlp", "embed"))

    new_state = {
        "conv": xpad[:, -(m.d_conv - 1):] if m.d_conv > 1 else pad,
        "ssm": hT,
    }
    return out, new_state
