"""Mixture-of-Experts with sort-based dispatch and explicit EP/TP shard_map.

Expert placement is the framework's flagship comp-comm decision (DESIGN.md
§4).  Two parallelization plans, chosen per-arch by ``MoEConfig.parallelism``:

* ``"ep"`` — experts sharded over the 'model' axis.  Dispatch/combine are
  two `lax.all_to_all`s *inside the pod* (the fast ICI tier); expert weights
  are fully sharded.  Right choice for many-expert models (DeepSeek 160e,
  Jamba 16e).  Note the deliberate placement: the all-to-all never crosses
  the 'pod' axis — high-volume traffic stays on the fast link, gradients
  (much smaller after reduction) cross pods.  This is the paper's cut-point
  logic verbatim.
* ``"tp"`` — experts replicated, expert d_ff sharded over 'model' (plain
  tensor parallelism + a psum).  Right choice when n_experts < model-axis
  size (Mixtral 8e on a 16-way axis).

Dispatch is **sort-based** (linear in tokens): assignments are ranked
within their expert via a stable argsort and scattered into a static
(e, capacity, d) buffer; overflow tokens are dropped, exactly like the
compacting cascade (core/cascade.py) — the same TPU adaptation of
data-dependent work.  A dense one-hot reference (`moe_ffn_dense`) provides
the oracle for tests.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import dense, spec
from repro.parallel.axes import constrain, current_context


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0              # DeepSeek shared experts (always-on)
    capacity_factor: float = 1.25
    router_z_loss: float = 1e-3
    parallelism: str = "ep"        # "ep" | "tp"


def moe_specs(cfg, m: MoEConfig) -> dict:
    d, dt = cfg.d_model, cfg.param_dtype
    e, f = m.n_experts, m.d_ff_expert
    exp_axes_in = ("experts", "embed", "mlp") if m.parallelism == "ep" else (None, "embed", "mlp")
    exp_axes_out = ("experts", "mlp", "embed") if m.parallelism == "ep" else (None, "mlp", "embed")
    out = {
        "router": spec((d, e), ("embed_nofsdp", None), dtype=jnp.float32),
        "w_gate": spec((e, d, f), exp_axes_in, dtype=dt),
        "w_up": spec((e, d, f), exp_axes_in, dtype=dt),
        "w_down": spec((e, f, d), exp_axes_out, dtype=dt),
    }
    if m.n_shared:
        fs = f * m.n_shared
        out["shared"] = {
            "w_gate": spec((d, fs), ("embed", "mlp"), dtype=dt),
            "w_up": spec((d, fs), ("embed", "mlp"), dtype=dt),
            "w_down": spec((fs, d), ("mlp", "embed"), dtype=dt),
        }
    return out


# ---------------------------------------------------------------------------
# Router
# ---------------------------------------------------------------------------


def router_topk(router_w, m: MoEConfig, xt):
    """xt: (t, d) -> (top_w (t,k), top_idx (t,k), aux scalar)."""
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), router_w)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_idx = jax.lax.top_k(probs, m.top_k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_idx, m.n_experts, dtype=jnp.float32), axis=1),
        axis=0,
    ) / m.top_k
    lb_loss = m.n_experts * jnp.sum(me * ce)
    z_loss = m.router_z_loss * jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return top_w, top_idx, lb_loss + z_loss


# ---------------------------------------------------------------------------
# Sort-based dispatch (linear in tokens)
# ---------------------------------------------------------------------------


def _capacity(t: int, m: MoEConfig) -> int:
    cap = int(max(1, round(t * m.top_k * m.capacity_factor / m.n_experts)))
    return min(cap, t * m.top_k)


def sort_dispatch(xt, top_idx, e: int, cap: int):
    """Scatter tokens into a static (e, cap, d) expert buffer.

    Returns (expert_in, slot (t,k) int32, keep (t,k) bool).  slot indexes the
    flattened (e*cap) buffer; dropped assignments have keep=False.
    """
    t, k = top_idx.shape
    d = xt.shape[-1]
    flat_e = top_idx.reshape(-1)                             # (t*k,)
    order = jnp.argsort(flat_e, stable=True)                 # assignment ids sorted by expert
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=e)                  # (e,)
    seg_start = jnp.cumsum(counts) - counts                  # exclusive prefix
    rank_sorted = jnp.arange(t * k) - seg_start[sorted_e]
    rank = jnp.zeros((t * k,), jnp.int32).at[order].set(rank_sorted.astype(jnp.int32))
    keep = rank < cap
    slot = jnp.where(keep, flat_e * cap + rank, e * cap)     # overflow row
    token_of = jnp.arange(t * k) // k
    buf = jnp.zeros((e * cap + 1, d), xt.dtype)
    buf = buf.at[slot].set(xt[token_of], mode="drop")
    expert_in = buf[: e * cap].reshape(e, cap, d)
    return expert_in, slot.reshape(t, k), keep.reshape(t, k)


def sort_combine(expert_out, slot, keep, top_w):
    """Inverse of sort_dispatch.  expert_out: (e, cap, d) -> (t, d)."""
    e, cap, d = expert_out.shape
    flat = jnp.concatenate([expert_out.reshape(e * cap, d),
                            jnp.zeros((1, d), expert_out.dtype)], axis=0)
    gathered = flat[jnp.minimum(slot, e * cap)]              # (t, k, d)
    w = (top_w * keep).astype(jnp.float32)[..., None]
    return jnp.sum(gathered.astype(jnp.float32) * w, axis=1)


def _expert_ffn(w_gate, w_up, w_down, expert_in):
    """Batched SwiGLU over experts.  expert_in: (e, c, d)."""
    g = jnp.einsum("ecd,edf->ecf", expert_in, w_gate,
                   preferred_element_type=jnp.float32)
    u = jnp.einsum("ecd,edf->ecf", expert_in, w_up,
                   preferred_element_type=jnp.float32)
    h = (jax.nn.silu(g) * u).astype(expert_in.dtype)
    return jnp.einsum("ecf,efd->ecd", h, w_down,
                      preferred_element_type=jnp.float32).astype(expert_in.dtype)


# ---------------------------------------------------------------------------
# Local (single-shard) path — also the body run inside shard_map shards
# ---------------------------------------------------------------------------


def _moe_local(params, m: MoEConfig, xt):
    top_w, top_idx, aux = router_topk(params["router"], m, xt)
    cap = _capacity(xt.shape[0], m)
    expert_in, slot, keep = sort_dispatch(xt, top_idx, m.n_experts, cap)
    expert_out = _expert_ffn(params["w_gate"], params["w_up"], params["w_down"], expert_in)
    yt = sort_combine(expert_out, slot, keep, top_w)
    return yt.astype(xt.dtype), aux


# ---------------------------------------------------------------------------
# shard_map paths
# ---------------------------------------------------------------------------


def _moe_ep_body(params, m: MoEConfig, xt, model_axis: str, msize: int):
    """EP: tokens local, experts sharded.  Two all-to-alls over `model_axis`."""
    top_w, top_idx, aux = router_topk(params["router"], m, xt)
    cap = _capacity(xt.shape[0], m)
    e = m.n_experts
    e_local = e // msize
    expert_in, slot, keep = sort_dispatch(xt, top_idx, e, cap)

    # (e, cap, d) -> send expert block i to model-shard i
    a2a = jax.lax.all_to_all(
        expert_in.reshape(msize, e_local, cap, -1),
        model_axis, split_axis=0, concat_axis=0, tiled=False,
    )                                                        # (msize, e_local, cap, d)
    a2a = jnp.moveaxis(a2a, 0, 1).reshape(e_local, msize * cap, -1)

    expert_out = _expert_ffn(params["w_gate"], params["w_up"], params["w_down"], a2a)

    back = jnp.moveaxis(expert_out.reshape(e_local, msize, cap, -1), 1, 0)
    back = jax.lax.all_to_all(back, model_axis, split_axis=0, concat_axis=0,
                              tiled=False)                   # (msize, e_local, cap, d)
    expert_out_local = back.reshape(e, cap, -1)
    yt = sort_combine(expert_out_local, slot, keep, top_w)
    return yt.astype(xt.dtype), aux


def _moe_tp_body(params, m: MoEConfig, xt, model_axis: str):
    """TP: experts replicated, d_ff sharded; one psum on the down-proj.

    The psum runs on the *combined* (t, d) output, not the (e, cap, d)
    capacity buffer — combine is linear, so the results are identical and
    the all-reduce shrinks by cap*e/t = top_k*capacity_factor x
    (§Perf hillclimb on mixtral: 2.5x less TP-MoE collective traffic)."""
    top_w, top_idx, aux = router_topk(params["router"], m, xt)
    cap = _capacity(xt.shape[0], m)
    expert_in, slot, keep = sort_dispatch(xt, top_idx, m.n_experts, cap)
    g = jnp.einsum("ecd,edf->ecf", expert_in, params["w_gate"],
                   preferred_element_type=jnp.float32)
    u = jnp.einsum("ecd,edf->ecf", expert_in, params["w_up"],
                   preferred_element_type=jnp.float32)
    h = (jax.nn.silu(g) * u).astype(expert_in.dtype)
    partial_out = jnp.einsum("ecf,efd->ecd", h, params["w_down"],
                             preferred_element_type=jnp.float32)
    yt_partial = sort_combine(partial_out.astype(jnp.float32), slot, keep, top_w)
    yt = jax.lax.psum(yt_partial, model_axis)
    return yt.astype(xt.dtype), aux


def moe_ffn(params, cfg, m: MoEConfig, x):
    """x: (b, s, d) -> (y, aux).  Dispatches to the plan the context allows."""
    b, s, d = x.shape
    ctx = current_context()
    shared_y = None
    if m.n_shared:
        sh = params["shared"]
        gs = dense(sh["w_gate"], x, "...d,df->...f")
        us = dense(sh["w_up"], x, "...d,df->...f")
        hs = jax.nn.silu(gs.astype(jnp.float32)).astype(x.dtype) * us
        hs = constrain(hs, ("batch", "seq", "mlp_act"))
        shared_y = dense(sh["w_down"], hs, "...f,fd->...d")

    routed = {k: v for k, v in params.items() if k != "shared"}

    if ctx is None or "model" not in ctx.mesh.axis_names or ctx.mesh.shape["model"] == 1:
        xt = x.reshape(b * s, d)
        yt, aux = _moe_local(routed, m, xt)
        y = yt.reshape(b, s, d)
    else:
        y, aux = _moe_shard_mapped(routed, cfg, m, x, ctx)

    if shared_y is not None:
        y = y + shared_y
    return y, aux


def _moe_shard_mapped(params, cfg, m: MoEConfig, x, ctx):
    mesh = ctx.mesh
    msize = mesh.shape["model"]
    # shard batch over as many data axes as divide it (batch=1 decode cells
    # keep tokens replicated and rely on EP/TP for the expert work)
    batch_axes = []
    ways = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names and x.shape[0] % (ways * mesh.shape[a]) == 0:
            batch_axes.append(a)
            ways *= mesh.shape[a]
    batch_axes = tuple(batch_axes)
    bspec = batch_axes if len(batch_axes) > 1 else (batch_axes[0] if batch_axes else None)

    use_ep = m.parallelism == "ep" and m.n_experts % msize == 0
    # EP additionally shards the *sequence* over 'model' inside the block:
    # without it every model rank routes and dispatches the full local batch
    # redundantly, multiplying all-to-all traffic by the model-axis size
    # (measured 16x on deepseek train_4k — §Perf iteration 5).
    seq_shard = use_ep and x.shape[1] % msize == 0
    x_spec = P(bspec, "model" if seq_shard else None, None)

    if use_ep:
        w_spec = {"router": P(None, None),
                  "w_gate": P("model", None, None),
                  "w_up": P("model", None, None),
                  "w_down": P("model", None, None)}
        body = lambda p, xs: _ep_wrap(p, cfg, m, xs, msize, batch_axes, seq_shard)
    else:
        w_spec = {"router": P(None, None),
                  "w_gate": P(None, None, "model"),
                  "w_up": P(None, None, "model"),
                  "w_down": P(None, "model", None)}
        body = lambda p, xs: _tp_wrap(p, cfg, m, xs, batch_axes)

    # ambient mesh when nested inside outer partial-manual regions (pod-axis
    # gradient compression); concrete mesh otherwise
    from repro.parallel.axes import compat_shard_map, shard_map_mesh
    fn = compat_shard_map(
        body, mesh=shard_map_mesh(ctx),
        in_specs=(w_spec, x_spec),
        out_specs=(x_spec, P()),
        check_vma=False,
    )
    return fn(params, x)


def _ep_wrap(params, cfg, m, x, msize, batch_axes, seq_shard):
    b, s, d = x.shape
    yt, aux = _moe_ep_body(params, m, x.reshape(b * s, d), "model", msize)
    aux_axes = batch_axes + (("model",) if seq_shard else ())
    if aux_axes:
        aux = jax.lax.pmean(aux, aux_axes)
    return yt.reshape(b, s, d), aux


def _tp_wrap(params, cfg, m, x, batch_axes):
    b, s, d = x.shape
    yt, aux = _moe_tp_body(params, m, x.reshape(b * s, d), "model")
    if batch_axes:
        aux = jax.lax.pmean(aux, batch_axes)
    return yt.reshape(b, s, d), aux


# ---------------------------------------------------------------------------
# Dense one-hot reference (oracle for tests; exact same routing semantics)
# ---------------------------------------------------------------------------


def moe_ffn_dense(params, cfg, m: MoEConfig, x):
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    routed = {k: v for k, v in params.items() if k != "shared"}
    top_w, top_idx, aux = router_topk(routed["router"], m, xt)
    cap = _capacity(t, m)

    onehot = jax.nn.one_hot(top_idx, m.n_experts, dtype=jnp.int32)   # (t,k,e)
    flat = onehot.reshape(t * m.top_k, m.n_experts)
    pos = jnp.cumsum(flat, axis=0) * flat - 1
    pos = pos.reshape(t, m.top_k, m.n_experts)
    in_cap = (pos >= 0) & (pos < cap)
    slotmat = jax.nn.one_hot(jnp.clip(pos, 0, cap - 1), cap, dtype=jnp.float32)
    slotmat = slotmat * in_cap[..., None]
    dispatch = jnp.sum(slotmat, axis=1)                              # (t,e,c)
    combine = jnp.sum(slotmat * top_w[:, :, None, None], axis=1)

    expert_in = jnp.einsum("tec,td->ecd", dispatch, xt.astype(jnp.float32)).astype(x.dtype)
    expert_out = _expert_ffn(routed["w_gate"], routed["w_up"], routed["w_down"], expert_in)
    yt = jnp.einsum("tec,ecd->td", combine, expert_out.astype(jnp.float32)).astype(x.dtype)
    y = yt.reshape(b, s, d)
    if m.n_shared:
        sh = params["shared"]
        gs = dense(sh["w_gate"], x, "...d,df->...f")
        us = dense(sh["w_up"], x, "...d,df->...f")
        hs = jax.nn.silu(gs.astype(jnp.float32)).astype(x.dtype) * us
        y = y + dense(sh["w_down"], hs, "...f,fd->...d")
    return y, aux
