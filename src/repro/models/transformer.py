"""Model assembly: one config schema, ten architectures, scan-over-layers.

Design rules (framework-scale, not demo-scale):

* **Scan over layer periods.**  Layers are grouped into the smallest
  repeating *period* of layer kinds (Jamba's attn/mamba 1:7 interleave with
  MoE every other layer has period 8; homogeneous models have period 1).
  Parameters are stacked over periods and the period body is a single
  `lax.scan` step — HLO size is O(period), not O(depth), which is what
  makes 88-layer granite compile fast and keeps the dry-run tractable.
* **Remat at the period boundary** (`jax.checkpoint`) — full recompute in
  backward, activation memory O(period) not O(depth).
* **Heterogeneous prefixes** (DeepSeek's first dense layer) are unscanned
  standalone layers before the scanned stack.
* **Decode carries cache stacks**: the same scan runs with per-period cache
  slices as scan xs/ys.

The mixer/MLP kinds combine freely: attention (full/SWA/MLA), RWKV6
time-mix, Mamba; SwiGLU / GELU MLP / MoE / RWKV channel-mix — that's what
lets ten architectures share one assembly.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import ssm
from repro.models.layers import (
    dense,
    embed,
    embed_spec,
    gelu_mlp,
    init_params,
    layer_norm,
    param_count,
    rms_norm,
    sinusoidal_positions,
    spec,
    stack_specs,
    swiglu,
    unembed,
)
from repro.parallel.axes import constrain


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora: int = 512
    qk_nope: int = 128
    qk_rope: int = 64
    v_dim: int = 128


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_head: int
    d_ff: int
    vocab: int
    # attention
    attn_type: str = "full"         # full | swa | mla
    window: int = 4096
    rope_theta: float = 1e4
    attn_bias: bool = False
    qk_norm: bool = False
    mla: Optional[MLAConfig] = None
    # mixer pattern (ssm / hybrid)
    mixer: str = "attn"             # attn | rwkv | mamba
    attn_every: int = 0             # hybrid: attention where i % attn_every == attn_offset
    attn_offset: int = 0
    mamba: Optional[ssm.MambaConfig] = None
    # mlp pattern
    mlp_type: str = "swiglu"        # swiglu | gelu | rwkv_cm
    moe: Optional[moe_lib.MoEConfig] = None
    moe_every: int = 1              # MoE where i % moe_every == moe_offset (if moe set)
    moe_offset: int = 0
    first_dense: int = 0            # leading dense-MLP layers (DeepSeek: 1)
    # encoder-decoder (whisper)
    enc_layers: int = 0
    enc_seq: int = 1500
    # misc
    norm_type: str = "rms"          # rms | ln
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    param_dtype: object = jnp.bfloat16
    remat: bool = True
    # assignment metadata
    sub_quadratic: bool = False     # may run long_500k
    source: str = ""

    @property
    def is_encdec(self) -> bool:
        return self.enc_layers > 0


# ---------------------------------------------------------------------------
# Layer-kind resolution
# ---------------------------------------------------------------------------


def layer_kind(cfg: ModelConfig, i: int) -> tuple:
    """(mixer, mlp) kind of decoder layer ``i``."""
    if cfg.mixer == "rwkv":
        return ("rwkv", "rwkv_cm")
    if cfg.mixer == "mamba":
        # hybrid: attention islands in a mamba sea (Jamba 1:7)
        is_attn = bool(cfg.attn_every) and (i % cfg.attn_every == cfg.attn_offset)
        mixer = "attn" if is_attn else "mamba"
    else:
        mixer = "attn"
    mlp = cfg.mlp_type
    if cfg.moe is not None and i >= cfg.first_dense and i % cfg.moe_every == cfg.moe_offset:
        mlp = "moe"
    return (mixer, mlp)


def layer_kinds(cfg: ModelConfig) -> list:
    return [layer_kind(cfg, i) for i in range(cfg.n_layers)]


def find_period(kinds: list) -> int:
    n = len(kinds)
    for p in range(1, n + 1):
        if n % p == 0 and kinds == kinds[:p] * (n // p):
            return p
    return n


# ---------------------------------------------------------------------------
# Single-layer specs / forward
# ---------------------------------------------------------------------------


def _norm_specs(cfg):
    d, dt = cfg.d_model, cfg.param_dtype
    if cfg.norm_type == "ln":
        return {"scale": spec((d,), ("embed_nofsdp",), "ones", dtype=dt),
                "bias": spec((d,), ("embed_nofsdp",), "zeros", dtype=dt)}
    return {"scale": spec((d,), ("embed_nofsdp",), "ones", dtype=dt)}


def _apply_norm(p, cfg, x):
    if cfg.norm_type == "ln":
        return layer_norm(p["scale"], p["bias"], x, cfg.norm_eps)
    return rms_norm(p["scale"], x, cfg.norm_eps)


def _mlp_specs(cfg, kind: str):
    d, f, dt = cfg.d_model, cfg.d_ff, cfg.param_dtype
    if kind == "moe":
        return moe_lib.moe_specs(cfg, cfg.moe)
    if kind == "swiglu":
        return {"w_gate": spec((d, f), ("embed", "mlp"), dtype=dt),
                "w_up": spec((d, f), ("embed", "mlp"), dtype=dt),
                "w_down": spec((f, d), ("mlp", "embed"), dtype=dt)}
    if kind == "gelu":
        return {"w_fc": spec((d, f), ("embed", "mlp"), dtype=dt),
                "b_fc": spec((f,), ("mlp",), "zeros", dtype=dt),
                "w_proj": spec((f, d), ("mlp", "embed"), dtype=dt),
                "b_proj": spec((d,), ("embed_nofsdp",), "zeros", dtype=dt)}
    if kind == "rwkv_cm":
        return ssm.rwkv_channel_mix_specs(cfg)
    raise ValueError(kind)


def _mixer_specs(cfg, kind: str, cross: bool = False):
    if kind in ("attn", "bidir"):
        out = attn.attn_specs(cfg)
        if cross:
            out_cross = attn.cross_attn_specs(cfg)
            return out, out_cross
        return out
    if kind == "mla":
        return attn.attn_specs(cfg)
    if kind == "rwkv":
        return ssm.rwkv_time_mix_specs(cfg)
    if kind == "mamba":
        return ssm.mamba_specs(cfg, cfg.mamba)
    raise ValueError(kind)


def decoder_layer_specs(cfg, kind: tuple, cross: bool = False) -> dict:
    mixer, mlp = kind
    mixer_key = "mla" if (mixer == "attn" and cfg.attn_type == "mla") else mixer
    out = {
        "norm1": _norm_specs(cfg),
        "mixer": _mixer_specs(cfg, mixer_key),
        "norm2": _norm_specs(cfg),
        "mlp": _mlp_specs(cfg, mlp),
    }
    if cross:
        out["norm_cross"] = _norm_specs(cfg)
        out["cross"] = attn.cross_attn_specs(cfg)
    return out


def _apply_mixer_train(p, cfg, kind: str, x, positions, state=None):
    """Returns (out, new_state_or_None)."""
    if kind == "attn":
        if cfg.attn_type == "mla":
            return attn.mla_train(p, cfg, x, positions), None
        return attn.attention_train(p, cfg, x, positions), None
    if kind == "rwkv":
        st = None if state is None else {k: state[k] for k in ("x_prev", "wkv", "x_prev_cm")}
        out, new = ssm.rwkv_time_mix(p, cfg, x, st)
        return out, new
    if kind == "mamba":
        out, new = ssm.mamba_mixer(p, cfg, cfg.mamba, x, state)
        return out, new
    raise ValueError(kind)


def _apply_mlp(p, cfg, kind: str, x, cm_state=None):
    """Returns (out, aux_loss, new_cm_state)."""
    if kind == "moe":
        y, aux = moe_lib.moe_ffn(p, cfg, cfg.moe, x)
        return y, aux, None
    if kind == "swiglu":
        return swiglu(p["w_gate"], p["w_up"], p["w_down"], x), 0.0, None
    if kind == "gelu":
        return gelu_mlp(p["w_fc"], p["b_fc"], p["w_proj"], p["b_proj"], x), 0.0, None
    if kind == "rwkv_cm":
        y, last = ssm.rwkv_channel_mix(p, cfg, x, cm_state)
        return y, 0.0, last
    raise ValueError(kind)


def decoder_layer_train(p, cfg, kind: tuple, x, positions):
    mixer, mlp = kind
    h = _apply_norm(p["norm1"], cfg, x)
    mix_out, _ = _apply_mixer_train(p["mixer"], cfg, mixer, h, positions)
    x = x + mix_out
    h = _apply_norm(p["norm2"], cfg, x)
    mlp_out, aux, _ = _apply_mlp(p["mlp"], cfg, mlp, h)
    x = x + mlp_out
    x = constrain(x, ("batch", "seq", "embed_act"))
    return x, aux


# ---------------------------------------------------------------------------
# Decode-path per-layer
# ---------------------------------------------------------------------------


def layer_cache_init(cfg, kind: tuple, batch: int, max_seq: int):
    mixer, _ = kind
    if mixer == "attn":
        return attn.init_cache(cfg, batch, max_seq)
    if mixer == "rwkv":
        return ssm.rwkv_state_init(cfg, batch)
    if mixer == "mamba":
        return ssm.mamba_state_init(cfg, cfg.mamba, batch)
    raise ValueError(mixer)


def layer_cache_axes(cfg, kind: tuple):
    mixer, _ = kind
    if mixer == "attn":
        return attn.cache_specs(cfg, 0, 0)
    if mixer == "rwkv":
        return ssm.rwkv_state_axes()
    if mixer == "mamba":
        return ssm.mamba_state_axes()
    raise ValueError(mixer)


def decoder_layer_decode(p, cfg, kind: tuple, x, cache, position):
    mixer, mlp = kind
    h = _apply_norm(p["norm1"], cfg, x)
    if mixer == "attn":
        if cfg.attn_type == "mla":
            mix_out, new_cache = attn.mla_decode(p["mixer"], cfg, h, cache, position)
        else:
            mix_out, new_cache = attn.attention_decode(p["mixer"], cfg, h, cache, position)
    elif mixer == "rwkv":
        mix_out, new_cache = ssm.rwkv_time_mix(p["mixer"], cfg, h, cache)
    elif mixer == "mamba":
        mix_out, new_cache = ssm.mamba_mixer(p["mixer"], cfg, cfg.mamba, h, cache)
    else:
        raise ValueError(mixer)
    x = x + mix_out
    h = _apply_norm(p["norm2"], cfg, x)
    cm_state = cache.get("x_prev_cm") if mixer == "rwkv" else None
    mlp_out, _, new_cm = _apply_mlp(p["mlp"], cfg, mlp, h, cm_state)
    if mixer == "rwkv" and new_cm is not None:
        new_cache = dict(new_cache, x_prev_cm=new_cm)
    x = x + mlp_out
    return x, new_cache


# ---------------------------------------------------------------------------
# The Model
# ---------------------------------------------------------------------------


class Model:
    """A configured architecture: specs, init, train loss, decode step."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.kinds = layer_kinds(cfg)
        body = self.kinds[cfg.first_dense:]
        self.period = find_period(body) if body else 1
        self.n_periods = len(body) // self.period if body else 0
        self.period_kinds = body[: self.period]

    # -- specs ---------------------------------------------------------------
    def specs(self) -> dict:
        cfg = self.cfg
        out = {"embed": embed_spec(cfg.vocab, cfg.d_model, cfg.param_dtype)}
        if cfg.first_dense:
            out["prefix"] = [
                decoder_layer_specs(cfg, self.kinds[i]) for i in range(cfg.first_dense)
            ]
        if self.n_periods:
            period_spec = {
                f"sub{j}": decoder_layer_specs(cfg, k, cross=cfg.is_encdec)
                for j, k in enumerate(self.period_kinds)
            }
            out["stack"] = stack_specs(period_spec, self.n_periods)
        out["final_norm"] = _norm_specs(cfg)
        if not cfg.tie_embeddings:
            out["unembed"] = {
                "w": spec((cfg.d_model, cfg.vocab), ("embed", "vocab"),
                          "scaled", 0.02 / math.sqrt(cfg.d_model), dtype=cfg.param_dtype)
            }
        if cfg.is_encdec:
            enc_layer = {
                "norm1": _norm_specs(cfg),
                "mixer": attn.attn_specs(cfg),
                "norm2": _norm_specs(cfg),
                "mlp": _mlp_specs(cfg, cfg.mlp_type),
            }
            out["enc_stack"] = stack_specs(enc_layer, cfg.enc_layers)
            out["enc_final_norm"] = _norm_specs(cfg)
        return out

    def init(self, key) -> dict:
        return init_params(self.specs(), key)

    # -- parameter accounting --------------------------------------------
    def n_params(self) -> int:
        return param_count(self.specs())

    def n_active_params(self) -> int:
        """Per-token active params: routed experts count top_k/n_experts."""
        cfg = self.cfg
        specs = self.specs()

        def count(tree, pred):
            c = 0
            leaves = jax.tree_util.tree_leaves_with_path(
                tree, is_leaf=lambda x: hasattr(x, "shape") and hasattr(x, "axes"))
            for path, leaf in leaves:
                n = 1
                for s in leaf.shape:
                    n *= s
                if pred(path, leaf):
                    c += n
            return c

        total = count(specs, lambda p, l: True)
        if cfg.moe is None:
            return total

        def is_routed_expert(path, leaf):
            # routed expert weights carry an explicit n_experts dimension
            body = leaf.shape[1:] if (leaf.axes and leaf.axes[0] == "stack") else leaf.shape
            names = [str(getattr(k, "key", k)) for k in path]
            return (len(body) == 3 and body[0] == cfg.moe.n_experts
                    and any(n in ("w_gate", "w_up", "w_down") for n in names)
                    and "shared" not in names)

        routed = count(specs, is_routed_expert)
        return total - routed + int(routed * cfg.moe.top_k / cfg.moe.n_experts)

    # -- encoder -----------------------------------------------------------
    def encode(self, params, enc_input):
        """enc_input: (b, enc_seq, d_model) precomputed frame embeddings (stub
        frontend per assignment).  Adds sinusoidal positions, runs the
        bidirectional stack."""
        cfg = self.cfg
        x = enc_input + sinusoidal_positions(enc_input.shape[1], cfg.d_model).astype(
            enc_input.dtype
        )

        def body(carry, layer_p):
            h = _apply_norm(layer_p["norm1"], cfg, carry)
            carry = carry + attn.bidir_attention(layer_p["mixer"], cfg, h)
            h = _apply_norm(layer_p["norm2"], cfg, carry)
            mlp_out, _, _ = _apply_mlp(layer_p["mlp"], cfg, cfg.mlp_type, h)
            return carry + mlp_out, None

        if cfg.remat:
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, params["enc_stack"])
        return _apply_norm(params["enc_final_norm"], cfg, x)

    # -- train forward -------------------------------------------------------
    def logits(self, params, tokens, enc_out=None):
        cfg = self.cfg
        b, s = tokens.shape
        x = embed(params["embed"], tokens)
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        aux_total = jnp.float32(0.0)

        for i in range(cfg.first_dense):
            x, aux = decoder_layer_train(params["prefix"][i], cfg, self.kinds[i], x, positions)
            aux_total += aux

        if self.n_periods:
            def body(carry, layer_p):
                x, aux_total = carry
                for j, kind in enumerate(self.period_kinds):
                    p = layer_p[f"sub{j}"]
                    mixer, mlp = kind
                    h = _apply_norm(p["norm1"], cfg, x)
                    mix_out, _ = _apply_mixer_train(p["mixer"], cfg, mixer, h, positions)
                    x = x + mix_out
                    if cfg.is_encdec:
                        h = _apply_norm(p["norm_cross"], cfg, x)
                        x = x + attn.cross_attention(p["cross"], cfg, h, enc_out)
                    h = _apply_norm(p["norm2"], cfg, x)
                    mlp_out, aux, _ = _apply_mlp(p["mlp"], cfg, mlp, h)
                    x = x + mlp_out
                    aux_total += aux
                x = constrain(x, ("batch", "seq", "embed_act"))
                return (x, aux_total), None

            if cfg.remat:
                body = jax.checkpoint(body)
            (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), params["stack"])

        x = _apply_norm(params["final_norm"], cfg, x)
        if cfg.tie_embeddings:
            logits = unembed(params["embed"], x)
        else:
            logits = dense(params["unembed"]["w"], x, "bsd,dv->bsv",
                           waxes=("embed", "vocab"))
            logits = constrain(logits, ("batch", "seq", "vocab_act"))
        return logits, aux_total

    def loss(self, params, batch):
        """Next-token CE (+ MoE aux).  batch: {tokens, [enc_input]}.

        The gold-logit pick uses a vocab-range compare + masked sum instead
        of take_along_axis: a gather along the TP-sharded vocab axis forces
        SPMD to all-gather the logits (GiBs at 512 devices); the compare
        formulation reduces shard-locally and psums a scalar per token.
        """
        cfg = self.cfg
        tokens = batch["tokens"]
        enc_out = None
        if cfg.is_encdec:
            enc_out = self.encode(params, batch["enc_input"])
        logits, aux = self.logits(params, tokens, enc_out)
        tgt = tokens[:, 1:]
        lg = logits[:, :-1].astype(jnp.float32)
        logz = jax.nn.logsumexp(lg, axis=-1)
        vocab_ids = jnp.arange(cfg.vocab, dtype=tokens.dtype)
        onehot = (vocab_ids[None, None, :] == tgt[..., None])
        gold = jnp.sum(jnp.where(onehot, lg, 0.0), axis=-1)
        ce = jnp.mean(logz - gold)
        return ce + aux, {"ce": ce, "aux": aux}

    # -- prefill ----------------------------------------------------------
    def _layer_prefill(self, p, kind, x, positions, enc_out):
        """One layer forward that also emits its decode-cache entry."""
        cfg = self.cfg
        mixer, mlp = kind
        h = _apply_norm(p["norm1"], cfg, x)
        if mixer == "attn":
            if cfg.attn_type == "mla":
                mo, entry = attn.mla_train(p["mixer"], cfg, h, positions, return_kv=True)
            else:
                mo, entry = attn.attention_train(p["mixer"], cfg, h, positions, return_kv=True)
        elif mixer == "rwkv":
            mo, entry = ssm.rwkv_time_mix(p["mixer"], cfg, h)
        elif mixer == "mamba":
            mo, entry = ssm.mamba_mixer(p["mixer"], cfg, cfg.mamba, h)
        else:
            raise ValueError(mixer)
        x = x + mo
        if cfg.is_encdec:
            hq = _apply_norm(p["norm_cross"], cfg, x)
            x = x + attn.cross_attention(p["cross"], cfg, hq, enc_out)
        h = _apply_norm(p["norm2"], cfg, x)
        cm_in = jnp.zeros((x.shape[0], cfg.d_model), x.dtype) if mixer == "rwkv" else None
        mo, _, new_cm = _apply_mlp(p["mlp"], cfg, mlp, h, cm_in)
        if mixer == "rwkv" and new_cm is not None:
            entry = dict(entry, x_prev_cm=new_cm)
        x = x + mo
        x = constrain(x, ("batch", "seq", "embed_act"))
        return x, entry

    def prefill(self, params, tokens, enc_out=None):
        """Process a full prompt; return (last-token logits, decode cache).

        The cache sequence capacity equals the prompt length (SWA: the
        window) — use ``pad_cache`` to extend it before generating.
        """
        cfg = self.cfg
        b, s = tokens.shape
        x = embed(params["embed"], tokens)
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        cache = {}

        if cfg.first_dense:
            prefix = []
            for i in range(cfg.first_dense):
                x, entry = self._layer_prefill(
                    params["prefix"][i], self.kinds[i], x, positions, enc_out)
                prefix.append(entry)
            cache["prefix"] = prefix

        if self.n_periods:
            def body(x, layer_p):
                entries = {}
                for j, kind in enumerate(self.period_kinds):
                    x, entry = self._layer_prefill(
                        layer_p[f"sub{j}"], kind, x, positions, enc_out)
                    entries[f"sub{j}"] = entry
                return x, entries

            x, stack_cache = jax.lax.scan(body, x, params["stack"])
            cache["stack"] = stack_cache

        x = _apply_norm(params["final_norm"], cfg, x[:, -1:])
        if cfg.tie_embeddings:
            logits = unembed(params["embed"], x)
        else:
            logits = dense(params["unembed"]["w"], x, "bsd,dv->bsv")
        if cfg.is_encdec:
            cache = self.prefill_cross(params, cache, enc_out)
        return logits[:, 0], cache

    def pad_cache(self, cache, extra: int):
        """Grow attention caches by ``extra`` positions (for generation)."""
        cfg = self.cfg
        if cfg.attn_type == "swa" or cfg.mixer == "rwkv":
            return cache    # ring buffer / recurrent state: fixed size

        def grow(path, a):
            names = [str(getattr(k, "key", k)) for k in path]
            if any(n in ("k", "v", "ckv", "krope") for n in names) and "cross" not in names:
                seq_axis = a.ndim - (2 if names[-1] in ("ckv", "krope") else 3)
                pad = [(0, 0)] * a.ndim
                pad[seq_axis] = (0, extra)
                return jnp.pad(a, pad)
            return a

        return jax.tree_util.tree_map_with_path(grow, cache)

    # -- decode ----------------------------------------------------------
    def init_cache(self, batch: int, max_seq: int):
        cfg = self.cfg
        out = {}
        if cfg.first_dense:
            out["prefix"] = [
                layer_cache_init(cfg, self.kinds[i], batch, max_seq)
                for i in range(cfg.first_dense)
            ]
        if self.n_periods:
            def stack(i_kind):
                j, kind = i_kind
                one = layer_cache_init(cfg, kind, batch, max_seq)
                return jax.tree_util.tree_map(
                    lambda a: jnp.broadcast_to(a, (self.n_periods,) + a.shape), one)
            out["stack"] = {f"sub{j}": stack((j, k))
                            for j, k in enumerate(self.period_kinds)}
        if cfg.is_encdec:
            # cross K/V cached at encode time; placeholder zeros here
            H, hd = cfg.n_heads, cfg.d_head
            ck = jnp.zeros((self.n_periods, batch, cfg.enc_seq, H, hd), cfg.param_dtype)
            out["cross"] = {"k": ck, "v": ck}
        return out

    def cache_axes(self):
        cfg = self.cfg
        out = {}
        if cfg.first_dense:
            out["prefix"] = [
                layer_cache_axes(cfg, self.kinds[i]) for i in range(cfg.first_dense)
            ]
        if self.n_periods:
            out["stack"] = {
                f"sub{j}": jax.tree_util.tree_map(
                    lambda ax: ("stack",) + ax,
                    layer_cache_axes(cfg, k),
                    is_leaf=lambda x: isinstance(x, tuple) and all(
                        isinstance(e, (str, type(None))) for e in x),
                )
                for j, k in enumerate(self.period_kinds)
            }
        if cfg.is_encdec:
            ax = ("stack", "batch", None, "heads_act", None)
            out["cross"] = {"k": ax, "v": ax}
        return out

    def prefill_cross(self, params, cache, enc_out):
        """Fill cross-attention K/V from encoder output (whisper serve).

        Computed once per request instead of per decode step — the KV form
        of "compute early, transmit less" (DESIGN.md §4: the encoder output
        is the natural cut point of an enc-dec pipeline).
        """

        def per_layer(_, layer_p):
            cr = layer_p["sub0"]["cross"]     # enc-dec stacks have period 1
            k = dense(cr["wk"], enc_out, "btd,dhe->bthe")
            v = dense(cr["wv"], enc_out, "btd,dhe->bthe")
            return None, (k, v)

        _, (ks, vs) = jax.lax.scan(per_layer, None, params["stack"])
        return dict(cache, cross={"k": ks, "v": vs})

    def decode_step(self, params, token, cache, position):
        """token: (b, 1) int32; position: scalar int32.  -> (logits, cache)."""
        cfg = self.cfg
        b = token.shape[0]
        x = embed(params["embed"], token)
        new_cache = dict(cache)

        if cfg.first_dense:
            new_prefix = []
            for i in range(cfg.first_dense):
                x, c = decoder_layer_decode(
                    params["prefix"][i], cfg, self.kinds[i], x, cache["prefix"][i], position)
                new_prefix.append(c)
            new_cache["prefix"] = new_prefix

        if self.n_periods:
            cross = cache.get("cross")

            def body(x, xs):
                layer_p, layer_c, cross_kv = xs
                new_c = {}
                for j, kind in enumerate(self.period_kinds):
                    p, c = layer_p[f"sub{j}"], layer_c[f"sub{j}"]
                    h = _apply_norm(p["norm1"], cfg, x)
                    mixer, mlp = kind
                    if mixer == "attn":
                        if cfg.attn_type == "mla":
                            mo, nc = attn.mla_decode(p["mixer"], cfg, h, c, position)
                        else:
                            mo, nc = attn.attention_decode(p["mixer"], cfg, h, c, position)
                    elif mixer == "rwkv":
                        mo, nc = ssm.rwkv_time_mix(p["mixer"], cfg, h, c)
                    elif mixer == "mamba":
                        mo, nc = ssm.mamba_mixer(p["mixer"], cfg, cfg.mamba, h, c)
                    x = x + mo
                    if cfg.is_encdec:
                        hq = _apply_norm(p["norm_cross"], cfg, x)
                        q = dense(p["cross"]["wq"], hq, "bsd,dhe->bshe")
                        ck, cv = cross_kv
                        lg = jnp.einsum("bshe,bthe->bhst", q, ck,
                                        preferred_element_type=jnp.float32)
                        pr = jax.nn.softmax(lg / math.sqrt(cfg.d_head), axis=-1)
                        co = jnp.einsum("bhst,bthe->bshe", pr.astype(cv.dtype), cv,
                                        preferred_element_type=jnp.float32).astype(cv.dtype)
                        x = x + dense(p["cross"]["wo"], co, "bshe,hed->bsd")
                    h = _apply_norm(p["norm2"], cfg, x)
                    cm_state = c.get("x_prev_cm") if mixer == "rwkv" else None
                    mo, _, new_cm = _apply_mlp(p["mlp"], cfg, mlp, h, cm_state)
                    if mixer == "rwkv" and new_cm is not None:
                        nc = dict(nc, x_prev_cm=new_cm)
                    x = x + mo
                    new_c[f"sub{j}"] = nc
                return x, new_c

            cross_xs = ((cache["cross"]["k"], cache["cross"]["v"])
                        if cfg.is_encdec else
                        (jnp.zeros((self.n_periods,)), jnp.zeros((self.n_periods,))))
            x, new_stack = jax.lax.scan(body, x, (params["stack"], cache["stack"], cross_xs))
            new_cache["stack"] = new_stack

        x = _apply_norm(params["final_norm"], cfg, x)
        if cfg.tie_embeddings:
            logits = unembed(params["embed"], x)
        else:
            logits = dense(params["unembed"]["w"], x, "bsd,dv->bsv")
        return logits, new_cache
