"""AdamW with ZeRO-compatible sharded state (pure JAX; no optax offline).

Optimizer state mirrors the parameter pytree: f32 master copy + first/second
moments.  Because state leaves inherit the parameters' logical axes, the
FSDP rules shard them automatically — ZeRO-1/2 falls out of the sharding
rules rather than being a special code path.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    lr_min: float = 3e-5
    warmup_steps: int = 200
    decay_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


class OptState(NamedTuple):
    step: jax.Array           # scalar int32
    master: object            # f32 copy of params (pytree)
    mu: object                # first moment (pytree, f32)
    nu: object                # second moment (pytree, f32)


def init_opt_state(params) -> OptState:
    # explicit copy: f32 params would otherwise alias the master buffer and
    # break double-donation in the jit'd step
    f32 = lambda p: jnp.array(p, dtype=jnp.float32, copy=True)
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(
        step=jnp.zeros((), jnp.int32),
        master=jax.tree_util.tree_map(f32, params),
        mu=jax.tree_util.tree_map(zeros, params),
        nu=jax.tree_util.tree_map(zeros, params),
    )


def lr_schedule(cfg: AdamWConfig, step):
    """Linear warmup then cosine decay to lr_min."""
    step = step.astype(jnp.float32)
    warm = cfg.lr_peak * step / jnp.maximum(cfg.warmup_steps, 1)
    frac = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.lr_min + 0.5 * (cfg.lr_peak - cfg.lr_min) * (1 + jnp.cos(jnp.pi * frac))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree_util.tree_map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def adamw_update(cfg: AdamWConfig, grads, state: OptState, param_dtype=jnp.bfloat16):
    """One AdamW step.  Returns (new_params_cast, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        # decoupled weight decay on the master copy
        new_p = p - lr * (mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p)
        return new_p, m, v

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    flat_p = treedef.flatten_up_to(state.master)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_master = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_mu = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_nu = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])

    new_params = jax.tree_util.tree_map(lambda p: p.astype(param_dtype), new_master)
    new_state = OptState(step=step, master=new_master, mu=new_mu, nu=new_nu)
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
