"""Train step builders: plain SPMD, and pod-compressed gradient exchange.

Two variants, A/B-comparable in the roofline harness:

* :func:`make_train_step` — canonical fully-automatic SPMD step.  Gradient
  reduction over every data axis (including 'pod') is inserted by XLA.

* :func:`make_train_step_compressed` — the paper's early-data-reduction
  insight applied to the slowest link: the step is `shard_map`-manual over
  the **pod axis only** (everything else stays auto-SPMD).  Per-pod
  gradients are reduced in full precision inside the pod, then exchanged
  across pods as int8 + scales with error feedback
  (core.reduction.compressed_pod_allreduce) — ~8x fewer bytes on the
  pod-to-pod link at 2 pods.  EXPERIMENTS.md §Perf quantifies the
  collective-term drop on the compiled HLO.
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.reduction import EFState, compressed_pod_allreduce
from repro.train.optimizer import AdamWConfig, OptState, adamw_update, init_opt_state
from repro.parallel.axes import current_context


def make_train_step(model, opt_cfg: AdamWConfig, accum: int = 1):
    """Plain SPMD train step: loss -> grads -> AdamW.

    ``accum`` > 1 splits the per-step batch into microbatches scanned
    sequentially with f32 gradient accumulation: activation live range
    (saved layer boundaries under remat) shrinks by the accumulation
    factor, which is what fits the 4k-seq x 256-batch cells into 16 GiB
    HBM (EXPERIMENTS.md §Perf iteration 2).
    """

    def grads_of(params, batch):
        return jax.value_and_grad(model.loss, has_aux=True)(params, batch)

    def train_step(params, opt_state: OptState, batch):
        if accum == 1:
            (loss, metrics), grads = grads_of(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                assert b % accum == 0, (b, accum)
                return x.reshape(accum, b // accum, *x.shape[1:])

            micro = jax.tree_util.tree_map(split, batch)

            def body(acc, mb):
                (l, met), g = grads_of(params, mb)
                acc_g, acc_l = acc
                acc_g = jax.tree_util.tree_map(
                    lambda a, x: a + x.astype(jnp.float32), acc_g, g)
                return (acc_g, acc_l + l), met

            zero_g = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), mets = jax.lax.scan(body, (zero_g, jnp.float32(0.0)), micro)
            grads = jax.tree_util.tree_map(lambda g: g / accum, gsum)
            loss = lsum / accum
            metrics = jax.tree_util.tree_map(lambda m: m[-1], mets)

        new_params, new_opt, opt_metrics = adamw_update(
            opt_cfg, grads, opt_state, model.cfg.param_dtype)
        return new_params, new_opt, dict(metrics, loss=loss, **opt_metrics)

    return train_step


def init_ef_states(params):
    """Error-feedback residuals for every gradient leaf (f32, param-shaped)."""
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def make_train_step_compressed(model, opt_cfg: AdamWConfig, pod_axis: str = "pod"):
    """Train step with int8+EF gradient exchange over the pod axis.

    Manual over `pod_axis` only (partial-manual shard_map); 'data'/'model'
    remain automatic so all intra-pod behaviour matches the plain step.
    """

    def per_pod_step(params, opt_state, ef, batch):
        npods = jax.lax.axis_size(pod_axis)
        (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(
            params, batch)

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_ef = treedef.flatten_up_to(ef)
        out_g, out_ef = [], []
        for g, e in zip(flat_g, flat_ef):
            summed, new_e = compressed_pod_allreduce(
                g.astype(jnp.float32), EFState(e), pod_axis=pod_axis)
            out_g.append(summed / npods)
            out_ef.append(new_e.residual)
        grads = jax.tree_util.tree_unflatten(treedef, out_g)
        new_ef = jax.tree_util.tree_unflatten(treedef, out_ef)
        loss = jax.lax.pmean(loss, pod_axis)
        metrics = jax.tree_util.tree_map(lambda m: jax.lax.pmean(m, pod_axis), metrics)

        new_params, new_opt, opt_metrics = adamw_update(
            opt_cfg, grads, opt_state, model.cfg.param_dtype)
        return new_params, new_opt, new_ef, dict(metrics, loss=loss, **opt_metrics)

    def train_step(params, opt_state, ef, batch):
        ctx = current_context()
        mesh = ctx.mesh
        # batch tensors carry the pod shard on dim 0; everything else is
        # replicated across pods (params/opt/ef live pod-replicated, sharded
        # over data/model by the auto axes).
        from repro.parallel.axes import compat_shard_map
        fn = compat_shard_map(
            per_pod_step,
            mesh=mesh,
            # prefix specs: batch sharded over pod on dim 0; params/opt/ef and
            # all outputs pod-replicated (data/model sharding stays automatic)
            in_specs=(P(), P(), P(), P(pod_axis)),
            out_specs=(P(), P(), P(), P()),
            axis_names=frozenset({pod_axis}),
            check_vma=False,
        )
        return fn(params, opt_state, ef, batch)

    return train_step


def make_prefill_step(model):
    def prefill_step(params, batch):
        enc_out = None
        if model.cfg.is_encdec:
            enc_out = model.encode(params, batch["enc_input"])
        return model.prefill(params, batch["tokens"], enc_out)
    return prefill_step


def make_serve_step(model):
    def serve_step(params, token, cache, position):
        return model.decode_step(params, token, cache, position)
    return serve_step
