"""Fault-tolerant training loop: checkpoint/restart, failure injection,
straggler accounting, elastic restart.

At 1000+ nodes the loop's contract is: (a) any step may fail (device loss,
preemption) — recover from the last durable checkpoint with identical data
order; (b) the mesh after recovery may differ (elastic) — checkpoints are
mesh-agnostic (ckpt/checkpoint.py); (c) stragglers are visible — per-step
wall times feed a straggler monitor that flags slow steps (on real fleets:
triggers hot-spare swap; here: recorded + tested).
"""

from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from repro.ckpt.checkpoint import (
    latest_step,
    prune_old,
    restore_checkpoint,
    save_checkpoint,
)
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.step import make_train_step


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 2
    max_retries: int = 3
    straggler_factor: float = 2.0      # step > factor * median => straggler
    accum: int = 1


@dataclasses.dataclass
class StragglerMonitor:
    times: list = dataclasses.field(default_factory=list)
    flagged: list = dataclasses.field(default_factory=list)

    def record(self, step: int, dt: float, factor: float):
        self.times.append(dt)
        med = float(np.median(self.times[-50:]))
        if len(self.times) > 5 and dt > factor * med:
            self.flagged.append((step, dt, med))

    @property
    def median(self) -> float:
        return float(np.median(self.times)) if self.times else 0.0


def train(model, make_batch, loop_cfg: LoopConfig, opt_cfg: AdamWConfig = None,
          params=None, seed: int = 0, fail_hook=None, log_every: int = 10,
          verbose: bool = True):
    """Run (or resume) training.  Returns (params, opt_state, history).

    ``make_batch(step) -> batch`` must be deterministic (data/pipeline.py).
    ``fail_hook(step)`` may raise to emulate node failure — the loop
    restores the last checkpoint and replays; tests assert loss continuity.
    """
    opt_cfg = opt_cfg or AdamWConfig()
    step_fn = jax.jit(make_train_step(model, opt_cfg, accum=loop_cfg.accum),
                      donate_argnums=(0, 1))

    if params is None:
        params = model.init(jax.random.PRNGKey(seed))
    opt_state = init_opt_state(params)

    start = 0
    last = latest_step(loop_cfg.ckpt_dir)
    if last is not None:
        (params, opt_state), extra = restore_checkpoint(
            loop_cfg.ckpt_dir, last, (params, opt_state))
        start = extra["next_step"]
        if verbose:
            print(f"[loop] resumed from step {last} -> continuing at {start}")

    history = []
    monitor = StragglerMonitor()
    step = start
    retries = 0
    while step < loop_cfg.total_steps:
        t0 = time.time()
        try:
            if fail_hook is not None:
                fail_hook(step)
            batch = make_batch(step)
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            loss = float(metrics["loss"])
        except Exception as e:  # noqa: BLE001 — the recovery path IS the feature
            retries += 1
            if retries > loop_cfg.max_retries:
                raise
            last = latest_step(loop_cfg.ckpt_dir)
            if verbose:
                print(f"[loop] step {step} failed ({e}); restoring ckpt {last}")
            if last is None:
                params = model.init(jax.random.PRNGKey(seed))
                opt_state = init_opt_state(params)
                step = 0
            else:
                (params, opt_state), extra = restore_checkpoint(
                    loop_cfg.ckpt_dir, last, (params, opt_state))
                step = extra["next_step"]
            continue

        dt = time.time() - t0
        monitor.record(step, dt, loop_cfg.straggler_factor)
        history.append({"step": step, "loss": loss, "dt": dt})
        if verbose and step % log_every == 0:
            print(f"[loop] step {step:5d} loss {loss:.4f} ({dt*1e3:.0f} ms)")

        step += 1
        if step % loop_cfg.ckpt_every == 0 or step == loop_cfg.total_steps:
            save_checkpoint(loop_cfg.ckpt_dir, step, (params, opt_state),
                            extra={"next_step": step})
            prune_old(loop_cfg.ckpt_dir, loop_cfg.keep)

    return params, opt_state, {"history": history, "stragglers": monitor.flagged}
