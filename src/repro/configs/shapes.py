"""Assigned input-shape sets (assignment: 4 shapes x 10 archs = 40 cells).

``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a
seq_len KV cache/state), NOT ``train_step``.  ``long_500k`` requires
sub-quadratic attention — pure full-attention archs skip it (recorded, not
silently dropped).
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq: int
    batch: int
    mode: str            # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def applicable(cfg, shape: ShapeSpec) -> tuple:
    """(runs: bool, reason-if-skipped).  Encoder-only archs would skip decode
    shapes; every assigned arch has a decoder, so the only skip rule here is
    the sub-quadratic requirement for long_500k."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "full-attention arch: long_500k requires sub-quadratic attention (assignment rule; see DESIGN.md §4)"
    return True, ""
