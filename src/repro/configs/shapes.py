"""Assigned input-shape sets (assignment: 4 shapes x 10 archs = 40 cells).

``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a
seq_len KV cache/state), NOT ``train_step``.  ``long_500k`` requires
sub-quadratic attention — pure full-attention archs skip it (recorded, not
silently dropped).
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq: int
    batch: int
    mode: str            # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def applicable(cfg, shape: ShapeSpec) -> tuple:
    """(runs: bool, reason-if-skipped).  Encoder-only archs would skip decode
    shapes; every assigned arch has a decoder, so the only skip rule here is
    the sub-quadratic requirement for long_500k."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "full-attention arch: long_500k requires sub-quadratic attention (assignment rule; see DESIGN.md §4)"
    return True, ""


# ---------------------------------------------------------------------------
# Kernel legality cases (repro.analysis pass 3)
# ---------------------------------------------------------------------------
# Representative shapes each Pallas kernel must tile legally at: the camera
# pipelines' native sizes (security_video 144x176, stereo_pair 256x320, the
# paper's 4K VR eye) and the LM SHAPES above for the two sequence kernels.
# Each entry is interpreted by that kernel package's ANALYSIS.plan hook.

KERNEL_SHAPES = {
    "integral_image": [
        {"case": "fa_native", "n": 8, "h": 144, "w": 176, "block_h": 32},
        {"case": "vr_4k_eye", "n": 1, "h": 2160, "w": 3840, "block_h": 32},
    ],
    "haar_frontend": [
        {"case": "fa_scan", "n_windows": 5868, "L": 145 * 177,
         "n_scales": 4, "sz": 33, "K": 8, "block_n": 256},
    ],
    "quant_matmul": [
        {"case": "fa_nn_l1", "m": 512, "k": 400, "n": 8},
        {"case": "fa_nn_l2", "m": 512, "k": 8, "n": 1},
        {"case": "grad_tile", "m": 1024, "k": 1024, "n": 1024},
    ],
    "wire_codec": [
        {"case": "fa_motion_cut", "n_values": 5 * 144 * 176, "bits": 8},
        {"case": "vr_depth_cut", "n_values": 2 * 256 * 320, "bits": 4},
    ],
    "flash_attention": [
        {"case": "train_4k", "bh": 8, "s": 4096, "d": 128,
         "block_q": 256, "block_k": 256},
        {"case": "prefill_32k", "bh": 8, "s": 32_768, "d": 128,
         "block_q": 256, "block_k": 256},
    ],
    "rwkv_scan": [
        {"case": "train_4k", "bh": 8, "T": 4096, "K": 64, "V": 64,
         "chunk": 32},
    ],
    "bilateral_blur": [
        {"case": "vr_stereo", "h": 256, "w": 320, "sigma_spatial": 16,
         "sigma_range": 16.0, "block_gy": 32},
        {"case": "vr_4k_eye", "h": 2160, "w": 3840, "sigma_spatial": 16,
         "sigma_range": 16.0, "block_gy": 32},
    ],
}
