"""Arch registry: ``--arch <id>`` resolution + input_specs for every cell.

``input_specs(cfg, shape, ctx)`` returns weak-type-correct
ShapeDtypeStructs for every model input of the (arch x shape) cell — no
device allocation, the dry-run pattern.  Modality frontends are stubs per
the assignment: whisper gets precomputed frame embeddings; chameleon gets
token ids that already include VQ image-token codes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import lm_archs as A
from repro.configs.shapes import SHAPES, ShapeSpec, applicable
from repro.models.transformer import Model, ModelConfig

CONFIGS = {
    "mixtral-8x22b": A.MIXTRAL_8X22B,
    "deepseek-v2-236b": A.DEEPSEEK_V2,
    "granite-34b": A.GRANITE_34B,
    "yi-9b": A.YI_9B,
    "codeqwen1.5-7b": A.CODEQWEN_7B,
    "phi3-medium-14b": A.PHI3_MEDIUM,
    "rwkv6-7b": A.RWKV6_7B,
    "whisper-medium": A.WHISPER_MEDIUM,
    "chameleon-34b": A.CHAMELEON_34B,
    "jamba-v0.1-52b": A.JAMBA_52B,
}

SMOKE_CONFIGS = {
    "mixtral-8x22b": A.MIXTRAL_SMOKE,
    "deepseek-v2-236b": A.DEEPSEEK_SMOKE,
    "granite-34b": A.GRANITE_SMOKE,
    "yi-9b": A.YI_SMOKE,
    "codeqwen1.5-7b": A.CODEQWEN_SMOKE,
    "phi3-medium-14b": A.PHI3_SMOKE,
    "rwkv6-7b": A.RWKV6_SMOKE,
    "whisper-medium": A.WHISPER_SMOKE,
    "chameleon-34b": A.CHAMELEON_SMOKE,
    "jamba-v0.1-52b": A.JAMBA_SMOKE,
}


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    table = SMOKE_CONFIGS if smoke else CONFIGS
    if arch not in table:
        raise KeyError(f"unknown arch {arch!r}; have {sorted(table)}")
    return table[arch]


def list_archs():
    return sorted(CONFIGS)


def _sds(shape, dtype, ctx=None, axes=None):
    if ctx is None or axes is None:
        return jax.ShapeDtypeStruct(shape, dtype)
    return jax.ShapeDtypeStruct(
        shape, dtype, sharding=ctx.named_sharding(axes, shape))


def input_specs(cfg: ModelConfig, shape: ShapeSpec, ctx=None) -> dict:
    """Model-input stand-ins for one (arch x shape) cell.

    train/prefill: {"tokens": (B, S) i32, ["enc_input": (B, enc_seq, D)]}
    decode:        {"token": (B, 1) i32, "position": scalar i32,
                    "cache": <per-arch cache tree>, ["enc_out" via cross cache]}
    """
    runs, why = applicable(cfg, shape)
    if not runs:
        raise ValueError(f"{cfg.name} x {shape.name} skipped: {why}")
    B, S = shape.batch, shape.seq
    out = {}
    if shape.mode in ("train", "prefill"):
        out["tokens"] = _sds((B, S), jnp.int32, ctx, ("batch", "seq"))
        if cfg.is_encdec:
            out["enc_input"] = _sds((B, cfg.enc_seq, cfg.d_model), jnp.bfloat16,
                                    ctx, ("batch", None, "embed_act"))
        return out

    # decode: one new token against a populated length-S cache/state
    model = Model(cfg)
    out["token"] = _sds((B, 1), jnp.int32, ctx, ("batch", "seq"))
    out["position"] = jax.ShapeDtypeStruct((), jnp.int32)
    cache_shapes = jax.eval_shape(lambda: model.init_cache(B, S))
    if ctx is None:
        out["cache"] = cache_shapes
    else:
        out["cache"] = _attach_tree(cache_shapes, model.cache_axes(), ctx)
    return out


def _attach_tree(shapes_tree, axes_tree, ctx):
    is_ax_leaf = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)
    flat_s, treedef = jax.tree_util.tree_flatten(shapes_tree)
    flat_a = jax.tree_util.tree_flatten(axes_tree, is_leaf=is_ax_leaf)[0]
    assert len(flat_s) == len(flat_a), (len(flat_s), len(flat_a))
    out = [
        jax.ShapeDtypeStruct(s.shape, s.dtype,
                             sharding=ctx.named_sharding(a, s.shape))
        for s, a in zip(flat_s, flat_a)
    ]
    return jax.tree_util.tree_unflatten(treedef, out)


def all_cells():
    """Yield every (arch, shape, runs, skip_reason) of the 40-cell table."""
    for arch in list_archs():
        cfg = CONFIGS[arch]
        for sname, sh in SHAPES.items():
            runs, why = applicable(cfg, sh)
            yield arch, sname, runs, why
