"""The 10 assigned architectures — exact configs from the assignment table,
plus reduced SMOKE variants (same family shape, CPU-runnable).

Every entry records its provenance tag verbatim.  MoE parallelism per arch
is the placement-solver's default recommendation (EP when n_experts divides
the 16-way model axis, TP otherwise — see core/placement and DESIGN.md §4);
benchmarks/roofline can override it per plan.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.models.moe import MoEConfig
from repro.models.ssm import MambaConfig
from repro.models.transformer import MLAConfig, ModelConfig


def _replace(cfg, **kw):
    return dataclasses.replace(cfg, **kw)


# ---------------------------------------------------------------------------
# [moe] mixtral-8x22b — 8 experts top-2, SWA  [arXiv:2401.04088; hf]
# ---------------------------------------------------------------------------
MIXTRAL_8X22B = ModelConfig(
    name="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv=8, d_head=128,
    d_ff=16384, vocab=32768,
    attn_type="swa", window=4096,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=16384, parallelism="tp"),
    rope_theta=1e6,
    sub_quadratic=True,                      # SWA => O(s*w) attention
    source="arXiv:2401.04088; hf",
)
MIXTRAL_SMOKE = _replace(
    MIXTRAL_8X22B, n_layers=4, d_model=64, n_heads=4, n_kv=2, d_head=16,
    d_ff=128, vocab=256, window=16,
    moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=128, parallelism="tp"),
)

# ---------------------------------------------------------------------------
# [moe] deepseek-v2-236b — MLA kv_lora=512, 2 shared + 160 routed top-6
# [arXiv:2405.04434; hf]
# ---------------------------------------------------------------------------
DEEPSEEK_V2 = ModelConfig(
    name="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv=128, d_head=128,
    d_ff=12288, vocab=102400,
    attn_type="mla", mla=MLAConfig(kv_lora=512, qk_nope=128, qk_rope=64, v_dim=128),
    moe=MoEConfig(n_experts=160, top_k=6, d_ff_expert=1536, n_shared=2,
                  parallelism="ep"),
    first_dense=1,
    source="arXiv:2405.04434; hf",
)
DEEPSEEK_SMOKE = _replace(
    DEEPSEEK_V2, n_layers=3, d_model=64, n_heads=4, n_kv=4, d_head=16,
    d_ff=128, vocab=256,
    mla=MLAConfig(kv_lora=32, qk_nope=16, qk_rope=8, v_dim=16),
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32, n_shared=1,
                  parallelism="ep"),
)

# ---------------------------------------------------------------------------
# [dense] granite-34b — llama-arch per assignment, MQA (kv=1), code
# [arXiv:2405.04324; hf]  (GPT-BigCode lineage: GELU MLP, LN, tied, biases)
# ---------------------------------------------------------------------------
GRANITE_34B = ModelConfig(
    name="granite-34b", family="dense",
    n_layers=88, d_model=6144, n_heads=48, n_kv=1, d_head=128,
    d_ff=24576, vocab=49152,
    mlp_type="gelu", norm_type="ln", attn_bias=True, tie_embeddings=True,
    source="arXiv:2405.04324; hf",
)
GRANITE_SMOKE = _replace(
    GRANITE_34B, n_layers=4, d_model=64, n_heads=4, n_kv=1, d_head=16,
    d_ff=128, vocab=256,
)

# ---------------------------------------------------------------------------
# [dense] yi-9b — llama-arch GQA  [arXiv:2403.04652; hf]
# ---------------------------------------------------------------------------
YI_9B = ModelConfig(
    name="yi-9b", family="dense",
    n_layers=48, d_model=4096, n_heads=32, n_kv=4, d_head=128,
    d_ff=11008, vocab=64000,
    rope_theta=5e6,
    source="arXiv:2403.04652; hf",
)
YI_SMOKE = _replace(YI_9B, n_layers=4, d_model=64, n_heads=4, n_kv=2,
                    d_head=16, d_ff=128, vocab=256)

# ---------------------------------------------------------------------------
# [dense] codeqwen1.5-7b — qwen1.5-arch (MHA kv=32, attn bias)
# [hf:Qwen/CodeQwen1.5-7B; hf]
# ---------------------------------------------------------------------------
CODEQWEN_7B = ModelConfig(
    name="codeqwen1.5-7b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv=32, d_head=128,
    d_ff=13440, vocab=92416,
    attn_bias=True, rope_theta=1e6,
    source="hf:Qwen/CodeQwen1.5-7B; hf",
)
CODEQWEN_SMOKE = _replace(CODEQWEN_7B, n_layers=4, d_model=64, n_heads=4,
                          n_kv=4, d_head=16, d_ff=128, vocab=256)

# ---------------------------------------------------------------------------
# [dense] phi3-medium-14b — RoPE SwiGLU GQA  [arXiv:2404.14219; unverified]
# ---------------------------------------------------------------------------
PHI3_MEDIUM = ModelConfig(
    name="phi3-medium-14b", family="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv=10, d_head=128,
    d_ff=17920, vocab=100352,
    source="arXiv:2404.14219; unverified",
)
PHI3_SMOKE = _replace(PHI3_MEDIUM, n_layers=4, d_model=64, n_heads=4, n_kv=2,
                      d_head=16, d_ff=128, vocab=256)

# ---------------------------------------------------------------------------
# [ssm] rwkv6-7b — Finch, data-dependent decay, attention-free
# [arXiv:2404.05892; hf]   (heads = d_model/64)
# ---------------------------------------------------------------------------
RWKV6_7B = ModelConfig(
    name="rwkv6-7b", family="ssm",
    n_layers=32, d_model=4096, n_heads=64, n_kv=64, d_head=64,
    d_ff=14336, vocab=65536,
    mixer="rwkv", norm_type="ln",
    sub_quadratic=True,
    source="arXiv:2404.05892; hf",
)
RWKV6_SMOKE = _replace(RWKV6_7B, n_layers=3, d_model=128, n_heads=2, n_kv=2,
                       d_head=64, d_ff=256, vocab=256)

# ---------------------------------------------------------------------------
# [audio] whisper-medium — enc-dec, conv frontend STUB (precomputed frame
# embeddings per assignment)  [arXiv:2212.04356; unverified]
# vocab 51865 padded to 51968 (multiple of 128) for clean vocab sharding —
# standard practice; noted in DESIGN.md §5.
# ---------------------------------------------------------------------------
WHISPER_MEDIUM = ModelConfig(
    name="whisper-medium", family="audio",
    n_layers=24, enc_layers=24, enc_seq=1500,
    d_model=1024, n_heads=16, n_kv=16, d_head=64,
    d_ff=4096, vocab=51968,
    mlp_type="gelu", norm_type="ln", attn_bias=True, tie_embeddings=True,
    source="arXiv:2212.04356; unverified",
)
WHISPER_SMOKE = _replace(WHISPER_MEDIUM, n_layers=2, enc_layers=2, enc_seq=16,
                         d_model=64, n_heads=4, n_kv=4, d_head=16, d_ff=128,
                         vocab=256)

# ---------------------------------------------------------------------------
# [vlm] chameleon-34b — early-fusion, VQ image tokens in the vocab (frontend
# stub: input_specs provides token ids incl. image-token range), QK-norm
# [arXiv:2405.09818; unverified]
# ---------------------------------------------------------------------------
CHAMELEON_34B = ModelConfig(
    name="chameleon-34b", family="vlm",
    n_layers=48, d_model=8192, n_heads=64, n_kv=8, d_head=128,
    d_ff=22016, vocab=65536,
    qk_norm=True,
    source="arXiv:2405.09818; unverified",
)
CHAMELEON_SMOKE = _replace(CHAMELEON_34B, n_layers=4, d_model=64, n_heads=4,
                           n_kv=2, d_head=16, d_ff=128, vocab=256)

# ---------------------------------------------------------------------------
# [hybrid] jamba-v0.1-52b — Mamba+attn 1:7 interleave, MoE 16e top-2 every
# other layer  [arXiv:2403.19887; hf]
# ---------------------------------------------------------------------------
JAMBA_52B = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv=8, d_head=128,
    d_ff=14336, vocab=65536,
    mixer="mamba", attn_every=8, attn_offset=4,
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2, dt_rank=256),
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=14336, parallelism="ep"),
    moe_every=2, moe_offset=1,
    sub_quadratic=True,
    source="arXiv:2403.19887; hf",
)
JAMBA_SMOKE = _replace(
    JAMBA_52B, n_layers=8, d_model=64, n_heads=4, n_kv=2, d_head=16,
    d_ff=128, vocab=256, attn_every=4, attn_offset=2,
    mamba=MambaConfig(d_state=4, d_conv=4, expand=2, dt_rank=16),
    moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=128, parallelism="ep"),
)
