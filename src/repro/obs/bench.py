"""Normalized BENCH_*.json schema + machine diffing (bench.v1).

Every ``benchmarks/run.py --json`` section writes through
``bench_record``, so all artifacts share one top-level shape:

    {"schema": "bench.v1", "section": str, "generated_at": float,
     "smoke": bool, "wall_s": float, "rows": [[str, ...], ...]}

Rows keep the historical 4-column layout ``[section_tag, metric,
value, note]`` (everything stringified) — existing row consumers keep
working.  ``load_bench`` upgrades legacy files (pre-PR-10, no schema
key) in memory so ``diff`` works across the boundary.

Diff semantics: rows are keyed by ``(row[0], row[1])``; only the value
column is compared.  ``wall_s``/``generated_at``/notes are run-local
and never make two benches "different" — that's the property that
makes BENCH files machine-diffable across machines and dates.
"""

from __future__ import annotations

import json
import time
from typing import Dict, List, Optional, Tuple

BENCH_SCHEMA = "bench.v1"
_VOLATILE = ("wall_s", "generated_at", "smoke")


def bench_record(section: str, rows, wall_s: float, *, smoke: bool = False,
                 generated_at: Optional[float] = None) -> dict:
    """Build the canonical artifact dict for one benchmark section."""
    return {
        "schema": BENCH_SCHEMA,
        "section": str(section),
        "generated_at": float(time.time() if generated_at is None
                              else generated_at),
        "smoke": bool(smoke),
        "wall_s": float(wall_s),
        "rows": [[str(c) for c in row] for row in rows],
    }


def write_bench(path: str, record: dict) -> None:
    with open(path, "w") as fh:
        json.dump(record, fh, indent=1)


def load_bench(path: str) -> dict:
    """Load a BENCH json, upgrading legacy (schema-less) files."""
    with open(path) as fh:
        data = json.load(fh)
    if "schema" not in data:
        data = {
            "schema": "legacy",
            "section": data.get("section", "?"),
            "generated_at": 0.0,
            "smoke": False,
            "wall_s": float(data.get("wall_s", 0.0)),
            "rows": [[str(c) for c in row] for row in data.get("rows", [])],
        }
    return data


def _row_map(record: dict) -> Dict[Tuple[str, str], List[str]]:
    out = {}
    for row in record.get("rows", []):
        key = (row[0] if len(row) > 0 else "?",
               row[1] if len(row) > 1 else "?")
        out[key] = row
    return out


def diff_bench(a: dict, b: dict) -> dict:
    """Structured diff of two bench records (volatile keys ignored)."""
    ra, rb = _row_map(a), _row_map(b)
    added = sorted(k for k in rb if k not in ra)
    removed = sorted(k for k in ra if k not in rb)
    changed = []
    for k in sorted(set(ra) & set(rb)):
        va = ra[k][2] if len(ra[k]) > 2 else ""
        vb = rb[k][2] if len(rb[k]) > 2 else ""
        if va != vb:
            changed.append({"key": list(k), "a": va, "b": vb})
    return {
        "section_a": a.get("section"), "section_b": b.get("section"),
        "added": [list(k) for k in added],
        "removed": [list(k) for k in removed],
        "changed": changed,
        "identical": not (added or removed or changed),
    }


def format_diff(d: dict) -> str:
    lines = [f"bench-diff: {d['section_a']} vs {d['section_b']}"]
    if d["identical"]:
        lines.append("  identical (all row values match)")
        return "\n".join(lines)
    for k in d["removed"]:
        lines.append(f"  - {k[0]}/{k[1]}")
    for k in d["added"]:
        lines.append(f"  + {k[0]}/{k[1]}")
    for c in d["changed"]:
        lines.append(f"  ~ {c['key'][0]}/{c['key'][1]}: "
                     f"{c['a']} -> {c['b']}")
    return "\n".join(lines)


def summarize_bench(record: dict) -> str:
    rows = record.get("rows", [])
    lines = [f"BENCH {record.get('section')} · schema={record.get('schema')}"
             f" · smoke={record.get('smoke')} · {len(rows)} rows"
             f" · wall={record.get('wall_s', 0.0):.3g}s"]
    for row in rows:
        metric = row[1] if len(row) > 1 else "?"
        value = row[2] if len(row) > 2 else ""
        note = row[3] if len(row) > 3 else ""
        lines.append(f"  {metric:<32} {value:<16} {note}")
    return "\n".join(lines)
