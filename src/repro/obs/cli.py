"""``python -m repro.obs`` — summarize/diff bench runs, inspect traces.

    python -m repro.obs summary BENCH_serving.json [...]
    python -m repro.obs diff BENCH_a.json BENCH_b.json   # exit 1 if differ
    python -m repro.obs trace TRACE.jsonl [--perfetto out.json]
    python -m repro.obs dashboard TRACE.jsonl
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

from repro.obs.bench import (
    diff_bench,
    format_diff,
    load_bench,
    summarize_bench,
)
from repro.obs.dashboard import fleet_dashboard
from repro.obs.trace import TraceRecorder, kind_counts, perfetto_events


def _cmd_summary(ns) -> int:
    for path in ns.files:
        print(summarize_bench(load_bench(path)))
    return 0


def _cmd_diff(ns) -> int:
    d = diff_bench(load_bench(ns.a), load_bench(ns.b))
    if ns.json:
        print(json.dumps(d, indent=1))
    else:
        print(format_diff(d))
    return 0 if d["identical"] else 1


def _cmd_trace(ns) -> int:
    recs = TraceRecorder.load_jsonl(ns.file)
    runs = sorted({r.run_id for r in recs})
    print(f"trace: {len(recs)} records · runs {', '.join(runs) or '-'}")
    for k, n in kind_counts(recs).items():
        print(f"  {k:<12} {n}")
    if ns.perfetto:
        with open(ns.perfetto, "w") as fh:
            json.dump({"traceEvents": perfetto_events(recs),
                       "displayTimeUnit": "ms"}, fh)
        print(f"wrote {ns.perfetto}")
    return 0


def _cmd_dashboard(ns) -> int:
    recs = TraceRecorder.load_jsonl(ns.file)
    run_id = recs[0].run_id if recs else ""
    print(fleet_dashboard(records=recs, run_id=run_id))
    return 0


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.obs",
                                 description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    s = sub.add_parser("summary", help="summarize BENCH_*.json artifacts")
    s.add_argument("files", nargs="+")
    s.set_defaults(fn=_cmd_summary)

    d = sub.add_parser("diff", help="diff two BENCH_*.json artifacts")
    d.add_argument("a")
    d.add_argument("b")
    d.add_argument("--json", action="store_true")
    d.set_defaults(fn=_cmd_diff)

    t = sub.add_parser("trace", help="summarize a TRACE.jsonl")
    t.add_argument("file")
    t.add_argument("--perfetto", default=None,
                   help="also write a Perfetto/chrome trace json")
    t.set_defaults(fn=_cmd_trace)

    b = sub.add_parser("dashboard", help="text dashboard from a TRACE.jsonl")
    b.add_argument("file")
    b.set_defaults(fn=_cmd_dashboard)

    ns = ap.parse_args(argv)
    try:
        return ns.fn(ns)
    except BrokenPipeError:
        # stdout died mid-print (| head etc.) — exit quietly like any
        # well-behaved unix filter instead of tracebacking
        try:
            sys.stdout.close()
        except Exception:
            pass
        return 141                     # 128 + SIGPIPE, the shell idiom


if __name__ == "__main__":
    sys.exit(main())
