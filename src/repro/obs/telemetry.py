"""The Telemetry facade handed to executors, sessions, and the server.

One object bundles the three planes — counters, trace, SLO ledger —
behind a single ``enabled`` switch.  Instrumented call sites hold an
``Optional[Telemetry]`` and gate on ``telemetry_on(tel)`` at
*construction* time wherever the instrumentation would change a traced
graph, so disabled telemetry is not "cheap", it is *absent*: the
jaxpr, dispatch count, and outputs are bit-identical to an
uninstrumented build (pinned by tests/test_obs.py).
"""

from __future__ import annotations

from typing import Optional

from repro.obs.counters import CounterPanel
from repro.obs.ledger import SLOLedger
from repro.obs.trace import TraceRecorder


class Telemetry:
    def __init__(self, enabled: bool = True, *, trace_capacity: int = 65536,
                 run_id: Optional[str] = None, slo_s: Optional[float] = None):
        self.enabled = bool(enabled)
        self.counters = CounterPanel(enabled=self.enabled)
        self.trace = TraceRecorder(capacity=trace_capacity, run_id=run_id)
        self.ledger = SLOLedger(slo_s=slo_s)

    @property
    def run_id(self) -> str:
        return self.trace.run_id

    def emit(self, *args, **kwargs) -> int:
        """Trace passthrough (no-op returning -1 when disabled)."""
        if not self.enabled:
            return -1
        return self.trace.emit(*args, **kwargs)

    # ---- checkpoint plumbing ----------------------------------------------
    def state_dict(self) -> dict:
        return {"run_id": self.run_id,
                "counters": self.counters.state_dict(),
                "ledger": self.ledger.state_dict(),
                "trace_next_eid": self.trace._next_eid}

    def load_state(self, state: dict) -> None:
        state = state or {}
        self.counters.load_state(state.get("counters", {}))
        self.ledger.load_state(state.get("ledger", {}))
        # a restored run keeps its own run_id (it IS a new run) but
        # remembers the ancestry for cross-run correlation
        self.trace.emit("ckpt", "restore",
                        parent_run=state.get("run_id", ""))


def telemetry_on(tel: Optional[Telemetry]) -> bool:
    """The one construction-time gate every instrumented site uses."""
    return tel is not None and tel.enabled
