"""fleet_dashboard: a plain-text operational report.

Renders whatever subset of the telemetry plane the caller hands it —
counter totals, the per-(stream, rung) SLO ledger, and trace kind
counts — into an aligned text block suitable for terminals and bench
notes.  Pure formatting: no device work, no file IO.
"""

from __future__ import annotations

import math
from typing import Iterable, Optional

from repro.obs.ledger import SLOLedger
from repro.obs.trace import TraceRecord, kind_counts


def _rule(title: str, width: int) -> str:
    pad = max(width - len(title) - 6, 2)
    return f"== {title} {'=' * pad}"


def _fmt(x: float) -> str:
    if isinstance(x, float) and math.isnan(x):
        return "-"
    return f"{x:.4g}" if isinstance(x, float) else str(x)


def fleet_dashboard(counters: Optional[dict] = None,
                    ledger: Optional[SLOLedger] = None,
                    records: Optional[Iterable[TraceRecord]] = None,
                    run_id: str = "", width: int = 72,
                    max_streams: int = 12) -> str:
    lines = [_rule(f"FLEET TELEMETRY{' · run ' + run_id if run_id else ''}",
                   width)]

    if counters:
        lines.append(_rule("counters", width))
        kw = max(len(k) for k in counters)
        for k, v in sorted(counters.items()):
            lines.append(f"  {k:<{kw}}  {v}")

    if ledger is not None:
        rows = ledger.report()
        lines.append(_rule(f"slo ledger · {len(rows)} (stream, rung) cells",
                           width))
        if rows:
            hdr = (f"  {'sid':<10}{'rung':<12}{'n':>6}{'p50':>9}{'p95':>9}"
                   f"{'p99':>9}{'flips':>8}{'rate':>8}")
            lines.append(hdr)
            shown = rows[:max_streams]
            for r in shown:
                lines.append(
                    f"  {r['sid']:<10}{r['rung']:<12}{r['n_latency']:>6}"
                    f"{_fmt(r['p50']):>9}{_fmt(r['p95']):>9}"
                    f"{_fmt(r['p99']):>9}"
                    f"{r['flipped']:>5}/{r['compared']:<3}"
                    f"{_fmt(r['flip_rate']):>7}")
            if len(rows) > len(shown):
                lines.append(f"  ... {len(rows) - len(shown)} more cells")
        fl, tot = ledger.flip_counts()
        lines.append(f"  fleet flip rate: {fl}/{tot}"
                     f" = {_fmt(fl / tot if tot else 0.0)}"
                     + (f" · slo violations: {ledger.slo_violations()}"
                        if ledger.slo_s is not None else ""))

    if records is not None:
        records = list(records)
        lines.append(_rule(f"trace · {len(records)} records", width))
        for k, n in kind_counts(records).items():
            lines.append(f"  {k:<12} {n}")

    return "\n".join(lines)
