"""Jit-safe counters: in-graph ``tel_`` aux outputs + a host-side panel.

The telemetry plane's hot-path contract (DESIGN.md §15):

* **In-graph counters are static-shape scalar aux outputs.** A stage
  closure that wants to count something emits a ``tel_``-prefixed
  int32/uint32 scalar alongside its real outputs.  The counter is part
  of the same jit dispatch — no extra dispatch, no host callback.
* **Zero host syncs on the hot path.**  ``CounterPanel.add`` keeps the
  running total as a lazy device expression; nothing calls ``int()``
  (which would block on the device) until ``totals()`` at export time.
* **Disabled ⇒ bit-identical.**  Instrumented call sites gate aux
  emission on construction-time flags, so a disabled executor traces
  the *same jaxpr* as an uninstrumented one and returns bit-identical
  outputs.

``TELEMETRY_AUX`` is the declaration registry the static analyzer's
ObsPass (O001–O003) checks registered executor targets against: every
analyzer target must map to a declaration here, and every declared
counter must be int32/uint32.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

TEL_PREFIX = "tel_"
ALLOWED_DTYPES = ("int32", "uint32")

# Analyzer-facing declarations: target stem -> ((counter, dtype), ...).
# Stems are analyzer target names with the "[...]" parameterization
# stripped (see ``telemetry_decl``).  An empty tuple is a valid
# declaration: "this target intentionally emits no in-graph counters"
# (pure-compute kernels whose accounting happens at the session layer).
TELEMETRY_AUX: Dict[str, Tuple[Tuple[str, str], ...]] = {
    "face_auth.funnel": (
        ("windows", "int32"), ("auth", "int32"),
        ("motion_dropped", "int32"), ("cascade_dropped", "int32"),
    ),
    "vr_rig.depth": (("pairs", "int32"),),
    "vr_rig.panorama": (("views", "int32"),),
    # the offload halves emit no tel_ aux: their bytes accounting IS the
    # charged first-class ``wire_b`` output, and per-attempt counters
    # (retries, crc failures) live at the OffloadSession host layer —
    # telemetry must never ride the WirePayload uncharged (O002)
    "fa_offload.node": (),
    "fa_offload.cloud": (),
    "vr_offload.node": (),
    "vr_offload.cloud": (),
    # batch_step vmaps the instrumented funnel, inheriting its aux
    "serve.batch_step": (
        ("windows", "int32"), ("auth", "int32"),
        ("motion_dropped", "int32"), ("cascade_dropped", "int32"),
    ),
    "serve.group_step": (),
    "serve.group_step_degraded": (),
    "serve.restore_rescore": (),
    "serve.cascade_admit": (),
    "quant.nn_forward": (),
    "codec.roundtrip": (),
}


def telemetry_decl(target_name: str):
    """Resolve an analyzer target name to its TELEMETRY_AUX declaration.

    ``fa_offload[nn,8].node`` -> ``fa_offload.node``;
    ``serve.batch_step[3x4]`` -> ``serve.batch_step``;
    ``face_auth.funnel`` -> itself.  Returns None when undeclared
    (an O001 finding), a (possibly empty) tuple otherwise.
    """
    stem = target_name.split("[", 1)[0]
    if "]." in target_name:
        stem = stem + "." + target_name.rsplit("].", 1)[1]
    return TELEMETRY_AUX.get(stem)


def graph_counter(value, dtype: str = "int32"):
    """Cast ``value`` to a scalar telemetry counter inside a jitted fn.

    Only int32/uint32 are legal counter dtypes (analyzer O003): wide
    enough for per-dispatch tallies, and identical across backends so
    telemetry never perturbs dispatch caching.
    """
    if dtype not in ALLOWED_DTYPES:
        raise ValueError(
            f"telemetry counter dtype must be one of {ALLOWED_DTYPES}, "
            f"got {dtype!r}")
    import jax.numpy as jnp

    return jnp.asarray(value).astype(dtype).reshape(())


def graph_counters(_dtypes: Optional[Dict[str, str]] = None, **values):
    """Build a ``{tel_name: scalar}`` aux dict inside a jitted fn."""
    dtypes = _dtypes or {}
    return {TEL_PREFIX + name: graph_counter(v, dtypes.get(name, "int32"))
            for name, v in values.items()}


class CounterPanel:
    """Host-side accumulator for counters (device-lazy + plain ints).

    ``add`` folds device scalars into a lazy running sum (async
    dispatch, never blocks); ``bump`` adds host integers.  ``totals``
    is the only method that materializes device values.
    """

    def __init__(self, enabled: bool = True):
        self.enabled = bool(enabled)
        self._dev: Dict[str, object] = {}
        self._host: Dict[str, int] = {}

    def bump(self, name: str, n: int = 1) -> None:
        if not self.enabled:
            return
        self._host[name] = self._host.get(name, 0) + int(n)

    def add(self, name: str, value) -> None:
        """Accumulate a device scalar without a host sync."""
        if not self.enabled:
            return
        cur = self._dev.get(name)
        self._dev[name] = value if cur is None else cur + value

    def consume(self, out: dict, prefix: str = "") -> dict:
        """Pop ``tel_*`` keys out of a dispatch result dict into the
        panel (device-lazy), returning the cleaned dict."""
        if not any(k.startswith(TEL_PREFIX) for k in out):
            return out
        clean = {}
        for k, v in out.items():
            if k.startswith(TEL_PREFIX):
                if self.enabled:
                    self.add(prefix + k[len(TEL_PREFIX):], v)
            else:
                clean[k] = v
        return clean

    def totals(self) -> Dict[str, int]:
        """Materialize every counter to a plain int (the one sync
        point — call at export/report time, never per tick)."""
        out = dict(self._host)
        for name, v in self._dev.items():
            out[name] = out.get(name, 0) + int(v)
        return dict(sorted(out.items()))

    def state_dict(self) -> Dict[str, int]:
        return self.totals()

    def load_state(self, state: Dict[str, int]) -> None:
        self._dev = {}
        self._host = {str(k): int(v) for k, v in (state or {}).items()}

    def merge(self, other: "CounterPanel") -> None:
        for name, v in other.totals().items():
            self.bump(name, v)
