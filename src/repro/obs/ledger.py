"""Per-stream SLO ledger: latency percentiles AND accuracy deltas.

Closes the ROADMAP gap "per-stream accuracy SLOs alongside the latency
SLO": the degradation ladder trades accuracy implicitly; this ledger
measures it per stream, attributed to the rung that served each frame.

Accuracy is tracked as *auth flips vs the pinned full-fidelity path*:
callers observe the served auth decisions next to the reference
decisions the fused, unquantized executor would have produced for the
same frames.  The ledger never recomputes the reference itself — the
caller (benchmark, test, or server harness) owns which run is the
pinned oracle, the ledger just attributes deltas to (stream, rung).
"""

from __future__ import annotations

from collections import defaultdict
from typing import Dict, List, Optional, Tuple

import numpy as np


def rung_key(rung) -> str:
    """Canonical string for a ladder rung: ``(cut, bits)`` tuples become
    ``"nn@16"`` / ``"vj@raw"``; the on-node fallback is ``"on_node"``;
    strings pass through."""
    if rung is None:
        return "none"
    if isinstance(rung, str):
        return rung
    cut, bits = rung
    if cut is None:
        return "local"
    if cut == "on_node":
        return "on_node"
    return f"{cut}@{'raw' if bits is None else bits}"


class SLOLedger:
    """Latency + accuracy ledger keyed by (stream id, rung)."""

    def __init__(self, slo_s: Optional[float] = None):
        self.slo_s = slo_s
        self._lat: Dict[Tuple[str, str], List[float]] = defaultdict(list)
        # (sid, rung) -> [flipped_units, compared_units]
        self._flip: Dict[Tuple[str, str], List[int]] = defaultdict(
            lambda: [0, 0])

    # ---- feeding ----------------------------------------------------------
    def observe_latency(self, sid: str, rung, latency_s: float) -> None:
        self._lat[(str(sid), rung_key(rung))].append(float(latency_s))

    def observe_auth(self, sid: str, rung, auth, ref_auth) -> None:
        """Attribute served-vs-reference auth mismatches to (sid, rung).

        ``auth`` / ``ref_auth`` are arraylike decision vectors for the
        same frames (or scalars).  A dropped frame (auth None) counts
        every reference unit as flipped — degradation that sheds a
        frame costs its full accuracy.
        """
        k = (str(sid), rung_key(rung))
        ref = np.asarray(ref_auth).reshape(-1)
        if auth is None:
            self._flip[k][0] += int(ref.size)
            self._flip[k][1] += int(ref.size)
            return
        got = np.asarray(auth).reshape(-1)
        self._flip[k][0] += int(np.sum(got != ref))
        self._flip[k][1] += int(ref.size)

    # ---- querying ---------------------------------------------------------
    def _select(self, table, sid, rung):
        rk = None if rung is None else rung_key(rung)
        for (s, r), v in table.items():
            if (sid is None or s == str(sid)) and (rk is None or r == rk):
                yield (s, r), v

    def latency_percentiles(self, sid=None, rung=None,
                            qs=(50, 95, 99)) -> Dict[str, float]:
        samples: List[float] = []
        for _, v in self._select(self._lat, sid, rung):
            samples.extend(v)
        if not samples:
            return {f"p{q}": float("nan") for q in qs}
        arr = np.asarray(samples)
        return {f"p{q}": float(np.percentile(arr, q)) for q in qs}

    def flip_counts(self, sid=None, rung=None) -> Tuple[int, int]:
        flipped = total = 0
        for _, (f, n) in self._select(self._flip, sid, rung):
            flipped += f
            total += n
        return flipped, total

    def flip_rate(self, sid=None, rung=None) -> float:
        flipped, total = self.flip_counts(sid, rung)
        return flipped / total if total else 0.0

    def slo_violations(self, sid=None) -> int:
        if self.slo_s is None:
            return 0
        return sum(1 for _, v in self._select(self._lat, sid, None)
                   for x in v if x > self.slo_s)

    def keys(self) -> List[Tuple[str, str]]:
        return sorted(set(self._lat) | set(self._flip))

    def report(self) -> List[dict]:
        """One row per (sid, rung): latency percentiles + flip stats."""
        rows = []
        for sid, rk in self.keys():
            lat = self._lat.get((sid, rk), [])
            f, n = self._flip.get((sid, rk), (0, 0))
            pct = ({f"p{q}": float(np.percentile(np.asarray(lat), q))
                    for q in (50, 95, 99)} if lat
                   else {"p50": float("nan"), "p95": float("nan"),
                         "p99": float("nan")})
            rows.append({"sid": sid, "rung": rk, "n_latency": len(lat),
                         **pct, "flipped": int(f), "compared": int(n),
                         "flip_rate": (f / n if n else 0.0)})
        return rows

    # ---- persistence (rides the server checkpoint extra) -------------------
    def state_dict(self) -> dict:
        return {
            "slo_s": self.slo_s,
            "lat": {f"{s}|{r}": v for (s, r), v in self._lat.items()},
            "flip": {f"{s}|{r}": list(v) for (s, r), v in self._flip.items()},
        }

    def load_state(self, state: dict) -> None:
        state = state or {}
        self.slo_s = state.get("slo_s", self.slo_s)
        self._lat = defaultdict(list)
        self._flip = defaultdict(lambda: [0, 0])
        for k, v in state.get("lat", {}).items():
            s, r = k.split("|", 1)
            self._lat[(s, r)] = [float(x) for x in v]
        for k, v in state.get("flip", {}).items():
            s, r = k.split("|", 1)
            self._flip[(s, r)] = [int(v[0]), int(v[1])]
