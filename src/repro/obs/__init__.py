"""repro.obs — the fleet telemetry plane (DESIGN.md §15).

Three planes behind one ``Telemetry`` facade:

* counters  — jit-safe in-graph ``tel_`` aux outputs + host panel
* trace     — typed ring buffer -> JSONL -> Perfetto trace_event
* ledger    — per-(stream, rung) latency percentiles + auth-flip rates

Plus the normalized BENCH schema (``bench_record``/``diff_bench``) and
the ``fleet_dashboard`` text report.  ``python -m repro.obs`` exposes
summary/diff/trace/dashboard on the command line.
"""

from repro.obs.bench import (
    BENCH_SCHEMA,
    bench_record,
    diff_bench,
    format_diff,
    load_bench,
    summarize_bench,
    write_bench,
)
from repro.obs.counters import (
    ALLOWED_DTYPES,
    CounterPanel,
    TEL_PREFIX,
    TELEMETRY_AUX,
    graph_counter,
    graph_counters,
    telemetry_decl,
)
from repro.obs.dashboard import fleet_dashboard
from repro.obs.ledger import SLOLedger, rung_key
from repro.obs.telemetry import Telemetry, telemetry_on
from repro.obs.trace import (
    TraceRecord,
    TraceRecorder,
    kind_counts,
    perfetto_events,
)

__all__ = [
    "ALLOWED_DTYPES",
    "BENCH_SCHEMA",
    "CounterPanel",
    "SLOLedger",
    "TEL_PREFIX",
    "TELEMETRY_AUX",
    "Telemetry",
    "TraceRecord",
    "TraceRecorder",
    "bench_record",
    "diff_bench",
    "fleet_dashboard",
    "format_diff",
    "graph_counter",
    "graph_counters",
    "kind_counts",
    "load_bench",
    "perfetto_events",
    "rung_key",
    "summarize_bench",
    "telemetry_decl",
    "telemetry_on",
    "write_bench",
]
