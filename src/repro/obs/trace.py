"""Span/event trace recorder: typed ring buffer -> JSONL -> Perfetto.

Every record carries the correlation keys that let a chaos drive be
reconstructed offline from the JSONL alone (DESIGN.md §15):

* ``run_id``  — one random id per recorder, stamped on every record;
* ``eid``     — monotonically increasing event id, unique per run;
* ``tick``    — the server tick index the event belongs to (-1 if n/a);
* ``sid``     — stream id ("" if fleet-wide).

Kinds used by the instrumented stack: ``tick`` (one span per server
tick), ``dispatch`` (one span per rung-group jit dispatch), ``link``
(one event per transmit, args carry attempts/lost/crc), ``chaos``
(injected device events), ``ladder`` (rung transitions), ``failover``
(pmap<->vmap re-shard), ``shed`` (DRR shedding), ``ckpt``
(checkpoint/restore).  The set is open — the schema is the record
shape, not the kind vocabulary.
"""

from __future__ import annotations

import collections
import dataclasses
import json
import uuid
from typing import Dict, Iterable, List, Optional


@dataclasses.dataclass(frozen=True)
class TraceRecord:
    eid: int
    run_id: str
    kind: str
    name: str
    t: float            # simulated seconds since run start
    dur: float          # span duration in simulated seconds (0 = instant)
    tick: int
    sid: str
    args: dict

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: dict) -> "TraceRecord":
        return cls(eid=int(d["eid"]), run_id=str(d["run_id"]),
                   kind=str(d["kind"]), name=str(d["name"]),
                   t=float(d["t"]), dur=float(d["dur"]),
                   tick=int(d["tick"]), sid=str(d["sid"]),
                   args=dict(d.get("args", {})))


class TraceRecorder:
    """Bounded ring buffer of TraceRecords.

    Appends are O(1) host work (no device interaction); the ring keeps
    the newest ``capacity`` records and counts what it overwrote so an
    export can state its own truncation instead of silently lying.
    """

    def __init__(self, capacity: int = 65536, run_id: Optional[str] = None):
        self.run_id = run_id or uuid.uuid4().hex[:12]
        self._buf: collections.deque = collections.deque(maxlen=int(capacity))
        self._next_eid = 0
        self.dropped = 0

    def __len__(self) -> int:
        return len(self._buf)

    def emit(self, kind: str, name: str, *, t: float = 0.0, dur: float = 0.0,
             tick: int = -1, sid: str = "", **args) -> int:
        rec = TraceRecord(eid=self._next_eid, run_id=self.run_id,
                          kind=str(kind), name=str(name), t=float(t),
                          dur=float(dur), tick=int(tick), sid=str(sid),
                          args=args)
        self._next_eid += 1
        if self._buf.maxlen and len(self._buf) == self._buf.maxlen:
            self.dropped += 1
        self._buf.append(rec)
        return rec.eid

    def records(self, kind: Optional[str] = None) -> List[TraceRecord]:
        if kind is None:
            return list(self._buf)
        return [r for r in self._buf if r.kind == kind]

    # ---- JSONL ------------------------------------------------------------
    def to_jsonl(self, path: str) -> int:
        """One JSON object per line; returns the number written."""
        recs = self.records()
        with open(path, "w") as fh:
            for r in recs:
                fh.write(json.dumps(r.to_json(), sort_keys=True) + "\n")
        return len(recs)

    @staticmethod
    def load_jsonl(path: str) -> List[TraceRecord]:
        out = []
        with open(path) as fh:
            for line in fh:
                line = line.strip()
                if line:
                    out.append(TraceRecord.from_json(json.loads(line)))
        return out

    # ---- Perfetto / chrome://tracing --------------------------------------
    def export_perfetto(self, path: str) -> int:
        events = perfetto_events(self.records())
        with open(path, "w") as fh:
            json.dump({"traceEvents": events,
                       "displayTimeUnit": "ms",
                       "otherData": {"run_id": self.run_id,
                                     "dropped": self.dropped}}, fh)
        return len(events)


def perfetto_events(records: Iterable[TraceRecord]) -> List[dict]:
    """Convert TraceRecords to Chrome ``trace_event`` dicts.

    Spans (dur > 0) become complete events (``ph: "X"``); instants
    become ``ph: "i"``.  Simulated seconds map to microseconds; each
    kind gets its own tid lane so tick/dispatch/link/chaos stack
    visually, all under one pid per run.
    """
    lanes: Dict[str, int] = {}
    out = []
    for r in records:
        tid = lanes.setdefault(r.kind, len(lanes) + 1)
        ev = {"name": r.name, "cat": r.kind, "pid": 1, "tid": tid,
              "ts": r.t * 1e6,
              "args": {**r.args, "eid": r.eid, "tick": r.tick,
                       "sid": r.sid, "run_id": r.run_id}}
        if r.dur > 0:
            ev["ph"] = "X"
            ev["dur"] = r.dur * 1e6
        else:
            ev["ph"] = "i"
            ev["s"] = "t"
        out.append(ev)
    return out


def kind_counts(records: Iterable[TraceRecord]) -> Dict[str, int]:
    out: Dict[str, int] = {}
    for r in records:
        out[r.kind] = out.get(r.kind, 0) + 1
    return dict(sorted(out.items()))
