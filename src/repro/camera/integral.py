"""Integral image (summed-area table) — reference implementation.

The paper's key VJ-accelerator trick (§III-B) is computing the integral
image *streaming* with a two-row buffer (<1 kB) instead of materializing a
57 kB frame.  The TPU adaptation of that idea is a blocked two-pass
cumulative sum in VMEM with row/column carries (kernels/integral_image);
this module is the pure-jnp oracle plus the window-sum helpers the cascade
uses.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def integral_image(img: jax.Array) -> jax.Array:
    """(..., h, w) -> summed-area table, zero-padded at top/left.

    ii[..., i, j] = sum(img[..., :i, :j]); shape (..., h+1, w+1) so window
    sums need no boundary special-cases (the hardware unit does the same by
    seeding its row buffer with zeros).
    """
    ii = jnp.cumsum(jnp.cumsum(img, axis=-2), axis=-1)
    ii = jnp.pad(ii, [(0, 0)] * (img.ndim - 2) + [(1, 0), (1, 0)])
    return ii


def window_sum(ii: jax.Array, y0, x0, h, w) -> jax.Array:
    """Rectangle sum via 4 corner lookups.  y0/x0 may be arrays (broadcast)."""
    return (ii[..., y0 + h, x0 + w] - ii[..., y0, x0 + w]
            - ii[..., y0 + h, x0] + ii[..., y0, x0])


def frame_integral(img: jax.Array, *, use_pallas: bool = False,
                   interpret: bool = False) -> jax.Array:
    """Frame-level integral, (..., h, w) -> (..., h+1, w+1).

    With ``use_pallas`` the blocked streaming Pallas kernel
    (kernels/integral_image) produces the table — the detector's frame is
    then touched exactly once, on-chip; otherwise the jnp cumsum oracle.
    Both return identical values (pinned in tests/test_kernels.py).
    """
    if use_pallas:
        from repro.kernels.integral_image.ops import integral_image as _k
        return _k(img, interpret=interpret)
    return integral_image(img)


def streaming_integral_rows(img: jax.Array) -> jax.Array:
    """Row-at-a-time formulation mirroring the paper's hardware unit:
    carry = last completed integral row; each new pixel row is prefix-summed
    and added.  Semantically identical to integral_image (tested); exists to
    document/validate the streaming dataflow the Pallas kernel blocks up.
    """
    h, w = img.shape[-2:]

    def step(last_row, pixel_row):
        row = jnp.cumsum(pixel_row, axis=-1) + last_row
        return row, row

    init = jnp.zeros(img.shape[:-2] + (w,), img.dtype)
    _, rows = jax.lax.scan(step, init, jnp.moveaxis(img, -2, 0))
    ii = jnp.moveaxis(rows, 0, -2)
    return jnp.pad(ii, [(0, 0)] * (img.ndim - 2) + [(1, 0), (1, 0)])
