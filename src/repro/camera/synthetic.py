"""Seeded synthetic workloads for the two camera case studies.

LFW and the paper's collected security/wearable videos are not available
offline (DESIGN.md §7-2), so we generate controlled stand-ins:

* :func:`face_patch` — parametric 20x20 "faces": eyes/mouth/nose blobs with
  an identity embedding (per-identity geometry offsets), pose jitter,
  illumination; non-faces are textured clutter with matched statistics.
  Enough structure that a 400-8-1 MLP separates identities at paper-like
  error rates and Haar cascades fire on face geometry.
* :func:`security_video` — 176x144 @1 FPS scenes with a static background,
  occasional walkers (motion), and faces present in a controlled fraction
  of frames: reproduces the paper's funnel statistics (62 frames -> 12
  motion-positive -> 40 windows -> NN).
* :func:`stereo_pair` — VR rig stand-in: textured scene with a ground-truth
  disparity field and two shifted views, for BSSA quality (MS-SSIM vs grid
  size, Fig. 11b).
"""

from __future__ import annotations

import numpy as np


def _rng(seed):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------------------
# Faces
# ---------------------------------------------------------------------------


def face_patch(rng, identity_vec, size: int = 20, jitter: float = 1.0,
               light: float = 0.0) -> np.ndarray:
    """Render one face-ish patch in [0,1].  identity_vec: (8,) in [-1,1]."""
    y, x = np.mgrid[0:size, 0:size] / (size - 1)
    iv = identity_vec

    def blob(cy, cx, sy, sx, amp):
        return amp * np.exp(-(((y - cy) / sy) ** 2 + ((x - cx) / sx) ** 2))

    jy, jx = rng.normal(0, jitter / size, 2)
    face = np.zeros((size, size))
    # head disc
    face += blob(0.5 + jy, 0.5 + jx, 0.42 + 0.05 * iv[0], 0.34 + 0.05 * iv[1], 0.8)
    # eyes (dark)
    eye_dy = 0.36 + 0.04 * iv[2]
    eye_dx = 0.20 + 0.03 * iv[3]
    face -= blob(eye_dy + jy, 0.5 - eye_dx + jx, 0.06, 0.07 + 0.02 * iv[4], 0.55)
    face -= blob(eye_dy + jy, 0.5 + eye_dx + jx, 0.06, 0.07 + 0.02 * iv[4], 0.55)
    # nose ridge (light)
    face += blob(0.55 + jy, 0.5 + jx, 0.16 + 0.03 * iv[5], 0.05, 0.25)
    # mouth (dark)
    face -= blob(0.76 + 0.03 * iv[6] + jy, 0.5 + jx, 0.05, 0.16 + 0.04 * iv[7], 0.45)
    face = face + light + rng.normal(0, 0.04, face.shape)
    return np.clip(face + 0.1, 0, 1)


def nonface_patch(rng, size: int = 20) -> np.ndarray:
    """Clutter with face-like first/second moments but no face geometry."""
    kind = rng.integers(0, 3)
    y, x = np.mgrid[0:size, 0:size] / (size - 1)
    if kind == 0:   # oriented stripes
        th = rng.uniform(0, np.pi)
        f = rng.uniform(2, 6)
        img = 0.5 + 0.3 * np.sin(2 * np.pi * f * (x * np.cos(th) + y * np.sin(th)))
    elif kind == 1:  # random blobs
        img = np.zeros((size, size))
        for _ in range(rng.integers(2, 6)):
            cy, cx = rng.uniform(0.1, 0.9, 2)
            s = rng.uniform(0.05, 0.3)
            img += rng.uniform(-0.5, 0.7) * np.exp(-(((y - cy) / s) ** 2 + ((x - cx) / s) ** 2))
        img = 0.5 + img
    else:            # smooth gradient
        g = rng.uniform(-0.5, 0.5, 2)
        img = 0.5 + g[0] * (x - 0.5) + g[1] * (y - 0.5)
    img = img + rng.normal(0, 0.05, img.shape)
    return np.clip(img, 0, 1)


def face_dataset(n_per_class: int = 600, n_identities: int = 24, size: int = 20,
                 target_identity: int = 0, seed: int = 0):
    """Face-authentication dataset: positives = target identity, negatives =
    other identities + clutter (the paper's FA task: match one reference).

    Returns (X (n, size*size) f32, y (n,) {0,1}, meta dict)."""
    rng = _rng(seed)
    ids = rng.uniform(-1, 1, (n_identities, 8))
    X, y = [], []
    for _ in range(n_per_class):
        X.append(face_patch(rng, ids[target_identity],
                            size=size,
                            jitter=rng.uniform(0.5, 1.6),
                            light=rng.uniform(-0.15, 0.15)))
        y.append(1)
    n_other = n_per_class // 2
    for _ in range(n_other):
        other = rng.integers(1, n_identities)
        X.append(face_patch(rng, ids[other], size=size,
                            jitter=rng.uniform(0.5, 1.6),
                            light=rng.uniform(-0.15, 0.15)))
        y.append(0)
    for _ in range(n_per_class - n_other):
        X.append(nonface_patch(rng, size=size))
        y.append(0)
    X = np.stack(X).reshape(len(X), -1).astype(np.float32)
    y = np.array(y, np.int32)
    perm = rng.permutation(len(X))
    return X[perm], y[perm], {"identities": ids, "target": target_identity}


# ---------------------------------------------------------------------------
# Security video (WISPCam workload, 176x144 @ 1 FPS)
# ---------------------------------------------------------------------------


def security_video(n_frames: int = 62, h: int = 144, w: int = 176,
                   motion_frames: int = 12, faces_in_motion: float = 0.66,
                   seed: int = 1):
    """Paper §III-D workload statistics: 62 frames, 12 pass motion detection,
    VJ then passes ~40 windows of which ~10% are false positives.

    Returns (frames (n, h, w) f32, truth dicts per frame)."""
    rng = _rng(seed)
    # frame 0 is always the static reference, so at most n_frames - 1 frames
    # can carry motion; clamp instead of letting rng.choice raise.
    motion_frames = max(0, min(motion_frames, n_frames - 1))
    yb, xb = np.mgrid[0:h, 0:w]
    background = (
        0.45
        + 0.1 * np.sin(xb / 17.0)
        + 0.08 * np.cos(yb / 23.0)
        + 0.05 * rng.standard_normal((h, w))
    )
    # a static "poster" face in the scene (the paper's FP source)
    poster = face_patch(rng, rng.uniform(-1, 1, 8), size=20)
    background[20:40, 140:160] = 0.7 * poster + 0.3 * background[20:40, 140:160]
    background = np.clip(background, 0, 1)

    ids = rng.uniform(-1, 1, (4, 8))
    frames = []
    truth = []
    move_set = set(rng.choice(np.arange(1, n_frames), motion_frames, replace=False))
    for t in range(n_frames):
        f = background.copy()
        info = {"moving": t in move_set, "faces": []}
        if t in move_set:
            # a walker: vertical bar + optional face at head
            px = int(rng.uniform(10, w - 30))
            py = int(rng.uniform(30, h - 60))
            f[py:py + 46, px:px + 14] *= 0.55
            if rng.uniform() < faces_in_motion:
                fp = face_patch(rng, ids[rng.integers(0, len(ids))], size=20,
                                jitter=rng.uniform(0.5, 1.2))
                f[py - 20:py, px - 3:px + 17] = fp
                info["faces"].append((py - 20, px - 3, 20))
        f = np.clip(f + rng.normal(0, 0.01, f.shape), 0, 1)
        frames.append(f.astype(np.float32))
        truth.append(info)
    return np.stack(frames), truth


# ---------------------------------------------------------------------------
# Stereo pairs (VR rig)
# ---------------------------------------------------------------------------


def stereo_pair(h: int = 256, w: int = 320, max_disp: int = 12, seed: int = 2):
    """A textured scene + piecewise-smooth disparity; right view = left
    shifted per-pixel by the disparity (with occlusion fill).

    Returns (left, right, disparity) float32 in [0,1] / pixels."""
    rng = _rng(seed)
    y, x = np.mgrid[0:h, 0:w]
    # texture: multi-scale noise
    tex = np.zeros((h, w))
    for s_ in (4, 8, 16, 32):
        n = rng.standard_normal((h // s_ + 2, w // s_ + 2))
        up = np.kron(n, np.ones((s_, s_)))[:h, :w]
        tex += up / np.sqrt(s_)
    tex = (tex - tex.min()) / (np.ptp(tex) + 1e-9)

    # disparity: background plane + 2 foreground boxes (depth edges)
    disp = 2.0 + 2.0 * (y / h)
    for _ in range(2):
        cy, cx = rng.integers(h // 4, 3 * h // 4), rng.integers(w // 4, 3 * w // 4)
        hh, ww = rng.integers(h // 8, h // 4), rng.integers(w // 8, w // 4)
        d = rng.uniform(max_disp * 0.6, max_disp)
        disp[max(cy - hh, 0):cy + hh, max(cx - ww, 0):cx + ww] = d
    left = tex
    right = np.zeros_like(left)
    xs = np.clip(x - disp.astype(int), 0, w - 1)
    right = left[y, xs]
    return left.astype(np.float32), right.astype(np.float32), disp.astype(np.float32)
