"""Typed wire payloads for offload cut points (DESIGN.md §10).

A :class:`WirePayload` is everything that crosses the offload link when a
pipeline is cut: the codec-packed (or raw) tensors, the integer/boolean
sideband (indices, counts, drop counters), and two byte accountings:

* ``wire_bytes`` — the **measured** bytes a real variable-length transmit
  would put on the air: only *valid* (non-capacity-padding) payload
  elements are charged, at the codec bit-width plus one f32 scale per
  block; index/count sideband at 4 B per valid entry; booleans at 1 bit.
  Computed in-graph by the node-side jit region, so it is data-dependent
  (a quiet scene after the motion cut charges almost nothing) while every
  shape stays static.
* ``capacity_bytes`` — the static padded size of the arrays actually held
  in memory (the §9 capacity-padding contract's worst case).  The gap
  between the two is exactly what compaction buys on the wire.

Payload arrays stay capacity-padded device arrays; the node halves zero
every invalid slot before encoding, so the codec packs padding as exact
zeros (a zero quantizes to zero, and a padding slot can never inflate a
block scale shared with valid data) and the padding is never charged.
"""

from __future__ import annotations

import dataclasses

# Session-layer sideband the resilience runtime staples onto EVERY wire
# payload (resilience.OffloadSession): a monotone sequence number, an
# integrity checksum over the payload bytes, and the retransmit-attempt
# counter.  Declared here — not in resilience.py — so both offload
# executor families and the analysis C006 pass share ONE spec without an
# import cycle.  Each field is charged at 4 B per transmission attempt;
# dtype discipline (uint32/int32, nothing wider, nothing float) is
# enforced by repro.analysis pass C006.
SESSION_SIDEBAND = (("seq", "uint32"), ("crc", "uint32"),
                    ("attempt", "int32"))
SESSION_SIDEBAND_NAMES = tuple(n for n, _ in SESSION_SIDEBAND)
SESSION_SIDEBAND_BYTES = 4.0 * len(SESSION_SIDEBAND)


def static_array_bytes(a) -> float:
    """Static wire size of one array: bools at 1 bit, else itemsize.

    Reads only shape/dtype metadata — never materializes device arrays
    on the host (this runs inside the controller's timed calibration)."""
    import numpy as np

    dtype = np.dtype(a.dtype)
    size = int(np.prod(a.shape)) if a.shape else 1
    if dtype == np.bool_:
        return size / 8.0
    return float(size * dtype.itemsize)


@dataclasses.dataclass(frozen=True)
class PayloadSchema:
    """Declared wire contract for one cut (repro.analysis pass 4).

    Every array a node half may put on the wire must be declared here:
    ``codec`` fields go through the wire codec (f32 raw at ``bits=None``,
    packed+scales otherwise) and are charged per valid element at codec
    width; ``i32`` sideband fields are charged at 4 B per valid entry;
    ``bools`` ship bit-packed at 1/8 B.  The cut-soundness pass
    cross-checks the declared fields against the avals the node half
    actually emits — an undeclared array is *uncharged padding on the
    wire* and fails analysis.

    ``session`` declares the session-layer sideband (seq / checksum /
    attempt counter) the resilience runtime adds per transmission —
    host-side framing, never part of the node jit's output, but on the
    wire and charged all the same.  Pass C006 checks the declaration
    matches :data:`SESSION_SIDEBAND` name-for-name with uint32/int32
    dtype discipline.
    """

    codec: tuple = ()
    i32: tuple = ()
    bools: tuple = ()
    session: tuple = ()

    def declared(self, bits) -> set:
        """Full expected key set of the node-half ``arrays`` dict."""
        out = set(self.i32) | set(self.bools) | set(self.codec)
        if bits is not None:
            out |= {f + "_scales" for f in self.codec}
        return out


@dataclasses.dataclass
class WirePayload:
    """One cut's wire payload (node-side jit output).

    ``arrays`` holds every on-wire tensor (packed codec bytes + scales
    under ``<field>``/``<field>_scales``, plus sideband).  ``meta`` holds
    the static decode contract: per-codec-field original shape, the codec
    bit-width/block, and the source batch size.
    """

    cut: str
    bits: int | None              # codec width; None = raw f32 passthrough
    arrays: dict
    meta: dict
    wire_b: object                # () f32 — measured (valid-element) bytes

    def nbytes(self) -> float:
        """Measured wire bytes for this batch (valid elements only)."""
        return float(self.wire_b)

    def capacity_bytes(self) -> float:
        """Static padded wire size (every slot shipped, none elided)."""
        return sum(static_array_bytes(a) for a in self.arrays.values())
