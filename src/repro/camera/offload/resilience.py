"""Fault-tolerant offload sessions (DESIGN.md §12).

PR 5's split executors assume a lossless link and uninterrupted power —
every BENCH_offload number is a best case.  This module wraps them in a
session layer that survives the two real failure modes of the paper's
regimes and *charges what survival costs*:

* :class:`OffloadSession` — per-payload sequence numbers + integrity
  checksums in the session sideband (``payloads.SESSION_SIDEBAND``),
  sender timeout with bounded retry under exponential backoff.  Every
  retransmission is charged real link bytes and energy, and the full
  per-attempt byte trace re-enters ``simulate_shared_link`` so retries
  congest neighboring streams (:func:`fleet_link_report`).
* **Stage-boundary commit points** — when a harvested-energy brownout
  (``link.BrownoutModel`` via ``link.FaultInjector``) kills the node
  mid-funnel, the staged node runner restores the last committed stage
  state from a ``ckpt/checkpoint.py`` checkpoint and resumes the funnel
  there instead of recomputing from capture.
* :class:`DegradationLadder` — a sliding window of measured loss /
  latency drives graceful degradation: drop wire-codec bits (16→8→4),
  retreat to the measured-cheapest cut, finally fall back to all-on-node
  (ship only the decision).  Built from live calibration data by
  ``CutController.degradation_ladder``.

The zero-fault path is pinned bit-exact to PR 5: with no injector and no
ladder motion, ``send`` is exactly ``encode`` + ``decode_run`` of the
underlying split executor at every cut x bits (tests/test_resilience.py).
"""

from __future__ import annotations

import collections
import dataclasses
import os
import zlib

import numpy as np

from repro.camera.offload.link import BACKSCATTER, FaultInjector, LinkProfile
from repro.camera.offload.payloads import (
    SESSION_SIDEBAND,
    SESSION_SIDEBAND_BYTES,
    WirePayload,
)
from repro.obs.ledger import rung_key as _ledger_rung_key
from repro.obs.telemetry import telemetry_on

# wire bytes of an all-on-node delivery: the paper's "ship the decision"
# terminal rung — per-frame auth bits plus one i32 count
_DECISION_BITS_PER_UNIT = 1.0 / 8.0
_I32_B = 4.0


def payload_checksum(payload: WirePayload) -> int:
    """Deterministic uint32 CRC over every on-wire array (key-ordered).

    The integrity word the session ships in its sideband; the receiver
    recomputes it before ``decode_run`` and NACKs on mismatch (modeled by
    the injector's ``corrupt`` outcome — detected here, not by sender
    timeout).
    """
    crc = 0
    for k in sorted(payload.arrays):
        a = np.asarray(payload.arrays[k])
        crc = zlib.crc32(k.encode(), crc)
        crc = zlib.crc32(np.ascontiguousarray(a).tobytes(), crc)
    return int(crc & 0xFFFFFFFF)


def session_sideband(seq: int, crc: int, attempt: int) -> dict:
    """The session-layer sideband, dtype-disciplined per C006."""
    return {"seq": np.uint32(seq), "crc": np.uint32(crc),
            "attempt": np.int32(attempt)}


# ---------------------------------------------------------------------------
# staged node execution with commit points
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Stage:
    """One node-side funnel stage: ``fn(state) -> dict`` of new entries."""

    name: str
    fn: object


class StagedNodeRunner:
    """Stage-granular mirror of a split executor's node half.

    Composes the SAME traceable stage closures the fused node jit runs
    (``FunnelStages`` / ``VRRigExecutor``'s pair_depth + pano_fn), but one
    jit per stage with a commit point at every boundary — the granularity
    a brownout-recovering node actually needs.  ``encode(state)`` packs
    the cut payload from the final state exactly as the fused
    ``_node_fn`` does (same codec, same byte charging).
    """

    def __init__(self, stages, encode, capture_key: str):
        self.stages = tuple(stages)
        self.encode = encode
        self.capture_key = capture_key


def _fa_staged(ex) -> StagedNodeRunner:
    """Stage plan for :class:`FaceAuthOffloadExecutor` at its cut."""
    import jax
    import jax.numpy as jnp

    st, cdc, cut = ex._st, ex.codec, ex.cut
    det_c, pos_c, nn_c = st.split_consts(ex._consts)
    h, w = ex._h, ex._w
    _I32, _BOOL = 4.0, 1.0 / 8.0

    motion_j = jax.jit(st.motion)
    detect_j = jax.jit(st.detect)
    gather_j = jax.jit(st.gather)
    nn_j = jax.jit(st.nn)

    def s_motion(s):
        mframes, fidx, fvalid, motion, mdrop = motion_j(s["frames"])
        return dict(mframes=mframes, fidx=fidx, fvalid=fvalid,
                    motion=motion, motion_dropped=mdrop)

    def s_detect(s):
        dmask, n_win, casc_drop = detect_j(s["mframes"], s["fvalid"], det_c)
        return dict(dmask=dmask, n_win=n_win, casc_drop=casc_drop)

    def s_gather(s):
        patches, wsel, wvalid, wdrop = gather_j(
            s["mframes"], s["dmask"], s["n_win"], pos_c)
        return dict(patches=patches, wsel=wsel, wvalid=wvalid,
                    win_dropped=wdrop)

    def s_nn(s):
        scores, auth, n_auth = nn_j(s["patches"], s["wvalid"], nn_c)
        return dict(scores=scores, auth=auth, n_auth=n_auth)

    stages = []
    if cut != "sensor":
        stages.append(Stage("motion", s_motion))
    if cut in ("vj", "nn"):
        stages.append(Stage("detect", s_detect))
        stages.append(Stage("gather", s_gather))
    if cut == "nn":
        stages.append(Stage("nn", s_nn))

    def encode(s):
        # mirrors FaceAuthOffloadExecutor._node_fn field for field — the
        # same codec instance, the same zero-padding-before-encode, the
        # same valid-element byte charging
        arrays: dict = {}
        if cut == "sensor":
            B = s["frames"].shape[0]
            cdc.enc(arrays, "frames", s["frames"].astype(jnp.float32))
            wire_b = jnp.asarray(cdc.static_bytes(B * h * w), jnp.float32)
            return arrays, wire_b
        B = s["motion"].shape[0]
        n_valid_f = jnp.sum(s["fvalid"]).astype(jnp.float32)
        side = _I32 * n_valid_f + _BOOL * B + _I32
        if cut == "motion":
            cdc.enc(arrays, "mframes",
                    jnp.where(s["fvalid"][:, None, None], s["mframes"], 0.0))
            arrays.update(fidx=s["fidx"].astype(jnp.int32),
                          motion=s["motion"],
                          motion_dropped=s["motion_dropped"])
            return arrays, cdc.dyn_bytes(n_valid_f * (h * w)) + side
        n_valid_w = jnp.sum(s["wvalid"]).astype(jnp.float32)
        side = side + _I32 * 3 * n_valid_f
        common = dict(wsel=s["wsel"].astype(jnp.int32), n_win=s["n_win"],
                      win_dropped=s["win_dropped"],
                      casc_drop=s["casc_drop"],
                      fidx=s["fidx"].astype(jnp.int32), motion=s["motion"],
                      motion_dropped=s["motion_dropped"])
        if cut == "vj":
            patches = s["patches"]
            cdc.enc(arrays, "patches",
                    jnp.where(s["wvalid"][:, :, None, None], patches, 0.0))
            arrays.update(common)
            wire_b = (cdc.dyn_bytes(n_valid_w * patches.shape[-1]
                                    * patches.shape[-2])
                      + _I32 * n_valid_w + side)
            return arrays, wire_b
        cdc.enc(arrays, "scores", s["scores"])
        arrays.update(common, auth=s["auth"])
        wire_b = (cdc.dyn_bytes(n_valid_w) + _BOOL * n_valid_w
                  + _I32 * n_valid_w + side)
        return arrays, wire_b

    return StagedNodeRunner(stages, encode, capture_key="frames")


def _vr_staged(ex) -> StagedNodeRunner:
    """Stage plan for :class:`VROffloadExecutor` at its cut."""
    import jax
    import jax.numpy as jnp

    cdc, cut = ex.codec, ex.cut
    depth_j = jax.jit(ex._depth)
    pano_j = jax.jit(ex._pano)

    stages = []
    if cut in ("depth", "stitch"):
        stages.append(Stage(
            "depth", lambda s: dict(depths=depth_j(s["lefts"], s["rights"]))))
    if cut == "stitch":
        def s_pano(s):
            lp, rp = pano_j(s["lefts"], s["rights"], s["depths"])
            return dict(left_pano=lp, right_pano=rp)
        stages.append(Stage("pano", s_pano))

    def encode(s):
        arrays: dict = {}
        P, h, w = s["lefts"].shape
        if cut == "capture":
            cdc.enc(arrays, "lefts", s["lefts"].astype(jnp.float32))
            cdc.enc(arrays, "rights", s["rights"].astype(jnp.float32))
            wire_b = 2 * cdc.static_bytes(P * h * w)
        elif cut == "depth":
            cdc.enc(arrays, "depths", s["depths"])
            cdc.enc(arrays, "lefts", s["lefts"].astype(jnp.float32))
            cdc.enc(arrays, "rights", s["rights"].astype(jnp.float32))
            wire_b = 3 * cdc.static_bytes(P * h * w)
        else:
            cdc.enc(arrays, "left_pano", s["left_pano"])
            cdc.enc(arrays, "right_pano", s["right_pano"])
            wire_b = (cdc.static_bytes(int(np.prod(s["left_pano"].shape)))
                      + cdc.static_bytes(int(np.prod(s["right_pano"].shape))))
        return arrays, jnp.asarray(wire_b, jnp.float32)

    return StagedNodeRunner(stages, encode, capture_key="lefts")


def staged_runner_for(ex) -> StagedNodeRunner:
    from repro.camera.offload.executors import (FaceAuthOffloadExecutor,
                                                VROffloadExecutor)

    if isinstance(ex, FaceAuthOffloadExecutor):
        return _fa_staged(ex)
    if isinstance(ex, VROffloadExecutor):
        return _vr_staged(ex)
    raise TypeError(
        f"no staged node plan for {type(ex).__name__}; OffloadSession "
        "brownout recovery supports the registered offload executor "
        "families only")


def _stage_names(ex) -> tuple:
    """Node-side stage names at ``ex``'s cut (cost model; no jit built)."""
    from repro.camera.offload.executors import FaceAuthOffloadExecutor

    if isinstance(ex, FaceAuthOffloadExecutor):
        names = {"sensor": (), "motion": ("motion",),
                 "vj": ("motion", "detect", "gather"),
                 "nn": ("motion", "detect", "gather", "nn")}[ex.cut]
    else:
        names = {"capture": (), "depth": ("depth",),
                 "stitch": ("depth", "pano")}[ex.cut]
    return names + ("encode",)


# ---------------------------------------------------------------------------
# degradation ladder
# ---------------------------------------------------------------------------


ON_NODE = ("on_node", None)


class DegradationLadder:
    """Sliding-window policy over the session's measured loss/latency.

    ``rungs`` is an ordered list of ``(cut, bits)`` configurations, most
    capable first; the terminal rung may be :data:`ON_NODE` (compute the
    whole funnel on the node, ship only the decision).  The ladder steps
    DOWN one rung when the observation window shows sustained faults —
    a delivery failure (retries exhausted), a windowed retransmit
    fraction above ``max_retry_frac``, or (when ``deadline_s`` is set)
    most deliveries blowing the deadline — and steps back UP after
    ``recover_after`` consecutive clean first-attempt deliveries.  The
    asymmetry (fast down, slow up) is deliberate hysteresis: a brownout
    costs a frame, flapping costs the whole window.

    A ladder that never observes a fault never moves — the zero-fault
    path stays pinned to rung 0 (bit-exactness contract).
    """

    def __init__(self, rungs, *, window: int = 16,
                 max_retry_frac: float = 0.3, deadline_s: float | None = None,
                 recover_after: int = 24):
        rungs = [tuple(r) for r in rungs]
        if not rungs:
            raise ValueError("DegradationLadder needs at least one rung")
        if len(set(rungs)) != len(rungs):
            raise ValueError(f"duplicate ladder rungs: {rungs}")
        self.rungs = rungs
        self.window = int(window)
        self.max_retry_frac = float(max_retry_frac)
        self.deadline_s = deadline_s
        self.recover_after = int(recover_after)
        self.level = 0
        self.transitions: list = []       # (seq, old_level, new_level)
        self._hist: collections.deque = collections.deque(maxlen=window)
        self._clean = 0

    @property
    def rung(self) -> tuple:
        return self.rungs[self.level]

    def _move(self, seq, new_level):
        new_level = max(0, min(new_level, len(self.rungs) - 1))
        if new_level != self.level:
            self.transitions.append((seq, self.level, new_level))
            self.level = new_level
            self._hist.clear()
            self._clean = 0

    def observe(self, record: "DeliveryRecord"):
        """Feed one delivery record; may move the ladder for the NEXT send."""
        self._hist.append(record)
        if not record.delivered or record.fallback:
            self._move(record.seq, self.level + 1)
            return
        attempts = sum(r.attempts for r in self._hist)
        retrans = sum(r.attempts - 1 for r in self._hist)
        retry_frac = retrans / attempts if attempts else 0.0
        late = (sum(1 for r in self._hist
                    if self.deadline_s is not None
                    and r.latency_s > self.deadline_s)
                / max(len(self._hist), 1))
        if len(self._hist) >= self.window and (
                retry_frac > self.max_retry_frac or late > 0.5):
            self._move(record.seq, self.level + 1)
            return
        if record.attempts == 1:
            self._clean += 1
            if self._clean >= self.recover_after and self.level > 0:
                self._move(record.seq, self.level - 1)
        else:
            self._clean = 0


# ---------------------------------------------------------------------------
# the session
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class DeliveryRecord:
    """Everything one payload's delivery cost (simulated time/bytes/energy)."""

    seq: int
    cut: str
    bits: int | None
    delivered: bool
    fallback: bool               # delivered via the all-on-node rung
    attempts: int                # transmissions put on the air
    lost: int                    # sender-timeout losses
    corrupt: int                 # receiver checksum failures (NACKed)
    payload_bytes: float         # one transmission's bytes (incl. sideband)
    bytes_on_air: float          # total across every attempt
    compute_s: float             # node-side stage time (simulated)
    latency_s: float             # capture -> delivery, incl. backoff/recovery
    energy_j: float              # node compute + every tx attempt
    brownouts: int               # node power losses during compute
    restores: int                # checkpoint restores (commit-point resumes)
    recovery_s: float            # time spent dark + restoring

    @property
    def retransmit_overhead(self) -> float:
        """Extra on-air bytes over a single clean transmission (fraction)."""
        return (self.bytes_on_air / self.payload_bytes - 1.0
                if self.payload_bytes else 0.0)


class OffloadSession:
    """Reliable delivery wrapper around one split executor.

    ``make_executor(cut, bits)`` builds the underlying PR-5 split
    executor; a fixed-configuration session passes ``executor=`` instead.
    ``send(*inputs)`` runs the node half (staged, with commit points,
    when a brownout model is present), frames the payload with the
    session sideband (seq/crc/attempt — ``payloads.SESSION_SIDEBAND``),
    transmits it through the injector's fault process with bounded
    exponential-backoff retry, and runs the cloud half on delivery.
    Returns ``(result, DeliveryRecord)``; ``result`` is None only when
    retries exhaust with no on-node fallback (the receiver sees the gap
    via the sequence numbers).

    Every attempt is charged real bytes and energy, and
    :meth:`attempt_trace` exposes the per-send on-air byte totals for
    re-entry into ``simulate_shared_link`` (see :func:`fleet_link_report`)
    so retries congest neighboring streams.

    With ``injector=None`` (or a fully-disabled injector) and a ladder
    that never moves, outputs are bit-exact with the wrapped executor —
    the PR-5 pinning contract.

    ``telemetry=`` (a :class:`repro.obs.Telemetry`) makes the session a
    §15 trace/counter source: every send is charged to per-attempt
    counters (``offload.attempts`` / ``offload.retries`` /
    ``offload.crc_fail`` / ``offload.bytes_on_air`` ...), emits one
    ``link`` span, and feeds the per-stream SLO ledger under ``sid=``.
    Telemetry observes the DeliveryRecord after the fact — it never
    perturbs the fault process, the clock, or the payload bytes.
    """

    def __init__(self, executor=None, *, make_executor=None, cut=None,
                 bits=None, link: LinkProfile = BACKSCATTER,
                 injector: FaultInjector | None = None,
                 ladder: DegradationLadder | None = None,
                 max_retries: int = 4, timeout_s: float | None = None,
                 backoff_s: float | None = None, ckpt_dir: str | None = None,
                 stage_cost_s=0.02, node_active_w: float = 200e-6,
                 on_node_fn=None, keep_ckpts: int = 8,
                 telemetry=None, sid: str = ""):
        if executor is None and make_executor is None:
            raise ValueError("pass executor= or make_executor=")
        if executor is not None:
            cut, bits = executor.cut, executor.bits
        if max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {max_retries}")
        self._make = make_executor
        self._execs: dict = {}
        if executor is not None:
            self._execs[(executor.cut, executor.bits)] = executor
        self.cut, self.bits = cut, bits
        self.link = link
        self.injector = injector
        self.ladder = ladder
        self.max_retries = int(max_retries)
        self.timeout_s = timeout_s
        self.backoff_s = backoff_s
        self.ckpt_dir = ckpt_dir
        self.stage_cost_s = stage_cost_s
        self.node_active_w = float(node_active_w)
        self.on_node_fn = on_node_fn
        self.keep_ckpts = int(keep_ckpts)
        self.telemetry = telemetry
        self.sid = str(sid)
        self._tel_on = telemetry_on(telemetry)
        self._runners: dict = {}
        self.now = 0.0                     # simulated session clock
        self.records: list = []
        self.stage_started: dict = {}      # staged-runner executions begun
        self.stage_completed: dict = {}    # ... and completed (no brownout)
        self.received: list = []           # (seq, crc, attempt) at receiver
        self._received_seqs: set = set()
        self.duplicates = 0

    # -- helpers -------------------------------------------------------------

    def _executor(self, rung):
        ex = self._execs.get(rung)
        if ex is None:
            if self._make is None:
                raise ValueError(
                    f"session has no executor for rung {rung} and no "
                    "make_executor factory — pass make_executor= to let "
                    "the ladder change configuration")
            ex = self._make(*rung)
            self._execs[rung] = ex
        return ex

    def _stage_cost(self, name: str) -> float:
        if isinstance(self.stage_cost_s, dict):
            return float(self.stage_cost_s.get(name, 0.0))
        return float(self.stage_cost_s)

    def seq_gaps(self) -> list:
        """Sequence numbers the receiver never saw (undelivered payloads)."""
        if not self._received_seqs:
            return [r.seq for r in self.records]
        hi = max(self._received_seqs)
        return [s for s in range(hi + 1) if s not in self._received_seqs]

    def attempt_trace(self) -> np.ndarray:
        """Per-send total on-air bytes — the link-simulator re-entry trace.

        Retransmissions inflate the entry for their send, so replaying
        this trace through ``simulate_shared_link`` makes retries queue
        against (and delay) neighboring streams' frames.
        """
        return np.array([r.bytes_on_air for r in self.records], np.float64)

    @property
    def energy_j(self) -> float:
        return float(sum(r.energy_j for r in self.records))

    @property
    def bytes_on_air(self) -> float:
        return float(sum(r.bytes_on_air for r in self.records))

    # -- node side (staged, commit points, brownout recovery) ----------------

    def _node_payload(self, ex, inputs):
        """Run the node half; returns (payload, compute_s, brownouts,
        restores, recovery_s).

        Fast path (no brownout model): the executor's own single-dispatch
        ``encode`` — bit-exact PR 5.  With a brownout model: the staged
        runner with a commit point at every stage boundary.
        """
        inj = self.injector
        if inj is None or inj.brownout is None:
            total_cost = sum(self._stage_cost(n) for n in _stage_names(ex))
            self.now += total_cost
            return ex.encode(*inputs), total_cost, 0, 0, 0.0
        return self._staged_node(ex, inputs)

    def _staged_node(self, ex, inputs):
        from repro.ckpt.checkpoint import (prune_old, restore_checkpoint,
                                           save_checkpoint)

        if self.ckpt_dir is None:
            raise ValueError(
                "brownout recovery needs ckpt_dir= for its stage-boundary "
                "commit points (the node's nonvolatile store)")
        runner = self._runners.get((ex.cut, ex.bits))
        if runner is None:
            runner = staged_runner_for(ex)
            self._runners[(ex.cut, ex.bits)] = runner
        inj = self.injector
        seq = len(self.records)
        in_names = ("lefts", "rights") if runner.capture_key == "lefts" \
            else ("frames",)
        state = dict(zip(in_names, inputs))
        # commit 0: capture itself goes to the nonvolatile store, so a
        # brownout in the FIRST stage resumes from stored capture data,
        # never from a re-capture
        base_step = seq * 16
        save_checkpoint(self.ckpt_dir, base_step, state,
                        extra={"stage": "capture", "seq": seq})
        committed, committed_step = dict(state), base_step
        compute_s = recovery_s = 0.0
        brownouts = restores = 0

        def run_guarded(name, apply_fn):
            """Run one stage under the node-power schedule."""
            nonlocal compute_s, brownouts, restores, recovery_s, state
            cost = self._stage_cost(name)
            for _try in range(64):
                powered, boundary = inj.power_window(self.now)
                if not powered:
                    recovery_s += boundary - self.now
                    self.now = boundary
                    continue
                if self.now + cost <= boundary:
                    self.stage_started[name] = \
                        self.stage_started.get(name, 0) + 1
                    out = apply_fn()
                    self.stage_completed[name] = \
                        self.stage_completed.get(name, 0) + 1
                    self.now += cost
                    compute_s += cost
                    return out
                # brownout mid-stage: this stage's work is lost; the node
                # draws power until the lights go out, recharges, restores
                # the last commit and re-enters HERE — never at capture
                self.stage_started[name] = \
                    self.stage_started.get(name, 0) + 1
                brownouts += 1
                compute_s += boundary - self.now
                recovery_s += boundary - self.now
                self.now = boundary
                restored, _extra = restore_checkpoint(
                    self.ckpt_dir, committed_step, committed)
                state = dict(restored)
                restores += 1
            raise RuntimeError(
                f"stage {name!r} (cost {cost}s) cannot complete inside any "
                "harvested on-window — shrink the stage cost or grow "
                "BrownoutModel.storage_j")

        for i, stg in enumerate(runner.stages):
            new = run_guarded(stg.name, lambda stg=stg: stg.fn(state))
            # NB: two statements — run_guarded may rebind `state` to a
            # restored checkpoint, and state.update(run_guarded(...)) would
            # resolve the bound method against the abandoned dict
            state.update(new)
            step = base_step + 1 + i
            save_checkpoint(self.ckpt_dir, step, state,
                            extra={"stage": stg.name, "seq": seq})
            committed, committed_step = dict(state), step
        arrays, wire_b = run_guarded("encode", lambda: runner.encode(state))
        prune_old(self.ckpt_dir, keep=self.keep_ckpts)
        payload = WirePayload(cut=ex.cut, bits=ex.bits, arrays=arrays,
                              meta=self._payload_meta(ex, inputs),
                              wire_b=wire_b)
        return payload, compute_s, brownouts, restores, recovery_s

    def _payload_meta(self, ex, inputs) -> dict:
        from repro.camera.offload.executors import VROffloadExecutor

        if isinstance(ex, VROffloadExecutor):
            pano_shapes = None
            if ex.cut == "stitch":
                # same shape-inference cache the executor's encode uses
                import jax

                key = tuple(inputs[0].shape)
                if key not in ex._pano_shape_cache:
                    lp, rp = jax.eval_shape(
                        lambda l, r: ex._pano(l, r, ex._depth(l, r)),
                        inputs[0], inputs[1])
                    ex._pano_shape_cache[key] = (tuple(lp.shape),
                                                 tuple(rp.shape))
                pano_shapes = ex._pano_shape_cache[key]
            return {"view_shape": tuple(inputs[0].shape),
                    "pano_shapes": pano_shapes}
        return {"frames_shape": tuple(inputs[0].shape)}

    # -- transmission --------------------------------------------------------

    def _transmit(self, nbytes: float) -> tuple:
        """Push one framed payload through the fault process.

        Returns ``(delivered, attempts, lost, corrupt, bytes_on_air,
        tx_energy_j, final_attempt)``.  Every attempt — delivered or not —
        is charged full bytes and energy; losses pay the sender timeout,
        corruptions pay the NACK round trip, and retries back off
        exponentially (which is also how a transmit escapes an outage
        window).
        """
        link, inj = self.link, self.injector
        tx_s = link.latency_s + nbytes / link.bytes_per_s
        timeout = self.timeout_s if self.timeout_s is not None \
            else tx_s + 4.0 * link.latency_s
        backoff0 = self.backoff_s if self.backoff_s is not None else tx_s
        attempts = lost = corrupt = 0
        bytes_on_air = 0.0
        while True:
            attempts += 1
            outcome = inj.attempt(self.now) if inj is not None else "ok"
            bytes_on_air += nbytes
            if outcome == "ok":
                self.now += tx_s
                break
            if outcome == "corrupt":
                corrupt += 1
                self.now += tx_s + link.latency_s     # NACK round trip
            else:
                lost += 1
                self.now += tx_s + timeout            # ack never comes
            if attempts > self.max_retries:
                return (False, attempts, lost, corrupt, bytes_on_air,
                        bytes_on_air * link.joules_per_byte, attempts)
            self.now += backoff0 * (2.0 ** (attempts - 1))
        return (True, attempts, lost, corrupt, bytes_on_air,
                bytes_on_air * link.joules_per_byte, attempts)

    # -- the send loop -------------------------------------------------------

    def send(self, *inputs):
        """Deliver one frame batch; returns ``(result, DeliveryRecord)``."""
        seq = len(self.records)
        t0 = self.now
        rung = self.ladder.rung if self.ladder is not None \
            else (self.cut, self.bits)
        fallback = False
        if rung == ON_NODE:
            result, payload, compute_s, brownouts, restores, recovery_s = \
                self._run_on_node(inputs)
            nbytes = self._decision_bytes(inputs) + SESSION_SIDEBAND_BYTES
            crc = 0
            fallback = True
            cut, bits = ON_NODE
        else:
            ex = self._executor(rung)
            cut, bits = rung
            payload, compute_s, brownouts, restores, recovery_s = \
                self._node_payload(ex, inputs)
            crc = payload_checksum(payload)
            nbytes = payload.nbytes() + SESSION_SIDEBAND_BYTES
            result = None

        delivered, attempts, lost, corrupt, on_air, tx_j, att = \
            self._transmit(nbytes)

        if delivered:
            self._receive(seq, crc, att)
            if not fallback:
                if payload_checksum(payload) != crc:   # integrity contract
                    raise AssertionError("checksum drift on clean delivery")
                result = self._executor(rung).decode_run(payload)
        elif not fallback and self.on_node_fn is not None:
            # retries exhausted: degrade THIS payload to the terminal rung
            # (compute on node, ship the tiny decision) rather than drop it
            result, _p, c2, b2, r2, rec2 = self._run_on_node(inputs)
            compute_s += c2
            brownouts += b2
            restores += r2
            recovery_s += rec2
            nb2 = self._decision_bytes(inputs) + SESSION_SIDEBAND_BYTES
            d2, a2, l2, cr2, oa2, j2, att2 = self._transmit(nb2)
            attempts += a2
            lost += l2
            corrupt += cr2
            on_air += oa2
            tx_j += j2
            delivered, fallback = d2, True
            if d2:
                self._receive(seq, 0, att2)

        rec = DeliveryRecord(
            seq=seq, cut=cut, bits=bits, delivered=delivered,
            fallback=fallback, attempts=attempts, lost=lost, corrupt=corrupt,
            payload_bytes=nbytes, bytes_on_air=on_air, compute_s=compute_s,
            latency_s=self.now - t0,
            energy_j=tx_j + compute_s * self.node_active_w,
            brownouts=brownouts, restores=restores, recovery_s=recovery_s)
        self.records.append(rec)
        if self._tel_on:
            self._record_delivery(rec, t0)
        if self.ladder is not None:
            n_tr = len(self.ladder.transitions)
            self.ladder.observe(rec)
            if self._tel_on and len(self.ladder.transitions) > n_tr:
                _s, old, new = self.ladder.transitions[-1]
                self.telemetry.emit(
                    "ladder", "descend" if new > old else "recover",
                    t=self.now, sid=self.sid, seq=rec.seq,
                    old_level=old, new_level=new,
                    rung=_ledger_rung_key(self.ladder.rung))
                self.telemetry.counters.bump("offload.ladder_moves")
        return (result if delivered else None), rec

    def _record_delivery(self, rec: DeliveryRecord, t0: float) -> None:
        """Per-attempt accounting + one link trace span per send (§15)."""
        tel = self.telemetry
        c = tel.counters
        c.bump("offload.sends")
        c.bump("offload.attempts", rec.attempts)
        c.bump("offload.retries", rec.attempts - 1)
        c.bump("offload.lost", rec.lost)
        c.bump("offload.crc_fail", rec.corrupt)
        c.bump("offload.bytes_on_air", int(round(rec.bytes_on_air)))
        c.bump("offload.delivered" if rec.delivered else "offload.dropped")
        if rec.fallback:
            c.bump("offload.fallbacks")
        if rec.brownouts:
            c.bump("offload.brownouts", rec.brownouts)
        if rec.restores:
            c.bump("offload.restores", rec.restores)
        rung = "on_node" if rec.fallback else (rec.cut, rec.bits)
        tel.emit(
            "link", f"send[{_ledger_rung_key(rung)}]", t=t0,
            dur=rec.latency_s, sid=self.sid, seq=rec.seq,
            delivered=rec.delivered, fallback=rec.fallback,
            attempts=rec.attempts, lost=rec.lost, crc_fail=rec.corrupt,
            payload_b=rec.payload_bytes, on_air_b=rec.bytes_on_air,
            brownouts=rec.brownouts, restores=rec.restores,
            energy_j=rec.energy_j)
        tel.ledger.observe_latency(self.sid, rung, rec.latency_s)

    def _receive(self, seq, crc, attempt):
        if seq in self._received_seqs:
            self.duplicates += 1
            return
        self._received_seqs.add(seq)
        self.received.append(session_sideband(seq, crc, attempt))

    def _run_on_node(self, inputs):
        if self.on_node_fn is None:
            raise ValueError(
                "ladder reached the on_node rung but the session has no "
                "on_node_fn — pass one (e.g. the fused base executor) or "
                "drop the ON_NODE rung")
        compute = sum(self._stage_cost(n)
                      for n in ("motion", "detect", "gather", "nn", "encode"))
        brownouts = restores = 0
        recovery = 0.0
        inj = self.injector
        if inj is not None and inj.brownout is not None:
            # on-node still runs on harvested power; wait out dark windows
            for _ in range(32):
                powered, boundary = inj.power_window(self.now)
                if powered and self.now + compute <= boundary:
                    break
                recovery += boundary - self.now
                self.now = boundary
                if powered:
                    brownouts += 1
        result = self.on_node_fn(*inputs)
        self.now += compute
        return result, None, compute, brownouts, restores, recovery

    def _decision_bytes(self, inputs) -> float:
        n_units = int(np.asarray(inputs[0]).shape[0])
        return n_units * _DECISION_BITS_PER_UNIT + _I32_B


def fleet_link_report(sessions, link: LinkProfile, frame_period_s: float,
                      **kw):
    """Replay N sessions' on-air traces through ONE shared link.

    The congestion view of resilience: each session's trace already
    includes every retransmission, so a faulty stream's retries queue
    against its neighbors' frames — the p99 the closed-form model (and
    the fault-free PR-5 sweep) cannot see.
    """
    from repro.camera.offload.link import simulate_shared_link

    traces = [s.attempt_trace() for s in sessions]
    n = min(len(t) for t in traces)
    if n == 0:
        raise ValueError("fleet_link_report: a session has no sends yet")
    return simulate_shared_link(
        np.stack([t[:n] for t in traces]), link, frame_period_s, **kw)
