"""Cut-point split executors: node-side + cloud-side jit halves.

The paper's configuration space — *where do you cut the pipeline?* — has
so far only been scored analytically (`core/placement.solve_cut` over
hand-entered Block descriptors) while the live executors (PRs 2-4) always
ran end-to-end on-node.  This module makes every legal cut executable:

* :class:`FaceAuthOffloadExecutor` splits the §III funnel at any of its
  four block boundaries.  Both halves compose the *same* traceable stage
  closures the fused :class:`~repro.camera.pipelines.FaceAuthExecutor`
  runs (``FunnelStages``), so the split can never drift from the on-node
  math, and each half is ONE jit dispatch (the PR-4 single-dispatch and
  capacity-padding contracts carry over unchanged).
* :class:`VROffloadExecutor` splits the §IV rig pipeline (raw views /
  depth maps / panorama) around :class:`~repro.camera.pipelines.VRRigExecutor`'s
  traceable per-pair depth + stitch functions.

The wire payload between the halves is typed (`payloads.WirePayload`) and
optionally compressed by the Pallas wire codec (`kernels/wire_codec`) at
16/8/4 bits; ``bits=None`` ships the raw f32 runtime representation, the
uncompressed baseline of the knee sweep.  Measured wire bytes are charged
in-graph for *valid* payload elements only (see payloads.py).

Cut payload contracts (DESIGN.md §10):

  face_auth
    sensor  frames (B,h,w)            [codec]
    motion  mframes (M,h,w)           [codec] + fidx/motion/drop sideband
    vj      patches (M,W,20,20)       [codec] + wsel/counts sideband
    nn      scores (M,W)              [codec] + auth bits + counts sideband
  vr_video
    capture lefts,rights (P,h,w)      [codec]
    depth   depths (P,h,w) + views    [codec]  (stitch needs full-res views
                                      — the runtime exposes that the §IV
                                      mid-cut ships MORE than raw, which
                                      the analytic linear model hides)
    stitch  left/right panoramas      [codec]
"""

from __future__ import annotations

import dataclasses
import functools

import numpy as np

from repro.camera.offload.payloads import (
    SESSION_SIDEBAND_NAMES,
    PayloadSchema,
    WirePayload,
)
from repro.kernels.wire_codec.ops import (
    wire_bytes,
    wire_bytes_dynamic,
    wire_decode,
    wire_encode,
)

_I32_B = 4.0          # index / count sideband bytes per valid entry
_BOOL_B = 1.0 / 8.0   # booleans ship bit-packed


class _Codec:
    """Static codec configuration shared by both executor families."""

    def __init__(self, bits, block, use_pallas, interpret):
        if bits not in (None, 4, 8, 16):
            raise ValueError(f"codec bits must be None/4/8/16, got {bits}")
        self.bits = bits
        self.block = int(block)
        self.use_pallas = use_pallas
        self.interpret = bool(interpret)

    def enc(self, arrays: dict, name: str, x):
        """Pack field ``x`` into ``arrays`` (traceable)."""
        import jax.numpy as jnp

        if self.bits is None:
            arrays[name] = x.astype(jnp.float32)
            return
        packed, scales = wire_encode(
            x, bits=self.bits, block=self.block,
            use_pallas=self.use_pallas, interpret=self.interpret)
        arrays[name] = packed
        arrays[name + "_scales"] = scales

    def dec(self, arrays: dict, name: str, shape):
        """Unpack field ``name`` back to f32 of static ``shape``."""
        if self.bits is None:
            return arrays[name].reshape(shape)
        return wire_decode(
            arrays[name], arrays[name + "_scales"], tuple(shape),
            bits=self.bits, block=self.block,
            use_pallas=self.use_pallas, interpret=self.interpret)

    def dyn_bytes(self, n_values):
        return wire_bytes_dynamic(n_values, self.bits, block=self.block)

    def static_bytes(self, n_values):
        return wire_bytes(n_values, self.bits, block=self.block)


# ---------------------------------------------------------------------------
# §III face authentication
# ---------------------------------------------------------------------------


class FaceAuthOffloadExecutor:
    """Split §III funnel: node-side prefix, wire payload, cloud-side suffix.

    Construct *after* ``base.calibrate(...)`` — the split snapshots the
    base executor's stage closures and capacity knobs.  ``encode`` is the
    node's single dispatch, ``decode_run`` the cloud's; ``__call__`` runs
    both and returns ``(FAExecResult, WirePayload)``.  With ``bits=None``
    the end-to-end result is bit-identical to the fused executor at every
    cut (pinned by tests/test_offload.py); with a codec the deviation is
    the measured accuracy axis of the knee sweep.
    """

    CUTS = ("sensor", "motion", "vj", "nn")

    # Declared wire contract per cut (repro.analysis cross-checks these
    # against the avals _node_fn actually emits — see payloads.PayloadSchema)
    PAYLOAD_SCHEMA = {
        "sensor": PayloadSchema(codec=("frames",),
                                session=SESSION_SIDEBAND_NAMES),
        "motion": PayloadSchema(codec=("mframes",),
                                i32=("fidx", "motion_dropped"),
                                bools=("motion",),
                                session=SESSION_SIDEBAND_NAMES),
        "vj": PayloadSchema(codec=("patches",),
                            i32=("wsel", "n_win", "win_dropped", "casc_drop",
                                 "fidx", "motion_dropped"),
                            bools=("motion",),
                            session=SESSION_SIDEBAND_NAMES),
        "nn": PayloadSchema(codec=("scores",),
                            i32=("wsel", "n_win", "win_dropped", "casc_drop",
                                 "fidx", "motion_dropped"),
                            bools=("motion", "auth"),
                            session=SESSION_SIDEBAND_NAMES),
    }

    def __init__(self, base, cut: str, *, bits: int | None = None,
                 block: int = 256, use_pallas=None, interpret: bool = False):
        import jax

        if cut not in self.CUTS:
            raise ValueError(f"cut {cut!r} not in {self.CUTS}")
        self.base = base
        self.cut = cut
        self.codec = _Codec(bits, block, use_pallas, interpret)
        self.bits = self.codec.bits
        self._st = base.stages
        self._consts = base._consts
        self._h, self._w = base.det.grid.h, base.det.grid.w
        self._node = jax.jit(self._node_fn)
        # cloud jit cached per source-frame shape: the sensor cut's packed
        # payload does not carry (B, h, w), so the decode contract rides in
        # WirePayload.meta (same scheme as VROffloadExecutor)
        self._cloud_cache: dict = {}

    # -- node side -----------------------------------------------------------

    def _node_fn(self, frames, *c):
        import jax.numpy as jnp

        st, cdc = self._st, self.codec
        cut = self.cut
        B = frames.shape[0]
        h, w = self._h, self._w
        arrays: dict = {}
        if cut == "sensor":
            cdc.enc(arrays, "frames", frames.astype(jnp.float32))
            wire_b = jnp.asarray(cdc.static_bytes(B * h * w), jnp.float32)
            return arrays, wire_b

        det_c, pos_c, nn_c = st.split_consts(c)
        mframes, fidx, fvalid, motion, motion_dropped = st.motion(frames)
        n_valid_f = jnp.sum(fvalid).astype(jnp.float32)
        side = _I32_B * n_valid_f + _BOOL_B * B + _I32_B   # fidx+motion+drop
        if cut == "motion":
            # zero the capacity-padding frames (fidx padding points at real
            # non-motion frames): a zero quantizes to zero exactly, so
            # padding cannot perturb the codec's block scales, matching the
            # variable-length transmit the byte accounting models.  The
            # cloud half masks everything by fvalid, so results are
            # unchanged (bits=None stays bit-exact).
            cdc.enc(arrays, "mframes",
                    jnp.where(fvalid[:, None, None], mframes, 0.0))
            arrays.update(fidx=fidx.astype(jnp.int32), motion=motion,
                          motion_dropped=motion_dropped)
            wire_b = cdc.dyn_bytes(n_valid_f * (h * w)) + side
            return arrays, wire_b

        dmask, n_win_m, casc_drop_m = st.detect(mframes, fvalid, det_c)
        patches, wsel, wvalid, win_dropped_m = st.gather(
            mframes, dmask, n_win_m, pos_c)
        n_valid_w = jnp.sum(wvalid).astype(jnp.float32)
        # per processed valid frame: n_win + win_dropped + casc_drop counts
        side = side + _I32_B * 3 * n_valid_f
        common = dict(wsel=wsel.astype(jnp.int32),
                      n_win=n_win_m, win_dropped=win_dropped_m,
                      casc_drop=casc_drop_m, fidx=fidx.astype(jnp.int32),
                      motion=motion, motion_dropped=motion_dropped)
        if cut == "vj":
            # zero padding windows (wsel defaults to position 0) — same
            # scale-isolation argument as the motion cut above
            cdc.enc(arrays, "patches",
                    jnp.where(wvalid[:, :, None, None], patches, 0.0))
            arrays.update(common)
            wire_b = (cdc.dyn_bytes(n_valid_w * patches.shape[-1]
                                    * patches.shape[-2])
                      + _I32_B * n_valid_w + side)
            return arrays, wire_b

        s, auth, _n_auth_m = st.nn(patches, wvalid, nn_c)
        cdc.enc(arrays, "scores", s)
        arrays.update(common, auth=auth)
        wire_b = (cdc.dyn_bytes(n_valid_w) + _BOOL_B * n_valid_w
                  + _I32_B * n_valid_w + side)
        return arrays, wire_b

    # -- cloud side ----------------------------------------------------------

    def _cloud_fn(self, arrays, *c, frames_shape):
        import jax.numpy as jnp

        st, cdc = self._st, self.codec
        cut = self.cut
        det_c, pos_c, nn_c = st.split_consts(c)
        h, w = self._h, self._w
        W = st.window_capacity
        if cut == "sensor":
            frames = cdc.dec(arrays, "frames", frames_shape)
            mframes, fidx, fvalid, motion, motion_dropped = st.motion(frames)
        else:
            fidx = arrays["fidx"]
            motion = arrays["motion"]
            motion_dropped = arrays["motion_dropped"]
            fvalid = jnp.take(motion, fidx)
        B = motion.shape[0]
        M = fidx.shape[0]

        if cut in ("sensor", "motion"):
            if cut == "motion":
                mframes = cdc.dec(arrays, "mframes", (M, h, w))
            dmask, n_win_m, casc_drop_m = st.detect(mframes, fvalid, det_c)
            patches, wsel, wvalid, win_dropped_m = st.gather(
                mframes, dmask, n_win_m, pos_c)
        else:
            wsel = arrays["wsel"]
            n_win_m = arrays["n_win"]
            win_dropped_m = arrays["win_dropped"]
            casc_drop_m = arrays["casc_drop"]
            wvalid = (jnp.arange(W, dtype=jnp.int32)[None, :]
                      < jnp.minimum(n_win_m, W)[:, None])

        if cut == "nn":
            s = jnp.where(wvalid, cdc.dec(arrays, "scores", (M, W)), 0.0)
            auth = arrays["auth"]
            n_auth_m = jnp.sum(auth, axis=1).astype(jnp.int32)
        else:
            if cut == "vj":
                patches = cdc.dec(arrays, "patches", (M, W, 20, 20))
            s, auth, n_auth_m = st.nn(patches, wvalid, nn_c)

        return st.scatter(B, fidx, motion, motion_dropped, n_win_m,
                          casc_drop_m, wsel, wvalid, win_dropped_m,
                          s, auth, n_auth_m)

    # -- execution -----------------------------------------------------------

    def encode(self, frames) -> WirePayload:
        """Node-side dispatch: frames -> wire payload."""
        import jax.numpy as jnp

        frames = jnp.asarray(frames)
        arrays, wire_b = self._node(frames, *self._consts)
        return WirePayload(cut=self.cut, bits=self.bits, arrays=arrays,
                           meta={"frames_shape": tuple(frames.shape)},
                           wire_b=wire_b)

    def decode_run(self, payload: WirePayload):
        """Cloud-side dispatch: wire payload -> FAExecResult."""
        import functools

        import jax

        from repro.camera.pipelines import FAExecResult

        key = payload.meta["frames_shape"]
        fn = self._cloud_cache.get(key)
        if fn is None:
            fn = jax.jit(functools.partial(self._cloud_fn, frames_shape=key))
            self._cloud_cache[key] = fn
        return FAExecResult(**fn(payload.arrays, *self._consts))

    def __call__(self, frames):
        payload = self.encode(frames)
        return self.decode_run(payload), payload


# ---------------------------------------------------------------------------
# §IV VR rig
# ---------------------------------------------------------------------------


class VROffloadExecutor:
    """Split §IV rig pipeline around :class:`VRRigExecutor`'s stages.

    ``encode(lefts, rights)`` is the rig-side dispatch, ``decode_run`` the
    cloud side; results are ``(left_pano, right_pano)``.  Depth is vmapped
    over camera pairs inside whichever half owns it, exactly as the fused
    executor runs it.
    """

    CUTS = ("capture", "depth", "stitch")

    PAYLOAD_SCHEMA = {
        "capture": PayloadSchema(codec=("lefts", "rights"),
                                 session=SESSION_SIDEBAND_NAMES),
        "depth": PayloadSchema(codec=("depths", "lefts", "rights"),
                               session=SESSION_SIDEBAND_NAMES),
        "stitch": PayloadSchema(codec=("left_pano", "right_pano"),
                                session=SESSION_SIDEBAND_NAMES),
    }

    def __init__(self, base, cut: str, *, bits: int | None = None,
                 block: int = 256, use_pallas=None, interpret: bool = False):
        import jax

        if cut not in self.CUTS:
            raise ValueError(f"cut {cut!r} not in {self.CUTS}")
        self.base = base
        self.cut = cut
        self.codec = _Codec(bits, block, use_pallas, interpret)
        self.bits = self.codec.bits
        self._depth = jax.vmap(base.pair_depth)
        self._pano = base.pano_fn
        self._node = jax.jit(self._node_fn)
        self._cloud_cache: dict = {}
        self._pano_shape_cache: dict = {}

    def _node_fn(self, lefts, rights):
        import jax.numpy as jnp

        cdc = self.codec
        P, h, w = lefts.shape
        arrays: dict = {}
        if self.cut == "capture":
            cdc.enc(arrays, "lefts", lefts.astype(jnp.float32))
            cdc.enc(arrays, "rights", rights.astype(jnp.float32))
            wire_b = 2 * cdc.static_bytes(P * h * w)
        elif self.cut == "depth":
            depths = self._depth(lefts, rights)
            cdc.enc(arrays, "depths", depths)
            cdc.enc(arrays, "lefts", lefts.astype(jnp.float32))
            cdc.enc(arrays, "rights", rights.astype(jnp.float32))
            wire_b = 3 * cdc.static_bytes(P * h * w)
        else:                                      # stitch: full on-node
            depths = self._depth(lefts, rights)
            lp, rp = self._pano(lefts, rights, depths)
            cdc.enc(arrays, "left_pano", lp)
            cdc.enc(arrays, "right_pano", rp)
            wire_b = (cdc.static_bytes(int(np.prod(lp.shape)))
                      + cdc.static_bytes(int(np.prod(rp.shape))))
        return arrays, jnp.asarray(wire_b, jnp.float32)

    def _cloud_fn_for(self, meta_key):
        import jax

        view_shape, pano_shapes = meta_key
        cdc = self.codec

        def cloud(arrays):
            if self.cut == "capture":
                lefts = cdc.dec(arrays, "lefts", view_shape)
                rights = cdc.dec(arrays, "rights", view_shape)
                depths = self._depth(lefts, rights)
                return self._pano(lefts, rights, depths)
            if self.cut == "depth":
                depths = cdc.dec(arrays, "depths", view_shape)
                lefts = cdc.dec(arrays, "lefts", view_shape)
                rights = cdc.dec(arrays, "rights", view_shape)
                return self._pano(lefts, rights, depths)
            return (cdc.dec(arrays, "left_pano", pano_shapes[0]),
                    cdc.dec(arrays, "right_pano", pano_shapes[1]))

        return jax.jit(cloud)

    def encode(self, lefts, rights) -> WirePayload:
        import jax
        import jax.numpy as jnp

        lefts, rights = jnp.asarray(lefts), jnp.asarray(rights)
        arrays, wire_b = self._node(lefts, rights)
        pano_shapes = None
        if self.cut == "stitch":
            key = tuple(lefts.shape)
            if key not in self._pano_shape_cache:
                # shape inference only — cached so the timed encode path
                # stays dispatch-only after the first call
                lp, rp = jax.eval_shape(
                    lambda l, r: self._pano(l, r, self._depth(l, r)),
                    lefts, rights)
                self._pano_shape_cache[key] = (tuple(lp.shape),
                                               tuple(rp.shape))
            pano_shapes = self._pano_shape_cache[key]
        return WirePayload(
            cut=self.cut, bits=self.bits, arrays=arrays,
            meta={"view_shape": tuple(lefts.shape),
                  "pano_shapes": pano_shapes},
            wire_b=wire_b)

    def decode_run(self, payload: WirePayload):
        key = (payload.meta["view_shape"], payload.meta["pano_shapes"])
        if key not in self._cloud_cache:
            self._cloud_cache[key] = self._cloud_fn_for(key)
        return self._cloud_cache[key](payload.arrays)

    def __call__(self, lefts, rights):
        payload = self.encode(lefts, rights)
        return self.decode_run(payload), payload
