"""Trace-driven offload-link simulator (paper §II-A's communication cost,
made executable).

The cost model charges a cut-point payload ``bytes x joules_per_byte`` or
``bytes / bandwidth`` — a closed form with no queueing.  This simulator
replays *measured* per-frame payload byte traces from the live split
executors (``camera/offload/executors``) through a shared serial link and
produces what the closed form cannot: per-frame completion latency under
contention when N streams share one uplink (the WISPCam-fleet shape: many
energy-harvesting cameras, one RFID reader; the 16-camera rig: eight
pairs, one 25 GbE port), sustained-vs-offered throughput, and transmit
energy.

Two calibrated profiles anchor the paper's two regimes:

* :data:`BACKSCATTER` — RFID backscatter uplink (WISP-class).  EPC Gen2
  backscatter peaks at ~640 kbps; WISPCam-style duty-cycled harvesting
  sustains far less — we use 64 kbps (8 kB/s) with the §III calibrated
  transmit energy (``core/costmodel.RF_LINK``'s 83 nJ/B default; the
  controller swaps in the workload-calibrated value).
* :data:`ETH_25G_LINK` / :data:`ETH_400G_LINK` — the §IV wired uplinks.

``LinkProfile.scaled`` supports evaluating toy-resolution traces at a
paper-native operating point: scaling bandwidth by (toy pixels / native
pixels) is *exactly* equivalent to scaling the measured bytes up to
native resolution (payload bytes are linear in pixels at every cut).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class LinkProfile:
    """A serial offload link: bandwidth, per-message latency, energy."""

    name: str
    bytes_per_s: float
    latency_s: float = 0.0           # per-message propagation + framing
    joules_per_byte: float = 0.0

    def scaled(self, factor: float, name: str | None = None) -> "LinkProfile":
        """Bandwidth scaled by ``factor`` (see module docstring)."""
        return dataclasses.replace(
            self, bytes_per_s=self.bytes_per_s * factor,
            name=name or f"{self.name}x{factor:g}")


BACKSCATTER = LinkProfile("rfid_backscatter", bytes_per_s=8e3,
                          latency_s=2e-3, joules_per_byte=83e-9)
ETH_25G_LINK = LinkProfile("eth_25g", bytes_per_s=25e9 / 8,
                           latency_s=5e-6, joules_per_byte=4e-9)
ETH_400G_LINK = LinkProfile("eth_400g", bytes_per_s=400e9 / 8,
                            latency_s=5e-6, joules_per_byte=4e-9)


@dataclasses.dataclass(frozen=True)
class LinkReport:
    """Result of replaying byte traces through one shared link."""

    link: str
    n_streams: int
    frame_period_s: float
    latency_s: np.ndarray            # (n_streams, n_frames) completion - arrival
    bytes_total: float
    joules: float
    utilization: float               # busy fraction of the makespan
    offered_bps: float               # offered load, bytes/s
    delivered_fps: float             # completed frames / makespan

    @property
    def mean_latency_s(self) -> float:
        return float(self.latency_s.mean()) if self.latency_s.size else 0.0

    @property
    def p99_latency_s(self) -> float:
        return (float(np.quantile(self.latency_s, 0.99))
                if self.latency_s.size else 0.0)

    @property
    def max_latency_s(self) -> float:
        return float(self.latency_s.max()) if self.latency_s.size else 0.0

    def realtime_fraction(self, deadline_s: float) -> float:
        """Fraction of frames delivered within ``deadline_s`` of capture."""
        if not self.latency_s.size:
            return 1.0
        return float((self.latency_s <= deadline_s).mean())


def simulate_shared_link(traces, link: LinkProfile, frame_period_s: float,
                         duty: float = 1.0, stagger: bool = True) -> LinkReport:
    """Replay per-frame payload traces from N streams over one shared link.

    ``traces``: (n_streams, n_frames) or (n_frames,) measured bytes per
    frame.  Stream s's frame i arrives at ``(i + phase_s) * period`` with
    ``period = frame_period_s / duty`` (``duty`` scales the source rate —
    the paper's duty-cycle knob); ``stagger`` offsets streams by
    ``period / n_streams`` so the fleet is not pathologically synchronized
    (set False to model a globally-triggered rig).  The link serves one
    message at a time, FIFO in arrival order — transmit time
    ``bytes / bytes_per_s`` after ``latency_s`` framing.

    Deterministic, trace-exact, O(total frames log total frames).
    """
    traces = np.atleast_2d(np.asarray(traces, np.float64))
    n_streams, n_frames = traces.shape
    if duty <= 0:
        raise ValueError(f"duty must be positive, got {duty}")
    period = frame_period_s / duty
    phase = (np.arange(n_streams) / n_streams if stagger
             else np.zeros(n_streams))
    arrive = (np.arange(n_frames)[None, :] + phase[:, None]) * period
    order = np.argsort(arrive, axis=None, kind="stable")
    flat_arrive = arrive.reshape(-1)[order]
    flat_bytes = traces.reshape(-1)[order]

    done = np.zeros_like(flat_arrive)
    busy = 0.0
    free_at = 0.0
    for i in range(flat_arrive.shape[0]):
        if flat_bytes[i] == 0.0:
            # nothing to send: a real node keys up no transmission, so a
            # quiet frame pays neither framing latency nor queue time
            done[i] = flat_arrive[i]
            continue
        start = max(flat_arrive[i], free_at)
        tx = link.latency_s + flat_bytes[i] / link.bytes_per_s
        free_at = start + tx
        busy += tx
        done[i] = free_at

    latency = np.empty_like(done)
    latency[order] = done - flat_arrive
    # done is completion per arrival-ordered message; a trailing zero-byte
    # frame completes at its arrival, so the makespan is the max, not the
    # last entry
    makespan = max(float(done.max()), 1e-12) if done.size else 1e-12
    total_bytes = float(traces.sum())
    offered_window = n_frames * period
    return LinkReport(
        link=link.name,
        n_streams=n_streams,
        frame_period_s=period,
        latency_s=latency.reshape(n_streams, n_frames),
        bytes_total=total_bytes,
        joules=total_bytes * link.joules_per_byte,
        utilization=min(busy / makespan, 1.0),
        offered_bps=total_bytes / offered_window if offered_window else 0.0,
        delivered_fps=done.size / makespan,
    )


def link_energy_w(bytes_per_unit: float, unit_rate_hz: float,
                  link: LinkProfile) -> float:
    """Average transmit watts — the cost model's ``comm_w`` term, from
    measured bytes (the closed-form cross-check of the simulator)."""
    return bytes_per_unit * unit_rate_hz * link.joules_per_byte
