"""Trace-driven offload-link simulator (paper §II-A's communication cost,
made executable).

The cost model charges a cut-point payload ``bytes x joules_per_byte`` or
``bytes / bandwidth`` — a closed form with no queueing.  This simulator
replays *measured* per-frame payload byte traces from the live split
executors (``camera/offload/executors``) through a shared serial link and
produces what the closed form cannot: per-frame completion latency under
contention when N streams share one uplink (the WISPCam-fleet shape: many
energy-harvesting cameras, one RFID reader; the 16-camera rig: eight
pairs, one 25 GbE port), sustained-vs-offered throughput, and transmit
energy.

Two calibrated profiles anchor the paper's two regimes:

* :data:`BACKSCATTER` — RFID backscatter uplink (WISP-class).  EPC Gen2
  backscatter peaks at ~640 kbps; WISPCam-style duty-cycled harvesting
  sustains far less — we use 64 kbps (8 kB/s) with the §III calibrated
  transmit energy (``core/costmodel.RF_LINK``'s 83 nJ/B default; the
  controller swaps in the workload-calibrated value).
* :data:`ETH_25G_LINK` / :data:`ETH_400G_LINK` — the §IV wired uplinks.

``LinkProfile.scaled`` supports evaluating toy-resolution traces at a
paper-native operating point: scaling bandwidth by (toy pixels / native
pixels) is *exactly* equivalent to scaling the measured bytes up to
native resolution (payload bytes are linear in pixels at every cut).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class LinkProfile:
    """A serial offload link: bandwidth, per-message latency, energy."""

    name: str
    bytes_per_s: float
    latency_s: float = 0.0           # per-message propagation + framing
    joules_per_byte: float = 0.0

    def scaled(self, factor: float, name: str | None = None) -> "LinkProfile":
        """Bandwidth scaled by ``factor`` (see module docstring)."""
        if not (np.isfinite(factor) and factor > 0):
            raise ValueError(
                f"LinkProfile.scaled: factor must be a finite positive "
                f"number, got {factor!r} — a zero/negative bandwidth scale "
                "would make every transmit time undefined (to model an "
                "outage, use FaultInjector, not a dead link profile)")
        return dataclasses.replace(
            self, bytes_per_s=self.bytes_per_s * factor,
            name=name or f"{self.name}x{factor:g}")


BACKSCATTER = LinkProfile("rfid_backscatter", bytes_per_s=8e3,
                          latency_s=2e-3, joules_per_byte=83e-9)
ETH_25G_LINK = LinkProfile("eth_25g", bytes_per_s=25e9 / 8,
                           latency_s=5e-6, joules_per_byte=4e-9)
ETH_400G_LINK = LinkProfile("eth_400g", bytes_per_s=400e9 / 8,
                            latency_s=5e-6, joules_per_byte=4e-9)


@dataclasses.dataclass(frozen=True)
class LinkReport:
    """Result of replaying byte traces through one shared link."""

    link: str
    n_streams: int
    frame_period_s: float
    latency_s: np.ndarray            # (n_streams, n_frames) completion - arrival
    bytes_total: float
    joules: float
    utilization: float               # busy fraction of the makespan
    offered_bps: float               # offered load, bytes/s
    delivered_fps: float             # completed frames / makespan

    @property
    def mean_latency_s(self) -> float:
        return float(self.latency_s.mean()) if self.latency_s.size else 0.0

    @property
    def p99_latency_s(self) -> float:
        return (float(np.quantile(self.latency_s, 0.99))
                if self.latency_s.size else 0.0)

    @property
    def max_latency_s(self) -> float:
        return float(self.latency_s.max()) if self.latency_s.size else 0.0

    def realtime_fraction(self, deadline_s: float) -> float:
        """Fraction of frames delivered within ``deadline_s`` of capture."""
        if not self.latency_s.size:
            return 1.0
        return float((self.latency_s <= deadline_s).mean())


def simulate_shared_link(traces, link: LinkProfile, frame_period_s: float,
                         duty: float = 1.0, stagger: bool = True) -> LinkReport:
    """Replay per-frame payload traces from N streams over one shared link.

    ``traces``: (n_streams, n_frames) or (n_frames,) measured bytes per
    frame.  Stream s's frame i arrives at ``(i + phase_s) * period`` with
    ``period = frame_period_s / duty`` (``duty`` scales the source rate —
    the paper's duty-cycle knob); ``stagger`` offsets streams by
    ``period / n_streams`` so the fleet is not pathologically synchronized
    (set False to model a globally-triggered rig).  The link serves one
    message at a time, FIFO in arrival order — transmit time
    ``bytes / bytes_per_s`` after ``latency_s`` framing.

    Deterministic, trace-exact, O(total frames log total frames).
    """
    traces = np.atleast_2d(np.asarray(traces, np.float64))
    n_streams, n_frames = traces.shape
    if not (np.isfinite(frame_period_s) and frame_period_s >= 0):
        raise ValueError(
            f"simulate_shared_link: frame_period_s must be a finite "
            f"non-negative number of seconds, got {frame_period_s!r} — "
            "negative periods would make frames arrive in reverse time; "
            "to model a faster source rate, raise duty instead")
    if duty <= 0:
        raise ValueError(f"duty must be positive, got {duty}")
    period = frame_period_s / duty
    phase = (np.arange(n_streams) / n_streams if stagger
             else np.zeros(n_streams))
    arrive = (np.arange(n_frames)[None, :] + phase[:, None]) * period
    order = np.argsort(arrive, axis=None, kind="stable")
    flat_arrive = arrive.reshape(-1)[order]
    flat_bytes = traces.reshape(-1)[order]

    done = np.zeros_like(flat_arrive)
    busy = 0.0
    free_at = 0.0
    for i in range(flat_arrive.shape[0]):
        if flat_bytes[i] == 0.0:
            # nothing to send: a real node keys up no transmission, so a
            # quiet frame pays neither framing latency nor queue time
            done[i] = flat_arrive[i]
            continue
        start = max(flat_arrive[i], free_at)
        tx = link.latency_s + flat_bytes[i] / link.bytes_per_s
        free_at = start + tx
        busy += tx
        done[i] = free_at

    latency = np.empty_like(done)
    latency[order] = done - flat_arrive
    # done is completion per arrival-ordered message; a trailing zero-byte
    # frame completes at its arrival, so the makespan is the max, not the
    # last entry
    makespan = max(float(done.max()), 1e-12) if done.size else 1e-12
    total_bytes = float(traces.sum())
    offered_window = n_frames * period
    return LinkReport(
        link=link.name,
        n_streams=n_streams,
        frame_period_s=period,
        latency_s=latency.reshape(n_streams, n_frames),
        bytes_total=total_bytes,
        joules=total_bytes * link.joules_per_byte,
        utilization=min(busy / makespan, 1.0),
        offered_bps=total_bytes / offered_window if offered_window else 0.0,
        delivered_fps=done.size / makespan,
    )


def link_energy_w(bytes_per_unit: float, unit_rate_hz: float,
                  link: LinkProfile) -> float:
    """Average transmit watts — the cost model's ``comm_w`` term, from
    measured bytes (the closed-form cross-check of the simulator)."""
    return bytes_per_unit * unit_rate_hz * link.joules_per_byte


# ---------------------------------------------------------------------------
# Fault models (DESIGN.md §12)
# ---------------------------------------------------------------------------
#
# PR 5's simulator is lossless and always powered — every BENCH_offload
# number is a best case.  The models below make the two real failure
# modes of the paper's regimes injectable and *deterministic under a
# seed*:
#
# * Gilbert–Elliott burst loss + timed outages on any LinkProfile — the
#   backscatter uplink drops bursts, the shared 25 GbE port browns out
#   under incast.
# * Harvested-energy brownout traces for BACKSCATTER-class nodes — a
#   WISP camera runs off a capacitor charged by RF harvest; when the
#   charge runs out mid-funnel the node dies and must recover.
#
# The models only *decide* fault outcomes; charging the retries' bytes,
# energy and queueing back into simulate_shared_link is the job of
# resilience.OffloadSession.


@dataclasses.dataclass(frozen=True)
class GilbertElliott:
    """Two-state Markov burst-loss channel (good <-> bad).

    Per transmit attempt, the chain sits in ``good`` (loss prob
    ``loss_good``) or ``bad`` (``loss_bad``) and transitions with
    ``p_gb`` / ``p_bg``.  The classic burst model: mean burst length is
    ``1 / p_bg`` attempts, and the stationary loss rate has the closed
    form checked by the hypothesis property suite.
    """

    p_gb: float = 0.05            # P(good -> bad) per attempt
    p_bg: float = 0.5             # P(bad -> good) per attempt
    loss_good: float = 0.0
    loss_bad: float = 1.0

    def __post_init__(self):
        for f in ("p_gb", "p_bg", "loss_good", "loss_bad"):
            v = getattr(self, f)
            if not (np.isfinite(v) and 0.0 <= v <= 1.0):
                raise ValueError(
                    f"GilbertElliott.{f} must be a probability in [0, 1], "
                    f"got {v!r}")

    @property
    def stationary_bad(self) -> float:
        """Stationary probability of the bad state."""
        denom = self.p_gb + self.p_bg
        return self.p_gb / denom if denom > 0 else 0.0

    @property
    def stationary_loss(self) -> float:
        """Analytic long-run loss rate (the property-test anchor)."""
        pi_b = self.stationary_bad
        return pi_b * self.loss_bad + (1.0 - pi_b) * self.loss_good

    @property
    def mean_burst_len(self) -> float:
        """Mean consecutive attempts spent in the bad state."""
        return 1.0 / self.p_bg if self.p_bg > 0 else float("inf")


@dataclasses.dataclass(frozen=True)
class BrownoutModel:
    """Harvested-energy power supply of a WISP-class node.

    The node draws ``load_w`` while computing/transmitting and harvests
    ``harvest_w`` continuously; ``storage_j`` is the usable capacitor
    energy between full charge and the brownout cutoff.  Active windows
    therefore last ``storage_j / (load_w - harvest_w)`` seconds and
    recharging from cutoff takes ``storage_j / harvest_w`` seconds —
    jittered per cycle by the injector's seeded RNG so fleets do not
    brown out in lockstep.
    """

    harvest_w: float = 15e-6      # WISP-scale RF harvest
    storage_j: float = 3e-3       # usable capacitor energy
    load_w: float = 200e-6        # active draw while the funnel runs
    jitter: float = 0.2           # +-fraction applied per cycle

    def __post_init__(self):
        for f in ("harvest_w", "storage_j", "load_w"):
            v = getattr(self, f)
            if not (np.isfinite(v) and v > 0):
                raise ValueError(
                    f"BrownoutModel.{f} must be finite and positive, "
                    f"got {v!r}")
        if self.load_w <= self.harvest_w:
            raise ValueError(
                f"BrownoutModel: load_w ({self.load_w}) must exceed "
                f"harvest_w ({self.harvest_w}) or the node never browns "
                "out — drop the model instead of degenerating it")
        if not 0.0 <= self.jitter < 1.0:
            raise ValueError(f"jitter must be in [0, 1), got {self.jitter}")

    @property
    def on_s(self) -> float:
        return self.storage_j / (self.load_w - self.harvest_w)

    @property
    def recharge_s(self) -> float:
        return self.storage_j / self.harvest_w


class FaultInjector:
    """Seeded, deterministic fault process for one offload session.

    Consulted by ``resilience.OffloadSession`` at two points:

    * :meth:`attempt` — per transmit attempt at simulated time ``t``:
      returns ``"ok"`` / ``"lost"`` / ``"corrupt"``.  Loss comes from
      the Gilbert–Elliott chain (advanced once per attempt) OR from a
      timed outage window; a lost-by-channel attempt is reported as
      ``corrupt`` with probability ``corrupt_fraction`` (the payload
      arrives but fails the integrity checksum — detected at the
      receiver rather than by sender timeout).
    * :meth:`power_window` — the node-power schedule from the brownout
      model: on/off windows over simulated time, jittered per cycle.

    Identical seeds + identical query sequences produce identical fault
    sequences (BENCH_resilience.json must reproduce bit-for-bit), and a
    fully-disabled injector is indistinguishable from no injector.
    """

    def __init__(self, *, loss: GilbertElliott | None = None,
                 outage_period_s: float | None = None,
                 outage_duty: float = 0.0,
                 brownout: BrownoutModel | None = None,
                 corrupt_fraction: float = 0.0, seed: int = 0):
        if outage_period_s is not None and outage_period_s <= 0:
            raise ValueError(
                f"outage_period_s must be positive, got {outage_period_s}")
        if not 0.0 <= outage_duty < 1.0:
            raise ValueError(
                f"outage_duty must be in [0, 1), got {outage_duty}")
        if not 0.0 <= corrupt_fraction <= 1.0:
            raise ValueError(
                f"corrupt_fraction must be in [0, 1], got {corrupt_fraction}")
        self.loss = loss
        self.outage_period_s = outage_period_s
        self.outage_duty = float(outage_duty)
        self.brownout = brownout
        self.corrupt_fraction = float(corrupt_fraction)
        self.seed = int(seed)
        self.reset()

    def reset(self):
        """Rewind to the seeded initial state (sweep determinism)."""
        self._rng = np.random.default_rng(self.seed)
        self._power_rng = np.random.default_rng(self.seed + 0x9E3779B9)
        self._bad = False                  # GE chain starts in good
        self._power_edges: list = []       # [on_end_0, off_end_0, on_end_1, ...]
        self.attempts = 0
        self.losses = 0

    # -- link faults ---------------------------------------------------------

    def outage_at(self, t: float) -> bool:
        """Is the link inside a scheduled outage window at time ``t``?

        Outages occupy the last ``outage_duty`` fraction of each period
        (deterministic in *time*, not in the attempt count — retries that
        back off past the window's end escape it, which is the behavior
        the exponential-backoff policy is for).
        """
        if not self.outage_period_s or self.outage_duty <= 0.0:
            return False
        phase = (t / self.outage_period_s) % 1.0
        return phase >= 1.0 - self.outage_duty

    def next_outage_end(self, t: float) -> float:
        """End time of the outage containing ``t`` (t if no outage)."""
        if not self.outage_at(t):
            return t
        period = self.outage_period_s
        return (np.floor(t / period) + 1.0) * period

    def attempt(self, t: float) -> str:
        """Outcome of one transmit attempt starting at time ``t``."""
        self.attempts += 1
        lost = self.outage_at(t)
        if self.loss is not None:
            # advance the chain exactly once per attempt, even during an
            # outage, so the fault sequence depends only on the attempt
            # index (determinism under congestion-shifted timings)
            p = self.loss.loss_bad if self._bad else self.loss.loss_good
            flip = self.loss.p_bg if self._bad else self.loss.p_gb
            chain_lost = self._rng.random() < p
            if self._rng.random() < flip:
                self._bad = not self._bad
            lost = lost or chain_lost
        if not lost:
            return "ok"
        self.losses += 1
        if self.corrupt_fraction and self._rng.random() < self.corrupt_fraction:
            return "corrupt"
        return "lost"

    @property
    def empirical_loss(self) -> float:
        """Observed loss fraction over every attempt so far."""
        return self.losses / self.attempts if self.attempts else 0.0

    # -- node power ----------------------------------------------------------

    def _extend_power_edges(self, until: float):
        bo = self.brownout
        t = self._power_edges[-1] if self._power_edges else 0.0
        while t <= until:
            j = bo.jitter
            on = bo.on_s * (1.0 + j * (2.0 * self._power_rng.random() - 1.0))
            off = bo.recharge_s * (1.0 + j * (2.0 * self._power_rng.random()
                                              - 1.0))
            self._power_edges.extend([t + on, t + on + off])
            t = t + on + off

    def power_window(self, t: float) -> tuple:
        """``(powered, boundary)`` for simulated time ``t``.

        ``powered`` is whether the node has energy at ``t``; ``boundary``
        is when that changes (the brownout instant if powered, the
        recovery instant if not).  Without a brownout model the node is
        always powered (boundary = +inf).
        """
        if self.brownout is None:
            return True, float("inf")
        self._extend_power_edges(t)
        i = int(np.searchsorted(np.asarray(self._power_edges), t,
                                side="right"))
        while i >= len(self._power_edges):
            self._extend_power_edges(self._power_edges[-1] + 1.0)
        # even index -> inside an on-window (next edge is the brownout)
        return i % 2 == 0, float(self._power_edges[i])
