"""Executable offload runtime (DESIGN.md §10).

Splits the live §III/§IV executors at any legal cut point into a
node-side and a cloud-side jit region with a typed, codec-compressed wire
payload between them; replays measured payload traces through a link
simulator; and closes the loop from measured executors back into
``core.placement.solve_cut`` via the cut controller.
"""

from repro.camera.offload.controller import (
    ControllerReport,
    CutController,
    CutMeasurement,
)
from repro.camera.offload.executors import (
    FaceAuthOffloadExecutor,
    VROffloadExecutor,
)
from repro.camera.offload.link import (
    BACKSCATTER,
    ETH_25G_LINK,
    ETH_400G_LINK,
    LinkProfile,
    LinkReport,
    link_energy_w,
    simulate_shared_link,
)
from repro.camera.offload.payloads import WirePayload

__all__ = [
    "BACKSCATTER",
    "ControllerReport",
    "CutController",
    "CutMeasurement",
    "ETH_25G_LINK",
    "ETH_400G_LINK",
    "FaceAuthOffloadExecutor",
    "LinkProfile",
    "LinkReport",
    "VROffloadExecutor",
    "WirePayload",
    "link_energy_w",
    "simulate_shared_link",
]
