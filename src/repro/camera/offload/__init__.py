"""Executable offload runtime (DESIGN.md §10).

Splits the live §III/§IV executors at any legal cut point into a
node-side and a cloud-side jit region with a typed, codec-compressed wire
payload between them; replays measured payload traces through a link
simulator; and closes the loop from measured executors back into
``core.placement.solve_cut`` via the cut controller.  The resilience
layer (DESIGN.md §12) wraps the split executors in fault-tolerant
sessions: seeded burst-loss/outage/brownout injection, checksummed
retransmission charged at real link cost, commit-point brownout
recovery, and a measured graceful-degradation ladder.
"""

from repro.camera.offload.controller import (
    ControllerReport,
    CutController,
    CutMeasurement,
)
from repro.camera.offload.executors import (
    FaceAuthOffloadExecutor,
    VROffloadExecutor,
)
from repro.camera.offload.link import (
    BACKSCATTER,
    ETH_25G_LINK,
    ETH_400G_LINK,
    BrownoutModel,
    FaultInjector,
    GilbertElliott,
    LinkProfile,
    LinkReport,
    link_energy_w,
    simulate_shared_link,
)
from repro.camera.offload.payloads import (
    SESSION_SIDEBAND,
    PayloadSchema,
    WirePayload,
)
from repro.camera.offload.resilience import (
    ON_NODE,
    DegradationLadder,
    DeliveryRecord,
    OffloadSession,
    fleet_link_report,
    payload_checksum,
)

__all__ = [
    "BACKSCATTER",
    "BrownoutModel",
    "ControllerReport",
    "CutController",
    "CutMeasurement",
    "DegradationLadder",
    "DeliveryRecord",
    "ETH_25G_LINK",
    "ETH_400G_LINK",
    "FaceAuthOffloadExecutor",
    "FaultInjector",
    "GilbertElliott",
    "LinkProfile",
    "LinkReport",
    "ON_NODE",
    "OffloadSession",
    "PayloadSchema",
    "SESSION_SIDEBAND",
    "VROffloadExecutor",
    "WirePayload",
    "fleet_link_report",
    "link_energy_w",
    "payload_checksum",
    "simulate_shared_link",
]
