"""Measurement-driven cut controller (closes the §III-D/§IV-C loop).

Until now the repo had two disconnected halves: `core/placement.solve_cut`
ranked *hand-entered* Block descriptors, and the executors ran the real
funnel but never consulted the solver.  The controller connects them:

  1. **Calibrate** — run every legal cut's split executor
     (`camera/offload/executors`) on live data, measuring node/cloud wall
     clock and the wire payload bytes the node half actually charges.
  2. **Fit** — convert the measurements into `core.pipeline.Block`
     descriptors: per-stage time deltas become flops under the node
     profile's rate, measured per-unit wire bytes become ``bytes_out``
     (inverted through the selectivity chain so
     ``Pipeline.cut_payload_bytes`` reproduces the measurement exactly).
  3. **Solve** — feed the measured pipeline to ``solve_cut`` in the
     workload's regime and execute the chosen cut.
  4. **Audit** — compare the analytic template's predicted ranking with
     the measured ranking (pairwise concordance) and verify the chosen
     cut matches the exhaustive measured optimum.

The fitted pipeline marks every block CORE: the split executors always
run the full funnel prefix on the node side, so the optional-subset axis
of the analytic search space is not executable here — the controller
optimizes *where to cut*, which is the axis the runtime actually has.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Mapping, Sequence

from repro.camera.offload.link import LinkProfile, link_energy_w
from repro.core.costmodel import HardwareProfile, energy_cost, throughput_cost
from repro.core.pipeline import Block, BlockKind, Pipeline
from repro.core.placement import CutSolution, solve_cut
from repro.core.timing import timed as _timed


@dataclasses.dataclass(frozen=True)
class CutMeasurement:
    """Live measurements for one cut point."""

    cut: str
    node_s: float                 # node-half seconds per batch (warm)
    cloud_s: float                # cloud-half seconds per batch (warm)
    wire_bytes: float             # measured valid-element bytes per batch
    capacity_bytes: float         # static padded wire size per batch
    units: int                    # source units (frames) in the batch

    @property
    def bytes_per_unit(self) -> float:
        return self.wire_bytes / max(self.units, 1)

    @property
    def node_s_per_unit(self) -> float:
        return self.node_s / max(self.units, 1)


@dataclasses.dataclass(frozen=True)
class ControllerReport:
    """Outcome of one calibrate -> solve -> audit pass."""

    regime: str
    measurements: tuple           # (CutMeasurement, ...) in pipeline order
    measured_pipeline: Pipeline
    solution: CutSolution         # solve_cut on the measured pipeline
    chosen_cut: str
    measured_objectives: dict     # cut -> objective (watts | -fps), measured
    predicted_objectives: dict    # cut -> objective from the analytic template
    measured_best_cut: str

    @property
    def agrees(self) -> bool:
        """Does the solver's pick match the exhaustive measured optimum?"""
        return self.chosen_cut == self.measured_best_cut

    @property
    def rank_agreement(self) -> float:
        """Pairwise concordance of predicted vs measured cut orderings."""
        cuts = [c for c in self.measured_objectives
                if c in self.predicted_objectives]
        pairs = [(a, b) for i, a in enumerate(cuts) for b in cuts[i + 1:]]
        if not pairs:
            return 1.0
        ok = sum(
            1 for a, b in pairs
            if ((self.measured_objectives[a] - self.measured_objectives[b])
                * (self.predicted_objectives[a]
                   - self.predicted_objectives[b])) >= 0)
        return ok / len(pairs)


class CutController:
    """Calibrates, fits, solves and executes the offload cut decision."""

    def __init__(self, make_executor: Callable, cuts: Sequence[str],
                 template: Pipeline, profiles: Mapping[str, HardwareProfile],
                 link: LinkProfile, regime: str = "energy",
                 unit_rate_hz: float = 1.0,
                 duties: Mapping[str, float] | None = None,
                 target_fps: float = 30.0,
                 byte_scale: float = 1.0, time_scale: float = 1.0):
        """``make_executor(cut)`` builds a split executor whose ``encode``
        consumes the calibration inputs and whose ``decode_run`` consumes
        the payload.  ``template`` is the analytic pipeline (its blocks
        must include every name in ``cuts``, in order); ``profiles`` maps
        block name -> node HardwareProfile; ``link`` is an offload
        LinkProfile (converted to the cost model's vocabulary).

        ``byte_scale`` / ``time_scale`` extrapolate toy-resolution
        measurements to the paper's native operating point before fitting
        (payload bytes and per-stage times are linear in pixels at every
        §IV cut) so the fitted pipeline, the analytic template, and the
        link all live at ONE scale.  Identity (1.0) for native-resolution
        workloads like the 176x144 §III funnel."""
        self.make_executor = make_executor
        self.cuts = tuple(cuts)
        self.template = template
        self.profiles = dict(profiles)
        self.link = link
        self.link_hw = HardwareProfile(
            name=link.name, link_bw=link.bytes_per_s,
            joules_per_byte=link.joules_per_byte)
        if regime not in ("energy", "throughput"):
            raise ValueError(regime)
        self.regime = regime
        self.unit_rate_hz = float(unit_rate_hz)
        self.duties = dict(duties) if duties else None
        self.target_fps = float(target_fps)
        self.byte_scale = float(byte_scale)
        self.time_scale = float(time_scale)
        self.executors: dict = {}
        self.measurements: list = []
        # sliding-window live telemetry (serving runtime): cut -> deque of
        # (units, wire_bytes, node_s, cloud_s) samples; resolves counts
        # windowed re-solves actually fired (the DESIGN.md §13 cadence pin)
        self.window = 32
        self._window_obs: dict = {}
        self.resolves = 0
        # optional §15 telemetry sink (set attribute-style by the owner:
        # ``controller.telemetry = repro.obs.Telemetry(...)``); observed
        # after each windowed re-solve, never consulted by the solver
        self.telemetry = None

    # -- 1. calibrate --------------------------------------------------------

    def calibrate(self, *inputs, units: int | None = None,
                  reps: int = 1) -> list:
        """Run every cut's split executor on ``inputs``; returns the
        measurement list (also kept on ``self``)."""
        if units is None:
            units = int(inputs[0].shape[0])
        self.measurements = []
        for cut in self.cuts:
            ex = self.executors.get(cut) or self.make_executor(cut)
            self.executors[cut] = ex
            node_s, payload = _timed(lambda: ex.encode(*inputs), reps=reps)
            cloud_s, _res = _timed(lambda: ex.decode_run(payload), reps=reps)
            m = CutMeasurement(
                cut=cut, node_s=node_s, cloud_s=cloud_s,
                wire_bytes=payload.nbytes(),
                capacity_bytes=payload.capacity_bytes(), units=units)
            self._check_finite(m)
            self.measurements.append(m)
        return self.measurements

    @staticmethod
    def _check_finite(m: CutMeasurement):
        import math

        for field in ("node_s", "cloud_s", "wire_bytes", "capacity_bytes"):
            v = getattr(m, field)
            if not (isinstance(v, (int, float)) and math.isfinite(v)
                    and v >= 0):
                raise ValueError(
                    f"calibration for cut {m.cut!r} produced non-finite "
                    f"{field}={v!r} — the executor's encode/decode_run is "
                    "emitting NaN/inf (check codec bits and input ranges) "
                    "and solve_cut would silently rank garbage")

    def _validated_measurements(self) -> list:
        """Calibration table checked before anything reaches solve_cut.

        Raises a ``ValueError`` NAMING the offending cut for every hole a
        bare ``KeyError`` (or a NaN objective) used to fall through:
        missing measurement, missing hardware profile, a cut absent from
        the analytic template, or a non-finite measured value."""
        if not self.measurements:
            raise RuntimeError("calibrate() first")
        measured = {m.cut for m in self.measurements}
        for cut in self.cuts:
            if cut not in measured:
                raise ValueError(
                    f"no calibration entry for cut {cut!r} — "
                    f"calibrate() measured only {sorted(measured)}; "
                    "re-run calibrate() after changing self.cuts")
        tmpl_names = {b.name for b in self.template.blocks}
        for m in self.measurements:
            self._check_finite(m)
            if m.cut not in self.profiles:
                raise ValueError(
                    f"cut {m.cut!r} has a calibration entry but no "
                    "HardwareProfile in controller.profiles — add one or "
                    "drop the cut")
            if m.cut not in tmpl_names:
                raise ValueError(
                    f"cut {m.cut!r} is not a block of the analytic "
                    f"template {self.template.name!r} "
                    f"(blocks: {sorted(tmpl_names)})")
        return self.measurements

    # -- 2. fit --------------------------------------------------------------

    def measured_pipeline(self) -> Pipeline:
        """Measured Block descriptors: the loop-closing artifact.

        One block per cut point.  ``bytes_out`` is inverted through the
        template's selectivity chain so ``cut_payload_bytes`` returns the
        measured per-unit wire bytes exactly; flops come from measured
        node-time *deltas* under the block profile's rate (so
        ``HardwareProfile.time_for`` reproduces the measured stage time).
        """
        self._validated_measurements()
        blocks = []
        frac = 1.0                       # upstream selectivity product
        prev_node = 0.0
        prev_bytes_in = 0.0
        for m in self.measurements:
            tmpl = self.template.block(m.cut)
            sel = tmpl.selectivity
            bytes_out = (m.bytes_per_unit * self.byte_scale
                         / max(frac * sel, 1e-12))
            stage_s = max(m.node_s_per_unit - prev_node,
                          0.0) * self.time_scale
            prof = self.profiles[m.cut]
            if prof.flops_per_s and frac > 0:
                flops = stage_s * prof.flops_per_s / frac
            else:
                flops = tmpl.flops
            kind = (BlockKind.SOURCE if tmpl.kind is BlockKind.SOURCE
                    else BlockKind.CORE)
            blocks.append(Block(
                name=m.cut, flops=flops, bytes_in=prev_bytes_in,
                bytes_out=bytes_out, kind=kind, selectivity=sel,
                meta=(("measured_stage_s", stage_s),
                      ("measured_wire_bytes", m.bytes_per_unit))))
            frac *= sel
            prev_node = m.node_s_per_unit
            prev_bytes_in = bytes_out
        return Pipeline(f"{self.template.name}|measured", tuple(blocks))

    # -- 3. solve + execute --------------------------------------------------

    def choose(self) -> CutSolution:
        return solve_cut(
            self.measured_pipeline(), self.profiles, self.link_hw,
            regime=self.regime, unit_rate_hz=self.unit_rate_hz,
            duties=self.duties, target_fps=self.target_fps)

    def execute(self, *inputs):
        """Run the solver-chosen cut's split executor end to end."""
        sol = self.choose()
        ex = self.executors[sol.cut_after]
        payload = ex.encode(*inputs)
        return ex.decode_run(payload), payload, sol

    # -- 3b. windowed re-solve (serving runtime, DESIGN.md §13) ---------------

    def observe(self, cut: str, *, units: int, wire_bytes: float,
                node_s: float | None = None, cloud_s: float | None = None):
        """Push one live sample into the sliding window for ``cut``.

        The serving runtime measures real per-micro-batch wire bytes (and,
        when it has them, split wall clocks); anything not measured falls
        back to the calibration table in :meth:`window_measurements`.
        """
        import collections

        if cut not in self.cuts:
            raise ValueError(f"cut {cut!r} not in {self.cuts}")
        dq = self._window_obs.get(cut)
        if dq is None or dq.maxlen != self.window:
            dq = collections.deque(dq or (), maxlen=self.window)
            self._window_obs[cut] = dq
        dq.append((int(units), float(wire_bytes),
                   None if node_s is None else float(node_s),
                   None if cloud_s is None else float(cloud_s)))

    def window_measurements(self,
                            predicted_bytes: Mapping[str, float] | None = None
                            ) -> list:
        """Calibration table with sliding-window live telemetry folded in.

        Windowed samples override the calibrated per-unit wire bytes (and
        node/cloud seconds where the runtime measured them); cuts with no
        live samples keep their calibration row.  ``predicted_bytes`` maps
        cut -> predicted per-unit wire bytes and takes precedence over both
        — the runtime uses it to ask "what would cut c cost for *this*
        stream's measured funnel stats" without executing cut c.
        """
        out = []
        for m in self._validated_measurements():
            dq = self._window_obs.get(m.cut)
            if dq:
                units = max(sum(s[0] for s in dq), 1)
                wire = sum(s[1] for s in dq)

                def _win_s(col, fallback_per_unit):
                    timed = [(s[col], s[0]) for s in dq if s[col] is not None]
                    if not timed:
                        return fallback_per_unit * units
                    return (sum(t for t, _ in timed)
                            / max(sum(u for _, u in timed), 1) * units)

                m = dataclasses.replace(
                    m, units=units, wire_bytes=wire,
                    node_s=_win_s(2, m.node_s_per_unit),
                    cloud_s=_win_s(3, m.cloud_s / max(m.units, 1)))
            if predicted_bytes and m.cut in predicted_bytes:
                m = dataclasses.replace(
                    m, wire_bytes=float(predicted_bytes[m.cut]) * m.units)
            self._check_finite(m)
            out.append(m)
        return out

    def resolve_window(self, *, deadline_s: float | None = None,
                       cut_latency_s: Mapping[str, float] | None = None,
                       predicted_bytes: Mapping[str, float] | None = None
                       ) -> CutSolution:
        """One sliding-window re-solve: :meth:`choose` on the windowed
        table, then a congestion deadline filter.

        ``solve_cut`` has no constraint axis, so the deadline lives here:
        ``cut_latency_s`` maps cut -> predicted shared-link p99 completion
        latency (the runtime anchors it at ``simulate_shared_link``'s
        ``LinkReport.p99_latency_s`` and first-order-adjusts for each
        candidate cut's bytes).  Cuts over ``deadline_s`` are infeasible;
        if the unconstrained optimum is infeasible the cheapest *feasible*
        cut (by the regime objective) wins, and when nothing is feasible
        the minimum-latency cut is the graceful floor — congestion must
        never pick a cut that makes congestion worse.
        """
        saved = self.measurements
        self.measurements = self.window_measurements(predicted_bytes)
        try:
            sol = self.choose()
            self.resolves += 1
            if deadline_s is not None and cut_latency_s:
                lat = {c: float(cut_latency_s.get(c, 0.0)) for c in self.cuts}
                feasible = [c for c in self.cuts if lat[c] <= deadline_s]
                if sol.cut_after not in feasible:
                    pipe = self.measured_pipeline()
                    if feasible:
                        best = min(feasible,
                                   key=lambda c: self._objective(pipe, c))
                    else:
                        best = min(self.cuts, key=lambda c: lat[c])
                    sol = dataclasses.replace(
                        sol, cut_after=best,
                        report=self._report_for(pipe, best),
                        objective=self._objective(pipe, best))
            tel = self.telemetry
            if tel is not None and getattr(tel, "enabled", False):
                tel.counters.bump("controller.resolves")
                tel.emit("dispatch", "resolve_window", cut=sol.cut_after,
                         objective=float(sol.objective),
                         resolves=self.resolves)
            return sol
        finally:
            self.measurements = saved

    def degradation_rungs(self, cut: str | None = None,
                          *, bits_ladder=(16, 8, 4)) -> list:
        """Ordered ``(cut, bits)`` rung list for one granted placement.

        Rung 0 is ``cut`` (the solver's choice when None) at the widest
        codec; faults walk it down through narrower codecs, then retreat
        to the measured-cheapest-bytes cut (the calibration table's own
        answer to "which cut survives a starved link"), and finally to
        the all-on-node terminal rung.  The serving runtime calls this
        per stream with the placement *admission granted* (DESIGN.md
        §14), which may differ from the fleet-global solver choice —
        the ladder degrades the stream it protects, not a hypothetical
        one.
        """
        from repro.camera.offload.resilience import ON_NODE

        self._validated_measurements()
        if cut is None:
            cut = self.choose().cut_after
        elif cut not in self.cuts:
            raise ValueError(f"cut {cut!r} not in {tuple(self.cuts)}")
        rungs = [(cut, b) for b in bits_ladder]
        cheapest = min(self.measurements,
                       key=lambda m: m.bytes_per_unit).cut
        if cheapest != cut:
            rungs.append((cheapest, bits_ladder[-1]))
        rungs.append(ON_NODE)
        return rungs

    def degradation_ladder(self, *, bits_ladder=(16, 8, 4), **ladder_kw):
        """Build the resilience ladder from this controller's calibration
        (:meth:`degradation_rungs` at the solver-chosen cut)."""
        from repro.camera.offload.resilience import DegradationLadder

        return DegradationLadder(
            self.degradation_rungs(bits_ladder=bits_ladder), **ladder_kw)

    # -- 4. audit ------------------------------------------------------------

    def _objective(self, pipeline: Pipeline, cut: str) -> float:
        """Regime objective of one cut on ``pipeline`` (watts | -fps).

        One formula for both the measured and the predicted score — the
        solver's own cost functions — so the audit compares *descriptors*
        (measured vs hand-entered), never two different models.
        """
        rep = self._report_for(pipeline, cut)
        return rep.total_w if self.regime == "energy" else -rep.fps

    def _report_for(self, pipeline: Pipeline, cut: str):
        """Regime cost report of one cut on ``pipeline``."""
        if self.regime == "energy":
            return energy_cost(pipeline, self.profiles, self.link_hw, cut,
                               unit_rate_hz=self.unit_rate_hz,
                               duties=self.duties)
        return throughput_cost(pipeline, self.profiles, self.link_hw, cut)

    def report(self) -> ControllerReport:
        measured_pipe = self.measured_pipeline()
        sol = self.choose()
        measured = {m.cut: self._objective(measured_pipe, m.cut)
                    for m in self.measurements}
        tmpl_full = self.template.configure(self.template.optional_names)
        predicted = {}
        for cut in self.cuts:
            predicted[cut] = self._objective(tmpl_full, cut)
        best = min(measured, key=measured.get)
        return ControllerReport(
            regime=self.regime,
            measurements=tuple(self.measurements),
            measured_pipeline=measured_pipe,
            solution=sol,
            chosen_cut=sol.cut_after,
            measured_objectives=measured,
            predicted_objectives=predicted,
            measured_best_cut=best,
        )

    def comm_watts(self, cut: str) -> float:
        """Measured transmit power at ``cut`` (closed-form link energy)."""
        m = {m.cut: m for m in self.measurements}[cut]
        return link_energy_w(m.bytes_per_unit, self.unit_rate_hz, self.link)
