"""Panorama composition (paper Fig. 10 B5): project + feather-blend.

The stitch block is computationally marginal next to BSSA (§IV-C: "The
computation cost of image stitching is marginal compared to BSSA") but its
*output size* is what makes offload feasible — it is the pipeline's last
data-reduction step.  We implement a cylindrical-projection stitcher with
feathered blending over camera seams, enough to measure the real
bytes-in/bytes-out the cost model uses.

Every stage is batched over the view axis (no per-view Python loops):
warping is one gather over (n, h, w), blending one scatter-add into the
canvas — so the whole ring composes inside a single jit region and the
rig executor (camera.pipelines.VRRigExecutor) can fuse it after the
vmapped depth stage.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def cylindrical_warp(img: jax.Array, f: float) -> jax.Array:
    """Project (..., h, w) image(s) onto a cylinder of focal length f
    (pixels).  Batched: leading axes are carried through the gather."""
    h, w = img.shape[-2:]
    yc, xc = (h - 1) / 2.0, (w - 1) / 2.0
    ys, xs = jnp.mgrid[0:h, 0:w]
    theta = (xs - xc) / f
    hh = (ys - yc) / f
    x_src = f * jnp.tan(theta) + xc
    y_src = hh * f / jnp.cos(theta) + yc
    # clamp in float BEFORE the int cast: tan/cos blow up near the cylinder
    # edge, and float->int casts of NaN/inf are backend-defined (the masked
    # lanes must still index in-bounds).  Same values wherever ``valid``.
    x0 = jnp.clip(x_src, 0, w - 1).astype(jnp.int32)
    y0 = jnp.clip(y_src, 0, h - 1).astype(jnp.int32)
    valid = (x_src >= 0) & (x_src < w) & (y_src >= 0) & (y_src < h)
    return jnp.where(valid, img[..., y0, x0], 0.0)


def feather_ramp(w: int, overlap: int) -> jax.Array:
    """Per-tile blend weight profile: linear up / flat / linear down.

    Adjacent tiles overlap by ``overlap`` columns; there the falling ramp of
    tile i and the rising ramp of tile i+1 sum to exactly 1 (seam
    continuity — pinned in tests/test_stitch.py)."""
    return jnp.concatenate([
        jnp.linspace(0, 1, overlap),
        jnp.ones(w - 2 * overlap),
        jnp.linspace(1, 0, overlap),
    ])


def feather_blend(tiles, overlap: int):
    """Blend horizontally-adjacent warped tiles with linear feathering.

    tiles: (n, h, w) array (or list of (h, w) arrays); adjacent tiles share
    ``overlap`` columns.  One scatter-add builds the canvas and the weight
    field for all tiles at once.
    """
    tiles = jnp.asarray(tiles)
    n, h, w = tiles.shape
    step = w - overlap
    total_w = step * (n - 1) + w
    ramp = feather_ramp(w, overlap)
    cols = (jnp.arange(n) * step)[:, None] + jnp.arange(w)[None, :]   # (n, w)
    weighted = jnp.moveaxis(tiles * ramp, 0, 1).reshape(h, n * w)
    canvas = jnp.zeros((h, total_w)).at[:, cols.reshape(-1)].add(weighted)
    weight = jnp.zeros((total_w,)).at[cols.reshape(-1)].add(jnp.tile(ramp, n))
    return canvas / jnp.maximum(weight, 1e-6)


def stitch_ring(views, focal: Optional[float] = None,
                overlap_frac: float = 0.15):
    """Stitch a ring of camera views ((n, h, w) or list) into a panorama
    strip — one batched warp, one batched blend."""
    views = jnp.asarray(views)
    h, w = views.shape[-2:]
    f = focal or 0.8 * w
    warped = cylindrical_warp(views, f)
    return feather_blend(warped, int(w * overlap_frac))


def stereo_panorama(left_views, right_views, depths, ipd_px: float = 6.0):
    """Assemble the stereo pair: right-eye views are re-projected by a
    disparity proportional to inverse depth (view synthesis lite).  The
    per-view re-projection is one batched gather, so the whole assembly is
    jit-compatible (no host round-trip on the depth maxima)."""
    left_views = jnp.asarray(left_views)
    right_views = jnp.asarray(right_views)
    depths = jnp.asarray(depths)                  # (n, h, w)
    w = right_views.shape[-1]
    dmax = jnp.maximum(depths.max(axis=(-2, -1), keepdims=True), 1e-6)
    # clamp the disparity in float before casting: the cast of a NaN depth
    # would be backend-defined, and the shift can never usefully exceed the
    # row width anyway (the gather index is re-clipped below).
    shift = jnp.clip(ipd_px * depths / dmax, 0, w - 1).astype(jnp.int32)
    xs = jnp.clip(jnp.arange(w)[None, None, :] - shift, 0, w - 1)
    shifted = jnp.take_along_axis(right_views, xs, axis=-1)
    return stitch_ring(left_views), stitch_ring(shifted)
