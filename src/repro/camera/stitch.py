"""Panorama composition (paper Fig. 10 B5): project + feather-blend.

The stitch block is computationally marginal next to BSSA (§IV-C: "The
computation cost of image stitching is marginal compared to BSSA") but its
*output size* is what makes offload feasible — it is the pipeline's last
data-reduction step.  We implement a cylindrical-projection stitcher with
feathered blending over camera seams, enough to measure the real
bytes-in/bytes-out the cost model uses.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp


def cylindrical_warp(img: jax.Array, f: float) -> jax.Array:
    """Project an (h, w) image onto a cylinder of focal length f (pixels)."""
    h, w = img.shape
    yc, xc = (h - 1) / 2.0, (w - 1) / 2.0
    ys, xs = jnp.mgrid[0:h, 0:w]
    theta = (xs - xc) / f
    hh = (ys - yc) / f
    x_src = f * jnp.tan(theta) + xc
    y_src = hh * f / jnp.cos(theta) + yc
    x0 = jnp.clip(x_src.astype(jnp.int32), 0, w - 1)
    y0 = jnp.clip(y_src.astype(jnp.int32), 0, h - 1)
    valid = (x_src >= 0) & (x_src < w) & (y_src >= 0) & (y_src < h)
    return jnp.where(valid, img[y0, x0], 0.0)


def feather_blend(tiles, overlap: int):
    """Blend horizontally-adjacent warped tiles with linear feathering.

    tiles: list of (h, w) arrays; adjacent tiles share ``overlap`` columns.
    """
    h, w = tiles[0].shape
    step = w - overlap
    total_w = step * (len(tiles) - 1) + w
    canvas = jnp.zeros((h, total_w))
    weight = jnp.zeros((h, total_w))
    ramp = jnp.concatenate([
        jnp.linspace(0, 1, overlap),
        jnp.ones(w - 2 * overlap),
        jnp.linspace(1, 0, overlap),
    ])
    for i, tile in enumerate(tiles):
        x0 = i * step
        canvas = canvas.at[:, x0:x0 + w].add(tile * ramp)
        weight = weight.at[:, x0:x0 + w].add(ramp)
    return canvas / jnp.maximum(weight, 1e-6)


def stitch_ring(views, focal: float = None, overlap_frac: float = 0.15):
    """Stitch a ring of camera views into a panorama strip."""
    h, w = views[0].shape
    f = focal or 0.8 * w
    warped = [cylindrical_warp(jnp.asarray(v), f) for v in views]
    overlap = int(w * overlap_frac)
    return feather_blend(warped, overlap)


def stereo_panorama(left_views, right_views, depths, ipd_px: float = 6.0):
    """Assemble the stereo pair: right-eye views are re-projected by a
    disparity proportional to inverse depth (view synthesis lite)."""
    left_pano = stitch_ring(left_views)
    shifted = []
    for v, d in zip(right_views, depths):
        dmax = float(jnp.maximum(jnp.max(d), 1e-6))
        shift = (ipd_px * (d / dmax)).astype(jnp.int32)
        xs = jnp.clip(jnp.arange(v.shape[1])[None, :] - shift, 0, v.shape[1] - 1)
        shifted.append(jnp.take_along_axis(jnp.asarray(v), xs, axis=1))
    right_pano = stitch_ring(shifted)
    return left_pano, right_pano
