"""Motion detection — the pipeline's first optional data-reduction block.

Paper §II-A: "an optional motion detection block can reduce the bandwidth
and ensuing power consumption of core blocks."  The WISPCam-class
implementation is a frame-difference comparator; we reproduce exactly
that: mean absolute difference against the previous frame, thresholded,
optionally on a downsampled grid (the ASIC's analog comparator operates on
a coarse pixel grid to stay in the uW range).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def downsample(frame: jax.Array, factor: int = 8) -> jax.Array:
    h, w = frame.shape[-2:]
    h2, w2 = h // factor * factor, w // factor * factor
    f = frame[..., :h2, :w2]
    f = f.reshape(*f.shape[:-2], h2 // factor, factor, w2 // factor, factor)
    return jnp.mean(f, axis=(-3, -1))


def motion_score(prev: jax.Array, cur: jax.Array, factor: int = 8) -> jax.Array:
    """Mean |Δ| on a coarse grid; scalar per frame (batched over leading dims)."""
    dp = downsample(prev, factor)
    dc = downsample(cur, factor)
    return jnp.mean(jnp.abs(dc - dp), axis=(-2, -1))


def motion_mask(frames: jax.Array, threshold: float = 0.01, factor: int = 8):
    """frames: (n, h, w).  Returns (n,) bool — frame passed motion detection.
    Frame 0 never passes (no reference), matching a cold-start sensor."""
    prev = frames[:-1]
    cur = frames[1:]
    scores = motion_score(prev, cur, factor)
    return jnp.concatenate([jnp.zeros((1,), bool), scores > threshold]), scores
