"""Fleet-scale streaming serving runtime (DESIGN.md §13).

The continuous front door over the §III executors: dynamic stream churn,
per-stream frame queues, capacity-padded micro-batches under a latency
SLO via the bugfixed ``cascade_serve`` admission path, measured-byte
congestion monitoring through ``simulate_shared_link``, and sliding-window
per-stream cut re-solves via ``CutController.resolve_window``.
"""

from repro.camera.serve.bytes_model import (FA_CUTS, fa_cut_bytes,
                                            fa_quiet_bytes)
from repro.camera.serve.runtime import (AdmissionDecision, Completion,
                                        ServeConfig, StreamingServer,
                                        TickReport)

__all__ = [
    "AdmissionDecision",
    "Completion",
    "FA_CUTS",
    "ServeConfig",
    "StreamingServer",
    "TickReport",
    "fa_cut_bytes",
    "fa_quiet_bytes",
]
