"""Fleet-scale streaming serving runtime (DESIGN.md §13, chaos plane §14).

The continuous front door over the §III executors: dynamic stream churn,
per-stream frame queues, capacity-padded micro-batches under a latency
SLO via the bugfixed ``cascade_serve`` admission path, measured-byte
congestion monitoring through ``simulate_shared_link``, and sliding-window
per-stream cut re-solves via ``CutController.resolve_window``.

The §14 chaos plane hardens it against hostile fleets: per-stream fault
injection with retry-charged bytes, scripted device loss with pmap
re-sharding, deficit-round-robin fair shedding over bounded queues,
serve-driven degradation ladders, and checkpoint/restore of the full
server state with exactly-once frame accounting.
"""

from repro.camera.serve.bytes_model import (FA_CUTS, fa_attempt_bytes,
                                            fa_cut_bytes, fa_decision_bytes,
                                            fa_quiet_bytes)
from repro.camera.serve.chaos import ChaosEngine, ChaosSpec
from repro.camera.serve.runtime import (AdmissionDecision, Completion,
                                        ServeConfig, ServeError, ShedRecord,
                                        StreamDrainingError, StreamingServer,
                                        TickReport, UnknownStreamError,
                                        chunk_motion_scores)

__all__ = [
    "AdmissionDecision",
    "ChaosEngine",
    "ChaosSpec",
    "Completion",
    "FA_CUTS",
    "ServeConfig",
    "ServeError",
    "ShedRecord",
    "StreamDrainingError",
    "StreamingServer",
    "TickReport",
    "UnknownStreamError",
    "chunk_motion_scores",
    "fa_attempt_bytes",
    "fa_cut_bytes",
    "fa_decision_bytes",
    "fa_quiet_bytes",
]
