"""Per-cut wire-byte predictor for the streaming runtime (DESIGN.md §13).

The offload executors charge valid-element bytes *in-graph*
(``FaceAuthOffloadExecutor._node_fn``); the serving scheduler additionally
needs the same accounting as a host-side *prediction*: "given this
stream's measured funnel stats, what would cut ``c`` put on the wire?" —
that feeds admission control and the windowed re-solve without executing
every candidate cut.  The formulas here mirror ``_node_fn`` term for term
(codec payload + i32/bool sideband at the executors' ``_I32_B``/``_BOOL_B``
rates), so a prediction evaluated at a chunk's *measured* stats equals the
bytes the split executor actually charged.
"""

from __future__ import annotations

from repro.kernels.wire_codec.ops import wire_bytes

_I32_B = 4.0          # index / count sideband bytes per valid entry
_BOOL_B = 1.0 / 8.0   # booleans ship bit-packed

FA_CUTS = ("sensor", "motion", "vj", "nn")


def fa_cut_bytes(cut: str, bits: int | None, *, frames: int, h: int, w: int,
                 motion_frames: float = 0.0, valid_windows: float = 0.0,
                 block: int = 256) -> float:
    """Predicted wire bytes for one ``frames``-frame chunk at ``cut``.

    ``motion_frames`` / ``valid_windows`` are the chunk's (expected) funnel
    stats; zero for both gives the quiet-chunk floor — at every cut past
    the sensor that is a few sideband bytes, while the sensor cut still
    ships every pixel (the paper's early-reduction argument, visible to
    the admission controller).
    """
    if cut not in FA_CUTS:
        raise ValueError(f"cut {cut!r} not in {FA_CUTS}")
    if frames <= 0:
        return 0.0
    m = max(float(motion_frames), 0.0)
    v = max(float(valid_windows), 0.0)

    def codec(n_values: float) -> float:
        return wire_bytes(int(round(n_values)), bits, block=block)

    if cut == "sensor":
        return codec(frames * h * w)
    side = _I32_B * m + _BOOL_B * frames + _I32_B      # fidx+motion+drop
    if cut == "motion":
        return codec(m * h * w) + side
    side += _I32_B * 3 * m                             # n_win/win_drop/casc
    if cut == "vj":
        return codec(v * 20 * 20) + _I32_B * v + side
    return codec(v) + _BOOL_B * v + _I32_B * v + side  # nn: scores+auth+wsel


def fa_quiet_bytes(cut: str, bits: int | None, *, frames: int, h: int,
                   w: int, block: int = 256) -> float:
    """Bytes a chunk with no motion still costs at ``cut``."""
    return fa_cut_bytes(cut, bits, frames=frames, h=h, w=w,
                        motion_frames=0.0, valid_windows=0.0, block=block)


def fa_attempt_bytes(wire_b: float, attempts: int = 1) -> float:
    """On-air bytes of ``attempts`` chaos-plane transmissions of one
    payload (DESIGN.md §14).

    Every attempt — delivered or not — re-ships the payload plus the §12
    session sideband (seq/crc/attempt), so retries congest the shared
    uplink exactly like ``OffloadSession`` retries do.
    """
    from repro.camera.offload.payloads import SESSION_SIDEBAND_BYTES

    if attempts < 0:
        raise ValueError(f"attempts must be >= 0, got {attempts}")
    return float(attempts) * (float(wire_b) + SESSION_SIDEBAND_BYTES)


def fa_decision_bytes(frames: int) -> float:
    """Wire bytes of the all-on-node terminal rung's decision beacon.

    Mirrors ``resilience``'s decision accounting: one packed auth bit per
    frame plus one i32 count — what a ladder-bottomed stream still ships
    so the fleet monitor can tell "degraded but alive" from "dead".
    """
    return max(int(frames), 0) * _BOOL_B + _I32_B
