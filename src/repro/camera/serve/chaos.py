"""Fleet chaos plane for the streaming runtime (DESIGN.md §14).

PR 7's fault machinery protects one :class:`OffloadSession`; this module
lifts the same models to the fleet layer so a whole
:class:`~repro.camera.serve.StreamingServer` can be chaos-tested:

* **Per-stream fault processes** — every registered stream gets its own
  seeded :class:`~repro.camera.offload.link.FaultInjector` (derived
  deterministically from ``(spec.seed, sid)``), so a fleet sweep is
  reproducible bit-for-bit while streams fault *independently* — the
  WISPCam regime, where each camera sees its own channel.
* **Device-loss events** — a scripted schedule of ``kill`` / ``restore``
  events against the serving host's local devices.  The server applies
  them at tick boundaries; a pmapped placement group that loses a device
  re-shards over the survivors (single-device vmap when they stop
  dividing the batch) within one tick.
* **Client brownouts** — ``spec.brownout`` gates each faulty stream's
  *feed* through the injector's jittered power schedule
  (:meth:`ChaosEngine.node_powered`): a harvested-energy camera that is
  dark enqueues nothing.  Server-side brownout is different — the server
  process dies and comes back — and is driven by the harness through
  ``StreamingServer.checkpoint`` / ``StreamingServer.restore``.

The engine only *decides* fault outcomes and event schedules; charging
retry bytes, moving per-stream ladders, and re-sharding groups is the
server's job (``serve/runtime.py``).  An engine whose spec carries no
fault models is inert: ``injector_for`` returns None for every stream
and the served outputs are bit-identical to running without chaos (the
zero-fault pin in BENCH_serving_chaos).
"""

from __future__ import annotations

import dataclasses
import zlib

from repro.camera.offload.link import (BrownoutModel, FaultInjector,
                                       GilbertElliott)

_EVENT_KINDS = ("kill", "restore")


@dataclasses.dataclass(frozen=True)
class ChaosSpec:
    """Declarative fleet fault plan (all knobs optional — empty = inert).

    ``loss`` is a Gilbert–Elliott *template*: every faulty stream runs its
    own chain instance with a derived seed.  ``faulty_fraction`` selects
    which streams fault at all (deterministic per sid, not random per
    run).  ``device_events`` is ``((tick, kind, device_index), ...)`` with
    kind ``"kill"`` or ``"restore"``, applied when the server's tick
    counter *reaches* ``tick``.  Ladder knobs shape the per-stream
    :class:`~repro.camera.offload.resilience.DegradationLadder` the
    server builds for chaos-enabled streams — the window is deliberately
    short and recovery deliberately shorter than PR 7's session default
    (a serve tick aggregates a whole chunk, so symptoms arrive slower
    than per-payload sends).
    """

    loss: GilbertElliott | None = None
    corrupt_fraction: float = 0.0
    brownout: BrownoutModel | None = None
    faulty_fraction: float = 1.0
    max_retries: int = 3
    device_events: tuple = ()
    ladder_window: int = 8
    ladder_max_retry_frac: float = 0.3
    ladder_recover_after: int = 6
    seed: int = 0

    def __post_init__(self):
        if not 0.0 <= self.faulty_fraction <= 1.0:
            raise ValueError(
                f"faulty_fraction must be in [0, 1], got "
                f"{self.faulty_fraction!r}")
        if self.max_retries < 0:
            raise ValueError(
                f"max_retries must be >= 0, got {self.max_retries}")
        for ev in self.device_events:
            if len(ev) != 3 or ev[1] not in _EVENT_KINDS:
                raise ValueError(
                    f"device_events entries are (tick, 'kill'|'restore', "
                    f"device_index), got {ev!r}")
            if int(ev[0]) < 0 or int(ev[2]) < 0:
                raise ValueError(f"negative tick/device in event {ev!r}")

    @property
    def has_stream_faults(self) -> bool:
        return (self.loss is not None or self.brownout is not None
                or self.corrupt_fraction > 0.0)


class ChaosEngine:
    """Seeded fault oracle one :class:`StreamingServer` consults.

    Injectors are created lazily per sid and cached — identical spec +
    identical sid set + identical query order reproduce identical fault
    sequences (the sweep-determinism contract BENCH_serving_chaos pins).
    """

    def __init__(self, spec: ChaosSpec = ChaosSpec()):
        if not isinstance(spec, ChaosSpec):
            raise TypeError(
                f"ChaosEngine wants a ChaosSpec, got {type(spec).__name__}")
        self.spec = spec
        self._injectors: dict = {}

    # -- per-stream fault processes ------------------------------------------

    @staticmethod
    def _salt(sid: str) -> int:
        return zlib.crc32(sid.encode("utf-8")) & 0xFFFFFFFF

    def is_faulty(self, sid: str) -> bool:
        """Does ``sid`` get a fault process at all?  Deterministic in the
        sid (a hash bucket against ``faulty_fraction``), not sampled per
        run — re-registering the same fleet faults the same streams."""
        if not self.spec.has_stream_faults:
            return False
        frac = self.spec.faulty_fraction
        if frac >= 1.0:
            return True
        if frac <= 0.0:
            return False
        return (self._salt(sid) % 10_000) < frac * 10_000

    def injector_for(self, sid: str) -> FaultInjector | None:
        """The stream's own injector (cached), or None for clean streams."""
        if not self.is_faulty(sid):
            return None
        inj = self._injectors.get(sid)
        if inj is None:
            inj = FaultInjector(
                loss=self.spec.loss, brownout=self.spec.brownout,
                corrupt_fraction=self.spec.corrupt_fraction,
                seed=(self.spec.seed * 0x1_0000_0001 + self._salt(sid))
                % (2 ** 63))
            self._injectors[sid] = inj
        return inj

    def fault_id(self, sid: str) -> int:
        """Stable correlation id of ``sid``'s fault process (its derived
        injector seed; 0 for clean streams).  The §15 trace stamps this
        on every link event so a recorded drive can be joined back to
        the exact seeded chaos trajectory offline."""
        if not self.is_faulty(sid):
            return 0
        return (self.spec.seed * 0x1_0000_0001 + self._salt(sid)) % (2 ** 63)

    def node_powered(self, sid: str, t: float) -> bool:
        """Client-side brownout gate: is ``sid``'s camera powered at ``t``?

        Harness-facing — a dark node enqueues nothing (the frames were
        never captured; they are not "lost frames" in the seq audit).
        """
        inj = self.injector_for(sid)
        if inj is None or inj.brownout is None:
            return True
        return inj.power_window(t)[0]

    def retx_factor(self, sid: str) -> float:
        """Expected transmissions per delivery under the loss template.

        Admission control inflates a faulty stream's predicted bps by
        this factor so chaos-era retries are budgeted, not discovered.
        """
        if self.spec.loss is None or not self.is_faulty(sid):
            return 1.0
        p = min(self.spec.loss.stationary_loss, 0.9)
        return 1.0 / (1.0 - p)

    # -- device-loss schedule -------------------------------------------------

    def events_at(self, tick: int) -> list:
        """``(kind, device_index)`` events scheduled for this tick."""
        return [(kind, int(idx))
                for (tk, kind, idx) in self.spec.device_events
                if int(tk) == int(tick)]
