"""Continuous streaming serving runtime (DESIGN.md §13, chaos plane §14).

Serves N heterogeneous camera streams on one serving host — the WISPCam
fleet shape: thousands of harvested-energy cameras sharing one
backscatter uplink into a cloud that runs (part of) the §III funnel.
Streams register and leave dynamically; frames queue per stream; every
scheduler tick forms capacity-padded micro-batches and pushes them
through ONE dispatch per placement group:

* the scorer→big-model admission path is the bugfixed
  :func:`repro.serve.engine.cascade_serve` — a chunk motion-energy scorer
  filters quiet chunks in front of the funnel ("Viola-Jones in front of
  the NN" at fleet scale), the compacting cascade bounds the big batch to
  a static capacity, and capacity-overflowed survivors come back as
  deterministic indices that the scheduler *re-queues* (never drops);
* local streams (``cut=None``) run through
  :meth:`FaceAuthExecutor.batch_step` — the fused funnel vmapped across
  the micro-batch (pmapped across devices when they divide);
* offloaded streams run the split executors' node/cloud halves vmapped,
  so per-chunk *measured* wire bytes come out of the same dispatch.

The scorer threshold equals the funnel's own motion threshold, so a
filtered chunk's canonical quiet result is bit-identical to running the
funnel on it — filtering saves compute with zero semantic change (chunk
boundaries are batch boundaries, as everywhere else in the repo).

Admission control and per-stream cut selection close the two carried
ROADMAP items: measured per-tick byte traces replay through
``simulate_shared_link`` every ``link_window`` ticks, and each active
stream's sliding-window funnel stats drive a
``CutController.resolve_window`` re-solve with the link report's
``p99_latency_s`` as the deadline constraint — congestion rises, cuts
retreat toward fewer wire bytes.

**The §14 chaos plane** hardens all of the above against hostile fleets:

* every frame carries a per-stream sequence number; queues are *bounded*
  (``ServeConfig.max_queue_frames``) and overload sheds oldest-first,
  with every shed frame surfaced per-stream in the next
  :class:`TickReport` — never silently dropped;
* micro-batch slots are granted in **deficit-round-robin** order: each
  stream with an eligible chunk accrues one chunk-quantum of deficit per
  tick and spends it on service, so the cascade's keep-lowest-indices
  capacity drop implements fair rotation instead of
  first-registered-wins.  A continuously-backlogged stream is served at
  least once every ``ceil(R / capacity)`` ticks (R = backlogged streams
  on its rung) — the documented starvation bound;
* a :class:`~repro.camera.serve.chaos.ChaosEngine` injects per-stream
  link faults (each served offloaded chunk transits its stream's seeded
  ``FaultInjector`` with bounded retries, every attempt charged real
  uplink bytes) and scripted device-loss events — a pmapped local
  placement group that loses a device re-shards over the survivors
  within one tick (vmap fallback when they stop dividing);
* each faulty offloaded stream carries a serve-driven
  ``DegradationLadder`` fed by fleet symptoms (delivery failures,
  retransmit fraction, deadline misses widened by the link report's
  p99): sustained faults walk the stream down to narrower codecs, the
  cheapest cut, finally all-on-node; ``recover_after`` clean deliveries
  walk it back up.  While a ladder holds a stream below rung 0 the
  windowed ``resolve_window`` skips it — the ladder has the wheel during
  an incident, the solver gets it back in the clean state;
* :meth:`StreamingServer.checkpoint` persists the full server state at a
  tick boundary through ``ckpt/checkpoint.py`` and
  :meth:`StreamingServer.restore` rebuilds a server that resumes with no
  frame lost or double-served — :meth:`StreamingServer.seq_audit` proves
  the accounting.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque

import numpy as np

from repro.camera.serve.bytes_model import (FA_CUTS, fa_cut_bytes,
                                            fa_decision_bytes,
                                            fa_quiet_bytes)

_RESULT_KEYS = ("motion", "n_windows", "n_auth", "scores", "window_id",
                "window_valid", "auth", "windows_dropped", "motion_dropped",
                "cascade_dropped")

# the resilience module's terminal rung, by value (see resilience.ON_NODE)
_ON_NODE = ("on_node", None)


class ServeError(ValueError):
    """Named serving-layer contract violation (DESIGN.md §14).

    Subclasses ``ValueError`` so pre-§14 callers that caught the bare
    errors keep working; new callers catch the named family.
    """


class UnknownStreamError(ServeError):
    """An operation referenced a stream id the server does not know."""

    def __init__(self, sid, known):
        known = sorted(known)
        shown = ", ".join(repr(s) for s in known[:8])
        if len(known) > 8:
            shown += f", ... ({len(known)} total)"
        super().__init__(
            f"unknown stream {sid!r}; known streams: [{shown}]"
            if known else
            f"unknown stream {sid!r}; no streams are registered")
        self.sid = sid


class StreamDrainingError(ServeError):
    """The sid is still draining — re-register after the drain completes."""

    def __init__(self, sid, frames_left):
        super().__init__(
            f"stream {sid!r} is draining ({frames_left} frames still "
            "queued); re-registering now would clobber them — wait for "
            "the drain to complete")
        self.sid = sid
        self.frames_left = frames_left


def chunk_motion_scores(chunks, motion_factor):
    """Chunk motion energy — the cascade's cheap scorer.

    ``chunks`` is ``(n, chunk, h, w)``; returns the max intra-chunk
    transition score per chunk (``-inf`` for single-frame chunks, which
    can never clear a strictly-positive threshold).  Module-level so the
    §11 analyzer can trace the admission scorer without a live server.
    """
    import jax.numpy as jnp

    from repro.camera.motion import motion_score

    if chunks.shape[1] < 2:
        return jnp.full((chunks.shape[0],), -np.inf, jnp.float32)
    sc = motion_score(chunks[:, :-1], chunks[:, 1:], motion_factor)
    return jnp.max(sc, axis=-1)


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Scheduler contract knobs (DESIGN.md §13/§14)."""

    chunk: int = 4              # frames per micro-batch slot
    capacity: int = 8           # micro-batch slots per placement group/tick
    slo_s: float = 0.5          # p99 micro-batch dispatch latency SLO (wall)
    tick_s: float = 1.0         # scheduler period (simulated seconds)
    max_queue_s: float = 6.0    # flush a partial chunk older than this
    resolve_every: int = 16     # served frames between per-stream re-solves
    link_window: int = 8        # ticks of byte traces per congestion report
    admit_util: float = 0.7     # uplink utilization ceiling at admission
    admit_headroom: float = 0.8 # admit only while link p99 <= headroom*slo
    admit_motion_frac: float = 0.5   # activity prior for undeclared streams
    admit_windows_per_frame: float = 2.0
    stats_window: int = 32      # chunks of funnel stats per stream window
    max_queue_frames: int = 64  # per-stream queue bound; overflow sheds
                                # oldest-first (0 disables the bound)


@dataclasses.dataclass(frozen=True)
class AdmissionDecision:
    admitted: bool
    sid: str
    cut: str | None             # placement actually granted (may differ)
    bits: int | None
    reason: str
    predicted_bps: float = 0.0
    predicted_util: float = 0.0


@dataclasses.dataclass(frozen=True)
class Completion:
    """One chunk's delivery: per-frame leaves sliced to the real frames."""

    sid: str
    t: float
    n_frames: int
    kind: str                   # "served" | "quiet"
    result: dict                # FAExecResult fields, leading axis n_frames
    wire_bytes: float
    seqs: tuple = ()            # per-frame sequence numbers, len n_frames


@dataclasses.dataclass(frozen=True)
class ShedRecord:
    """Frames shed from one stream's bounded queue — surfaced, not silent."""

    sid: str
    seqs: tuple                 # shed frames' sequence numbers, oldest first
    arrivals: tuple             # their enqueue times


@dataclasses.dataclass(frozen=True)
class TickReport:
    t: float
    n_ready: int
    n_served: int
    n_quiet: int
    n_requeued: int
    batch_s: float              # wall clock of this tick's dispatches
    bytes_sent: float
    completions: tuple          # (Completion, ...)
    resolves_fired: int
    cut_changes: tuple          # ((sid, old_cut, new_cut), ...)
    shed: tuple = ()            # (ShedRecord, ...) since the last tick
    n_failed_tx: int = 0        # chunks whose delivery exhausted retries
    ladder_moves: tuple = ()    # ((sid, old_level, new_level), ...)
    device_events: tuple = ()   # (("kill"|"restore", device_index), ...)


@dataclasses.dataclass
class _Stream:
    sid: str
    fps: float
    cut: str | None
    bits: int | None
    t_join: float
    queue: deque                # (t_arrival, frame, seq) FIFO, seq ascending
    draining: bool = False
    frames_done: int = 0
    frames_since_resolve: int = 0
    resolves: int = 0
    requeues: int = 0
    declared_bps: float = 0.0
    seq_next: int = 0           # next sequence number to assign
    delivered_n: int = 0        # frames delivered in completions
    last_served_seq: int = -1   # highest seq ever delivered (monotone)
    shed_n: int = 0             # frames shed from the bounded queue
    tx_failures: int = 0        # chunk deliveries that exhausted retries
    deficit: float = 0.0        # DRR service credit, in frames
    order: int = 0              # registration rank (DRR tiebreak)
    ladder: object = None       # DegradationLadder | None (chaos plane)
    pending_shed: list = dataclasses.field(default_factory=list)
    stats: deque = dataclasses.field(
        default_factory=lambda: deque(maxlen=32))   # (n, motion, windows)
    trace: deque = dataclasses.field(
        default_factory=lambda: deque(maxlen=8))    # bytes per tick
    transitions: list = dataclasses.field(default_factory=list)

    @property
    def rung(self):
        """Effective placement: the ladder's rung while it holds the
        stream below rung 0 (``ON_NODE`` maps to the local group), the
        granted ``(cut, bits)`` otherwise."""
        if self.ladder is not None and self.ladder.level > 0:
            r = tuple(self.ladder.rung)
            return (None, None) if r == _ON_NODE else r
        return (self.cut, self.bits if self.cut is not None else None)

    def window_stats(self):
        """Sliding-window mean (motion_frames, valid_windows) per chunk."""
        rows = [r for r in self.stats if r[0] > 0]
        if not rows:
            return 0.0, 0.0
        n = len(rows)
        return (sum(r[1] for r in rows) / n, sum(r[2] for r in rows) / n)


@dataclasses.dataclass(frozen=True)
class _ReadyChunk:
    sid: str
    frames: np.ndarray          # (chunk, h, w) f32, padded with last frame
    arrivals: tuple             # simulated arrival times, len n_real
    seqs: tuple                 # per-frame sequence numbers, len n_real
    n_real: int


class StreamingServer:
    """Fleet-scale streaming front door over one :class:`FaceAuthExecutor`.

    ``base`` must be calibrated.  ``controller`` (a ``CutController``
    calibrated for the same base) enables windowed per-stream cut
    re-solves; without it, granted cuts are static.  ``link`` is the
    shared uplink every offloaded stream transmits on.  ``chaos`` (a
    :class:`~repro.camera.serve.chaos.ChaosSpec` or ``ChaosEngine``)
    arms the §14 fault plane; None — or an inert spec — leaves every
    served output bit-identical to the pre-chaos runtime.  ``telemetry``
    (a :class:`repro.obs.Telemetry`) arms the §15 observability plane:
    per-tick spans, link/chaos/ladder/failover trace events, fleet
    counters, and the per-(stream, rung) SLO ledger; None or disabled
    changes nothing — not one host branch, not one traced graph.
    """

    def __init__(self, base, *, link=None, controller=None,
                 config: ServeConfig = ServeConfig(), chaos=None,
                 telemetry=None):
        import jax

        from repro.camera.offload.link import BACKSCATTER
        from repro.obs.telemetry import telemetry_on

        self.base = base
        self.cfg = config
        self.link = link or BACKSCATTER
        self.controller = controller
        # §15 telemetry plane: None or disabled leaves every host code
        # path and every traced graph exactly as the pre-obs runtime
        self.telemetry = telemetry
        self._tel_on = telemetry_on(telemetry)
        self.h, self.w = base.det.grid.h, base.det.grid.w
        self._streams: dict = {}
        self._group_steps: dict = {}
        self._offload_execs: dict = {}
        self._quiet_cache: dict = {}
        self.tick_count = 0
        self.frames_completed = 0
        self.batch_lat_s: list = []      # wall seconds per dispatching tick
        self.queue_delay_s: list = []    # simulated frame sojourn times
        self.last_link_report = None
        self.rejections: list = []
        self.total_enqueued = 0          # fleet seq-accounting counters:
        self.total_delivered = 0         # survive stream churn so the
        self.total_shed = 0              # audit holds across reaps
        self._order_counter = 0
        self._devices = list(jax.local_devices())
        self._dead: set = set()          # dead device indices
        self._chaos = self._wrap_chaos(chaos)
        # scorer semantics == the funnel's motion gate: survive iff any
        # intra-chunk transition scores strictly above motion_threshold
        self._score_threshold = float(np.nextafter(
            np.float32(base.motion_threshold), np.float32(np.inf)))

    @staticmethod
    def _wrap_chaos(chaos):
        if chaos is None:
            return None
        from repro.camera.serve.chaos import ChaosEngine, ChaosSpec

        if isinstance(chaos, ChaosEngine):
            return chaos
        if isinstance(chaos, ChaosSpec):
            return ChaosEngine(chaos)
        raise TypeError(
            f"chaos= wants a ChaosSpec or ChaosEngine, got "
            f"{type(chaos).__name__}")

    # -- registration / churn -------------------------------------------------

    def register(self, sid: str, *, fps: float = 1.0, cut: str | None = None,
                 bits: int | None = 8, t: float = 0.0,
                 motion_frac: float | None = None) -> AdmissionDecision:
        """Admit (or reject, or re-place) one new stream.

        Local streams (``cut=None``) are admitted against the compute
        budget; offloaded streams against the shared-uplink budget — if
        the requested cut does not fit, cheaper-byte cuts are tried before
        rejecting, so a stream may be granted a different placement than
        it asked for (congestion-aware placement at admission time).
        Under chaos, a faulty stream's predicted bps is inflated by its
        expected retransmission factor so retries are budgeted up front.
        """
        st = self._streams.get(sid)
        if st is not None:
            if st.draining:
                raise StreamDrainingError(sid, len(st.queue))
            raise ServeError(f"stream {sid!r} already registered")
        cfg = self.cfg
        if cut is None:
            projected = sum(s.fps for s in self._streams.values()
                            if s.cut is None) + fps
            budget = cfg.capacity * cfg.chunk / cfg.tick_s
            if projected > cfg.admit_headroom * budget:
                dec = AdmissionDecision(
                    False, sid, None, None,
                    f"compute: {projected:.1f} fps over "
                    f"{cfg.admit_headroom * budget:.1f} fps budget")
                self.rejections.append(dec)
                return dec
            self._admit(sid, fps, None, None, t, 0.0)
            return AdmissionDecision(True, sid, None, None, "admitted")

        if cut not in FA_CUTS:
            raise ServeError(f"cut {cut!r} not in {FA_CUTS}")
        frac = cfg.admit_motion_frac if motion_frac is None else motion_frac
        retx = self._chaos.retx_factor(sid) if self._chaos is not None else 1.0
        fleet_bps = sum(s.declared_bps for s in self._streams.values())
        p99 = (self.last_link_report.p99_latency_s
               if self.last_link_report is not None else 0.0)
        if p99 > cfg.admit_headroom * cfg.slo_s:
            dec = AdmissionDecision(
                False, sid, cut, bits,
                f"congestion: link p99 {p99:.3f}s over "
                f"{cfg.admit_headroom * cfg.slo_s:.3f}s headroom")
            self.rejections.append(dec)
            return dec
        candidates = [cut] + [c for c in FA_CUTS if c != cut]
        candidates.sort(key=lambda c: (c != cut,
                                       self._predict_bps(c, bits, fps, frac)))
        for c in candidates:
            bps = self._predict_bps(c, bits, fps, frac) * retx
            util = (fleet_bps + bps) / self.link.bytes_per_s
            if util <= cfg.admit_util:
                reason = ("admitted" if c == cut else
                          f"re-placed from {cut!r}: requested cut over "
                          f"{cfg.admit_util:.0%} uplink utilization")
                self._admit(sid, fps, c, bits, t, bps)
                return AdmissionDecision(True, sid, c, bits, reason, bps, util)
        bps = self._predict_bps(candidates[-1], bits, fps, frac) * retx
        dec = AdmissionDecision(
            False, sid, cut, bits,
            f"uplink: even cheapest cut exceeds {cfg.admit_util:.0%} "
            f"utilization ({fleet_bps:.0f}+{bps:.0f} B/s of "
            f"{self.link.bytes_per_s:.0f})", bps,
            (fleet_bps + bps) / self.link.bytes_per_s)
        self.rejections.append(dec)
        return dec

    def _predict_bps(self, cut, bits, fps, motion_frac):
        cfg = self.cfg
        chunk_b = fa_cut_bytes(
            cut, bits, frames=cfg.chunk, h=self.h, w=self.w,
            motion_frames=motion_frac * cfg.chunk,
            valid_windows=motion_frac * cfg.chunk
            * cfg.admit_windows_per_frame)
        return chunk_b / cfg.chunk * fps

    def _admit(self, sid, fps, cut, bits, t, bps):
        cfg = self.cfg
        st = _Stream(sid=sid, fps=fps, cut=cut,
                     bits=bits if cut is not None else None, t_join=t,
                     queue=deque(), declared_bps=bps,
                     order=self._order_counter)
        self._order_counter += 1
        st.stats = deque(maxlen=cfg.stats_window)
        st.trace = deque([0.0] * min(self.tick_count, cfg.link_window),
                         maxlen=cfg.link_window)
        st.ladder = self._build_ladder(sid, cut, bits)
        self._streams[sid] = st

    def unregister(self, sid: str) -> int:
        """Begin draining ``sid``; queued frames are still served.

        Returns the number of frames left in the queue — the stream object
        disappears once they have all completed (immediately when empty).
        """
        st = self._streams.get(sid)
        if st is None:
            raise UnknownStreamError(sid, self._streams)
        st.draining = True
        n = len(st.queue)
        if n == 0:
            del self._streams[sid]
        return n

    def enqueue(self, sid: str, frame, t: float) -> int:
        """Queue one frame; returns its per-stream sequence number.

        Validates the frame against the registered stream's geometry
        *here*, where the caller can still tell which stream misbehaved —
        not inside the next tick's fused dispatch.  When the bounded
        queue overflows, the *oldest* queued frames are shed (the stalest
        data is the least useful under overload) and surfaced in the next
        :class:`TickReport`'s ``shed`` records.
        """
        st = self._streams.get(sid)
        if st is None:
            raise UnknownStreamError(sid, self._streams)
        if st.draining:
            raise StreamDrainingError(sid, len(st.queue))
        try:
            arr = np.asarray(frame, np.float32)
        except (TypeError, ValueError) as e:
            raise ServeError(
                f"stream {sid!r}: frame is not float32-castable "
                f"({e})") from e
        if arr.shape != (self.h, self.w):
            raise ServeError(
                f"stream {sid!r}: frame shape {arr.shape} != registered "
                f"({self.h}, {self.w})")
        seq = st.seq_next
        st.seq_next += 1
        self.total_enqueued += 1
        st.queue.append((float(t), arr, seq))
        bound = self.cfg.max_queue_frames
        if bound and len(st.queue) > bound:
            a, _f, sq = st.queue.popleft()
            st.pending_shed.append((a, sq))
            st.shed_n += 1
            self.total_shed += 1
        return seq

    @property
    def streams(self):
        return dict(self._streams)

    # -- chaos plane: devices + ladders ---------------------------------------

    def _healthy(self) -> tuple:
        return tuple(d for i, d in enumerate(self._devices)
                     if i not in self._dead)

    def kill_device(self, idx: int):
        """Simulate losing local device ``idx`` — placement groups that
        pmapped over it re-shard onto the survivors at the next dispatch
        (single-device vmap when the survivors stop dividing)."""
        if not 0 <= idx < len(self._devices):
            raise ServeError(
                f"device index {idx} out of range "
                f"[0, {len(self._devices)})")
        self._dead.add(int(idx))
        if not self._healthy():
            self._dead.discard(int(idx))
            raise ServeError(
                "cannot kill the last healthy device — the serving host "
                "needs at least one")
        if self._tel_on:
            self.telemetry.emit(
                "chaos", "device_kill", t=self.tick_count * self.cfg.tick_s,
                tick=self.tick_count, device=int(idx),
                healthy=len(self._healthy()))
            self.telemetry.counters.bump("serve.device_kills")

    def restore_device(self, idx: int):
        """Bring device ``idx`` back; groups re-shard to the wider set.

        Local closures stay cached per healthy-device set (``_group_step``
        selects by the *current* set every tick, so a stale entry is never
        dispatched), which makes flapping kill/restore cycles recompile
        nothing.
        """
        self._dead.discard(int(idx))
        if self._tel_on:
            self.telemetry.emit(
                "chaos", "device_restore",
                t=self.tick_count * self.cfg.tick_s,
                tick=self.tick_count, device=int(idx),
                healthy=len(self._healthy()))
            self.telemetry.counters.bump("serve.device_restores")

    def _ladder_kwargs(self):
        cfg = self.cfg
        if self._chaos is not None:
            spec = self._chaos.spec
            return dict(window=spec.ladder_window,
                        max_retry_frac=spec.ladder_max_retry_frac,
                        deadline_s=cfg.slo_s,
                        recover_after=spec.ladder_recover_after)
        return dict(deadline_s=cfg.slo_s)

    def _make_ladder(self, rungs):
        from repro.camera.offload.resilience import DegradationLadder

        return DegradationLadder(rungs, **self._ladder_kwargs())

    def _ladder_rungs(self, cut, bits):
        """Rung list below one granted placement: narrower codecs, the
        calibrated-cheapest cut (via the controller when it has
        measurements), then all-on-node."""
        widths = [bits] + [b for b in (8, 4) if bits is None or b < bits]
        if self.controller is not None and \
                getattr(self.controller, "measurements", None):
            try:
                return self.controller.degradation_rungs(
                    cut, bits_ladder=tuple(widths))
            except ValueError:
                pass
        return [(cut, b) for b in widths] + [_ON_NODE]

    def _build_ladder(self, sid, cut, bits):
        if self._chaos is None or cut is None:
            return None
        if not self._chaos.is_faulty(sid):
            return None
        return self._make_ladder(self._ladder_rungs(cut, bits))

    def _injector(self, sid):
        return None if self._chaos is None else self._chaos.injector_for(sid)

    def _transmit(self, inj, wire_b, t):
        """One chunk delivery through a stream's fault process.

        Returns ``(delivered, bytes_on_air, attempts, lost, corrupt)``.
        Every attempt re-ships payload + session sideband; exhausted
        retries mean the cloud never saw the chunk (the caller re-queues
        the frames — they are retried, not lost).
        """
        from repro.camera.offload.payloads import SESSION_SIDEBAND_BYTES

        per = float(wire_b) + SESSION_SIDEBAND_BYTES
        max_att = 1 + self._chaos.spec.max_retries
        lost = corrupt = 0
        on_air = 0.0
        for att in range(1, max_att + 1):
            on_air += per
            outcome = inj.attempt(t)
            if outcome == "ok":
                return True, on_air, att, lost, corrupt
            if outcome == "corrupt":
                corrupt += 1
            else:
                lost += 1
        return False, on_air, max_att, lost, corrupt

    def _observe_ladder(self, st, moves, *, rung, delivered, attempts, lost,
                        corrupt, payload_b, on_air, latency_s):
        from repro.camera.offload.resilience import DeliveryRecord

        cut, bits = rung
        rec = DeliveryRecord(
            seq=st.frames_done, cut=cut, bits=bits, delivered=delivered,
            fallback=False, attempts=attempts, lost=lost, corrupt=corrupt,
            payload_bytes=payload_b, bytes_on_air=on_air, compute_s=0.0,
            latency_s=latency_s, energy_j=on_air * self.link.joules_per_byte,
            brownouts=0, restores=0, recovery_s=0.0)
        old = st.ladder.level
        st.ladder.observe(rec)
        if st.ladder.level != old:
            moves.append((st.sid, old, st.ladder.level))
            if self._tel_on:
                from repro.obs.ledger import rung_key as _rk

                self.telemetry.emit(
                    "ladder",
                    "descend" if st.ladder.level > old else "recover",
                    t=self.tick_count * self.cfg.tick_s,
                    tick=self.tick_count, sid=st.sid, old_level=old,
                    new_level=st.ladder.level,
                    rung=_rk(tuple(st.ladder.rung)))
                self.telemetry.counters.bump("serve.ladder_moves")

    # -- placement groups ------------------------------------------------------

    def _bucket(self, n: int) -> int:
        """Round a ready-count up to a multiple of ``capacity``.

        Dispatch batch shapes come from a small static set, so a tick
        never pays a fresh XLA compile just because the number of ready
        chunks drifted by one (p99 dispatch latency would otherwise be
        compile time, not compute).
        """
        cap = self.cfg.capacity
        return cap * max(1, -(-n // cap))

    def prewarm(self, rungs, *, max_ready: int | None = None,
                device_counts=()):
        """Compile every placement group ahead of the measured ticks.

        Runs one zeros dispatch through the full scorer->cascade->group
        path per ``rung`` x shape bucket (buckets cover ``max_ready``
        ready chunks, default one ``capacity``).  Zero chunks are
        motionless, so nothing is observed and no stats move — this only
        populates the jit caches.

        ``device_counts`` additionally compiles the local group over
        degraded device prefixes (e.g. ``(3, 1)`` on a 4-device host
        whose chaos schedule kills the last device) so failover pays
        compute, not XLA compile.  Kills that leave a non-prefix healthy
        set still work but compile lazily at the first degraded tick.
        """
        import jax
        import jax.numpy as jnp

        from repro.serve.engine import cascade_serve

        cfg = self.cfg
        top = self._bucket(max_ready or cfg.capacity)
        widths = range(cfg.capacity, top + 1, cfg.capacity)
        healthy = self._healthy()
        local_keys = [None if not self._dead
                      else tuple(d.id for d in healthy)]
        for n in device_counts:
            n = max(1, min(int(n), len(healthy)))
            local_keys.append(tuple(d.id for d in healthy[:n]))
        steps = []
        for rung in rungs:
            if rung == (None, None):
                steps.extend(self._local_step_for(k) for k in local_keys)
            else:
                steps.append(self._group_step(rung))
        for step in steps:
            for b in widths:
                stack = jnp.zeros((b, cfg.chunk, self.h, self.w),
                                  jnp.float32)
                out = cascade_serve(self._scores, step, stack,
                                    threshold=self._score_threshold,
                                    capacity=cfg.capacity)
                jax.block_until_ready(out)

    def _local_step_for(self, devices_key):
        """Local placement-group step over one healthy-device set.

        ``devices_key`` is None for "all local devices" (the pre-chaos
        closure, bit-identical to PR 8) or a tuple of device ids — the
        failover shape after kills.  Cached per key, so restoring a
        previously-seen set re-dispatches without compiling.
        """
        key = ((None, None), devices_key)
        step = self._group_steps.get(key)
        if step is not None:
            return step
        import jax.numpy as jnp

        cap, chunk = self.cfg.capacity, self.cfg.chunk
        if devices_key is None:
            inner = self.base.batch_step(cap, chunk)
        else:
            by_id = {d.id: d for d in self._devices}
            inner = self.base.batch_step(
                cap, chunk, devices=[by_id[i] for i in devices_key])
        ones = jnp.ones((cap,), bool)

        def step(chunks):
            out = dict(inner(chunks, ones))
            out["wire_b"] = jnp.zeros((cap,), jnp.float32)
            return out

        self._group_steps[key] = step
        return step

    def _group_step(self, rung):
        """Cached single-dispatch micro-batch closure for one placement."""
        cut, bits = rung
        if cut is None:
            healthy_key = (None if not self._dead
                           else tuple(d.id for d in self._healthy()))
            return self._local_step_for(healthy_key)
        key = (rung, None)
        step = self._group_steps.get(key)
        if step is not None:
            return step
        import jax

        chunk = self.cfg.chunk

        from repro.camera.offload.executors import FaceAuthOffloadExecutor

        off = self._offload_execs.get(rung)
        if off is None:
            off = FaceAuthOffloadExecutor(self.base, cut, bits=bits,
                                          use_pallas=False)
            self._offload_execs[rung] = off
        consts = tuple(off._consts)
        shape = (chunk, self.h, self.w)

        def one(frames):
            arrays, wire_b = off._node_fn(frames, *consts)
            res = off._cloud_fn(arrays, *consts, frames_shape=shape)
            out = dict(res)
            out["wire_b"] = wire_b
            return out

        step = jax.jit(jax.vmap(one))
        self._group_steps[key] = step
        return step

    def _scores(self, chunks):
        """Chunk motion energy — the cascade's cheap scorer."""
        return chunk_motion_scores(chunks, self.base.motion_factor)

    def _quiet_result(self, n):
        res = self._quiet_cache.get(n)
        if res is None:
            W = self.base.stages.window_capacity
            res = dict(
                motion=np.zeros(n, bool),
                n_windows=np.zeros(n, np.int32),
                n_auth=np.zeros(n, np.int32),
                scores=np.zeros((n, W), np.float32),
                window_id=np.full((n, W), -1, np.int32),
                window_valid=np.zeros((n, W), bool),
                auth=np.zeros((n, W), bool),
                windows_dropped=np.zeros(n, np.int32),
                motion_dropped=np.int32(0),
                cascade_dropped=np.zeros(n, np.int32))
            self._quiet_cache[n] = res
        return res

    # -- the tick --------------------------------------------------------------

    def _gather_ready(self, t):
        """Take at most one eligible chunk per stream, in DRR order.

        Deficit-round-robin slot grants: streams are visited by
        ``(-deficit, registration order)``; every visited-and-eligible
        stream accrues one chunk-quantum.  The ready order IS the
        dispatch stack order, so ``cascade_serve``'s deterministic
        keep-lowest-indices capacity drop serves the highest-deficit
        streams first — capacity-dropped streams keep their credit and
        outrank this tick's winners next tick.  With no contention every
        deficit stays zero and the order degenerates to registration
        order — the pre-chaos scheduler, bit for bit.
        """
        cfg = self.cfg
        ready = []
        for st in sorted(self._streams.values(),
                         key=lambda s: (-s.deficit, s.order)):
            q = st.queue
            if not q:
                continue
            full = len(q) >= cfg.chunk
            stale = (t - q[0][0]) >= cfg.max_queue_s
            if not (full or stale or st.draining):
                continue
            st.deficit += float(cfg.chunk)
            n_real = min(cfg.chunk, len(q))
            taken = [q.popleft() for _ in range(n_real)]
            frames = [f for _, f, _ in taken]
            while len(frames) < cfg.chunk:      # pad: repeated last frame is
                frames.append(frames[-1])       # motionless, hence quiet
            ready.append(_ReadyChunk(
                sid=st.sid, frames=np.stack(frames),
                arrivals=tuple(a for a, _, _ in taken),
                seqs=tuple(s for _, _, s in taken), n_real=n_real))
        return ready

    def _collect_shed(self):
        # canonical sorted-sid order — the same ordering seq_audit uses,
        # so shed records and audit rows line up row-for-row (PR 10 fix;
        # previously both walked dict insertion order, which diverges
        # from each other after churn re-registers a stream)
        shed = []
        for st in sorted(self._streams.values(), key=lambda s: s.sid):
            if st.pending_shed:
                shed.append(ShedRecord(
                    sid=st.sid,
                    seqs=tuple(sq for _, sq in st.pending_shed),
                    arrivals=tuple(a for a, _ in st.pending_shed)))
                st.pending_shed = []
        return tuple(shed)

    def _requeue(self, st, rc):
        for a, f, sq in zip(reversed(rc.arrivals),
                            reversed(rc.frames[:rc.n_real]),
                            reversed(rc.seqs)):
            st.queue.appendleft((a, f, sq))

    def tick(self, t: float) -> TickReport:
        """One scheduler period at simulated time ``t``."""
        import jax.numpy as jnp

        from repro.serve.engine import cascade_serve

        cfg = self.cfg
        t0 = time.perf_counter()
        events = []
        if self._chaos is not None:
            for kind, idx in self._chaos.events_at(self.tick_count):
                (self.kill_device if kind == "kill"
                 else self.restore_device)(idx)
                events.append((kind, idx))
        if self._tel_on and events and any(
                s.cut is None for s in self._streams.values()):
            # the local placement group re-shards at this tick's dispatch
            self.telemetry.emit(
                "failover", "local_group_reshard", t=t,
                tick=self.tick_count,
                events=[list(e) for e in events],
                healthy=len(self._healthy()), dead=sorted(self._dead))
        shed = self._collect_shed()
        if self._tel_on:
            for sr in shed:
                self.telemetry.emit(
                    "shed", "queue_overflow", t=t, tick=self.tick_count,
                    sid=sr.sid, seq_lo=min(sr.seqs), seq_hi=max(sr.seqs),
                    n=len(sr.seqs))
                self.telemetry.counters.bump("serve.frames_shed",
                                             len(sr.seqs))
        ready = self._gather_ready(t)
        gathered = [self._streams[rc.sid] for rc in ready]
        groups: dict = {}
        for rc in ready:
            groups.setdefault(self._streams[rc.sid].rung, []).append(rc)

        p99_link = (self.last_link_report.p99_latency_s
                    if self.last_link_report is not None
                    else self.link.latency_s)
        completions, changes, moves = [], [], []
        tick_bytes = {sid: 0.0 for sid in self._streams}
        n_served = n_quiet = n_requeued = n_failed_tx = 0
        dispatched = False
        led_obs = []                     # (sid, rung, arrivals) per delivery
        for rung, rcs in groups.items():
            dispatched = True
            cut, bits = rung
            disp_t0 = time.perf_counter() if self._tel_on else 0.0
            # pad the request stack to a capacity-multiple bucket so both
            # the big model's (capacity, ...) batch and the scorer's see
            # tick-invariant shapes: zero chunks are motionless, score
            # below threshold, filtered before any compute
            n = len(rcs)
            b = self._bucket(n)
            stack = np.zeros((b, cfg.chunk, self.h, self.w), np.float32)
            for i, rc in enumerate(rcs):
                stack[i] = rc.frames
            outputs, served, stats = cascade_serve(
                self._scores, self._group_step(rung), jnp.asarray(stack),
                threshold=self._score_threshold, capacity=cfg.capacity)
            served = np.asarray(served)
            dropped = set(int(i) for i in np.asarray(
                stats["dropped_capacity_idx"]) if i >= 0)
            out_np = {k: np.asarray(v) for k, v in outputs.items()}
            if self._tel_on:
                from repro.obs.ledger import rung_key as _rk

                # harvest funnel tel_ aux (present when the base executor
                # is itself instrumented); out_np is already materialized
                # host-side, so this adds no device sync
                for k in [k for k in out_np if k.startswith("tel_")]:
                    self.telemetry.counters.bump(
                        "exec." + k[4:], int(out_np.pop(k).sum()))

                self.telemetry.emit(
                    "dispatch", f"group:{_rk(rung)}", t=t,
                    dur=time.perf_counter() - disp_t0,
                    tick=self.tick_count, n_chunks=n, bucket=b,
                    n_served=int(served.sum()),
                    n_capacity_dropped=len(dropped))
                self.telemetry.counters.bump("serve.dispatches")
            for i, rc in enumerate(rcs):
                st = self._streams[rc.sid]
                if i in dropped:                 # re-queue, oldest first
                    n_requeued += 1
                    st.requeues += 1
                    self._requeue(st, rc)
                    continue
                if served[i]:
                    n_served += 1
                    result = {k: (out_np[k][i] if out_np[k][i].ndim == 0
                                  else out_np[k][i][:rc.n_real])
                              for k in _RESULT_KEYS}
                    wire = float(out_np["wire_b"][i]) if cut else 0.0
                    kind = "served"
                    motion_n = int(result["motion"].sum())
                    windows_n = int(result["window_valid"].sum())
                else:                            # scorer-filtered: quiet
                    n_quiet += 1
                    q = self._quiet_result(cfg.chunk)
                    result = {k: (q[k] if np.ndim(q[k]) == 0
                                  else q[k][:rc.n_real]) for k in _RESULT_KEYS}
                    wire = (fa_quiet_bytes(cut, bits, frames=cfg.chunk,
                                           h=self.h, w=self.w)
                            if cut else 0.0)
                    kind = "quiet"
                    motion_n = windows_n = 0
                inj = self._injector(rc.sid)
                payload_b = wire
                if cut is not None and inj is not None:
                    # chaos plane: the chunk transits the stream's fault
                    # process; every attempt congests the shared uplink
                    ok, on_air, att, lost, corrupt = \
                        self._transmit(inj, wire, t)
                    lat = (t - rc.arrivals[0]) + p99_link
                    if self._tel_on:
                        self.telemetry.emit(
                            "link", "chunk_tx", t=t, tick=self.tick_count,
                            sid=rc.sid, delivered=bool(ok), attempts=att,
                            lost=lost, crc_fail=corrupt,
                            payload_b=payload_b, on_air_b=on_air,
                            seq_lo=rc.seqs[0], seq_hi=rc.seqs[-1],
                            fault_id=self._chaos.fault_id(rc.sid))
                        c = self.telemetry.counters
                        c.bump("serve.link_attempts", att)
                        c.bump("serve.link_lost", lost)
                        c.bump("serve.link_crc_fail", corrupt)
                        c.bump("serve.bytes_on_air", int(round(on_air)))
                    if st.ladder is not None:
                        self._observe_ladder(
                            st, moves, rung=rung, delivered=ok,
                            attempts=att, lost=lost, corrupt=corrupt,
                            payload_b=payload_b, on_air=on_air,
                            latency_s=lat)
                    tick_bytes[rc.sid] = tick_bytes.get(rc.sid, 0.0) + on_air
                    if not ok:
                        # the cloud never received the payload — retried
                        # next tick (possibly at a degraded rung), not lost
                        n_failed_tx += 1
                        st.tx_failures += 1
                        self._requeue(st, rc)
                        continue
                    wire = on_air
                elif cut is not None:
                    tick_bytes[rc.sid] = tick_bytes.get(rc.sid, 0.0) + wire
                elif (inj is not None and st.ladder is not None
                        and st.ladder.level > 0):
                    # ON_NODE rung: the decision beacon probes the channel
                    # so hysteresis recovery has a signal
                    beacon = fa_decision_bytes(rc.n_real)
                    ok_b, on_air, att, lost, corrupt = \
                        self._transmit(inj, beacon, t)
                    self._observe_ladder(
                        st, moves, rung=_ON_NODE, delivered=ok_b,
                        attempts=att, lost=lost, corrupt=corrupt,
                        payload_b=beacon, on_air=on_air, latency_s=0.0)
                    tick_bytes[rc.sid] = tick_bytes.get(rc.sid, 0.0) + on_air
                if (cut is not None and kind == "served"
                        and self.controller is not None):
                    # the byte model learns from the payload, never from
                    # retransmissions — faults must not skew predictions
                    self.controller.observe(cut, units=rc.n_real,
                                            wire_bytes=payload_b)
                st.stats.append((rc.n_real, motion_n, windows_n))
                st.frames_done += rc.n_real
                st.delivered_n += rc.n_real
                st.last_served_seq = max(st.last_served_seq, rc.seqs[-1])
                self.total_delivered += rc.n_real
                st.deficit = max(0.0, st.deficit - float(cfg.chunk))
                if st.cut is not None:
                    st.frames_since_resolve += rc.n_real
                if self._tel_on:
                    led_obs.append((rc.sid, rung, rc.arrivals))
                    c = self.telemetry.counters
                    c.bump("serve.frames_delivered", rc.n_real)
                    c.bump("serve.chunks_" + kind)
                completions.append(Completion(
                    sid=rc.sid, t=t, n_frames=rc.n_real, kind=kind,
                    result=result, wire_bytes=wire, seqs=rc.seqs))

        # DRR normalization: shift gathered deficits down by their min so
        # credits stay bounded (relative order — the only thing the grant
        # sort reads — is unchanged)
        if gathered:
            m = min(st.deficit for st in gathered)
            if m > 0.0:
                for st in gathered:
                    st.deficit -= m

        batch_s = time.perf_counter() - t0
        if dispatched:
            self.batch_lat_s.append(batch_s)
        # simulated frame sojourn: queue wait + this tick's dispatch
        # (at most one ready chunk per stream per tick, so sid identifies it)
        completed_sids = {c.sid for c in completions}
        for rc in ready:
            if rc.sid in completed_sids:
                self.queue_delay_s.extend(
                    (t + batch_s) - a for a in rc.arrivals)
        self.frames_completed += sum(c.n_frames for c in completions)
        if self._tel_on:
            for sid, rung, arrivals in led_obs:
                for a in arrivals:
                    self.telemetry.ledger.observe_latency(
                        sid, rung, (t + batch_s) - a)
            depths = [len(s.queue) for s in self._streams.values()]
            self.telemetry.emit(
                "tick", f"tick{self.tick_count}", t=t, dur=batch_s,
                tick=self.tick_count, n_streams=len(self._streams),
                n_ready=len(ready), n_served=n_served, n_quiet=n_quiet,
                n_requeued=n_requeued, n_failed_tx=n_failed_tx,
                queue_frames=int(sum(depths)),
                queue_max=int(max(depths, default=0)),
                deficit_max=float(max(
                    (s.deficit for s in self._streams.values()),
                    default=0.0)),
                bytes_sent=float(sum(tick_bytes.values())))
            c = self.telemetry.counters
            c.bump("serve.ticks")
            c.bump("serve.chunks_requeued", n_requeued)
            c.bump("serve.tx_failures", n_failed_tx)

        # byte traces + congestion report
        for sid, st in self._streams.items():
            st.trace.append(tick_bytes.get(sid, 0.0))
        self.tick_count += 1
        if (self.tick_count % cfg.link_window == 0
                and any(s.cut is not None for s in self._streams.values())):
            self._refresh_link_report()
        # refresh measured offered load for admission
        for st in self._streams.values():
            if st.cut is not None and st.trace:
                st.declared_bps = (sum(st.trace)
                                   / (len(st.trace) * cfg.tick_s))

        resolves = self._maybe_resolve(changes)
        if self._tel_on and resolves:
            self.telemetry.counters.bump("serve.resolves_fired", resolves)
            for sid, old_cut, new_cut in changes:
                self.telemetry.emit(
                    "dispatch", "cut_change", t=t, tick=self.tick_count,
                    sid=sid, old_cut=str(old_cut), new_cut=str(new_cut))
        self._reap_drained()
        return TickReport(
            t=t, n_ready=len(ready), n_served=n_served, n_quiet=n_quiet,
            n_requeued=n_requeued, batch_s=batch_s,
            bytes_sent=float(sum(tick_bytes.values())),
            completions=tuple(completions), resolves_fired=resolves,
            cut_changes=tuple(changes), shed=shed,
            n_failed_tx=n_failed_tx, ladder_moves=tuple(moves),
            device_events=tuple(events))

    def _refresh_link_report(self):
        from repro.camera.offload.link import simulate_shared_link

        cfg = self.cfg
        rows = [list(s.trace) for s in self._streams.values()
                if s.cut is not None and s.trace]
        if not rows:
            return
        width = max(len(r) for r in rows)
        mat = np.zeros((len(rows), width))
        for i, r in enumerate(rows):
            mat[i, width - len(r):] = r
        self.last_link_report = simulate_shared_link(
            mat, self.link, frame_period_s=cfg.tick_s)

    def _maybe_resolve(self, changes):
        """Windowed per-stream cut re-solves under the congestion deadline.

        Ladder-degraded streams are skipped — during an incident the
        ladder has the wheel; once it recovers to rung 0 the solver
        resumes, and a re-solve that changes the cut rebuilds the
        stream's rung list around the new placement.
        """
        cfg = self.cfg
        if self.controller is None:
            return 0
        fired = 0
        p99 = (self.last_link_report.p99_latency_s
               if self.last_link_report is not None else self.link.latency_s)
        for st in self._streams.values():
            if st.cut is None or st.frames_since_resolve < cfg.resolve_every:
                continue
            if st.ladder is not None and st.ladder.level > 0:
                continue
            m, v = st.window_stats()
            chunk_b = {c: fa_cut_bytes(c, st.bits, frames=cfg.chunk,
                                       h=self.h, w=self.w, motion_frames=m,
                                       valid_windows=v)
                       for c in FA_CUTS}
            cur = chunk_b[st.cut]
            lat = {c: max(self.link.latency_s,
                          p99 + (chunk_b[c] - cur) / self.link.bytes_per_s)
                   for c in FA_CUTS}
            sol = self.controller.resolve_window(
                deadline_s=cfg.slo_s, cut_latency_s=lat,
                predicted_bytes={c: chunk_b[c] / cfg.chunk for c in FA_CUTS})
            st.resolves += 1
            st.frames_since_resolve = 0
            fired += 1
            if sol.cut_after != st.cut:
                st.transitions.append((self.tick_count, st.cut,
                                       sol.cut_after))
                changes.append((st.sid, st.cut, sol.cut_after))
                st.cut = sol.cut_after
                if st.ladder is not None:
                    old = st.ladder
                    st.ladder = self._make_ladder(
                        self._ladder_rungs(st.cut, st.bits))
                    st.ladder.transitions = old.transitions
        return fired

    def _reap_drained(self):
        done = [sid for sid, st in self._streams.items()
                if st.draining and not st.queue]
        for sid in done:
            del self._streams[sid]

    # -- checkpoint / restore (DESIGN.md §14) ----------------------------------

    def checkpoint(self, ckpt_dir: str, step: int | None = None) -> str:
        """Persist the full server state at a tick boundary.

        Queue contents go to the array tree (one leaf triple per stream:
        arrival times, frames, sequence numbers); every scalar — stream
        descriptors, ladder levels, DRR credits, seq counters, controller
        windows — rides the JSON ``extra``.  Call between ticks only: a
        mid-tick snapshot would double-serve in-flight chunks on restore.
        Wall-clock metric lists (``batch_lat_s``/``queue_delay_s``) are
        host measurements, not server state, and reset on restore.
        """
        from repro.ckpt.checkpoint import save_checkpoint

        tree = {"queues": {}}
        meta = {}
        for sid, st in self._streams.items():
            q = list(st.queue)
            tree["queues"][sid] = {
                "t": np.asarray([a for a, _, _ in q], np.float64),
                "f": (np.stack([f for _, f, _ in q])
                      if q else np.zeros((0, self.h, self.w), np.float32)),
                "seq": np.asarray([s for _, _, s in q], np.int64),
            }
            lad = None
            if st.ladder is not None:
                lad = {"level": st.ladder.level,
                       "clean": st.ladder._clean,
                       "rungs": [list(r) for r in st.ladder.rungs],
                       "transitions": [list(x)
                                       for x in st.ladder.transitions]}
            meta[sid] = {
                "fps": st.fps, "cut": st.cut, "bits": st.bits,
                "t_join": st.t_join, "draining": st.draining,
                "frames_done": st.frames_done,
                "frames_since_resolve": st.frames_since_resolve,
                "resolves": st.resolves, "requeues": st.requeues,
                "declared_bps": st.declared_bps, "seq_next": st.seq_next,
                "delivered_n": st.delivered_n,
                "last_served_seq": st.last_served_seq,
                "shed_n": st.shed_n, "tx_failures": st.tx_failures,
                "deficit": st.deficit, "order": st.order,
                "qlen": len(q),
                "pending_shed": [list(x) for x in st.pending_shed],
                "stats": [list(x) for x in st.stats],
                "trace": list(st.trace),
                "transitions": [list(x) for x in st.transitions],
                "ladder": lad,
            }
        ctl = None
        if self.controller is not None:
            ctl = {"resolves": self.controller.resolves,
                   "window_obs": {c: [list(row) for row in dq]
                                  for c, dq in
                                  self.controller._window_obs.items()}}
        extra = {
            "version": 1,
            "tick_count": self.tick_count,
            "frames_completed": self.frames_completed,
            "total_enqueued": self.total_enqueued,
            "total_delivered": self.total_delivered,
            "total_shed": self.total_shed,
            "order_counter": self._order_counter,
            "dead_devices": sorted(self._dead),
            "streams": meta,
            "controller": ctl,
        }
        if self._tel_on:
            # optional key: telemetry totals + ledger survive the restart
            # (absent pre-PR-10 checkpoints restore fine — .get below)
            extra["telemetry"] = self.telemetry.state_dict()
            self.telemetry.emit(
                "ckpt", "checkpoint",
                t=self.tick_count * self.cfg.tick_s, tick=self.tick_count,
                step=int(self.tick_count if step is None else step))
        if step is None:
            step = self.tick_count
        return save_checkpoint(ckpt_dir, step, tree, extra=extra)

    @classmethod
    def restore(cls, ckpt_dir: str, base, *, link=None, controller=None,
                config: ServeConfig = ServeConfig(), chaos=None,
                telemetry=None, step: int | None = None) -> "StreamingServer":
        """Rebuild a server from its newest (or ``step``'s) checkpoint.

        Resumes exactly where :meth:`checkpoint` left off: queued frames,
        seq counters, ladder levels, DRR credits, draining flags, dead
        devices, and the controller's sliding windows (written into the
        ``controller`` instance passed here).  Fault-injector RNG state is
        NOT part of server state — a restored fleet faults afresh from
        its seeds, which models an independent post-restart channel.
        """
        from repro.ckpt.checkpoint import (latest_step, read_extra,
                                           restore_checkpoint)

        if step is None:
            step = latest_step(ckpt_dir)
            if step is None:
                raise ServeError(
                    f"no complete checkpoint under {ckpt_dir!r}")
        extra = read_extra(ckpt_dir, step)
        if extra.get("version") != 1:
            raise ServeError(
                f"unsupported server checkpoint version "
                f"{extra.get('version')!r}")
        srv = cls(base, link=link, controller=controller, config=config,
                  chaos=chaos, telemetry=telemetry)
        if srv._tel_on and extra.get("telemetry"):
            # counter totals + SLO ledger continue across the restart;
            # the trace starts a fresh run that records its ancestry
            srv.telemetry.load_state(extra["telemetry"])
        like = {"queues": {
            sid: {"t": np.zeros(m["qlen"], np.float64),
                  "f": np.zeros((m["qlen"], srv.h, srv.w), np.float32),
                  "seq": np.zeros(m["qlen"], np.int64)}
            for sid, m in extra["streams"].items()}}
        tree, _ = restore_checkpoint(ckpt_dir, step, like)

        srv.tick_count = int(extra["tick_count"])
        srv.frames_completed = int(extra["frames_completed"])
        srv.total_enqueued = int(extra["total_enqueued"])
        srv.total_delivered = int(extra["total_delivered"])
        srv.total_shed = int(extra["total_shed"])
        srv._order_counter = int(extra["order_counter"])
        srv._dead = {int(i) for i in extra["dead_devices"]
                     if i < len(srv._devices)}
        for sid, m in extra["streams"].items():
            q = tree["queues"][sid]
            ts = np.asarray(q["t"])
            fs = np.asarray(q["f"])
            sq = np.asarray(q["seq"])
            st = _Stream(
                sid=sid, fps=float(m["fps"]), cut=m["cut"],
                bits=m["bits"], t_join=float(m["t_join"]),
                queue=deque((float(ts[i]), np.asarray(fs[i], np.float32),
                             int(sq[i])) for i in range(len(ts))),
                draining=bool(m["draining"]),
                frames_done=int(m["frames_done"]),
                frames_since_resolve=int(m["frames_since_resolve"]),
                resolves=int(m["resolves"]), requeues=int(m["requeues"]),
                declared_bps=float(m["declared_bps"]),
                seq_next=int(m["seq_next"]),
                delivered_n=int(m["delivered_n"]),
                last_served_seq=int(m["last_served_seq"]),
                shed_n=int(m["shed_n"]), tx_failures=int(m["tx_failures"]),
                deficit=float(m["deficit"]), order=int(m["order"]))
            st.pending_shed = [tuple(x) for x in m["pending_shed"]]
            st.stats = deque((tuple(x) for x in m["stats"]),
                             maxlen=config.stats_window)
            st.trace = deque(m["trace"], maxlen=config.link_window)
            st.transitions = [tuple(x) for x in m["transitions"]]
            lad = m.get("ladder")
            if lad is not None:
                ladder = srv._make_ladder([tuple(r) for r in lad["rungs"]])
                ladder.level = int(lad["level"])
                ladder._clean = int(lad["clean"])
                ladder.transitions = [tuple(x) for x in lad["transitions"]]
                st.ladder = ladder
            srv._streams[sid] = st
        if controller is not None and extra.get("controller"):
            import collections as _c

            ctl = extra["controller"]
            controller.resolves = int(ctl["resolves"])
            controller._window_obs = {
                c: _c.deque((tuple(row) for row in rows),
                            maxlen=controller.window)
                for c, rows in ctl["window_obs"].items()}
        return srv

    def seq_audit(self) -> dict:
        """Prove the exactly-once frame accounting (DESIGN.md §14).

        Per live stream: assigned seqs partition into delivered + shed +
        queued; queued seqs are strictly ascending and strictly above the
        highest delivered seq (so nothing can be served twice).  Fleet
        totals use churn-surviving counters, so the identity holds across
        unregister/reap and across checkpoint/restore.
        """
        per = {}
        ok = True
        queued_total = 0
        # canonical sorted-sid order, matching _collect_shed (PR 10 fix)
        for sid, st in sorted(self._streams.items()):
            seqs = [e[2] for e in st.queue]
            queued_total += len(seqs)
            ascending = all(a < b for a, b in zip(seqs, seqs[1:]))
            unserved = all(s > st.last_served_seq for s in seqs)
            balanced = st.seq_next == (st.delivered_n + st.shed_n
                                       + len(seqs))
            per[sid] = {"ok": ascending and unserved and balanced,
                        "assigned": st.seq_next,
                        "delivered": st.delivered_n, "shed": st.shed_n,
                        "queued": len(seqs),
                        "last_served_seq": st.last_served_seq}
            ok = ok and per[sid]["ok"]
        fleet = (self.total_enqueued
                 == self.total_delivered + self.total_shed + queued_total)
        return {"ok": bool(ok and fleet), "fleet_balanced": bool(fleet),
                "enqueued": self.total_enqueued,
                "delivered": self.total_delivered,
                "shed": self.total_shed, "queued": queued_total,
                "streams": per}

    # -- fleet metrics ---------------------------------------------------------

    def p99_batch_s(self) -> float:
        if not self.batch_lat_s:
            return 0.0
        return float(np.quantile(np.asarray(self.batch_lat_s), 0.99))

    def frames_served(self) -> int:
        return self.frames_completed

    def total_resolves(self) -> int:
        return 0 if self.controller is None else self.controller.resolves
