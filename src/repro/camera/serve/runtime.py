"""Continuous streaming serving runtime (DESIGN.md §13).

Serves N heterogeneous camera streams on one serving device — the
WISPCam fleet shape: thousands of harvested-energy cameras sharing one
backscatter uplink into a cloud that runs (part of) the §III funnel.
Streams register and leave dynamically; frames queue per stream; every
scheduler tick forms capacity-padded micro-batches and pushes them
through ONE dispatch per placement group:

* the scorer→big-model admission path is the bugfixed
  :func:`repro.serve.engine.cascade_serve` — a chunk motion-energy scorer
  filters quiet chunks in front of the funnel ("Viola-Jones in front of
  the NN" at fleet scale), the compacting cascade bounds the big batch to
  a static capacity, and capacity-overflowed survivors come back as
  deterministic indices that the scheduler *re-queues* (never drops);
* local streams (``cut=None``) run through
  :meth:`FaceAuthExecutor.batch_step` — the fused funnel vmapped across
  the micro-batch (pmapped across devices when they divide);
* offloaded streams run the split executors' node/cloud halves vmapped,
  so per-chunk *measured* wire bytes come out of the same dispatch.

The scorer threshold equals the funnel's own motion threshold, so a
filtered chunk's canonical quiet result is bit-identical to running the
funnel on it — filtering saves compute with zero semantic change (chunk
boundaries are batch boundaries, as everywhere else in the repo).

Admission control and per-stream cut selection close the two carried
ROADMAP items: measured per-tick byte traces replay through
``simulate_shared_link`` every ``link_window`` ticks, and each active
stream's sliding-window funnel stats drive a
``CutController.resolve_window`` re-solve with the link report's
``p99_latency_s`` as the deadline constraint — congestion rises, cuts
retreat toward fewer wire bytes.  A zero-traffic stream accumulates no
served frames and therefore never triggers a re-solve (the PR 7
"zero-fault stream never moves" pin, transplanted).
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque

import numpy as np

from repro.camera.serve.bytes_model import (FA_CUTS, fa_cut_bytes,
                                            fa_quiet_bytes)

_RESULT_KEYS = ("motion", "n_windows", "n_auth", "scores", "window_id",
                "window_valid", "auth", "windows_dropped", "motion_dropped",
                "cascade_dropped")


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Scheduler contract knobs (DESIGN.md §13)."""

    chunk: int = 4              # frames per micro-batch slot
    capacity: int = 8           # micro-batch slots per placement group/tick
    slo_s: float = 0.5          # p99 micro-batch dispatch latency SLO (wall)
    tick_s: float = 1.0         # scheduler period (simulated seconds)
    max_queue_s: float = 6.0    # flush a partial chunk older than this
    resolve_every: int = 16     # served frames between per-stream re-solves
    link_window: int = 8        # ticks of byte traces per congestion report
    admit_util: float = 0.7     # uplink utilization ceiling at admission
    admit_headroom: float = 0.8 # admit only while link p99 <= headroom*slo
    admit_motion_frac: float = 0.5   # activity prior for undeclared streams
    admit_windows_per_frame: float = 2.0
    stats_window: int = 32      # chunks of funnel stats per stream window


@dataclasses.dataclass(frozen=True)
class AdmissionDecision:
    admitted: bool
    sid: str
    cut: str | None             # placement actually granted (may differ)
    bits: int | None
    reason: str
    predicted_bps: float = 0.0
    predicted_util: float = 0.0


@dataclasses.dataclass(frozen=True)
class Completion:
    """One chunk's delivery: per-frame leaves sliced to the real frames."""

    sid: str
    t: float
    n_frames: int
    kind: str                   # "served" | "quiet"
    result: dict                # FAExecResult fields, leading axis n_frames
    wire_bytes: float


@dataclasses.dataclass(frozen=True)
class TickReport:
    t: float
    n_ready: int
    n_served: int
    n_quiet: int
    n_requeued: int
    batch_s: float              # wall clock of this tick's dispatches
    bytes_sent: float
    completions: tuple          # (Completion, ...)
    resolves_fired: int
    cut_changes: tuple          # ((sid, old_cut, new_cut), ...)


@dataclasses.dataclass
class _Stream:
    sid: str
    fps: float
    cut: str | None
    bits: int | None
    t_join: float
    queue: deque                # (t_arrival, frame) FIFO
    draining: bool = False
    frames_done: int = 0
    frames_since_resolve: int = 0
    resolves: int = 0
    requeues: int = 0
    declared_bps: float = 0.0
    stats: deque = dataclasses.field(
        default_factory=lambda: deque(maxlen=32))   # (n, motion, windows)
    trace: deque = dataclasses.field(
        default_factory=lambda: deque(maxlen=8))    # bytes per tick
    transitions: list = dataclasses.field(default_factory=list)

    @property
    def rung(self):
        return (self.cut, self.bits if self.cut is not None else None)

    def window_stats(self):
        """Sliding-window mean (motion_frames, valid_windows) per chunk."""
        rows = [r for r in self.stats if r[0] > 0]
        if not rows:
            return 0.0, 0.0
        n = len(rows)
        return (sum(r[1] for r in rows) / n, sum(r[2] for r in rows) / n)


@dataclasses.dataclass(frozen=True)
class _ReadyChunk:
    sid: str
    frames: np.ndarray          # (chunk, h, w) f32, padded with last frame
    arrivals: tuple             # simulated arrival times, len n_real
    n_real: int


class StreamingServer:
    """Fleet-scale streaming front door over one :class:`FaceAuthExecutor`.

    ``base`` must be calibrated.  ``controller`` (a ``CutController``
    calibrated for the same base) enables windowed per-stream cut
    re-solves; without it, granted cuts are static.  ``link`` is the
    shared uplink every offloaded stream transmits on.
    """

    def __init__(self, base, *, link=None, controller=None,
                 config: ServeConfig = ServeConfig()):
        from repro.camera.offload.link import BACKSCATTER

        self.base = base
        self.cfg = config
        self.link = link or BACKSCATTER
        self.controller = controller
        self.h, self.w = base.det.grid.h, base.det.grid.w
        self._streams: dict = {}
        self._group_steps: dict = {}
        self._offload_execs: dict = {}
        self._quiet_cache: dict = {}
        self.tick_count = 0
        self.frames_completed = 0
        self.batch_lat_s: list = []      # wall seconds per dispatching tick
        self.queue_delay_s: list = []    # simulated frame sojourn times
        self.last_link_report = None
        self.rejections: list = []
        # scorer semantics == the funnel's motion gate: survive iff any
        # intra-chunk transition scores strictly above motion_threshold
        self._score_threshold = float(np.nextafter(
            np.float32(base.motion_threshold), np.float32(np.inf)))

    # -- registration / churn -------------------------------------------------

    def register(self, sid: str, *, fps: float = 1.0, cut: str | None = None,
                 bits: int | None = 8, t: float = 0.0,
                 motion_frac: float | None = None) -> AdmissionDecision:
        """Admit (or reject, or re-place) one new stream.

        Local streams (``cut=None``) are admitted against the compute
        budget; offloaded streams against the shared-uplink budget — if
        the requested cut does not fit, cheaper-byte cuts are tried before
        rejecting, so a stream may be granted a different placement than
        it asked for (congestion-aware placement at admission time).
        """
        if sid in self._streams:
            raise ValueError(f"stream {sid!r} already registered")
        cfg = self.cfg
        if cut is None:
            projected = sum(s.fps for s in self._streams.values()
                            if s.cut is None) + fps
            budget = cfg.capacity * cfg.chunk / cfg.tick_s
            if projected > cfg.admit_headroom * budget:
                dec = AdmissionDecision(
                    False, sid, None, None,
                    f"compute: {projected:.1f} fps over "
                    f"{cfg.admit_headroom * budget:.1f} fps budget")
                self.rejections.append(dec)
                return dec
            self._admit(sid, fps, None, None, t, 0.0)
            return AdmissionDecision(True, sid, None, None, "admitted")

        if cut not in FA_CUTS:
            raise ValueError(f"cut {cut!r} not in {FA_CUTS}")
        frac = cfg.admit_motion_frac if motion_frac is None else motion_frac
        fleet_bps = sum(s.declared_bps for s in self._streams.values())
        p99 = (self.last_link_report.p99_latency_s
               if self.last_link_report is not None else 0.0)
        if p99 > cfg.admit_headroom * cfg.slo_s:
            dec = AdmissionDecision(
                False, sid, cut, bits,
                f"congestion: link p99 {p99:.3f}s over "
                f"{cfg.admit_headroom * cfg.slo_s:.3f}s headroom")
            self.rejections.append(dec)
            return dec
        candidates = [cut] + [c for c in FA_CUTS if c != cut]
        candidates.sort(key=lambda c: (c != cut,
                                       self._predict_bps(c, bits, fps, frac)))
        for c in candidates:
            bps = self._predict_bps(c, bits, fps, frac)
            util = (fleet_bps + bps) / self.link.bytes_per_s
            if util <= cfg.admit_util:
                reason = ("admitted" if c == cut else
                          f"re-placed from {cut!r}: requested cut over "
                          f"{cfg.admit_util:.0%} uplink utilization")
                self._admit(sid, fps, c, bits, t, bps)
                return AdmissionDecision(True, sid, c, bits, reason, bps, util)
        bps = self._predict_bps(candidates[-1], bits, fps, frac)
        dec = AdmissionDecision(
            False, sid, cut, bits,
            f"uplink: even cheapest cut exceeds {cfg.admit_util:.0%} "
            f"utilization ({fleet_bps:.0f}+{bps:.0f} B/s of "
            f"{self.link.bytes_per_s:.0f})", bps,
            (fleet_bps + bps) / self.link.bytes_per_s)
        self.rejections.append(dec)
        return dec

    def _predict_bps(self, cut, bits, fps, motion_frac):
        cfg = self.cfg
        chunk_b = fa_cut_bytes(
            cut, bits, frames=cfg.chunk, h=self.h, w=self.w,
            motion_frames=motion_frac * cfg.chunk,
            valid_windows=motion_frac * cfg.chunk
            * cfg.admit_windows_per_frame)
        return chunk_b / cfg.chunk * fps

    def _admit(self, sid, fps, cut, bits, t, bps):
        cfg = self.cfg
        st = _Stream(sid=sid, fps=fps, cut=cut,
                     bits=bits if cut is not None else None, t_join=t,
                     queue=deque(), declared_bps=bps)
        st.stats = deque(maxlen=cfg.stats_window)
        st.trace = deque([0.0] * min(self.tick_count, cfg.link_window),
                         maxlen=cfg.link_window)
        self._streams[sid] = st

    def unregister(self, sid: str) -> int:
        """Begin draining ``sid``; queued frames are still served.

        Returns the number of frames left in the queue — the stream object
        disappears once they have all completed (immediately when empty).
        """
        st = self._streams[sid]
        st.draining = True
        n = len(st.queue)
        if n == 0:
            del self._streams[sid]
        return n

    def enqueue(self, sid: str, frame, t: float):
        st = self._streams[sid]
        if st.draining:
            raise ValueError(f"stream {sid!r} is draining")
        st.queue.append((float(t), np.asarray(frame, np.float32)))

    @property
    def streams(self):
        return dict(self._streams)

    # -- placement groups ------------------------------------------------------

    def _bucket(self, n: int) -> int:
        """Round a ready-count up to a multiple of ``capacity``.

        Dispatch batch shapes come from a small static set, so a tick
        never pays a fresh XLA compile just because the number of ready
        chunks drifted by one (p99 dispatch latency would otherwise be
        compile time, not compute).
        """
        cap = self.cfg.capacity
        return cap * max(1, -(-n // cap))

    def prewarm(self, rungs, *, max_ready: int | None = None):
        """Compile every placement group ahead of the measured ticks.

        Runs one zeros dispatch through the full scorer->cascade->group
        path per ``rung`` x shape bucket (buckets cover ``max_ready``
        ready chunks, default one ``capacity``).  Zero chunks are
        motionless, so nothing is observed and no stats move — this only
        populates the jit caches.
        """
        import jax
        import jax.numpy as jnp

        from repro.serve.engine import cascade_serve

        cfg = self.cfg
        top = self._bucket(max_ready or cfg.capacity)
        widths = range(cfg.capacity, top + 1, cfg.capacity)
        for rung in rungs:
            step = self._group_step(rung)
            for b in widths:
                stack = jnp.zeros((b, cfg.chunk, self.h, self.w),
                                  jnp.float32)
                out = cascade_serve(self._scores, step, stack,
                                    threshold=self._score_threshold,
                                    capacity=cfg.capacity)
                jax.block_until_ready(out)

    def _group_step(self, rung):
        """Cached single-dispatch micro-batch closure for one placement."""
        step = self._group_steps.get(rung)
        if step is not None:
            return step
        import jax
        import jax.numpy as jnp

        cap, chunk = self.cfg.capacity, self.cfg.chunk
        cut, bits = rung
        if cut is None:
            inner = self.base.batch_step(cap, chunk)
            ones = jnp.ones((cap,), bool)

            def step(chunks):
                out = dict(inner(chunks, ones))
                out["wire_b"] = jnp.zeros((cap,), jnp.float32)
                return out
        else:
            from repro.camera.offload.executors import FaceAuthOffloadExecutor

            off = self._offload_execs.get(rung)
            if off is None:
                off = FaceAuthOffloadExecutor(self.base, cut, bits=bits,
                                              use_pallas=False)
                self._offload_execs[rung] = off
            consts = tuple(off._consts)
            shape = (chunk, self.h, self.w)

            def one(frames):
                arrays, wire_b = off._node_fn(frames, *consts)
                res = off._cloud_fn(arrays, *consts, frames_shape=shape)
                out = dict(res)
                out["wire_b"] = wire_b
                return out

            step = jax.jit(jax.vmap(one))
        self._group_steps[rung] = step
        return step

    def _scores(self, chunks):
        """Chunk motion energy — the cascade's cheap scorer."""
        import jax.numpy as jnp

        from repro.camera.motion import motion_score

        if chunks.shape[1] < 2:
            return jnp.full((chunks.shape[0],), -np.inf, jnp.float32)
        sc = motion_score(chunks[:, :-1], chunks[:, 1:],
                          self.base.motion_factor)
        return jnp.max(sc, axis=-1)

    def _quiet_result(self, n):
        res = self._quiet_cache.get(n)
        if res is None:
            W = self.base.stages.window_capacity
            res = dict(
                motion=np.zeros(n, bool),
                n_windows=np.zeros(n, np.int32),
                n_auth=np.zeros(n, np.int32),
                scores=np.zeros((n, W), np.float32),
                window_id=np.full((n, W), -1, np.int32),
                window_valid=np.zeros((n, W), bool),
                auth=np.zeros((n, W), bool),
                windows_dropped=np.zeros(n, np.int32),
                motion_dropped=np.int32(0),
                cascade_dropped=np.zeros(n, np.int32))
            self._quiet_cache[n] = res
        return res

    # -- the tick --------------------------------------------------------------

    def _gather_ready(self, t):
        cfg = self.cfg
        ready = []
        for st in self._streams.values():
            q = st.queue
            if not q:
                continue
            full = len(q) >= cfg.chunk
            stale = (t - q[0][0]) >= cfg.max_queue_s
            if not (full or stale or st.draining):
                continue
            n_real = min(cfg.chunk, len(q))
            taken = [q.popleft() for _ in range(n_real)]
            frames = [f for _, f in taken]
            while len(frames) < cfg.chunk:      # pad: repeated last frame is
                frames.append(frames[-1])       # motionless, hence quiet
            ready.append(_ReadyChunk(
                sid=st.sid, frames=np.stack(frames),
                arrivals=tuple(a for a, _ in taken), n_real=n_real))
        return ready

    def tick(self, t: float) -> TickReport:
        """One scheduler period at simulated time ``t``."""
        import jax.numpy as jnp

        from repro.serve.engine import cascade_serve

        cfg = self.cfg
        t0 = time.perf_counter()
        ready = self._gather_ready(t)
        groups: dict = {}
        for rc in ready:
            groups.setdefault(self._streams[rc.sid].rung, []).append(rc)

        completions, changes = [], []
        tick_bytes = {sid: 0.0 for sid in self._streams}
        n_served = n_quiet = n_requeued = 0
        dispatched = False
        for rung, rcs in groups.items():
            dispatched = True
            cut, bits = rung
            # pad the request stack to a capacity-multiple bucket so both
            # the big model's (capacity, ...) batch and the scorer's see
            # tick-invariant shapes: zero chunks are motionless, score
            # below threshold, filtered before any compute
            n = len(rcs)
            b = self._bucket(n)
            stack = np.zeros((b, cfg.chunk, self.h, self.w), np.float32)
            for i, rc in enumerate(rcs):
                stack[i] = rc.frames
            outputs, served, stats = cascade_serve(
                self._scores, self._group_step(rung), jnp.asarray(stack),
                threshold=self._score_threshold, capacity=cfg.capacity)
            served = np.asarray(served)
            dropped = set(int(i) for i in np.asarray(
                stats["dropped_capacity_idx"]) if i >= 0)
            out_np = {k: np.asarray(v) for k, v in outputs.items()}
            for i, rc in enumerate(rcs):
                st = self._streams[rc.sid]
                if i in dropped:                 # re-queue, oldest first
                    n_requeued += 1
                    st.requeues += 1
                    for a, f in zip(reversed(rc.arrivals),
                                    reversed(rc.frames[:rc.n_real])):
                        st.queue.appendleft((a, f))
                    continue
                if served[i]:
                    n_served += 1
                    result = {k: (out_np[k][i] if out_np[k][i].ndim == 0
                                  else out_np[k][i][:rc.n_real])
                              for k in _RESULT_KEYS}
                    wire = float(out_np["wire_b"][i]) if cut else 0.0
                    kind = "served"
                    motion_n = int(result["motion"].sum())
                    windows_n = int(result["window_valid"].sum())
                    if cut and self.controller is not None:
                        self.controller.observe(cut, units=rc.n_real,
                                                wire_bytes=wire)
                else:                            # scorer-filtered: quiet
                    n_quiet += 1
                    q = self._quiet_result(cfg.chunk)
                    result = {k: (q[k] if np.ndim(q[k]) == 0
                                  else q[k][:rc.n_real]) for k in _RESULT_KEYS}
                    wire = (fa_quiet_bytes(cut, bits, frames=cfg.chunk,
                                           h=self.h, w=self.w)
                            if cut else 0.0)
                    kind = "quiet"
                    motion_n = windows_n = 0
                tick_bytes[rc.sid] = tick_bytes.get(rc.sid, 0.0) + wire
                st.stats.append((rc.n_real, motion_n, windows_n))
                st.frames_done += rc.n_real
                if st.cut is not None:
                    st.frames_since_resolve += rc.n_real
                completions.append(Completion(
                    sid=rc.sid, t=t, n_frames=rc.n_real, kind=kind,
                    result=result, wire_bytes=wire))

        batch_s = time.perf_counter() - t0
        if dispatched:
            self.batch_lat_s.append(batch_s)
        # simulated frame sojourn: queue wait + this tick's dispatch
        # (at most one ready chunk per stream per tick, so sid identifies it)
        completed_sids = {c.sid for c in completions}
        for rc in ready:
            if rc.sid in completed_sids:
                self.queue_delay_s.extend(
                    (t + batch_s) - a for a in rc.arrivals)
        self.frames_completed += sum(c.n_frames for c in completions)

        # byte traces + congestion report
        for sid, st in self._streams.items():
            st.trace.append(tick_bytes.get(sid, 0.0))
        self.tick_count += 1
        if (self.tick_count % cfg.link_window == 0
                and any(s.cut is not None for s in self._streams.values())):
            self._refresh_link_report()
        # refresh measured offered load for admission
        for st in self._streams.values():
            if st.cut is not None and st.trace:
                st.declared_bps = (sum(st.trace)
                                   / (len(st.trace) * cfg.tick_s))

        resolves = self._maybe_resolve(changes)
        self._reap_drained()
        return TickReport(
            t=t, n_ready=len(ready), n_served=n_served, n_quiet=n_quiet,
            n_requeued=n_requeued, batch_s=batch_s,
            bytes_sent=float(sum(tick_bytes.values())),
            completions=tuple(completions), resolves_fired=resolves,
            cut_changes=tuple(changes))

    def _refresh_link_report(self):
        from repro.camera.offload.link import simulate_shared_link

        cfg = self.cfg
        rows = [list(s.trace) for s in self._streams.values()
                if s.cut is not None and s.trace]
        if not rows:
            return
        width = max(len(r) for r in rows)
        mat = np.zeros((len(rows), width))
        for i, r in enumerate(rows):
            mat[i, width - len(r):] = r
        self.last_link_report = simulate_shared_link(
            mat, self.link, frame_period_s=cfg.tick_s)

    def _maybe_resolve(self, changes):
        """Windowed per-stream cut re-solves under the congestion deadline."""
        cfg = self.cfg
        if self.controller is None:
            return 0
        fired = 0
        p99 = (self.last_link_report.p99_latency_s
               if self.last_link_report is not None else self.link.latency_s)
        for st in self._streams.values():
            if st.cut is None or st.frames_since_resolve < cfg.resolve_every:
                continue
            m, v = st.window_stats()
            chunk_b = {c: fa_cut_bytes(c, st.bits, frames=cfg.chunk,
                                       h=self.h, w=self.w, motion_frames=m,
                                       valid_windows=v)
                       for c in FA_CUTS}
            cur = chunk_b[st.cut]
            lat = {c: max(self.link.latency_s,
                          p99 + (chunk_b[c] - cur) / self.link.bytes_per_s)
                   for c in FA_CUTS}
            sol = self.controller.resolve_window(
                deadline_s=cfg.slo_s, cut_latency_s=lat,
                predicted_bytes={c: chunk_b[c] / cfg.chunk for c in FA_CUTS})
            st.resolves += 1
            st.frames_since_resolve = 0
            fired += 1
            if sol.cut_after != st.cut:
                st.transitions.append((self.tick_count, st.cut,
                                       sol.cut_after))
                changes.append((st.sid, st.cut, sol.cut_after))
                st.cut = sol.cut_after
        return fired

    def _reap_drained(self):
        done = [sid for sid, st in self._streams.items()
                if st.draining and not st.queue]
        for sid in done:
            del self._streams[sid]

    # -- fleet metrics ---------------------------------------------------------

    def p99_batch_s(self) -> float:
        if not self.batch_lat_s:
            return 0.0
        return float(np.quantile(np.asarray(self.batch_lat_s), 0.99))

    def frames_served(self) -> int:
        return self.frames_completed

    def total_resolves(self) -> int:
        return 0 if self.controller is None else self.controller.resolves
