"""Viola-Jones face detection: Haar features, AdaBoost cascade, scanning.

Paper §III-B reproduced end to end:

* rectangular Haar features evaluated on the integral image;
* a trained cascade — 10 stages x 33 weak classifiers (Table I: "Cascade
  10x33") fitted with AdaBoost on the synthetic face set, each stage's
  threshold tuned to a target per-stage recall (the classic cascade
  construction);
* window scanning with *scale factor* and *step size* knobs, including the
  paper's adaptive step ("a percentage of the window size") — Fig. 4a/4c;
* the cost model counts classifier invocations and per-window feature
  evaluations, reproducing the "86% fewer invocations at scale 1.25 /
  adaptive 2.5% with no accuracy loss" result.

Execution model (DESIGN.md §3): the production path is the *frame-resident
fused front-end* (:class:`FusedDetector` / :func:`detect_faces_batch`) —
one frame-level integral image, every window at every scale evaluated by
batched corner-tap gathers into that single table, and the stage loop
routed through ``core.cascade.compacting_cascade`` so later stages only
compute on survivors.  :func:`detect_faces` is the slow reference (golden
oracle): per-window integral images and a Python loop over features,
evaluating the *same* scaled-feature math.  Scaled-feature semantics
(classic VJ: scale the features, not the image) replaced the seed's
nearest-neighbor window resampling — resampled row subsets are not
contiguous rectangles, so they cannot be expressed as corner lookups in a
frame integral image, while scaled features can, exactly; at the training
scale (win == 20) the two are identical.

The *invocation count* (what the paper's energy model charges for) is the
number of stage evaluations a data-dependent implementation would run,
computed exactly from the survivor masks.
"""

from __future__ import annotations

import dataclasses
import functools

import numpy as np
import jax
import jax.numpy as jnp

from repro.camera.integral import frame_integral, integral_image, window_sum
from repro.core.cascade import (
    Stage as CoreStage,
    capacities_from_counts,
    compacting_cascade,
)
from repro.kernels.haar_frontend.ops import haar_stage_scores

BASE = 20    # canonical window resolution (matches the NN input 20x20)


# ---------------------------------------------------------------------------
# Haar features on the canonical 20x20 window
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class HaarFeature:
    """Two/three-rectangle feature, coordinates in the canonical window."""
    kind: int            # 0: 2-rect horiz, 1: 2-rect vert, 2: 3-rect horiz, 3: 3-rect vert
    y: int
    x: int
    h: int
    w: int


def make_feature_pool(seed: int = 0, n: int = 400) -> list:
    rng = np.random.default_rng(seed)
    pool = []
    while len(pool) < n:
        kind = int(rng.integers(0, 4))
        nsplit = 2 if kind < 2 else 3
        if kind in (0, 2):   # horizontal split: w divisible
            w = max(nsplit, (int(rng.integers(nsplit, BASE // 2 + 1)) // nsplit) * nsplit)
            h = int(rng.integers(2, BASE // 2 + 1))
        else:
            h = max(nsplit, (int(rng.integers(nsplit, BASE // 2 + 1)) // nsplit) * nsplit)
            w = int(rng.integers(2, BASE // 2 + 1))
        y = int(rng.integers(0, BASE - h + 1))
        x = int(rng.integers(0, BASE - w + 1))
        pool.append(HaarFeature(kind, y, x, h, w))
    for f in pool:
        split = f.w if f.kind in (0, 2) else f.h
        assert split % (2 if f.kind < 2 else 3) == 0, f
    return pool


def scale_feature(f: HaarFeature, win: int) -> HaarFeature:
    """Scale a canonical-20x20 feature to a ``win`` x ``win`` window.

    Rounds each dimension while preserving split divisibility (the 2-/3-way
    split stays exact) and clamps inside the window.  At ``win == BASE``
    this is the identity — the scale the cascade was trained at.  Both the
    reference detector and the gather tables go through this one function,
    so the two paths always evaluate the same rectangles.
    """
    s = win / BASE
    if f.kind == 0:
        part = max(1, int(round(f.w / 2 * s)))
        w, h = 2 * part, max(1, int(round(f.h * s)))
    elif f.kind == 1:
        part = max(1, int(round(f.h / 2 * s)))
        h, w = 2 * part, max(1, int(round(f.w * s)))
    elif f.kind == 2:
        part = max(1, int(round(f.w / 3 * s)))
        w, h = 3 * part, max(1, int(round(f.h * s)))
    else:
        part = max(1, int(round(f.h / 3 * s)))
        h, w = 3 * part, max(1, int(round(f.w * s)))
    wq = 2 if f.kind == 0 else (3 if f.kind == 2 else 1)
    hq = 2 if f.kind == 1 else (3 if f.kind == 3 else 1)
    while w > win:
        w -= wq
    while h > win:
        h -= hq
    y = min(max(int(round(f.y * s)), 0), win - h)
    x = min(max(int(round(f.x * s)), 0), win - w)
    return HaarFeature(f.kind, y, x, h, w)


CORNER_SLOTS = 8     # max corner taps per feature (3-rect decomposition)


def feature_corners(f: HaarFeature):
    """Corner-tap decomposition: [(dy, dx, weight), ...], <= 8 taps.

    Merging the shared edges of the 2-/3-rect sums collapses the naive
    8/12 integral-image lookups to 6/8 with static +-1/+-2/+-3 weights:
    response = sum_k weight_k * ii[y0 + dy_k, x0 + dx_k] for a window whose
    top-left corner maps to ii position (y0, x0).
    """
    y, x, h, w = f.y, f.x, f.h, f.w
    if f.kind == 0:      # left - right
        hw = w // 2
        return [(y, x, 1.0), (y + h, x, -1.0),
                (y, x + hw, -2.0), (y + h, x + hw, 2.0),
                (y, x + w, 1.0), (y + h, x + w, -1.0)]
    if f.kind == 1:      # top - bottom
        hh = h // 2
        return [(y, x, 1.0), (y, x + w, -1.0),
                (y + hh, x, -2.0), (y + hh, x + w, 2.0),
                (y + h, x, 1.0), (y + h, x + w, -1.0)]
    if f.kind == 2:      # sides - 2*middle, horizontal thirds
        w3 = w // 3
        return [(y, x, 1.0), (y, x + w3, -3.0),
                (y, x + 2 * w3, 3.0), (y, x + w, -1.0),
                (y + h, x, -1.0), (y + h, x + w3, 3.0),
                (y + h, x + 2 * w3, -3.0), (y + h, x + w, 1.0)]
    h3 = h // 3          # sides - 2*middle, vertical thirds
    return [(y, x, 1.0), (y + h3, x, -3.0),
            (y + 2 * h3, x, 3.0), (y + h, x, -1.0),
            (y, x + w, -1.0), (y + h3, x + w, 3.0),
            (y + 2 * h3, x + w, -3.0), (y + h, x + w, 1.0)]


def _haar_response(ii: jax.Array, f: HaarFeature) -> jax.Array:
    """Raw (unnormalized) response of one feature via rectangle sums."""
    if f.kind == 0:      # 2-rect horizontal: left - right
        wl = window_sum(ii, f.y, f.x, f.h, f.w // 2)
        wr = window_sum(ii, f.y, f.x + f.w // 2, f.h, f.w // 2)
        return wl - wr
    if f.kind == 1:      # 2-rect vertical: top - bottom
        wt = window_sum(ii, f.y, f.x, f.h // 2, f.w)
        wb = window_sum(ii, f.y + f.h // 2, f.x, f.h // 2, f.w)
        return wt - wb
    if f.kind == 2:      # 3-rect horizontal: sides - 2*middle
        w3 = f.w // 3
        a = window_sum(ii, f.y, f.x, f.h, w3)
        b = window_sum(ii, f.y, f.x + w3, f.h, w3)
        c = window_sum(ii, f.y, f.x + 2 * w3, f.h, w3)
        return a + c - 2 * b
    h3 = f.h // 3        # 3-rect vertical
    a = window_sum(ii, f.y, f.x, h3, f.w)
    b = window_sum(ii, f.y + h3, f.x, h3, f.w)
    c = window_sum(ii, f.y + 2 * h3, f.x, h3, f.w)
    return a + c - 2 * b


def eval_features(windows: jax.Array, feats: list) -> jax.Array:
    """windows: (n, 20, 20) -> (n, n_feats) Haar responses (variance-normalized).

    Evaluated via each window's integral image — the same arithmetic the
    streaming accelerator performs, vectorized over windows.
    """
    ii = integral_image(windows)                     # (n, 21, 21)
    mu = window_sum(ii, 0, 0, BASE, BASE) / (BASE * BASE)
    sq = integral_image(windows * windows)
    var = window_sum(sq, 0, 0, BASE, BASE) / (BASE * BASE) - mu * mu
    sd = jnp.sqrt(jnp.maximum(var, 1e-6))
    cols = [_haar_response(ii, f) / (sd * BASE * BASE) for f in feats]
    return jnp.stack(cols, axis=-1)


def eval_features_scaled(patches: jax.Array, win: int, feats: list) -> jax.Array:
    """Native-resolution windows (n, win, win) -> (n, n_feats) responses
    with the canonical features *scaled* to the window (classic VJ: scale
    the features, not the image).  At ``win == BASE`` this is exactly
    :func:`eval_features`."""
    area = win * win
    ii = integral_image(patches)
    sq = integral_image(patches * patches)
    mu = window_sum(ii, 0, 0, win, win) / area
    var = window_sum(sq, 0, 0, win, win) / area - mu * mu
    sd = jnp.sqrt(jnp.maximum(var, 1e-6))
    cols = [_haar_response(ii, scale_feature(f, win)) / (sd * win * win)
            for f in feats]
    return jnp.stack(cols, axis=-1)


# ---------------------------------------------------------------------------
# AdaBoost cascade (10 stages x 33 weak classifiers, Table I)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Cascade:
    feats: list                     # selected HaarFeatures, flat
    thresholds: np.ndarray          # (n_weak,) decision-stump thresholds
    polarity: np.ndarray            # (n_weak,) +-1
    alphas: np.ndarray              # (n_weak,) AdaBoost weights
    stage_sizes: list               # weak-classifier count per stage
    stage_thresholds: np.ndarray    # (n_stages,) stage pass thresholds

    @property
    def n_stages(self):
        return len(self.stage_sizes)


def train_cascade(X: np.ndarray, y: np.ndarray, pool: list,
                  n_stages: int = 10, per_stage: int = 33,
                  stage_recall: float = 0.995, seed: int = 0) -> Cascade:
    """AdaBoost decision stumps per stage; stage thresholds set to hit
    ``stage_recall`` on training positives (classic VJ construction:
    Fig. 4b's nested tree with cheap-front stages)."""
    rng = np.random.default_rng(seed)
    windows = jnp.asarray(X.reshape(-1, BASE, BASE))
    F = np.asarray(eval_features(windows, pool))     # (n, n_pool)
    yb = y.astype(np.float64) * 2 - 1

    active = np.ones(len(X), bool)                   # survivors so far
    feats, thresholds, polarity, alphas = [], [], [], []
    stage_sizes, stage_thrs = [], []

    for _ in range(n_stages):
        idx = np.where(active)[0]
        if len(idx) < 10 or (y[idx] == 1).sum() < 5 or (y[idx] == 0).sum() < 2:
            break
        Xi, yi = F[idx], yb[idx]
        w = np.ones(len(idx)) / len(idx)
        stage_score = np.zeros(len(idx))
        stage_feats = []
        for _k in range(per_stage):
            # best stump over a random subsample of the pool (speed)
            cand = rng.choice(len(pool), size=min(80, len(pool)), replace=False)
            best = None
            for ci in cand:
                vals = Xi[:, ci]
                order = np.argsort(vals)
                sv, sy, sw = vals[order], yi[order], w[order]
                # threshold between consecutive values; vectorized error
                cum_pos = np.cumsum(sw * (sy > 0))
                cum_neg = np.cumsum(sw * (sy < 0))
                tot_pos, tot_neg = cum_pos[-1], cum_neg[-1]
                # polarity +1: predict + if val > thr
                err_p = cum_pos + (tot_neg - cum_neg)
                err_m = cum_neg + (tot_pos - cum_pos)
                i_p, i_m = np.argmin(err_p), np.argmin(err_m)
                if err_p[i_p] <= err_m[i_m]:
                    err, i_thr, pol = err_p[i_p], i_p, 1.0
                else:
                    err, i_thr, pol = err_m[i_m], i_m, -1.0
                thr = sv[min(i_thr, len(sv) - 1)]
                if best is None or err < best[0]:
                    best = (err, ci, thr, pol)
            err, ci, thr, pol = best
            err = min(max(err, 1e-10), 1 - 1e-10)
            alpha = 0.5 * np.log((1 - err) / err)
            pred = pol * np.sign(Xi[:, ci] - thr)
            pred[pred == 0] = 1
            w = w * np.exp(-alpha * yi * pred)
            w /= w.sum()
            stage_score += alpha * pred
            feats.append(pool[ci])
            thresholds.append(thr)
            polarity.append(pol)
            alphas.append(alpha)
            stage_feats.append(ci)
        # stage threshold for target recall on positives
        pos_scores = np.sort(stage_score[yi > 0])
        k = max(0, int((1 - stage_recall) * len(pos_scores)) - 1)
        thr_stage = pos_scores[k] - 1e-9 if len(pos_scores) else 0.0
        stage_thrs.append(thr_stage)
        stage_sizes.append(len(stage_feats))
        # survivors: windows passing this stage
        passed = stage_score >= thr_stage
        active[idx] = passed

    return Cascade(feats, np.array(thresholds), np.array(polarity),
                   np.array(alphas), stage_sizes, np.array(stage_thrs))


def _run_stages(cascade: Cascade, F: jax.Array, strictness: float = 0.0):
    """Stump votes + masked stage loop on precomputed features (n, n_weak).

    Returns (accepted (n,) bool, stage_evals (n,) int32 — how many stages a
    data-dependent implementation would evaluate per window; the energy
    model charges exactly this).
    """
    pol = jnp.asarray(cascade.polarity, jnp.float32)
    thr = jnp.asarray(cascade.thresholds, jnp.float32)
    al = jnp.asarray(cascade.alphas, jnp.float32)
    pred = pol * jnp.sign(F - thr)
    pred = jnp.where(pred == 0, 1.0, pred)
    weighted = al * pred                              # (n, n_weak)

    alive = jnp.ones(F.shape[0], bool)
    evals = jnp.zeros(F.shape[0], jnp.int32)
    off = 0
    for si, size in enumerate(cascade.stage_sizes):
        evals = evals + alive.astype(jnp.int32)
        score = jnp.sum(weighted[:, off:off + size], axis=1)
        alive = alive & (score >= cascade.stage_thresholds[si] + strictness)
        off += size
    return alive, evals


def cascade_apply(cascade: Cascade, windows: jax.Array):
    """Run the cascade on canonical (n, 20, 20) windows (training scale)."""
    F = eval_features(windows, cascade.feats)        # (n, n_weak)
    return _run_stages(cascade, F)


# ---------------------------------------------------------------------------
# Window scanning (Fig. 4a): scale pyramid + (adaptive) step
# ---------------------------------------------------------------------------


def scan_positions(h: int, w: int, scale_factor: float = 1.25,
                   step: float = 0.025, adaptive: bool = True,
                   min_window: int = BASE):
    """Yield (y, x, win) scanning positions per Fig. 4a.

    ``adaptive`` step = max(1, step * window) pixels (the paper's 2.5%
    choice); non-adaptive uses ``int(step)`` pixels at every scale.
    """
    out = []
    win = float(min_window)
    while win <= min(h, w):
        iw = int(round(win))
        # adaptive floor of 2 px: the paper's 2.5%-of-window step on its
        # (higher-resolution) imagery never reaches sub-pixel steps; at our
        # 176x144 scale the equivalent relative step floors at 2 px
        s = max(2, int(round(step * iw))) if adaptive else max(1, int(step))
        for y in range(0, h - iw + 1, s):
            for x in range(0, w - iw + 1, s):
                out.append((y, x, iw))
        win *= scale_factor
    return out


def extract_windows(frame: np.ndarray, positions) -> np.ndarray:
    """Resample each scanning window to the canonical 20x20 (nearest)."""
    out = np.empty((len(positions), BASE, BASE), np.float32)
    for i, (y, x, win) in enumerate(positions):
        patch = frame[y:y + win, x:x + win]
        yy = (np.arange(BASE) * win // BASE).clip(0, win - 1)
        xx = (np.arange(BASE) * win // BASE).clip(0, win - 1)
        out[i] = patch[np.ix_(yy, xx)]
    return out


def detect_faces(cascade: Cascade, frame: np.ndarray, scale_factor=1.25,
                 step=0.025, adaptive=True, strictness: float = 0.0,
                 chunk: int = 1024):
    """Full-frame detection — the slow *reference* path (golden oracle).

    Returns (detections, n_invocations, n_stage_evals).  Every scanning
    window is materialized at native resolution, gets its own integral
    image, and the features (scaled to the window) are evaluated in a
    Python loop — the per-window dataflow the paper's streaming ASIC
    executes, with no early-exit savings.  :func:`detect_faces_batch`
    computes the same math from one frame-level integral image and is what
    production uses; tests pin the two to identical detection sets.

    ``strictness`` adds a margin to every stage threshold — the deployment
    precision/recall knob (the paper tunes stage thresholds the same way).
    """
    pos = scan_positions(frame.shape[0], frame.shape[1], scale_factor, step, adaptive)
    if not pos:
        return [], 0, 0
    dets, total_evals = [], 0
    i = 0
    while i < len(pos):                 # scan order is scale-major
        win = pos[i][2]
        j = i
        while j < len(pos) and pos[j][2] == win:
            j += 1
        for c0 in range(i, j, chunk):
            group = pos[c0:min(c0 + chunk, j)]
            patches = np.stack([frame[y:y + win, x:x + win]
                                for (y, x, _w) in group])
            F = eval_features_scaled(jnp.asarray(patches), win, cascade.feats)
            alive, evals = _run_stages(cascade, F, strictness)
            dets.extend(group[k] for k in np.where(np.asarray(alive))[0])
            total_evals += int(np.asarray(evals).sum())
        i = j
    return dets, len(pos), total_evals


# ---------------------------------------------------------------------------
# Frame-resident fused front-end (DESIGN.md §3): one integral image,
# gathered Haar features, compacting cascade
# ---------------------------------------------------------------------------

_NORM_W = np.array([1.0, -1.0, -1.0, 1.0], np.float32)   # window-sum corners


@dataclasses.dataclass(frozen=True)
class ScanGrid:
    """Static scan geometry for one (frame shape, scan parameters) pair:
    every (y, x, win) position, each window's flat *base* index into the
    zero-padded (h+1, w+1) integral image, and its pyramid-scale id."""

    h: int
    w: int
    positions: tuple
    scales: tuple                # distinct window sizes, pyramid order
    bases: np.ndarray            # (n,) int32: y * (w + 1) + x
    scale_id: np.ndarray         # (n,) int32 index into ``scales``


@functools.lru_cache(maxsize=32)
def build_scan_grid(h: int, w: int, scale_factor: float = 1.25,
                    step: float = 0.025, adaptive: bool = True) -> ScanGrid:
    pos = scan_positions(h, w, scale_factor, step, adaptive)
    scales, sid = [], []
    for (_y, _x, win) in pos:
        if not scales or scales[-1] != win:
            scales.append(win)
        sid.append(len(scales) - 1)
    bases = np.array([y * (w + 1) + x for (y, x, _win) in pos], np.int32)
    return ScanGrid(h, w, tuple(pos), tuple(scales), bases,
                    np.array(sid, np.int32))


@dataclasses.dataclass(frozen=True)
class GatherTables:
    """Per-(cascade, grid) corner-tap tensors for the fused front-end:
    each weak classifier as <= 8 integral-image taps, coordinates scaled
    per pyramid level and flattened to base-relative offsets."""

    offsets: np.ndarray          # (n_scales, n_weak, CORNER_SLOTS) int32
    weights: np.ndarray          # (n_weak, CORNER_SLOTS) f32, 0-padded
    norm_offsets: np.ndarray     # (n_scales, 4) int32 window-sum taps
    areas: np.ndarray            # (n_scales,) f32 win^2
    thresholds: np.ndarray       # (n_weak,) stump params
    polarity: np.ndarray
    alphas: np.ndarray
    stage_sizes: tuple
    stage_thresholds: np.ndarray


def build_gather_tables(cascade: Cascade, grid: ScanGrid) -> GatherTables:
    stride = grid.w + 1
    n_weak = len(cascade.feats)
    offsets = np.zeros((len(grid.scales), n_weak, CORNER_SLOTS), np.int32)
    weights = np.zeros((n_weak, CORNER_SLOTS), np.float32)
    for k, f in enumerate(cascade.feats):
        for c, (_dy, _dx, wv) in enumerate(feature_corners(f)):
            weights[k, c] = wv     # weight pattern is scale-invariant
    for s, win in enumerate(grid.scales):
        for k, f in enumerate(cascade.feats):
            for c, (dy, dx, _wv) in enumerate(
                    feature_corners(scale_feature(f, win))):
                offsets[s, k, c] = dy * stride + dx
    norm_offsets = np.array(
        [[win * stride + win, win, win * stride, 0] for win in grid.scales],
        np.int32)
    areas = np.array([float(win * win) for win in grid.scales], np.float32)
    return GatherTables(
        offsets, weights, norm_offsets, areas,
        np.asarray(cascade.thresholds, np.float32),
        np.asarray(cascade.polarity, np.float32),
        np.asarray(cascade.alphas, np.float32),
        tuple(cascade.stage_sizes),
        np.asarray(cascade.stage_thresholds, np.float32))


class FusedDetector:
    """Frame-resident fused detection front-end.

    The frame is touched once: one frame-level integral image (plus one of
    the squared frame, for variance normalization) — computed by the
    streaming Pallas kernel on TPU (kernels/integral_image) or the jnp
    oracle elsewhere.  Every scanning window at every pyramid scale is then
    evaluated by gathering <= 8 corners per weak classifier out of that one
    table (kernels/haar_frontend), replacing the seed's ~400x data
    amplification (25,853 materialized 20x20 windows per 176x144 frame)
    with lookups.  The stage loop runs through
    ``core.cascade.compacting_cascade``: after :meth:`calibrate`, stage i
    computes only on a capacity-bounded survivor prefix, so the paper's
    "86% fewer invocations" saves real FLOPs under static shapes.

    :func:`detect_faces` is the golden oracle; with ample capacities the
    two produce identical detection sets (tests/test_detect.py).
    """

    def __init__(self, cascade: Cascade, h: int, w: int, *,
                 scale_factor: float = 1.25, step: float = 0.025,
                 adaptive: bool = True, strictness: float = 0.0,
                 capacities=None, use_pallas=None, interpret: bool = False):
        self.cascade = cascade
        # window bases ride through the compacted item triple as float32,
        # which is exact only below 2^24
        if (h + 1) * (w + 1) >= 2 ** 24:
            raise ValueError(f"frame {h}x{w} too large for f32-exact "
                             "window indices (needs (h+1)*(w+1) < 2^24)")
        self.grid = build_scan_grid(h, w, scale_factor, step, adaptive)
        self.tables = build_gather_tables(cascade, self.grid)
        self.n_windows = len(self.grid.positions)
        self.n_stages = len(self.tables.stage_sizes)
        self.strictness = float(strictness)
        if use_pallas is None:
            use_pallas = jax.default_backend() == "tpu"
        self.use_pallas = bool(use_pallas)
        self.interpret = bool(interpret)
        self.capacities = (list(capacities) if capacities is not None
                           else [self.n_windows] * self.n_stages)
        self._apply = self._build(tuple(self.capacities))

    # -- jitted core --------------------------------------------------------

    def _build(self, capacities: tuple):
        t = self.tables
        # NOTE: the tables ride in as jit *arguments*, not closure constants
        # — embedded as constants, XLA constant-folds the (n_windows, sz, 8)
        # index tensors at compile time (minutes of folding for zero runtime
        # gain, since the gathers themselves depend on the frame).
        consts = tuple(jnp.asarray(a) for a in (
            self.grid.bases, self.grid.scale_id, t.offsets, t.weights,
            t.thresholds, t.polarity, t.alphas, t.norm_offsets, t.areas))
        bounds, o = [], 0
        for sz in t.stage_sizes:
            bounds.append((o, o + sz))
            o += sz
        stage_thr = [float(v) + self.strictness for v in t.stage_thresholds]
        use_pallas, interpret = self.use_pallas, self.interpret

        def apply(frames, bases, sids, offsets, weights, thr, pol, al,
                  n_off, areas):
            norm_w = jnp.asarray(_NORM_W)
            # the scale-id table rides in as a jit *argument* (NOTE above),
            # so its in-bounds promise is data-dependent; clamp once — a
            # no-op for real grids — to make the per-scale lookups below
            # statically guarded
            sids = jnp.clip(sids, 0, areas.shape[0] - 1)

            def one_frame(iif, ii2f):
                nidx = bases[:, None] + n_off[sids]
                s1 = jnp.sum(jnp.take(iif, nidx.reshape(-1))
                             .reshape(nidx.shape) * norm_w, -1)
                s2 = jnp.sum(jnp.take(ii2f, nidx.reshape(-1))
                             .reshape(nidx.shape) * norm_w, -1)
                area = areas[sids]
                mu = s1 / area
                var = s2 / area - mu * mu
                sd = jnp.sqrt(jnp.maximum(var, 1e-6))
                inv = 1.0 / (sd * area)
                # a window is fully described by (base, scale id,
                # normalizer) — this triple is what the compacting cascade
                # carries and compacts.
                items = jnp.stack([bases.astype(jnp.float32),
                                   sids.astype(jnp.float32), inv], axis=1)

                def stage_fn(lo, hi):
                    def fn(it):
                        # the item triple rides through compaction as f32
                        # (exact below 2^24); clamp in float before the int
                        # casts so dead/padded slots index in-bounds instead
                        # of hitting a backend-defined NaN cast
                        return haar_stage_scores(
                            iif,
                            jnp.clip(it[:, 0], 0,
                                     iif.shape[0] - 1).astype(jnp.int32),
                            jnp.clip(it[:, 1], 0,
                                     areas.shape[0] - 1).astype(jnp.int32),
                            it[:, 2],
                            offsets[:, lo:hi], weights[lo:hi], thr[lo:hi],
                            pol[lo:hi], al[lo:hi],
                            use_pallas=use_pallas, interpret=interpret)
                    return fn

                stages = [CoreStage(stage_fn(lo, hi), stage_thr[si],
                                    f"vj{si}")
                          for si, (lo, hi) in enumerate(bounds)]
                res = compacting_cascade(stages, items, list(capacities))
                return res.mask, res.n_survivors, res.dropped

            frames = frames.astype(jnp.float32)
            ii = frame_integral(frames, use_pallas=use_pallas,
                                interpret=interpret)
            ii2 = frame_integral(frames * frames, use_pallas=use_pallas,
                                 interpret=interpret)
            b = frames.shape[0]
            return jax.vmap(one_frame)(ii.reshape(b, -1), ii2.reshape(b, -1))

        jitted = jax.jit(apply)
        # Traceable handle for callers that fuse the detector into a LARGER
        # jit region (camera/pipelines.FaceAuthExecutor): call
        # ``traceable_apply(frames, *apply_consts)`` inside your own jit and
        # pass ``apply_consts`` through as jit *arguments* (same
        # constant-folding hazard as the NOTE above).
        self.traceable_apply = apply
        self.apply_consts = consts
        return lambda frames: jitted(frames, *consts)

    # -- capacity calibration ----------------------------------------------

    def calibrate(self, frames, margin: float = 2.0, quantum: int = 128):
        """Measure per-stage survivor counts on calibration frames (full-
        capacity pass = masked oracle) and set compacting capacities from
        them — choosing the knob from workload statistics, exactly how the
        paper picked window scale/step."""
        frames = np.asarray(frames, np.float32)
        if frames.ndim == 2:
            frames = frames[None]
        if frames.shape[0] == 0:
            return self.capacities            # nothing to measure; keep as-is
        full = (self._apply
                if self.capacities == [self.n_windows] * self.n_stages
                else self._build((self.n_windows,) * self.n_stages))
        _, surv, _ = full(jnp.asarray(frames))
        counts = np.asarray(surv).max(axis=0)
        self.capacities = capacities_from_counts(
            self.n_windows, counts, margin=margin, quantum=quantum)
        self._apply = self._build(tuple(self.capacities))
        return self.capacities

    # -- detection ----------------------------------------------------------

    def __call__(self, frames):
        """(B, h, w) -> (mask (B, n_windows), n_survivors (B, n_stages),
        dropped (B, n_stages)) as device arrays."""
        return self._apply(jnp.asarray(frames))

    def detect(self, frames):
        """Batched detection with detect_faces-compatible accounting.

        Returns (detections per frame — list of (y, x, win) lists, stats).
        stats["stage_evals"] counts data-dependent stage evaluations (the
        energy model's charge); stats["static_stage_evals"] counts what the
        static-shape compacted execution actually computed.
        """
        frames = np.asarray(frames, np.float32)
        if frames.ndim == 2:
            frames = frames[None]
        mask, surv, dropped = (np.asarray(a) for a in self(frames))
        pos = self.grid.positions
        dets = [[pos[i] for i in np.where(m)[0]] for m in mask]
        entering = np.concatenate(
            [np.full((len(frames), 1), self.n_windows, np.int64),
             surv[:, :-1].astype(np.int64)], axis=1)
        stats = {
            "n_windows": self.n_windows,
            "n_invocations": self.n_windows * len(frames),
            "stage_evals": int(entering.sum()),
            "static_stage_evals": len(frames) * int(np.sum(self.capacities)),
            "n_survivors": surv,
            "dropped": int(dropped.sum()),
            "capacities": list(self.capacities),
        }
        return dets, stats


_FUSED_CACHE: dict = {}


def detect_faces_batch(cascade: Cascade, frames, scale_factor=1.25,
                       step=0.025, adaptive=True, strictness: float = 0.0,
                       capacities="auto", use_pallas=None,
                       interpret: bool = False):
    """Fused, jitted, batched detection over (B, h, w) frames.

    ``capacities="auto"`` calibrates compacting capacities on the first
    (up to 4) frames; ``None`` disables compaction (full capacities, the
    masked oracle); an explicit list is used as-is.  Detectors are cached
    per (cascade, shape, scan parameters), so steady-state calls pay only
    the jitted computation.  Returns (dets_per_frame, stats) as
    :meth:`FusedDetector.detect`.
    """
    frames = np.asarray(frames, np.float32)
    if frames.ndim == 2:
        frames = frames[None]
    if frames.shape[0] == 0:
        return [], {"n_windows": 0, "n_invocations": 0, "stage_evals": 0,
                    "static_stage_evals": 0,
                    "n_survivors": np.zeros((0, 0), np.int32),
                    "dropped": 0, "capacities": []}
    h, w = frames.shape[-2:]
    auto = isinstance(capacities, str) and capacities == "auto"
    cap_key = (capacities if auto or capacities is None
               else tuple(capacities))
    key = (id(cascade), h, w, scale_factor, step, adaptive, strictness,
           use_pallas, interpret, cap_key)
    hit = _FUSED_CACHE.get(key)
    if hit is not None and hit[0] is cascade:
        det = hit[1]
    else:
        det = FusedDetector(cascade, h, w, scale_factor=scale_factor,
                            step=step, adaptive=adaptive,
                            strictness=strictness,
                            capacities=None if auto else capacities,
                            use_pallas=use_pallas, interpret=interpret)
        if auto:
            det.calibrate(frames[: min(4, len(frames))])
        if len(_FUSED_CACHE) >= 16:      # bound the jitted-program cache
            _FUSED_CACHE.pop(next(iter(_FUSED_CACHE)))
        _FUSED_CACHE[key] = (cascade, det)
    return det.detect(frames)


def harvest_hard_negatives(frames, truth, n: int = 1500, seed: int = 0):
    """Bootstrap negatives from scene windows away from true faces — the
    classic cascade-training trick (the paper's detector is trained the
    same way on real imagery)."""
    rng = np.random.default_rng(seed)
    neg = []
    idxs = rng.choice(len(frames), min(10, len(frames)), replace=False)
    per = max(1, n // len(idxs))
    for i in idxs:
        pos = scan_positions(frames[i].shape[0], frames[i].shape[1], 1.6, 0.08, True)
        take = rng.choice(len(pos), min(per, len(pos)), replace=False)
        wins = extract_windows(frames[i], [pos[j] for j in take])
        for w, (yy, xx, sz) in zip(wins, [pos[j] for j in take]):
            near = any(abs(yy - fy) < 15 and abs(xx - fx) < 15
                       for (fy, fx, _s) in truth[i]["faces"])
            if not near:
                neg.append(w.reshape(-1))
    return np.stack(neg).astype(np.float32)
