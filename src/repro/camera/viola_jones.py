"""Viola-Jones face detection: Haar features, AdaBoost cascade, scanning.

Paper §III-B reproduced end to end:

* rectangular Haar features evaluated on the integral image;
* a trained cascade — 10 stages x 33 weak classifiers (Table I: "Cascade
  10x33") fitted with AdaBoost on the synthetic face set, each stage's
  threshold tuned to a target per-stage recall (the classic cascade
  construction);
* window scanning with *scale factor* and *step size* knobs, including the
  paper's adaptive step ("a percentage of the window size") — Fig. 4a/4c;
* the cost model counts classifier invocations and per-window feature
  evaluations, reproducing the "86% fewer invocations at scale 1.25 /
  adaptive 2.5% with no accuracy loss" result.

Execution model: batched over windows with masking (TPU-style; see
core/cascade.py) — the cascade's early exits become survivor masks, and
the *invocation count* (what the paper's energy model charges for) is the
number of stage evaluations a data-dependent implementation would run,
computed exactly from the masks.
"""

from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.camera.integral import integral_image, window_sum

BASE = 20    # canonical window resolution (matches the NN input 20x20)


# ---------------------------------------------------------------------------
# Haar features on the canonical 20x20 window
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class HaarFeature:
    """Two/three-rectangle feature, coordinates in the canonical window."""
    kind: int            # 0: 2-rect horiz, 1: 2-rect vert, 2: 3-rect horiz, 3: 3-rect vert
    y: int
    x: int
    h: int
    w: int


def make_feature_pool(seed: int = 0, n: int = 400) -> list:
    rng = np.random.default_rng(seed)
    pool = []
    while len(pool) < n:
        kind = int(rng.integers(0, 4))
        nsplit = 2 if kind < 2 else 3
        if kind in (0, 2):   # horizontal split: w divisible
            w = int(rng.integers(nsplit, BASE // 2 + 1)) * nsplit // nsplit
            w = max(nsplit, (w // nsplit) * nsplit)
            h = int(rng.integers(2, BASE // 2 + 1))
        else:
            h = max(nsplit, (int(rng.integers(nsplit, BASE // 2 + 1)) // nsplit) * nsplit)
            w = int(rng.integers(2, BASE // 2 + 1))
        y = int(rng.integers(0, BASE - h + 1))
        x = int(rng.integers(0, BASE - w + 1))
        pool.append(HaarFeature(kind, y, x, h, w))
    return pool


def eval_features(windows: jax.Array, feats: list) -> jax.Array:
    """windows: (n, 20, 20) -> (n, n_feats) Haar responses (variance-normalized).

    Evaluated via each window's integral image — the same arithmetic the
    streaming accelerator performs, vectorized over windows.
    """
    n = windows.shape[0]
    ii = integral_image(windows)                     # (n, 21, 21)
    mu = window_sum(ii, 0, 0, BASE, BASE) / (BASE * BASE)
    sq = integral_image(windows * windows)
    var = window_sum(sq, 0, 0, BASE, BASE) / (BASE * BASE) - mu * mu
    sd = jnp.sqrt(jnp.maximum(var, 1e-6))

    cols = []
    for f in feats:
        if f.kind == 0:      # 2-rect horizontal: left - right
            wl = window_sum(ii, f.y, f.x, f.h, f.w // 2)
            wr = window_sum(ii, f.y, f.x + f.w // 2, f.h, f.w // 2)
            r = wl - wr
        elif f.kind == 1:    # 2-rect vertical: top - bottom
            wt = window_sum(ii, f.y, f.x, f.h // 2, f.w)
            wb = window_sum(ii, f.y + f.h // 2, f.x, f.h // 2, f.w)
            r = wt - wb
        elif f.kind == 2:    # 3-rect horizontal: sides - 2*middle
            w3 = f.w // 3
            a = window_sum(ii, f.y, f.x, f.h, w3)
            b = window_sum(ii, f.y, f.x + w3, f.h, w3)
            c = window_sum(ii, f.y, f.x + 2 * w3, f.h, w3)
            r = a + c - 2 * b
        else:                # 3-rect vertical
            h3 = f.h // 3
            a = window_sum(ii, f.y, f.x, h3, f.w)
            b = window_sum(ii, f.y + h3, f.x, h3, f.w)
            c = window_sum(ii, f.y + 2 * h3, f.x, h3, f.w)
            r = a + c - 2 * b
        cols.append(r / (sd * BASE * BASE))
    return jnp.stack(cols, axis=-1)


# ---------------------------------------------------------------------------
# AdaBoost cascade (10 stages x 33 weak classifiers, Table I)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Cascade:
    feats: list                     # selected HaarFeatures, flat
    thresholds: np.ndarray          # (n_weak,) decision-stump thresholds
    polarity: np.ndarray            # (n_weak,) +-1
    alphas: np.ndarray              # (n_weak,) AdaBoost weights
    stage_sizes: list               # weak-classifier count per stage
    stage_thresholds: np.ndarray    # (n_stages,) stage pass thresholds

    @property
    def n_stages(self):
        return len(self.stage_sizes)


def train_cascade(X: np.ndarray, y: np.ndarray, pool: list,
                  n_stages: int = 10, per_stage: int = 33,
                  stage_recall: float = 0.995, seed: int = 0) -> Cascade:
    """AdaBoost decision stumps per stage; stage thresholds set to hit
    ``stage_recall`` on training positives (classic VJ construction:
    Fig. 4b's nested tree with cheap-front stages)."""
    rng = np.random.default_rng(seed)
    windows = jnp.asarray(X.reshape(-1, BASE, BASE))
    F = np.asarray(eval_features(windows, pool))     # (n, n_pool)
    yb = y.astype(np.float64) * 2 - 1

    active = np.ones(len(X), bool)                   # survivors so far
    feats, thresholds, polarity, alphas = [], [], [], []
    stage_sizes, stage_thrs = [], []

    for _ in range(n_stages):
        idx = np.where(active)[0]
        if len(idx) < 10 or (y[idx] == 1).sum() < 5 or (y[idx] == 0).sum() < 2:
            break
        Xi, yi = F[idx], yb[idx]
        w = np.ones(len(idx)) / len(idx)
        stage_score = np.zeros(len(idx))
        stage_feats = []
        for _k in range(per_stage):
            # best stump over a random subsample of the pool (speed)
            cand = rng.choice(len(pool), size=min(80, len(pool)), replace=False)
            best = None
            for ci in cand:
                vals = Xi[:, ci]
                order = np.argsort(vals)
                sv, sy, sw = vals[order], yi[order], w[order]
                # threshold between consecutive values; vectorized error
                cum_pos = np.cumsum(sw * (sy > 0))
                cum_neg = np.cumsum(sw * (sy < 0))
                tot_pos, tot_neg = cum_pos[-1], cum_neg[-1]
                # polarity +1: predict + if val > thr
                err_p = cum_pos + (tot_neg - cum_neg)
                err_m = cum_neg + (tot_pos - cum_pos)
                i_p, i_m = np.argmin(err_p), np.argmin(err_m)
                if err_p[i_p] <= err_m[i_m]:
                    err, i_thr, pol = err_p[i_p], i_p, 1.0
                else:
                    err, i_thr, pol = err_m[i_m], i_m, -1.0
                thr = sv[min(i_thr, len(sv) - 1)]
                if best is None or err < best[0]:
                    best = (err, ci, thr, pol)
            err, ci, thr, pol = best
            err = min(max(err, 1e-10), 1 - 1e-10)
            alpha = 0.5 * np.log((1 - err) / err)
            pred = pol * np.sign(Xi[:, ci] - thr)
            pred[pred == 0] = 1
            w = w * np.exp(-alpha * yi * pred)
            w /= w.sum()
            stage_score += alpha * pred
            feats.append(pool[ci])
            thresholds.append(thr)
            polarity.append(pol)
            alphas.append(alpha)
            stage_feats.append(ci)
        # stage threshold for target recall on positives
        pos_scores = np.sort(stage_score[yi > 0])
        k = max(0, int((1 - stage_recall) * len(pos_scores)) - 1)
        thr_stage = pos_scores[k] - 1e-9 if len(pos_scores) else 0.0
        stage_thrs.append(thr_stage)
        stage_sizes.append(len(stage_feats))
        # survivors: windows passing this stage
        passed = stage_score >= thr_stage
        active[idx] = passed

    return Cascade(feats, np.array(thresholds), np.array(polarity),
                   np.array(alphas), stage_sizes, np.array(stage_thrs))


def cascade_apply(cascade: Cascade, windows: jax.Array):
    """Run the cascade on (n, 20, 20) windows.

    Returns (accepted (n,) bool, stage_evals (n,) int32 — how many stages a
    data-dependent implementation would evaluate per window; the energy
    model charges exactly this).
    """
    F = eval_features(windows, cascade.feats)        # (n, n_weak)
    pol = jnp.asarray(cascade.polarity, jnp.float32)
    thr = jnp.asarray(cascade.thresholds, jnp.float32)
    al = jnp.asarray(cascade.alphas, jnp.float32)
    pred = pol * jnp.sign(F - thr)
    pred = jnp.where(pred == 0, 1.0, pred)
    weighted = al * pred                              # (n, n_weak)

    alive = jnp.ones(windows.shape[0], bool)
    evals = jnp.zeros(windows.shape[0], jnp.int32)
    off = 0
    for si, size in enumerate(cascade.stage_sizes):
        evals = evals + alive.astype(jnp.int32)
        score = jnp.sum(weighted[:, off:off + size], axis=1)
        alive = alive & (score >= cascade.stage_thresholds[si])
        off += size
    return alive, evals


# ---------------------------------------------------------------------------
# Window scanning (Fig. 4a): scale pyramid + (adaptive) step
# ---------------------------------------------------------------------------


def scan_positions(h: int, w: int, scale_factor: float = 1.25,
                   step: float = 0.025, adaptive: bool = True,
                   min_window: int = BASE):
    """Yield (y, x, win) scanning positions per Fig. 4a.

    ``adaptive`` step = max(1, step * window) pixels (the paper's 2.5%
    choice); non-adaptive uses ``int(step)`` pixels at every scale.
    """
    out = []
    win = float(min_window)
    while win <= min(h, w):
        iw = int(round(win))
        # adaptive floor of 2 px: the paper's 2.5%-of-window step on its
        # (higher-resolution) imagery never reaches sub-pixel steps; at our
        # 176x144 scale the equivalent relative step floors at 2 px
        s = max(2, int(round(step * iw))) if adaptive else max(1, int(step))
        for y in range(0, h - iw + 1, s):
            for x in range(0, w - iw + 1, s):
                out.append((y, x, iw))
        win *= scale_factor
    return out


def extract_windows(frame: np.ndarray, positions) -> np.ndarray:
    """Resample each scanning window to the canonical 20x20 (nearest)."""
    out = np.empty((len(positions), BASE, BASE), np.float32)
    for i, (y, x, win) in enumerate(positions):
        patch = frame[y:y + win, x:x + win]
        yy = (np.arange(BASE) * win // BASE).clip(0, win - 1)
        xx = (np.arange(BASE) * win // BASE).clip(0, win - 1)
        out[i] = patch[np.ix_(yy, xx)]
    return out


def detect_faces(cascade: Cascade, frame: np.ndarray, scale_factor=1.25,
                 step=0.025, adaptive=True, strictness: float = 0.0):
    """Full-frame detection.  Returns (detections, n_invocations, n_stage_evals).

    ``strictness`` adds a margin to every stage threshold — the deployment
    precision/recall knob (the paper tunes stage thresholds the same way).
    """
    pos = scan_positions(frame.shape[0], frame.shape[1], scale_factor, step, adaptive)
    if not pos:
        return [], 0, 0
    wins = extract_windows(frame, pos)
    casc = cascade
    if strictness:
        casc = Cascade(cascade.feats, cascade.thresholds, cascade.polarity,
                       cascade.alphas, cascade.stage_sizes,
                       cascade.stage_thresholds + strictness)
    accepted, evals = cascade_apply(casc, jnp.asarray(wins))
    accepted = np.asarray(accepted)
    dets = [pos[i] for i in np.where(accepted)[0]]
    return dets, len(pos), int(np.asarray(evals).sum())


def harvest_hard_negatives(frames, truth, n: int = 1500, seed: int = 0):
    """Bootstrap negatives from scene windows away from true faces — the
    classic cascade-training trick (the paper's detector is trained the
    same way on real imagery)."""
    rng = np.random.default_rng(seed)
    neg = []
    idxs = rng.choice(len(frames), min(10, len(frames)), replace=False)
    per = max(1, n // len(idxs))
    for i in idxs:
        pos = scan_positions(frames[i].shape[0], frames[i].shape[1], 1.6, 0.08, True)
        take = rng.choice(len(pos), min(per, len(pos)), replace=False)
        wins = extract_windows(frames[i], [pos[j] for j in take])
        for w, (yy, xx, sz) in zip(wins, [pos[j] for j in take]):
            near = any(abs(yy - fy) < 15 and abs(xx - fx) < 15
                       for (fy, fx, _s) in truth[i]["faces"])
            if not near:
                neg.append(w.reshape(-1))
    return np.stack(neg).astype(np.float32)
