"""Bilateral-space stereo (BSSA) — paper §IV-A/B, after Barron et al. [4].

Pipeline per camera pair (Fig. 10/12):

1. **Rough disparity** — block matching over a disparity range (the "rough
   disparity" of global stereo pipelines).
2. **Bilateral grid construction (splat)** — pixels map to grid vertices
   (y/s_y, x/s_x, intensity/s_r): the paper's B3 output, the biggest
   intermediate (Fig. 13).
3. **Bilateral-space refinement** — the FPGA-accelerated block: iterated
   [1,2,1] blurs of the disparity-weighted grid ("applying millions of
   blurs ... most of these filters can run in parallel"), which in
   bilateral space equals a global edge-aware smoothing in pixel space.
   f32 throughout — the paper found >=32-bit float necessary for quality.
4. **Slice** — sample the refined grid back at pixel coordinates.

The blur kernel is the perf-critical unit: kernels/bilateral_blur holds
the Pallas TPU version, and :func:`bssa_depth` refines through it (via
``ops.refine_grid`` backend dispatch).  This module keeps the jnp oracles
(:func:`rough_disparity_ref`, :func:`refine`, :func:`bssa_depth_ref`) and
the quality harness (MS-SSIM vs grid size, Fig. 11b); the rig-scale
batched executor is ``camera.pipelines.VRRigExecutor``.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import numpy as np
import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Rough disparity (block matching)
# ---------------------------------------------------------------------------
#
# Disparity convention (both implementations): hypothesis d aligns
# ``left[y, x]`` with ``right[y, x - d]`` after shifting the right view d
# pixels toward higher x — i.e. a pair generated as right[x] = left[x + d]
# is recovered exactly (pinned by the shifted-pair property test).


def rough_disparity(left: jax.Array, right: jax.Array, max_disp: int = 16,
                    patch: int = 5, *, hypothesis_chunk: int = 8,
                    use_pallas: bool | None = None,
                    interpret: bool = False) -> jax.Array:
    """Winner-take-all SAD block matching.  (h, w) f32 -> (h, w) f32.

    Fused cost-volume formulation: shifted right views are gathered as one
    indexed load, their |left - right_d| maps stacked and pushed through a
    single batched padded integral image (the same unit VJ uses —
    kernels/integral_image when ``use_pallas``), and the winning hypothesis
    taken by a vectorized argmin.  The hypothesis axis is blocked into
    ``hypothesis_chunk``-sized chunks scanned with a running min so the
    working set stays cache-resident (chunk >= D+1 degenerates to the pure
    one-shot stack).  Numerically identical to the seed Python loop
    (:func:`rough_disparity_ref`): same cumsum association per hypothesis,
    same edge replication, same first-wins tie-breaking.
    """
    from repro.camera.integral import frame_integral

    if use_pallas is None:
        use_pallas = interpret or jax.default_backend() == "tpu"
    h, w = left.shape
    pad = patch // 2
    n_hyp = max_disp + 1
    chunk = min(hypothesis_chunk, n_hyp)
    n_chunks = -(-n_hyp // chunk)

    def sad_chunk(ds):
        # shifted right views as one gather: rs[d, y, x] = right[y, max(x-d, 0)]
        # (edge columns replicate, matching the seed's roll + first-column fill)
        # two-sided clip (d >= 0 makes the upper bound vacuous, but the
        # gather below is PROMISE_IN_BOUNDS — guard both sides statically)
        xs = jnp.clip(jnp.arange(w)[None, :] - ds[:, None], 0, w - 1)
        rstack = jnp.moveaxis(right[:, xs], 1, 0)          # (chunk, h, w)
        diff = jnp.abs(left[None] - rstack)
        dp = jnp.pad(diff, ((0, 0), (pad, pad), (pad, pad)), mode="edge")
        ii = frame_integral(dp, use_pallas=use_pallas, interpret=interpret)
        sad = (ii[:, patch:, patch:] - ii[:, :-patch, patch:]
               - ii[:, patch:, :-patch] + ii[:, :-patch, :-patch])
        return sad[:, :h, :w]

    def body(carry, c):
        best, bestd = carry
        # clamp the ragged tail to d = max_disp: the duplicates produce
        # identical SADs and the strict running min keeps the first winner
        ds = jnp.minimum(c * chunk + jnp.arange(chunk), max_disp)
        sad = sad_chunk(ds)
        cmin = jnp.min(sad, axis=0)
        carg = jnp.argmin(sad, axis=0)
        better = cmin < best
        return (jnp.where(better, cmin, best),
                jnp.where(better, ds[carg], bestd)), None

    init = (jnp.full((h, w), jnp.inf), jnp.zeros((h, w), jnp.int32))
    (_, bestd), _ = jax.lax.scan(body, init, jnp.arange(n_chunks))
    return bestd.astype(jnp.float32)


def rough_disparity_ref(left: jax.Array, right: jax.Array, max_disp: int = 16,
                        patch: int = 5) -> jax.Array:
    """Seed per-hypothesis Python loop — the golden oracle (and the
    benchmark baseline): materializes D+1 full-frame SAD maps, one integral
    image each."""
    h, w = left.shape
    pad = patch // 2
    costs = []
    for d in range(max_disp + 1):
        rs = jnp.roll(right, d, axis=1)
        rs = rs.at[:, :d].set(right[:, :1] if d else rs[:, :d])
        diff = jnp.abs(left - rs)
        dp = jnp.pad(diff, pad, mode="edge")
        # box filter via cumsum (integral image trick — same unit as VJ!)
        ii = jnp.cumsum(jnp.cumsum(dp, axis=0), axis=1)
        ii = jnp.pad(ii, ((1, 0), (1, 0)))
        sad = (ii[patch:, patch:] - ii[:-patch, patch:]
               - ii[patch:, :-patch] + ii[:-patch, :-patch])
        costs.append(sad[:h, :w])
    cost = jnp.stack(costs)                      # (D+1, h, w)
    return jnp.argmin(cost, axis=0).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Bilateral grid
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GridSpec:
    sigma_spatial: int          # pixels per grid vertex (paper sweeps 4..64)
    sigma_range: float = 16.0   # intensity bins (on [0,255] scale)

    def dims(self, h: int, w: int):
        gy = int(np.ceil(h / self.sigma_spatial)) + 1
        gx = int(np.ceil(w / self.sigma_spatial)) + 1
        gr = int(np.ceil(256.0 / self.sigma_range)) + 1
        return gy, gx, gr


def _grid_coords(img: jax.Array, spec: GridSpec):
    h, w = img.shape
    yy, xx = jnp.mgrid[0:h, 0:w]
    gy = yy / spec.sigma_spatial
    gx = xx / spec.sigma_spatial
    gr = img * 255.0 / spec.sigma_range
    return gy.reshape(-1), gx.reshape(-1), gr.reshape(-1)


def splat(img: jax.Array, values: jax.Array, spec: GridSpec):
    """Accumulate (value, weight) into the bilateral grid (nearest vertex).

    Returns (grid_val, grid_wt) of shape (gy, gx, gr).  Nearest-vertex
    splatting matches the hardware design (the FPGA streams vertices, not
    8-corner trilinear updates); slicing interpolates instead.
    """
    h, w = img.shape
    gy, gx, gr = spec.dims(h, w)
    cy, cx, cr = _grid_coords(img, spec)
    # clip in float, then cast: same vertices for finite inputs, but a NaN
    # intensity no longer hits a backend-defined float->int cast
    iy = jnp.clip(jnp.round(cy), 0, gy - 1).astype(jnp.int32)
    ix = jnp.clip(jnp.round(cx), 0, gx - 1).astype(jnp.int32)
    ir = jnp.clip(jnp.round(cr), 0, gr - 1).astype(jnp.int32)
    flat = (iy * gx + ix) * gr + ir
    v = jnp.zeros((gy * gx * gr,), jnp.float32).at[flat].add(values.reshape(-1))
    wt = jnp.zeros((gy * gx * gr,), jnp.float32).at[flat].add(1.0)
    return v.reshape(gy, gx, gr), wt.reshape(gy, gx, gr)


def blur_121(grid: jax.Array) -> jax.Array:
    """Separable [1,2,1]/4 blur over the three grid dimensions.

    This is the compute unit the paper maps to FPGA DSPs; the Pallas TPU
    version lives in kernels/bilateral_blur (same semantics, tested
    allclose against this oracle).
    """
    def blur_axis(g, axis):
        lo = jnp.roll(g, 1, axis)
        hi = jnp.roll(g, -1, axis)
        # replicate edges (roll wraps; overwrite the wrapped slices)
        idx_lo = [slice(None)] * g.ndim
        idx_lo[axis] = slice(0, 1)
        idx_hi = [slice(None)] * g.ndim
        idx_hi[axis] = slice(-1, None)
        lo = lo.at[tuple(idx_lo)].set(g[tuple(idx_lo)])
        hi = hi.at[tuple(idx_hi)].set(g[tuple(idx_hi)])
        return 0.25 * lo + 0.5 * g + 0.25 * hi

    for ax in range(3):
        grid = blur_axis(grid, ax)
    return grid


def refine(grid_val: jax.Array, grid_wt: jax.Array, n_iters: int = 8):
    """Iterated bilateral-space smoothing of the disparity field.

    Normalized blur: both value and weight grids are blurred each
    iteration; the ratio is the edge-aware smoothed disparity ("simple
    local filters are equivalent to costly global edge-aware filters").
    """
    def body(carry, _):
        v, w = carry
        return (blur_121(v), blur_121(w)), None

    (v, w), _ = jax.lax.scan(body, (grid_val, grid_wt), None, length=n_iters)
    return v, w


def slice_grid(grid_val: jax.Array, grid_wt: jax.Array, img: jax.Array,
               spec: GridSpec) -> jax.Array:
    """Trilinear sampling of the refined grid at each pixel's coordinates."""
    h, w = img.shape
    gy, gx, gr = grid_val.shape
    cy, cx, cr = _grid_coords(img, spec)

    # clip in float, then cast (see splat): keeps the trilinear corner
    # indices in-bounds even for non-finite pixel values
    y0 = jnp.clip(jnp.floor(cy), 0, gy - 2).astype(jnp.int32)
    x0 = jnp.clip(jnp.floor(cx), 0, gx - 2).astype(jnp.int32)
    r0 = jnp.clip(jnp.floor(cr), 0, gr - 2).astype(jnp.int32)
    fy, fx, fr = cy - y0, cx - x0, cr - r0
    fy = jnp.clip(fy, 0, 1)
    fx = jnp.clip(fx, 0, 1)
    fr = jnp.clip(fr, 0, 1)

    def at(dy, dx, dr):
        flat = ((y0 + dy) * gx + (x0 + dx)) * gr + (r0 + dr)
        return grid_val.reshape(-1)[flat], grid_wt.reshape(-1)[flat]

    num = jnp.zeros_like(cy)
    den = jnp.zeros_like(cy)
    for dy in (0, 1):
        for dx in (0, 1):
            for dr in (0, 1):
                wv = (jnp.where(dy, fy, 1 - fy)
                      * jnp.where(dx, fx, 1 - fx)
                      * jnp.where(dr, fr, 1 - fr))
                v, wt = at(dy, dx, dr)
                num += wv * v
                den += wv * wt
    out = num / jnp.maximum(den, 1e-6)
    return out.reshape(h, w)


def bssa_depth(left: jax.Array, right: jax.Array, spec: GridSpec,
               max_disp: int = 16, n_iters: int = 8, *,
               use_pallas: bool | None = None, interpret: bool = False):
    """Full BSSA: fused rough disparity -> splat -> refine_grid -> slice.

    Refinement runs through kernels/bilateral_blur's ``refine_grid``
    (backend dispatch: the Pallas stencil on TPU, the blur_121 oracle math
    elsewhere — identical semantics either way, pinned in
    tests/test_kernels.py).  The end-to-end seed path survives as
    :func:`bssa_depth_ref`, the golden oracle.
    """
    from repro.kernels.bilateral_blur.ops import refine_grid

    rough = rough_disparity(left, right, max_disp, use_pallas=use_pallas,
                            interpret=interpret)
    gv, gw = splat(left, rough, spec)
    gv, gw = refine_grid(gv, gw, n_iters=n_iters, use_pallas=use_pallas,
                         interpret=interpret)
    return slice_grid(gv, gw, left, spec)


def bssa_depth_ref(left: jax.Array, right: jax.Array, spec: GridSpec,
                   max_disp: int = 16, n_iters: int = 8):
    """Seed jnp oracle: Python-loop rough disparity -> splat -> scan refine
    -> slice.  The benchmark baseline and parity anchor for the fused path."""
    rough = rough_disparity_ref(left, right, max_disp)
    gv, gw = splat(left, rough, spec)
    gv, gw = refine(gv, gw, n_iters)
    return slice_grid(gv, gw, left, spec)


# ---------------------------------------------------------------------------
# MS-SSIM (paper's quality metric, Fig. 11b) — [42]
# ---------------------------------------------------------------------------


def _ssim(a: jax.Array, b: jax.Array, win: int = 8):
    """Mean SSIM with box windows (adequate for relative comparisons)."""
    def box(x):
        ii = jnp.cumsum(jnp.cumsum(x, 0), 1)
        ii = jnp.pad(ii, ((1, 0), (1, 0)))
        s = (ii[win:, win:] - ii[:-win, win:] - ii[win:, :-win]
             + ii[:-win, :-win])
        return s / (win * win)

    c1, c2 = 0.01 ** 2, 0.03 ** 2
    mu_a, mu_b = box(a), box(b)
    va = box(a * a) - mu_a ** 2
    vb = box(b * b) - mu_b ** 2
    cov = box(a * b) - mu_a * mu_b
    ssim = ((2 * mu_a * mu_b + c1) * (2 * cov + c2)) / (
        (mu_a ** 2 + mu_b ** 2 + c1) * (va + vb + c2))
    return jnp.mean(ssim)


def ms_ssim(a: jax.Array, b: jax.Array, levels: int = 3) -> float:
    """Multi-scale SSIM: geometric mean of SSIM over dyadic downsamples."""
    total = 1.0
    for _ in range(levels):
        total = total * jnp.clip(_ssim(a, b), 1e-4, 1.0) ** (1.0 / levels)
        h, w = a.shape
        a = a[: h // 2 * 2, : w // 2 * 2].reshape(h // 2, 2, w // 2, 2).mean((1, 3))
        b = b[: h // 2 * 2, : w // 2 * 2].reshape(h // 2, 2, w // 2, 2).mean((1, 3))
    return float(total)
