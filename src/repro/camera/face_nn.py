"""Face-authentication NN (paper §III-A): 400-8-1 MLP, 8-bit datapath,
256-entry LUT sigmoid.

Reproduces every §III-A study:

* topology sweep (input window 5x5..20x20, hidden width) — accuracy vs
  energy, the paper picks 400-8-1;
* LUT sigmoid (256 entries) vs exact — "negligible effect on accuracy";
* datapath width 16/8/4-bit — 8-bit loses ~0.4%, 4-bit >1% (the knee);
  energy model: 8-bit datapath = 41% power reduction at 8 PEs (Table I).

Training is plain f32 AdamW (repro.train.optimizer is the big-model one;
this 3.2k-param model uses a local loop for clarity).  Inference offers
float / LUT / quantized paths; the quantized path emulates the ASIC:
int-b weights & activations, integer MACs, LUT activation.
"""

from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.reduction import quantize_bits


@dataclasses.dataclass
class FaceNN:
    w1: jnp.ndarray     # (in, hidden)
    b1: jnp.ndarray
    w2: jnp.ndarray     # (hidden, 1)
    b2: jnp.ndarray

    @property
    def topology(self):
        return (self.w1.shape[0], self.w1.shape[1], 1)

    @property
    def macs(self):
        return int(self.w1.size + self.w2.size)


def init_face_nn(key, n_in: int = 400, n_hidden: int = 8) -> FaceNN:
    k1, k2 = jax.random.split(key)
    return FaceNN(
        w1=jax.random.normal(k1, (n_in, n_hidden)) * (1.0 / np.sqrt(n_in)),
        b1=jnp.zeros((n_hidden,)),
        w2=jax.random.normal(k2, (n_hidden, 1)) * (1.0 / np.sqrt(n_hidden)),
        b2=jnp.zeros((1,)),
    )


# -- activation variants ------------------------------------------------------


def sigmoid_exact(x):
    return jax.nn.sigmoid(x)


def make_sigmoid_lut(entries: int = 256, lo: float = -8.0, hi: float = 8.0):
    """The hardware LUT: ``entries`` samples of sigmoid over [lo, hi]."""
    xs = np.linspace(lo, hi, entries, dtype=np.float32)
    return jnp.asarray(1.0 / (1.0 + np.exp(-xs))), (lo, hi, entries)


def sigmoid_lut(x, lut, meta):
    lo, hi, entries = meta
    # clamp in float BEFORE the int cast (same index for finite x, but a
    # NaN/inf pre-activation no longer hits a backend-defined cast, and the
    # clip statically guards the LUT gather on both sides)
    idx = jnp.clip((x - lo) / (hi - lo) * (entries - 1),
                   0, entries - 1).astype(jnp.int32)
    return lut[idx]


# -- forward paths ------------------------------------------------------------


def forward_float(nn: FaceNN, x, act=sigmoid_exact):
    h = act(x @ nn.w1 + nn.b1)
    return act(h @ nn.w2 + nn.b2)[..., 0]


def forward_lut(nn: FaceNN, x, lut, meta):
    h = sigmoid_lut(x @ nn.w1 + nn.b1, lut, meta)
    return sigmoid_lut(h @ nn.w2 + nn.b2, lut, meta)[..., 0]


def forward_quantized(nn: FaceNN, x, bits: int, lut, meta):
    """ASIC emulation: weights and activations fake-quantized to ``bits``,
    MAC accumulation exact (the PE accumulator is wide), LUT sigmoid."""
    w1 = quantize_bits(nn.w1, bits, block=nn.w1.shape[0])
    w2 = quantize_bits(nn.w2, bits, block=nn.w2.shape[0])
    xq = quantize_bits(x, bits, block=x.shape[-1])
    h = sigmoid_lut(xq @ w1 + nn.b1, lut, meta)
    hq = quantize_bits(h, bits, block=h.shape[-1])
    return sigmoid_lut(hq @ w2 + nn.b2, lut, meta)[..., 0]


# -- training -----------------------------------------------------------------


def train_face_nn(X: np.ndarray, y: np.ndarray, n_hidden: int = 8,
                  steps: int = 3000, lr: float = 3e-3, seed: int = 0,
                  l2: float = 1e-4) -> FaceNN:
    nn = init_face_nn(jax.random.PRNGKey(seed), X.shape[1], n_hidden)
    params = (nn.w1, nn.b1, nn.w2, nn.b2)
    Xj, yj = jnp.asarray(X), jnp.asarray(y, jnp.float32)

    def loss_fn(ps, xb, yb):
        w1, b1, w2, b2 = ps
        h = jax.nn.sigmoid(xb @ w1 + b1)
        logit = (h @ w2 + b2)[..., 0]
        ce = jnp.mean(jnp.maximum(logit, 0) - logit * yb +
                      jnp.log1p(jnp.exp(-jnp.abs(logit))))
        return ce + l2 * (jnp.sum(w1 * w1) + jnp.sum(w2 * w2))

    # Adam
    m = jax.tree_util.tree_map(jnp.zeros_like, params)
    v = jax.tree_util.tree_map(jnp.zeros_like, params)

    @jax.jit
    def step_fn(ps, m, v, t, key):
        idx = jax.random.randint(key, (128,), 0, Xj.shape[0])
        g = jax.grad(loss_fn)(ps, Xj[idx], yj[idx])
        m = jax.tree_util.tree_map(lambda a, b: 0.9 * a + 0.1 * b, m, g)
        v = jax.tree_util.tree_map(lambda a, b: 0.999 * a + 0.001 * b * b, v, g)
        mh = jax.tree_util.tree_map(lambda a: a / (1 - 0.9 ** t), m)
        vh = jax.tree_util.tree_map(lambda a: a / (1 - 0.999 ** t), v)
        ps = jax.tree_util.tree_map(
            lambda p, a, b: p - lr * a / (jnp.sqrt(b) + 1e-8), ps, mh, vh)
        return ps, m, v

    key = jax.random.PRNGKey(seed + 1)
    for t in range(1, steps + 1):
        key, sub = jax.random.split(key)
        params, m, v = step_fn(params, m, v, t, sub)
    w1, b1, w2, b2 = params

    # sigmoid output head (training used logit; store raw weights — forward
    # paths apply sigmoid at the output themselves)
    return FaceNN(w1=w1, b1=b1, w2=w2, b2=b2)


def classification_error(scores: jnp.ndarray, y: np.ndarray,
                         threshold: float = 0.5) -> float:
    pred = np.asarray(scores) >= threshold
    return float((pred != (y == 1)).mean())


# -- energy model (paper Table I + §III-A) -----------------------------------

NN_POWER_8PE_8BIT_W = 393e-6          # Table I
NN_FREQ_HZ = 27.9e6
NN_PES = 8


def nn_time_per_window(macs: int, n_pes: int = NN_PES,
                       n_hidden: int = 8) -> float:
    """Systolic schedule: macs spread over PEs, 1 MAC/PE/cycle + drain.

    Parallelism is per-neuron in the PE array, so PEs beyond the hidden
    width sit idle — the paper's "too many PEs results in underutilized
    resources and reduced parallelism for the narrow network" (§III-A);
    that idle-silicon power is what makes 8 PEs the energy optimum."""
    eff = min(n_pes, n_hidden)
    cycles = int(np.ceil(macs / eff)) + 32
    return cycles / NN_FREQ_HZ


def nn_power(bits: int = 8, n_pes: int = NN_PES) -> float:
    """Datapath-width & geometry scaling around the Table I point.

    Paper: 16->8 bits gives 41% power reduction at 8 PEs => P16 = P8/0.59.
    Width scaling linear in bits through the two anchors; PE scaling linear
    with a fixed sequencer overhead (the 'scheduling inefficiency' floor
    that makes <8 PEs energy-suboptimal, §III-A).
    """
    p8 = NN_POWER_8PE_8BIT_W
    p16 = p8 / 0.59
    slope = (p16 - p8) / 8.0               # watts per extra bit
    p_width = p8 + slope * (bits - 8)
    fixed = 0.25 * p8                      # sequencer + control overhead
    return fixed + (p_width - fixed) * (n_pes / NN_PES)


def nn_energy_per_window(macs: int, bits: int = 8, n_pes: int = NN_PES) -> float:
    return nn_power(bits, n_pes) * nn_time_per_window(macs, n_pes)
