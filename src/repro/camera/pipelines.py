"""The paper's two pipelines as Pipeline objects + calibrated cost profiles.

This is where the faithful reproduction meets the cost model
(core/costmodel): block work descriptors come from the *measured* synthetic
workload (funnel statistics), device profiles from Table I, and the two
under-determined constants — RF joules/byte and the NN ASIC's standby
leakage — are **calibrated** so the paper's two stated headline relations
hold exactly:

  (1) adding the NN in-camera raises total power by +28% (Fig. 9), and
  (2) the offload-vs-in-camera decision flips at 2.68x comm energy.

Everything else (config ordering in Fig. 8, the 8 MP crossover direction,
filter funnel, 265x/442,146x accelerator gains, the VR Fig. 14 ladder)
must then *emerge* — benchmarks/fa_system.py and vr_system.py check that
they do.  See DESIGN.md §5 and EXPERIMENTS.md for the argument.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.costmodel import (
    ARM_A9,
    ETH_25G,
    ETH_400G,
    HardwareProfile,
    IMAGE_SENSOR,
    MOTION_ASIC,
    MSP430,
    NN_ASIC,
    QUADRO_GPU,
    RF_LINK,
    VIRTEX_FPGA,
    VJ_ASIC,
    ZYNQ_FPGA,
)
from repro.core.pipeline import Block, BlockKind, Pipeline

# ---------------------------------------------------------------------------
# §III face authentication pipeline (WISPCam: 176x144 @ 1 FPS)
# ---------------------------------------------------------------------------

FRAME_H, FRAME_W = 144, 176
FRAME_BYTES = FRAME_H * FRAME_W          # 8-bit pixels
WINDOW_PIXELS = 400                      # 20x20 window to the NN
NN_MACS = 400 * 8 + 8                    # 400-8-1 topology


@dataclasses.dataclass(frozen=True)
class FAWorkloadStats:
    """Funnel statistics measured on the (synthetic) security workload.

    Paper §III-D: 62 frames -> 12 pass motion -> 40 windows to the NN
    (≈3.33 windows per motion frame), ~7.9k scan positions per frame at
    fine parameters.
    """

    n_frames: int = 62
    motion_frames: int = 12
    windows_to_nn: int = 40
    scan_windows_per_frame: float = 7900.0
    vj_stage_evals_per_frame: float = 11000.0   # masked-cascade measurement hook

    @property
    def motion_sel(self) -> float:
        return self.motion_frames / self.n_frames

    @property
    def windows_per_motion_frame(self) -> float:
        return self.windows_to_nn / self.motion_frames

    @property
    def nn_windows_per_second(self) -> float:     # at 1 FPS source rate
        return self.windows_to_nn / self.n_frames


def fa_pipeline(stats: FAWorkloadStats, with_cpu_nn: bool = False) -> Pipeline:
    """Block pipeline of Fig. 2.  Work is per *source frame* (1 FPS); the
    selectivity chain scales downstream blocks exactly like the paper's
    duty-cycling argument."""
    wpf = stats.windows_per_motion_frame
    blocks = (
        Block("sensor", flops=0.0, bytes_in=0.0, bytes_out=FRAME_BYTES,
              kind=BlockKind.SOURCE),
        Block("motion", flops=3 * FRAME_BYTES, bytes_in=FRAME_BYTES,
              bytes_out=FRAME_BYTES, kind=BlockKind.OPTIONAL,
              selectivity=stats.motion_sel),
        # VJ on a motion-passed frame: integral image + cascade stages;
        # output = detected windows (de-integral-ized 20x20 crops).
        # selectivity = fraction of motion frames with >=1 detection (every
        # motion frame in the measured workload); bytes_out = windows per
        # surviving frame — the 40-windows/62-s payload the paper charges.
        Block("vj", flops=2 * FRAME_BYTES + 9 * stats.vj_stage_evals_per_frame,
              bytes_in=FRAME_BYTES,
              bytes_out=wpf * WINDOW_PIXELS, kind=BlockKind.OPTIONAL,
              selectivity=1.0),
        Block("nn", flops=2 * NN_MACS * wpf, bytes_in=wpf * WINDOW_PIXELS,
              bytes_out=1.0 / 8.0,       # 1-bit decision
              requires=("vj",)),         # NN input = FD's 20x20 windows
    )
    return Pipeline("face_auth", blocks)


def fa_profiles(nn_on_cpu: bool = False) -> dict:
    nn = MSP430 if nn_on_cpu else NN_ASIC
    return {"sensor": IMAGE_SENSOR, "motion": MOTION_ASIC,
            "vj": VJ_ASIC, "nn": nn}


# -- calibration --------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FACalibration:
    rf_joules_per_byte: float
    nn_effective_w: float         # leakage+duty effective power of the NN block
    base_compute_w: float         # sensor+motion+vj through-VJ compute power

    def rf_link(self) -> HardwareProfile:
        return HardwareProfile(name="rf_link",
                               joules_per_byte=self.rf_joules_per_byte)

    def nn_profile(self) -> HardwareProfile:
        # the calibrated value IS the block's average power (leakage-dominated
        # + duty-scaled dynamic); both rails set so duty drops out
        return dataclasses.replace(
            NN_ASIC, p_active_w=self.nn_effective_w,
            p_leak_w=self.nn_effective_w)


def calibrate_fa(stats: FAWorkloadStats,
                 sensor_w: float = IMAGE_SENSOR.p_active_w,
                 motion_w: float = MOTION_ASIC.p_active_w,
                 vj_eff_w: float = VJ_ASIC.p_leak_w,
                 plus_pct: float = 0.28,
                 crossover: float = 2.68) -> FACalibration:
    """Solve the two paper constraints for (e_c, P_nn_eff).

    Let C = compute power through VJ, B = bytes/s after VJ.  Then
      (1)  C + P_nn + e_c*B_nn = (1 + plus_pct) * (C + e_c*B)
      (2)  P_nn = crossover * e_c * (B - B_nn)              [tie at k*e_c]
    With B_nn ~ 0:  e_c*B = C * plus_pct / (crossover - 1 - plus_pct)
                    P_nn  = crossover * e_c * B.
    """
    C = sensor_w + motion_w + vj_eff_w
    B = stats.nn_windows_per_second * WINDOW_PIXELS      # bytes/s after VJ
    # Post-NN uplink traffic: one 1-bit authentication decision per source
    # frame at the 1 FPS source rate = 1/8 byte/s.  This tiny residual is
    # what keeps the crossover equation (2) exactly solvable rather than
    # assuming B_nn = 0; it feeds the e_c denominator below.
    B_nn = 1.0 / 8.0
    ec_B = C * plus_pct / (crossover - 1.0 - plus_pct)
    e_c = ec_B / (B - B_nn * crossover / (crossover - 1.0 - plus_pct))
    p_nn = crossover * e_c * (B - B_nn)
    return FACalibration(rf_joules_per_byte=e_c, nn_effective_w=p_nn,
                         base_compute_w=C)


# ---------------------------------------------------------------------------
# §IV VR pipeline (16x 4K cameras @ 30 FPS target)
# ---------------------------------------------------------------------------

VR_CAMS = 16
VR_W, VR_H = 3840, 2160                   # 4K per camera
VR_FPS_TARGET = 30.0


@dataclasses.dataclass(frozen=True)
class VRWorkloadStats:
    """Per-frame work for the 2-camera pipeline slice of Fig. 13 (x8 pairs
    gives the 16-camera rig; the paper plots 2 of 16 cameras)."""

    grid_sigma: int = 16                  # pixels per grid vertex
    disp_range: int = 32
    refine_iters: int = 8

    @property
    def pixels(self) -> float:
        return 2 * VR_W * VR_H            # a camera pair

    def grid_vertices(self) -> float:
        gy = VR_H / self.grid_sigma
        gx = VR_W / self.grid_sigma
        return gy * gx * 17.0             # 16 intensity bins + 1

    def rough_flops(self) -> float:       # SAD block matching
        return self.pixels / 2 * self.disp_range * 8

    def refine_flops(self) -> float:      # iterated 3-axis [1,2,1] blurs, v+w
        return self.grid_vertices() * self.refine_iters * 3 * 4 * 2


def vr_pipeline(stats: VRWorkloadStats) -> Pipeline:
    """B1 capture -> B2 ISP/rectify -> B3 grid construction (data expands)
    -> B4 depth refinement (dominant) -> B5 stitch/compose.  Bytes from
    Fig. 13's shape: biggest intermediate into the depth block; small depth
    maps after."""
    px = stats.pixels
    raw = px * 1.0                         # 8-bit Bayer off the sensor
    rgb = px * 3.0
    grid = stats.grid_vertices() * 8.0     # f32 (value, weight) per vertex
    depth = px / 2 * 2.0                   # 16-bit depth map per pair
    # stitch output = encoded stereo panorama slice (the paper's only
    # uploadable intermediate; video-rate panoramas ship compressed)
    pano = 2 * 8192 * 4096 * 3.0 / 8 / 50.0
    blocks = (
        Block("capture", flops=0.0, bytes_in=0.0, bytes_out=raw,
              kind=BlockKind.SOURCE),
        Block("isp", flops=20 * px, bytes_in=raw, bytes_out=rgb),
        # grid construction = splatting (cheap, bandwidth-ish); the rough
        # disparity estimate belongs to the stereo solve itself and moves
        # with it onto the accelerator
        Block("grid", flops=2 * px, bytes_in=rgb, bytes_out=rgb + grid),
        Block("depth",
              flops=stats.rough_flops() / 16 + stats.refine_flops() * 420,
              bytes_in=rgb + grid, bytes_out=depth),
        Block("stitch", flops=2 * px, bytes_in=depth + rgb, bytes_out=pano),
    )
    return Pipeline("vr_video", blocks)


def vr_profiles(depth_device: HardwareProfile) -> dict:
    """depth_device is the knob (CPU/GPU/FPGA); Fig. 14's passing "FPGA"
    configuration uses the Table II production target (VIRTEX_FPGA)."""
    return {"capture": IMAGE_SENSOR, "isp": ZYNQ_FPGA, "grid": ARM_A9,
            "depth": depth_device, "stitch": ARM_A9}


# ---------------------------------------------------------------------------
# §IV rig-resident fused executor (DESIGN.md §8)
# ---------------------------------------------------------------------------


class VRRigExecutor:
    """Batched §IV hot path: vmapped BSSA depth over the rig's camera pairs
    + loop-free stereo panorama composition.

    Two jit regions per rig frame: ``depth_maps`` (rough -> splat ->
    refine_grid -> slice, vmapped over pairs; refinement dispatches to the
    Pallas bilateral-blur kernel on TPU) and ``panorama`` (batched
    cylindrical warp + one scatter-add feather blend).  With
    ``rig_parallel`` and enough local devices, pairs are pmapped one per
    device — the software analogue of the paper's rig of 8 parallel
    per-pair FPGAs.  The seed per-pair Python loop over ``bssa_depth_ref``
    is the oracle the benchmark (benchmarks/vr_depth_hotpath.py) and the
    parity tests measure against.
    """

    def __init__(self, spec, max_disp: int = 32, n_iters: int = 8,
                 ipd_px: float = 6.0, use_pallas: bool | None = None,
                 interpret: bool = False, rig_parallel: bool | None = None,
                 telemetry=None):
        import functools

        import jax

        from repro.camera.bssa import bssa_depth
        from repro.camera.stitch import stereo_panorama
        from repro.obs.telemetry import telemetry_on

        self.telemetry = telemetry
        self._tel_on = telemetry_on(telemetry)
        self.spec = spec
        self.max_disp = max_disp
        self.n_iters = n_iters
        self.ipd_px = ipd_px
        if rig_parallel is None:
            rig_parallel = jax.local_device_count() > 1
        self.rig_parallel = rig_parallel
        pair_depth = functools.partial(
            bssa_depth, spec=spec, max_disp=max_disp, n_iters=n_iters,
            use_pallas=use_pallas, interpret=interpret)
        # traceable handles for callers composing the rig pipeline into
        # their own jit regions (camera/offload's split executors)
        self.pair_depth = pair_depth
        self.pano_fn = functools.partial(stereo_panorama, ipd_px=ipd_px)
        if self._tel_on:
            # §15 in-graph counters: same dispatch, one extra int32 scalar
            # per region (TELEMETRY_AUX vr_rig.*); the disabled branch
            # below traces the exact pre-obs closures
            from repro.obs.counters import graph_counters

            def pair_depth_tel(left, right):
                return pair_depth(left, right), graph_counters(pairs=1)

            def pano_tel(lefts, rights, depths):
                pano = self.pano_fn(lefts, rights, depths)
                return pano, graph_counters(views=2 * lefts.shape[0])

            self._depth = jax.jit(jax.vmap(pair_depth_tel))
            self._depth_pmap = (jax.pmap(pair_depth_tel)
                                if rig_parallel else None)
            self._pano = jax.jit(pano_tel)
        else:
            self._depth = jax.jit(jax.vmap(pair_depth))
            self._depth_pmap = jax.pmap(pair_depth) if rig_parallel else None
            self._pano = jax.jit(self.pano_fn)

    def depth_maps(self, lefts, rights):
        """(n_pairs, h, w) x2 -> (n_pairs, h, w) refined depth."""
        import jax
        import jax.numpy as jnp

        if (self._depth_pmap is not None
                and lefts.shape[0] <= jax.local_device_count()):
            out = self._depth_pmap(lefts, rights)
        else:
            out = self._depth(lefts, rights)
        if self._tel_on:
            depths, aux = out
            self.telemetry.counters.add("vr.pairs",
                                        jnp.sum(aux["tel_pairs"]))
            return depths
        return out

    def panorama(self, lefts, rights, depths):
        """(left_pano, right_pano) from per-pair views + depth maps."""
        import jax.numpy as jnp

        if self._tel_on:
            pano, aux = self._pano(lefts, rights, depths)
            self.telemetry.counters.add("vr.views",
                                        jnp.sum(aux["tel_views"]))
            return pano
        return self._pano(lefts, rights, depths)

    def __call__(self, lefts, rights):
        """Full rig frame: returns (left_pano, right_pano, depths)."""
        depths = self.depth_maps(lefts, rights)
        left_pano, right_pano = self.panorama(lefts, rights, depths)
        return left_pano, right_pano, depths


# ---------------------------------------------------------------------------
# §III frame-to-auth streaming executor (DESIGN.md §9)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FAExecResult:
    """One stream's funnel output, every array in source-frame order.

    Leading axis B = frames in the batch (add a leading S axis for
    :meth:`FaceAuthExecutor.run_streams`).  ``window_id`` indexes the
    detector's ``grid.positions``; slots beyond a frame's detections carry
    ``window_id == -1`` / ``window_valid == False`` / ``scores == 0``.
    """

    motion: object            # (B,) bool — passed motion detection
    n_windows: object         # (B,) int32 exact detection count (pre-capacity)
    n_auth: object            # (B,) int32 authenticated windows
    scores: object            # (B, W) f32 NN scores
    window_id: object         # (B, W) int32 grid position id, -1 = padding
    window_valid: object      # (B, W) bool
    auth: object              # (B, W) bool score > threshold
    windows_dropped: object   # (B,) int32 detections beyond window capacity
    motion_dropped: object    # () int32 motion frames beyond frame capacity
    cascade_dropped: object   # (B,) int32 detector-internal capacity drops

    def total_dropped(self) -> int:
        """Sum of every drop counter — 0 means the funnel was lossless."""
        import numpy as np
        return int(np.asarray(self.motion_dropped).sum()
                   + np.asarray(self.windows_dropped).sum()
                   + np.asarray(self.cascade_dropped).sum())


@dataclasses.dataclass(frozen=True)
class FunnelStages:
    """Traceable stage closures of one configured §III funnel.

    Rebuilt by :meth:`FaceAuthExecutor._rebuild`; the fused funnel and the
    offload runtime's split executors (``repro.camera.offload``) compose
    these same functions, so a cut can never drift from the on-node math.
    ``split_consts`` partitions the executor's jit-argument tuple into the
    (detector, position-table, NN) constant groups the stages consume.
    """

    motion: object            # frames -> (mframes, fidx, fvalid, motion, motion_dropped)
    detect: object            # (mframes, fvalid, det_c) -> (dmask, n_win_m, casc_drop_m)
    gather: object            # (mframes, dmask, n_win_m, pos_c) -> (patches, wsel, wvalid, win_dropped_m)
    nn: object                # (patches, wvalid, nn_c) -> (s, auth, n_auth_m)
    scatter: object           # source-frame-order result dict
    split_consts: object      # consts tuple -> (det_c, pos_c, nn_c)
    window_capacity: int


class FaceAuthExecutor:
    """Fused §III hot path: the whole motion -> Viola-Jones -> 400-8-1 NN
    funnel as ONE jit region per frame batch — the software shape of the
    paper's sensor-resident ASIC chain, with no host round-trips between
    stages.

    Stages inside the single dispatch (DESIGN.md §9):

    1. **Motion gating** — frame-difference scores in-graph; motion-passing
       frames are *compacted* to a statically-bounded prefix
       (``frame_capacity``), the §2 capacity trick applied at frame
       granularity, so downstream work scales with the motion rate while
       shapes stay static.
    2. **Fused detection** — ``FusedDetector``'s traceable core (one frame
       integral image, gathered Haar corner taps, compacting cascade).
    3. **Capacity-padded window gather** — per frame, up to
       ``window_capacity`` detected windows are gathered and
       nearest-resampled to 20x20 *on device* (integer-exact replica of
       ``viola_jones.extract_windows``); detections beyond capacity are
       dropped and counted, like MoE token dropping.
    4. **Int8 NN tail** — both layers through the quant_matmul kernel with
       static calibrated scales and the LUT sigmoid in-kernel
       (``kernels.quant_matmul.ops.nn_forward_quantized``).

    Multi-stream scaling: ``run_streams`` vmaps the funnel over N
    independent camera feeds on one device and pmaps one stream per device
    when available — the WISPCam-fleet analogue of ``VRRigExecutor``'s rig
    parallelism.  The per-motion-frame host loop
    (``examples/camera_face_auth.py``'s cross-check, with
    ``extract_windows`` + ``forward_quantized``) is the golden oracle for
    funnel-count and score parity.
    """

    def __init__(self, cascade, nn, h: int, w: int, *, lut=None,
                 lut_meta=None, scale_factor: float = 1.25,
                 step: float = 0.025, adaptive: bool = True,
                 strictness: float = 0.0, capacities=None,
                 motion_threshold: float = 0.004, motion_factor: int = 8,
                 frame_capacity: int | None = None,
                 window_capacity: int = 64, bits: int = 8,
                 auth_threshold: float = 0.5, use_pallas: bool | None = None,
                 interpret: bool = False, stream_parallel: bool | None = None,
                 telemetry=None):
        import jax

        from repro.camera.face_nn import make_sigmoid_lut
        from repro.camera.viola_jones import FusedDetector
        from repro.kernels.quant_matmul.ops import quantize_nn
        from repro.obs.telemetry import telemetry_on

        # §15 telemetry: when enabled, the funnel emits static-shape
        # ``tel_`` int32 aux scalars from the SAME dispatch (checked at
        # _rebuild time — disabled executors trace the pre-obs jaxpr)
        self.telemetry = telemetry
        self._tel_on = telemetry_on(telemetry)

        if lut is None:
            lut, lut_meta = make_sigmoid_lut()
        elif lut_meta is None:
            raise ValueError("pass lut_meta alongside an explicit lut")
        self.lut = lut
        self.lut_meta = lut_meta
        self.det = FusedDetector(
            cascade, h, w, scale_factor=scale_factor, step=step,
            adaptive=adaptive, strictness=strictness, capacities=capacities,
            use_pallas=use_pallas, interpret=interpret)
        pos = np.asarray(self.det.grid.positions, np.int32)   # (n, 3)
        self._pos_y, self._pos_x, self._pos_win = pos[:, 0], pos[:, 1], pos[:, 2]
        self.nn = nn
        self.qnn = quantize_nn(nn, bits=bits)
        self.motion_threshold = float(motion_threshold)
        self.motion_factor = int(motion_factor)
        self.frame_capacity = frame_capacity
        self.window_capacity = int(window_capacity)
        self.auth_threshold = float(auth_threshold)
        self.use_pallas = use_pallas
        self.interpret = bool(interpret)
        if stream_parallel is None:
            stream_parallel = jax.local_device_count() > 1
        self.stream_parallel = bool(stream_parallel)
        self._rebuild()

    # -- jitted funnel -------------------------------------------------------

    def _rebuild(self):
        import jax
        import jax.numpy as jnp

        from repro.camera.motion import motion_score
        from repro.camera.viola_jones import BASE
        from repro.kernels.quant_matmul.ops import nn_forward_quantized

        det_fn = self.det.traceable_apply
        det_consts = self.det.apply_consts
        n_det = len(det_consts)
        pos_consts = tuple(jnp.asarray(a) for a in (
            self._pos_y, self._pos_x, self._pos_win))
        nn_consts = (self.qnn.w1_q, self.qnn.b1, self.qnn.w2_q, self.qnn.b2,
                     jnp.asarray(self.lut))
        consts = det_consts + pos_consts + nn_consts
        qnn, meta = self.qnn, self.lut_meta
        W = int(self.window_capacity)
        fcap = self.frame_capacity
        thr, factor = self.motion_threshold, self.motion_factor
        auth_thr = self.auth_threshold
        use_pallas, interpret = self.use_pallas, self.interpret

        # The funnel is factored into traceable stage closures so the
        # offload runtime (camera/offload) can split it at any legal cut
        # point into a node-side and a cloud-side jit region while the
        # fused funnel below composes the very same functions — one
        # implementation, two placements (DESIGN.md §10).

        def stage_motion(frames):
            """-- 1. motion gating + frame compaction to capacity M ------"""
            frames = frames.astype(jnp.float32)
            B = frames.shape[0]
            M = B if fcap is None else max(1, min(int(fcap), B))
            msc = motion_score(frames[:-1], frames[1:], factor)
            motion = jnp.concatenate(
                [jnp.zeros((1,), bool), msc > thr])
            order = jnp.argsort(jnp.where(motion, 0, 1), stable=True)
            fidx = order[:M]
            fvalid = jnp.take(motion, fidx)
            motion_dropped = jnp.maximum(
                jnp.sum(motion).astype(jnp.int32) - M, 0)
            mframes = jnp.take(frames, fidx, axis=0)
            return mframes, fidx, fvalid, motion, motion_dropped

        def stage_detect(mframes, fvalid, det_c):
            """-- 2. fused VJ front-end (masked by the motion gate) ------

            The detector's compacting cascade has its own capacities; its
            internal drops on motion-valid frames must surface too (the §9
            contract: dropped and counted, never silent)."""
            dmask, _surv, ddrop = det_fn(mframes, *det_c)
            dmask = dmask & fvalid[:, None]
            casc_drop_m = jnp.where(fvalid,
                                    jnp.sum(ddrop, axis=1), 0).astype(jnp.int32)
            n_win_m = jnp.sum(dmask, axis=1).astype(jnp.int32)
            return dmask, n_win_m, casc_drop_m

        def stage_gather(mframes, dmask, n_win_m, pos_c):
            """-- 3. capacity-padded window gather + 20x20 resample ------

            O(n) stable compaction (a full argsort over 25k window slots
            per frame would dominate the funnel): rank survivors by prefix
            count, scatter their indices into W slots, dump overflow +
            dead windows into a discard slot."""
            pos_y, pos_x, pos_win = pos_c
            M = mframes.shape[0]
            col = jnp.arange(dmask.shape[1], dtype=jnp.int32)
            rank = jnp.cumsum(dmask.astype(jnp.int32), axis=1) - 1
            slot = jnp.where(dmask & (rank < W), rank, W)
            wsel = jnp.zeros((M, W + 1), jnp.int32).at[
                jnp.arange(M)[:, None], slot].set(col[None, :])[:, :W]
            wvalid = (jnp.arange(W, dtype=jnp.int32)[None, :]
                      < jnp.minimum(n_win_m, W)[:, None])
            win_dropped_m = jnp.maximum(n_win_m - W, 0)
            wy = jnp.take(pos_y, wsel)
            wx = jnp.take(pos_x, wsel)
            ww = jnp.take(pos_win, wsel)                       # (M, W)
            t = jnp.arange(BASE, dtype=jnp.int32)
            # integer-exact replica of extract_windows' nearest resample:
            # (arange(20) * win // 20).clip(0, win - 1)
            off = jnp.minimum(t[None, None, :] * ww[:, :, None] // BASE,
                              ww[:, :, None] - 1)              # (M, W, 20)
            # two-sided clamp before the PROMISE_IN_BOUNDS patch gather:
            # every (pos_y, pos_x, pos_win) row fits the frame by
            # construction, so this is a no-op for real tables — it makes
            # the in-bounds promise *static* instead of data-dependent
            h_m, w_m = mframes.shape[-2:]
            rows = jnp.clip(wy[:, :, None] + off, 0, h_m - 1)
            cols = jnp.clip(wx[:, :, None] + off, 0, w_m - 1)
            patches = jax.vmap(
                lambda fr, r, co: fr[r[:, :, None], co[:, None, :]])(
                    mframes, rows, cols)                       # (M, W, 20, 20)
            return patches, wsel, wvalid, win_dropped_m

        def stage_nn(patches, wvalid, nn_c):
            """-- 4. int8 NN tail (both layers on the quant kernel) ------"""
            w1_q, b1, w2_q, b2, lut = nn_c
            M, Wc = patches.shape[:2]
            x = patches.reshape(M * Wc, BASE * BASE)
            q = dataclasses.replace(qnn, w1_q=w1_q, b1=b1, w2_q=w2_q, b2=b2)
            s = nn_forward_quantized(q, x, lut, meta,
                                     use_pallas=use_pallas,
                                     interpret=interpret).reshape(M, Wc)
            s = jnp.where(wvalid, s, 0.0)
            auth = wvalid & (s > auth_thr)
            n_auth_m = jnp.sum(auth, axis=1).astype(jnp.int32)
            return s, auth, n_auth_m

        def stage_scatter(B, fidx, motion, motion_dropped, n_win_m,
                          casc_drop_m, wsel, wvalid, win_dropped_m,
                          s, auth, n_auth_m):
            """-- scatter back to source-frame order ---------------------"""
            return dict(
                motion=motion,
                n_windows=jnp.zeros((B,), jnp.int32).at[fidx].set(n_win_m),
                n_auth=jnp.zeros((B,), jnp.int32).at[fidx].set(n_auth_m),
                scores=jnp.zeros((B, W), s.dtype).at[fidx].set(s),
                window_id=jnp.full((B, W), -1, jnp.int32).at[fidx].set(
                    jnp.where(wvalid, wsel.astype(jnp.int32), -1)),
                window_valid=jnp.zeros((B, W), bool).at[fidx].set(wvalid),
                auth=jnp.zeros((B, W), bool).at[fidx].set(auth),
                windows_dropped=jnp.zeros((B,), jnp.int32).at[fidx].set(
                    win_dropped_m),
                motion_dropped=motion_dropped,
                cascade_dropped=jnp.zeros((B,), jnp.int32).at[fidx].set(
                    casc_drop_m),
            )

        def split_consts(c):
            return c[:n_det], c[n_det:n_det + 3], c[n_det + 3:]

        def funnel(frames, *c):
            det_c, pos_c, nn_c = split_consts(c)
            B = frames.shape[0]
            mframes, fidx, fvalid, motion, motion_dropped = stage_motion(
                frames)
            dmask, n_win_m, casc_drop_m = stage_detect(mframes, fvalid, det_c)
            patches, wsel, wvalid, win_dropped_m = stage_gather(
                mframes, dmask, n_win_m, pos_c)
            s, auth, n_auth_m = stage_nn(patches, wvalid, nn_c)
            return stage_scatter(B, fidx, motion, motion_dropped, n_win_m,
                                 casc_drop_m, wsel, wvalid, win_dropped_m,
                                 s, auth, n_auth_m)

        if self._tel_on:
            # §15 in-graph counters: tel_ int32 scalars hoisted out of the
            # same dispatch (TELEMETRY_AUX["face_auth.funnel"]).  Gated at
            # rebuild time, so a disabled executor traces the exact jaxpr
            # above and returns bit-identical outputs.
            from repro.obs.counters import graph_counters

            fused = funnel

            def funnel(frames, *c):
                out = fused(frames, *c)
                out.update(graph_counters(
                    windows=jnp.sum(out["n_windows"]),
                    auth=jnp.sum(out["n_auth"]),
                    motion_dropped=out["motion_dropped"],
                    cascade_dropped=jnp.sum(out["cascade_dropped"])))
                return out

        self.stages = FunnelStages(
            motion=stage_motion, detect=stage_detect, gather=stage_gather,
            nn=stage_nn, scatter=stage_scatter, split_consts=split_consts,
            window_capacity=W)
        self._consts = consts
        self._funnel = funnel
        self._single = jax.jit(funnel)
        self._multi = jax.jit(jax.vmap(
            funnel, in_axes=(0,) + (None,) * len(consts)))
        self._pmapped = (jax.pmap(funnel,
                                  in_axes=(0,) + (None,) * len(consts))
                         if self.stream_parallel else None)
        self._batch_steps = {}   # (n_streams, chunk, pmap) -> step closure

    # -- calibration ---------------------------------------------------------

    def calibrate(self, frames, margin: float = 2.0, quantum: int = 32,
                  frame_margin: float = 1.25):
        """Measure the workload's funnel on calibration frames and set every
        capacity knob from it (the §2 measure-then-set procedure): cascade
        compaction capacities (via ``FusedDetector.calibrate``), the
        per-batch motion-frame capacity, and the per-frame window capacity.
        Returns (frame_capacity, window_capacity, cascade_capacities).

        ``frame_margin`` is deliberately tighter than the window ``margin``:
        every spare frame slot re-pays the whole detection front-end,
        whereas a spare window slot only pays 400 int8 MACs — and
        motion-frame overflow degrades gracefully (dropped frames are
        counted in ``motion_dropped``, never silently wrong).
        """
        import math

        import jax.numpy as jnp

        from repro.camera.motion import motion_mask

        frames = np.asarray(frames, np.float32)
        mask, _ = motion_mask(jnp.asarray(frames), self.motion_threshold,
                              self.motion_factor)
        midx = np.where(np.asarray(mask))[0]
        max_w = 1
        if len(midx):
            self.det.calibrate(frames[midx[:4]])
            dets, _stats = self.det.detect(frames[midx])
            max_w = max((len(d) for d in dets), default=1)
        fcap = int(math.ceil(len(midx) * frame_margin))
        self.frame_capacity = int(min(len(frames), max(4, (fcap + 3) // 4 * 4)))
        wcap = (int(math.ceil(max_w * margin)) // quantum + 1) * quantum
        self.window_capacity = int(min(self.det.n_windows,
                                       max(quantum, wcap)))
        self._rebuild()
        return self.frame_capacity, self.window_capacity, list(self.det.capacities)

    # -- execution -----------------------------------------------------------

    def __call__(self, frames) -> FAExecResult:
        """One stream: (B, h, w) frames -> :class:`FAExecResult`."""
        import jax.numpy as jnp

        out = self._single(jnp.asarray(frames), *self._consts)
        if self._tel_on:
            # pop the tel_ aux scalars into the panel device-lazily — no
            # host sync here; totals() materializes at export time
            out = self.telemetry.counters.consume(dict(out), prefix="fa.")
        return FAExecResult(**out)

    def batch_step(self, n_streams: int, chunk: int,
                   stream_parallel: bool | None = None, devices=None):
        """Re-entrant capacity-padded micro-batch step for the serving
        runtime (DESIGN.md §13).

        Returns ``step(frames, valid) -> dict`` where ``frames`` is
        ``(n_streams, chunk, h, w)`` and ``valid`` is ``(n_streams,)`` bool;
        the result dict has the :class:`FAExecResult` fields with a leading
        ``n_streams`` axis (``motion_dropped`` becomes ``(n_streams,)``).
        Invalid slots carry the canonical quiet result — ``motion`` False,
        ``window_id`` -1, everything else zero — exactly what the funnel
        emits for a motionless chunk, so padding a micro-batch can never be
        told apart from serving a quiet stream.

        One jit dispatch per call: the same ``FunnelStages`` funnel vmapped
        across the stream axis, with one pmap shard per device when
        ``stream_parallel`` and the device count divides ``n_streams``.
        ``devices`` restricts the pmap to an explicit device subset — the
        failover path (DESIGN.md §14): a serving runtime that loses a
        device re-requests the closure over the survivors, and falls back
        to the single-device vmap jit when they stop dividing the batch.
        Closures are cached per ``(n_streams, chunk, device-set)`` and
        invalidated by :meth:`calibrate`'s rebuild, so a scheduler can call
        the step every tick without retracing.
        """
        import jax
        import jax.numpy as jnp

        if stream_parallel is None:
            stream_parallel = self.stream_parallel
        if devices is not None:
            devices = tuple(devices)
            if not devices:
                raise ValueError("batch_step: devices must be non-empty "
                                 "when given — a group with zero devices "
                                 "cannot serve")
            ndev = len(devices)
        else:
            ndev = jax.local_device_count()
        use_pmap = bool(stream_parallel) and ndev > 1 and n_streams % ndev == 0
        if not use_pmap:
            # the vmap fallback never touches `devices`; normalizing the
            # key means failing over to it (survivors stop dividing the
            # batch) reuses the already-compiled single-device closure
            devices = None
        key = (int(n_streams), int(chunk), use_pmap,
               None if devices is None else tuple(d.id for d in devices))
        cached = self._batch_steps.get(key)
        if cached is not None:
            return cached

        funnel, consts = self._funnel, self._consts

        def step_core(frames, valid, *c):
            res = jax.vmap(funnel, in_axes=(0,) + (None,) * len(c))(
                frames, *c)
            def quiet(name, a):
                fill = (jnp.full_like(a, -1) if name == "window_id"
                        else jnp.zeros_like(a))
                keep = valid.reshape(valid.shape + (1,) * (a.ndim - 1))
                return jnp.where(keep, a, fill)
            return {k: quiet(k, v) for k, v in res.items()}

        if use_pmap:
            shard = jax.pmap(step_core,
                             in_axes=(0, 0) + (None,) * len(consts),
                             devices=None if devices is None
                             else list(devices))

            def step(frames, valid):
                self._check_step_args(frames, valid, n_streams, chunk)
                fr = frames.reshape((ndev, n_streams // ndev)
                                    + tuple(frames.shape[1:]))
                va = valid.reshape(ndev, n_streams // ndev)
                out = shard(fr, va, *consts)
                return {k: v.reshape((n_streams,) + tuple(v.shape[2:]))
                        for k, v in out.items()}
        else:
            jitted = jax.jit(step_core)

            def step(frames, valid):
                self._check_step_args(frames, valid, n_streams, chunk)
                return jitted(frames, valid, *consts)

        # the raw traceable core (consts as explicit args) — what the
        # static analyzer registers, the same way it traces self._funnel
        step._core = step_core
        step._consts = consts
        self._batch_steps[key] = step
        return step

    @staticmethod
    def _check_step_args(frames, valid, n_streams, chunk):
        if tuple(frames.shape[:2]) != (n_streams, chunk):
            raise ValueError(
                f"batch_step closure is shape-bound: expected frames "
                f"({n_streams}, {chunk}, h, w), got {tuple(frames.shape)} — "
                "request a new closure via batch_step() instead of reusing "
                "one across micro-batch geometries")
        if tuple(valid.shape) != (n_streams,):
            raise ValueError(
                f"valid must be ({n_streams},) bool, got "
                f"{tuple(valid.shape)}")

    def run_streams(self, frames) -> FAExecResult:
        """N independent feeds: (S, B, h, w) -> FAExecResult with leading S.

        One stream per local device via pmap when the fleet fits
        (``stream_parallel``); otherwise all streams vmapped on one device.
        """
        import jax
        import jax.numpy as jnp

        frames = jnp.asarray(frames)
        if (self._pmapped is not None
                and frames.shape[0] <= jax.local_device_count()):
            out = self._pmapped(frames, *self._consts)
        else:
            out = self._multi(frames, *self._consts)
        if self._tel_on:
            # vmapped/pmapped tel_ aux carry a leading stream axis — sum
            # device-side before the lazy accumulate (still no host sync)
            out = dict(out)
            for k in [k for k in out if k.startswith("tel_")]:
                self.telemetry.counters.add("fa." + k[4:],
                                            jnp.sum(out.pop(k)))
        return FAExecResult(**out)
