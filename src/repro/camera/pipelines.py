"""The paper's two pipelines as Pipeline objects + calibrated cost profiles.

This is where the faithful reproduction meets the cost model
(core/costmodel): block work descriptors come from the *measured* synthetic
workload (funnel statistics), device profiles from Table I, and the two
under-determined constants — RF joules/byte and the NN ASIC's standby
leakage — are **calibrated** so the paper's two stated headline relations
hold exactly:

  (1) adding the NN in-camera raises total power by +28% (Fig. 9), and
  (2) the offload-vs-in-camera decision flips at 2.68x comm energy.

Everything else (config ordering in Fig. 8, the 8 MP crossover direction,
filter funnel, 265x/442,146x accelerator gains, the VR Fig. 14 ladder)
must then *emerge* — benchmarks/fa_system.py and vr_system.py check that
they do.  See DESIGN.md §5 and EXPERIMENTS.md for the argument.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.costmodel import (
    ARM_A9,
    ETH_25G,
    ETH_400G,
    HardwareProfile,
    IMAGE_SENSOR,
    MOTION_ASIC,
    MSP430,
    NN_ASIC,
    QUADRO_GPU,
    RF_LINK,
    VIRTEX_FPGA,
    VJ_ASIC,
    ZYNQ_FPGA,
)
from repro.core.pipeline import Block, BlockKind, Pipeline

# ---------------------------------------------------------------------------
# §III face authentication pipeline (WISPCam: 176x144 @ 1 FPS)
# ---------------------------------------------------------------------------

FRAME_H, FRAME_W = 144, 176
FRAME_BYTES = FRAME_H * FRAME_W          # 8-bit pixels
WINDOW_PIXELS = 400                      # 20x20 window to the NN
NN_MACS = 400 * 8 + 8                    # 400-8-1 topology


@dataclasses.dataclass(frozen=True)
class FAWorkloadStats:
    """Funnel statistics measured on the (synthetic) security workload.

    Paper §III-D: 62 frames -> 12 pass motion -> 40 windows to the NN
    (≈3.33 windows per motion frame), ~7.9k scan positions per frame at
    fine parameters.
    """

    n_frames: int = 62
    motion_frames: int = 12
    windows_to_nn: int = 40
    scan_windows_per_frame: float = 7900.0
    vj_stage_evals_per_frame: float = 11000.0   # masked-cascade measurement hook

    @property
    def motion_sel(self) -> float:
        return self.motion_frames / self.n_frames

    @property
    def windows_per_motion_frame(self) -> float:
        return self.windows_to_nn / self.motion_frames

    @property
    def nn_windows_per_second(self) -> float:     # at 1 FPS source rate
        return self.windows_to_nn / self.n_frames


def fa_pipeline(stats: FAWorkloadStats, with_cpu_nn: bool = False) -> Pipeline:
    """Block pipeline of Fig. 2.  Work is per *source frame* (1 FPS); the
    selectivity chain scales downstream blocks exactly like the paper's
    duty-cycling argument."""
    wpf = stats.windows_per_motion_frame
    blocks = (
        Block("sensor", flops=0.0, bytes_in=0.0, bytes_out=FRAME_BYTES,
              kind=BlockKind.SOURCE),
        Block("motion", flops=3 * FRAME_BYTES, bytes_in=FRAME_BYTES,
              bytes_out=FRAME_BYTES, kind=BlockKind.OPTIONAL,
              selectivity=stats.motion_sel),
        # VJ on a motion-passed frame: integral image + cascade stages;
        # output = detected windows (de-integral-ized 20x20 crops).
        # selectivity = fraction of motion frames with >=1 detection (every
        # motion frame in the measured workload); bytes_out = windows per
        # surviving frame — the 40-windows/62-s payload the paper charges.
        Block("vj", flops=2 * FRAME_BYTES + 9 * stats.vj_stage_evals_per_frame,
              bytes_in=FRAME_BYTES,
              bytes_out=wpf * WINDOW_PIXELS, kind=BlockKind.OPTIONAL,
              selectivity=1.0),
        Block("nn", flops=2 * NN_MACS * wpf, bytes_in=wpf * WINDOW_PIXELS,
              bytes_out=1.0 / 8.0,       # 1-bit decision
              requires=("vj",)),         # NN input = FD's 20x20 windows
    )
    return Pipeline("face_auth", blocks)


def fa_profiles(nn_on_cpu: bool = False) -> dict:
    nn = MSP430 if nn_on_cpu else NN_ASIC
    return {"sensor": IMAGE_SENSOR, "motion": MOTION_ASIC,
            "vj": VJ_ASIC, "nn": nn}


# -- calibration --------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FACalibration:
    rf_joules_per_byte: float
    nn_effective_w: float         # leakage+duty effective power of the NN block
    base_compute_w: float         # sensor+motion+vj through-VJ compute power

    def rf_link(self) -> HardwareProfile:
        return HardwareProfile(name="rf_link",
                               joules_per_byte=self.rf_joules_per_byte)

    def nn_profile(self) -> HardwareProfile:
        # the calibrated value IS the block's average power (leakage-dominated
        # + duty-scaled dynamic); both rails set so duty drops out
        return dataclasses.replace(
            NN_ASIC, p_active_w=self.nn_effective_w,
            p_leak_w=self.nn_effective_w)


def calibrate_fa(stats: FAWorkloadStats,
                 sensor_w: float = IMAGE_SENSOR.p_active_w,
                 motion_w: float = MOTION_ASIC.p_active_w,
                 vj_eff_w: float = VJ_ASIC.p_leak_w,
                 plus_pct: float = 0.28,
                 crossover: float = 2.68) -> FACalibration:
    """Solve the two paper constraints for (e_c, P_nn_eff).

    Let C = compute power through VJ, B = bytes/s after VJ.  Then
      (1)  C + P_nn + e_c*B_nn = (1 + plus_pct) * (C + e_c*B)
      (2)  P_nn = crossover * e_c * (B - B_nn)              [tie at k*e_c]
    With B_nn ~ 0:  e_c*B = C * plus_pct / (crossover - 1 - plus_pct)
                    P_nn  = crossover * e_c * B.
    """
    C = sensor_w + motion_w + vj_eff_w
    B = stats.nn_windows_per_second * WINDOW_PIXELS      # bytes/s after VJ
    B_nn = 1.0 / 8.0 / stats.n_frames * stats.n_frames   # ~0.125 B/s
    ec_B = C * plus_pct / (crossover - 1.0 - plus_pct)
    e_c = ec_B / (B - B_nn * crossover / (crossover - 1.0 - plus_pct))
    p_nn = crossover * e_c * (B - B_nn)
    return FACalibration(rf_joules_per_byte=e_c, nn_effective_w=p_nn,
                         base_compute_w=C)


# ---------------------------------------------------------------------------
# §IV VR pipeline (16x 4K cameras @ 30 FPS target)
# ---------------------------------------------------------------------------

VR_CAMS = 16
VR_W, VR_H = 3840, 2160                   # 4K per camera
VR_FPS_TARGET = 30.0


@dataclasses.dataclass(frozen=True)
class VRWorkloadStats:
    """Per-frame work for the 2-camera pipeline slice of Fig. 13 (x8 pairs
    gives the 16-camera rig; the paper plots 2 of 16 cameras)."""

    grid_sigma: int = 16                  # pixels per grid vertex
    disp_range: int = 32
    refine_iters: int = 8

    @property
    def pixels(self) -> float:
        return 2 * VR_W * VR_H            # a camera pair

    def grid_vertices(self) -> float:
        gy = VR_H / self.grid_sigma
        gx = VR_W / self.grid_sigma
        return gy * gx * 17.0             # 16 intensity bins + 1

    def rough_flops(self) -> float:       # SAD block matching
        return self.pixels / 2 * self.disp_range * 8

    def refine_flops(self) -> float:      # iterated 3-axis [1,2,1] blurs, v+w
        return self.grid_vertices() * self.refine_iters * 3 * 4 * 2


def vr_pipeline(stats: VRWorkloadStats) -> Pipeline:
    """B1 capture -> B2 ISP/rectify -> B3 grid construction (data expands)
    -> B4 depth refinement (dominant) -> B5 stitch/compose.  Bytes from
    Fig. 13's shape: biggest intermediate into the depth block; small depth
    maps after."""
    px = stats.pixels
    raw = px * 1.0                         # 8-bit Bayer off the sensor
    rgb = px * 3.0
    grid = stats.grid_vertices() * 8.0     # f32 (value, weight) per vertex
    depth = px / 2 * 2.0                   # 16-bit depth map per pair
    # stitch output = encoded stereo panorama slice (the paper's only
    # uploadable intermediate; video-rate panoramas ship compressed)
    pano = 2 * 8192 * 4096 * 3.0 / 8 / 50.0
    blocks = (
        Block("capture", flops=0.0, bytes_in=0.0, bytes_out=raw,
              kind=BlockKind.SOURCE),
        Block("isp", flops=20 * px, bytes_in=raw, bytes_out=rgb),
        # grid construction = splatting (cheap, bandwidth-ish); the rough
        # disparity estimate belongs to the stereo solve itself and moves
        # with it onto the accelerator
        Block("grid", flops=2 * px, bytes_in=rgb, bytes_out=rgb + grid),
        Block("depth",
              flops=stats.rough_flops() / 16 + stats.refine_flops() * 420,
              bytes_in=rgb + grid, bytes_out=depth),
        Block("stitch", flops=2 * px, bytes_in=depth + rgb, bytes_out=pano),
    )
    return Pipeline("vr_video", blocks)


def vr_profiles(depth_device: HardwareProfile) -> dict:
    """depth_device is the knob (CPU/GPU/FPGA); Fig. 14's passing "FPGA"
    configuration uses the Table II production target (VIRTEX_FPGA)."""
    return {"capture": IMAGE_SENSOR, "isp": ZYNQ_FPGA, "grid": ARM_A9,
            "depth": depth_device, "stitch": ARM_A9}


# ---------------------------------------------------------------------------
# §IV rig-resident fused executor (DESIGN.md §8)
# ---------------------------------------------------------------------------


class VRRigExecutor:
    """Batched §IV hot path: vmapped BSSA depth over the rig's camera pairs
    + loop-free stereo panorama composition.

    Two jit regions per rig frame: ``depth_maps`` (rough -> splat ->
    refine_grid -> slice, vmapped over pairs; refinement dispatches to the
    Pallas bilateral-blur kernel on TPU) and ``panorama`` (batched
    cylindrical warp + one scatter-add feather blend).  With
    ``rig_parallel`` and enough local devices, pairs are pmapped one per
    device — the software analogue of the paper's rig of 8 parallel
    per-pair FPGAs.  The seed per-pair Python loop over ``bssa_depth_ref``
    is the oracle the benchmark (benchmarks/vr_depth_hotpath.py) and the
    parity tests measure against.
    """

    def __init__(self, spec, max_disp: int = 32, n_iters: int = 8,
                 ipd_px: float = 6.0, use_pallas: bool | None = None,
                 interpret: bool = False, rig_parallel: bool | None = None):
        import functools

        import jax

        from repro.camera.bssa import bssa_depth
        from repro.camera.stitch import stereo_panorama

        self.spec = spec
        self.max_disp = max_disp
        self.n_iters = n_iters
        self.ipd_px = ipd_px
        if rig_parallel is None:
            rig_parallel = jax.local_device_count() > 1
        self.rig_parallel = rig_parallel
        pair_depth = functools.partial(
            bssa_depth, spec=spec, max_disp=max_disp, n_iters=n_iters,
            use_pallas=use_pallas, interpret=interpret)
        self._depth = jax.jit(jax.vmap(pair_depth))
        self._depth_pmap = jax.pmap(pair_depth) if rig_parallel else None
        self._pano = jax.jit(functools.partial(stereo_panorama,
                                               ipd_px=ipd_px))

    def depth_maps(self, lefts, rights):
        """(n_pairs, h, w) x2 -> (n_pairs, h, w) refined depth."""
        import jax

        if (self._depth_pmap is not None
                and lefts.shape[0] <= jax.local_device_count()):
            return self._depth_pmap(lefts, rights)
        return self._depth(lefts, rights)

    def panorama(self, lefts, rights, depths):
        """(left_pano, right_pano) from per-pair views + depth maps."""
        return self._pano(lefts, rights, depths)

    def __call__(self, lefts, rights):
        """Full rig frame: returns (left_pano, right_pano, depths)."""
        depths = self.depth_maps(lefts, rights)
        left_pano, right_pano = self.panorama(lefts, rights, depths)
        return left_pano, right_pano, depths
