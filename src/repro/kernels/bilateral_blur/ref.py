"""Oracle: the camera substrate's blur_121 applied to (value, weight)."""

from repro.camera.bssa import blur_121


def blur_ref(val, wt):
    return blur_121(val), blur_121(wt)
