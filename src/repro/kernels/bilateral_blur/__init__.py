"""Analysis registration hook (repro.analysis pass 3: kernel legality)."""

import math

from repro.analysis.spec import (DivCheck, FnPair, KernelAnalysisSpec,
                                 KernelPlan, Tile, adapt_block)
from repro.kernels.bilateral_blur.kernel import bilateral_blur_pallas
from repro.kernels.bilateral_blur.ref import blur_ref


def _plan(case):
    # bilateral-grid dims, mirroring bssa.GridSpec.dims
    gy = math.ceil(case["h"] / case["sigma_spatial"]) + 1
    gx = math.ceil(case["w"] / case["sigma_spatial"]) + 1
    gr = math.ceil(256.0 / case.get("sigma_range", 16.0)) + 1
    bgy = adapt_block(gy, case.get("block_gy", 32))  # ops.py shrinks to divisor
    return KernelPlan(
        case=case["case"],
        grid=(gy // bgy,),
        tiles=[Tile("val_halo_block", (1, bgy + 2, gx, gr)),
               Tile("wt_halo_block", (1, bgy + 2, gx, gr)),
               Tile("val_out_block", (1, bgy, gx, gr)),
               Tile("wt_out_block", (1, bgy, gx, gr))],
        checks=[DivCheck("gy % block_gy", gy, bgy)],
    )


ANALYSIS = KernelAnalysisSpec(
    name="bilateral_blur",
    pairs=[FnPair(bilateral_blur_pallas, blur_ref,
                  frozenset({"block_gy", "interpret"}))],
    plan=_plan,
)
