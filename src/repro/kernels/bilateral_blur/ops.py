"""Backend dispatch + jit wrapper: n iterations of the bilateral-grid blur
(paper: the BSSA refinement loop the FPGA accelerates).

Mirrors the haar_frontend dispatch contract: the blur_121 oracle math *is*
the production path on CPU (XLA fuses the 3-axis stencil well; Pallas
interpret mode would add per-grid-step Python overhead), while on TPU the
Pallas kernel keeps both grids VMEM-resident across the halo-exchanged gy
blocks.  ``interpret=True`` forces the Pallas path in interpreter mode —
that is what the parity tests pin against ``camera.bssa.refine``.
"""

from __future__ import annotations

import functools

import jax

from repro.kernels.bilateral_blur.kernel import bilateral_blur_pallas


@functools.partial(jax.jit, static_argnames=("n_iters", "block_gy",
                                             "use_pallas", "interpret"))
def refine_grid(val, wt, *, n_iters: int = 8, block_gy: int = 32,
                use_pallas: bool | None = None, interpret: bool = False):
    """val/wt: (gy, gx, gr) f32 -> n_iters of the normalized-blur iteration.

    Returns the blurred (val, wt) pair — same contract as
    ``camera.bssa.refine``, which stays the golden oracle.
    """
    if use_pallas is None:
        use_pallas = interpret or jax.default_backend() == "tpu"

    if use_pallas:
        gy = val.shape[0]
        bgy = min(block_gy, gy)
        while gy % bgy:
            bgy -= 1

        def body(i, carry):
            v, w = carry
            return bilateral_blur_pallas(v, w, block_gy=bgy,
                                         interpret=interpret)
    else:
        from repro.camera.bssa import blur_121

        def body(i, carry):
            v, w = carry
            return blur_121(v), blur_121(w)

    return jax.lax.fori_loop(0, n_iters, body, (val, wt))
