"""jit'd wrapper: n iterations of the bilateral-grid blur (paper: the BSSA
refinement loop the FPGA accelerates)."""

from __future__ import annotations

import functools

import jax

from repro.kernels.bilateral_blur.kernel import bilateral_blur_pallas


@functools.partial(jax.jit, static_argnames=("n_iters", "block_gy", "interpret"))
def refine_grid(val, wt, *, n_iters: int = 8, block_gy: int = 32,
                interpret: bool = False):
    gy = val.shape[0]
    bgy = min(block_gy, gy)
    while gy % bgy:
        bgy -= 1

    def body(i, carry):
        v, w = carry
        return bilateral_blur_pallas(v, w, block_gy=bgy, interpret=interpret)

    return jax.lax.fori_loop(0, n_iters, body, (val, wt))
