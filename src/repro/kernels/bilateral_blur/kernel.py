"""Bilateral-grid [1,2,1] blur for TPU (paper §IV-B, hardware-adapted).

The paper maps "millions of blurs" over grid vertices onto FPGA DSP
compute units (18 DSPs each, 12 on the Zynq, 682 projected on a Virtex).
The TPU analogue: tile the (gy, gx, gr) grid into VMEM blocks along gy
(with a one-vertex halo handled by re-reading neighbor rows through the
index map) and run the separable 3-axis [1,2,1]/4 stencil on the VPU.
Value and weight grids are blurred in one kernel invocation (they always
travel together — the homogeneous-coordinates trick of bilateral
filtering).

Block shape: (block_gy + 2 halo, gx, gr) f32 x2 — e.g. (34, 240, 17) x 2
x 4 B = 1.1 MB, comfortably inside VMEM with MXU-free VPU work.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _blur_axis(g, axis):
    """[1,2,1]/4 with edge replication, in VMEM."""
    lo = jnp.concatenate([
        jax.lax.slice_in_dim(g, 0, 1, axis=axis),
        jax.lax.slice_in_dim(g, 0, g.shape[axis] - 1, axis=axis)], axis=axis)
    hi = jnp.concatenate([
        jax.lax.slice_in_dim(g, 1, g.shape[axis], axis=axis),
        jax.lax.slice_in_dim(g, g.shape[axis] - 1, g.shape[axis], axis=axis)],
        axis=axis)
    return 0.25 * lo + 0.5 * g + 0.25 * hi


def _blur_kernel(val_ref, wt_ref, val_out_ref, wt_out_ref, *,
                 block_gy: int, n_blocks: int):
    v = val_ref[0]                    # (block_gy + 2, gx, gr) with halo
    w = wt_ref[0]

    for axis in (0, 1, 2):
        v = _blur_axis(v, axis)
        w = _blur_axis(w, axis)

    # interior rows only (halo rows are neighbors' property).
    # Edge blocks: the halo row duplicates the edge row, which reproduces
    # the replicate-edge boundary of the oracle.
    val_out_ref[0] = v[1:block_gy + 1]
    wt_out_ref[0] = w[1:block_gy + 1]


def bilateral_blur_pallas(val, wt, *, block_gy: int = 32, interpret=False):
    """val/wt: (gy, gx, gr) f32 -> one [1,2,1]^3 blur step of both."""
    gy, gx, gr = val.shape
    block_gy = min(block_gy, gy)
    assert gy % block_gy == 0, (gy, block_gy)
    n_blocks = gy // block_gy

    # halo: materialize a padded copy (edge-replicated) so every block can
    # read (block_gy + 2) rows with a plain BlockSpec — halo via padding,
    # the standard Pallas stencil pattern when block index maps are affine.
    pad = lambda g: jnp.concatenate([g[:1], g, g[-1:]], axis=0)
    vpad, wpad = pad(val), pad(wt)

    # overlapping blocks: block i covers rows [i*block_gy, i*block_gy + block_gy + 2)
    # of the padded array.  Express via element index_map (block size 1 in
    # the gy dim would lose vectorization; instead replicate rows into a
    # gathered stack outside the kernel).
    idx = (jnp.arange(n_blocks)[:, None] * block_gy
           + jnp.arange(block_gy + 2)[None, :])          # (n_blocks, bgy+2)
    vstack = vpad[idx]                                   # (n_blocks, bgy+2, gx, gr)
    wstack = wpad[idx]

    kernel = functools.partial(_blur_kernel, block_gy=block_gy,
                               n_blocks=n_blocks)
    vout, wout = pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((1, block_gy + 2, gx, gr), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, block_gy + 2, gx, gr), lambda i: (i, 0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_gy, gx, gr), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, block_gy, gx, gr), lambda i: (i, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_blocks, block_gy, gx, gr), jnp.float32),
            jax.ShapeDtypeStruct((n_blocks, block_gy, gx, gr), jnp.float32),
        ],
        interpret=interpret,
    )(vstack, wstack)
    return vout.reshape(gy, gx, gr), wout.reshape(gy, gx, gr)
