"""Analysis registration hook (repro.analysis pass 3: kernel legality)."""

from repro.analysis.spec import (DivCheck, FnPair, KernelAnalysisSpec,
                                 KernelPlan, Tile, adapt_block)
from repro.kernels.integral_image.kernel import integral_image_pallas
from repro.kernels.integral_image.ref import integral_ref


def _plan(case):
    n, h, w = case["n"], case["h"], case["w"]
    bh = adapt_block(h, case.get("block_h", 32))     # ops.py shrinks to divisor
    return KernelPlan(
        case=case["case"],
        grid=(n, h // bh),
        tiles=[Tile("img_block", (1, bh, w)),
               Tile("out_block", (1, bh, w)),
               Tile("row_carry", (w,))],
        checks=[DivCheck("h % block_h", h, bh)],
    )


ANALYSIS = KernelAnalysisSpec(
    name="integral_image",
    pairs=[FnPair(integral_image_pallas, integral_ref,
                  frozenset({"block_h", "interpret"}))],
    plan=_plan,
)
