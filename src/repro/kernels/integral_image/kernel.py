"""Streaming integral image for TPU (paper §III-B, hardware-adapted).

The paper's ASIC computes the integral image with a two-row buffer,
streaming pixels once.  The TPU-native re-think (DESIGN.md §2): process
the image in row *blocks*; each grid step loads (block_h, w) into VMEM,
does a row-wise prefix sum (VPU cumsum) plus a column-wise prefix within
the block, adds the running carry row, and stores the completed integral
rows.  The carry (one row, like the hardware's "last row" buffer) lives in
VMEM scratch across sequential grid steps — the same never-hold-the-frame
dataflow, blocked for a vector machine instead of a shift register.

Batched over a leading dim (frames).  Width must fit VMEM (~176 for
WISPCam; up to ~32k f32 is fine).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _integral_kernel(img_ref, out_ref, carry_ref, *, block_h: int):
    bi = pl.program_id(0)     # frame (parallel)
    ri = pl.program_id(1)     # row block (sequential)

    @pl.when(ri == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    rows = img_ref[0].astype(jnp.float32)            # (block_h, w)
    row_prefix = jnp.cumsum(rows, axis=1)            # per-row prefix
    col_prefix = jnp.cumsum(row_prefix, axis=0)      # within-block column sum
    ii = col_prefix + carry_ref[...][None, :]
    out_ref[0] = ii.astype(out_ref.dtype)
    carry_ref[...] = ii[-1]


def integral_image_pallas(img, *, block_h: int = 32, interpret: bool = False):
    """img: (n, h, w) -> integral (n, h, w) [no zero padding row/col —
    ops.py adds it to match the camera.integral convention]."""
    n, h, w = img.shape
    block_h = min(block_h, h)
    assert h % block_h == 0, (h, block_h)
    grid = (n, h // block_h)
    return pl.pallas_call(
        functools.partial(_integral_kernel, block_h=block_h),
        grid=grid,
        in_specs=[pl.BlockSpec((1, block_h, w), lambda b, r: (b, r, 0))],
        out_specs=pl.BlockSpec((1, block_h, w), lambda b, r: (b, r, 0)),
        out_shape=jax.ShapeDtypeStruct((n, h, w), jnp.float32),
        scratch_shapes=[pltpu.VMEM((w,), jnp.float32)],
        interpret=interpret,
    )(img)
