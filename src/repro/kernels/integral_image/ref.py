"""Oracle: plain double cumsum (same as repro.camera.integral)."""

import jax.numpy as jnp


def integral_ref(img):
    """img: (n, h, w) -> (n, h, w) f32 (no zero-pad row/col)."""
    return jnp.cumsum(jnp.cumsum(img.astype(jnp.float32), axis=-2), axis=-1)
