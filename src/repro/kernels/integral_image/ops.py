"""jit'd wrapper: batched integral image with the camera zero-pad convention."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.integral_image.kernel import integral_image_pallas


@functools.partial(jax.jit, static_argnames=("block_h", "interpret"))
def integral_image(img, *, block_h: int = 32, interpret: bool = False):
    """img: (..., h, w) -> (..., h+1, w+1), ii[...,0,:]=ii[...,:,0]=0."""
    lead = img.shape[:-2]
    h, w = img.shape[-2:]
    flat = img.reshape(-1, h, w)
    bh = block_h
    while h % bh:
        bh -= 1
    ii = integral_image_pallas(flat, block_h=bh, interpret=interpret)
    ii = ii.reshape(*lead, h, w)
    return jnp.pad(ii, [(0, 0)] * len(lead) + [(1, 0), (1, 0)])
