"""Int8 quantized matmul + LUT sigmoid for TPU (paper §III-A, adapted).

The face-auth NN ASIC: 8x 8-bit PEs doing systolic MACs into a wide
accumulator, then a 256-entry LUT sigmoid.  TPU-native equivalent
(DESIGN.md §2): int8 x int8 -> int32 tiles on the MXU, f32 rescale, LUT
activation done as a VMEM lookup.  Tiled (block_m, block_k) x (block_k,
block_n) with the k grid dimension sequential and an int32 VMEM
accumulator — the standard Pallas matmul skeleton, int8-ized.

Also serves as the framework's reference int8 GEMM for the gradient-
compression path (core/reduction) — same rescale convention.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _qmm_kernel(x_ref, w_ref, lut_ref, b_ref, o_ref, acc_ref, *,
                n_k_blocks: int, scale_x: float, scale_w: float,
                apply_lut: bool, lut_lo: float, lut_hi: float,
                lut_entries: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.int32)          # (bm, bk) int8 -> i32
    w = w_ref[...].astype(jnp.int32)          # (bk, bn)
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)

    @pl.when(ki == n_k_blocks - 1)
    def _finalize():
        # ASIC accumulator datapath: rescale, bias add, then the LUT —
        # the bias lives in the wide-accumulator (f32) domain, exactly
        # where the hardware adds it before the activation lookup.
        y = acc_ref[...].astype(jnp.float32) * (scale_x * scale_w)
        y = y + b_ref[...]                        # (1, bn) broadcast
        if apply_lut:
            # hardware LUT: clamp to [lo, hi], index the table
            idx = jnp.clip(
                ((y - lut_lo) / (lut_hi - lut_lo) * (lut_entries - 1)),
                0, lut_entries - 1).astype(jnp.int32)
            y = lut_ref[...][idx.reshape(-1)].reshape(y.shape)
        o_ref[...] = y


def quant_matmul_pallas(x_q, w_q, lut, *, scale_x: float, scale_w: float,
                        bias=None, apply_lut: bool = True,
                        lut_lo: float = -8.0, lut_hi: float = 8.0,
                        block_m: int = 128, block_n: int = 128,
                        block_k: int = 128, interpret: bool = False):
    """x_q: (m, k) int8, w_q: (k, n) int8, lut: (entries,) f32 -> (m, n) f32.

    ``bias`` (n,) f32 is added in the accumulator domain (after rescale,
    before the LUT); ``lut_lo``/``lut_hi`` come from the same
    ``make_sigmoid_lut`` meta the LUT was built with, so the kernel's
    indexing can never drift from ``face_nn.sigmoid_lut``.
    """
    m, k = x_q.shape
    n = w_q.shape[1]
    bm, bk, bn = min(block_m, m), min(block_k, k), min(block_n, n)
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, (m, k, n, bm, bk, bn)
    if bias is None:
        bias = jnp.zeros((n,), jnp.float32)
    bias2d = jnp.asarray(bias, jnp.float32).reshape(1, n)

    kernel = functools.partial(
        _qmm_kernel, n_k_blocks=k // bk, scale_x=scale_x, scale_w=scale_w,
        apply_lut=apply_lut, lut_lo=lut_lo, lut_hi=lut_hi,
        lut_entries=lut.shape[0])

    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda mi, ni, ki: (mi, ki)),
            pl.BlockSpec((bk, bn), lambda mi, ni, ki: (ki, ni)),
            pl.BlockSpec(lut.shape, lambda mi, ni, ki: (0,)),
            pl.BlockSpec((1, bn), lambda mi, ni, ki: (0, ni)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda mi, ni, ki: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(x_q, w_q, lut, bias2d)
