"""Analysis registration hook (repro.analysis pass 3: kernel legality)."""

from repro.analysis.spec import (DivCheck, FnPair, KernelAnalysisSpec,
                                 KernelPlan, Tile, round_up)
from repro.kernels.quant_matmul.kernel import quant_matmul_pallas
from repro.kernels.quant_matmul.ref import quant_matmul_ref


def _plan(case):
    m, k, n = case["m"], case["k"], case["n"]
    # mirror ops.quant_matmul's block choice + zero-padding
    bm = 8 if m <= 8 else 128
    bk = 128 if k >= 128 else k
    bn = 128 if n >= 128 else n
    mp, kp, np_ = round_up(m, bm), round_up(k, bk), round_up(n, bn)
    return KernelPlan(
        case=case["case"],
        grid=(mp // bm, np_ // bn, kp // bk),
        tiles=[Tile("x_block", (bm, bk), "int8"),
               Tile("w_block", (bk, bn), "int8"),
               Tile("lut", (256,)),
               Tile("bias", (1, bn)),
               Tile("out_block", (bm, bn)),
               Tile("acc_scratch", (bm, bn), "int32")],
        checks=[DivCheck("m_pad % block_m", mp, bm),
                DivCheck("k_pad % block_k", kp, bk),
                DivCheck("n_pad % block_n", np_, bn)],
    )


ANALYSIS = KernelAnalysisSpec(
    name="quant_matmul",
    pairs=[FnPair(quant_matmul_pallas, quant_matmul_ref,
                  frozenset({"block_m", "block_n", "block_k", "interpret"}))],
    plan=_plan,
)
