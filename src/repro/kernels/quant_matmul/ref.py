"""Oracle: int32 matmul of int8 operands + rescale + bias + LUT sigmoid."""

import jax.numpy as jnp


def quant_matmul_ref(x_q, w_q, lut, *, scale_x, scale_w, bias=None,
                     apply_lut=True, lut_lo=-8.0, lut_hi=8.0):
    acc = jnp.einsum("mk,kn->mn", x_q.astype(jnp.int32), w_q.astype(jnp.int32))
    y = acc.astype(jnp.float32) * (scale_x * scale_w)
    if bias is not None:
        y = y + jnp.asarray(bias, jnp.float32)[None, :]
    if apply_lut:
        entries = lut.shape[0]
        idx = jnp.clip(((y - lut_lo) / (lut_hi - lut_lo) * (entries - 1)),
                       0, entries - 1).astype(jnp.int32)
        y = lut[idx]
    return y
