"""jit'd wrapper: quantize f32 operands per-tensor and run the int8 kernel.

`nn_forward_quantized` runs the paper's whole 400-8-1 NN on the kernel —
the ASIC's datapath end-to-end (int8 MACs + LUT sigmoid at both layers).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.quant_matmul.kernel import quant_matmul_pallas


def symmetric_quantize(x, bits: int = 8):
    qmax = 2 ** (bits - 1) - 1
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / qmax
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax).astype(jnp.int8)
    return q, scale


def _pad2(x, bm, bk):
    m, k = x.shape
    return jnp.pad(x, ((0, (-m) % bm), (0, (-k) % bk)))


@functools.partial(jax.jit, static_argnames=("apply_lut", "interpret"))
def quant_matmul(x, w, lut, *, apply_lut=True, interpret=False):
    """f32 in, int8 compute, rescale + optional LUT outside the kernel
    (scales are data-dependent here, so they can't be kernel constants)."""
    m, k = x.shape
    n = w.shape[1]
    x_q, sx = symmetric_quantize(x)
    w_q, sw = symmetric_quantize(w)
    bm = 8 if m <= 8 else 128
    bk = 128 if k >= 128 else k
    bn = 128 if n >= 128 else n
    xp = _pad2(x_q, bm, bk)
    wp = _pad2(w_q, bk, bn)
    out = quant_matmul_pallas(
        xp, wp, lut, scale_x=1.0, scale_w=1.0,
        apply_lut=False, interpret=interpret)
    y = out[:m, :n] * (sx * sw)
    if apply_lut:
        entries = lut.shape[0]
        idx = jnp.clip(((y + 8.0) / 16.0 * (entries - 1)), 0, entries - 1).astype(jnp.int32)
        y = lut[idx]
    return y


def quant_matmul_static(x_q, w_q, lut, *, scale_x: float, scale_w: float,
                        apply_lut=True, interpret=False):
    """ASIC path: pre-quantized operands with *calibrated* (static) scales —
    rescale and the 256-entry LUT sigmoid run inside the kernel, exactly
    like the hardware datapath."""
    m, k = x_q.shape
    n = w_q.shape[1]
    bm = 8 if m <= 8 else 128
    bk = 128 if k >= 128 else k
    bn = 128 if n >= 128 else n
    xp = _pad2(x_q, bm, bk)
    wp = _pad2(w_q, bk, bn)
    out = quant_matmul_pallas(
        xp, wp, lut, scale_x=scale_x, scale_w=scale_w,
        apply_lut=apply_lut, interpret=interpret)
    return out[:m, :n]
