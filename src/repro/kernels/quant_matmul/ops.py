"""jit'd wrappers around the int8 Pallas GEMM (paper §III-A).

Two regimes:

* :func:`quant_matmul` — quantize f32 operands per call (data-dependent
  scales, so rescale + LUT run outside the kernel);
* :func:`quant_matmul_static` / :func:`nn_forward_quantized` — the ASIC
  path: pre-quantized operands with *calibrated* (static) scales, bias add
  and the 256-entry LUT sigmoid inside the kernel.  `nn_forward_quantized`
  runs the paper's whole 400-8-1 NN on the kernel — the ASIC's datapath
  end-to-end (int8 MACs into a wide accumulator, bias, LUT sigmoid at both
  layers).  On CPU backends the same math dispatches to the jnp oracle
  (ref.py), which XLA fuses well; the Pallas lowering is the TPU path and
  what interpret-mode tests pin.

LUT indexing is always driven by the ``(lo, hi, entries)`` meta returned
by ``camera.face_nn.make_sigmoid_lut``, threaded through every entry
point, so the kernels and ``face_nn.sigmoid_lut`` cannot drift.
"""

from __future__ import annotations

import dataclasses
import functools

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels.quant_matmul.kernel import quant_matmul_pallas
from repro.kernels.quant_matmul.ref import quant_matmul_ref


def _meta_or_default(lut, meta):
    """(lo, hi, entries) — default is make_sigmoid_lut's default range."""
    if meta is None:
        return (-8.0, 8.0, int(lut.shape[0]))
    lo, hi, entries = meta
    if int(entries) != int(lut.shape[0]):
        raise ValueError(f"lut has {lut.shape[0]} entries, meta says {entries}")
    return (float(lo), float(hi), int(entries))


def symmetric_quantize(x, bits: int = 8):
    qmax = 2 ** (bits - 1) - 1
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / qmax
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax).astype(jnp.int8)
    return q, scale


def _pad2(x, bm, bk):
    m, k = x.shape
    return jnp.pad(x, ((0, (-m) % bm), (0, (-k) % bk)))


@functools.partial(jax.jit, static_argnames=("meta", "apply_lut", "interpret"))
def quant_matmul(x, w, lut, *, meta=None, apply_lut=True, interpret=False):
    """f32 in, int8 compute, rescale + optional LUT outside the kernel
    (scales are data-dependent here, so they can't be kernel constants).
    ``meta`` is the ``make_sigmoid_lut`` (lo, hi, entries) triple; None
    means the default (-8, 8) sigmoid range."""
    lo, hi, entries = _meta_or_default(lut, meta)
    m, k = x.shape
    n = w.shape[1]
    x_q, sx = symmetric_quantize(x)
    w_q, sw = symmetric_quantize(w)
    bm = 8 if m <= 8 else 128
    bk = 128 if k >= 128 else k
    bn = 128 if n >= 128 else n
    xp = _pad2(x_q, bm, bk)
    wp = _pad2(w_q, bk, bn)
    out = quant_matmul_pallas(
        xp, wp, lut, scale_x=1.0, scale_w=1.0,
        apply_lut=False, interpret=interpret)
    y = out[:m, :n] * (sx * sw)
    if apply_lut:
        idx = jnp.clip(((y - lo) / (hi - lo) * (entries - 1)),
                       0, entries - 1).astype(jnp.int32)
        y = lut[idx]
    return y


def quant_matmul_static(x_q, w_q, lut, *, scale_x: float, scale_w: float,
                        bias=None, meta=None, apply_lut=True,
                        interpret=False):
    """ASIC path: pre-quantized operands with *calibrated* (static) scales —
    rescale, bias add and the LUT sigmoid run inside the kernel, exactly
    like the hardware datapath."""
    lo, hi, _entries = _meta_or_default(lut, meta)
    m, k = x_q.shape
    n = w_q.shape[1]
    bm = 8 if m <= 8 else 128
    bk = 128 if k >= 128 else k
    bn = 128 if n >= 128 else n
    xp = _pad2(x_q, bm, bk)
    wp = _pad2(w_q, bk, bn)
    if bias is not None:               # pad with w_q's n (sliced off below)
        bias = jnp.pad(jnp.asarray(bias, jnp.float32),
                       (0, wp.shape[1] - n))
    out = quant_matmul_pallas(
        xp, wp, lut, scale_x=scale_x, scale_w=scale_w, bias=bias,
        apply_lut=apply_lut, lut_lo=lo, lut_hi=hi, interpret=interpret)
    return out[:m, :n]


# ---------------------------------------------------------------------------
# The 400-8-1 face-auth NN on the int8 kernel (paper §III-A datapath)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class QuantizedNN:
    """Statically-calibrated int8 parameters of the 400-8-1 face NN.

    Scales are *Python floats* fixed at calibration time (they compile into
    the kernel as constants — the ASIC's fixed rescale shifters), weights
    are int8 device arrays, biases stay f32 in the accumulator domain.
    """

    w1_q: jax.Array       # (n_in, n_hidden) int8
    b1: jax.Array         # (n_hidden,) f32
    w2_q: jax.Array       # (n_hidden, 1) int8
    b2: jax.Array         # (1,) f32
    scale_x: float        # input-pixel quantization step
    scale_w1: float
    scale_h: float        # hidden (sigmoid output in [0, 1]) step
    scale_w2: float
    bits: int = 8

    @property
    def qmax(self) -> int:
        return 2 ** (self.bits - 1) - 1


def quantize_nn(nn, *, bits: int = 8, x_max: float = 1.0) -> QuantizedNN:
    """Offline calibration: per-tensor symmetric scales from the trained
    weights; activation scales from the *known* ranges (input pixels in
    [0, ``x_max``], hidden sigmoid outputs in [0, 1]) — static, like the
    ASIC's fixed-point format, not per-batch like ``symmetric_quantize``.

    ``nn`` is duck-typed: anything with ``w1``/``b1``/``w2``/``b2``
    (``camera.face_nn.FaceNN`` in practice).
    """
    qmax = 2 ** (bits - 1) - 1
    w1 = np.asarray(nn.w1, np.float32)
    w2 = np.asarray(nn.w2, np.float32)
    sw1 = float(max(np.abs(w1).max(), 1e-12)) / qmax
    sw2 = float(max(np.abs(w2).max(), 1e-12)) / qmax
    return QuantizedNN(
        w1_q=jnp.asarray(np.clip(np.round(w1 / sw1), -qmax, qmax), jnp.int8),
        b1=jnp.asarray(np.asarray(nn.b1, np.float32)),
        w2_q=jnp.asarray(np.clip(np.round(w2 / sw2), -qmax, qmax), jnp.int8),
        b2=jnp.asarray(np.asarray(nn.b2, np.float32)),
        scale_x=float(x_max) / qmax, scale_w1=sw1,
        scale_h=1.0 / qmax, scale_w2=sw2, bits=bits)


def _quantize_static(x, scale: float, qmax: int):
    return jnp.clip(jnp.round(x / scale), -qmax, qmax).astype(jnp.int8)


def nn_forward_quantized(qnn: QuantizedNN, x, lut, meta=None, *,
                         use_pallas: bool | None = None,
                         interpret: bool = False):
    """Both NN layers through the int8 kernel: (..., n_in) f32 -> (...,) f32.

    Traceable (jit/vmap/pmap-safe): all scales and the dispatch decision
    are static.  On TPU (or with ``interpret=True`` under
    ``use_pallas=True``) each layer is one ``quant_matmul_pallas`` call
    with rescale + bias + LUT fused in-kernel; elsewhere the identical
    math runs through the jnp oracle ``quant_matmul_ref``.
    """
    lo, hi, entries = _meta_or_default(lut, meta)
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"

    def layer(h_q, w_q, bias, scale_in, scale_w):
        if use_pallas:
            return quant_matmul_static(
                h_q, w_q, lut, scale_x=scale_in, scale_w=scale_w, bias=bias,
                meta=(lo, hi, entries), apply_lut=True, interpret=interpret)
        return quant_matmul_ref(
            h_q, w_q, lut, scale_x=scale_in, scale_w=scale_w, bias=bias,
            apply_lut=True, lut_lo=lo, lut_hi=hi)

    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    x_q = _quantize_static(x2, qnn.scale_x, qnn.qmax)
    h = layer(x_q, qnn.w1_q, qnn.b1, qnn.scale_x, qnn.scale_w1)
    h_q = _quantize_static(h, qnn.scale_h, qnn.qmax)
    y = layer(h_q, qnn.w2_q, qnn.b2, qnn.scale_h, qnn.scale_w2)
    return y[:, 0].reshape(lead)
