"""Oracle: block-scaled quantize + bit-pack for cut-point payloads.

Quantization semantics are shared with ``core/reduction.quantize_int8``
(flat blocks, symmetric absmax/qmax scale, zero-blocks get scale 1,
round-half-to-even) so a wire-codec int8 payload dequantizes to exactly
``dequantize_int8(quantize_int8(x))``.  Packing layouts:

  bits=8   one int8 byte per value                  (n_blocks, block)
  bits=4   two values per byte, low nibble first    (n_blocks, block // 2)
  bits=16  little-endian int16 as two int8 bytes    (n_blocks, block * 2)

Scales are f32, one per block: (n_blocks, 1).  Wire size per block is
``block * bits / 8`` payload bytes + 4 scale bytes.
"""

from __future__ import annotations

import jax.numpy as jnp


def _qparams(bits: int):
    if bits not in (4, 8, 16):
        raise ValueError(f"wire codec supports 4/8/16 bits, got {bits}")
    return 2 ** (bits - 1) - 1


def quantize_blocks_ref(blocks, bits: int):
    """(n_blocks, block) f32 -> (q int32, scales f32 (n_blocks, 1))."""
    qmax = _qparams(bits)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / qmax
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(blocks / scale), -qmax, qmax).astype(jnp.int32)
    return q, scale.astype(jnp.float32)


def pack_ref(q, bits: int):
    """Quantized int32 values (n_blocks, block) -> packed int8 bytes."""
    nb = q.shape[0]
    if bits == 8:
        return q.astype(jnp.int8)
    if bits == 4:
        pair = (q & 0xF).reshape(nb, -1, 2)
        return (pair[:, :, 0] | (pair[:, :, 1] << 4)).astype(jnp.int8)
    lo = q & 0xFF
    hi = (q >> 8) & 0xFF
    return jnp.stack([lo, hi], axis=-1).reshape(nb, -1).astype(jnp.int8)


def unpack_ref(packed, bits: int):
    """Packed int8 bytes -> quantized int32 values (n_blocks, block)."""
    nb = packed.shape[0]
    p = packed.astype(jnp.int32) & 0xFF
    if bits == 8:
        return packed.astype(jnp.int32)
    if bits == 4:
        lo = p & 0xF
        hi = (p >> 4) & 0xF
        lo = lo - ((lo & 0x8) << 1)          # sign-extend the nibble
        hi = hi - ((hi & 0x8) << 1)
        return jnp.stack([lo, hi], axis=-1).reshape(nb, -1)
    b = p.reshape(nb, -1, 2)
    v = b[:, :, 0] | (b[:, :, 1] << 8)
    return v - ((v & 0x8000) << 1)           # sign-extend 16 bits


def wire_encode_ref(blocks, *, bits: int = 8):
    """(n_blocks, block) f32 -> (packed int8, scales (n_blocks, 1) f32)."""
    q, scale = quantize_blocks_ref(blocks, bits)
    return pack_ref(q, bits), scale


def wire_decode_ref(packed, scales, *, bits: int = 8):
    """(packed, scales) -> (n_blocks, block) f32 dequantized blocks."""
    return unpack_ref(packed, bits).astype(jnp.float32) * scales
