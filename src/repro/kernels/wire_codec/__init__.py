"""Analysis registration hook (repro.analysis pass 3: kernel legality)."""

from repro.analysis.spec import (DivCheck, FnPair, KernelAnalysisSpec,
                                 KernelPlan, Tile, round_up)
from repro.kernels.wire_codec.kernel import (wire_decode_pallas,
                                             wire_encode_pallas)
from repro.kernels.wire_codec.ref import wire_decode_ref, wire_encode_ref

BLOCK = 256   # values per codec block (ops.BLOCK)


def _plan(case):
    bits = case["bits"]
    nb = -(-case["n_values"] // BLOCK)
    bm = min(case.get("block_rows", 32), nb)
    nbp = round_up(nb, bm)                      # ops.py pads rows
    pw = BLOCK * bits // 8
    return KernelPlan(
        case=case["case"],
        grid=(nbp // bm,),
        tiles=[Tile("blocks", (bm, BLOCK)),
               Tile("packed", (bm, pw), "uint8"),
               Tile("scales", (bm, 1)),
               Tile("decoded", (bm, BLOCK))],
        checks=[DivCheck("nb_pad % block_rows", nbp, bm)],
    )


ANALYSIS = KernelAnalysisSpec(
    name="wire_codec",
    pairs=[FnPair(wire_encode_pallas, wire_encode_ref,
                  frozenset({"bits", "block_rows", "interpret"})),
           FnPair(wire_decode_pallas, wire_decode_ref,
                  frozenset({"bits", "block_rows", "interpret"}))],
    plan=_plan,
)
