"""Pallas wire codec for TPU: block-scaled quantize + bit-pack in VMEM.

The node side of an offload cut runs this right before the radio: each
grid step loads a (block_rows, block) f32 tile of flattened payload
blocks, computes the per-block absmax scale on the VPU, quantizes, and
packs 4-bit pairs (or 8-bit values) into int8 bytes — the payload never
returns to HBM at full precision.  The decode kernel is the cloud-side
inverse (unpack, sign-extend, rescale).

Quantization semantics are pinned to ``core/reduction.quantize_int8``
(see ref.py); interpret-mode tests require bit-exact agreement with the
jnp oracle.  16-bit payloads ship through the ref path (ops.py): the
two-byte split is pure memory movement with nothing to fuse.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _encode_kernel(x_ref, p_ref, s_ref, *, bits: int):
    x = x_ref[...]                                    # (bm, block) f32
    qmax = 2 ** (bits - 1) - 1
    scale = jnp.max(jnp.abs(x), axis=1, keepdims=True) / qmax
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax).astype(jnp.int32)
    if bits == 8:
        p_ref[...] = q.astype(jnp.int8)
    else:                                             # 4-bit nibble pairs
        pair = (q & 0xF).reshape(q.shape[0], -1, 2)
        p_ref[...] = (pair[:, :, 0] | (pair[:, :, 1] << 4)).astype(jnp.int8)
    s_ref[...] = scale.astype(jnp.float32)


def _decode_kernel(p_ref, s_ref, o_ref, *, bits: int):
    if bits == 8:
        q = p_ref[...].astype(jnp.int32)
    else:
        p = p_ref[...].astype(jnp.int32) & 0xFF
        lo = p & 0xF
        hi = (p >> 4) & 0xF
        lo = lo - ((lo & 0x8) << 1)                   # sign-extend nibbles
        hi = hi - ((hi & 0x8) << 1)
        q = jnp.stack([lo, hi], axis=-1).reshape(p.shape[0], -1)
    o_ref[...] = q.astype(jnp.float32) * s_ref[...]


def wire_encode_pallas(blocks, *, bits: int = 8, block_rows: int = 32,
                       interpret: bool = False):
    """(n_blocks, block) f32 -> (packed int8, scales (n_blocks, 1) f32).

    ``n_blocks`` must divide into ``block_rows`` tiles (ops.py pads).
    """
    assert bits in (4, 8), bits
    nb, block = blocks.shape
    bm = min(block_rows, nb)
    assert nb % bm == 0, (nb, bm)
    pw = block * bits // 8
    return pl.pallas_call(
        functools.partial(_encode_kernel, bits=bits),
        grid=(nb // bm,),
        in_specs=[pl.BlockSpec((bm, block), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((bm, pw), lambda i: (i, 0)),
                   pl.BlockSpec((bm, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((nb, pw), jnp.int8),
                   jax.ShapeDtypeStruct((nb, 1), jnp.float32)],
        interpret=interpret,
    )(blocks)


def wire_decode_pallas(packed, scales, *, bits: int = 8,
                       block_rows: int = 32, interpret: bool = False):
    """(packed int8, scales) -> (n_blocks, block) f32 dequantized blocks."""
    assert bits in (4, 8), bits
    nb, pw = packed.shape
    block = pw * 8 // bits
    bm = min(block_rows, nb)
    assert nb % bm == 0, (nb, bm)
    return pl.pallas_call(
        functools.partial(_decode_kernel, bits=bits),
        grid=(nb // bm,),
        in_specs=[pl.BlockSpec((bm, pw), lambda i: (i, 0)),
                  pl.BlockSpec((bm, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, block), jnp.float32),
        interpret=interpret,
    )(packed, scales)
