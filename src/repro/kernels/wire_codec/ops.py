"""jit'd wire-codec entry points + wire-size accounting (paper §III-A).

The codec turns a cut-point payload tensor into what actually crosses the
offload link: block-scaled intN bytes plus one f32 scale per block, the
same 8-bit-datapath tradeoff the paper studies (8-bit costs 0.4% accuracy
for 41% of the bytes^H^H^H power; 4-bit is past the knee).  Quantization
semantics are shared with ``core/reduction.quantize_int8`` — an int8
wire payload dequantizes to exactly ``dequantize_int8(quantize_int8(x))``
(pinned by tests/test_kernels.py).

Dispatch follows the repo convention (DESIGN.md §4): Pallas on TPU (or
``interpret=True`` for tests) for 4/8-bit, the jnp oracle elsewhere;
16-bit always ships through the oracle (pure byte movement).

All entry points are traceable, so the offload executors fuse the codec
into the node-side / cloud-side jit regions (DESIGN.md §10).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.kernels.wire_codec.kernel import (
    wire_decode_pallas,
    wire_encode_pallas,
)
from repro.kernels.wire_codec.ref import (
    wire_decode_ref,
    wire_encode_ref,
)

BLOCK = 256                      # default flat block (quantize_int8's)
SCALE_BYTES = 4                  # one f32 scale per block


def _use_pallas(use_pallas, bits):
    if bits == 16:               # byte split only; nothing to fuse
        return False
    if use_pallas is None:
        return jax.default_backend() == "tpu"
    return bool(use_pallas)


def _to_blocks(x, block):
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, block)


def _pad_rows(a, bm):
    pad = (-a.shape[0]) % bm
    if pad:
        a = jnp.pad(a, ((0, pad), (0, 0)))
    return a


@functools.partial(jax.jit, static_argnames=(
    "bits", "block", "use_pallas", "interpret"))
def wire_encode(x, *, bits: int = 8, block: int = BLOCK,
                use_pallas=None, interpret: bool = False):
    """Payload tensor (any shape, f32-castable) -> (packed, scales).

    packed: (n_blocks, block * bits // 8) int8 wire bytes.
    scales: (n_blocks, 1) f32, one per flat block of ``block`` values.
    """
    blocks = _to_blocks(x.astype(jnp.float32), block)
    nb = blocks.shape[0]
    if _use_pallas(use_pallas, bits):
        bm = min(32, nb)
        packed, scales = wire_encode_pallas(
            _pad_rows(blocks, bm), bits=bits, block_rows=bm,
            interpret=interpret)
        return packed[:nb], scales[:nb]
    return wire_encode_ref(blocks, bits=bits)


@functools.partial(jax.jit, static_argnames=(
    "shape", "bits", "block", "use_pallas", "interpret"))
def wire_decode(packed, scales, shape, *, bits: int = 8, block: int = BLOCK,
                use_pallas=None, interpret: bool = False):
    """(packed, scales) -> f32 tensor of static ``shape``."""
    nb = packed.shape[0]
    if _use_pallas(use_pallas, bits):
        bm = min(32, nb)
        blocks = wire_decode_pallas(
            _pad_rows(packed, bm), _pad_rows(scales, bm), bits=bits,
            block_rows=bm, interpret=interpret)[:nb]
    else:
        blocks = wire_decode_ref(packed, scales, bits=bits)
    n = math.prod(shape)
    return blocks.reshape(-1)[:n].reshape(shape)


def wire_roundtrip(x, *, bits: int = 8, block: int = BLOCK,
                   use_pallas=None, interpret: bool = False):
    """encode-then-decode — the codec's end-to-end distortion operator."""
    packed, scales = wire_encode(x, bits=bits, block=block,
                                 use_pallas=use_pallas, interpret=interpret)
    return wire_decode(packed, scales, tuple(x.shape), bits=bits,
                       block=block, use_pallas=use_pallas,
                       interpret=interpret)


# ---------------------------------------------------------------------------
# Wire-size accounting
# ---------------------------------------------------------------------------


def wire_bytes(n_values: int, bits: int | None, *, block: int = BLOCK,
               value_bytes: float = 4.0) -> float:
    """Wire bytes for ``n_values`` payload values at ``bits`` width.

    ``bits=None`` means raw passthrough at ``value_bytes`` per value (f32
    runtime representation = 4).  Quantized payloads pay bits/8 per value
    plus one f32 scale per (partial) block.
    """
    if n_values <= 0:
        return 0.0
    if bits is None:
        return float(n_values) * value_bytes
    return (n_values * bits / 8.0
            + math.ceil(n_values / block) * SCALE_BYTES)


def wire_bytes_dynamic(n_values, bits: int | None, *, block: int = BLOCK,
                       value_bytes: float = 4.0):
    """Traceable ``wire_bytes``: ``n_values`` may be a traced int scalar.

    Used by the offload executors to charge only *valid* (non-padding)
    payload elements in-graph — the measured bytes a real variable-length
    transmit would put on the air, while shapes stay static.
    """
    n = jnp.maximum(n_values, 0).astype(jnp.float32)
    if bits is None:
        return n * value_bytes
    return n * (bits / 8.0) + jnp.ceil(n / block) * SCALE_BYTES
