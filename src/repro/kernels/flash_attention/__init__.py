"""Analysis registration hook (repro.analysis pass 3: kernel legality)."""

from repro.analysis.spec import (DivCheck, FnPair, KernelAnalysisSpec,
                                 KernelPlan, Tile, round_up)
from repro.kernels.flash_attention.kernel import flash_attention_bhsd
from repro.kernels.flash_attention.ref import attention_ref


def _plan(case):
    bh, s, d = case["bh"], case["s"], case["d"]
    bq = min(case.get("block_q", 256), max(s, 1))
    bk = min(case.get("block_k", 256), max(s, 1))
    sq, sk = round_up(s, bq), round_up(s, bk)   # ops.py pads both seq axes
    return KernelPlan(
        case=case["case"],
        grid=(bh, sq // bq, sk // bk),
        tiles=[Tile("q_block", (1, bq, d)),
               Tile("k_block", (1, bk, d)),
               Tile("v_block", (1, bk, d)),
               Tile("out_block", (1, bq, d)),
               Tile("m_scratch", (bq,)),
               Tile("l_scratch", (bq,)),
               Tile("acc_scratch", (bq, d))],
        checks=[DivCheck("s_pad % block_q", sq, bq),
                DivCheck("t_pad % block_k", sk, bk)],
    )


ANALYSIS = KernelAnalysisSpec(
    name="flash_attention",
    pairs=[FnPair(flash_attention_bhsd, attention_ref,
                  frozenset({"block_q", "block_k", "interpret"}))],
    plan=_plan,
)
