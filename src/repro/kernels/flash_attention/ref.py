"""Pure-jnp oracle for the flash attention kernel."""

from __future__ import annotations

import math

import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal=True, window=None, scale=None):
    """q: (BH, s, d), k/v: (BH, t, d|dv) -> (BH, s, dv).  Dense softmax."""
    BH, s, d = q.shape
    t = k.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    logits = jnp.einsum("hsd,htd->hst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(s)[:, None]
    k_pos = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    logits = jnp.where(mask[None], logits, NEG_INF)
    probs = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    probs = probs / jnp.maximum(jnp.sum(probs, axis=-1, keepdims=True), 1e-37)
    out = jnp.einsum("hst,htd->hsd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)
