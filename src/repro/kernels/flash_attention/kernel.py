"""Flash attention for TPU (pl.pallas_call + BlockSpec VMEM tiling).

Blockwise online-softmax attention: grid = (batch*heads, q_blocks,
k_blocks) with the k dimension 'arbitrary' (sequential) so the running
(m, l, acc) state lives in VMEM scratch across k iterations.  Per-program
VMEM footprint: q (block_q, d) + k/v (block_k, d) + scratch (block_q, d)
f32 — all MXU-aligned (block sizes multiples of 128, d = head_dim).

Causal and sliding-window masks are applied from absolute positions, so
the same kernel serves full attention, SWA (mixtral), and prefill.
Fully-masked (q_block, k_block) pairs are skipped with pl.when — the
cascade idea at kernel granularity: don't spend MXU cycles on work a
cheap test can discard.

TARGET: TPU (MXU).  This container is CPU-only: tests run interpret=True
against ref.py; the dry-run lowers the pure-jnp streaming reference
instead (Pallas does not lower to the CPU backend).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window, block_q: int,
                  block_k: int, n_k_blocks: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)

    # static-ish skip: with causality, blocks entirely in the future are dead
    run = jnp.bool_(True)
    if causal:
        run = (ki * block_k) <= (qi * block_q + block_q - 1)
    if window is not None:
        run = jnp.logical_and(
            run, (ki * block_k + block_k - 1) > (qi * block_q - window))

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, d)
        k = k_ref[0].astype(jnp.float32)                  # (bk, d)
        v = v_ref[0].astype(jnp.float32)                  # (bk, dv)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)           # (bq, bk)
        mask = jnp.bool_(True)
        if causal:
            mask = k_pos <= q_pos
        if window is not None:
            mask = jnp.logical_and(mask, k_pos > q_pos - window)
        logits = jnp.where(mask, logits, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(logits, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(logits - m_new[:, None])
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == n_k_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-37)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal=True, window=None, scale=None,
                         block_q=256, block_k=256, interpret=False):
    """q: (BH, s, d), k/v: (BH, t, d) -> (BH, s, d).

    Shapes must tile exactly (ops.py pads); d should be a multiple of 128
    on real TPU for MXU alignment.
    """
    BH, s, d = q.shape
    t = k.shape[1]
    dv = v.shape[2]
    block_q = min(block_q, s)
    block_k = min(block_k, t)
    assert s % block_q == 0 and t % block_k == 0, (s, t, block_q, block_k)
    n_q, n_k = s // block_q, t // block_k
    scale = scale if scale is not None else 1.0 / math.sqrt(d)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, n_k_blocks=n_k)

    return pl.pallas_call(
        kernel,
        grid=(BH, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda h, qi, ki: (h, qi, 0)),
            pl.BlockSpec((1, block_k, d), lambda h, qi, ki: (h, ki, 0)),
            pl.BlockSpec((1, block_k, dv), lambda h, qi, ki: (h, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dv), lambda h, qi, ki: (h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, s, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),        # m (running max)
            pltpu.VMEM((block_q,), jnp.float32),        # l (running denom)
            pltpu.VMEM((block_q, dv), jnp.float32),     # acc
        ],
        interpret=interpret,
    )(q, k, v)
