"""jit'd public wrapper: (b, s, H, d) attention via the flash kernel.

Handles GQA head expansion, (b, H) flattening, and block padding; this is
the call signature the model stack would use on real TPU hardware (the
CPU dry-run keeps the jnp streaming reference — Pallas lowers to TPU).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_bhsd


def _pad_to(x, axis, mult):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=None, block_q=256,
                    block_k=256, interpret=False):
    """q: (b, s, H, d); k/v: (b, t, KV, d) with KV | H -> (b, s, H, d)."""
    b, s, H, d = q.shape
    KV = k.shape[2]
    if KV != H:
        g = H // KV
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)

    qf = jnp.moveaxis(q, 2, 1).reshape(b * H, s, d)
    kf = jnp.moveaxis(k, 2, 1).reshape(b * H, k.shape[1], d)
    vf = jnp.moveaxis(v, 2, 1).reshape(b * H, v.shape[1], v.shape[-1])

    bq = min(block_q, max(s, 1))
    bk = min(block_k, max(kf.shape[1], 1))
    qf, pad_q = _pad_to(qf, 1, bq)
    kf, pad_k = _pad_to(kf, 1, bk)
    vf, _ = _pad_to(vf, 1, bk)
    # padded k positions must never win: causal masking handles the q side;
    # for the k side we rely on causal=True cells (all ours) or window
    if pad_k and not causal:
        raise ValueError("non-causal padding unsupported; pad inputs upstream")

    out = flash_attention_bhsd(qf, kf, vf, causal=causal, window=window,
                               block_q=bq, block_k=bk, interpret=interpret)
    if pad_q:
        out = out[:, :s]
    return jnp.moveaxis(out.reshape(b, H, s, -1), 1, 2)
