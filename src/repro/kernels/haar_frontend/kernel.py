"""Fused Haar front-end for TPU (DESIGN.md §3).

One cascade stage over a block of scanning windows, fused in VMEM:

  gather   — each weak classifier is <= 8 corner taps into the flattened
             frame integral image (which fits VMEM whole: a 176x145 f32
             table is ~100 kB, far under the ~16 MB budget), indexed as
             window-base + per-scale static offset;
  vote     — decision stumps on the variance-normalized responses;
  reduce   — AdaBoost-weighted sum into one stage score per window.

The grid runs over window row-blocks; the integral image, corner tables
and stump parameters are broadcast to every step.  The frame is touched
once (by the integral-image kernel); everything downstream is lookups —
the paper's early-data-reduction principle applied to the VJ front-end
itself.  kernels/integral_image produces the table; this kernel consumes
it without ever re-materializing windows.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _stage_kernel(ii_ref, base_ref, sid_ref, inv_ref, off_ref, wgt_ref,
                  par_ref, out_ref):
    ii = ii_ref[0]                                    # (Lp,)
    base = base_ref[0]                                # (block_n,)
    sid = sid_ref[0]
    inv = inv_ref[0]
    off = jnp.take(off_ref[...], sid, axis=0)         # (block_n, sz*K)
    idx = base[:, None] + off
    vals = jnp.take(ii, idx.reshape(-1), axis=0).reshape(idx.shape)
    bn = vals.shape[0]
    sz = par_ref.shape[1]
    resp = jnp.sum((vals * wgt_ref[0][None, :]).reshape(bn, sz, -1), axis=-1)
    resp = resp * inv[:, None]
    pred = par_ref[1][None] * jnp.sign(resp - par_ref[0][None])
    pred = jnp.where(pred == 0.0, 1.0, pred)
    out_ref[0] = jnp.sum(pred * par_ref[2][None], axis=-1)


def haar_stage_scores_pallas(ii_flat, base, sid, inv_norm, offsets, weights,
                             thresholds, polarity, alphas, *,
                             block_n: int = 256, interpret: bool = False):
    """Stage scores (n,) f32; argument contract matches ref.py.

    offsets: (n_scales, sz, K) int32; weights: (sz, K) f32 (0-padded slots).
    """
    n = base.shape[0]
    n_scales, sz, K = offsets.shape
    L = ii_flat.shape[0]
    lp = _round_up(L, 128)
    block_n = min(block_n, _round_up(n, 8))
    npad = _round_up(n, block_n)

    ii2d = jnp.pad(ii_flat.astype(jnp.float32), (0, lp - L)).reshape(1, lp)
    base2d = jnp.pad(base.astype(jnp.int32), (0, npad - n)).reshape(1, npad)
    sid2d = jnp.pad(sid.astype(jnp.int32), (0, npad - n)).reshape(1, npad)
    inv2d = jnp.pad(inv_norm.astype(jnp.float32), (0, npad - n)).reshape(1, npad)
    off2d = offsets.reshape(n_scales, sz * K).astype(jnp.int32)
    wgt2d = weights.reshape(1, sz * K).astype(jnp.float32)
    par = jnp.stack([thresholds, polarity, alphas]).astype(jnp.float32)

    out = pl.pallas_call(
        _stage_kernel,
        grid=(npad // block_n,),
        in_specs=[
            pl.BlockSpec((1, lp), lambda i: (0, 0)),
            pl.BlockSpec((1, block_n), lambda i: (0, i)),
            pl.BlockSpec((1, block_n), lambda i: (0, i)),
            pl.BlockSpec((1, block_n), lambda i: (0, i)),
            pl.BlockSpec((n_scales, sz * K), lambda i: (0, 0)),
            pl.BlockSpec((1, sz * K), lambda i: (0, 0)),
            pl.BlockSpec((3, sz), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, npad), jnp.float32),
        interpret=interpret,
    )(ii2d, base2d, sid2d, inv2d, off2d, wgt2d, par)
    return out[0, :n]
