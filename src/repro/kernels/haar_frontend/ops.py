"""Backend dispatch for the fused Haar front-end.

The jnp reference (ref.py) *is* the production path on CPU — XLA fuses the
gather/vote/reduce chain well there, and Pallas interpret mode would add
per-grid-step Python overhead to the hot loop.  On TPU the Pallas kernel
(kernel.py) keeps the integral image and corner tables VMEM-resident
across window blocks.  Both compute the same math; tests/test_kernels.py
pins them together in interpret mode.
"""

from __future__ import annotations

import jax

from repro.kernels.haar_frontend.kernel import haar_stage_scores_pallas
from repro.kernels.haar_frontend.ref import haar_stage_scores_ref


def haar_stage_scores(ii_flat, base, sid, inv_norm, offsets, weights,
                      thresholds, polarity, alphas, *,
                      use_pallas: bool | None = None,
                      block_n: int = 256, interpret: bool = False):
    """One cascade stage's AdaBoost scores, (n,) f32.  See ref.py for the
    argument contract."""
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        return haar_stage_scores_pallas(
            ii_flat, base, sid, inv_norm, offsets, weights,
            thresholds, polarity, alphas, block_n=block_n, interpret=interpret)
    return haar_stage_scores_ref(ii_flat, base, sid, inv_norm, offsets,
                                 weights, thresholds, polarity, alphas)
