"""Oracle for the fused Haar front-end: gather + stump vote + stage reduce.

One cascade *stage* over a batch of scanning windows, expressed entirely as
corner-tap gathers into the flattened frame-level integral image:

  * each weak classifier is <= 8 corner lookups with static +-1/+-2/+-3
    weights (the 2-/3-rect Haar decomposition after merging shared edges);
  * corner offsets are precomputed per pyramid *scale* relative to the
    window's top-left flat index, so a window is fully described by a single
    base offset plus a scale id;
  * the variance normalizer (1 / (sd * win^2)) is precomputed per window by
    the caller (camera.viola_jones) from the frame ii / ii^2 pair.

This jnp formulation is also the production path on CPU backends; the
Pallas kernel (kernel.py) is the TPU lowering of the same math.
"""

from __future__ import annotations

import jax.numpy as jnp


def haar_stage_scores_ref(ii_flat, base, sid, inv_norm, offsets, weights,
                          thresholds, polarity, alphas):
    """Stage score per window.

    ii_flat:    (L,) flattened zero-padded frame integral image.
    base:       (n,) int32 window top-left flat index, y * (W + 1) + x.
    sid:        (n,) int32 pyramid-scale id per window.
    inv_norm:   (n,) f32 per-window 1 / (sd * area).
    offsets:    (n_scales, sz, K) int32 corner taps per scale.
    weights:    (sz, K) f32 corner weights (0 in padded slots).
    thresholds, polarity, alphas: (sz,) decision-stump parameters.

    Returns (n,) f32 sum_k alpha_k * vote_k — the AdaBoost stage score.
    """
    off = jnp.take(offsets, sid, axis=0)                 # (n, sz, K)
    idx = base[:, None, None] + off
    vals = jnp.take(ii_flat, idx.reshape(-1), axis=0).reshape(idx.shape)
    resp = jnp.sum(vals * weights[None], axis=-1) * inv_norm[:, None]
    pred = polarity[None] * jnp.sign(resp - thresholds[None])
    pred = jnp.where(pred == 0, 1.0, pred)
    return jnp.sum(pred * alphas[None], axis=-1)
