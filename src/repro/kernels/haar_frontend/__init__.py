"""Analysis registration hook (repro.analysis pass 3: kernel legality)."""

from repro.analysis.spec import (DivCheck, FnPair, KernelAnalysisSpec,
                                 KernelPlan, Tile, round_up)
from repro.kernels.haar_frontend.kernel import haar_stage_scores_pallas
from repro.kernels.haar_frontend.ref import haar_stage_scores_ref


def _plan(case):
    n, L = case["n_windows"], case["L"]
    n_scales, sz, K = case["n_scales"], case["sz"], case["K"]
    lp = round_up(L, 128)                       # kernel pads the ii table
    bn = min(case.get("block_n", 256), round_up(n, 8))
    npad = round_up(n, bn)                      # kernel pads the window axis
    return KernelPlan(
        case=case["case"],
        grid=(npad // bn,),
        tiles=[Tile("ii", (1, lp)),
               Tile("base", (1, bn), "int32"),
               Tile("sid", (1, bn), "int32"),
               Tile("inv_norm", (1, bn)),
               Tile("offsets", (n_scales, sz * K), "int32"),
               Tile("weights", (1, sz * K)),
               Tile("stump_params", (3, sz)),
               Tile("out_scores", (1, bn))],
        checks=[DivCheck("npad % block_n", npad, bn),
                DivCheck("lp % 128", lp, 128)],
    )


ANALYSIS = KernelAnalysisSpec(
    name="haar_frontend",
    pairs=[FnPair(haar_stage_scores_pallas, haar_stage_scores_ref,
                  frozenset({"block_n", "interpret"}))],
    plan=_plan,
)
