"""jit'd wrapper for the chunked WKV kernel (pads T to the chunk size)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rwkv_scan.kernel import rwkv_wkv_pallas


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv_wkv(r, k, v, w, u, *, chunk: int = 32, interpret: bool = False):
    """(BH, T, K) x3 + (BH, T, K) decays + (BH, K) bonus -> (BH, T, V)."""
    BH, T, K = r.shape
    pad = (-T) % chunk
    if pad:
        zpad = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0)))
        r, k, v = zpad(r), zpad(k), zpad(v)
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
    out = rwkv_wkv_pallas(r, k, v, w, u, chunk=chunk, interpret=interpret)
    return out[:, :T]
