"""Analysis registration hook (repro.analysis pass 3: kernel legality)."""

from repro.analysis.spec import (DivCheck, FnPair, KernelAnalysisSpec,
                                 KernelPlan, Tile, round_up)
from repro.kernels.rwkv_scan.kernel import rwkv_wkv_pallas
from repro.kernels.rwkv_scan.ref import wkv_ref


def _plan(case):
    bh, T, K, V = case["bh"], case["T"], case["K"], case["V"]
    chunk = case.get("chunk", 32)
    Tp = round_up(T, chunk)                     # ops.py pads T
    return KernelPlan(
        case=case["case"],
        grid=(bh, Tp // chunk),
        tiles=[Tile("r_block", (1, chunk, K)),
               Tile("k_block", (1, chunk, K)),
               Tile("v_block", (1, chunk, V)),
               Tile("w_block", (1, chunk, K)),
               Tile("u", (1, K)),
               Tile("out_block", (1, chunk, V)),
               Tile("state_scratch", (K, V))],
        checks=[DivCheck("T_pad % chunk", Tp, chunk)],
    )


ANALYSIS = KernelAnalysisSpec(
    name="rwkv_scan",
    pairs=[FnPair(rwkv_wkv_pallas, wkv_ref,
                  frozenset({"chunk", "interpret"}))],
    plan=_plan,
)
