"""Chunked RWKV6 WKV recurrence for TPU.

The wkv state update S <- diag(w_t) S + k_t v_t^T is the sequential heart
of RWKV6 — a pure lax.scan over 4k+ steps leaves the MXU idle and HBM-
bound.  Kernel strategy (fla-style, adapted to Pallas/TPU):

* grid = (B*H, n_chunks) with the chunk dimension sequential;
* the (K, V) state lives in VMEM scratch across chunks;
* within a chunk of length L, the *inter-chunk* contribution is a matmul
  against the carried state (r_t . S with per-channel decay prefix), and
  the *intra-chunk* contribution uses the decay-factored score matmul
  (r~ @ k~^T masked strictly-lower) — both MXU work.  Chunk length bounds
  the decay ratio so the factored form stays in f32 range (L = 32 with
  w >= e^-20 keeps exponents < 64; RWKV6 decays are lower-bounded well
  above that in practice — documented assumption, tested against the
  sequential oracle including near-zero decays at L = 16).

VMEM per program: r/k/v/w chunks (L, K) x4 + state (K, V) + score (L, L):
with K = V = 64, L = 32: ~50 KB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, state_ref, *,
                chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    r = r_ref[0].astype(jnp.float32)          # (L, K)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)          # (L, V)
    w = w_ref[0].astype(jnp.float32)          # (L, K) decays in (0, 1)
    u = u_ref[...].astype(jnp.float32)        # (1, K) bonus

    logw = jnp.log(jnp.maximum(w, 1e-12))
    cum = jnp.cumsum(logw, axis=0)            # (L, K): log prod_{i<=t} w_i
    ecum = cum - logw                         # exclusive: log prod_{i<t} w_i

    # recurrence semantics (matches models/ssm._wkv_step): the state used by
    # token t has seen decays w_0..w_{t-1}; w_t applies only after t's output.
    # inter-chunk: out_t += (r_t * prod_{i<t} w_i) @ S_in
    S = state_ref[...]                        # (K, V)
    r_dec = r * jnp.exp(ecum)
    inter = jax.lax.dot_general(r_dec, S, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)

    # intra-chunk, strictly lower triangular: decay over j in (i, t).
    # Pairwise-difference form: D[t,i,k] = exp(ecum_t - cum_i) with t > i,
    # where ecum_t - cum_i = sum of logs over (i, t) which is <= 0 — the
    # factored (r e^ecum)(k e^-cum) form overflows for strong decays
    # (measured: NaN at |log w| ~ 6); pairwise exponents never do.
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    i_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    tri = t_idx > i_idx                                    # (L, L)
    ldiff = ecum[:, None, :] - cum[None, :, :]             # (L, L, K), <= 0 on tri
    D = jnp.where(tri[:, :, None], jnp.exp(ldiff), 0.0)
    scores = jnp.einsum("tk,ik,tik->ti", r, k, D)          # (L, L)
    # diagonal (bonus u) term: r_t . (u * k_t) v_t
    diag = jnp.sum(r * u * k, axis=1)         # (L,)
    intra = jax.lax.dot_general(scores, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    intra = intra + diag[:, None] * v

    o_ref[0] = (inter + intra).astype(o_ref.dtype)

    # state update: S_out = diag(prod w) S_in + sum_i (prod_{j>i} w_j) k_i v_i^T
    total = cum[-1]                           # (K,)
    k_dec = k * jnp.exp(total[None, :] - cum) # decay from i+1..L
    S_new = jnp.exp(total)[:, None] * S + jax.lax.dot_general(
        k_dec, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    state_ref[...] = S_new


def rwkv_wkv_pallas(r, k, v, w, u, *, chunk: int = 32, interpret=False):
    """r/k/v/w: (BH, T, K|V), u: (BH, K) -> out (BH, T, V).

    T must be a multiple of `chunk` (ops.py pads).
    """
    BH, T, K = r.shape
    V = v.shape[2]
    assert T % chunk == 0, (T, chunk)
    n_chunks = T // chunk

    kernel = functools.partial(_wkv_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(BH, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, K), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, chunk, K), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, chunk, V), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, chunk, K), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, K), lambda h, c: (h, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, V), lambda h, c: (h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, T, V), jnp.float32),
        scratch_shapes=[pltpu.VMEM((K, V), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u)
