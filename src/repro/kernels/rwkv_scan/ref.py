"""Oracle: sequential WKV recurrence (matches models/ssm._wkv_step)."""

import jax
import jax.numpy as jnp


def wkv_ref(r, k, v, w, u):
    """r/k/w: (BH, T, K), v: (BH, T, V), u: (BH, K) -> (BH, T, V)."""
    BH, T, K = r.shape
    V = v.shape[2]

    def step(S, inp):
        rt, kt, vt, wt = inp                       # (BH, K) ...
        kv = kt[:, :, None] * vt[:, None, :]       # (BH, K, V)
        out = jnp.einsum("bk,bkv->bv", rt, S + u[:, :, None] * kv)
        S = wt[:, :, None] * S + kv
        return S, out

    seq_first = lambda t: jnp.moveaxis(t, 1, 0)
    S0 = jnp.zeros((BH, K, V), jnp.float32)
    _, outs = jax.lax.scan(
        step, S0,
        (seq_first(r.astype(jnp.float32)), seq_first(k.astype(jnp.float32)),
         seq_first(v.astype(jnp.float32)), seq_first(w.astype(jnp.float32))))
    return jnp.moveaxis(outs, 0, 1)
