"""Deterministic, host-sharded synthetic data pipelines.

Real corpora are not available offline; what matters at framework level is
the *contract*: deterministic per-(step, host-shard) batches (so a
restarted or re-sharded job replays identical data), prefetchable, and
cheap to generate.  Token streams come from a seeded per-position hash
(counter-based, so random access by step is O(1) — the property that makes
failure recovery and elastic rescale deterministic: no iterator state to
checkpoint beyond the step number).
"""

from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq: int
    global_batch: int
    seed: int = 0


def _philox_like(x: np.ndarray, seed: int) -> np.ndarray:
    """Cheap counter-based hash -> uint32 (deterministic random access)."""
    with np.errstate(over="ignore"):
        x = x.astype(np.uint64)
        x = x + np.uint64((seed * 0x9E3779B97F4A7C15) % 2**64)
        x ^= x >> np.uint64(33)
        x = x * np.uint64(0xFF51AFD7ED558CCD)
        x ^= x >> np.uint64(33)
        x = x * np.uint64(0xC4CEB9FE1A85EC53)
        x ^= x >> np.uint64(33)
    return (x & np.uint64(0xFFFFFFFF)).astype(np.uint32)


def batch_for_step(cfg: DataConfig, step: int, host_index: int = 0,
                   host_count: int = 1) -> dict:
    """The host-sharded batch for a global step (O(1) random access).

    Markov-flavored stream: token_t depends on hash(step, row, t) mixed
    with token_{t-1} so models have actual structure to learn (loss
    decreases measurably within a few hundred steps on the quickstart).
    """
    assert cfg.global_batch % host_count == 0
    rows_per_host = cfg.global_batch // host_count
    row0 = host_index * rows_per_host
    rows = np.arange(row0, row0 + rows_per_host, dtype=np.uint64)
    t = np.arange(cfg.seq, dtype=np.uint64)
    counters = (np.uint64(step) << np.uint64(40)) ^ (rows[:, None] << np.uint64(20)) ^ t[None, :]
    h = _philox_like(counters, cfg.seed)
    raw = (h % np.uint32(cfg.vocab)).astype(np.int64)
    # impose learnable structure: with p~0.75 copy a function of prev token
    gate = (h >> np.uint32(8)) % np.uint32(4)
    toks = raw.copy()
    for col in range(1, cfg.seq):
        prev = toks[:, col - 1]
        structured = (prev * 31 + 7) % cfg.vocab
        toks[:, col] = np.where(gate[:, col] > 0, structured, raw[:, col])
    return {"tokens": toks.astype(np.int32)}


def encdec_batch_for_step(cfg: DataConfig, d_model: int, enc_seq: int,
                          step: int, host_index: int = 0, host_count: int = 1):
    """Whisper-style batch: precomputed frame embeddings (frontend stub) +
    target tokens correlated with a projection of the frames."""
    base = batch_for_step(cfg, step, host_index, host_count)
    rows = cfg.global_batch // host_count
    rng = np.random.default_rng((cfg.seed << 20) ^ step ^ (host_index << 10))
    enc = rng.standard_normal((rows, enc_seq, d_model), np.float32) * 0.02
    base["enc_input"] = enc.astype(np.float32)
    return base


class Prefetcher:
    """One-step lookahead prefetch (thread-free: generation is cheap; the
    hook exists so a real loader can slot in)."""

    def __init__(self, make_batch):
        self.make_batch = make_batch
        self._next = None
        self._next_step = None

    def get(self, step: int):
        if self._next_step == step:
            out = self._next
        else:
            out = self.make_batch(step)
        self._next = self.make_batch(step + 1)
        self._next_step = step + 1
        return out
