"""Fault-tolerant checkpointing: sharded, atomic, elastic.

Designed for the 1000+-node regime (assignment: checkpoint/restart, node
failures, elastic scaling), validated at CPU scale:

* **Sharded save**: every host writes only the leaves (or leaf shards) it
  owns; here (single-host CPU) that degenerates to one writer but the
  layout — one ``.npy`` per leaf + a JSON manifest — is the multi-writer
  layout.
* **Atomic**: writes go to ``step_N.tmp/`` and are renamed into place after
  the manifest is fsynced; a crash mid-save never corrupts the latest
  checkpoint (restore scans for the newest *complete* manifest).
* **Elastic restore**: leaves are restored by *logical path*, then
  device_put with the *current* mesh's shardings — a checkpoint written on
  a 16x16 mesh restores onto 2x16x16 (or a degraded 15x16 replacement
  mesh) without conversion, because nothing mesh-specific is persisted.
* **Failure recovery loop**: repro.train.loop catches step failures,
  restores the latest checkpoint and continues — tests inject failures.
"""

from __future__ import annotations

import json
import os
import shutil
import time

import numpy as np
import jax


def _leaf_paths(tree):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in leaves:
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        out.append((name, leaf))
    return out


def save_checkpoint(ckpt_dir: str, step: int, tree, extra: dict | None = None,
                    telemetry=None) -> str:
    """Atomically persist a pytree.  Returns the final directory path.

    ``telemetry=`` (a ``repro.obs.Telemetry``) charges the save to the
    §15 counters (``ckpt.saves`` / ``ckpt.bytes_written``) and emits a
    ``ckpt`` trace event — accounting only, no behavioral change.
    """
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    manifest = {"step": step, "leaves": [], "extra": extra or {},
                "time": time.time()}
    nbytes = 0
    for name, leaf in _leaf_paths(tree):
        arr = np.asarray(jax.device_get(leaf))
        fname = name.replace("/", "__") + ".npy"
        np.save(os.path.join(tmp, fname), arr)
        nbytes += int(arr.nbytes)
        manifest["leaves"].append(
            {"name": name, "file": fname, "shape": list(arr.shape),
             "dtype": str(arr.dtype)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    if telemetry is not None and getattr(telemetry, "enabled", False):
        telemetry.counters.bump("ckpt.saves")
        telemetry.counters.bump("ckpt.bytes_written", nbytes)
        telemetry.emit("ckpt", "save", step=step,
                       n_leaves=len(manifest["leaves"]), bytes=nbytes)
    return final


def latest_step(ckpt_dir: str) -> int | None:
    """Newest step with a complete manifest (ignores torn .tmp saves)."""
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and not d.endswith(".tmp"):
            manifest = os.path.join(ckpt_dir, d, "manifest.json")
            if os.path.exists(manifest):
                steps.append(int(d[5:]))
    return max(steps) if steps else None


def read_extra(ckpt_dir: str, step: int) -> dict:
    """The manifest's ``extra`` dict alone, no leaves materialized.

    Restorers whose tree *structure* depends on saved metadata (e.g. the
    serving runtime's per-stream queue lengths, DESIGN.md §14) read this
    first, build the ``like_tree`` from it, then call
    :func:`restore_checkpoint`.
    """
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        return json.load(f)["extra"]


def restore_checkpoint(ckpt_dir: str, step: int, like_tree, shardings=None,
                       telemetry=None):
    """Restore into the structure of ``like_tree``.

    ``shardings``: optional matching pytree of NamedShardings for the
    *current* mesh — this is the elastic-rescale path (leaves are re-placed
    shard-by-shard on whatever mesh is alive now).
    ``telemetry=`` charges the restore to ``ckpt.restores`` and emits a
    ``ckpt`` trace event (accounting only).
    """
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    by_name = {l["name"]: l for l in manifest["leaves"]}

    flat, treedef = jax.tree_util.tree_flatten_with_path(like_tree)
    shard_flat = None
    if shardings is not None:
        shard_flat = jax.tree_util.tree_flatten(shardings,
                                                is_leaf=lambda x: hasattr(x, "spec"))[0]
    out = []
    for i, (path, leaf) in enumerate(flat):
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        if name not in by_name:
            raise KeyError(f"checkpoint missing leaf {name}")
        arr = np.load(os.path.join(d, by_name[name]["file"]))
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape drift for {name}: ckpt {arr.shape} vs model {leaf.shape}")
        if shard_flat is not None:
            out.append(jax.device_put(arr, shard_flat[i]))
        else:
            out.append(jax.device_put(arr.astype(leaf.dtype)))
    if telemetry is not None and getattr(telemetry, "enabled", False):
        telemetry.counters.bump("ckpt.restores")
        telemetry.emit("ckpt", "restore_tree", step=step, n_leaves=len(out))
    return jax.tree_util.tree_unflatten(treedef, out), manifest["extra"]


def prune_old(ckpt_dir: str, keep: int = 3):
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(
        int(d[5:]) for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")
        and os.path.exists(os.path.join(ckpt_dir, d, "manifest.json"))
    )
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)
