"""Core library tests: pipeline, cost model, placement, cascade, reduction.

Includes hypothesis property tests on the system invariants (assignment
deliverable c)."""

import math

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis_compat import given, settings, st

from repro.core import (
    Block,
    BlockKind,
    EFState,
    HardwareProfile,
    Pipeline,
    Roofline,
    Stage,
    cascade_flops,
    compacting_cascade,
    dequantize_int8,
    ef_compress_int8,
    ef_compress_topk,
    energy_cost,
    estimate_plan,
    linear_pipeline,
    masked_cascade,
    quantize_bits,
    quantize_int8,
    ShardingPlan,
    solve_cut,
    throughput_cost,
)


def toy_pipeline():
    return linear_pipeline("toy", [
        dict(name="src", flops=0, bytes_in=0, bytes_out=1000, kind="source"),
        dict(name="filt", flops=5e3, bytes_in=1000, bytes_out=1000,
             kind="optional", selectivity=0.2),
        dict(name="big", flops=1e6, bytes_in=1000, bytes_out=10),
    ])


def toy_profiles():
    return {
        "src": HardwareProfile("s", p_active_w=10e-6, p_leak_w=10e-6),
        "filt": HardwareProfile("f", flops_per_s=1e6, p_active_w=20e-6, p_leak_w=5e-6),
        "big": HardwareProfile("b", flops_per_s=1e6, p_active_w=100e-6, p_leak_w=20e-6),
    }


class TestPipeline:
    def test_selectivity_scales_downstream(self):
        p = toy_pipeline()
        eff = p.effective_blocks()
        assert eff[2].flops == pytest.approx(1e6 * 0.2)

    def test_configure_drops_optional_only(self):
        p = toy_pipeline()
        q = p.configure(())
        assert [b.name for b in q] == ["src", "big"]
        with pytest.raises(KeyError):
            p.configure(("big",))

    def test_cut_payload(self):
        p = toy_pipeline()
        assert p.cut_payload_bytes(p.index("filt")) == pytest.approx(1000 * 0.2)


class TestCostModel:
    def test_energy_monotone_in_comm_price(self):
        p = toy_pipeline()
        profs = toy_profiles()
        cheap = energy_cost(p, profs, HardwareProfile("l", joules_per_byte=1e-9), "filt")
        dear = energy_cost(p, profs, HardwareProfile("l", joules_per_byte=1e-6), "filt")
        assert dear.total_w > cheap.total_w
        assert dear.compute_w == pytest.approx(cheap.compute_w)

    def test_throughput_bottleneck(self):
        p = toy_pipeline()
        profs = toy_profiles()
        rep = throughput_cost(p, profs, HardwareProfile("l", link_bw=1e6), "big")
        # big: 1e6 flops * 0.2 sel / 1e6 flops/s = 0.2 s -> 5 fps
        assert rep.compute_fps == pytest.approx(5.0, rel=0.05)

    def test_roofline_terms_and_dominance(self):
        r = Roofline("x", flops=197e12 * 256, hbm_bytes=0, collective_bytes=0,
                     n_chips=256, model_flops=197e12 * 256)
        assert r.compute_s == pytest.approx(1.0)
        assert r.dominant == "compute"
        assert r.roofline_fraction == pytest.approx(1.0)


class TestSolver:
    def test_solver_matches_bruteforce(self):
        p = toy_pipeline()
        profs = toy_profiles()
        link = HardwareProfile("l", joules_per_byte=1e-7)
        sol = solve_cut(p, profs, link, regime="energy")
        best = min(sol.all_reports, key=lambda r: r.total_w)
        assert sol.report.total_w == pytest.approx(best.total_w)

    @given(st.floats(min_value=1e-10, max_value=1e-4))
    @settings(max_examples=25, deadline=None)
    def test_solver_never_beaten(self, jpb):
        """Property: the solver's choice is optimal for any link price."""
        p = toy_pipeline()
        profs = toy_profiles()
        link = HardwareProfile("l", joules_per_byte=jpb)
        sol = solve_cut(p, profs, link, regime="energy")
        for rep in sol.all_reports:
            assert sol.report.total_w <= rep.total_w + 1e-15

    def test_plan_estimator_prefers_fsdp_for_small_dense(self):
        kw = dict(name="yi", params=8.8e9, active_params=8.8e9,
                  layer_flops=2 * 8.8e9 * 1_048_576, train=True,
                  tokens=1_048_576, d_model=4096, seq=4096, batch=256,
                  n_layers=48)
        tp = estimate_plan(ShardingPlan("tp", data=16, tensor=16), **kw)
        fsdp = estimate_plan(ShardingPlan("fsdp", data=16, fsdp=16), **kw)
        assert fsdp.roofline.collective_s < tp.roofline.collective_s


class TestCascade:
    def _stages(self):
        return [Stage(lambda x: x[:, 0], 0.4, "a"),
                Stage(lambda x: x[:, 1], 0.6, "b")]

    def test_masked_semantics(self):
        items = jax.random.uniform(jax.random.PRNGKey(0), (128, 2))
        r = masked_cascade(self._stages(), items)
        expect = np.asarray((items[:, 0] >= 0.4) & (items[:, 1] >= 0.6))
        assert np.array_equal(np.asarray(r.mask), expect)

    def test_compacting_matches_masked_with_capacity(self):
        items = jax.random.uniform(jax.random.PRNGKey(1), (128, 2))
        m = masked_cascade(self._stages(), items)
        c = compacting_cascade(self._stages(), items, capacities=[128, 128])
        assert np.array_equal(np.asarray(m.mask), np.asarray(c.mask))
        assert int(c.dropped.sum()) == 0

    def test_capacity_drops_are_counted(self):
        items = jax.random.uniform(jax.random.PRNGKey(2), (256, 2))
        m = masked_cascade(self._stages(), items)
        cap = max(1, int(m.n_survivors[0]) - 5)
        c = compacting_cascade(self._stages(), items, capacities=[256, cap])
        assert int(c.dropped[1]) >= 0
        assert int(c.mask.sum()) <= int(m.mask.sum())

    @given(st.lists(st.floats(0.05, 1.0), min_size=1, max_size=5),
           st.lists(st.floats(1.0, 100.0), min_size=1, max_size=5))
    @settings(max_examples=30, deadline=None)
    def test_cascade_flops_monotone(self, sels, flops):
        n = min(len(sels), len(flops))
        sels, flops = sels[:n], flops[:n]
        base = cascade_flops(flops, sels)
        cheaper = cascade_flops(flops, [s * 0.5 for s in sels])
        assert cheaper <= base + 1e-9


class TestReduction:
    @given(st.integers(0, 2**31 - 1))
    @settings(max_examples=20, deadline=None)
    def test_int8_bounded_error(self, seed):
        x = jax.random.normal(jax.random.PRNGKey(seed), (512,))
        q, s = quantize_int8(x, block=128)
        deq = dequantize_int8(q, s, x.shape)
        # per-block error bounded by scale/2 (round-to-nearest)
        err = jnp.abs(deq - x)
        bound = jnp.repeat(s.reshape(-1), 128)[:512] * 0.51
        assert bool(jnp.all(err <= bound))

    def test_bit_knee_ordering(self):
        x = jax.random.normal(jax.random.PRNGKey(3), (4096,))
        errs = {b: float(jnp.linalg.norm(quantize_bits(x, b) - x)) for b in (16, 8, 4)}
        assert errs[16] < errs[8] < errs[4]

    def test_error_feedback_bounded(self):
        """EF residual stays bounded over many rounds (no drift)."""
        x = jax.random.normal(jax.random.PRNGKey(4), (1024,))
        st_ = EFState.init(x)
        norms = []
        for i in range(20):
            xi = x * (1 + 0.01 * i)
            _, _, st_ = ef_compress_int8(xi, st_)
            norms.append(float(jnp.linalg.norm(st_.residual)))
        assert max(norms) < 0.1 * float(jnp.linalg.norm(x))

    def test_topk_ef_converges_on_constant_input(self):
        """With EF, repeated top-k transmission sums to the true value."""
        x = jax.random.normal(jax.random.PRNGKey(5), (256,))
        st_ = EFState.init(x)
        acc = jnp.zeros_like(x)
        for _ in range(40):
            _, dense, st_ = ef_compress_topk(x, st_, k_fraction=0.1)
            acc += dense
        assert float(jnp.linalg.norm(acc / 40 - x)) < 0.2 * float(jnp.linalg.norm(x))


class TestReductionWireCodec:
    """Satellite coverage: core/reduction invariants exercised through
    tests/hypothesis_compat (wire-codec roundtrip exactness, error-feedback
    convergence, and the 16/8/4-bit knee shape)."""

    @given(st.integers(0, 2**31 - 1), st.integers(1, 4))
    @settings(max_examples=15, deadline=None)
    def test_wire_codec_int8_roundtrip_is_quantize_int8(self, seed, rows):
        """For any input, the int8 wire codec's decode equals
        dequantize_int8(quantize_int8(x)) bit-for-bit — the shared-
        semantics contract between the kernel package and core/reduction."""
        from repro.core.reduction import dequantize_int8, quantize_int8
        from repro.kernels.wire_codec.ops import wire_roundtrip

        # both sides under jit: the codec always runs inside the offload
        # executors' jit regions, and XLA's constant-divisor rewrite makes
        # eager-vs-jit scales differ by 1 ulp — compile-context parity is
        # the real production contract
        @jax.jit
        def reduction_roundtrip(x):
            q, s = quantize_int8(x, block=256)
            return dequantize_int8(q, s, x.shape)

        x = jax.random.normal(jax.random.PRNGKey(seed), (rows, 173)) * 5.0
        deq = reduction_roundtrip(x)
        y = wire_roundtrip(x, bits=8, use_pallas=False)
        assert np.array_equal(np.asarray(deq), np.asarray(y))

    @given(st.integers(0, 2**31 - 1))
    @settings(max_examples=10, deadline=None)
    def test_error_feedback_time_average_converges(self, seed):
        """EF makes int8 compression unbiased over time: the running mean
        of transmitted (dequantized) values converges to the true signal
        far beyond one-shot quantization error."""
        x = jax.random.normal(jax.random.PRNGKey(seed), (512,))
        state = EFState.init(x)
        acc = jnp.zeros_like(x)
        n = 24
        for _ in range(n):
            _, deq, state = ef_compress_int8(x, state, block=128)
            acc = acc + deq
        mean_err = float(jnp.linalg.norm(acc / n - x))
        one_shot = float(jnp.linalg.norm(
            dequantize_int8(*quantize_int8(x, block=128), x.shape) - x))
        assert mean_err < one_shot / 4
        # and the residual itself stays bounded by one quantization step
        assert float(jnp.abs(state.residual).max()) < 0.2

    @given(st.integers(0, 2**31 - 1))
    @settings(max_examples=10, deadline=None)
    def test_bit_knee_shape(self, seed):
        """§III-A knee: relative error is negligible at 16/8 bits and
        jumps past the knee at 4 — for any input distribution scale."""
        x = jax.random.normal(jax.random.PRNGKey(seed), (4096,))
        nrm = float(jnp.linalg.norm(x))
        rel = {b: float(jnp.linalg.norm(quantize_bits(x, b) - x)) / nrm
               for b in (16, 8, 4)}
        assert rel[16] < rel[8] < rel[4]
        assert rel[8] < 0.02                 # 8-bit: within task tolerance
        assert rel[4] > 0.05                 # 4-bit: past the knee
        assert rel[16] < 1e-3


class TestSolveCutTieBreak:
    """Regression for the solve_cut tie-break wart: the key must use the
    *configured* pipeline's cut index, pinning the documented "offload as
    early as bandwidth allows" tie-break in both regimes."""

    def _tied_pipeline(self):
        # src -> filt (optional, free, sel=1) -> a -> b: every cut ships
        # identical bytes, compute is free => all configs tie exactly.
        return linear_pipeline("tied", [
            dict(name="src", flops=0, bytes_in=0, bytes_out=1000,
                 kind="source"),
            dict(name="filt", flops=0.0, bytes_in=1000, bytes_out=1000,
                 kind="optional", selectivity=1.0),
            dict(name="a", flops=0.0, bytes_in=1000, bytes_out=1000),
            dict(name="b", flops=0.0, bytes_in=1000, bytes_out=1000),
        ])

    def _free_profiles(self):
        free = HardwareProfile("free", flops_per_s=1e12)
        return {"src": HardwareProfile("s"), "filt": free, "a": free,
                "b": free}

    def test_throughput_tie_breaks_to_earliest_cut(self):
        p = self._tied_pipeline()
        sol = solve_cut(p, self._free_profiles(),
                        HardwareProfile("l", link_bw=1e4), regime="throughput")
        # all configs bottleneck on the same 10 fps link; the documented
        # tie-break offloads as early as possible
        assert sol.cut_after == "src"
        assert sol.pipeline.index(sol.cut_after) == 0

    def test_energy_tie_breaks_to_fewest_on_node_blocks(self):
        p = self._tied_pipeline()
        profs = self._free_profiles()
        sol = solve_cut(p, profs, HardwareProfile("l", joules_per_byte=1e-9),
                        regime="energy", duties={n: 0.0 for n in
                                                 ("src", "filt", "a", "b")})
        assert sol.cut_after == "src"

    def test_tie_break_uses_configured_index(self):
        """Among tied optima the returned configuration must minimize the
        CONFIGURED cut index (= on-node block count), not the unconfigured
        one — the exact wart fixed in placement.py."""
        p = self._tied_pipeline()
        profs = self._free_profiles()
        link = HardwareProfile("l", link_bw=1e4)
        sol = solve_cut(p, profs, link, regime="throughput")
        tied = [r for r in sol.all_reports
                if r.fps == pytest.approx(-(-sol.report.fps))]
        assert len(tied) > 1                   # the tie is real
        chosen_idx = sol.pipeline.index(sol.cut_after)
        for rep in tied:
            # no tied config has fewer on-node blocks than the winner
            name = rep.config_name.split("cut=")[1]
            subset = rep.config_name.split("|")[0]
            cfg = p.configure(() if subset == "none"
                              else tuple(subset.split("+")))
            assert chosen_idx <= cfg.index(name)
