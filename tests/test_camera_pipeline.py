"""Camera substrate tests: funnel behaviour, calibration constraints,
per-block correctness, BSSA quality direction."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis_compat import given, settings, st

from repro.camera.bssa import (
    GridSpec, _grid_coords, blur_121, bssa_depth, bssa_depth_ref, ms_ssim,
    refine, rough_disparity, rough_disparity_ref, slice_grid, splat)
from repro.camera.face_nn import (
    classification_error, forward_float, forward_lut, forward_quantized,
    make_sigmoid_lut, nn_power, train_face_nn)
from repro.camera.integral import integral_image, streaming_integral_rows, window_sum
from repro.camera.motion import motion_mask
from repro.camera.pipelines import (
    FAWorkloadStats, calibrate_fa, fa_pipeline, fa_profiles)
from repro.camera.synthetic import face_dataset, security_video, stereo_pair
from repro.core.costmodel import energy_cost


class TestIntegral:
    def test_streaming_equals_cumsum(self):
        img = jnp.asarray(np.random.default_rng(0).random((31, 47), np.float32))
        np.testing.assert_allclose(np.asarray(integral_image(img)),
                                   np.asarray(streaming_integral_rows(img)),
                                   rtol=1e-5, atol=1e-4)

    def test_window_sum(self):
        img = jnp.arange(20.0).reshape(4, 5)
        ii = integral_image(img)
        assert float(window_sum(ii, 1, 1, 2, 3)) == pytest.approx(
            float(jnp.sum(img[1:3, 1:4])))

    def test_two_row_buffer_claim(self):
        """Paper: streaming uses <1 kB (two rows) vs 57 kB full frame —
        the WISPCam numbers."""
        w = 176
        assert 2 * w * 2 < 1024            # two 16-bit rows < 1 kB
        assert 176 * 144 * 2 > 45 * 1024   # full-frame integral buffer ~50-57 kB


class TestMotion:
    def test_static_scene_passes_nothing(self):
        frames = np.ones((10, 32, 32), np.float32) * 0.5
        mask, _ = motion_mask(jnp.asarray(frames), threshold=0.004)
        assert int(mask.sum()) == 0

    def test_moving_scene_detected(self):
        frames, truth = security_video(seed=5)
        mask, _ = motion_mask(jnp.asarray(frames), threshold=0.004)
        moving = np.array([t["moving"] for t in truth])
        # every true motion frame must pass (filters must not drop signal)
        assert int((moving & ~np.asarray(mask)).sum()) == 0


class TestFaceNN:
    @pytest.fixture(scope="class")
    def trained(self):
        X, y, _ = face_dataset(n_per_class=250, seed=1)
        ntr = int(0.9 * len(X))
        nn = train_face_nn(X[:ntr], y[:ntr], steps=1500)
        return nn, X[ntr:], y[ntr:]

    def test_topology_is_400_8_1(self, trained):
        nn, _, _ = trained
        assert nn.topology == (400, 8, 1)

    def test_lut_negligible(self, trained):
        nn, Xte, yte = trained
        lut, meta = make_sigmoid_lut()
        e_f = classification_error(forward_float(nn, jnp.asarray(Xte)), yte)
        e_l = classification_error(forward_lut(nn, jnp.asarray(Xte), lut, meta), yte)
        assert abs(e_f - e_l) <= 0.01     # paper: negligible

    def test_bit_knee(self, trained):
        nn, Xte, yte = trained
        lut, meta = make_sigmoid_lut()
        errs = {b: classification_error(
            forward_quantized(nn, jnp.asarray(Xte), b, lut, meta), yte)
            for b in (16, 8, 4)}
        e_f = classification_error(forward_float(nn, jnp.asarray(Xte)), yte)
        assert errs[8] - e_f <= 0.015     # paper: ~0.4% loss at 8-bit
        assert errs[4] >= errs[8]         # 4-bit at/past the knee

    def test_power_anchor(self):
        assert nn_power(8) == pytest.approx(393e-6, rel=1e-6)
        assert 1 - nn_power(8) / nn_power(16) == pytest.approx(0.41, abs=0.02)


class TestCalibration:
    def test_constraints_hold(self):
        stats = FAWorkloadStats()
        cal = calibrate_fa(stats)
        pipe = fa_pipeline(stats)
        profiles = fa_profiles()
        profiles["nn"] = cal.nn_profile()
        duties = {"sensor": 1.0, "motion": 1.0, "vj": 0.0, "nn": 1.0}
        a = energy_cost(pipe.configure(("motion", "vj")), profiles,
                        cal.rf_link(), "vj", duties=duties).total_w
        b = energy_cost(pipe.configure(("motion", "vj")), profiles,
                        cal.rf_link(), "nn", duties=duties).total_w
        assert b / a == pytest.approx(1.28, abs=0.02)   # paper's +28%

    def test_ladder_ordering(self):
        """raw > motion-only > motion+vj (the Fig. 8 shape)."""
        stats = FAWorkloadStats()
        cal = calibrate_fa(stats)
        pipe = fa_pipeline(stats)
        profiles = fa_profiles()
        profiles["nn"] = cal.nn_profile()
        duties = {"sensor": 1.0, "motion": 1.0, "vj": 0.0, "nn": 1.0}
        raw = energy_cost(pipe.configure(()), profiles, cal.rf_link(),
                          "sensor", duties=duties).total_w
        mo = energy_cost(pipe.configure(("motion",)), profiles, cal.rf_link(),
                         "motion", duties=duties).total_w
        mv = energy_cost(pipe.configure(("motion", "vj")), profiles,
                         cal.rf_link(), "vj", duties=duties).total_w
        assert raw > mo > mv


class TestBSSA:
    def test_splat_slice_roundtrip_smooth_field(self):
        """Splatting a smooth field and slicing it back preserves it."""
        left, _, _ = stereo_pair(h=64, w=80, seed=1)
        field = jnp.asarray(np.tile(np.linspace(0, 10, 80), (64, 1)).astype(np.float32))
        spec = GridSpec(sigma_spatial=8)
        gv, gw = splat(jnp.asarray(left), field, spec)
        out = slice_grid(gv, gw, jnp.asarray(left), spec)
        assert float(jnp.mean(jnp.abs(out - field))) < 1.0

    def test_blur_is_smoothing(self):
        g = jax.random.normal(jax.random.PRNGKey(0), (16, 16, 9))
        blurred = blur_121(g)
        assert float(jnp.var(blurred)) < float(jnp.var(g))

    def test_refinement_improves_depth(self):
        left, right, gt = stereo_pair(h=96, w=128, seed=3)
        rough = rough_disparity(jnp.asarray(left), jnp.asarray(right), 12)
        refined = bssa_depth(jnp.asarray(left), jnp.asarray(right),
                             GridSpec(sigma_spatial=8), max_disp=12, n_iters=8)
        def nerr(d):
            d = np.asarray(d)
            dn = (d - d.min()) / (np.ptp(d) + 1e-9)
            gn = (gt - gt.min()) / (np.ptp(gt) + 1e-9)
            return float(np.mean(np.abs(dn - gn)))
        assert nerr(refined) < nerr(rough)  # edge-aware smoothing helps

    def test_msssim_identity(self):
        a = jnp.asarray(np.random.default_rng(0).random((64, 64), np.float32))
        assert ms_ssim(a, a) > 0.99


class TestBSSAFusedParity:
    """The fused cost-volume path vs the seed loop oracles (PR acceptance:
    same argmin disparities up to fp-borderline ties, depth within tol)."""

    def test_rough_fused_equals_seed_loop(self):
        left, right, _ = stereo_pair(h=72, w=96, seed=4)
        a = np.asarray(rough_disparity(jnp.asarray(left), jnp.asarray(right), 12))
        b = np.asarray(rough_disparity_ref(jnp.asarray(left), jnp.asarray(right), 12))
        assert (a == b).mean() >= 0.999

    @pytest.mark.parametrize("chunk", [1, 4, 64])
    def test_rough_chunk_sizes_agree(self, chunk):
        """chunk=1 (pure running-min scan) through chunk>=D+1 (the pure
        one-shot stack) are the same computation."""
        left, right, _ = stereo_pair(h=48, w=64, seed=5)
        l, r = jnp.asarray(left), jnp.asarray(right)
        a = np.asarray(rough_disparity(l, r, 12, hypothesis_chunk=chunk))
        b = np.asarray(rough_disparity_ref(l, r, 12))
        assert (a == b).mean() >= 0.999

    def test_rough_pallas_integral_matches(self):
        """interpret=True routes the cost-volume integral through the
        Pallas streaming kernel — same winners up to fp-borderline ties
        (the blocked integral carries a ~1e-3 association tolerance, so the
        pair must have well-separated SAD minima: iid noise, constant
        shift; smooth low-contrast regions would tie)."""
        rng = np.random.default_rng(7)
        full = rng.random((40, 60), np.float32)
        left = jnp.asarray(full[:, :48])
        right = jnp.asarray(full[:, 3:51])     # right[x] = left[x+3]
        a = np.asarray(rough_disparity(left, right, 8, interpret=True))
        b = np.asarray(rough_disparity_ref(left, right, 8))
        inner = (a == b)[2:-2, 10:-10]         # clamped borders can tie
        assert inner.mean() >= 0.99

    def test_bssa_depth_fused_matches_oracle(self):
        left, right, _ = stereo_pair(h=64, w=80, seed=6)
        spec = GridSpec(sigma_spatial=8)
        a = bssa_depth(jnp.asarray(left), jnp.asarray(right), spec,
                       max_disp=10, n_iters=6)
        b = bssa_depth_ref(jnp.asarray(left), jnp.asarray(right), spec,
                           max_disp=10, n_iters=6)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


class TestBSSAProperties:
    """Property tests for the bilateral-grid operators (satellite: splat/
    slice adjointness + mass conservation, blur normalization, rough
    disparity shift recovery)."""

    @given(st.integers(24, 56), st.integers(24, 56))
    @settings(max_examples=6, deadline=None)
    def test_splat_mass_conservation(self, h, w):
        rng = np.random.default_rng(100 * h + w)
        img = jnp.asarray(rng.random((h, w), np.float32))
        vals = jnp.asarray(rng.random((h, w), np.float32))
        gv, gw = splat(img, vals, GridSpec(sigma_spatial=8))
        assert float(gw.sum()) == pytest.approx(h * w, rel=1e-5)
        assert float(gv.sum()) == pytest.approx(float(vals.sum()), rel=1e-4)

    @given(st.integers(24, 48), st.integers(24, 48))
    @settings(max_examples=4, deadline=None)
    def test_splat_nearest_slice_adjoint(self, h, w):
        """<splat(v), G> == <v, G[nearest vertex]> for any grid field G:
        splat is exactly the adjoint of nearest-vertex sampling."""
        rng = np.random.default_rng(37 * h + w)
        spec = GridSpec(sigma_spatial=8)
        img = jnp.asarray(rng.random((h, w), np.float32))
        vals = jnp.asarray(rng.random((h, w), np.float32))
        gv, _ = splat(img, vals, spec)
        G = jnp.asarray(rng.random(gv.shape, np.float32))
        gy, gx, gr = gv.shape
        cy, cx, cr = _grid_coords(img, spec)
        iy = jnp.clip(jnp.round(cy).astype(jnp.int32), 0, gy - 1)
        ix = jnp.clip(jnp.round(cx).astype(jnp.int32), 0, gx - 1)
        ir = jnp.clip(jnp.round(cr).astype(jnp.int32), 0, gr - 1)
        lhs = float(jnp.sum(gv * G))
        rhs = float(jnp.sum(vals.reshape(-1) * G[iy, ix, ir]))
        assert lhs == pytest.approx(rhs, rel=1e-4)

    def test_slice_partition_of_unity(self):
        """Slicing a constant grid returns the constant everywhere — the
        trilinear weights normalize out."""
        spec = GridSpec(sigma_spatial=8)
        img = jnp.asarray(np.random.default_rng(0).random((48, 64), np.float32))
        gy, gx, gr = spec.dims(48, 64)
        gw = jnp.ones((gy, gx, gr))
        out = slice_grid(3.5 * gw, gw, img, spec)
        np.testing.assert_allclose(np.asarray(out), 3.5, atol=1e-5)

    @given(st.integers(6, 24), st.integers(6, 24))
    @settings(max_examples=6, deadline=None)
    def test_blur_121_weight_normalization(self, gy, gx):
        """DC gain 1 at every vertex (weights sum to 1, edges included) and
        exact mass conservation for interior-supported fields."""
        ones = jnp.ones((gy, gx, 9))
        np.testing.assert_allclose(np.asarray(blur_121(ones)), 1.0, atol=1e-6)
        rng = np.random.default_rng(13 * gy + gx)
        core = np.zeros((gy, gx, 9), np.float32)
        core[1:-1, 1:-1, 1:-1] = rng.random((gy - 2, gx - 2, 7))
        blurred = blur_121(jnp.asarray(core))
        assert float(blurred.sum()) == pytest.approx(float(core.sum()), rel=1e-5)

    @given(st.integers(2, 9))
    @settings(max_examples=6, deadline=None)
    def test_rough_disparity_recovers_injected_shift(self, s):
        """A pair built with right[x] = left[x+s] (the module's disparity
        convention) is recovered exactly away from the borders."""
        rng = np.random.default_rng(s)
        h, w, max_disp, patch = 40, 120, 12, 5
        base = rng.random((h, w + 16)).astype(np.float32)
        k = np.ones(7) / 7          # smooth so neighboring lags separate
        full = np.stack([np.convolve(row, k, "same") for row in base])
        left = jnp.asarray(full[:, :w])
        right = jnp.asarray(full[:, s:s + w])
        d = np.asarray(rough_disparity(left, right, max_disp, patch))
        pad = patch // 2
        inner = d[pad:-pad, max_disp + pad:-(max_disp + pad)]
        assert (inner == s).mean() >= 0.98
