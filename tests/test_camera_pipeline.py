"""Camera substrate tests: funnel behaviour, calibration constraints,
per-block correctness, BSSA quality direction."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.camera.bssa import (
    GridSpec, blur_121, bssa_depth, ms_ssim, refine, rough_disparity, slice_grid,
    splat)
from repro.camera.face_nn import (
    classification_error, forward_float, forward_lut, forward_quantized,
    make_sigmoid_lut, nn_power, train_face_nn)
from repro.camera.integral import integral_image, streaming_integral_rows, window_sum
from repro.camera.motion import motion_mask
from repro.camera.pipelines import (
    FAWorkloadStats, calibrate_fa, fa_pipeline, fa_profiles)
from repro.camera.synthetic import face_dataset, security_video, stereo_pair
from repro.core.costmodel import energy_cost


class TestIntegral:
    def test_streaming_equals_cumsum(self):
        img = jnp.asarray(np.random.default_rng(0).random((31, 47), np.float32))
        np.testing.assert_allclose(np.asarray(integral_image(img)),
                                   np.asarray(streaming_integral_rows(img)),
                                   rtol=1e-5, atol=1e-4)

    def test_window_sum(self):
        img = jnp.arange(20.0).reshape(4, 5)
        ii = integral_image(img)
        assert float(window_sum(ii, 1, 1, 2, 3)) == pytest.approx(
            float(jnp.sum(img[1:3, 1:4])))

    def test_two_row_buffer_claim(self):
        """Paper: streaming uses <1 kB (two rows) vs 57 kB full frame —
        the WISPCam numbers."""
        w = 176
        assert 2 * w * 2 < 1024            # two 16-bit rows < 1 kB
        assert 176 * 144 * 2 > 45 * 1024   # full-frame integral buffer ~50-57 kB


class TestMotion:
    def test_static_scene_passes_nothing(self):
        frames = np.ones((10, 32, 32), np.float32) * 0.5
        mask, _ = motion_mask(jnp.asarray(frames), threshold=0.004)
        assert int(mask.sum()) == 0

    def test_moving_scene_detected(self):
        frames, truth = security_video(seed=5)
        mask, _ = motion_mask(jnp.asarray(frames), threshold=0.004)
        moving = np.array([t["moving"] for t in truth])
        # every true motion frame must pass (filters must not drop signal)
        assert int((moving & ~np.asarray(mask)).sum()) == 0


class TestFaceNN:
    @pytest.fixture(scope="class")
    def trained(self):
        X, y, _ = face_dataset(n_per_class=250, seed=1)
        ntr = int(0.9 * len(X))
        nn = train_face_nn(X[:ntr], y[:ntr], steps=1500)
        return nn, X[ntr:], y[ntr:]

    def test_topology_is_400_8_1(self, trained):
        nn, _, _ = trained
        assert nn.topology == (400, 8, 1)

    def test_lut_negligible(self, trained):
        nn, Xte, yte = trained
        lut, meta = make_sigmoid_lut()
        e_f = classification_error(forward_float(nn, jnp.asarray(Xte)), yte)
        e_l = classification_error(forward_lut(nn, jnp.asarray(Xte), lut, meta), yte)
        assert abs(e_f - e_l) <= 0.01     # paper: negligible

    def test_bit_knee(self, trained):
        nn, Xte, yte = trained
        lut, meta = make_sigmoid_lut()
        errs = {b: classification_error(
            forward_quantized(nn, jnp.asarray(Xte), b, lut, meta), yte)
            for b in (16, 8, 4)}
        e_f = classification_error(forward_float(nn, jnp.asarray(Xte)), yte)
        assert errs[8] - e_f <= 0.015     # paper: ~0.4% loss at 8-bit
        assert errs[4] >= errs[8]         # 4-bit at/past the knee

    def test_power_anchor(self):
        assert nn_power(8) == pytest.approx(393e-6, rel=1e-6)
        assert 1 - nn_power(8) / nn_power(16) == pytest.approx(0.41, abs=0.02)


class TestCalibration:
    def test_constraints_hold(self):
        stats = FAWorkloadStats()
        cal = calibrate_fa(stats)
        pipe = fa_pipeline(stats)
        profiles = fa_profiles()
        profiles["nn"] = cal.nn_profile()
        duties = {"sensor": 1.0, "motion": 1.0, "vj": 0.0, "nn": 1.0}
        a = energy_cost(pipe.configure(("motion", "vj")), profiles,
                        cal.rf_link(), "vj", duties=duties).total_w
        b = energy_cost(pipe.configure(("motion", "vj")), profiles,
                        cal.rf_link(), "nn", duties=duties).total_w
        assert b / a == pytest.approx(1.28, abs=0.02)   # paper's +28%

    def test_ladder_ordering(self):
        """raw > motion-only > motion+vj (the Fig. 8 shape)."""
        stats = FAWorkloadStats()
        cal = calibrate_fa(stats)
        pipe = fa_pipeline(stats)
        profiles = fa_profiles()
        profiles["nn"] = cal.nn_profile()
        duties = {"sensor": 1.0, "motion": 1.0, "vj": 0.0, "nn": 1.0}
        raw = energy_cost(pipe.configure(()), profiles, cal.rf_link(),
                          "sensor", duties=duties).total_w
        mo = energy_cost(pipe.configure(("motion",)), profiles, cal.rf_link(),
                         "motion", duties=duties).total_w
        mv = energy_cost(pipe.configure(("motion", "vj")), profiles,
                         cal.rf_link(), "vj", duties=duties).total_w
        assert raw > mo > mv


class TestBSSA:
    def test_splat_slice_roundtrip_smooth_field(self):
        """Splatting a smooth field and slicing it back preserves it."""
        left, _, _ = stereo_pair(h=64, w=80, seed=1)
        field = jnp.asarray(np.tile(np.linspace(0, 10, 80), (64, 1)).astype(np.float32))
        spec = GridSpec(sigma_spatial=8)
        gv, gw = splat(jnp.asarray(left), field, spec)
        out = slice_grid(gv, gw, jnp.asarray(left), spec)
        assert float(jnp.mean(jnp.abs(out - field))) < 1.0

    def test_blur_is_smoothing(self):
        g = jax.random.normal(jax.random.PRNGKey(0), (16, 16, 9))
        blurred = blur_121(g)
        assert float(jnp.var(blurred)) < float(jnp.var(g))

    def test_refinement_improves_depth(self):
        left, right, gt = stereo_pair(h=96, w=128, seed=3)
        rough = rough_disparity(jnp.asarray(left), jnp.asarray(right), 12)
        refined = bssa_depth(jnp.asarray(left), jnp.asarray(right),
                             GridSpec(sigma_spatial=8), max_disp=12, n_iters=8)
        def nerr(d):
            d = np.asarray(d)
            dn = (d - d.min()) / (np.ptp(d) + 1e-9)
            gn = (gt - gt.min()) / (np.ptp(gt) + 1e-9)
            return float(np.mean(np.abs(dn - gn)))
        assert nerr(refined) < nerr(rough)  # edge-aware smoothing helps

    def test_msssim_identity(self):
        a = jnp.asarray(np.random.default_rng(0).random((64, 64), np.float32))
        assert ms_ssim(a, a) > 0.99
