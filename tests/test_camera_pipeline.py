"""Camera substrate tests: funnel behaviour, calibration constraints,
per-block correctness, BSSA quality direction."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis_compat import given, settings, st

from repro.camera.bssa import (
    GridSpec, _grid_coords, blur_121, bssa_depth, bssa_depth_ref, ms_ssim,
    refine, rough_disparity, rough_disparity_ref, slice_grid, splat)
from repro.camera.face_nn import (
    classification_error, forward_float, forward_lut, forward_quantized,
    make_sigmoid_lut, nn_power, train_face_nn)
from repro.camera.integral import integral_image, streaming_integral_rows, window_sum
from repro.camera.motion import motion_mask
from repro.camera.pipelines import (
    FAWorkloadStats, FaceAuthExecutor, calibrate_fa, fa_pipeline, fa_profiles)
from repro.camera.synthetic import face_dataset, security_video, stereo_pair
from repro.core.costmodel import energy_cost


class TestIntegral:
    def test_streaming_equals_cumsum(self):
        img = jnp.asarray(np.random.default_rng(0).random((31, 47), np.float32))
        np.testing.assert_allclose(np.asarray(integral_image(img)),
                                   np.asarray(streaming_integral_rows(img)),
                                   rtol=1e-5, atol=1e-4)

    def test_window_sum(self):
        img = jnp.arange(20.0).reshape(4, 5)
        ii = integral_image(img)
        assert float(window_sum(ii, 1, 1, 2, 3)) == pytest.approx(
            float(jnp.sum(img[1:3, 1:4])))

    def test_two_row_buffer_claim(self):
        """Paper: streaming uses <1 kB (two rows) vs 57 kB full frame —
        the WISPCam numbers."""
        w = 176
        assert 2 * w * 2 < 1024            # two 16-bit rows < 1 kB
        assert 176 * 144 * 2 > 45 * 1024   # full-frame integral buffer ~50-57 kB


class TestMotion:
    def test_static_scene_passes_nothing(self):
        frames = np.ones((10, 32, 32), np.float32) * 0.5
        mask, _ = motion_mask(jnp.asarray(frames), threshold=0.004)
        assert int(mask.sum()) == 0

    def test_moving_scene_detected(self):
        frames, truth = security_video(seed=5)
        mask, _ = motion_mask(jnp.asarray(frames), threshold=0.004)
        moving = np.array([t["moving"] for t in truth])
        # every true motion frame must pass (filters must not drop signal)
        assert int((moving & ~np.asarray(mask)).sum()) == 0


class TestFaceNN:
    @pytest.fixture(scope="class")
    def trained(self):
        X, y, _ = face_dataset(n_per_class=250, seed=1)
        ntr = int(0.9 * len(X))
        nn = train_face_nn(X[:ntr], y[:ntr], steps=1500)
        return nn, X[ntr:], y[ntr:]

    def test_topology_is_400_8_1(self, trained):
        nn, _, _ = trained
        assert nn.topology == (400, 8, 1)

    def test_lut_negligible(self, trained):
        nn, Xte, yte = trained
        lut, meta = make_sigmoid_lut()
        e_f = classification_error(forward_float(nn, jnp.asarray(Xte)), yte)
        e_l = classification_error(forward_lut(nn, jnp.asarray(Xte), lut, meta), yte)
        assert abs(e_f - e_l) <= 0.01     # paper: negligible

    def test_bit_knee(self, trained):
        nn, Xte, yte = trained
        lut, meta = make_sigmoid_lut()
        errs = {b: classification_error(
            forward_quantized(nn, jnp.asarray(Xte), b, lut, meta), yte)
            for b in (16, 8, 4)}
        e_f = classification_error(forward_float(nn, jnp.asarray(Xte)), yte)
        assert errs[8] - e_f <= 0.015     # paper: ~0.4% loss at 8-bit
        assert errs[4] >= errs[8]         # 4-bit at/past the knee

    def test_power_anchor(self):
        assert nn_power(8) == pytest.approx(393e-6, rel=1e-6)
        assert 1 - nn_power(8) / nn_power(16) == pytest.approx(0.41, abs=0.02)


class TestCalibration:
    def test_constraints_hold(self):
        stats = FAWorkloadStats()
        cal = calibrate_fa(stats)
        pipe = fa_pipeline(stats)
        profiles = fa_profiles()
        profiles["nn"] = cal.nn_profile()
        duties = {"sensor": 1.0, "motion": 1.0, "vj": 0.0, "nn": 1.0}
        a = energy_cost(pipe.configure(("motion", "vj")), profiles,
                        cal.rf_link(), "vj", duties=duties).total_w
        b = energy_cost(pipe.configure(("motion", "vj")), profiles,
                        cal.rf_link(), "nn", duties=duties).total_w
        assert b / a == pytest.approx(1.28, abs=0.02)   # paper's +28%

    def test_ladder_ordering(self):
        """raw > motion-only > motion+vj (the Fig. 8 shape)."""
        stats = FAWorkloadStats()
        cal = calibrate_fa(stats)
        pipe = fa_pipeline(stats)
        profiles = fa_profiles()
        profiles["nn"] = cal.nn_profile()
        duties = {"sensor": 1.0, "motion": 1.0, "vj": 0.0, "nn": 1.0}
        raw = energy_cost(pipe.configure(()), profiles, cal.rf_link(),
                          "sensor", duties=duties).total_w
        mo = energy_cost(pipe.configure(("motion",)), profiles, cal.rf_link(),
                         "motion", duties=duties).total_w
        mv = energy_cost(pipe.configure(("motion", "vj")), profiles,
                         cal.rf_link(), "vj", duties=duties).total_w
        assert raw > mo > mv


class TestFaceAuthExecutor:
    """The §III streaming executor vs the per-motion-frame host loop
    (golden oracle): identical motion/window/auth counts on the security
    workload, scores bit-identical to the same int8 datapath run on host
    crops, and the capacity-padding contract (DESIGN.md §9)."""

    SCAN = dict(scale_factor=1.4, step=4.0, adaptive=False)

    @pytest.fixture(scope="class")
    def setup(self):
        from repro.camera.face_nn import train_face_nn
        from repro.camera.viola_jones import make_feature_pool, train_cascade
        X, y, _ = face_dataset(n_per_class=250, seed=0)
        casc = train_cascade(X, y, make_feature_pool(n=200), n_stages=6,
                             per_stage=20, seed=0)
        nn = train_face_nn(X, y, steps=300)
        frames, _ = security_video(n_frames=14, motion_frames=6, seed=1)
        ex = FaceAuthExecutor(casc, nn, frames.shape[1], frames.shape[2],
                              **self.SCAN)
        ex.calibrate(frames)
        return casc, nn, frames, ex, ex(frames)

    def _host_loop(self, ex, nn, frames, nn_fn):
        """The golden-oracle funnel — the SAME implementation the benchmark
        pins parity against (benchmarks/workloads.py), so test and
        benchmark cannot drift onto different contracts."""
        from benchmarks.workloads import host_loop_funnel
        mask, n_win, n_auth, scores, _ = host_loop_funnel(
            ex, frames, nn_fn)
        return mask, n_win, n_auth, scores

    def test_funnel_parity_vs_host_loop(self, setup):
        from repro.kernels.quant_matmul.ops import nn_forward_quantized
        casc, nn, frames, ex, res = setup
        mask, n_win, n_auth, scores = self._host_loop(
            ex, nn, frames,
            lambda x: nn_forward_quantized(ex.qnn, jnp.asarray(x), ex.lut,
                                           ex.lut_meta, use_pallas=False))
        np.testing.assert_array_equal(np.asarray(res.motion), mask)
        np.testing.assert_array_equal(np.asarray(res.n_windows), n_win)
        np.testing.assert_array_equal(np.asarray(res.n_auth), n_auth)
        assert res.total_dropped() == 0
        # the in-graph gather must replicate extract_windows exactly, so
        # scores (same int8 datapath) are identical, window-for-window
        for i, s in scores.items():
            v = np.asarray(res.window_valid[i])
            np.testing.assert_array_equal(np.asarray(res.scores[i])[v], s)

    def test_scores_match_fake_quant_oracle(self, setup):
        """Against forward_quantized (the seed's float fake-quantization):
        same scores up to the quantization-scheme gap, and identical
        decisions for every window that is not threshold-borderline."""
        from repro.camera.face_nn import forward_quantized
        casc, nn, frames, ex, res = setup
        _, _, _, scores = self._host_loop(
            ex, nn, frames,
            lambda x: forward_quantized(nn, jnp.asarray(x), 8, ex.lut,
                                        ex.lut_meta))
        checked = 0
        for i, s_fq in scores.items():
            v = np.asarray(res.window_valid[i])
            s_ex = np.asarray(res.scores[i])[v]
            assert np.abs(s_ex - s_fq).max() < 0.08
            clear = np.abs(s_fq - ex.auth_threshold) > 0.1
            np.testing.assert_array_equal(
                (s_ex > ex.auth_threshold)[clear],
                (s_fq > ex.auth_threshold)[clear])
            checked += int(clear.sum())
        assert checked > 0

    def test_capacity_contract(self, setup):
        """Overflow never corrupts results: excess detections/motion frames
        are dropped and COUNTED, survivors keep original window order."""
        casc, nn, frames, ex, res = setup
        tight = FaceAuthExecutor(casc, nn, frames.shape[1], frames.shape[2],
                                 window_capacity=2, frame_capacity=3,
                                 **self.SCAN)
        # detector-internal cascade drops must surface too (not just the
        # executor's own two capacities)
        starved = FaceAuthExecutor(
            casc, nn, frames.shape[1], frames.shape[2],
            capacities=[ex.det.n_windows] + [1] * (ex.det.n_stages - 1),
            **self.SCAN)
        rs = starved(frames)
        lost = np.asarray(res.n_windows).sum() - np.asarray(rs.n_windows).sum()
        if lost:
            assert int(np.asarray(rs.cascade_dropped).sum()) > 0
            assert rs.total_dropped() > 0
        r = tight(frames)
        n_det = np.asarray(res.n_windows)
        n_mot = int(np.asarray(res.motion).sum())
        assert int(np.asarray(r.motion_dropped)) == max(n_mot - 3, 0)
        v = np.asarray(r.window_valid)
        assert v.sum(axis=1).max() <= 2
        # processed frames report exact pre-capacity counts and the drops
        proc = np.asarray(r.n_windows) > 0
        np.testing.assert_array_equal(np.asarray(r.n_windows)[proc],
                                      n_det[proc])
        drops = np.asarray(r.windows_dropped)
        np.testing.assert_array_equal(
            drops[proc], np.maximum(n_det[proc] - 2, 0))
        for i in np.where(proc)[0]:
            ids = np.asarray(r.window_id[i])[np.asarray(r.window_valid[i])]
            assert list(ids) == sorted(ids)       # stable, original order

    def test_multi_stream_vmap_matches_single(self, setup):
        casc, nn, frames, ex, res = setup
        streams = jnp.stack([jnp.asarray(frames),
                             jnp.asarray(np.roll(frames, 3, axis=0))])
        r = ex.run_streams(streams)
        np.testing.assert_array_equal(np.asarray(r.n_windows[0]),
                                      np.asarray(res.n_windows))
        np.testing.assert_array_equal(np.asarray(r.scores[0]),
                                      np.asarray(res.scores))
        assert np.asarray(r.n_windows).shape[0] == 2


class TestBSSA:
    def test_splat_slice_roundtrip_smooth_field(self):
        """Splatting a smooth field and slicing it back preserves it."""
        left, _, _ = stereo_pair(h=64, w=80, seed=1)
        field = jnp.asarray(np.tile(np.linspace(0, 10, 80), (64, 1)).astype(np.float32))
        spec = GridSpec(sigma_spatial=8)
        gv, gw = splat(jnp.asarray(left), field, spec)
        out = slice_grid(gv, gw, jnp.asarray(left), spec)
        assert float(jnp.mean(jnp.abs(out - field))) < 1.0

    def test_blur_is_smoothing(self):
        g = jax.random.normal(jax.random.PRNGKey(0), (16, 16, 9))
        blurred = blur_121(g)
        assert float(jnp.var(blurred)) < float(jnp.var(g))

    def test_refinement_improves_depth(self):
        left, right, gt = stereo_pair(h=96, w=128, seed=3)
        rough = rough_disparity(jnp.asarray(left), jnp.asarray(right), 12)
        refined = bssa_depth(jnp.asarray(left), jnp.asarray(right),
                             GridSpec(sigma_spatial=8), max_disp=12, n_iters=8)
        def nerr(d):
            d = np.asarray(d)
            dn = (d - d.min()) / (np.ptp(d) + 1e-9)
            gn = (gt - gt.min()) / (np.ptp(gt) + 1e-9)
            return float(np.mean(np.abs(dn - gn)))
        assert nerr(refined) < nerr(rough)  # edge-aware smoothing helps

    def test_msssim_identity(self):
        a = jnp.asarray(np.random.default_rng(0).random((64, 64), np.float32))
        assert ms_ssim(a, a) > 0.99


class TestBSSAFusedParity:
    """The fused cost-volume path vs the seed loop oracles (PR acceptance:
    same argmin disparities up to fp-borderline ties, depth within tol)."""

    def test_rough_fused_equals_seed_loop(self):
        left, right, _ = stereo_pair(h=72, w=96, seed=4)
        a = np.asarray(rough_disparity(jnp.asarray(left), jnp.asarray(right), 12))
        b = np.asarray(rough_disparity_ref(jnp.asarray(left), jnp.asarray(right), 12))
        assert (a == b).mean() >= 0.999

    @pytest.mark.parametrize("chunk", [1, 4, 64])
    def test_rough_chunk_sizes_agree(self, chunk):
        """chunk=1 (pure running-min scan) through chunk>=D+1 (the pure
        one-shot stack) are the same computation."""
        left, right, _ = stereo_pair(h=48, w=64, seed=5)
        l, r = jnp.asarray(left), jnp.asarray(right)
        a = np.asarray(rough_disparity(l, r, 12, hypothesis_chunk=chunk))
        b = np.asarray(rough_disparity_ref(l, r, 12))
        assert (a == b).mean() >= 0.999

    def test_rough_pallas_integral_matches(self):
        """interpret=True routes the cost-volume integral through the
        Pallas streaming kernel — same winners up to fp-borderline ties
        (the blocked integral carries a ~1e-3 association tolerance, so the
        pair must have well-separated SAD minima: iid noise, constant
        shift; smooth low-contrast regions would tie)."""
        rng = np.random.default_rng(7)
        full = rng.random((40, 60), np.float32)
        left = jnp.asarray(full[:, :48])
        right = jnp.asarray(full[:, 3:51])     # right[x] = left[x+3]
        a = np.asarray(rough_disparity(left, right, 8, interpret=True))
        b = np.asarray(rough_disparity_ref(left, right, 8))
        inner = (a == b)[2:-2, 10:-10]         # clamped borders can tie
        assert inner.mean() >= 0.99

    def test_bssa_depth_fused_matches_oracle(self):
        left, right, _ = stereo_pair(h=64, w=80, seed=6)
        spec = GridSpec(sigma_spatial=8)
        a = bssa_depth(jnp.asarray(left), jnp.asarray(right), spec,
                       max_disp=10, n_iters=6)
        b = bssa_depth_ref(jnp.asarray(left), jnp.asarray(right), spec,
                           max_disp=10, n_iters=6)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


class TestBSSAProperties:
    """Property tests for the bilateral-grid operators (satellite: splat/
    slice adjointness + mass conservation, blur normalization, rough
    disparity shift recovery)."""

    @given(st.integers(24, 56), st.integers(24, 56))
    @settings(max_examples=6, deadline=None)
    def test_splat_mass_conservation(self, h, w):
        rng = np.random.default_rng(100 * h + w)
        img = jnp.asarray(rng.random((h, w), np.float32))
        vals = jnp.asarray(rng.random((h, w), np.float32))
        gv, gw = splat(img, vals, GridSpec(sigma_spatial=8))
        assert float(gw.sum()) == pytest.approx(h * w, rel=1e-5)
        assert float(gv.sum()) == pytest.approx(float(vals.sum()), rel=1e-4)

    @given(st.integers(24, 48), st.integers(24, 48))
    @settings(max_examples=4, deadline=None)
    def test_splat_nearest_slice_adjoint(self, h, w):
        """<splat(v), G> == <v, G[nearest vertex]> for any grid field G:
        splat is exactly the adjoint of nearest-vertex sampling."""
        rng = np.random.default_rng(37 * h + w)
        spec = GridSpec(sigma_spatial=8)
        img = jnp.asarray(rng.random((h, w), np.float32))
        vals = jnp.asarray(rng.random((h, w), np.float32))
        gv, _ = splat(img, vals, spec)
        G = jnp.asarray(rng.random(gv.shape, np.float32))
        gy, gx, gr = gv.shape
        cy, cx, cr = _grid_coords(img, spec)
        iy = jnp.clip(jnp.round(cy).astype(jnp.int32), 0, gy - 1)
        ix = jnp.clip(jnp.round(cx).astype(jnp.int32), 0, gx - 1)
        ir = jnp.clip(jnp.round(cr).astype(jnp.int32), 0, gr - 1)
        lhs = float(jnp.sum(gv * G))
        rhs = float(jnp.sum(vals.reshape(-1) * G[iy, ix, ir]))
        assert lhs == pytest.approx(rhs, rel=1e-4)

    def test_slice_partition_of_unity(self):
        """Slicing a constant grid returns the constant everywhere — the
        trilinear weights normalize out."""
        spec = GridSpec(sigma_spatial=8)
        img = jnp.asarray(np.random.default_rng(0).random((48, 64), np.float32))
        gy, gx, gr = spec.dims(48, 64)
        gw = jnp.ones((gy, gx, gr))
        out = slice_grid(3.5 * gw, gw, img, spec)
        np.testing.assert_allclose(np.asarray(out), 3.5, atol=1e-5)

    @given(st.integers(6, 24), st.integers(6, 24))
    @settings(max_examples=6, deadline=None)
    def test_blur_121_weight_normalization(self, gy, gx):
        """DC gain 1 at every vertex (weights sum to 1, edges included) and
        exact mass conservation for interior-supported fields."""
        ones = jnp.ones((gy, gx, 9))
        np.testing.assert_allclose(np.asarray(blur_121(ones)), 1.0, atol=1e-6)
        rng = np.random.default_rng(13 * gy + gx)
        core = np.zeros((gy, gx, 9), np.float32)
        core[1:-1, 1:-1, 1:-1] = rng.random((gy - 2, gx - 2, 7))
        blurred = blur_121(jnp.asarray(core))
        assert float(blurred.sum()) == pytest.approx(float(core.sum()), rel=1e-5)

    @given(st.integers(2, 9))
    @settings(max_examples=6, deadline=None)
    def test_rough_disparity_recovers_injected_shift(self, s):
        """A pair built with right[x] = left[x+s] (the module's disparity
        convention) is recovered exactly away from the borders."""
        rng = np.random.default_rng(s)
        h, w, max_disp, patch = 40, 120, 12, 5
        base = rng.random((h, w + 16)).astype(np.float32)
        k = np.ones(7) / 7          # smooth so neighboring lags separate
        full = np.stack([np.convolve(row, k, "same") for row in base])
        left = jnp.asarray(full[:, :w])
        right = jnp.asarray(full[:, s:s + w])
        d = np.asarray(rough_disparity(left, right, max_disp, patch))
        pad = patch // 2
        inner = d[pad:-pad, max_disp + pad:-(max_disp + pad)]
        assert (inner == s).mean() >= 0.98
