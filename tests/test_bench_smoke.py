"""Benchmark liveness (satellite of the §III streaming-executor PR): every
``benchmarks/run.py`` section must RUN at toy sizes, offline, so benchmark
bit-rot fails the suite instead of being discovered at release time.

One subprocess, all sections, ``--smoke`` (seconds per section); asserts
the orchestrator exits cleanly, every section emitted its JSON artifact,
and the new fa_hotpath section reports executor-vs-loop funnel parity.
"""

import json
import os
import subprocess
import sys
import tempfile

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SECTIONS = ("fa", "vr", "vj", "nn", "bssa", "detect", "fa_hotpath",
            "offload", "resilience", "serving", "serving_chaos",
            "analysis", "roofline")


def test_benchmark_smoke_all_sections():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    with tempfile.TemporaryDirectory() as td:
        out = subprocess.run(
            [sys.executable, "-m", "benchmarks.run", "--smoke", "--json", td],
            capture_output=True, text=True, env=env, cwd=REPO, timeout=900)
        assert out.returncode == 0, (
            f"benchmark smoke failed:\n{out.stdout[-4000:]}\n"
            f"{out.stderr[-4000:]}")
        for name in SECTIONS:
            path = os.path.join(td, f"BENCH_{name}.json")
            assert os.path.exists(path), f"section {name} wrote no JSON"
            data = json.load(open(path))
            assert data["section"] == name
            assert data["rows"], f"section {name} emitted no rows"
            # every section shares ONE top-level schema (bench.v1 via
            # bench_record) so BENCH files are machine-diffable
            assert data["schema"] == "bench.v1", f"{name}: {data.keys()}"
            assert data["smoke"] is True
            assert isinstance(data["wall_s"], float)
            assert isinstance(data["generated_at"], float)
            assert all(len(r) == 4 for r in data["rows"]), \
                f"section {name} broke the (tag, metric, value, note) " \
                "row layout"
        # bench-diff tooling: a file diffed against itself is identical
        # (exit 0) and against a different section is not (exit 1)
        diff = subprocess.run(
            [sys.executable, "-m", "repro.obs", "diff",
             os.path.join(td, "BENCH_fa.json"),
             os.path.join(td, "BENCH_fa.json")],
            capture_output=True, text=True, env=env, cwd=REPO, timeout=60)
        assert diff.returncode == 0, diff.stdout + diff.stderr
        assert "identical" in diff.stdout
        diff2 = subprocess.run(
            [sys.executable, "-m", "repro.obs", "diff",
             os.path.join(td, "BENCH_fa.json"),
             os.path.join(td, "BENCH_vr.json")],
            capture_output=True, text=True, env=env, cwd=REPO, timeout=60)
        assert diff2.returncode == 1, diff2.stdout + diff2.stderr
        fa = json.load(open(os.path.join(td, "BENCH_fa_hotpath.json")))
        parity = {r[1]: r[2] for r in fa["rows"]}
        assert parity.get("funnel_count_parity") == "identical"
        assert float(parity.get("score_parity_int8", "1")) == 0.0
        off = json.load(open(os.path.join(td, "BENCH_offload.json")))
        orow = {r[1]: (r[2], r[3]) for r in off["rows"]}
        assert orow["fa_knee_at_8bit"][0] == "True"
        assert "agrees=True" in orow["fa_controller_choice"][1]
        assert "agrees=True" in orow["vr_controller_choice"][1]
        res = json.load(open(os.path.join(td, "BENCH_resilience.json")))
        rrow = {r[1]: (r[2], r[3]) for r in res["rows"]}
        assert rrow["zero_fault_bitexact"][0] == "1"
        assert rrow["determinism"][0] == "1"
        assert rrow["brownout_resume_exact"][0] == "1"
        assert rrow["resume_not_recompute"][0] == "1"
        # a faulty neighbor's retries must congest the shared uplink
        assert (float(rrow["p99_congested_s"][0])
                > float(rrow["p99_clean_s"][0]))
        srv = json.load(open(os.path.join(td, "BENCH_serving.json")))
        srow = {r[1]: (r[2], r[3]) for r in srv["rows"]}
        # scheduler contract: measured p99 dispatch latency under the SLO,
        # and the windowed controller re-solve actually fired
        assert srow["slo_ok"][0] == "1"
        p99, note = srow["p99_batch_s"]
        assert float(p99) <= float(note.split("SLO=")[1].split("s")[0])
        assert int(srow["resolves_fired"][0]) >= 1
        assert srow["serve_bitexact_local"][0] == "1"
        assert srow["serve_bitexact_vj_raw"][0] == "1"
        cha = json.load(open(os.path.join(td, "BENCH_serving_chaos.json")))
        crow = {r[1]: (r[2], r[3]) for r in cha["rows"]}
        # §14 chaos plane: an inert spec is the PR 8 serving path bit for
        # bit; every fault cell keeps exactly-once frame accounting; the
        # server survives its own brownout via checkpoint/restore; and
        # recovery lands the fleet back under the SLO without starvation
        assert crow["zero_fault_bitexact"][0] == "1"
        assert crow["worst_cell_exactly_once"][0] == "1"
        assert crow["server_brownout_restore"][0] == "1"
        post, cnote = crow["post_recovery_p99_s"]
        assert float(post) <= float(cnote.split("SLO=")[1].split("s")[0])
        gap, gnote = crow["starvation_gap"]
        assert int(gap) <= int(gnote.split("ladder_depth=")[1].split(" ")[0])
        assert int(crow["overload_shed_frames"][0]) > 0
        # §15 telemetry plane: the recorded chaos drive proves the kill
        # chain from its exported JSONL alone, the Perfetto export is
        # well-formed, and the counter panel saw the fleet
        assert crow["trace_kill_chain"][0] == "1", crow["trace_kill_chain"]
        assert crow["trace_perfetto_export"][0] == "1"
        assert int(crow["telemetry_counters"][0]) > 0
        res_led = {r[1]: r[2] for r in res["rows"]}
        assert res_led["ledger_flip_match"] == "1"
        ana = json.load(open(os.path.join(td, "BENCH_analysis.json")))
        arow = {r[1]: r[2] for r in ana["rows"]}
        assert arow["non_baselined"] == "0"
        assert int(arow["kernel_subjects"]) == 7
