"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests must see ONE
device (assignment rule); multi-device tests spawn subprocesses that set
--xla_force_host_platform_device_count themselves."""

import os
import subprocess
import sys
import textwrap

import jax
import pytest

# Pin the precision platform: the analysis precision/dispatch passes (and
# the int8 bit-exactness contracts) are only stable with x64 promotion off.
# Assert rather than silently re-pin so an env/plugin that flipped it is
# surfaced instead of masked.
jax.config.update("jax_enable_x64", False)
assert not jax.config.jax_enable_x64, (
    "jax_enable_x64 must stay False for precision-domain analysis")


REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# tests share the benchmark-side oracle helpers (benchmarks/workloads.py);
# make `import benchmarks...` work however pytest was launched
if REPO not in sys.path:
    sys.path.insert(0, REPO)


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 560) -> str:
    """Run a python snippet in a subprocess with N fake CPU devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=timeout,
    )
    assert out.returncode == 0, f"subprocess failed:\n{out.stdout}\n{out.stderr}"
    return out.stdout


@pytest.fixture(scope="session")
def subproc():
    return run_with_devices
