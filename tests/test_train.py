"""Training substrate tests: optimizer, data determinism, checkpoint/restart,
failure injection, elastic restore."""

import dataclasses
import os
import shutil

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.registry import SMOKE_CONFIGS
from repro.ckpt.checkpoint import (
    latest_step, prune_old, restore_checkpoint, save_checkpoint)
from repro.data.pipeline import DataConfig, batch_for_step
from repro.models.transformer import Model
from repro.train.loop import LoopConfig, train
from repro.train.optimizer import (
    AdamWConfig, adamw_update, init_opt_state, lr_schedule)


class TestOptimizer:
    def test_lr_schedule_shape(self):
        cfg = AdamWConfig(lr_peak=1e-3, lr_min=1e-4, warmup_steps=10,
                          decay_steps=100)
        lrs = [float(lr_schedule(cfg, jnp.int32(s))) for s in (0, 5, 10, 55, 100, 200)]
        assert lrs[0] == 0.0
        assert lrs[2] == pytest.approx(1e-3, rel=1e-5)
        assert lrs[3] < lrs[2]
        assert lrs[4] == pytest.approx(1e-4, rel=1e-3)
        assert lrs[5] == pytest.approx(1e-4, rel=1e-3)

    def test_adamw_descends_quadratic(self):
        target = jnp.array([1.0, -2.0, 3.0])
        params = {"w": jnp.zeros(3)}
        opt = init_opt_state(params)
        cfg = AdamWConfig(lr_peak=0.1, warmup_steps=1, decay_steps=1000,
                          weight_decay=0.0)
        for _ in range(200):
            g = {"w": 2 * (params["w"] - target)}
            params, opt, _ = adamw_update(cfg, g, opt, param_dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                                   atol=0.05)


class TestData:
    def test_deterministic_random_access(self):
        cfg = DataConfig(vocab=100, seq=32, global_batch=8, seed=3)
        a = batch_for_step(cfg, 17)
        b = batch_for_step(cfg, 17)
        assert np.array_equal(a["tokens"], b["tokens"])
        c = batch_for_step(cfg, 18)
        assert not np.array_equal(a["tokens"], c["tokens"])

    def test_host_sharding_partitions_batch(self):
        cfg = DataConfig(vocab=100, seq=16, global_batch=8)
        full = batch_for_step(cfg, 5)["tokens"]
        parts = [batch_for_step(cfg, 5, host_index=i, host_count=4)["tokens"]
                 for i in range(4)]
        assert np.array_equal(np.concatenate(parts), full)


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
                "b": [jnp.ones(4), jnp.zeros(2)]}
        save_checkpoint(str(tmp_path), 7, tree, extra={"next_step": 7})
        assert latest_step(str(tmp_path)) == 7
        restored, extra = restore_checkpoint(str(tmp_path), 7, tree)
        assert extra["next_step"] == 7
        for x, y in zip(jax.tree_util.tree_leaves(tree),
                        jax.tree_util.tree_leaves(restored)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def test_torn_save_is_ignored(self, tmp_path):
        tree = {"a": jnp.ones(3)}
        save_checkpoint(str(tmp_path), 1, tree)
        os.makedirs(str(tmp_path / "step_00000002.tmp"))  # crash mid-save
        assert latest_step(str(tmp_path)) == 1

    def test_prune_keeps_newest(self, tmp_path):
        tree = {"a": jnp.ones(2)}
        for s in (1, 2, 3, 4):
            save_checkpoint(str(tmp_path), s, tree)
        prune_old(str(tmp_path), keep=2)
        assert latest_step(str(tmp_path)) == 4
        assert latest_step(str(tmp_path)) is not None
        left = sorted(os.listdir(str(tmp_path)))
        assert len([d for d in left if d.startswith("step_")]) == 2


def _mk(cfg_name="yi-9b"):
    cfg = dataclasses.replace(SMOKE_CONFIGS[cfg_name], param_dtype=jnp.float32)
    model = Model(cfg)
    data = DataConfig(vocab=cfg.vocab, seq=32, global_batch=8, seed=0)
    make_batch = lambda s: {"tokens": jnp.asarray(batch_for_step(data, s)["tokens"])}
    return model, make_batch


class TestLoop:
    def test_loss_decreases(self, tmp_path):
        model, make_batch = _mk()
        lc = LoopConfig(total_steps=120, ckpt_every=60, ckpt_dir=str(tmp_path))
        _, _, out = train(model, make_batch, lc,
                          AdamWConfig(lr_peak=5e-3, warmup_steps=15,
                                      decay_steps=120), verbose=False)
        hist = out["history"]
        first = np.mean([h["loss"] for h in hist[:10]])
        last = np.mean([h["loss"] for h in hist[-10:]])
        assert last < first - 0.15, (first, last)

    def test_failure_recovery_replays_identically(self, tmp_path):
        """A mid-run crash must not change the final state: run A (no crash)
        and run B (crash at step 25, recovers from ckpt 20) end identically —
        deterministic data + checkpointed state."""
        model, make_batch = _mk()
        lc = lambda d: LoopConfig(total_steps=40, ckpt_every=10, ckpt_dir=d,
                                  max_retries=2)
        pa, _, _ = train(model, make_batch, lc(str(tmp_path / "a")),
                         AdamWConfig(warmup_steps=5, decay_steps=40),
                         verbose=False)

        crashed = {"done": False}

        def fail_hook(step):
            if step == 25 and not crashed["done"]:
                crashed["done"] = True
                raise RuntimeError("injected node failure")

        pb, _, out = train(model, make_batch, lc(str(tmp_path / "b")),
                           AdamWConfig(warmup_steps=5, decay_steps=40),
                           fail_hook=fail_hook, verbose=False)
        assert crashed["done"]
        for a, b in zip(jax.tree_util.tree_leaves(pa),
                        jax.tree_util.tree_leaves(pb)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)

    def test_resume_from_checkpoint(self, tmp_path):
        model, make_batch = _mk()
        d = str(tmp_path)
        train(model, make_batch, LoopConfig(total_steps=20, ckpt_every=10,
                                            ckpt_dir=d), verbose=False)
        assert latest_step(d) == 20
        # continue to 30: resumes at 20, not 0
        _, _, out = train(model, make_batch,
                          LoopConfig(total_steps=30, ckpt_every=10, ckpt_dir=d),
                          verbose=False)
        steps = [h["step"] for h in out["history"]]
        assert steps[0] == 20 and steps[-1] == 29


def test_elastic_restore_across_meshes(subproc):
    """Checkpoint written unsharded restores onto a (2,2,2) pod mesh with
    current shardings — the elastic-rescale path."""
    subproc("""
import jax, jax.numpy as jnp, dataclasses, numpy as np, tempfile
from repro.configs.registry import SMOKE_CONFIGS
from repro.models.transformer import Model
from repro.models.layers import param_shardings
from repro.parallel.axes import use_sharding
from repro.ckpt.checkpoint import save_checkpoint, restore_checkpoint

cfg = dataclasses.replace(SMOKE_CONFIGS['yi-9b'], param_dtype=jnp.float32)
model = Model(cfg)
params = model.init(jax.random.PRNGKey(0))
d = tempfile.mkdtemp()
save_checkpoint(d, 5, params, extra={'next_step': 5})

mesh = jax.make_mesh((2, 2, 2), ('pod', 'data', 'model'))
with use_sharding(mesh) as ctx:
    sh = param_shardings(model.specs(), ctx)
    restored, extra = restore_checkpoint(d, 5, params, shardings=sh)
for a, b in zip(jax.tree_util.tree_leaves(params),
                jax.tree_util.tree_leaves(restored)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print('ELASTIC_OK')
""", n_devices=8)
