"""Fleet-scale streaming serving runtime (DESIGN.md §13).

Covers the seeding bugfixes (``cascade_serve`` enforcing its capacity
inside the compacting cascade with deterministic dropped-survivor
indices; ``sample`` surviving every ``top_k`` edge), the re-entrant
``FaceAuthExecutor.batch_step``, the serve-layer bytes model, the
``StreamingServer`` churn edge cases, the windowed ``CutController``
re-solve API, and the single-stream bit-identity acceptance pin.
"""

import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.camera.offload import BACKSCATTER, CutController
from repro.camera.offload.executors import FaceAuthOffloadExecutor
from repro.camera.pipelines import (FAWorkloadStats, FaceAuthExecutor,
                                    calibrate_fa, fa_pipeline, fa_profiles)
from repro.camera.serve import (FA_CUTS, ServeConfig, StreamingServer,
                                fa_cut_bytes, fa_quiet_bytes)
from repro.serve.engine import SamplerConfig, cascade_serve, sample

_RESULT_FIELDS = ("motion", "n_windows", "n_auth", "scores", "window_id",
                  "window_valid", "auth", "windows_dropped", "motion_dropped",
                  "cascade_dropped")


@pytest.fixture(scope="module")
def fa_setup():
    from benchmarks.workloads import fa_cascade, fa_scan
    from repro.camera.face_nn import train_face_nn
    from repro.camera.synthetic import face_dataset, security_video

    frames, _truth = security_video(n_frames=10, motion_frames=5, seed=1)
    casc = fa_cascade(smoke=True)
    X, y, _ = face_dataset(n_per_class=80, seed=3)
    nn = train_face_nn(X, y, steps=60)
    sf, st, ad = fa_scan(True)
    ex = FaceAuthExecutor(casc, nn, frames.shape[1], frames.shape[2],
                          scale_factor=sf, step=st, adaptive=ad)
    ex.calibrate(frames)
    fj = jnp.asarray(frames)
    return ex, frames, fj, ex(fj)


@pytest.fixture(scope="module")
def controller(fa_setup):
    ex, frames, fj, base = fa_setup
    stats = FAWorkloadStats(
        n_frames=len(frames),
        motion_frames=max(int(np.asarray(base.motion).sum()), 1),
        windows_to_nn=max(int(np.asarray(base.n_windows).sum()), 1))
    cal = calibrate_fa(stats)
    profiles = fa_profiles()
    profiles["nn"] = cal.nn_profile()
    link = dataclasses.replace(BACKSCATTER,
                               joules_per_byte=cal.rf_joules_per_byte)
    ctl = CutController(
        lambda cut: FaceAuthOffloadExecutor(ex, cut, bits=8,
                                            use_pallas=False),
        cuts=FA_CUTS, template=fa_pipeline(stats), profiles=profiles,
        link=link, regime="energy", unit_rate_hz=1.0,
        duties={"sensor": 1.0, "motion": 1.0, "vj": 0.0, "nn": 1.0})
    ctl.calibrate(fj)
    return ctl


def _motion_pair(frames, base):
    """Two consecutive frames whose transition passes the motion gate."""
    motion = np.asarray(base.motion)
    i = int(np.argmax(motion[1:])) + 1
    assert motion[i]
    return np.stack([frames[i - 1], frames[i]])


def _quiet_pair(frames):
    return np.stack([frames[0], frames[0]])


# ---------------------------------------------------------------------------
# cascade_serve: capacity enforced in-cascade, deterministic drops
# ---------------------------------------------------------------------------


def _value_scorer(items):
    return jnp.mean(items, axis=tuple(range(1, items.ndim)))


class TestCascadeServe:
    def test_capacity_enforced_with_deterministic_drops(self):
        # survivors at indices 1, 3, 4, 6; capacity 2 must keep the two
        # lowest-indexed survivors and surface exactly the other two
        vals = np.array([0, 5, 0, 5, 5, 0, 5, 0], np.float32)
        reqs = jnp.asarray(np.tile(vals[:, None], (1, 3)))
        out, served, stats = cascade_serve(
            _value_scorer, lambda x: x * 2.0, reqs,
            threshold=1.0, capacity=2)
        assert int(stats["n_candidates"]) == 4
        assert int(stats["n_served"]) == 2
        assert int(stats["n_dropped_capacity"]) == 2
        assert np.array_equal(np.asarray(served),
                              [False, True, False, True,
                               False, False, False, False])
        assert list(np.asarray(stats["dropped_capacity_idx"])[:2]) == [4, 6]
        assert all(i == -1
                   for i in np.asarray(stats["dropped_capacity_idx"])[2:])
        # deterministic: the exact same answer on a second call
        out2, served2, stats2 = cascade_serve(
            _value_scorer, lambda x: x * 2.0, reqs,
            threshold=1.0, capacity=2)
        assert np.array_equal(np.asarray(served), np.asarray(served2))
        assert np.array_equal(np.asarray(stats["dropped_capacity_idx"]),
                              np.asarray(stats2["dropped_capacity_idx"]))
        assert np.array_equal(np.asarray(out), np.asarray(out2))

    def test_outputs_scattered_pytree(self):
        vals = np.array([3, 0, 3, 3], np.float32)
        reqs = jnp.asarray(np.tile(vals[:, None], (1, 2)))
        big = lambda x: {"double": x * 2.0,  # noqa: E731
                         "row_sum": jnp.sum(x, axis=-1)}
        out, served, _ = cascade_serve(_value_scorer, big, reqs,
                                       threshold=1.0, capacity=4)
        assert np.array_equal(np.asarray(served), [True, False, True, True])
        dbl = np.asarray(out["double"])
        assert np.array_equal(dbl[0], np.asarray(reqs[0]) * 2)
        assert np.array_equal(dbl[1], np.zeros(2))  # non-served row zeroed
        assert float(np.asarray(out["row_sum"])[1]) == 0.0

    def test_capacity_fraction_derives_and_clamps(self):
        reqs = jnp.ones((8, 2), jnp.float32) * 5.0
        _, served, stats = cascade_serve(
            _value_scorer, lambda x: x, reqs, threshold=1.0,
            capacity_fraction=0.25)
        assert int(np.asarray(served).sum()) == 2       # 8 * 0.25
        # fraction 0 clamps to a 1-slot big batch, never zero
        _, served, _ = cascade_serve(
            _value_scorer, lambda x: x, reqs, threshold=1.0,
            capacity_fraction=0.0)
        assert int(np.asarray(served).sum()) == 1
        # capacity over b clamps to b: every survivor served, no drops
        _, served, stats = cascade_serve(
            _value_scorer, lambda x: x, reqs, threshold=1.0, capacity=99)
        assert int(np.asarray(served).sum()) == 8
        assert int(stats["n_dropped_capacity"]) == 0

    def test_no_survivors(self):
        reqs = jnp.zeros((4, 2), jnp.float32)
        out, served, stats = cascade_serve(
            _value_scorer, lambda x: x + 1.0, reqs, threshold=1.0,
            capacity=2)
        assert not np.asarray(served).any()
        assert int(stats["n_candidates"]) == 0
        assert np.array_equal(np.asarray(out), np.zeros((4, 2)))


# ---------------------------------------------------------------------------
# sample: top_k edges
# ---------------------------------------------------------------------------


class TestSample:
    VOCAB = 7

    def _logits(self):
        rng = np.random.default_rng(0)
        return jnp.asarray(rng.normal(size=(5, self.VOCAB)).astype(np.float32))

    @pytest.mark.parametrize("top_k", [0, 1, VOCAB, VOCAB + 5])
    def test_top_k_edges(self, top_k):
        logits = self._logits()
        toks = sample(logits, jax.random.PRNGKey(0),
                      SamplerConfig(temperature=1.0, top_k=top_k))
        toks = np.asarray(toks)
        assert toks.shape == (5,) and toks.dtype == np.int32
        assert ((0 <= toks) & (toks < self.VOCAB)).all()

    def test_top_k_one_is_argmax(self):
        logits = self._logits()
        toks = sample(logits, jax.random.PRNGKey(3),
                      SamplerConfig(temperature=1.0, top_k=1))
        assert np.array_equal(np.asarray(toks),
                              np.asarray(jnp.argmax(logits, axis=-1)))

    @pytest.mark.parametrize("top_k", [0, 1, VOCAB + 5])
    def test_temperature_zero_greedy_parity(self, top_k):
        logits = self._logits()
        toks = sample(logits, jax.random.PRNGKey(7),
                      SamplerConfig(temperature=0.0, top_k=top_k))
        assert np.array_equal(np.asarray(toks),
                              np.asarray(jnp.argmax(logits, axis=-1)))

    def test_full_vocab_matches_unfiltered(self):
        logits = self._logits()
        key = jax.random.PRNGKey(11)
        a = sample(logits, key, SamplerConfig(temperature=1.0, top_k=0))
        b = sample(logits, key,
                   SamplerConfig(temperature=1.0, top_k=self.VOCAB))
        assert np.array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# FaceAuthExecutor.batch_step
# ---------------------------------------------------------------------------


class TestBatchStep:
    def test_matches_single_stream_bitwise(self, fa_setup):
        ex, frames, fj, base = fa_setup
        chunks = [frames[0:4], frames[4:8], frames[2:6]]
        step = ex.batch_step(3, 4)
        out = step(jnp.asarray(np.stack(chunks)), jnp.ones((3,), bool))
        for i, ch in enumerate(chunks):
            ref = ex(jnp.asarray(ch))
            for f in _RESULT_FIELDS:
                assert np.array_equal(np.asarray(out[f])[i],
                                      np.asarray(getattr(ref, f))), (f, i)

    def test_invalid_slots_carry_quiet_result(self, fa_setup):
        ex, frames, fj, base = fa_setup
        stack = jnp.asarray(np.stack([frames[0:4], frames[4:8]]))
        out = ex.batch_step(2, 4)(stack, jnp.asarray([True, False]))
        assert not np.asarray(out["motion"])[1].any()
        assert (np.asarray(out["window_id"])[1] == -1).all()
        assert not np.asarray(out["scores"])[1].any()
        assert not np.asarray(out["window_valid"])[1].any()

    def test_closure_cached_and_invalidated_by_calibrate(self, fa_setup):
        ex, frames, fj, base = fa_setup
        step = ex.batch_step(2, 4)
        assert ex.batch_step(2, 4) is step
        ex.calibrate(frames)           # same data: rebuild, same semantics
        assert ex.batch_step(2, 4) is not step

    def test_shape_validation(self, fa_setup):
        ex, frames, fj, base = fa_setup
        step = ex.batch_step(2, 4)
        with pytest.raises(ValueError, match="shape-bound"):
            step(jnp.asarray(np.stack([frames[0:3], frames[3:6]])),
                 jnp.ones((2,), bool))
        with pytest.raises(ValueError):
            step(jnp.asarray(np.stack([frames[0:4]])), jnp.ones((1,), bool))


# ---------------------------------------------------------------------------
# serve-layer bytes model == the node halves' measured wire bytes
# ---------------------------------------------------------------------------


class TestBytesModel:
    @pytest.mark.parametrize("cut", FA_CUTS)
    def test_quiet_chunk_bytes_exact(self, fa_setup, cut):
        ex, frames, fj, base = fa_setup
        off = FaceAuthOffloadExecutor(ex, cut, bits=8, use_pallas=False)
        _, wb = off._node_fn(jnp.asarray(_quiet_pair(frames)), *off._consts)
        h, w = frames.shape[1], frames.shape[2]
        assert float(wb) == fa_quiet_bytes(cut, 8, frames=2, h=h, w=w)

    @pytest.mark.parametrize("cut", FA_CUTS)
    def test_live_chunk_bytes_exact_at_measured_stats(self, fa_setup, cut):
        ex, frames, fj, base = fa_setup
        chunk = frames[:4]
        res = ex(jnp.asarray(chunk))
        m = int(np.asarray(res.motion).sum())
        v = int(np.asarray(res.window_valid).sum())
        off = FaceAuthOffloadExecutor(ex, cut, bits=8, use_pallas=False)
        _, wb = off._node_fn(jnp.asarray(chunk), *off._consts)
        h, w = frames.shape[1], frames.shape[2]
        assert float(wb) == fa_cut_bytes(cut, 8, frames=4, h=h, w=w,
                                         motion_frames=m, valid_windows=v)

    def test_unknown_cut_raises(self):
        with pytest.raises(ValueError):
            fa_cut_bytes("head", 8, frames=4, h=16, w=16)


# ---------------------------------------------------------------------------
# StreamingServer: churn edge cases
# ---------------------------------------------------------------------------


def _local_server(ex, **kw):
    cfg = ServeConfig(chunk=2, capacity=2, tick_s=1.0, max_queue_s=100.0,
                      **kw)
    return StreamingServer(ex, config=cfg)


class TestStreamingChurn:
    def test_join_mid_window(self, fa_setup):
        ex, frames, fj, base = fa_setup
        srv = _local_server(ex)
        srv.register("a", fps=1.0)
        srv.enqueue("a", frames[0], t=0.0)
        srv.enqueue("a", frames[1], t=0.5)
        rep1 = srv.tick(1.0)
        assert {c.sid for c in rep1.completions} == {"a"}
        srv.register("b", fps=1.0, t=1.0)      # joins after serving started
        srv.enqueue("b", frames[2], t=1.1)
        srv.enqueue("b", frames[3], t=1.2)
        rep2 = srv.tick(2.0)
        assert {c.sid for c in rep2.completions} == {"b"}
        assert set(srv.streams) == {"a", "b"}

    def test_leave_with_queued_frames_drains_then_reaps(self, fa_setup):
        ex, frames, fj, base = fa_setup
        srv = _local_server(ex)
        srv.register("a", fps=1.0)
        for i in range(3):                     # 1.5 chunks queued
            srv.enqueue("a", frames[i], t=float(i) / 10)
        assert srv.unregister("a") == 3
        with pytest.raises(ValueError, match="draining"):
            srv.enqueue("a", frames[3], t=1.0)
        rep1 = srv.tick(1.0)                   # full chunk
        rep2 = srv.tick(2.0)                   # draining flushes the tail
        done = [c for r in (rep1, rep2) for c in r.completions]
        assert sum(c.n_frames for c in done) == 3
        assert "a" not in srv.streams          # reaped once empty
        assert srv.frames_served() == 3        # drained frames still counted

    def test_unregister_empty_queue_is_immediate(self, fa_setup):
        ex, frames, fj, base = fa_setup
        srv = _local_server(ex)
        srv.register("a", fps=1.0)
        assert srv.unregister("a") == 0
        assert "a" not in srv.streams

    def test_empty_tick(self, fa_setup):
        ex, frames, fj, base = fa_setup
        srv = _local_server(ex)
        srv.register("a", fps=1.0)
        rep = srv.tick(1.0)
        assert rep.n_ready == 0 and rep.completions == ()
        assert srv.batch_lat_s == []           # no dispatch, no latency row
        assert srv.p99_batch_s() == 0.0

    def test_duplicate_register_raises(self, fa_setup):
        ex, frames, fj, base = fa_setup
        srv = _local_server(ex)
        srv.register("a", fps=1.0)
        with pytest.raises(ValueError, match="already registered"):
            srv.register("a", fps=1.0)

    def test_capacity_overflow_requeues_without_loss(self, fa_setup):
        ex, frames, fj, base = fa_setup
        cfg = ServeConfig(chunk=2, capacity=1, tick_s=1.0, max_queue_s=100.0)
        srv = StreamingServer(ex, config=cfg)
        hot = _motion_pair(frames, base)
        for sid in ("a", "b"):                 # declared rates fit the
            srv.register(sid, fps=0.5)         # 1-slot compute budget
            srv.enqueue(sid, hot[0], t=0.0)
            srv.enqueue(sid, hot[1], t=0.1)
        rep1 = srv.tick(1.0)                   # both pass the scorer, cap 1
        assert rep1.n_served == 1 and rep1.n_requeued == 1
        rep2 = srv.tick(2.0)                   # the requeued chunk drains
        assert rep2.n_served == 1 and rep2.n_requeued == 0
        assert srv.frames_served() == 4        # nothing dropped
        assert sum(s.requeues for s in srv.streams.values()) == 1

    def test_local_admission_compute_budget(self, fa_setup):
        ex, frames, fj, base = fa_setup
        cfg = ServeConfig(chunk=2, capacity=1, tick_s=1.0)
        srv = StreamingServer(ex, config=cfg)   # budget: 1.6 fps x headroom
        assert srv.register("a", fps=1.0).admitted
        dec = srv.register("b", fps=1.0)
        assert not dec.admitted and dec.reason.startswith("compute")
        assert srv.rejections and srv.rejections[-1].sid == "b"

    def test_offload_admission_rejects_on_starved_link(self, fa_setup):
        ex, frames, fj, base = fa_setup
        link = dataclasses.replace(BACKSCATTER, bytes_per_s=1.0)
        srv = StreamingServer(ex, link=link, config=ServeConfig(chunk=2))
        dec = srv.register("a", fps=1.0, cut="vj", bits=8)
        assert not dec.admitted and "uplink" in dec.reason

    def test_offload_admission_replaces_cut_under_pressure(self, fa_setup):
        ex, frames, fj, base = fa_setup
        # vj's predicted rate busts a 100 B/s uplink; nn's does not
        link = dataclasses.replace(BACKSCATTER, bytes_per_s=100.0)
        srv = StreamingServer(ex, link=link, config=ServeConfig(chunk=2))
        dec = srv.register("a", fps=1.0, cut="vj", bits=8)
        assert dec.admitted and dec.cut == "nn"
        assert "re-placed" in dec.reason
        assert srv.streams["a"].cut == "nn"

    def test_bad_cut_raises(self, fa_setup):
        ex, frames, fj, base = fa_setup
        srv = _local_server(ex)
        with pytest.raises(ValueError, match="not in"):
            srv.register("a", fps=1.0, cut="head", bits=8)


class TestWindowedResolve:
    def test_zero_traffic_stream_never_resolves(self, fa_setup, controller):
        """The PR 7 'zero-fault stream never moves' pin, transplanted: a
        stream with no traffic accumulates no served frames, so its cut is
        never re-solved, while a served neighbor's is."""
        ex, frames, fj, base = fa_setup
        cfg = ServeConfig(chunk=2, capacity=2, tick_s=1.0, resolve_every=2,
                          link_window=2, max_queue_s=100.0)
        srv = StreamingServer(ex, link=BACKSCATTER.scaled(100.0),
                              controller=controller, config=cfg)
        srv.register("live", fps=1.0, cut="vj", bits=8)
        srv.register("idle", fps=1.0, cut="vj", bits=8)
        hot = _motion_pair(frames, base)
        before = controller.resolves
        for k in range(3):
            srv.enqueue("live", hot[0], t=float(k))
            srv.enqueue("live", hot[1], t=float(k) + 0.1)
            srv.tick(float(k + 1))
        assert srv.streams["live"].resolves >= 1
        assert controller.resolves > before
        assert srv.streams["idle"].resolves == 0
        assert srv.streams["idle"].cut == "vj"          # never moved
        assert srv.streams["idle"].frames_since_resolve == 0

    def test_observe_folds_into_window_measurements(self, controller):
        controller._window_obs.clear()
        controller.observe("vj", units=4, wire_bytes=400.0)
        controller.observe("vj", units=4, wire_bytes=440.0)
        rows = {m.cut: m for m in controller.window_measurements()}
        assert rows["vj"].units == 8
        assert rows["vj"].wire_bytes == 840.0
        assert rows["vj"].bytes_per_unit == 105.0
        # cuts with no live samples keep their calibration rows
        cal = {m.cut: m for m in controller.measurements}
        assert rows["nn"].wire_bytes == cal["nn"].wire_bytes
        controller._window_obs.clear()

    def test_predicted_bytes_take_precedence(self, controller):
        controller._window_obs.clear()
        controller.observe("vj", units=4, wire_bytes=400.0)
        rows = {m.cut: m
                for m in controller.window_measurements({"vj": 7.0})}
        assert rows["vj"].wire_bytes == 7.0 * rows["vj"].units
        controller._window_obs.clear()

    def test_observe_unknown_cut_raises(self, controller):
        with pytest.raises(ValueError):
            controller.observe("head", units=1, wire_bytes=1.0)

    def test_resolve_window_counts_and_restores(self, controller):
        controller._window_obs.clear()
        saved = list(controller.measurements)
        before = controller.resolves
        sol = controller.resolve_window()
        assert sol.cut_after in FA_CUTS
        assert controller.resolves == before + 1
        assert controller.measurements == saved         # table restored

    def test_deadline_filter_and_min_latency_floor(self, controller):
        controller._window_obs.clear()
        free = {c: 0.0 for c in FA_CUTS}
        c0 = controller.resolve_window(deadline_s=1e9,
                                       cut_latency_s=free).cut_after
        # make the unconstrained optimum infeasible: best FEASIBLE cut wins
        lat = {c: (10.0 if c == c0 else 0.0) for c in FA_CUTS}
        sol = controller.resolve_window(deadline_s=1.0, cut_latency_s=lat)
        assert sol.cut_after != c0 and lat[sol.cut_after] == 0.0
        # nothing feasible: the minimum-latency cut is the graceful floor
        lat = {c: 5.0 + i for i, c in enumerate(FA_CUTS)}
        sol = controller.resolve_window(deadline_s=1.0, cut_latency_s=lat)
        assert sol.cut_after == FA_CUTS[0]


# ---------------------------------------------------------------------------
# single-stream bit-identity through the serving path (acceptance pin)
# ---------------------------------------------------------------------------


class TestServeBitIdentity:
    @pytest.mark.parametrize("cut,bits", [(None, None), ("vj", None)])
    def test_single_stream_matches_fused_executor(self, fa_setup, cut, bits):
        from repro.camera.offload import ETH_25G_LINK

        ex, frames, fj, base = fa_setup
        cfg = ServeConfig(chunk=len(frames), capacity=1, tick_s=1.0,
                          max_queue_s=1e9)
        srv = StreamingServer(ex, link=ETH_25G_LINK, config=cfg)
        dec = srv.register("s", fps=1.0, cut=cut, bits=bits)
        assert dec.admitted and dec.cut == cut
        for i, f in enumerate(frames):
            srv.enqueue("s", f, t=i / len(frames))
        rep = srv.tick(1.0)
        (comp,) = rep.completions
        assert comp.kind == "served" and comp.n_frames == len(frames)
        for f in _RESULT_FIELDS:
            assert np.array_equal(np.asarray(comp.result[f]),
                                  np.asarray(getattr(base, f))), f
        if cut is None:
            assert comp.wire_bytes == 0.0
        else:
            assert comp.wire_bytes > 0.0
