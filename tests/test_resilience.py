"""Fault-injected offload runtime (DESIGN.md §12).

Covers the PR-5 pinning contract (zero-fault sessions bit-exact with the
split executors at every cut x bits), the fault models' determinism and
stationary statistics, retransmission byte/energy charging, brownout
recovery from stage-boundary commit points, the degradation ladder, and
the calibration-validation satellites on CutController and the link.
"""

import dataclasses

import numpy as np
import pytest
import jax.numpy as jnp

from hypothesis_compat import given, settings, st

from repro.camera.offload import (
    BACKSCATTER,
    ON_NODE,
    BrownoutModel,
    CutController,
    DegradationLadder,
    DeliveryRecord,
    FaceAuthOffloadExecutor,
    FaultInjector,
    GilbertElliott,
    LinkProfile,
    OffloadSession,
    VROffloadExecutor,
    WirePayload,
    fleet_link_report,
    payload_checksum,
    simulate_shared_link,
)
from repro.camera.offload.payloads import SESSION_SIDEBAND_BYTES
from repro.camera.pipelines import FaceAuthExecutor
from repro.core.costmodel import HardwareProfile
from repro.core.pipeline import linear_pipeline

FA_CUTS = FaceAuthOffloadExecutor.CUTS
ALL_BITS = (None, 4, 8, 16)
_RESULT_FIELDS = ("motion", "n_windows", "n_auth", "scores", "window_id",
                  "window_valid", "auth", "windows_dropped",
                  "motion_dropped", "cascade_dropped")


@pytest.fixture(scope="module")
def fa_setup():
    from benchmarks.workloads import fa_cascade, fa_scan
    from repro.camera.face_nn import train_face_nn
    from repro.camera.synthetic import face_dataset, security_video

    frames, _truth = security_video(n_frames=10, motion_frames=5, seed=1)
    casc = fa_cascade(smoke=True)
    X, y, _ = face_dataset(n_per_class=80, seed=3)
    nn = train_face_nn(X, y, steps=60)
    sf, stp, ad = fa_scan(True)
    ex = FaceAuthExecutor(casc, nn, frames.shape[1], frames.shape[2],
                          scale_factor=sf, step=stp, adaptive=ad)
    ex.calibrate(frames)
    fj = jnp.asarray(frames)
    base = ex(fj)
    offs = {(cut, bits): FaceAuthOffloadExecutor(ex, cut, bits=bits)
            for cut in FA_CUTS for bits in ALL_BITS}
    return ex, fj, base, offs


def _assert_result_equal(a, b, fields=_RESULT_FIELDS):
    for f in fields:
        assert np.array_equal(np.asarray(getattr(a, f)),
                              np.asarray(getattr(b, f))), f


# ---------------------------------------------------------------------------
# fault models
# ---------------------------------------------------------------------------


class TestGilbertElliott:
    def test_stationary_closed_form(self):
        ge = GilbertElliott(p_gb=0.1, p_bg=0.4)
        assert ge.stationary_bad == pytest.approx(0.2)
        assert ge.stationary_loss == pytest.approx(0.2)
        assert ge.mean_burst_len == pytest.approx(2.5)

    def test_rejects_non_probabilities(self):
        for bad in (-0.1, 1.5, float("nan")):
            with pytest.raises(ValueError, match="probability"):
                GilbertElliott(p_gb=bad)

    @settings(deadline=None, max_examples=5)
    @given(st.floats(min_value=0.05, max_value=0.95),
           st.floats(min_value=0.05, max_value=0.95),
           st.integers(min_value=0, max_value=10_000))
    def test_empirical_loss_converges_to_stationary(self, p_gb, p_bg, seed):
        """Property: the injector's long-run loss rate is the analytic
        stationary rate of its two-state chain (the satellite anchor)."""
        ge = GilbertElliott(p_gb=p_gb, p_bg=p_bg)
        inj = FaultInjector(loss=ge, seed=seed)
        n = 20_000
        for _ in range(n):
            inj.attempt(0.0)
        # burst correlation inflates the variance of the empirical mean:
        # correlation time ~ 1/p_bg + 1/p_gb <= 40 attempts here
        assert inj.attempts == n
        assert abs(inj.empirical_loss - ge.stationary_loss) < 0.08

    def test_seed_determinism(self):
        ge = GilbertElliott(p_gb=0.2, p_bg=0.3)
        a = FaultInjector(loss=ge, corrupt_fraction=0.4, seed=9)
        b = FaultInjector(loss=ge, corrupt_fraction=0.4, seed=9)
        seq_a = [a.attempt(i * 0.1) for i in range(200)]
        seq_b = [b.attempt(i * 0.1) for i in range(200)]
        assert seq_a == seq_b
        a.reset()
        assert [a.attempt(i * 0.1) for i in range(200)] == seq_a


class TestOutageAndBrownout:
    def test_outage_occupies_tail_of_period(self):
        inj = FaultInjector(outage_period_s=10.0, outage_duty=0.2)
        assert not inj.outage_at(0.0)
        assert not inj.outage_at(7.9)
        assert inj.outage_at(8.1)
        assert inj.next_outage_end(8.1) == pytest.approx(10.0)
        assert inj.attempt(8.1) in ("lost", "corrupt")
        assert inj.attempt(10.1) == "ok"

    def test_brownout_model_validation(self):
        with pytest.raises(ValueError, match="load_w"):
            BrownoutModel(harvest_w=2e-4, load_w=1e-4)
        with pytest.raises(ValueError, match="finite and positive"):
            BrownoutModel(storage_j=0.0)

    def test_power_schedule_alternates_deterministically(self):
        bo = BrownoutModel(harvest_w=15e-6, storage_j=13e-6, load_w=200e-6,
                           jitter=0.0)
        inj = FaultInjector(brownout=bo, seed=4)
        powered0, b0 = inj.power_window(0.0)
        assert powered0 and b0 == pytest.approx(bo.on_s)
        powered1, b1 = inj.power_window(b0)
        assert not powered1
        assert b1 == pytest.approx(bo.on_s + bo.recharge_s)
        inj2 = FaultInjector(brownout=BrownoutModel(
            harvest_w=15e-6, storage_j=13e-6, load_w=200e-6, jitter=0.3),
            seed=4)
        edges_a = [inj2.power_window(t)[1] for t in np.linspace(0, 5, 7)]
        inj2.reset()
        edges_b = [inj2.power_window(t)[1] for t in np.linspace(0, 5, 7)]
        assert edges_a == edges_b

    def test_no_brownout_means_always_powered(self):
        inj = FaultInjector(seed=0)
        assert inj.power_window(123.0) == (True, float("inf"))


# ---------------------------------------------------------------------------
# link validation satellites
# ---------------------------------------------------------------------------


class TestLinkValidation:
    def test_scaled_rejects_nonpositive_factor(self):
        for bad in (0.0, -2.0, float("nan")):
            with pytest.raises(ValueError, match="finite positive"):
                BACKSCATTER.scaled(bad)

    def test_scaled_error_points_at_fault_injector(self):
        with pytest.raises(ValueError, match="FaultInjector"):
            BACKSCATTER.scaled(0.0)

    def test_scaled_valid_factor_still_works(self):
        assert BACKSCATTER.scaled(2.0).bytes_per_s == pytest.approx(
            2 * BACKSCATTER.bytes_per_s)

    def test_simulator_rejects_negative_period(self):
        tr = np.array([[100.0, 100.0]])
        with pytest.raises(ValueError, match="frame_period_s"):
            simulate_shared_link(tr, BACKSCATTER, frame_period_s=-1.0)
        with pytest.raises(ValueError, match="raise duty"):
            simulate_shared_link(tr, BACKSCATTER,
                                 frame_period_s=float("nan"))


# ---------------------------------------------------------------------------
# controller calibration validation satellites
# ---------------------------------------------------------------------------


class _FakeSplitExec:
    def __init__(self, cut, wire_bytes):
        self.cut = cut
        self.bits = 8
        self._b = float(wire_bytes)

    def encode(self, frames):
        return WirePayload(cut=self.cut, bits=8,
                           arrays={"x": jnp.zeros((1,))}, meta={},
                           wire_b=jnp.asarray(self._b, jnp.float32))

    def decode_run(self, payload):
        return jnp.zeros(())


def _toy_controller(wire, profiles=None, **kw):
    template = linear_pipeline("toy", [
        dict(name="src", flops=0, bytes_in=0, bytes_out=1000, kind="source"),
        dict(name="filt", flops=1e3, bytes_in=1000, bytes_out=200,
             kind="optional", selectivity=0.5),
        dict(name="heavy", flops=1e6, bytes_in=200, bytes_out=10),
    ])
    if profiles is None:
        profiles = {
            "src": HardwareProfile("s", p_active_w=10e-6, p_leak_w=10e-6),
            "filt": HardwareProfile("f", flops_per_s=1e6, p_active_w=20e-6,
                                    p_leak_w=5e-6),
            "heavy": HardwareProfile("h", flops_per_s=1e6, p_active_w=100e-6,
                                     p_leak_w=50e-6),
        }
    link = LinkProfile("rf", bytes_per_s=1e4, joules_per_byte=1e-7)
    return CutController(
        lambda cut: _FakeSplitExec(cut, wire[cut]),
        cuts=("src", "filt", "heavy"), template=template,
        profiles=profiles, link=link, **kw)


class TestControllerValidation:
    WIRE = {"src": 1000.0, "filt": 120.0, "heavy": 7.0}

    def test_missing_profile_names_the_cut(self):
        ctl = _toy_controller(self.WIRE)
        ctl.calibrate(jnp.zeros((4, 2, 2)))
        del ctl.profiles["filt"]
        with pytest.raises(ValueError, match="'filt'.*no\\s+HardwareProfile"):
            ctl.choose()

    def test_missing_measurement_names_the_cut(self):
        ctl = _toy_controller(self.WIRE)
        ctl.calibrate(jnp.zeros((4, 2, 2)))
        ctl.cuts = ("src", "filt", "heavy", "ghost")
        with pytest.raises(ValueError,
                           match="no calibration entry for cut 'ghost'"):
            ctl.choose()

    def test_nonfinite_calibration_names_the_cut(self):
        ctl = _toy_controller(dict(self.WIRE, filt=float("nan")))
        with pytest.raises(ValueError, match="'filt'.*non-finite"):
            ctl.calibrate(jnp.zeros((4, 2, 2)))

    def test_tampered_measurement_caught_by_choose(self):
        ctl = _toy_controller(self.WIRE)
        ctl.calibrate(jnp.zeros((4, 2, 2)))
        ctl.measurements[1] = dataclasses.replace(
            ctl.measurements[1], node_s=float("inf"))
        with pytest.raises(ValueError, match="'filt'.*node_s"):
            ctl.choose()

    def test_clean_calibration_still_chooses(self):
        ctl = _toy_controller(self.WIRE, regime="energy")
        ctl.calibrate(jnp.zeros((4, 2, 2)))
        assert ctl.choose().cut_after in ("src", "filt", "heavy")

    def test_degradation_ladder_shape(self):
        ctl = _toy_controller(self.WIRE, regime="energy")
        ctl.calibrate(jnp.zeros((4, 2, 2)))
        ladder = ctl.degradation_ladder()
        chosen = ctl.choose().cut_after
        assert ladder.rungs[0] == (chosen, 16)
        assert ladder.rungs[-1] == ON_NODE
        # the measured-cheapest cut is on the ladder before on_node
        assert any(r[0] == "heavy" for r in ladder.rungs[:-1])


# ---------------------------------------------------------------------------
# sessions: zero-fault pinning
# ---------------------------------------------------------------------------


class TestZeroFaultPinning:
    @pytest.mark.parametrize("cut", FA_CUTS)
    @pytest.mark.parametrize("bits", ALL_BITS)
    def test_bitexact_with_split_executor(self, fa_setup, cut, bits):
        """Acceptance: faults disabled => OffloadSession output is
        bit-exact with the PR-5 split executor at every cut x bits."""
        ex, fj, base, offs = fa_setup
        off = offs[(cut, bits)]
        want, payload = off(fj)
        sess = OffloadSession(off, link=BACKSCATTER)
        got, rec = sess.send(fj)
        _assert_result_equal(want, got)
        assert rec.delivered and rec.attempts == 1 and rec.lost == 0
        assert rec.payload_bytes == pytest.approx(
            payload.nbytes() + SESSION_SIDEBAND_BYTES)
        assert rec.bytes_on_air == pytest.approx(rec.payload_bytes)

    def test_disabled_injector_identical_to_no_injector(self, fa_setup):
        """Satellite: zero-fault injection byte-identical to no injector."""
        ex, fj, base, offs = fa_setup
        off = offs[("nn", 8)]
        s_none = OffloadSession(off, link=BACKSCATTER)
        s_disabled = OffloadSession(off, link=BACKSCATTER,
                                    injector=FaultInjector(seed=123))
        for _ in range(3):
            r_none, _ = s_none.send(fj)
            r_dis, _ = s_disabled.send(fj)
        _assert_result_equal(r_none, r_dis)
        assert [dataclasses.asdict(r) for r in s_none.records] == \
               [dataclasses.asdict(r) for r in s_disabled.records]
        assert np.array_equal(s_none.attempt_trace(),
                              s_disabled.attempt_trace())

    def test_receiver_sideband_contract(self, fa_setup):
        ex, fj, base, offs = fa_setup
        off = offs[("nn", 8)]
        sess = OffloadSession(off, link=BACKSCATTER)
        for _ in range(3):
            sess.send(fj)
        seqs = [int(sb["seq"]) for sb in sess.received]
        assert seqs == [0, 1, 2] and sess.seq_gaps() == []
        sb = sess.received[0]
        assert sb["seq"].dtype == np.uint32
        assert sb["crc"].dtype == np.uint32
        assert sb["attempt"].dtype == np.int32
        assert int(sb["crc"]) == payload_checksum(off.encode(fj))


# ---------------------------------------------------------------------------
# sessions: faults charged for real
# ---------------------------------------------------------------------------


class TestFaultedDelivery:
    def test_retries_charge_bytes_and_congest_the_trace(self, fa_setup):
        ex, fj, base, offs = fa_setup
        off = offs[("nn", 8)]
        inj = FaultInjector(loss=GilbertElliott(p_gb=0.4, p_bg=0.4), seed=7)
        sess = OffloadSession(off, link=BACKSCATTER, injector=inj)
        clean = OffloadSession(off, link=BACKSCATTER)
        for _ in range(12):
            sess.send(fj)
            clean.send(fj)
        retrans = sum(r.attempts - 1 for r in sess.records)
        assert retrans > 0
        assert sess.bytes_on_air == pytest.approx(sum(
            r.attempts * r.payload_bytes for r in sess.records))
        assert sess.bytes_on_air > clean.bytes_on_air
        # every retransmission re-enters the shared-link trace
        assert float(sess.attempt_trace().sum()) > \
            float(clean.attempt_trace().sum())
        # and session latency paid the timeouts + backoff
        assert sess.now > clean.now

    def test_fault_sweep_is_deterministic_under_seed(self, fa_setup):
        ex, fj, base, offs = fa_setup
        off = offs[("nn", 8)]
        inj = FaultInjector(loss=GilbertElliott(p_gb=0.3, p_bg=0.3),
                            corrupt_fraction=0.4, seed=11)
        runs = []
        for _ in range(2):
            inj.reset()
            sess = OffloadSession(off, link=BACKSCATTER, injector=inj)
            for _ in range(10):
                sess.send(fj)
            runs.append([dataclasses.asdict(r) for r in sess.records])
        assert runs[0] == runs[1]

    def test_corruption_is_detected_not_timed_out(self, fa_setup):
        """A corrupt delivery pays the full transmit + a NACK round trip,
        never the sender timeout — the checksum is what catches it."""
        ex, fj, base, offs = fa_setup
        off = offs[("nn", 8)]
        ge = GilbertElliott(p_gb=0.0, p_bg=1.0, loss_good=1.0)
        inj = FaultInjector(loss=ge, corrupt_fraction=1.0, seed=3)
        sess = OffloadSession(off, link=BACKSCATTER, injector=inj,
                              max_retries=2)
        got, rec = sess.send(fj)
        assert rec.corrupt == rec.attempts and rec.lost == 0
        assert not rec.delivered and got is None
        assert sess.seq_gaps() == [0] or sess.received == []

    def test_exhausted_retries_leave_a_seq_gap(self, fa_setup):
        ex, fj, base, offs = fa_setup
        off = offs[("nn", 8)]
        inj = FaultInjector(loss=GilbertElliott(p_gb=1.0, p_bg=0.0,
                                                loss_good=1.0), seed=0)
        sess = OffloadSession(off, link=BACKSCATTER, injector=inj,
                              max_retries=1)
        got, rec = sess.send(fj)
        assert got is None and not rec.delivered
        assert rec.attempts == 2    # first try + one retry
        assert rec.bytes_on_air == pytest.approx(2 * rec.payload_bytes)


# ---------------------------------------------------------------------------
# brownout recovery from commit points
# ---------------------------------------------------------------------------


class TestBrownoutRecovery:
    def test_resumes_from_last_commit_not_capture(self, fa_setup, tmp_path):
        """Acceptance: a brownout mid-funnel restores the last committed
        stage and re-enters there — upstream stages run exactly once."""
        ex, fj, base, offs = fa_setup
        off = offs[("nn", 8)]
        want, _ = off(fj)
        bo = BrownoutModel(harvest_w=15e-6, storage_j=13e-6, load_w=200e-6,
                           jitter=0.0)     # on-window ~0.07 s < 5 x 0.02 s
        inj = FaultInjector(brownout=bo, seed=5)
        sess = OffloadSession(off, link=BACKSCATTER, injector=inj,
                              ckpt_dir=str(tmp_path), stage_cost_s=0.02)
        got, rec = sess.send(fj)
        assert rec.brownouts >= 1 and rec.restores >= 1
        assert rec.recovery_s > 0
        # the funnel prefix upstream of the brownout was NOT recomputed
        assert sess.stage_completed["motion"] == 1
        assert sess.stage_completed["detect"] == 1
        assert sess.stage_completed["gather"] == 1
        assert sess.stage_started["nn"] == rec.brownouts + 1
        # and the staged, recovered result equals the fused split executor
        _assert_result_equal(want, got)

    def test_second_send_reuses_runner_and_recovers_again(self, fa_setup,
                                                          tmp_path):
        ex, fj, base, offs = fa_setup
        off = offs[("nn", 8)]
        want, _ = off(fj)
        bo = BrownoutModel(harvest_w=15e-6, storage_j=13e-6, load_w=200e-6,
                           jitter=0.0)
        inj = FaultInjector(brownout=bo, seed=5)
        sess = OffloadSession(off, link=BACKSCATTER, injector=inj,
                              ckpt_dir=str(tmp_path), stage_cost_s=0.02)
        for _ in range(2):
            got, rec = sess.send(fj)
            _assert_result_equal(want, got)
        assert sess.records[1].brownouts >= 1

    def test_commit_points_live_in_the_checkpoint_store(self, fa_setup,
                                                        tmp_path):
        from repro.ckpt.checkpoint import latest_step

        ex, fj, base, offs = fa_setup
        off = offs[("vj", 8)]
        bo = BrownoutModel(harvest_w=15e-6, storage_j=20e-6, load_w=200e-6,
                           jitter=0.0)
        inj = FaultInjector(brownout=bo, seed=2)
        sess = OffloadSession(off, link=BACKSCATTER, injector=inj,
                              ckpt_dir=str(tmp_path), stage_cost_s=0.02)
        sess.send(fj)
        step = latest_step(str(tmp_path))
        assert step is not None
        # the newest commit is the vj cut's last stage, tagged with its seq
        import json
        import os
        with open(os.path.join(str(tmp_path), f"step_{step:08d}",
                               "manifest.json")) as f:
            extra = json.load(f)["extra"]
        assert extra["stage"] == "gather" and extra["seq"] == 0


# ---------------------------------------------------------------------------
# degradation ladder
# ---------------------------------------------------------------------------


def _rec(seq, attempts=1, delivered=True, fallback=False, latency=0.01):
    return DeliveryRecord(
        seq=seq, cut="nn", bits=16, delivered=delivered, fallback=fallback,
        attempts=attempts, lost=attempts - 1, corrupt=0, payload_bytes=100.0,
        bytes_on_air=100.0 * attempts, compute_s=0.0, latency_s=latency,
        energy_j=0.0, brownouts=0, restores=0, recovery_s=0.0)


class TestDegradationLadderPolicy:
    RUNGS = [("nn", 16), ("nn", 8), ("nn", 4), ON_NODE]

    def test_zero_fault_never_moves(self):
        lad = DegradationLadder(self.RUNGS, window=4)
        for i in range(50):
            lad.observe(_rec(i))
        assert lad.level == 0 and lad.transitions == []

    def test_delivery_failure_descends_immediately(self):
        lad = DegradationLadder(self.RUNGS)
        lad.observe(_rec(0, delivered=False))
        assert lad.rung == ("nn", 8)
        lad.observe(_rec(1, delivered=False))
        lad.observe(_rec(2, delivered=False))
        lad.observe(_rec(3, delivered=False))   # clamps at terminal
        assert lad.rung == ON_NODE

    def test_sustained_retries_descend(self):
        lad = DegradationLadder(self.RUNGS, window=4, max_retry_frac=0.3)
        for i in range(4):
            lad.observe(_rec(i, attempts=3))
        assert lad.level == 1

    def test_clean_streak_recovers_with_hysteresis(self):
        lad = DegradationLadder(self.RUNGS, window=4, recover_after=6)
        lad.observe(_rec(0, delivered=False))
        assert lad.level == 1
        for i in range(1, 6):
            lad.observe(_rec(i))
        assert lad.level == 1                   # not yet: hysteresis
        lad.observe(_rec(6))
        assert lad.level == 0
        assert lad.transitions == [(0, 0, 1), (6, 1, 0)]

    def test_deadline_breaches_descend(self):
        lad = DegradationLadder(self.RUNGS, window=4, deadline_s=0.1,
                                max_retry_frac=0.9)
        for i in range(4):
            lad.observe(_rec(i, latency=0.5))
        assert lad.level == 1

    def test_duplicate_rungs_rejected(self):
        with pytest.raises(ValueError, match="duplicate"):
            DegradationLadder([("nn", 8), ("nn", 8)])


class TestLadderEndToEnd:
    def test_ladder_absorbs_10pct_burst_loss_within_bounds(self, fa_setup):
        """Acceptance: <=10% burst loss on BACKSCATTER => auth decisions
        within 2% flipped vs fault-free, energy under fault-free x1.5."""
        ex, fj, base, offs = fa_setup
        make = lambda cut, bits: offs[(cut, bits)]    # noqa: E731
        rungs = [("nn", 16), ("nn", 8), ("nn", 4), ON_NODE]
        n_sends = 30

        def run(injector):
            sess = OffloadSession(
                make_executor=make, cut="nn", bits=16, link=BACKSCATTER,
                injector=injector, ladder=DegradationLadder(list(rungs)),
                on_node_fn=lambda f: ex(f))
            auths = []
            for _ in range(n_sends):
                got, rec = sess.send(fj)
                assert got is not None          # ladder never drops a frame
                auths.append(np.asarray(got.auth))
            return sess, auths

        base_sess, base_auth = run(None)
        # stationary loss = 0.05 / (0.05 + 0.45) = 10%, mean burst 2.2
        ge = GilbertElliott(p_gb=0.05, p_bg=0.45)
        faulty_sess, faulty_auth = run(FaultInjector(loss=ge, seed=21))
        flipped = np.mean([np.mean(a != b)
                           for a, b in zip(base_auth, faulty_auth)])
        assert flipped <= 0.02, f"flipped {flipped:.3%} of auth decisions"
        assert faulty_sess.energy_j <= 1.5 * base_sess.energy_j
        # and the faults were real: retransmissions actually happened
        assert sum(r.attempts - 1 for r in faulty_sess.records) > 0

    def test_hard_faults_walk_down_to_on_node(self, fa_setup):
        """Retries exhausted send after send: the ladder must reach the
        terminal rung and the on-node fallback must deliver exact
        (fused-executor) decisions."""
        ex, fj, base, offs = fa_setup
        make = lambda cut, bits: offs[(cut, bits)]    # noqa: E731
        # long deep fades: mostly-bad chain
        ge = GilbertElliott(p_gb=0.9, p_bg=0.1)
        inj = FaultInjector(loss=ge, seed=13)
        sess = OffloadSession(
            make_executor=make, cut="nn", bits=16, link=BACKSCATTER,
            injector=inj, max_retries=0,
            ladder=DegradationLadder(
                [("nn", 16), ("nn", 8), ("nn", 4), ON_NODE]),
            on_node_fn=lambda f: ex(f))
        results = [sess.send(fj) for _ in range(12)]
        assert sess.ladder.rung == ON_NODE
        fallbacks = [r for res, r in results if r.fallback and r.delivered]
        assert fallbacks, "no fallback delivery ever made it through"
        for res, r in results:
            if r.fallback and r.delivered:
                _assert_result_equal(base, res)
        # sends made AT the terminal rung ship only the decision —
        # orders of magnitude below the nn cut's payload
        terminal = [r for res, r in results if r.cut == "on_node"]
        assert terminal
        assert all(r.payload_bytes < 100 for r in terminal)


# ---------------------------------------------------------------------------
# congestion re-entry
# ---------------------------------------------------------------------------


class TestFleetCongestion:
    def test_retries_congest_neighboring_streams(self, fa_setup):
        ex, fj, base, offs = fa_setup
        off = offs[("nn", 8)]

        def fleet(with_faults):
            sessions = []
            for s in range(3):
                inj = (FaultInjector(loss=GilbertElliott(p_gb=0.5, p_bg=0.3),
                                     seed=s) if with_faults and s == 0
                       else None)
                sess = OffloadSession(off, link=BACKSCATTER, injector=inj)
                for _ in range(6):
                    sess.send(fj)
                sessions.append(sess)
            # globally-triggered rig: streams contend in every frame slot,
            # so queueing behind stream 0's retries is structural rather
            # than dependent on whether one burst outlasts the stagger gap
            return fleet_link_report(sessions, BACKSCATTER,
                                     frame_period_s=1.0, stagger=False)

        clean = fleet(False)
        congested = fleet(True)
        assert congested.bytes_total > clean.bytes_total
        # stream 0's retries queue against streams 1 and 2
        assert congested.latency_s[1:].max() > clean.latency_s[1:].max()
        assert congested.p99_latency_s >= clean.p99_latency_s

    def test_empty_sessions_rejected(self):
        with pytest.raises(ValueError, match="no sends"):
            fleet_link_report(
                [OffloadSession(_FakeSplitExec("src", 10.0),
                                link=BACKSCATTER)],
                BACKSCATTER, frame_period_s=1.0)


# ---------------------------------------------------------------------------
# VR sessions
# ---------------------------------------------------------------------------


class TestVRSessions:
    @pytest.fixture(scope="class")
    def vr_setup(self):
        from repro.camera.bssa import GridSpec
        from repro.camera.pipelines import VRRigExecutor
        from repro.camera.synthetic import stereo_pair

        views = [stereo_pair(h=48, w=64, max_disp=4, seed=2 + s)[:2]
                 for s in range(2)]
        lefts = jnp.stack([v[0] for v in views])
        rights = jnp.stack([v[1] for v in views])
        base = VRRigExecutor(GridSpec(sigma_spatial=8), max_disp=4,
                             n_iters=2, rig_parallel=False)
        return base, lefts, rights

    @pytest.mark.parametrize("cut", VROffloadExecutor.CUTS)
    def test_zero_fault_bitexact(self, vr_setup, cut):
        base, lefts, rights = vr_setup
        off = VROffloadExecutor(base, cut, bits=8)
        (lp0, rp0), _ = off(lefts, rights)
        sess = OffloadSession(off, link=BACKSCATTER)
        (lp, rp), rec = sess.send(lefts, rights)
        assert np.array_equal(np.asarray(lp0), np.asarray(lp))
        assert np.array_equal(np.asarray(rp0), np.asarray(rp))
        assert rec.delivered

    def test_vr_brownout_recovery(self, vr_setup, tmp_path):
        base, lefts, rights = vr_setup
        off = VROffloadExecutor(base, "stitch", bits=8)
        (lp0, rp0), _ = off(lefts, rights)
        bo = BrownoutModel(harvest_w=15e-6, storage_j=9e-6, load_w=200e-6,
                           jitter=0.0)     # on ~0.049 s < 3 x 0.02 s
        inj = FaultInjector(brownout=bo, seed=6)
        sess = OffloadSession(off, link=BACKSCATTER, injector=inj,
                              ckpt_dir=str(tmp_path), stage_cost_s=0.02)
        (lp, rp), rec = sess.send(lefts, rights)
        assert rec.brownouts >= 1 and rec.restores >= 1
        assert sess.stage_completed["depth"] == 1
        assert np.array_equal(np.asarray(lp0), np.asarray(lp))
        assert np.array_equal(np.asarray(rp0), np.asarray(rp))
