"""Static analyzer (repro.analysis, DESIGN.md §11).

Two layers:

* synthetic-violation units — tiny hand-built jaxprs/specs that each
  violate exactly one contract, pinning that every pass family actually
  fires (and stays quiet on the sanctioned variant);
* the repo gate — the real tree analyzed end-to-end must report nothing
  beyond the checked-in baseline (tier-1's "no new violations" contract).
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.analysis.passes import (
    CutPass, DispatchPass, KernelPass, ObsPass, PassContext, PrecisionPass)
from repro.analysis.report import AnalysisReport, Baseline, Finding, PassResult
from repro.analysis.spec import (
    DivCheck, FnPair, KernelAnalysisSpec, KernelPlan, Tile, adapt_block,
    signature_mismatches)
from repro.camera.offload.payloads import PayloadSchema


def _codes(findings):
    return sorted({f.code for f in findings})


def _dispatch_lint(fn, *args):
    return DispatchPass()._lint("synth", jax.make_jaxpr(fn)(*args))


def _precision_lint(fn, *args):
    return PrecisionPass()._lint("synth", jax.make_jaxpr(fn)(*args))


# ---------------------------------------------------------------------------
# dispatch family
# ---------------------------------------------------------------------------


class TestDispatchPass:
    def test_nested_pmap_flagged(self):
        out = _dispatch_lint(lambda x: jax.pmap(lambda y: y * 2)(x),
                             jnp.zeros((1, 4)))
        assert "D001" in _codes(out)

    def test_debug_callback_flagged(self):
        def fn(x):
            jax.debug.print("x = {}", x)
            return x + 1

        assert "D003" in _codes(_dispatch_lint(fn, jnp.zeros((3,))))

    def test_f64_promotion_point_flagged(self):
        with jax.experimental.enable_x64(True):
            out = _dispatch_lint(lambda x: x.astype(jnp.float64),
                                 jnp.zeros((3,), jnp.float32))
        assert "D004" in _codes(out)
        assert not jax.config.jax_enable_x64      # context restored

    def test_unguarded_gather_flagged_guarded_quiet(self):
        x = jnp.arange(8.0)
        i = jnp.array([1, 2])
        bad = _dispatch_lint(lambda x, i: x[i], x, i)
        assert "D005" in _codes(bad)
        good = _dispatch_lint(lambda x, i: x[jnp.clip(i, 0, 7)], x, i)
        assert "D005" not in _codes(good)
        # fill-mode gathers are self-guarding
        fill = _dispatch_lint(lambda x, i: jnp.take(x, i), x, i)
        assert "D005" not in _codes(fill)

    def test_unclamped_cast_flagged_clamped_quiet(self):
        x = jnp.zeros((3,), jnp.float32)
        bad = _dispatch_lint(lambda x: x.astype(jnp.int32), x)
        assert "D006" in _codes(bad)
        good = _dispatch_lint(
            lambda x: jnp.clip(x, 0, 10).astype(jnp.int32), x)
        assert "D006" not in _codes(good)


# ---------------------------------------------------------------------------
# precision family
# ---------------------------------------------------------------------------


class TestPrecisionPass:
    def test_unscaled_dequant_flagged_scaled_quiet(self):
        q = jnp.zeros((4,), jnp.int8)
        bad = _precision_lint(
            lambda q: jnp.sum(q.astype(jnp.float32) + 1.0), q)
        assert "P001" in _codes(bad)
        good = _precision_lint(
            lambda q: jnp.sum(q.astype(jnp.float32) * 0.5), q)
        assert "P001" not in _codes(good)

    def test_unclipped_quant_cast_flagged(self):
        x = jnp.zeros((4,), jnp.float32)
        bad = _precision_lint(lambda x: x.astype(jnp.int8), x)
        assert "P002" in _codes(bad)
        good = _precision_lint(
            lambda x: jnp.clip(x, -127, 127).astype(jnp.int8), x)
        assert "P002" not in _codes(good)

    def test_narrow_dot_without_wide_accum_flagged(self):
        a = jnp.zeros((4, 4), jnp.int8)
        dn = (((1,), (0,)), ((), ()))
        bad = _precision_lint(
            lambda a, b: jax.lax.dot_general(a, b, dn), a, a)
        assert "P004" in _codes(bad)
        good = _precision_lint(
            lambda a, b: jax.lax.dot_general(
                a, b, dn, preferred_element_type=jnp.int32), a, a)
        assert "P004" not in _codes(good)

    def test_lut_meta_drift_flagged(self):
        from repro.analysis.registry import ExecutorTarget
        from repro.camera.face_nn import make_sigmoid_lut

        lut, meta = make_sigmoid_lut()
        clean = ExecutorTarget("synth", None, (), lut_pairs=((lut, meta),))
        assert PrecisionPass()._lut_spec(clean) == []
        drifted = ExecutorTarget(
            "synth", None, (),
            lut_pairs=((lut.at[3].set(0.5), meta),))
        assert _codes(PrecisionPass()._lut_spec(drifted)) == ["P003"]


# ---------------------------------------------------------------------------
# kernel family
# ---------------------------------------------------------------------------


def _synth_kernel_ctx(plan_fn, *, name="synth_kernel", pairs=(),
                      shapes=None, missing=()):
    spec = KernelAnalysisSpec(name, list(pairs), plan_fn)
    return PassContext(
        targets=[], cut_families=[], kernel_specs=[spec],
        kernel_missing=list(missing),
        kernel_shapes={name: shapes} if shapes is not None else {})


class TestKernelPass:
    def test_nondivisible_blockspec_flagged(self):
        def plan(case):
            return KernelPlan(case["case"], grid=(3,),
                              tiles=[Tile("in", (33, 128))],
                              checks=[DivCheck("h % block_h", 100, 33)])

        res = KernelPass().run(_synth_kernel_ctx(
            plan, shapes=[{"case": "c0"}]))
        assert _codes(res.findings) == ["K001"]

    def test_vmem_budget_flagged(self):
        def plan(case):
            return KernelPlan(case["case"], grid=(1,),
                              tiles=[Tile("big", (4096, 4096))],  # 64 MiB f32
                              checks=[])

        res = KernelPass().run(_synth_kernel_ctx(
            plan, shapes=[{"case": "c0"}]))
        assert _codes(res.findings) == ["K002"]

    def test_signature_drift_flagged(self):
        def kernel(a, b, *, block_m=8, mystery=1, interpret=False):
            return a

        def ref(a, b):
            return a

        msgs = signature_mismatches(
            FnPair(kernel, ref, frozenset({"block_m", "interpret"})))
        assert any("mystery" in m for m in msgs)
        res = KernelPass().run(_synth_kernel_ctx(
            lambda case: KernelPlan(case["case"], (1,), [], []),
            pairs=[FnPair(kernel, ref, frozenset({"block_m", "interpret"}))],
            shapes=[{"case": "c0"}]))
        assert "K003" in _codes(res.findings)

    def test_missing_shapes_and_hook_flagged(self):
        res = KernelPass().run(_synth_kernel_ctx(
            lambda case: KernelPlan("c", (1,), [], []),
            shapes=None, missing=["ghost_kernel"]))
        assert _codes(res.findings) == ["K004", "K005"]

    def test_adapt_block_matches_wrapper_convention(self):
        assert adapt_block(144, 32) == 24      # largest divisor <= 32
        assert adapt_block(100, 33) == 25
        assert adapt_block(7, 32) == 7
        assert adapt_block(5, 3) == 1


# ---------------------------------------------------------------------------
# cut family
# ---------------------------------------------------------------------------


def _cut_ctx(exec_cls, template_blocks):
    from repro.analysis.registry import CutFamily

    fam = CutFamily(
        name="synth_fam", executor_cls=exec_cls,
        make=lambda cut, bits: exec_cls(),
        node_args=lambda ex: (jnp.zeros((2,), jnp.float32),),
        template_blocks=tuple(template_blocks))
    return PassContext(targets=[], cut_families=[fam], kernel_specs=[],
                       kernel_missing=[], kernel_shapes={})


class TestCutPass:
    def test_undeclared_payload_field_flagged(self):
        class Exec:
            CUTS = ("a",)
            PAYLOAD_SCHEMA = {"a": PayloadSchema(i32=("n",))}

            def _node_fn(self, x):
                return ({"n": jnp.zeros((), jnp.int32),
                         "stowaway": jnp.zeros((64,), jnp.float32)}, 0.0)

        res = CutPass().run(_cut_ctx(Exec, ("a",)))
        hits = [f for f in res.findings if f.code == "C001"]
        assert hits and all(f.where == "stowaway" for f in hits)

    def test_codec_layout_drift_flagged(self):
        # 300 logical values -> nb=2 blocks of 256 -> packed must be
        # (2, 256) int8 + (2, 1) scales; shipping (2, 100) hides padding
        class Exec:
            CUTS = ("a",)
            PAYLOAD_SCHEMA = {"a": PayloadSchema(codec=("x",))}

            def __init__(self):
                self.bits = None

            def _node_fn(self, v):
                if self.bits is None:
                    return ({"x": jnp.zeros((300,), jnp.float32)}, 0.0)
                return ({"x": jnp.zeros((2, 100), jnp.int8),
                         "x_scales": jnp.zeros((2, 1), jnp.float32)}, 0.0)

        from repro.analysis.registry import CutFamily

        def make(cut, bits):
            ex = Exec()
            ex.bits = bits
            return ex

        fam = CutFamily("synth_fam", Exec, make,
                        lambda ex: (jnp.zeros((2,), jnp.float32),), ("a",))
        ctx = PassContext(targets=[], cut_families=[fam], kernel_specs=[],
                          kernel_missing=[], kernel_shapes={})
        res = CutPass().run(ctx)
        assert "C003" in _codes(res.findings)

    def test_unknown_cut_flagged(self):
        class Exec:
            CUTS = ("rogue",)
            PAYLOAD_SCHEMA = {"rogue": PayloadSchema()}

            def _node_fn(self, x):
                return ({}, 0.0)

        res = CutPass().run(_cut_ctx(Exec, ("a", "b")))
        assert "C004" in _codes(res.findings)

    def test_sideband_dtype_discipline_flagged(self):
        class Exec:
            CUTS = ("a",)
            PAYLOAD_SCHEMA = {"a": PayloadSchema(i32=("n",))}

            def _node_fn(self, x):
                # charged at 4 B/entry but shipped as f32 — dtype drift
                return ({"n": jnp.zeros((), jnp.float32)}, 0.0)

        res = CutPass().run(_cut_ctx(Exec, ("a",)))
        assert "C005" in _codes(res.findings)

    def test_missing_schema_flagged(self):
        class Exec:
            CUTS = ("a",)
            PAYLOAD_SCHEMA = {}

            def _node_fn(self, x):
                return ({}, 0.0)

        res = CutPass().run(_cut_ctx(Exec, ("a",)))
        assert "C002" in _codes(res.findings)

    # -- C006: session-layer sideband discipline ----------------------------

    def test_undeclared_session_sideband_flagged(self):
        """Single violation: a schema that never declares the seq/crc/
        attempt sideband the resilience runtime charges per attempt."""
        class Exec:
            CUTS = ("a",)
            PAYLOAD_SCHEMA = {"a": PayloadSchema(i32=("n",))}   # session=()

            def _node_fn(self, x):
                return ({"n": jnp.zeros((), jnp.int32)}, 0.0)

        res = CutPass().run(_cut_ctx(Exec, ("a",)))
        hits = [f for f in res.findings if f.code == "C006"]
        assert {f.where for f in hits} == {"seq", "crc", "attempt"}
        assert all("not declared" in f.message for f in hits)

    def test_declared_session_sideband_quiet(self):
        from repro.camera.offload.payloads import SESSION_SIDEBAND_NAMES

        class Exec:
            CUTS = ("a",)
            PAYLOAD_SCHEMA = {"a": PayloadSchema(
                i32=("n",), session=SESSION_SIDEBAND_NAMES)}

            def _node_fn(self, x):
                return ({"n": jnp.zeros((), jnp.int32)}, 0.0)

        res = CutPass().run(_cut_ctx(Exec, ("a",)))
        assert "C006" not in _codes(res.findings)

    def test_unknown_session_field_flagged(self):
        from repro.camera.offload.payloads import SESSION_SIDEBAND_NAMES

        class Exec:
            CUTS = ("a",)
            PAYLOAD_SCHEMA = {"a": PayloadSchema(
                session=SESSION_SIDEBAND_NAMES + ("hmac",))}

            def _node_fn(self, x):
                return ({}, 0.0)

        res = CutPass().run(_cut_ctx(Exec, ("a",)))
        hits = [f for f in res.findings if f.code == "C006"]
        assert [f.where for f in hits] == ["hmac"]
        assert "unknown sideband" in hits[0].message

    def test_session_dtype_discipline_enforced_on_spec(self):
        """A family whose session spec strays from int32/uint32 fails the
        4 B/attempt charge contract even with names declared."""
        from repro.analysis.registry import CutFamily

        class Exec:
            CUTS = ("a",)
            PAYLOAD_SCHEMA = {"a": PayloadSchema(session=("seq",))}

            def _node_fn(self, x):
                return ({}, 0.0)

        fam = CutFamily("synth_fam", Exec, lambda cut, bits: Exec(),
                        lambda ex: (jnp.zeros((2,), jnp.float32),), ("a",),
                        session_spec=(("seq", "float32"),))
        ctx = PassContext(targets=[], cut_families=[fam], kernel_specs=[],
                          kernel_missing=[], kernel_shapes={})
        res = CutPass().run(ctx)
        hits = [f for f in res.findings if f.code == "C006"]
        assert hits and "int32/uint32 only" in hits[0].message

    def test_session_name_collision_with_payload_flagged(self):
        from repro.camera.offload.payloads import SESSION_SIDEBAND_NAMES

        class Exec:
            CUTS = ("a",)
            PAYLOAD_SCHEMA = {"a": PayloadSchema(
                i32=("seq",), session=SESSION_SIDEBAND_NAMES)}

            def _node_fn(self, x):
                # node half emits an array named like the session framing
                return ({"seq": jnp.zeros((), jnp.int32)}, 0.0)

        res = CutPass().run(_cut_ctx(Exec, ("a",)))
        hits = [f for f in res.findings
                if f.code == "C006" and "collides" in f.message]
        assert [f.where for f in hits] == ["seq"]


# ---------------------------------------------------------------------------
# obs family (telemetry-plane contracts, DESIGN.md §15)
# ---------------------------------------------------------------------------


class TestObsPass:
    def test_undeclared_target_flagged_declared_quiet(self):
        from repro.analysis.registry import ExecutorTarget

        rogue = ExecutorTarget("rogue.target", lambda x: x + 1,
                               (jnp.zeros((2,)),))
        known = ExecutorTarget("face_auth.funnel", lambda x: x + 1,
                               (jnp.zeros((2,)),))
        ctx = PassContext(targets=[rogue, known], cut_families=[],
                          kernel_specs=[], kernel_missing=[],
                          kernel_shapes={})
        res = ObsPass().run(ctx)
        hits = [f for f in res.findings if f.code == "O001"]
        assert [f.subject for f in hits] == ["rogue.target"]

    def test_parameterized_names_resolve_to_stems(self):
        """fa_offload[nn,8].node-style names must hit fa_offload.node."""
        from repro.analysis.registry import ExecutorTarget

        named = [ExecutorTarget(n, lambda x: x, (jnp.zeros((2,)),))
                 for n in ("fa_offload[nn,8].node", "vr_offload[depth,raw]"
                           ".cloud", "serve.batch_step[3x4]",
                           "codec.roundtrip[b8]")]
        ctx = PassContext(targets=named, cut_families=[], kernel_specs=[],
                          kernel_missing=[], kernel_shapes={})
        res = ObsPass().run(ctx)
        assert "O001" not in _codes(res.findings)

    def test_telemetry_in_payload_flagged(self):
        """Single violation: a node half that smuggles a tel_ counter
        into the WirePayload (uncharged sideband bytes — O002)."""
        class Exec:
            CUTS = ("a",)
            PAYLOAD_SCHEMA = {"a": PayloadSchema(i32=("n",))}

            def _node_fn(self, x):
                return ({"n": jnp.zeros((), jnp.int32),
                         "tel_windows": jnp.zeros((), jnp.int32)}, 0.0)

        res = ObsPass().run(_cut_ctx(Exec, ("a",)))
        hits = [f for f in res.findings if f.code == "O002"]
        assert hits and all(f.where == "tel_windows" for f in hits)

    def test_telemetry_in_schema_flagged(self):
        """A PayloadSchema that ADMITS a tel_ field is just as wrong as a
        node half that emits one."""
        class Exec:
            CUTS = ("a",)
            PAYLOAD_SCHEMA = {"a": PayloadSchema(i32=("n", "tel_auth"))}

            def _node_fn(self, x):
                return ({"n": jnp.zeros((), jnp.int32)}, 0.0)

        res = ObsPass().run(_cut_ctx(Exec, ("a",)))
        hits = [f for f in res.findings if f.code == "O002"]
        assert "tel_auth" in {f.where for f in hits}

    def test_bad_counter_dtype_flagged(self, monkeypatch):
        from repro.analysis.registry import ExecutorTarget
        from repro.obs import counters as obs_counters

        monkeypatch.setitem(obs_counters.TELEMETRY_AUX, "synth.widectr",
                            (("frames", "int64"),))
        tgt = ExecutorTarget("synth.widectr", lambda x: x,
                             (jnp.zeros((2,)),))
        ctx = PassContext(targets=[tgt], cut_families=[], kernel_specs=[],
                          kernel_missing=[], kernel_shapes={})
        res = ObsPass().run(ctx)
        hits = [f for f in res.findings if f.code == "O003"]
        assert [f.where for f in hits] == ["frames"]

    def test_clean_family_quiet(self):
        class Exec:
            CUTS = ("a",)
            PAYLOAD_SCHEMA = {"a": PayloadSchema(i32=("n",))}

            def _node_fn(self, x):
                return ({"n": jnp.zeros((), jnp.int32)}, 0.0)

        res = ObsPass().run(_cut_ctx(Exec, ("a",)))
        assert res.findings == []
        assert "synth_fam[a]" in res.subjects


# ---------------------------------------------------------------------------
# report / baseline mechanics
# ---------------------------------------------------------------------------


class TestReport:
    def _finding(self, **kw):
        base = dict(family="dispatch", code="D004", subject="s",
                    where="0:foo", message="msg")
        base.update(kw)
        return Finding(**base)

    def test_fingerprint_ignores_message(self):
        a = self._finding(message="one")
        b = self._finding(message="two")
        assert a.fingerprint == b.fingerprint
        assert a.fingerprint != self._finding(where="1:bar").fingerprint

    def test_baseline_roundtrip_and_strict(self, tmp_path):
        rep = AnalysisReport(
            [PassResult("dispatch", ["s"], [self._finding()])])
        assert len(rep.new_findings(Baseline())) == 1
        bl = Baseline.from_report(rep)
        path = str(tmp_path / "baseline.json")
        bl.save(path)
        assert rep.new_findings(Baseline.load(path)) == []
        # strict mode = no baseline at all
        assert len(rep.new_findings(None)) == 1
        totals = rep.to_dict(Baseline.load(path))["totals"]
        assert totals == {"subjects": 1, "findings": 1, "baselined": 1,
                          "non_baselined": 0}


# ---------------------------------------------------------------------------
# regressions for the violations the first full run surfaced (fixed at the
# source, NOT baselined — the repo gate below keeps them from returning)
# ---------------------------------------------------------------------------


class TestFixedViolations:
    def test_sigmoid_lut_defined_at_infinities(self):
        """Pre-fix, the LUT index was cast-then-clipped: an inf
        pre-activation hit a backend-defined float->int cast.  Now the clip
        happens in float, so saturation is exact at both ends."""
        from repro.camera.face_nn import make_sigmoid_lut, sigmoid_lut

        lut, meta = make_sigmoid_lut()
        x = jnp.array([jnp.inf, -jnp.inf, 0.0, 1e9, -1e9])
        y = np.asarray(sigmoid_lut(x, lut, meta))
        assert y[0] == y[3] == float(lut[-1])
        assert y[1] == y[4] == float(lut[0])
        # in-range values unchanged by the reordering
        xs = jnp.linspace(-8.0, 8.0, 77)
        lo, hi, entries = meta
        idx = np.clip(((np.asarray(xs) - lo) / (hi - lo)
                       * (entries - 1)).astype(np.int32), 0, entries - 1)
        assert np.array_equal(np.asarray(sigmoid_lut(xs, lut, meta)),
                              np.asarray(lut)[idx])

    def test_cylindrical_warp_defined_at_extreme_angles(self):
        """Pre-fix, tan/cos blowing up near the cylinder edge fed a
        backend-defined float->int cast; the masked-out lanes must still
        index in-bounds and come out exactly 0."""
        from repro.camera.stitch import cylindrical_warp

        img = jnp.ones((16, 64)) * 3.0
        # f small enough that |theta| sweeps past pi/2 inside the grid
        out = np.asarray(cylindrical_warp(img, f=8.0))
        assert np.all(np.isfinite(out))
        assert set(np.unique(out)) <= {0.0, 3.0}

    def test_splat_and_slice_roundtrip_unchanged(self):
        """The clip-before-cast reorder in splat/slice_grid must be
        value-identical for finite images: a constant field survives the
        splat -> refine -> slice roundtrip exactly as before."""
        from repro.camera.bssa import GridSpec, slice_grid, splat

        rng = np.random.RandomState(0)
        img = jnp.asarray(rng.rand(24, 32).astype(np.float32))
        spec = GridSpec(sigma_spatial=8)
        gv, gw = splat(img, jnp.full(img.shape, 2.5), spec)
        out = np.asarray(slice_grid(gv, gw, img, spec))
        np.testing.assert_allclose(out, 2.5, atol=1e-4)


# ---------------------------------------------------------------------------
# the repo gate: real tree vs checked-in baseline
# ---------------------------------------------------------------------------


class TestRepoGate:
    def test_tree_has_no_non_baselined_findings(self):
        from repro.analysis import run_analysis

        report = run_analysis()
        new = report.new_findings(Baseline.load())
        assert new == [], "non-baselined findings:\n" + "\n".join(
            f"  {f}" for f in new)
        # coverage floor: all four registered executors + 7 kernel packages
        subs = report.subjects
        assert len(subs["kernel"]) == 7
        dispatch_subjects = " ".join(subs["dispatch"])
        for must in ("face_auth.funnel", "vr_rig.depth", "vr_rig.panorama",
                     "fa_offload", "vr_offload",
                     "serve.group_step_degraded[vj,4]",
                     "serve.restore_rescore"):
            assert must in dispatch_subjects
        # §15 gate: every dispatch target is also obs-audited (O001 needs
        # full coverage to mean anything), plus one subject per offload cut
        assert set(subs["dispatch"]) <= set(subs["obs"])
        obs_subjects = " ".join(subs["obs"])
        assert "face_auth[nn]" in obs_subjects
        assert "vr_video[stitch]" in obs_subjects
