"""ckpt/checkpoint.py coverage: the brownout-recovery substrate.

The resilience runtime (DESIGN.md §12) commits funnel stage state through
these primitives, so their contracts — atomic save, round-tripped extra
metadata, torn-save immunity, keep-N pruning — are load-bearing for fault
recovery, not just for training restarts.
"""

import json
import os

import numpy as np
import pytest

from repro.ckpt.checkpoint import (
    latest_step,
    prune_old,
    restore_checkpoint,
    save_checkpoint,
)


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "frames": rng.normal(size=(4, 8, 8)).astype(np.float32),
        "fidx": np.arange(4, dtype=np.int32),
        "valid": np.array([True, False, True, True]),
        "nested": {"w": rng.normal(size=(3, 2)).astype(np.float32)},
    }


class TestRoundTrip:
    def test_save_restore_round_trip_with_extra(self, tmp_path):
        tree = _tree(1)
        extra = {"stage": "gather", "seq": 7}
        path = save_checkpoint(str(tmp_path), 3, tree, extra=extra)
        assert os.path.isdir(path) and not path.endswith(".tmp")
        got, got_extra = restore_checkpoint(str(tmp_path), 3, tree)
        assert got_extra == extra
        for k in ("frames", "fidx", "valid"):
            assert np.array_equal(np.asarray(got[k]), tree[k]), k
        assert np.array_equal(np.asarray(got["nested"]["w"]),
                              tree["nested"]["w"])

    def test_restore_casts_to_like_tree_dtype(self, tmp_path):
        tree = {"x": np.arange(6, dtype=np.float32)}
        save_checkpoint(str(tmp_path), 0, tree)
        got, _ = restore_checkpoint(str(tmp_path), 0, tree)
        assert np.asarray(got["x"]).dtype == np.float32

    def test_restore_rejects_shape_drift(self, tmp_path):
        save_checkpoint(str(tmp_path), 0, {"x": np.zeros((4,))})
        with pytest.raises(ValueError, match="shape drift"):
            restore_checkpoint(str(tmp_path), 0, {"x": np.zeros((5,))})

    def test_restore_missing_leaf_is_a_keyerror(self, tmp_path):
        save_checkpoint(str(tmp_path), 0, {"x": np.zeros((2,))})
        with pytest.raises(KeyError, match="missing leaf"):
            restore_checkpoint(str(tmp_path), 0,
                               {"x": np.zeros((2,)), "y": np.zeros((2,))})


class TestLatestStep:
    def test_empty_dir_is_none(self, tmp_path):
        assert latest_step(str(tmp_path)) is None

    def test_nonexistent_dir_is_none(self, tmp_path):
        assert latest_step(str(tmp_path / "nope")) is None

    def test_ignores_torn_tmp_saves(self, tmp_path):
        save_checkpoint(str(tmp_path), 1, _tree())
        # a crash mid-save leaves a .tmp dir with no rename — must not win
        os.makedirs(str(tmp_path / "step_00000009.tmp"))
        assert latest_step(str(tmp_path)) == 1

    def test_ignores_dir_without_manifest(self, tmp_path):
        save_checkpoint(str(tmp_path), 2, _tree())
        # renamed dir whose manifest never landed (corrupt save)
        os.makedirs(str(tmp_path / "step_00000005"))
        assert latest_step(str(tmp_path)) == 2

    def test_newest_complete_manifest_wins(self, tmp_path):
        for s in (1, 4, 2):
            save_checkpoint(str(tmp_path), s, _tree(s))
        assert latest_step(str(tmp_path)) == 4


class TestPruneOld:
    def test_keep_n_preserves_newest(self, tmp_path):
        for s in range(6):
            save_checkpoint(str(tmp_path), s, _tree(s))
        prune_old(str(tmp_path), keep=2)
        assert latest_step(str(tmp_path)) == 5
        kept = sorted(d for d in os.listdir(str(tmp_path))
                      if d.startswith("step_"))
        assert kept == ["step_00000004", "step_00000005"]
        # survivors still restore
        got, _ = restore_checkpoint(str(tmp_path), 5, _tree())
        assert np.array_equal(np.asarray(got["fidx"]),
                              np.arange(4, dtype=np.int32))

    def test_prune_missing_dir_is_noop(self, tmp_path):
        prune_old(str(tmp_path / "never"), keep=3)

    def test_prune_skips_torn_saves(self, tmp_path):
        for s in range(3):
            save_checkpoint(str(tmp_path), s, _tree(s))
        os.makedirs(str(tmp_path / "step_00000007.tmp"))
        prune_old(str(tmp_path), keep=1)
        assert latest_step(str(tmp_path)) == 2
        assert os.path.isdir(str(tmp_path / "step_00000007.tmp"))


class TestAtomicity:
    def test_resave_same_step_replaces(self, tmp_path):
        save_checkpoint(str(tmp_path), 0, {"x": np.zeros((2,))})
        save_checkpoint(str(tmp_path), 0, {"x": np.ones((2,))})
        got, _ = restore_checkpoint(str(tmp_path), 0, {"x": np.zeros((2,))})
        assert np.array_equal(np.asarray(got["x"]), np.ones((2,)))

    def test_manifest_records_leaves(self, tmp_path):
        path = save_checkpoint(str(tmp_path), 1, _tree())
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        names = {l["name"] for l in manifest["leaves"]}
        assert {"frames", "fidx", "valid", "nested/w"} <= names
