"""Fleet telemetry plane (DESIGN.md §15).

Pins the three contracts the ISSUE names verbatim plus the satellite-2
ordering fix:

* **counters-off bit-identity** — a telemetry-disabled (or absent)
  build is the uninstrumented build: same jaxpr, bit-identical outputs
  at every offload cut, and an enabled build never perturbs the real
  outputs either (counters are *extra* aux, never a rewrite);
* **counter conservation across checkpoint/restore** — CounterPanel /
  Telemetry state round-trips exactly, and a StreamingServer restored
  mid-drive carries its counter totals and SLO ledger forward;
* **trace ids unique per run** — eids are unique and monotone within a
  recorder, run_ids are distinct across recorders, and both survive the
  JSONL round trip;
* **sorted-sid shed/audit order** (satellite 2 regression) —
  ``TickReport.shed`` and ``seq_audit`` walk streams in sorted-sid
  order regardless of registration order.

Unit coverage for the obs primitives (rung_key, SLOLedger attribution,
bench.v1 diffing, Perfetto export, kill-chain reconstruction) rides in
the same file so the whole §15 surface lives in one place.
"""

import json
import math

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.obs import (CounterPanel, SLOLedger, Telemetry, TraceRecorder,
                       rung_key)
from repro.obs.bench import (bench_record, diff_bench, format_diff,
                             load_bench, write_bench)
from repro.obs.counters import (ALLOWED_DTYPES, TELEMETRY_AUX, graph_counter,
                                graph_counters, telemetry_decl)
from repro.obs.trace import TraceRecord, kind_counts, perfetto_events
from repro.obs.telemetry import telemetry_on


# ---------------------------------------------------------------------------
# shared FA workload (mirrors tests/test_serving_chaos.py's fixture)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def fa_setup():
    from benchmarks.workloads import fa_cascade, fa_scan
    from repro.camera.face_nn import train_face_nn
    from repro.camera.pipelines import FaceAuthExecutor
    from repro.camera.synthetic import face_dataset, security_video

    frames, _truth = security_video(n_frames=10, motion_frames=5, seed=1)
    casc = fa_cascade(smoke=True)
    X, y, _ = face_dataset(n_per_class=80, seed=3)
    nn = train_face_nn(X, y, steps=60)
    sf, st, ad = fa_scan(True)

    def make(telemetry=None):
        ex = FaceAuthExecutor(casc, nn, frames.shape[1], frames.shape[2],
                              scale_factor=sf, step=st, adaptive=ad,
                              telemetry=telemetry)
        ex.calibrate(frames)
        return ex

    ex = make()
    return make, ex, frames, ex(jnp.asarray(frames))


def _server(ex, *, chunk=2, capacity=2, chaos=None, telemetry=None, **kw):
    from repro.camera.serve import ServeConfig, StreamingServer

    kw.setdefault("max_queue_s", 100.0)
    cfg = ServeConfig(chunk=chunk, capacity=capacity, tick_s=1.0, **kw)
    return StreamingServer(ex, config=cfg, chaos=chaos, telemetry=telemetry)


FA_FIELDS = ("motion", "n_windows", "n_auth", "scores", "window_id",
             "window_valid", "auth", "windows_dropped", "motion_dropped",
             "cascade_dropped")


def _same_result(a, b):
    return all(bool(np.array_equal(np.asarray(getattr(a, f)),
                                   np.asarray(getattr(b, f))))
               for f in FA_FIELDS)


# ---------------------------------------------------------------------------
# ISSUE contract 1: counters-off path is bit-identical at every cut
# ---------------------------------------------------------------------------


class TestCountersOffBitIdentity:
    def test_disabled_executor_traces_identical_jaxpr(self, fa_setup):
        make, ex, frames, base = fa_setup
        off = make(telemetry=Telemetry(enabled=False))
        fj = jnp.asarray(frames)
        jx_plain = jax.make_jaxpr(ex._funnel)(fj, *ex._consts)
        jx_off = jax.make_jaxpr(off._funnel)(fj, *off._consts)
        assert str(jx_plain) == str(jx_off)
        # enabled builds do add aux outputs (the counters are real) ...
        on = make(telemetry=Telemetry(enabled=True))
        jx_on = jax.make_jaxpr(on._funnel)(fj, *on._consts)
        assert str(jx_on) != str(jx_plain)
        # ... but never perturb the real outputs
        assert _same_result(base, off(fj))
        assert _same_result(base, on(fj))

    def test_session_bit_identical_at_every_cut(self, fa_setup):
        from repro.camera.offload import (BACKSCATTER,
                                          FaceAuthOffloadExecutor,
                                          OffloadSession)

        make, ex, frames, base = fa_setup
        fj = jnp.asarray(frames)
        for cut in FaceAuthOffloadExecutor.CUTS:
            off = FaceAuthOffloadExecutor(ex, cut, bits=8)
            want, _ = off(fj)
            for tel in (None, Telemetry(enabled=False),
                        Telemetry(enabled=True)):
                got, rec = OffloadSession(off, link=BACKSCATTER,
                                          telemetry=tel,
                                          sid="cam0").send(fj)
                assert rec.delivered
                assert _same_result(want, got), (cut, tel)

    def test_enabled_session_counts_enabled_only(self, fa_setup):
        from repro.camera.offload import (BACKSCATTER,
                                          FaceAuthOffloadExecutor,
                                          OffloadSession)

        make, ex, frames, base = fa_setup
        fj = jnp.asarray(frames)
        off = FaceAuthOffloadExecutor(ex, "nn", bits=8)
        tel_off = Telemetry(enabled=False)
        OffloadSession(off, link=BACKSCATTER, telemetry=tel_off).send(fj)
        assert tel_off.counters.totals() == {}
        assert len(tel_off.trace) == 0
        tel = Telemetry(enabled=True)
        OffloadSession(off, link=BACKSCATTER, telemetry=tel,
                       sid="cam0").send(fj)
        tot = tel.counters.totals()
        assert tot["offload.sends"] == 1
        assert tot["offload.delivered"] == 1
        assert tot["offload.attempts"] == 1
        assert tot["offload.bytes_on_air"] > 0
        (link_ev,) = tel.trace.records("link")
        assert link_ev.sid == "cam0" and link_ev.args["delivered"]
        assert tel.ledger.keys() == [("cam0", "nn@8")]

    def test_funnel_counters_match_real_outputs(self, fa_setup):
        make, ex, frames, base = fa_setup
        tel = Telemetry(enabled=True)
        on = make(telemetry=tel)
        res = on(jnp.asarray(frames))
        tot = tel.counters.totals()
        assert tot["fa.windows"] == int(np.sum(np.asarray(res.n_windows)))
        assert tot["fa.auth"] == int(np.sum(np.asarray(res.n_auth)))
        assert tot["fa.motion_dropped"] == int(res.motion_dropped)
        assert tot["fa.cascade_dropped"] == int(
            np.sum(np.asarray(res.cascade_dropped)))


# ---------------------------------------------------------------------------
# ISSUE contract 2: counter totals conserve across checkpoint/restore
# ---------------------------------------------------------------------------


class TestCounterConservation:
    def test_panel_state_roundtrip_exact(self):
        p = CounterPanel()
        p.bump("a", 3)
        p.add("a", jnp.asarray(4, jnp.int32))      # device-lazy path
        p.add("b", jnp.asarray(7, jnp.int32))
        before = p.totals()
        assert before == {"a": 7, "b": 7}
        q = CounterPanel()
        q.load_state(p.state_dict())
        assert q.totals() == before
        q.bump("a")                                 # keeps accumulating
        assert q.totals()["a"] == 8

    def test_panel_merge_conserves_sum(self):
        a, b = CounterPanel(), CounterPanel()
        a.bump("x", 2)
        b.bump("x", 5)
        b.bump("y", 1)
        a.merge(b)
        assert a.totals() == {"x": 7, "y": 1}

    def test_disabled_panel_stays_empty(self):
        p = CounterPanel(enabled=False)
        p.bump("a")
        p.add("b", jnp.asarray(1, jnp.int32))
        out = p.consume({"tel_c": jnp.asarray(2, jnp.int32), "real": 9})
        assert out == {"real": 9}                  # tel_ keys still popped
        assert p.totals() == {}

    def test_telemetry_state_roundtrip(self):
        tel = Telemetry(enabled=True)
        tel.counters.bump("serve.dispatches", 11)
        tel.ledger.observe_latency("a", ("nn", 8), 0.5)
        tel.ledger.observe_auth("a", ("nn", 8), np.array([1, 0, 1]),
                                np.array([1, 1, 1]))
        tel2 = Telemetry(enabled=True)
        tel2.load_state(tel.state_dict())
        assert tel2.counters.totals() == tel.counters.totals()
        assert tel2.ledger.flip_counts() == (1, 3)
        assert tel2.ledger.keys() == tel.ledger.keys()
        # the restored run records its ancestry but keeps its own run_id
        (rst,) = tel2.trace.records("ckpt")
        assert rst.args["parent_run"] == tel.run_id
        assert tel2.run_id != tel.run_id

    def test_server_counters_survive_restore(self, fa_setup, tmp_path):
        make, ex, frames, base = fa_setup
        tel = Telemetry(enabled=True)
        srv = _server(ex, telemetry=tel)
        srv.register("a", fps=1.0)
        for i in range(3):
            srv.enqueue("a", frames[i], t=float(i) * 0.1)
        srv.tick(1.0)
        before = tel.counters.totals()
        assert before.get("serve.dispatches", 0) >= 1
        srv.checkpoint(str(tmp_path))

        from repro.camera.serve import StreamingServer

        tel2 = Telemetry(enabled=True)
        srv2 = StreamingServer.restore(str(tmp_path), ex,
                                       config=srv.cfg, telemetry=tel2)
        assert tel2.counters.totals() == before
        srv2.enqueue("a", frames[3], t=1.5)
        srv2.tick(2.0)
        after = tel2.counters.totals()
        # totals continue from the restored baseline, never reset
        assert after["serve.dispatches"] > before["serve.dispatches"]
        assert srv2.seq_audit()["ok"]

    def test_restore_without_telemetry_key_is_fine(self, fa_setup, tmp_path):
        # pre-PR-10 checkpoints carry no "telemetry" extra; restoring
        # with telemetry enabled must start from zero, not crash
        make, ex, frames, base = fa_setup
        srv = _server(ex)                          # no telemetry recorded
        srv.register("a", fps=1.0)
        srv.enqueue("a", frames[0], t=0.0)
        srv.tick(1.0)
        srv.checkpoint(str(tmp_path))

        from repro.camera.serve import StreamingServer

        tel = Telemetry(enabled=True)
        srv2 = StreamingServer.restore(str(tmp_path), ex,
                                       config=srv.cfg, telemetry=tel)
        srv2.enqueue("a", frames[1], t=1.5)
        srv2.tick(2.0)
        assert tel.counters.totals().get("serve.dispatches", 0) >= 1


# ---------------------------------------------------------------------------
# ISSUE contract 3: trace ids unique per run
# ---------------------------------------------------------------------------


class TestTraceIds:
    def test_eids_unique_and_monotone(self):
        tr = TraceRecorder()
        eids = [tr.emit("tick", f"t{i}", t=float(i)) for i in range(50)]
        assert eids == sorted(eids) == list(range(50))
        assert len({r.eid for r in tr.records()}) == 50
        assert all(r.run_id == tr.run_id for r in tr.records())

    def test_run_ids_distinct_across_recorders(self):
        ids = {TraceRecorder().run_id for _ in range(8)}
        assert len(ids) == 8

    def test_jsonl_roundtrip_preserves_ids(self, tmp_path):
        tr = TraceRecorder()
        tr.emit("tick", "t0", t=0.0, dur=1.0, tick=0, sid="a", n=3)
        tr.emit("link", "send[nn@8]", t=0.5, sid="a", attempts=2)
        path = str(tmp_path / "trace.jsonl")
        assert tr.to_jsonl(path) == 2
        back = TraceRecorder.load_jsonl(path)
        assert back == tr.records()
        assert kind_counts(back) == {"link": 1, "tick": 1}

    def test_ring_keeps_newest_and_counts_drops(self):
        tr = TraceRecorder(capacity=4)
        for i in range(10):
            tr.emit("tick", f"t{i}")
        assert len(tr) == 4
        assert tr.dropped == 6
        assert [r.eid for r in tr.records()] == [6, 7, 8, 9]

    def test_perfetto_export_well_formed(self, tmp_path):
        tr = TraceRecorder()
        tr.emit("tick", "t0", t=1.0, dur=0.5, tick=0)
        tr.emit("chaos", "device_kill", t=1.25, tick=0, device=1)
        path = str(tmp_path / "trace.json")
        assert tr.export_perfetto(path) == 2
        doc = json.load(open(path))
        evs = doc["traceEvents"]
        assert doc["otherData"]["run_id"] == tr.run_id
        span = next(e for e in evs if e["cat"] == "tick")
        assert span["ph"] == "X" and span["dur"] == pytest.approx(0.5e6)
        assert span["ts"] == pytest.approx(1e6)
        inst = next(e for e in evs if e["cat"] == "chaos")
        assert inst["ph"] == "i"
        # distinct kinds land on distinct tid lanes, one pid per run
        assert span["tid"] != inst["tid"]
        assert {e["pid"] for e in evs} == {1}
        assert all("eid" in e["args"] for e in evs)

    def test_disabled_telemetry_emit_is_noop(self):
        tel = Telemetry(enabled=False)
        assert tel.emit("tick", "t0") == -1
        assert len(tel.trace) == 0
        assert not telemetry_on(tel) and not telemetry_on(None)
        assert telemetry_on(Telemetry(enabled=True))


# ---------------------------------------------------------------------------
# satellite 2 regression: sorted-sid shed + seq_audit order
# ---------------------------------------------------------------------------


class TestSortedSidOrder:
    def test_shed_and_audit_sorted_regardless_of_registration(self,
                                                              fa_setup):
        make, ex, frames, base = fa_setup
        srv = _server(ex, max_queue_frames=2)
        for sid in ("zeta", "alpha", "mid"):       # non-sorted insertion
            srv.register(sid, fps=1.0)
        for k in range(5):
            for sid in ("zeta", "alpha", "mid"):
                srv.enqueue(sid, frames[k % len(frames)], t=float(k))
        rep = srv.tick(1.0)
        shed_sids = [s.sid for s in rep.shed]
        assert shed_sids == sorted(shed_sids) == ["alpha", "mid", "zeta"]
        assert all(s.seqs == tuple(sorted(s.seqs)) for s in rep.shed)
        audit = srv.seq_audit()
        assert audit["ok"]
        assert list(audit["streams"]) == sorted(audit["streams"])

    def test_order_stable_after_churn_reregister(self, fa_setup):
        # the pre-PR-10 bug: dict insertion order diverges from audit
        # order once a stream is unregistered, reaped, and re-registered
        make, ex, frames, base = fa_setup
        srv = _server(ex, max_queue_frames=2)
        for sid in ("a", "b"):
            srv.register(sid, fps=1.0)
        srv.enqueue("a", frames[0], t=0.0)
        srv.unregister("a")
        srv.tick(1.0)                              # drains + reaps "a"
        srv.register("a", fps=1.0)                 # now inserted AFTER "b"
        for k in range(5):
            for sid in ("a", "b"):
                srv.enqueue(sid, frames[k % len(frames)], t=1.0 + k)
        rep = srv.tick(2.0)
        assert [s.sid for s in rep.shed] == ["a", "b"]
        assert srv.seq_audit()["ok"]


# ---------------------------------------------------------------------------
# SLO ledger: rung keys + flip attribution
# ---------------------------------------------------------------------------


class TestLedger:
    def test_rung_key_canonicalization(self):
        assert rung_key(("nn", 16)) == "nn@16"
        assert rung_key(("vj", None)) == "vj@raw"
        assert rung_key(("on_node", None)) == "on_node"
        assert rung_key("on_node") == "on_node"
        assert rung_key((None, None)) == "local"
        assert rung_key(None) == "none"

    def test_flip_attribution_by_rung(self):
        led = SLOLedger()
        ref = np.array([1, 1, 0, 1])
        led.observe_auth("a", ("nn", 16), ref, ref)          # clean rung
        led.observe_auth("a", ("nn", 8), np.array([1, 0, 0, 0]), ref)
        assert led.flip_counts(rung=("nn", 16)) == (0, 4)
        assert led.flip_counts(rung=("nn", 8)) == (2, 4)
        assert led.flip_counts(sid="a") == (2, 8)
        assert led.flip_rate(rung=("nn", 8)) == pytest.approx(0.5)

    def test_dropped_frame_counts_all_units_flipped(self):
        led = SLOLedger()
        led.observe_auth("a", "on_node", None, np.zeros(6, bool))
        assert led.flip_counts() == (6, 6)
        assert led.flip_rate() == 1.0

    def test_latency_percentiles_and_slo(self):
        led = SLOLedger(slo_s=0.1)
        for i in range(10):
            led.observe_latency("a", ("nn", 8), 0.01 * (i + 1))
        pct = led.latency_percentiles(sid="a")
        assert pct["p50"] == pytest.approx(0.055)
        assert led.slo_violations() == 0
        led.observe_latency("a", "on_node", 0.5)
        assert led.slo_violations() == 1
        assert math.isnan(led.latency_percentiles(sid="ghost")["p50"])

    def test_report_rows_and_state_roundtrip(self):
        led = SLOLedger(slo_s=0.2)
        led.observe_latency("a", ("nn", 8), 0.05)
        led.observe_auth("a", ("nn", 8), np.array([1]), np.array([0]))
        led2 = SLOLedger()
        led2.load_state(led.state_dict())
        assert led2.slo_s == 0.2
        (row,) = led2.report()
        assert row["sid"] == "a" and row["rung"] == "nn@8"
        assert row["flipped"] == 1 and row["compared"] == 1
        assert row["p50"] == pytest.approx(0.05)


# ---------------------------------------------------------------------------
# counters registry + dtype law (analyzer O001/O003 ground truth)
# ---------------------------------------------------------------------------


class TestCounterPrimitives:
    def test_graph_counter_dtype_law(self):
        assert graph_counter(3).dtype == jnp.int32
        assert graph_counter(3, "uint32").dtype == jnp.uint32
        with pytest.raises(ValueError, match="int32"):
            graph_counter(3, "int64")
        with pytest.raises(ValueError):
            graph_counter(3, "float32")

    def test_graph_counters_prefix_and_shape(self):
        aux = graph_counters(windows=jnp.arange(3).sum(), auth=2)
        assert set(aux) == {"tel_windows", "tel_auth"}
        assert all(v.shape == () for v in aux.values())

    def test_telemetry_decl_parameterized_names(self):
        assert telemetry_decl("face_auth.funnel") == \
            TELEMETRY_AUX["face_auth.funnel"]
        assert telemetry_decl("fa_offload[nn,8].node") == ()
        assert telemetry_decl("serve.batch_step[3x4]") == \
            TELEMETRY_AUX["serve.batch_step"]
        assert telemetry_decl("codec.roundtrip[b8]") == ()
        assert telemetry_decl("rogue.target") is None

    def test_registry_dtypes_all_legal(self):
        for stem, decl in TELEMETRY_AUX.items():
            for cname, dtype in decl:
                assert dtype in ALLOWED_DTYPES, (stem, cname)


# ---------------------------------------------------------------------------
# bench.v1 schema + machine diff
# ---------------------------------------------------------------------------


class TestBenchSchema:
    ROWS = [("fa", "speedup", "3.2", "vs loop"),
            ("fa", "parity", "identical", "")]

    def test_record_shape(self):
        rec = bench_record("fa", self.ROWS, 1.5, smoke=True)
        assert rec["schema"] == "bench.v1"
        assert rec["section"] == "fa" and rec["smoke"] is True
        assert rec["wall_s"] == 1.5
        assert all(isinstance(c, str) for row in rec["rows"] for c in row)

    def test_diff_ignores_volatile_keys(self):
        a = bench_record("fa", self.ROWS, 1.5, smoke=True, generated_at=1.0)
        b = bench_record("fa", self.ROWS, 9.9, smoke=False, generated_at=2.0)
        d = diff_bench(a, b)
        assert d["identical"]
        assert "identical" in format_diff(d)

    def test_diff_flags_changed_added_removed(self):
        a = bench_record("fa", self.ROWS, 1.0)
        b = bench_record("fa", [("fa", "speedup", "2.9", "vs loop"),
                                ("fa", "new_metric", "1", "")], 1.0)
        d = diff_bench(a, b)
        assert not d["identical"]
        assert d["changed"] == [{"key": ["fa", "speedup"],
                                 "a": "3.2", "b": "2.9"}]
        assert d["added"] == [["fa", "new_metric"]]
        assert d["removed"] == [["fa", "parity"]]
        txt = format_diff(d)
        assert "~ fa/speedup: 3.2 -> 2.9" in txt

    def test_load_upgrades_legacy_files(self, tmp_path):
        legacy = tmp_path / "BENCH_old.json"
        legacy.write_text(json.dumps(
            {"section": "fa", "wall_s": 2.0, "rows": self.ROWS}))
        rec = load_bench(str(legacy))
        assert rec["schema"] == "legacy"
        fresh = bench_record("fa", self.ROWS, 1.0)
        write_bench(str(tmp_path / "BENCH_new.json"), fresh)
        assert diff_bench(rec, load_bench(
            str(tmp_path / "BENCH_new.json")))["identical"]


# ---------------------------------------------------------------------------
# kill-chain reconstruction from records alone (§15 acceptance shape)
# ---------------------------------------------------------------------------


def _chain_records(with_failover=True):
    recs = [
        dict(kind="tick", name="tick", tick=1, args={"n_served": 2}),
        dict(kind="chaos", name="device_kill", tick=2, args={"device": 1}),
        dict(kind="failover", name="reshard", tick=2,
             args={}) if with_failover else None,
        dict(kind="ladder", name="descend", tick=3, args={}),
        dict(kind="chaos", name="device_restore", tick=5,
             args={"device": 1}),
        dict(kind="tick", name="tick", tick=6, args={"n_served": 2}),
    ]
    return [r for r in recs if r is not None]


class TestKillChain:
    def test_full_chain_detected(self):
        from benchmarks.serving_chaos import kill_chain

        chain = kill_chain(_chain_records())
        assert chain["ok"]
        assert chain["kill_tick"] == 2 and chain["failover_tick"] == 2
        assert chain["descend_tick"] == 3 and chain["restore_tick"] == 5
        assert chain["recovered_tick"] == 6

    def test_missing_link_breaks_chain(self):
        from benchmarks.serving_chaos import kill_chain

        assert not kill_chain(_chain_records(with_failover=False))["ok"]
        assert not kill_chain([])["ok"]

    def test_accepts_trace_records(self):
        from benchmarks.serving_chaos import kill_chain

        recs = [TraceRecord(eid=i, run_id="r", kind=d["kind"],
                            name=d["name"], t=float(d["tick"]), dur=0.0,
                            tick=d["tick"], sid="", args=d["args"])
                for i, d in enumerate(_chain_records())]
        assert kill_chain(recs)["ok"]


# ---------------------------------------------------------------------------
# dashboard + CLI render without a server in the loop
# ---------------------------------------------------------------------------


class TestReporting:
    def _tel(self):
        tel = Telemetry(enabled=True, slo_s=0.2)
        tel.counters.bump("serve.dispatches", 4)
        tel.emit("tick", "tick", t=0.0, dur=1.0, tick=0)
        tel.ledger.observe_latency("a", ("nn", 8), 0.05)
        tel.ledger.observe_auth("a", ("nn", 8), np.array([1]),
                                np.array([1]))
        return tel

    def test_fleet_dashboard_renders(self):
        from repro.obs import fleet_dashboard

        tel = self._tel()
        txt = fleet_dashboard(counters=tel.counters.totals(),
                              ledger=tel.ledger,
                              records=tel.trace.records(),
                              run_id=tel.run_id)
        assert "serve.dispatches" in txt
        assert "nn@8" in txt
        assert tel.run_id in txt

    def test_cli_summary_and_trace(self, tmp_path, capsys):
        from repro.obs.cli import main

        tel = self._tel()
        jl = str(tmp_path / "t.jsonl")
        tel.trace.to_jsonl(jl)
        assert main(["trace", jl]) == 0
        assert "tick" in capsys.readouterr().out
        pf = str(tmp_path / "t.perfetto.json")
        assert main(["trace", jl, "--perfetto", pf]) == 0
        capsys.readouterr()
        assert json.load(open(pf))["traceEvents"]

        bench = str(tmp_path / "BENCH_fa.json")
        write_bench(bench, bench_record("fa", TestBenchSchema.ROWS, 1.0))
        assert main(["summary", bench]) == 0
        assert "speedup" in capsys.readouterr().out
        assert main(["diff", bench, bench]) == 0
        assert "identical" in capsys.readouterr().out
