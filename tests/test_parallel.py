"""Multi-device distribution tests (8 fake CPU devices via subprocess —
smoke tests must keep seeing one device, so the flag is set per-subprocess).

Covers: shard_map MoE (EP + TP) vs the local oracle, the manual-FSDP dense
path vs plain einsum, compressed pod all-reduce vs exact psum, and a full
sharded train step."""

import jax
import pytest


def test_moe_ep_matches_local(subproc):
    out = subproc("""
import jax, jax.numpy as jnp, numpy as np, dataclasses
from repro.configs.registry import SMOKE_CONFIGS
from repro.models.moe import moe_ffn, _moe_local, moe_specs
from repro.models.layers import init_params
from repro.parallel.axes import use_sharding
cfg = SMOKE_CONFIGS['deepseek-v2-236b']
cfg = dataclasses.replace(cfg, param_dtype=jnp.float32,
    moe=dataclasses.replace(cfg.moe, capacity_factor=32.0, parallelism='ep'))
m = cfg.moe
params = init_params(moe_specs(cfg, m), jax.random.PRNGKey(0))
x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, cfg.d_model), jnp.float32)
mesh = jax.make_mesh((2, 4), ('data', 'model'))
with use_sharding(mesh):
    y_sharded, aux_s = jax.jit(lambda p, x: moe_ffn(p, cfg, m, x))(params, x)
routed = {k: v for k, v in params.items() if k != 'shared'}
y_local, aux_l = _moe_local(routed, m, x.reshape(-1, cfg.d_model))
y_local = y_local.reshape(x.shape)
if m.n_shared:
    from repro.models.layers import dense
    sh = params['shared']
    g = jnp.einsum('...d,df->...f', x, sh['w_gate'])
    u = jnp.einsum('...d,df->...f', x, sh['w_up'])
    y_local = y_local + jnp.einsum('...f,fd->...d', jax.nn.silu(g) * u, sh['w_down'])
err = float(jnp.max(jnp.abs(y_sharded - y_local)))
print('EP_ERR', err)
assert err < 2e-4, err
""")
    assert "EP_ERR" in out


def test_moe_tp_matches_local(subproc):
    out = subproc("""
import jax, jax.numpy as jnp, dataclasses
from repro.configs.registry import SMOKE_CONFIGS
from repro.models.moe import moe_ffn, _moe_local, moe_specs
from repro.models.layers import init_params
from repro.parallel.axes import use_sharding
cfg = SMOKE_CONFIGS['mixtral-8x22b']
cfg = dataclasses.replace(cfg, param_dtype=jnp.float32,
    moe=dataclasses.replace(cfg.moe, capacity_factor=32.0, parallelism='tp'))
m = cfg.moe
params = init_params(moe_specs(cfg, m), jax.random.PRNGKey(0))
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model), jnp.float32)
mesh = jax.make_mesh((2, 4), ('data', 'model'))
with use_sharding(mesh):
    y_sharded, _ = jax.jit(lambda p, x: moe_ffn(p, cfg, m, x))(params, x)
y_local, _ = _moe_local(params, m, x.reshape(-1, cfg.d_model))
err = float(jnp.max(jnp.abs(y_sharded - y_local.reshape(x.shape))))
print('TP_ERR', err)
assert err < 2e-4, err
""")
    assert "TP_ERR" in out


@pytest.mark.skipif(
    not hasattr(jax, "shard_map"),
    reason="partial-manual dense needs jax.shard_map(axis_names=...); the "
           "0.4.x experimental auto= fallback trips XLA's manual-subgroup "
           "check inside sharding constraints")
def test_manual_fsdp_dense_matches_einsum(subproc):
    subproc("""
import jax, jax.numpy as jnp
from repro.models.layers import dense
from repro.parallel.axes import use_sharding
mesh = jax.make_mesh((2, 4), ('data', 'model'))
x = jax.random.normal(jax.random.PRNGKey(0), (4, 8, 32), jnp.float32)
w = jax.random.normal(jax.random.PRNGKey(1), (32, 64), jnp.float32)
ref = jnp.einsum('bsd,df->bsf', x, w)
with use_sharding(mesh, {'manual_fsdp': True, 'seq': 'model', 'embed': 'model',
                         'batch': ('pod', 'data'), 'mlp': None}):
    y = jax.jit(lambda w, x: dense(w, x, 'bsd,df->bsf', waxes=('embed', 'mlp')))(w, x)
err = float(jnp.max(jnp.abs(y - ref)))
print('DENSE_ERR', err)
assert err < 1e-5, err

# gradient path: d/dw must equal plain einsum's
def loss_manual(w):
    with use_sharding(mesh, {'manual_fsdp': True, 'seq': 'model',
                             'embed': 'model', 'mlp': None}):
        return jnp.sum(dense(w, x, 'bsd,df->bsf', waxes=('embed', 'mlp')) ** 2)
def loss_plain(w):
    return jnp.sum(jnp.einsum('bsd,df->bsf', x, w) ** 2)
g1 = jax.jit(jax.grad(loss_manual))(w)   # framework paths are always jit'd
g2 = jax.grad(loss_plain)(w)
gerr = float(jnp.max(jnp.abs(g1 - g2)))
print('GRAD_ERR', gerr)
assert gerr < 1e-3, gerr
""")


def test_compressed_pod_allreduce_close_to_exact(subproc):
    subproc("""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core.reduction import EFState, compressed_pod_allreduce
mesh = jax.make_mesh((2, 4), ('pod', 'data'))
g = jax.random.normal(jax.random.PRNGKey(0), (8, 256), jnp.float32)

def body(g_shard, ef):
    out, new_ef = compressed_pod_allreduce(g_shard, EFState(ef), pod_axis='pod',
                                           inner_axes=('data',))
    return out, new_ef.residual

from repro.parallel.axes import compat_shard_map
fn = compat_shard_map(body, mesh=mesh,
                      in_specs=(P(('pod', 'data')), P(('pod', 'data'))),
                      out_specs=(P(('pod', 'data')), P(('pod', 'data'))),
                      check_vma=False)
ef0 = jnp.zeros_like(g)
out, res = jax.jit(fn)(g, ef0)
# exact: full psum over both axes
exact = compat_shard_map(lambda s: jax.lax.psum(s, ('pod', 'data')), mesh=mesh,
                         in_specs=P(('pod', 'data')),
                         out_specs=P(('pod', 'data')),
                         check_vma=False)(g)
rel = float(jnp.linalg.norm(out - exact) / jnp.linalg.norm(exact))
print('AR_REL', rel)
assert rel < 0.02, rel     # int8 quantization error, bounded
""")


def test_sharded_train_step_runs_and_matches_single_device(subproc):
    subproc("""
import jax, jax.numpy as jnp, dataclasses, numpy as np
from repro.configs.registry import SMOKE_CONFIGS
from repro.models.transformer import Model
from repro.models.layers import param_shardings
from repro.parallel.axes import use_sharding
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.step import make_train_step

cfg = dataclasses.replace(SMOKE_CONFIGS['yi-9b'], param_dtype=jnp.float32)
model = Model(cfg)
params = model.init(jax.random.PRNGKey(0))
opt = init_opt_state(params)
batch = {'tokens': jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab)}
step = make_train_step(model, AdamWConfig(warmup_steps=1))

# single device reference
p1, o1, m1 = jax.jit(step)(params, opt, batch)

mesh = jax.make_mesh((2, 4), ('data', 'model'))
with use_sharding(mesh) as ctx:
    shardings = param_shardings(model.specs(), ctx)
    params_s = jax.device_put(params, shardings)
    opt_s = init_opt_state(params_s)
    p2, o2, m2 = jax.jit(step)(params_s, opt_s, batch)
d = abs(float(m1['loss']) - float(m2['loss']))
print('LOSS_DELTA', d)
assert d < 2e-3, d
leaves1 = jax.tree_util.tree_leaves(p1)
leaves2 = jax.tree_util.tree_leaves(p2)
worst = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(leaves1, leaves2))
print('PARAM_DELTA', worst)
assert worst < 5e-2, worst
""")
