"""Offload runtime tests: split-executor exactness vs the fused funnels,
wire-payload byte accounting vs the analytic cost model (the drift fence),
link-simulator semantics, and the measurement-driven cut controller."""

import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.camera.offload import (
    BACKSCATTER,
    CutController,
    FaceAuthOffloadExecutor,
    LinkProfile,
    VROffloadExecutor,
    WirePayload,
    link_energy_w,
    simulate_shared_link,
)
from repro.camera.pipelines import (
    FAWorkloadStats,
    FaceAuthExecutor,
    calibrate_fa,
    fa_pipeline,
    fa_profiles,
)
from repro.core.costmodel import HardwareProfile
from repro.core.pipeline import linear_pipeline

FA_CUTS = ("sensor", "motion", "vj", "nn")
_RESULT_FIELDS = ("motion", "n_windows", "n_auth", "scores", "window_id",
                  "window_valid", "auth", "windows_dropped", "motion_dropped",
                  "cascade_dropped")


@pytest.fixture(scope="module")
def fa_setup():
    from benchmarks.workloads import fa_cascade, fa_scan
    from repro.camera.face_nn import train_face_nn
    from repro.camera.synthetic import face_dataset, security_video

    frames, _truth = security_video(n_frames=10, motion_frames=5, seed=1)
    casc = fa_cascade(smoke=True)
    X, y, _ = face_dataset(n_per_class=80, seed=3)
    nn = train_face_nn(X, y, steps=60)
    sf, st, ad = fa_scan(True)
    ex = FaceAuthExecutor(casc, nn, frames.shape[1], frames.shape[2],
                          scale_factor=sf, step=st, adaptive=ad)
    ex.calibrate(frames)
    fj = jnp.asarray(frames)
    base = ex(fj)
    offs = {(cut, bits): FaceAuthOffloadExecutor(ex, cut, bits=bits)
            for cut in FA_CUTS for bits in (None, 8)}
    return ex, fj, base, offs


def _assert_result_equal(a, b, fields=_RESULT_FIELDS):
    for f in fields:
        assert np.array_equal(np.asarray(getattr(a, f)),
                              np.asarray(getattr(b, f))), f


class TestFaceAuthOffload:
    @pytest.mark.parametrize("cut", FA_CUTS)
    def test_raw_split_is_bitexact_vs_fused(self, fa_setup, cut):
        """bits=None: node+cloud = the fused funnel, field for field."""
        ex, fj, base, offs = fa_setup
        res, payload = offs[(cut, None)](fj)
        _assert_result_equal(base, res)
        assert payload.cut == cut and payload.bits is None

    def test_wire_bytes_shrink_down_the_funnel(self, fa_setup):
        """Measured (valid-element) bytes must shrink at every stage —
        the paper's data-reduction funnel, observed on the wire."""
        ex, fj, base, offs = fa_setup
        b = {cut: offs[(cut, 8)].encode(fj).nbytes() for cut in FA_CUTS}
        assert b["sensor"] > b["motion"] > b["vj"] > b["nn"]

    def test_capacity_vs_measured_gap(self, fa_setup):
        """Valid-element accounting charges less than the static padded
        size whenever capacity padding exists (the compaction win)."""
        ex, fj, base, offs = fa_setup
        pay = offs[("vj", 8)].encode(fj)
        assert pay.nbytes() < pay.capacity_bytes()

    def test_codec_bits_halve_wire_bytes(self, fa_setup):
        ex, fj, base, offs = fa_setup
        b8 = offs[("vj", 8)].encode(fj).nbytes()
        b4 = FaceAuthOffloadExecutor(ex, "vj", bits=4).encode(fj).nbytes()
        braw = offs[("vj", None)].encode(fj).nbytes()
        assert b8 < 0.30 * braw            # int8 vs f32: ~4x + sideband
        assert b4 < 0.65 * b8              # nibbles halve the codec bytes

    def test_nn_cut_int8_scores_preserve_auth_decisions(self, fa_setup):
        """The §III 'ship the decision' cut: int8-coded scores keep every
        auth decision (auth bits ship exactly) and stay within one codec
        step of the fused scores."""
        ex, fj, base, offs = fa_setup
        res, _pay = offs[("nn", 8)](fj)
        for f in ("motion", "n_windows", "n_auth", "auth", "window_id",
                  "window_valid"):
            assert np.array_equal(np.asarray(getattr(base, f)),
                                  np.asarray(getattr(res, f))), f
        d = np.abs(np.asarray(base.scores) - np.asarray(res.scores)).max()
        assert d < 1.0 / 127                # one int8 step of a [0,1] score

    def test_measured_bytes_match_analytic_descriptors(self, fa_setup):
        """Satellite drift fence: the hand-entered bytes_out/selectivity
        tables in the cost model must agree with what the runtime actually
        puts on the wire (8-bit codec ~ the paper's 8-bit pixels), within
        codec scale + sideband overhead."""
        ex, fj, base, offs = fa_setup
        stats = FAWorkloadStats(
            n_frames=int(fj.shape[0]),
            motion_frames=max(int(np.asarray(base.motion).sum()), 1),
            windows_to_nn=max(int(np.asarray(base.n_windows).sum()), 1))
        pipe = fa_pipeline(stats)
        n = int(fj.shape[0])
        for cut in ("sensor", "motion", "vj"):
            measured = offs[(cut, 8)].encode(fj).nbytes() / n
            analytic = pipe.cut_payload_bytes(pipe.index(cut))
            assert measured == pytest.approx(analytic, rel=0.10), cut
        # the post-NN payload is sideband-dominated; both must be tiny
        # (the paper ships a 1-bit decision)
        assert offs[("nn", 8)].encode(fj).nbytes() / n < 150
        assert pipe.cut_payload_bytes(pipe.index("nn")) < 1


class TestVROffload:
    @pytest.fixture(scope="class")
    def vr_setup(self):
        from repro.camera.bssa import GridSpec
        from repro.camera.pipelines import VRRigExecutor
        from repro.camera.synthetic import stereo_pair

        views = [stereo_pair(h=48, w=64, max_disp=4, seed=2 + s)[:2]
                 for s in range(2)]
        lefts = jnp.stack([v[0] for v in views])
        rights = jnp.stack([v[1] for v in views])
        base = VRRigExecutor(GridSpec(sigma_spatial=8), max_disp=4,
                             n_iters=2, rig_parallel=False)
        lp0, rp0, _d = base(lefts, rights)
        return base, lefts, rights, lp0, rp0

    @pytest.mark.parametrize("cut", VROffloadExecutor.CUTS)
    def test_raw_split_is_bitexact(self, vr_setup, cut):
        base, lefts, rights, lp0, rp0 = vr_setup
        off = VROffloadExecutor(base, cut, bits=None)
        (lp, rp), pay = off(lefts, rights)
        assert np.array_equal(np.asarray(lp0), np.asarray(lp))
        assert np.array_equal(np.asarray(rp0), np.asarray(rp))
        assert pay.nbytes() > 0

    def test_knee_on_panorama(self, vr_setup):
        base, lefts, rights, lp0, rp0 = vr_setup
        err = {}
        for bits in (8, 4):
            (lp, _rp), _ = VROffloadExecutor(base, "capture",
                                             bits=bits)(lefts, rights)
            err[bits] = float(jnp.abs(lp - lp0).max())
        assert err[8] < 0.02               # 8-bit views: sub-1% panorama
        assert err[4] > err[8]             # 4-bit is past the knee

    def test_depth_cut_ships_more_than_capture(self, vr_setup):
        """The runtime surfaces what the linear cost model hides: the §IV
        stitch consumes full-res views, so the mid-pipeline cut ships
        views + depths > raw views."""
        base, lefts, rights, *_ = vr_setup
        b_cap = VROffloadExecutor(base, "capture",
                                  bits=8).encode(lefts, rights).nbytes()
        b_dep = VROffloadExecutor(base, "depth",
                                  bits=8).encode(lefts, rights).nbytes()
        assert b_dep > b_cap


class TestLinkSimulator:
    def test_energy_is_bytes_times_jpb(self):
        tr = np.array([[1000.0, 500.0, 0.0]])
        rep = simulate_shared_link(tr, BACKSCATTER, frame_period_s=1.0)
        assert rep.joules == pytest.approx(1500.0 * BACKSCATTER.joules_per_byte)
        assert rep.joules == pytest.approx(
            3 * link_energy_w(500.0, 1.0, BACKSCATTER))

    def test_uncontended_latency_is_serialization_time(self):
        link = LinkProfile("l", bytes_per_s=1000.0, latency_s=0.01)
        rep = simulate_shared_link(np.array([[100.0] * 5]), link,
                                   frame_period_s=1.0)
        assert rep.latency_s == pytest.approx(0.11)      # 0.01 + 100/1000
        assert rep.utilization < 0.2

    def test_contention_grows_latency(self):
        link = LinkProfile("l", bytes_per_s=1000.0)
        lat = {}
        for n in (1, 4, 8):
            tr = np.full((n, 20), 400.0)
            lat[n] = simulate_shared_link(tr, link, 1.0).mean_latency_s
        assert lat[1] < lat[4] < lat[8]

    def test_oversubscription_queues_unboundedly(self):
        link = LinkProfile("l", bytes_per_s=1000.0)
        tr = np.full((4, 30), 500.0)       # offered 2x capacity
        rep = simulate_shared_link(tr, link, 1.0)
        assert rep.utilization == pytest.approx(1.0, abs=0.05)
        # queueing: the last frame waits ~half the trace duration
        assert rep.max_latency_s > 10.0
        assert rep.realtime_fraction(1.0) < 0.2

    def test_duty_scales_offered_load(self):
        link = LinkProfile("l", bytes_per_s=1000.0)
        tr = np.full((4, 30), 500.0)
        busy = simulate_shared_link(tr, link, 1.0, duty=1.0)
        idle = simulate_shared_link(tr, link, 1.0, duty=0.4)
        assert idle.mean_latency_s < busy.mean_latency_s
        assert idle.offered_bps == pytest.approx(busy.offered_bps * 0.4)

    def test_zero_byte_frames_transmit_nothing(self):
        """A quiet frame (0 B after the motion cut) keys up no radio:
        no framing latency, no queue occupancy, no energy."""
        link = LinkProfile("l", bytes_per_s=1000.0, latency_s=0.01,
                           joules_per_byte=1e-6)
        rep = simulate_shared_link(np.array([[0.0, 100.0, 0.0]]), link, 1.0)
        assert rep.latency_s[0, 0] == 0.0 and rep.latency_s[0, 2] == 0.0
        assert rep.latency_s[0, 1] == pytest.approx(0.11)
        assert rep.joules == pytest.approx(100.0 * 1e-6)
        all_quiet = simulate_shared_link(np.zeros((4, 10)), link, 1.0)
        assert all_quiet.utilization == 0.0
        assert all_quiet.joules == 0.0

    def test_conservation(self):
        link = LinkProfile("l", bytes_per_s=123.0)
        tr = np.array([[10.0, 20.0], [30.0, 40.0]])
        rep = simulate_shared_link(tr, link, 1.0)
        assert rep.bytes_total == 100.0
        assert rep.latency_s.shape == (2, 2)
        assert np.all(rep.latency_s > 0)

    def test_zero_length_trace(self):
        """A zero-frame trace (e.g. a cut probed before any frame arrives)
        must yield a well-formed all-zero report, not NaNs or div-by-zero."""
        link = LinkProfile("l", bytes_per_s=1000.0, latency_s=0.01,
                           joules_per_byte=1e-6)
        for n_streams in (1, 3):
            rep = simulate_shared_link(np.zeros((n_streams, 0)), link, 1.0)
            assert rep.latency_s.shape == (n_streams, 0)
            assert rep.bytes_total == 0.0
            assert rep.joules == 0.0
            assert rep.utilization == 0.0
            assert rep.delivered_fps == 0.0
            assert np.isfinite(rep.offered_bps)

    def test_single_stream_fifo_ordering(self):
        """One stream, one oversized frame: later frames queue behind it in
        arrival order, each starting exactly when its predecessor drains."""
        link = LinkProfile("l", bytes_per_s=1000.0)   # zero framing latency
        rep = simulate_shared_link(np.array([[2500.0, 100.0, 100.0]]),
                                   link, frame_period_s=1.0)
        # frame 0: arrives t=0, serializes 2.5 s
        assert rep.latency_s[0, 0] == pytest.approx(2.5)
        # frame 1: arrives t=1, waits until 2.5, drains by 2.6
        assert rep.latency_s[0, 1] == pytest.approx(1.6)
        # frame 2: arrives t=2, waits until 2.6, drains by 2.7 — FIFO, so
        # completion order matches arrival order even under queueing
        assert rep.latency_s[0, 2] == pytest.approx(0.7)
        done = np.arange(3) + rep.latency_s[0]
        assert np.all(np.diff(done) > 0)

    def test_subbyte_payload_still_charged(self):
        """A payload whose valid-element bytes round to zero (e.g. a lone
        bool sideband: 1/8 B) is still a transmission — framing latency and
        energy are charged; only exactly-0.0 B frames ride free."""
        link = LinkProfile("l", bytes_per_s=1000.0, latency_s=0.01,
                           joules_per_byte=1e-6)
        tiny = 1.0 / 8.0                       # one bool flag on the wire
        rep = simulate_shared_link(np.array([[tiny]]), link, 1.0)
        assert rep.latency_s[0, 0] == pytest.approx(0.01 + tiny / 1000.0)
        assert rep.joules == pytest.approx(tiny * 1e-6)
        zero = simulate_shared_link(np.array([[0.0]]), link, 1.0)
        assert zero.latency_s[0, 0] == 0.0 and zero.joules == 0.0


class _FakeSplitExec:
    """Deterministic stand-in with the split-executor protocol, for
    controller tests that must not depend on wall-clock noise."""

    def __init__(self, cut, wire_bytes):
        self.cut = cut
        self._b = float(wire_bytes)

    def encode(self, frames):
        return WirePayload(cut=self.cut, bits=8,
                           arrays={"x": jnp.zeros((1,))}, meta={},
                           wire_b=jnp.asarray(self._b, jnp.float32))

    def decode_run(self, payload):
        return jnp.zeros(())


class TestCutController:
    def _template(self):
        return linear_pipeline("toy", [
            dict(name="src", flops=0, bytes_in=0, bytes_out=1000,
                 kind="source"),
            dict(name="filt", flops=1e3, bytes_in=1000, bytes_out=200,
                 kind="optional", selectivity=0.5),
            dict(name="heavy", flops=1e6, bytes_in=200, bytes_out=10),
        ])

    def _profiles(self):
        return {
            "src": HardwareProfile("s", p_active_w=10e-6, p_leak_w=10e-6),
            "filt": HardwareProfile("f", flops_per_s=1e6, p_active_w=20e-6,
                                    p_leak_w=5e-6),
            "heavy": HardwareProfile("h", flops_per_s=1e6, p_active_w=100e-6,
                                     p_leak_w=50e-6),
        }

    def _controller(self, wire, **kw):
        link = LinkProfile("rf", bytes_per_s=1e4, joules_per_byte=1e-7)
        return CutController(
            lambda cut: _FakeSplitExec(cut, wire[cut]),
            cuts=("src", "filt", "heavy"), template=self._template(),
            profiles=self._profiles(), link=link, **kw)

    def test_fit_reproduces_measured_bytes_exactly(self):
        wire = {"src": 1000.0, "filt": 120.0, "heavy": 7.0}
        ctl = self._controller(wire, regime="energy")
        ctl.calibrate(jnp.zeros((4, 2, 2)))
        pipe = ctl.measured_pipeline()
        for cut, b in wire.items():
            got = pipe.cut_payload_bytes(pipe.index(cut))
            assert got == pytest.approx(b / 4.0), cut    # per unit (4 frames)

    def test_chosen_cut_is_exhaustive_measured_optimum(self):
        wire = {"src": 4000.0, "filt": 120.0, "heavy": 7.0}
        ctl = self._controller(wire, regime="energy",
                               duties={"src": 1.0, "filt": 1.0, "heavy": 1.0})
        ctl.calibrate(jnp.zeros((4, 2, 2)))
        rep = ctl.report()
        assert rep.chosen_cut == rep.measured_best_cut
        assert rep.agrees
        assert rep.chosen_cut == min(rep.measured_objectives,
                                     key=rep.measured_objectives.get)

    def test_measured_bytes_flip_the_decision(self):
        """If the wire says filtering does NOT shrink the payload, the
        controller must stop cutting late — the loop is actually closed."""
        duties = {"src": 1.0, "filt": 1.0, "heavy": 1.0}
        shrink = {"src": 4000.0, "filt": 120.0, "heavy": 7.0}
        ctl = self._controller(shrink, regime="energy", duties=duties)
        ctl.calibrate(jnp.zeros((4, 2, 2)))
        choice_shrink = ctl.report().chosen_cut
        bloat = {"src": 40.0, "filt": 4000.0, "heavy": 4000.0}
        ctl2 = self._controller(bloat, regime="energy", duties=duties)
        ctl2.calibrate(jnp.zeros((4, 2, 2)))
        choice_bloat = ctl2.report().chosen_cut
        assert choice_shrink != choice_bloat
        assert choice_bloat == "src"

    def test_byte_scale_extrapolation(self):
        wire = {"src": 100.0, "filt": 50.0, "heavy": 10.0}
        ctl = self._controller(wire, regime="throughput", byte_scale=10.0)
        ctl.calibrate(jnp.zeros((4, 2, 2)))
        pipe = ctl.measured_pipeline()
        assert pipe.cut_payload_bytes(pipe.index("src")) == pytest.approx(
            10.0 * 100.0 / 4.0)

    def test_fa_controller_end_to_end(self, fa_setup):
        """On the live §III funnel: solver choice == measured optimum, and
        the analytic model's predicted ranking matches the measured one."""
        ex, fj, base, offs = fa_setup
        stats = FAWorkloadStats(
            n_frames=int(fj.shape[0]),
            motion_frames=max(int(np.asarray(base.motion).sum()), 1),
            windows_to_nn=max(int(np.asarray(base.n_windows).sum()), 1))
        cal = calibrate_fa(stats)
        profiles = fa_profiles()
        profiles["nn"] = cal.nn_profile()
        link = dataclasses.replace(
            BACKSCATTER, joules_per_byte=cal.rf_joules_per_byte)
        ctl = CutController(
            lambda cut: offs[(cut, 8)], cuts=FA_CUTS,
            template=fa_pipeline(stats), profiles=profiles, link=link,
            regime="energy",
            duties={"sensor": 1.0, "motion": 1.0, "vj": 0.0, "nn": 1.0})
        ctl.calibrate(fj)
        rep = ctl.report()
        assert rep.agrees
        assert rep.rank_agreement >= 0.8
        # measured payloads reproduce through the fitted pipeline
        mp = rep.measured_pipeline
        for m in rep.measurements:
            assert mp.cut_payload_bytes(mp.index(m.cut)) == pytest.approx(
                m.bytes_per_unit)
