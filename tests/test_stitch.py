"""Stitch-layer regression tests: seam continuity across feathered tile
boundaries, vectorized-vs-seed-loop parity, batched warp semantics."""

import typing
from typing import Optional

import numpy as np
import pytest
import jax.numpy as jnp

from repro.camera.stitch import (
    cylindrical_warp, feather_blend, feather_ramp, stereo_panorama,
    stitch_ring)
from repro.camera.synthetic import stereo_pair


class TestFeather:
    def test_overlap_weights_sum_to_one(self):
        """In every overlap region the falling ramp of tile i plus the
        rising ramp of tile i+1 is exactly 1 — no seam brightening."""
        for w, overlap in [(128, 19), (96, 14), (64, 8)]:
            ramp = np.asarray(feather_ramp(w, overlap))
            np.testing.assert_allclose(ramp[-overlap:] + ramp[:overlap],
                                       1.0, atol=1e-6)

    def test_seam_continuity_reconstructs_shared_content(self):
        """Tiles cut with overlap from one strip blend back to the strip:
        agreeing content must pass through the seams untouched, with no
        NaNs at tile boundaries."""
        rng = np.random.default_rng(0)
        h, w, overlap, n = 32, 60, 12, 4
        step = w - overlap
        strip = rng.random((h, step * (n - 1) + w)).astype(np.float32)
        tiles = jnp.stack([jnp.asarray(strip[:, i * step:i * step + w])
                           for i in range(n)])
        out = np.asarray(feather_blend(tiles, overlap))
        assert np.isfinite(out).all()
        # the outermost columns carry zero feather weight by construction
        np.testing.assert_allclose(out[:, 1:-1], strip[:, 1:-1], atol=1e-5)

    def test_blend_matches_seed_loop(self):
        """The one-scatter blend == the seed per-tile Python loop."""
        rng = np.random.default_rng(1)
        h, w, overlap, n = 24, 48, 7, 3
        tiles = [jnp.asarray(rng.random((h, w), np.float32))
                 for _ in range(n)]
        step = w - overlap
        total_w = step * (n - 1) + w
        canvas = jnp.zeros((h, total_w))
        weight = jnp.zeros((h, total_w))
        ramp = feather_ramp(w, overlap)
        for i, tile in enumerate(tiles):
            x0 = i * step
            canvas = canvas.at[:, x0:x0 + w].add(tile * ramp)
            weight = weight.at[:, x0:x0 + w].add(ramp)
        seed = canvas / jnp.maximum(weight, 1e-6)
        np.testing.assert_allclose(np.asarray(feather_blend(tiles, overlap)),
                                   np.asarray(seed), atol=1e-6)


class TestStitchRing:
    def test_no_nans_at_tile_boundaries(self):
        views = [stereo_pair(h=48, w=64, seed=s)[0] for s in range(4)]
        pano = np.asarray(stitch_ring(views))
        assert np.isfinite(pano).all()

    def test_focal_annotation_is_optional(self):
        """Regression for the `focal: float = None` annotation."""
        hints = typing.get_type_hints(stitch_ring)
        assert hints["focal"] == Optional[float]

    def test_list_and_batched_inputs_agree(self):
        views = [stereo_pair(h=40, w=56, seed=s)[0] for s in range(3)]
        a = stitch_ring(views)
        b = stitch_ring(jnp.stack([jnp.asarray(v) for v in views]))
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=0)

    def test_batched_warp_equals_per_view(self):
        views = jnp.stack([jnp.asarray(stereo_pair(h=40, w=56, seed=s)[0])
                           for s in range(3)])
        batched = cylindrical_warp(views, 44.8)
        for i in range(3):
            np.testing.assert_allclose(
                np.asarray(batched[i]),
                np.asarray(cylindrical_warp(views[i], 44.8)), atol=0)


class TestStereoPanorama:
    def test_matches_seed_loop_semantics(self):
        """The batched disparity re-projection == the seed per-view loop
        (per-view max, int32 shift, clipped gather)."""
        views = [stereo_pair(h=40, w=56, seed=s)[0] for s in range(3)]
        depths = [jnp.asarray(stereo_pair(h=40, w=56, seed=s)[2])
                  for s in range(3)]
        lp, rp = stereo_panorama(views, views, depths, ipd_px=6.0)
        shifted = []
        for v, d in zip(views, depths):
            dmax = float(jnp.maximum(jnp.max(d), 1e-6))
            shift = (6.0 * (d / dmax)).astype(jnp.int32)
            xs = jnp.clip(jnp.arange(v.shape[1])[None, :] - shift, 0,
                          v.shape[1] - 1)
            shifted.append(jnp.take_along_axis(jnp.asarray(v), xs, axis=1))
        ref_rp = stitch_ring(shifted)
        np.testing.assert_allclose(np.asarray(rp), np.asarray(ref_rp),
                                   atol=1e-6)
        assert np.isfinite(np.asarray(lp)).all()
        assert np.isfinite(np.asarray(rp)).all()