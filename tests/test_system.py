"""End-to-end behaviour tests for the paper's system (replaces placeholder).

The system-level claims, each as an executable assertion:
  1. the placement solver reproduces the paper's §III decision (offload the
     NN, keep the filters) and flips when comm gets ~2.68x dearer;
  2. the §IV decision (only FPGA full pipeline is real-time) and flips at
     400 GbE;
  3. cascade serving bounds big-model load with static capacity;
  4. the serving engine generates consistently with teacher forcing.
"""

import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.camera.pipelines import (
    FAWorkloadStats, VRRigExecutor, VRWorkloadStats, calibrate_fa,
    fa_pipeline, fa_profiles, vr_pipeline, vr_profiles)
from repro.configs.registry import SMOKE_CONFIGS
from repro.core.costmodel import (
    ARM_A9, ETH_25G, ETH_400G, HardwareProfile, VIRTEX_FPGA, ZYNQ_FPGA,
    throughput_cost)
from repro.core.placement import solve_cut
from repro.models.transformer import Model
from repro.serve.engine import SamplerConfig, cascade_serve, generate


@pytest.fixture(scope="module")
def fa_setup():
    stats = FAWorkloadStats()
    cal = calibrate_fa(stats)
    pipe = fa_pipeline(stats)
    profiles = fa_profiles()
    profiles["nn"] = cal.nn_profile()
    duties = {"sensor": 1.0, "motion": 1.0, "vj": 0.0, "nn": 1.0}
    return stats, cal, pipe, profiles, duties


class TestPaperDecisions:
    def test_fa_solver_offloads_nn(self, fa_setup):
        _, cal, pipe, profiles, duties = fa_setup
        sol = solve_cut(pipe, profiles, cal.rf_link(), regime="energy",
                        duties=duties)
        assert sol.cut_after == "vj"
        assert set(sol.pipeline.optional_names) >= {"motion"}

    def test_fa_decision_flips_at_2p68x(self, fa_setup):
        _, cal, pipe, profiles, duties = fa_setup
        dear = HardwareProfile("rf", joules_per_byte=cal.rf_joules_per_byte * 3.0)
        sol = solve_cut(pipe, profiles, dear, regime="energy", duties=duties)
        assert sol.cut_after == "nn"      # in-camera NN wins past 2.68x

    def test_vr_only_fpga_realtime(self):
        # the passing "FPGA" configuration is the Table II production target
        # (Virtex US+, 682 compute units); the Zynq is the 2-camera eval SoC
        pipe = vr_pipeline(VRWorkloadStats())
        for dev, expect in [(ARM_A9, False), (VIRTEX_FPGA, True)]:
            rep = throughput_cost(pipe, vr_profiles(dev), ETH_25G, "stitch")
            comm_fps = ETH_25G.link_bw / (8 * pipe.cut_payload_bytes(
                pipe.index("stitch")))
            assert (min(rep.compute_fps, comm_fps) >= 30.0) == expect

    def test_vr_flips_at_400gbe(self):
        pipe = vr_pipeline(VRWorkloadStats())
        raw = 16 * pipe.cut_payload_bytes(0) / 2
        assert ETH_25G.link_bw / raw < 30.0       # must process in-camera
        assert ETH_400G.link_bw / raw > 300.0     # offload wins again (~395)

    def test_vr_measured_fps_ordering_matches_fig14(self):
        """The measured fused-executor-vs-seed-oracle FPS direction must
        agree with the fig14 ladder direction (accelerated depth wins) —
        so cost model and measurement can't silently diverge."""
        import time

        from repro.camera.bssa import GridSpec, bssa_depth_ref
        from repro.camera.synthetic import stereo_pair

        pairs = [stereo_pair(h=48, w=64, seed=s) for s in range(2)]
        lefts = jnp.stack([jnp.asarray(p[0]) for p in pairs])
        rights = jnp.stack([jnp.asarray(p[1]) for p in pairs])
        spec = GridSpec(sigma_spatial=8)

        ex = VRRigExecutor(spec, max_disp=8, n_iters=4)
        ex.depth_maps(lefts, rights).block_until_ready()   # compile + warm
        t0 = time.time()
        ex.depth_maps(lefts, rights).block_until_ready()
        fused_fps = 2 / (time.time() - t0)

        bssa_depth_ref(lefts[0], rights[0], spec, 8, 4).block_until_ready()
        t0 = time.time()
        for i in range(2):
            o = bssa_depth_ref(lefts[i], rights[i], spec, 8, 4)
        o.block_until_ready()
        oracle_fps = 2 / (time.time() - t0)

        pipe = vr_pipeline(VRWorkloadStats())
        model_fps = {}
        for name, dev in [("cpu_depth", ARM_A9), ("fpga_depth", VIRTEX_FPGA)]:
            rep = throughput_cost(pipe, vr_profiles(dev), ETH_25G, "stitch")
            comm = ETH_25G.link_bw / (8 * pipe.cut_payload_bytes(
                pipe.index("stitch")))
            model_fps[name] = min(rep.compute_fps, comm)

        model_says_accel_wins = model_fps["fpga_depth"] > model_fps["cpu_depth"]
        measured_says_accel_wins = fused_fps > oracle_fps
        assert measured_says_accel_wins == model_says_accel_wins
        assert model_says_accel_wins        # fig14: only FPGA BSSA is real-time


class TestServing:
    @pytest.fixture(scope="class")
    def model(self):
        cfg = dataclasses.replace(SMOKE_CONFIGS["yi-9b"],
                                  param_dtype=jnp.float32)
        m = Model(cfg)
        return m, m.init(jax.random.PRNGKey(0))

    def test_greedy_generation_consistent_with_forward(self, model):
        m, params = model
        prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                    m.cfg.vocab)
        toks = generate(m, params, prompt, 4)
        full = jnp.concatenate([prompt, toks], axis=1)
        logits, _ = m.logits(params, full)
        assert jnp.array_equal(jnp.argmax(logits[:, 7], -1).astype(jnp.int32),
                               toks[:, 0])

    def test_cascade_serve_bounds_big_model_load(self, model):
        m, params = model
        reqs = jax.random.randint(jax.random.PRNGKey(2), (16, 8), 0,
                                  m.cfg.vocab)
        calls = {"b": 0}

        def scorer(batch):
            return jnp.linspace(0, 1, batch.shape[0])

        def big(batch):
            calls["b"] = batch.shape[0]
            return jnp.ones((batch.shape[0], 4), jnp.int32)

        out, served, stats = cascade_serve(scorer, big, reqs, threshold=0.5,
                                           capacity_fraction=0.25)
        assert calls["b"] == 4            # static capacity: 25% of 16
        assert int(stats["n_served"]) <= 4
        assert out.shape == (16, 4)
