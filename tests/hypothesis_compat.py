"""`hypothesis` if available, else a tiny deterministic fallback.

Offline machines (no pip, no wheel cache) must still *collect and run* the
tier-1 suite.  The fallback replays each ``@given`` test on a fixed, seeded
set of examples drawn from the declared strategies — weaker than real
property testing, but the invariants stay exercised instead of the whole
module failing at import.

Only the strategy surface this repo uses is implemented: ``integers``,
``floats``, ``lists``.
"""

from __future__ import annotations

import random

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    _N_EXAMPLES = 5

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def draw(rng):
                n = rng.randint(min_size, max_size)
                return [elements.example(rng) for _ in range(n)]

            return _Strategy(draw)

    st = _Strategies()

    def settings(**_kwargs):
        def deco(fn):
            return fn

        return deco

    def given(*strategies):
        def deco(fn):
            # no functools.wraps: pytest must see the (*args, **kwargs)
            # signature, not the original one, or it would treat the
            # strategy-filled parameters as fixtures.
            def wrapper(*args, **kwargs):
                rng = random.Random(0)
                for _ in range(_N_EXAMPLES):
                    fn(*args, *[s.example(rng) for s in strategies], **kwargs)

            wrapper.__name__ = getattr(fn, "__name__", "given_test")
            wrapper.__doc__ = fn.__doc__
            return wrapper

        return deco
