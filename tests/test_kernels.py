"""Per-kernel allclose vs ref.py oracles, sweeping shapes/dtypes
(assignment deliverable c).  All kernels run interpret=True on CPU; TPU is
the lowering target."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis_compat import given, settings, st

from repro.kernels.flash_attention.kernel import flash_attention_bhsd
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.integral_image.ops import integral_image as integral_kernel
from repro.kernels.integral_image.ref import integral_ref
from repro.kernels.bilateral_blur.kernel import bilateral_blur_pallas
from repro.kernels.bilateral_blur.ref import blur_ref
from repro.kernels.haar_frontend.kernel import haar_stage_scores_pallas
from repro.kernels.haar_frontend.ref import haar_stage_scores_ref
from repro.kernels.quant_matmul.ops import (
    nn_forward_quantized, quant_matmul, quant_matmul_static, quantize_nn,
    symmetric_quantize)
from repro.kernels.quant_matmul.ref import quant_matmul_ref
from repro.kernels.rwkv_scan.ops import rwkv_wkv
from repro.kernels.rwkv_scan.ref import wkv_ref


class TestFlashAttention:
    @pytest.mark.parametrize("BH,s,d,dtype", [
        (4, 256, 64, jnp.float32),
        (2, 512, 128, jnp.float32),
        (2, 384, 64, jnp.bfloat16),
        (1, 128, 256, jnp.float32),
    ])
    def test_causal_allclose(self, BH, s, d, dtype):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (BH, s, d), dtype)
        k = jax.random.normal(ks[1], (BH, s, d), dtype)
        v = jax.random.normal(ks[2], (BH, s, d), dtype)
        out = flash_attention_bhsd(q, k, v, causal=True, block_q=128,
                                   block_k=128, interpret=True)
        ref = attention_ref(q, k, v, causal=True)
        tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32), atol=tol, rtol=tol)

    @pytest.mark.parametrize("window", [64, 128, 256])
    def test_sliding_window(self, window):
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q, k, v = (jax.random.normal(kk, (2, 512, 64)) for kk in ks)
        out = flash_attention_bhsd(q, k, v, causal=True, window=window,
                                   block_q=128, block_k=128, interpret=True)
        ref = attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)

    def test_gqa_wrapper_matches_expanded(self):
        ks = jax.random.split(jax.random.PRNGKey(2), 3)
        b, s, H, KV, d = 2, 256, 8, 2, 64
        q = jax.random.normal(ks[0], (b, s, H, d))
        k = jax.random.normal(ks[1], (b, s, KV, d))
        v = jax.random.normal(ks[2], (b, s, KV, d))
        out = flash_attention(q, k, v, causal=True, interpret=True)
        kf = jnp.repeat(k, H // KV, axis=2)
        vf = jnp.repeat(v, H // KV, axis=2)
        ref = attention_ref(
            jnp.moveaxis(q, 2, 1).reshape(b * H, s, d),
            jnp.moveaxis(kf, 2, 1).reshape(b * H, s, d),
            jnp.moveaxis(vf, 2, 1).reshape(b * H, s, d), causal=True)
        ref = jnp.moveaxis(ref.reshape(b, H, s, d), 1, 2)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)

    def test_matches_model_streaming_reference(self):
        """The kernel and the model's jnp streaming path agree — the dry-run
        lowers the latter; the TPU run would use the former."""
        from repro.models.attention import _mha_streaming
        ks = jax.random.split(jax.random.PRNGKey(3), 3)
        b, s, H, d = 2, 256, 4, 64
        q = jax.random.normal(ks[0], (b, s, H, d))
        k = jax.random.normal(ks[1], (b, s, H, d))
        v = jax.random.normal(ks[2], (b, s, H, d))
        pos = jnp.arange(s, dtype=jnp.int32)
        a = _mha_streaming(q, k, v, pos, pos, 1.0 / np.sqrt(d))
        bq = flash_attention(q, k, v, causal=True, interpret=True)
        np.testing.assert_allclose(np.asarray(a), np.asarray(bq), atol=2e-5)


class TestIntegralImage:
    @pytest.mark.parametrize("shape", [(1, 32, 64), (3, 144, 176), (2, 60, 300)])
    def test_allclose(self, shape):
        img = jax.random.uniform(jax.random.PRNGKey(0), shape)
        out = integral_kernel(img, interpret=True)
        ref = integral_ref(img)
        np.testing.assert_allclose(np.asarray(out[..., 1:, 1:]), np.asarray(ref),
                                   rtol=2e-5, atol=2e-3)

    def test_streaming_equals_camera_oracle(self):
        from repro.camera.integral import integral_image as cam
        img = jax.random.uniform(jax.random.PRNGKey(1), (2, 48, 80))
        np.testing.assert_allclose(
            np.asarray(integral_kernel(img, interpret=True)),
            np.asarray(cam(img)), rtol=2e-5, atol=2e-3)

    @given(st.integers(8, 64), st.integers(8, 64))
    @settings(max_examples=10, deadline=None)
    def test_property_last_cell_is_total(self, h, w):
        img = jnp.ones((1, h, w))
        ii = integral_kernel(img, interpret=True)
        assert float(ii[0, -1, -1]) == pytest.approx(h * w, rel=1e-6)


class TestHaarFrontend:
    def _random_stage(self, seed, n, n_scales, sz, K=8, L=500):
        rng = np.random.default_rng(seed)
        return dict(
            ii_flat=jnp.asarray(rng.random(L, dtype=np.float32)),
            base=jnp.asarray(rng.integers(0, L // 2, n).astype(np.int32)),
            sid=jnp.asarray(rng.integers(0, n_scales, n).astype(np.int32)),
            inv_norm=jnp.asarray(rng.random(n, dtype=np.float32)),
            offsets=jnp.asarray(
                rng.integers(0, L // 2, (n_scales, sz, K)).astype(np.int32)),
            weights=jnp.asarray(rng.normal(size=(sz, K)).astype(np.float32)),
            thresholds=jnp.asarray(rng.normal(size=sz).astype(np.float32)),
            polarity=jnp.asarray(
                np.where(rng.random(sz) < 0.5, -1.0, 1.0).astype(np.float32)),
            alphas=jnp.asarray(rng.random(sz, dtype=np.float32)),
        )

    @pytest.mark.parametrize("n,n_scales,sz", [
        (64, 1, 8), (200, 4, 33), (37, 3, 5), (512, 10, 16),
    ])
    def test_allclose(self, n, n_scales, sz):
        kw = self._random_stage(0, n, n_scales, sz)
        ref = haar_stage_scores_ref(**kw)
        out = haar_stage_scores_pallas(**kw, block_n=128, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-5)

    def test_block_padding(self):
        """n not a multiple of block_n: padded windows must not leak."""
        kw = self._random_stage(1, 130, 2, 7)
        ref = haar_stage_scores_ref(**kw)
        out = haar_stage_scores_pallas(**kw, block_n=64, interpret=True)
        assert out.shape == (130,)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-5)

    def test_matches_detector_tables(self):
        """Kernel x real gather tables == ref on a real frame's integral."""
        from repro.camera.integral import integral_image as cam_integral
        from repro.camera.synthetic import face_dataset, security_video
        from repro.camera.viola_jones import (
            build_gather_tables, build_scan_grid, make_feature_pool,
            train_cascade)
        X, y, _ = face_dataset(n_per_class=120, seed=2)
        casc = train_cascade(X, y, make_feature_pool(n=80), n_stages=2,
                             per_stage=8, seed=0)
        frames, _ = security_video(n_frames=2, motion_frames=1, seed=3)
        grid = build_scan_grid(frames.shape[1], frames.shape[2], 1.6, 8.0, False)
        tab = build_gather_tables(casc, grid)
        iif = cam_integral(jnp.asarray(frames[1])).reshape(-1)
        sz = tab.stage_sizes[0]
        kw = dict(
            ii_flat=iif,
            base=jnp.asarray(grid.bases),
            sid=jnp.asarray(grid.scale_id),
            inv_norm=jnp.ones(len(grid.bases), jnp.float32),
            offsets=jnp.asarray(tab.offsets[:, :sz]),
            weights=jnp.asarray(tab.weights[:sz]),
            thresholds=jnp.asarray(tab.thresholds[:sz]),
            polarity=jnp.asarray(tab.polarity[:sz]),
            alphas=jnp.asarray(tab.alphas[:sz]),
        )
        ref = np.asarray(haar_stage_scores_ref(**kw))
        out = np.asarray(haar_stage_scores_pallas(**kw, interpret=True))
        # fp-borderline stumps (response within rounding of a trained
        # threshold) may flip isolated windows between the two
        # associations; demand agreement everywhere else.
        assert np.mean(np.abs(out - ref) > 1e-4) < 0.01


class TestBilateralBlur:
    @pytest.mark.parametrize("shape,bgy", [((32, 24, 17), 16), ((16, 16, 9), 16),
                                           ((64, 30, 17), 32)])
    def test_allclose(self, shape, bgy):
        val = jax.random.normal(jax.random.PRNGKey(0), shape)
        wt = jax.random.uniform(jax.random.PRNGKey(1), shape)
        va, wa = bilateral_blur_pallas(val, wt, block_gy=bgy, interpret=True)
        vb, wb = blur_ref(val, wt)
        np.testing.assert_allclose(np.asarray(va), np.asarray(vb), atol=1e-5)
        np.testing.assert_allclose(np.asarray(wa), np.asarray(wb), atol=1e-5)

    def test_mass_preserved_interior(self):
        """[1,2,1]/4 preserves the sum for constant fields (DC gain 1)."""
        val = jnp.ones((16, 8, 9))
        wt = jnp.ones((16, 8, 9))
        va, _ = bilateral_blur_pallas(val, wt, block_gy=16, interpret=True)
        np.testing.assert_allclose(np.asarray(va), 1.0, atol=1e-6)

    @pytest.mark.parametrize("shape,n_iters", [
        ((32, 24, 17), 2),      # divisible: two 16-row blocks
        ((30, 12, 9), 3),       # 30 % 16 != 0 -> block_gy falls back to 15
        ((17, 10, 9), 2),       # prime gy -> single full-height block
        ((20, 16, 9), 1),       # 20 % 16 != 0 -> falls back to 10
    ])
    def test_refine_grid_matches_refine_oracle(self, shape, n_iters):
        """The wired refinement unit (ops.refine_grid, Pallas interpret) ==
        bssa.refine across grid shapes, including heights not divisible by
        block_gy — the dispatch bssa_depth now runs through."""
        from repro.camera.bssa import refine
        from repro.kernels.bilateral_blur.ops import refine_grid
        val = jax.random.normal(jax.random.PRNGKey(0), shape)
        wt = jax.random.uniform(jax.random.PRNGKey(1), shape)
        va, wa = refine_grid(val, wt, n_iters=n_iters, block_gy=16,
                             interpret=True)
        vb, wb = refine(val, wt, n_iters)
        np.testing.assert_allclose(np.asarray(va), np.asarray(vb), atol=1e-5)
        np.testing.assert_allclose(np.asarray(wa), np.asarray(wb), atol=1e-5)

    def test_refine_grid_jnp_backend_matches_oracle(self):
        """The CPU dispatch path (use_pallas=False) is the same math."""
        from repro.camera.bssa import refine
        from repro.kernels.bilateral_blur.ops import refine_grid
        val = jax.random.normal(jax.random.PRNGKey(2), (18, 31, 17))
        wt = jax.random.uniform(jax.random.PRNGKey(3), (18, 31, 17))
        va, wa = refine_grid(val, wt, n_iters=4, use_pallas=False)
        vb, wb = refine(val, wt, 4)
        np.testing.assert_allclose(np.asarray(va), np.asarray(vb), atol=1e-6)
        np.testing.assert_allclose(np.asarray(wa), np.asarray(wb), atol=1e-6)


class TestQuantMatmul:
    @pytest.mark.parametrize("m,k,n", [(64, 400, 8), (128, 128, 128),
                                       (8, 256, 16), (200, 300, 40)])
    def test_allclose(self, m, k, n):
        from repro.camera.face_nn import make_sigmoid_lut
        lut, _ = make_sigmoid_lut()
        x = jax.random.normal(jax.random.PRNGKey(0), (m, k)) * 0.5
        w = jax.random.normal(jax.random.PRNGKey(1), (k, n)) * 0.2
        y = quant_matmul(x, w, lut, apply_lut=True, interpret=True)
        xq, sx = symmetric_quantize(x)
        wq, sw = symmetric_quantize(w)
        ref = quant_matmul_ref(xq, wq, lut, scale_x=float(sx), scale_w=float(sw))
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-5)

    def test_static_asic_path(self):
        from repro.camera.face_nn import make_sigmoid_lut
        lut, _ = make_sigmoid_lut()
        x = jax.random.normal(jax.random.PRNGKey(2), (32, 400)) * 0.4
        w = jax.random.normal(jax.random.PRNGKey(3), (400, 8)) * 0.3
        xq, sx = symmetric_quantize(x)
        wq, sw = symmetric_quantize(w)
        y = quant_matmul_static(xq, wq, lut, scale_x=float(sx),
                                scale_w=float(sw), interpret=True)
        ref = quant_matmul_ref(xq, wq, lut, scale_x=float(sx), scale_w=float(sw))
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-5)

    def test_bias_and_custom_lut_meta(self):
        """Accumulator-domain bias + a non-default LUT range threaded via
        the make_sigmoid_lut meta: kernel == ref."""
        from repro.camera.face_nn import make_sigmoid_lut
        lut, meta = make_sigmoid_lut(entries=128, lo=-6.0, hi=6.0)
        x = jax.random.normal(jax.random.PRNGKey(4), (24, 96)) * 0.4
        w = jax.random.normal(jax.random.PRNGKey(5), (96, 16)) * 0.3
        bias = jax.random.normal(jax.random.PRNGKey(6), (16,))
        xq, sx = symmetric_quantize(x)
        wq, sw = symmetric_quantize(w)
        y = quant_matmul_static(xq, wq, lut, scale_x=float(sx),
                                scale_w=float(sw), bias=bias, meta=meta,
                                interpret=True)
        ref = quant_matmul_ref(xq, wq, lut, scale_x=float(sx),
                               scale_w=float(sw), bias=bias,
                               lut_lo=meta[0], lut_hi=meta[1])
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-5)
        # the meta must agree with face_nn.sigmoid_lut's own indexing
        from repro.camera.face_nn import sigmoid_lut
        z = quant_matmul_ref(xq, wq, lut, scale_x=float(sx),
                             scale_w=float(sw), bias=bias, apply_lut=False)
        np.testing.assert_allclose(np.asarray(y),
                                   np.asarray(sigmoid_lut(z, lut, meta)),
                                   atol=1e-6)

    def test_bias_with_padded_n(self):
        """n not a multiple of the block: the bias must be padded with w_q
        (regression: unpadded bias crashed the kernel's (1, n) reshape)."""
        from repro.camera.face_nn import make_sigmoid_lut
        lut, _ = make_sigmoid_lut()
        x = jax.random.normal(jax.random.PRNGKey(9), (16, 64)) * 0.4
        w = jax.random.normal(jax.random.PRNGKey(10), (64, 200)) * 0.3
        bias = jax.random.normal(jax.random.PRNGKey(11), (200,))
        xq, sx = symmetric_quantize(x)
        wq, sw = symmetric_quantize(w)
        y = quant_matmul_static(xq, wq, lut, scale_x=float(sx),
                                scale_w=float(sw), bias=bias, interpret=True)
        ref = quant_matmul_ref(xq, wq, lut, scale_x=float(sx),
                               scale_w=float(sw), bias=bias)
        assert y.shape == (16, 200)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-5)

    def test_meta_mismatch_rejected(self):
        from repro.camera.face_nn import make_sigmoid_lut
        lut, _ = make_sigmoid_lut(entries=256)
        with pytest.raises(ValueError):
            quant_matmul_static(
                jnp.zeros((8, 16), jnp.int8), jnp.zeros((16, 8), jnp.int8),
                lut, scale_x=1.0, scale_w=1.0, meta=(-8.0, 8.0, 128),
                interpret=True)


class TestNNForwardQuantized:
    """The paper's whole 400-8-1 NN on the int8 kernel (the tail of
    FaceAuthExecutor's single dispatch) vs the face_nn oracles."""

    def _setup(self, seed=0):
        from repro.camera.face_nn import init_face_nn, make_sigmoid_lut
        nn = init_face_nn(jax.random.PRNGKey(seed))
        lut, meta = make_sigmoid_lut()
        return nn, quantize_nn(nn), lut, meta

    @pytest.mark.parametrize("m", [8, 37, 130, 256])
    def test_pallas_matches_jnp_ref(self, m):
        """Kernel path (interpret) == ref.py path bit-for-bit, including
        batch sizes that are not a multiple of the block size."""
        nn, qnn, lut, meta = self._setup()
        x = jax.random.uniform(jax.random.PRNGKey(m), (m, 400))
        a = nn_forward_quantized(qnn, x, lut, meta, use_pallas=True,
                                 interpret=True)
        b = nn_forward_quantized(qnn, x, lut, meta, use_pallas=False)
        assert a.shape == (m,)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_matches_fake_quant_and_lut_oracles(self):
        """Static-scale int8 vs forward_quantized (per-tensor fake-quant)
        and forward_lut (float weights): same scores up to the
        quantization-scheme gap, well below the decision scale."""
        from repro.camera.face_nn import forward_lut, forward_quantized
        nn, qnn, lut, meta = self._setup(1)
        x = jax.random.uniform(jax.random.PRNGKey(7), (200, 400))
        y = nn_forward_quantized(qnn, x, lut, meta, use_pallas=True,
                                 interpret=True)
        y_fq = forward_quantized(nn, x, 8, lut, meta)
        y_lut = forward_lut(nn, x, lut, meta)
        assert float(jnp.abs(y - y_fq).max()) < 0.06
        assert float(jnp.abs(y - y_lut).max()) < 0.08

    def test_traceable_inside_jit_and_vmap(self):
        nn, qnn, lut, meta = self._setup(2)
        x = jax.random.uniform(jax.random.PRNGKey(8), (3, 16, 400))
        f = jax.jit(jax.vmap(
            lambda xs: nn_forward_quantized(qnn, xs, lut, meta,
                                            use_pallas=False)))
        out = f(x)
        ref = nn_forward_quantized(qnn, x.reshape(-1, 400), lut, meta,
                                   use_pallas=False)
        np.testing.assert_array_equal(np.asarray(out).reshape(-1),
                                      np.asarray(ref))


class TestRwkvScan:
    @pytest.mark.parametrize("T,chunk,dscale", [(64, 16, 2.0), (100, 32, 2.0),
                                                (96, 16, 6.0), (128, 32, 10.0)])
    def test_allclose(self, T, chunk, dscale):
        ks = jax.random.split(jax.random.PRNGKey(0), 5)
        BH, K = 4, 64
        r = jax.random.normal(ks[0], (BH, T, K)) * 0.5
        k = jax.random.normal(ks[1], (BH, T, K)) * 0.5
        v = jax.random.normal(ks[2], (BH, T, K)) * 0.5
        w = jax.nn.sigmoid(jax.random.normal(ks[3], (BH, T, K)) * dscale)
        u = jax.random.normal(ks[4], (BH, K)) * 0.3
        out = rwkv_wkv(r, k, v, w, u, chunk=chunk, interpret=True)
        ref = wkv_ref(r, k, v, w, u)
        rel = float(jnp.max(jnp.abs(out - ref)) / (jnp.max(jnp.abs(ref)) + 1e-9))
        assert rel < 2e-4, rel

    def test_matches_model_layer_semantics(self):
        """Kernel == the model's lax.scan wkv (models/ssm)."""
        from repro.models.ssm import _wkv_step
        ks = jax.random.split(jax.random.PRNGKey(1), 5)
        BH, T, K = 2, 48, 64
        r = jax.random.normal(ks[0], (BH, T, K)) * 0.5
        k = jax.random.normal(ks[1], (BH, T, K)) * 0.5
        v = jax.random.normal(ks[2], (BH, T, K)) * 0.5
        w = jax.nn.sigmoid(jax.random.normal(ks[3], (BH, T, K)) * 3)
        u = jax.random.normal(ks[4], (BH, K)) * 0.3
        out = rwkv_wkv(r, k, v, w, u, chunk=16, interpret=True)
        ref = wkv_ref(r, k, v, w, u)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)


class TestWireCodec:
    """Pallas wire codec: interpret-mode bit-exactness vs the jnp oracle,
    and int8 semantics pinned to core/reduction.quantize_int8."""

    @pytest.mark.parametrize("shape,bits", [
        ((512,), 8), ((512,), 4),
        ((3, 97), 8), ((3, 97), 4),           # needs flat-block padding
        ((7, 20, 20), 8), ((7, 20, 20), 4),   # the vj window payload shape
        ((1,), 8), ((1,), 4),
        ((40, 256), 8), ((40, 256), 4),       # needs row padding (40 % 32)
    ])
    def test_pallas_encode_decode_bitexact_vs_ref(self, shape, bits):
        from repro.kernels.wire_codec.ops import wire_decode, wire_encode

        x = jax.random.normal(jax.random.PRNGKey(0), shape) * 11.0
        p_ref, s_ref = wire_encode(x, bits=bits, use_pallas=False)
        p_pal, s_pal = wire_encode(x, bits=bits, use_pallas=True,
                                   interpret=True)
        assert np.array_equal(np.asarray(p_ref), np.asarray(p_pal))
        assert np.array_equal(np.asarray(s_ref), np.asarray(s_pal))
        y_ref = wire_decode(p_ref, s_ref, shape, bits=bits, use_pallas=False)
        y_pal = wire_decode(p_pal, s_pal, shape, bits=bits, use_pallas=True,
                            interpret=True)
        assert np.array_equal(np.asarray(y_ref), np.asarray(y_pal))

    def test_int8_roundtrip_matches_reduction_quantizer_exactly(self):
        """Wire-codec int8 == dequantize_int8(quantize_int8(x)) bit-for-bit
        (the ISSUE's shared-semantics contract)."""
        from repro.core.reduction import dequantize_int8, quantize_int8
        from repro.kernels.wire_codec.ops import (
            wire_encode, wire_roundtrip)

        x = jax.random.normal(jax.random.PRNGKey(3), (5, 333)) * 7.0
        # jit the reduction side: the codec runs inside jit regions, and
        # XLA's constant-divisor rewrite shifts eager scales by 1 ulp
        q, s = jax.jit(lambda v: quantize_int8(v, block=256))(x)
        deq = jax.jit(
            lambda a, b: dequantize_int8(a, b, x.shape))(q, s)
        for use_pallas in (False, True):
            y = wire_roundtrip(x, bits=8, use_pallas=use_pallas,
                               interpret=use_pallas)
            assert np.array_equal(np.asarray(deq), np.asarray(y))
        p, sc = wire_encode(x, bits=8, use_pallas=False)
        assert np.array_equal(np.asarray(p).reshape(-1)[: x.size],
                              np.asarray(q).reshape(-1)[: x.size])
        assert np.array_equal(np.asarray(sc), np.asarray(s))

    @pytest.mark.parametrize("bits", [4, 8, 16])
    def test_packed_values_roundtrip_exactly(self, bits):
        """decode(encode(x)) == dequantized quantization of x: the pack /
        unpack byte plumbing is lossless at every width."""
        from repro.kernels.wire_codec.ref import (
            pack_ref, quantize_blocks_ref, unpack_ref)

        x = (jax.random.normal(jax.random.PRNGKey(4), (6, 256)) * 9.0)
        q, _s = quantize_blocks_ref(x, bits)
        assert np.array_equal(np.asarray(unpack_ref(pack_ref(q, bits), bits)),
                              np.asarray(q))

    def test_zero_blocks_and_extremes(self):
        from repro.kernels.wire_codec.ops import wire_roundtrip

        x = jnp.concatenate([jnp.zeros((256,)),
                             jnp.array([127.0, -127.0, 1e-8, -1e-8]),
                             jnp.zeros((252,))])
        for bits in (4, 8, 16):
            y = wire_roundtrip(x, bits=bits, use_pallas=False)
            assert np.all(np.isfinite(np.asarray(y)))
            assert float(y[0]) == 0.0
        y8 = wire_roundtrip(x, bits=8, use_pallas=True, interpret=True)
        np.testing.assert_allclose(float(y8[256]), 127.0)
        np.testing.assert_allclose(float(y8[257]), -127.0)

    def test_wire_bytes_accounting(self):
        from repro.kernels.wire_codec.ops import wire_bytes

        # one 256-value block: bits/8 per value + one f32 scale
        assert wire_bytes(256, 8) == 256 + 4
        assert wire_bytes(256, 4) == 128 + 4
        assert wire_bytes(256, 16) == 512 + 4
        assert wire_bytes(257, 8) == 257 + 8        # second (partial) block
        assert wire_bytes(100, None) == 400.0       # raw f32 passthrough
        assert wire_bytes(0, 8) == 0.0

    def test_knee_shape_on_wire(self):
        """The §III-A knee as measured through the codec: halving bits
        halves wire bytes; error is ~flat 16->8 and jumps at 4."""
        from repro.kernels.wire_codec.ops import wire_bytes, wire_roundtrip

        x = jax.random.normal(jax.random.PRNGKey(5), (4096,))
        err = {b: float(jnp.linalg.norm(wire_roundtrip(x, bits=b,
                                                       use_pallas=False) - x))
               for b in (16, 8, 4)}
        assert err[16] < err[8] < err[4]
        assert err[4] / err[8] > 4.0                # the knee: 4-bit is past it
        assert wire_bytes(4096, 4) < wire_bytes(4096, 8) < wire_bytes(4096, 16)
