"""Per-arch smoke tests (assignment deliverable f) + decode/prefill parity.

Every assigned architecture instantiates its REDUCED config, runs one
forward/train step on CPU, and asserts output shapes + no NaNs.  The
parity tests are the deep invariant: prefill + step-by-step decode must
reproduce full-sequence logits exactly (capacity-unconstrained MoE)."""

import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.registry import CONFIGS, SMOKE_CONFIGS, input_specs, list_archs
from repro.configs.shapes import SHAPES, applicable
from repro.models.transformer import Model

ARCHS = list_archs()


def _f32_nodrop(cfg):
    kw = dict(param_dtype=jnp.float32)
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(cfg.moe, capacity_factor=16.0)
    return dataclasses.replace(cfg, **kw)


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch, rng):
    cfg = SMOKE_CONFIGS[arch]
    model = Model(cfg)
    params = model.init(rng)
    B, S = 2, 16
    batch = {"tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab)}
    if cfg.is_encdec:
        batch["enc_input"] = jax.random.normal(
            rng, (B, cfg.enc_seq, cfg.d_model), cfg.param_dtype)

    logits, aux = model.logits(params, batch["tokens"],
                               model.encode(params, batch["enc_input"])
                               if cfg.is_encdec else None)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    # one actual optimizer step
    from repro.train.optimizer import AdamWConfig, init_opt_state
    from repro.train.step import make_train_step
    step = jax.jit(make_train_step(model, AdamWConfig(warmup_steps=1)))
    opt = init_opt_state(params)
    new_params, new_opt, metrics = step(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(new_opt.step) == 1
    # params actually changed
    delta = jax.tree_util.tree_reduce(
        lambda a, l: a + float(jnp.sum(jnp.abs(l.astype(jnp.float32)))),
        jax.tree_util.tree_map(lambda a, b: a.astype(jnp.float32) - b.astype(jnp.float32),
                               new_params, params), 0.0)
    assert delta > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_parity(arch):
    cfg = _f32_nodrop(SMOKE_CONFIGS[arch])
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(42))
    B, S, EXTRA = 2, 8, 4
    toks = jax.random.randint(jax.random.PRNGKey(7), (B, S + EXTRA), 0, cfg.vocab)
    enc_out = None
    if cfg.is_encdec:
        enc_in = jax.random.normal(jax.random.PRNGKey(9),
                                   (B, cfg.enc_seq, cfg.d_model), jnp.float32)
        enc_out = model.encode(params, enc_in)
    full, _ = model.logits(params, toks, enc_out)

    pl_logits, cache = model.prefill(params, toks[:, :S], enc_out)
    errs = [float(jnp.max(jnp.abs(pl_logits - full[:, S - 1])))]
    cache = model.pad_cache(cache, EXTRA)
    for t in range(S, S + EXTRA):
        lg, cache = model.decode_step(params, toks[:, t:t + 1], cache, jnp.int32(t))
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - full[:, t]))))
    rel = max(errs) / (float(jnp.max(jnp.abs(full))) + 1e-9)
    assert rel < 1e-4, f"{arch}: prefill/decode diverges from train ({rel:.2e})"


@pytest.mark.parametrize("arch", ARCHS)
def test_param_count_matches_assignment_scale(arch):
    """FULL configs hit the advertised parameter counts (±12%)."""
    expected = {
        "mixtral-8x22b": 141e9, "deepseek-v2-236b": 236e9, "granite-34b": 34e9,
        "yi-9b": 8.8e9, "codeqwen1.5-7b": 8.0e9, "phi3-medium-14b": 14e9,
        "rwkv6-7b": 7.5e9, "whisper-medium": 0.76e9, "chameleon-34b": 34e9,
        "jamba-v0.1-52b": 52e9,
    }[arch]
    n = Model(CONFIGS[arch]).n_params()
    assert abs(n - expected) / expected < 0.12, f"{arch}: {n:.3e} vs {expected:.3e}"


def test_moe_capacity_drops_monotone():
    """Lower capacity factor => fewer tokens served, never more."""
    cfg = SMOKE_CONFIGS["mixtral-8x22b"]
    base = dataclasses.replace(cfg, param_dtype=jnp.float32)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)

    losses = {}
    for cf in (0.5, 4.0):
        c = dataclasses.replace(
            base, moe=dataclasses.replace(base.moe, capacity_factor=cf))
        m = Model(c)
        params = m.init(jax.random.PRNGKey(0))
        loss, metrics = m.loss(params, {"tokens": toks})
        losses[cf] = float(metrics["ce"])
    assert np.isfinite(losses[0.5]) and np.isfinite(losses[4.0])


def test_input_specs_cover_all_runnable_cells():
    n_cells = 0
    for arch in ARCHS:
        cfg = CONFIGS[arch]
        for name, sh in SHAPES.items():
            runs, why = applicable(cfg, sh)
            if not runs:
                assert "sub-quadratic" in why
                continue
            specs = input_specs(cfg, sh)
            n_cells += 1
            if sh.mode in ("train", "prefill"):
                assert specs["tokens"].shape == (sh.batch, sh.seq)
            else:
                assert specs["token"].shape == (sh.batch, 1)
                assert "cache" in specs
    assert n_cells == 33  # 40 - 7 long_500k skips


def test_swa_ring_cache_matches_window():
    cfg = SMOKE_CONFIGS["mixtral-8x22b"]
    model = Model(dataclasses.replace(cfg, param_dtype=jnp.float32))
    cache = model.init_cache(2, 64)
    k = cache["stack"]["sub0"]["k"]
    assert k.shape[2] == cfg.window  # ring buffer, not full seq
